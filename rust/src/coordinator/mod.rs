//! The evaluation coordinator — the L3 orchestration layer of the
//! co-design framework (paper Fig. 5).
//!
//! DSE configurations flow through a bounded job queue (backpressure)
//! into a worker pool; each worker quantizes the model under its
//! configuration (CPU-bound), obtains accuracy from the shared
//! [`AccuracyEval`] backend (the batched PJRT artifact, or the host
//! reference when artifacts are absent) and composes cycle/memory cost
//! from the per-layer [`CycleModel`]. Results are cached by
//! configuration so repeated sweeps (Fig. 6 → Fig. 8 reuse) are free.

use crate::dse::cycles::CycleModel;
use crate::dse::{total_mac_instructions, Config, EvalPoint};
use crate::error::{Error, Result};
use crate::models::format::LoadedModel;
use crate::models::infer::QModel;
use crate::models::synthetic::Dataset;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Mutex;

/// Accuracy-evaluation backend.
pub trait AccuracyEval: Send {
    /// Top-1 accuracy of `qm` over the first `n` test samples.
    fn evaluate(&mut self, qm: &QModel, n: usize) -> Result<f32>;
    /// Backend label (metrics/logs).
    fn name(&self) -> &'static str;
}

/// Host-reference evaluator: the Rust integer forward pass. Always
/// available (no artifacts needed); slower than the PJRT path.
pub struct HostEval {
    /// Evaluation set.
    pub test: Dataset,
}

impl AccuracyEval for HostEval {
    fn evaluate(&mut self, qm: &QModel, n: usize) -> Result<f32> {
        let n = n.min(self.test.images.len());
        let mut correct = 0usize;
        for (img, &label) in self.test.images.iter().zip(&self.test.labels).take(n) {
            if crate::models::infer::qpredict(qm, img) == label {
                correct += 1;
            }
        }
        Ok(correct as f32 / n as f32)
    }
    fn name(&self) -> &'static str {
        "host"
    }
}

/// PJRT evaluator: batched inference through the AOT model artifact.
pub struct PjrtEval {
    /// PJRT session (executable cache inside).
    pub session: crate::runtime::Session,
    /// Evaluation set.
    pub test: Dataset,
    /// Artifact batch size.
    pub batch: usize,
}

// SAFETY: the `xla` crate's client/executable handles are raw C
// pointers (hence !Send by default), but the PJRT CPU plugin has no
// thread affinity and the coordinator serialises every access through
// its evaluator Mutex — the value is only ever *used* by one thread at
// a time.
unsafe impl Send for PjrtEval {}

impl AccuracyEval for PjrtEval {
    fn evaluate(&mut self, qm: &QModel, n: usize) -> Result<f32> {
        let n = n.min(self.test.images.len());
        crate::runtime::evaluate_accuracy(
            &mut self.session,
            qm,
            &self.test.images[..n],
            &self.test.labels[..n],
            self.batch,
        )
    }
    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// Coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Configurations submitted.
    pub submitted: AtomicU64,
    /// Cache hits.
    pub cache_hits: AtomicU64,
    /// Accuracy evaluations executed.
    pub acc_evals: AtomicU64,
}

/// The evaluation coordinator.
pub struct Coordinator {
    /// Loaded model (spec + trained params + scales + test set).
    pub model: LoadedModel,
    /// Per-layer cycle table (ISS-measured).
    pub cycle_model: CycleModel,
    /// Model analysis (computed once).
    pub analysis: crate::models::ModelAnalysis,
    /// Per-(layer, width) quantization cache: configs assemble from
    /// these instead of re-running the MSE scale search (§Perf
    /// iteration 2 — the quantize step falls out of the sweep hot path).
    qcache: Vec<[crate::nn::QLayer; 3]>,
    evaluator: Mutex<Box<dyn AccuracyEval>>,
    cache: Mutex<HashMap<Config, f32>>,
    /// Worker threads for the sweep.
    pub workers: usize,
    /// Bounded-queue capacity (backpressure).
    pub queue_cap: usize,
    /// Metrics.
    pub metrics: Metrics,
}

impl Coordinator {
    /// Build a coordinator; measures the cycle model up front, fanning
    /// the per-layer ISS measurements out over the worker pool.
    pub fn new(
        model: LoadedModel,
        evaluator: Box<dyn AccuracyEval>,
        workers: usize,
    ) -> Result<Self> {
        let workers = workers.max(1);
        let analysis = crate::models::analyze(&model.spec);
        let cycle_model = CycleModel::build_with_workers(
            &analysis,
            crate::sim::MacUnitConfig::full(),
            0xC1C1E,
            workers,
        )?;
        let qcache = analysis
            .layers
            .iter()
            .zip(&model.params)
            .map(|(info, p)| {
                [8u32, 4, 2].map(|b| {
                    crate::nn::quantize_layer(
                        &p.w,
                        &p.b,
                        model.sites[info.site_in],
                        model.sites[info.site_out],
                        b,
                    )
                })
            })
            .collect();
        Ok(Coordinator {
            model,
            cycle_model,
            analysis,
            qcache,
            evaluator: Mutex::new(evaluator),
            cache: Mutex::new(HashMap::new()),
            workers,
            queue_cap: 64,
            metrics: Metrics::default(),
        })
    }

    /// Assemble a quantized model from the per-(layer, width) cache.
    pub fn quantized(&self, cfg: &Config) -> QModel {
        let layers = cfg
            .iter()
            .enumerate()
            .map(|(i, &b)| {
                let slot = match b {
                    8 => 0,
                    4 => 1,
                    2 => 2,
                    _ => panic!("unsupported width {b}"),
                };
                self.qcache[i][slot].clone()
            })
            .collect();
        QModel {
            spec: self.model.spec.clone(),
            analysis: self.analysis.clone(),
            layers,
            sites: self.model.sites.clone(),
            bits: cfg.clone(),
        }
    }

    /// Quantize + evaluate one configuration (cached).
    pub fn evaluate(&self, cfg: &Config, n_eval: usize) -> Result<EvalPoint> {
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let cached = self.cache.lock().unwrap().get(cfg).copied();
        let accuracy = match cached {
            Some(a) => {
                self.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                a
            }
            None => {
                let qm = self.quantized(cfg);
                self.metrics.acc_evals.fetch_add(1, Ordering::Relaxed);
                let a = self.evaluator.lock().unwrap().evaluate(&qm, n_eval)?;
                self.cache.lock().unwrap().insert(cfg.clone(), a);
                a
            }
        };
        let cost = self.cycle_model.config_total(cfg);
        Ok(EvalPoint {
            config: cfg.clone(),
            accuracy,
            mac_instructions: total_mac_instructions(&self.analysis, cfg),
            cycles: cost.cycles,
            mem_accesses: cost.mem_accesses,
        })
    }

    /// Evaluate a sweep of configurations through the worker pool
    /// (bounded queue → workers → ordered result collection).
    pub fn run_sweep(&self, configs: &[Config], n_eval: usize) -> Result<Vec<EvalPoint>> {
        let (job_tx, job_rx) = sync_channel::<(usize, Config)>(self.queue_cap);
        let job_rx = Mutex::new(job_rx);
        let results: Mutex<Vec<Option<EvalPoint>>> = Mutex::new(vec![None; configs.len()]);
        let first_err: Mutex<Option<Error>> = Mutex::new(None);

        std::thread::scope(|s| {
            for _ in 0..self.workers {
                s.spawn(|| loop {
                    let job = job_rx.lock().unwrap().recv();
                    let Ok((i, cfg)) = job else { break };
                    match self.evaluate(&cfg, n_eval) {
                        Ok(p) => results.lock().unwrap()[i] = Some(p),
                        Err(e) => {
                            let mut fe = first_err.lock().unwrap();
                            if fe.is_none() {
                                *fe = Some(e);
                            }
                        }
                    }
                });
            }
            // Producer: the bounded send blocks when workers fall behind
            // (the backpressure the architecture calls for).
            for (i, cfg) in configs.iter().enumerate() {
                if first_err.lock().unwrap().is_some() {
                    break;
                }
                job_tx.send((i, cfg.clone())).expect("workers alive");
            }
            drop(job_tx);
        });

        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }
        Ok(results.into_inner().unwrap().into_iter().map(|p| p.unwrap()).collect())
    }

    /// Cache size (distinct configurations evaluated).
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::format::load_or_fallback;
    use std::path::Path;

    fn tiny_coordinator() -> Coordinator {
        // Fallback model (no artifacts needed) + host evaluator.
        let model = load_or_fallback(Path::new("/nonexistent"), "lenet5", 11).unwrap();
        let test = model.test.clone();
        Coordinator::new(model, Box::new(HostEval { test }), 2).unwrap()
    }

    #[test]
    fn sweep_returns_ordered_points_and_caches() {
        let c = tiny_coordinator();
        let n = crate::models::analyze(&c.model.spec).layers.len();
        let configs: Vec<Vec<u32>> =
            vec![vec![8; n], vec![4; n], vec![2; n], vec![8; n] /* dup */];
        let pts = c.run_sweep(&configs, 8).unwrap();
        assert_eq!(pts.len(), 4);
        // Order preserved.
        assert_eq!(pts[0].config, configs[0]);
        assert_eq!(pts[3].config, configs[3]);
        // The duplicate hits the cache.
        assert_eq!(c.cache_len(), 3);
        assert!(c.metrics.cache_hits.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        // Cost ordering: 2-bit config must be cheapest.
        assert!(pts[2].cycles < pts[0].cycles);
        assert!(pts[2].mac_instructions < pts[0].mac_instructions);
    }

    #[test]
    fn accuracy_degrades_monotonically_in_aggregate() {
        // 8-bit should be at least as accurate as 2-bit on the fallback
        // (random-weights) model is NOT guaranteed — use a trained-free
        // structural check instead: accuracies are valid probabilities.
        let c = tiny_coordinator();
        let n = crate::models::analyze(&c.model.spec).layers.len();
        let pts = c.run_sweep(&[vec![8; n], vec![2; n]], 8).unwrap();
        for p in pts {
            assert!((0.0..=1.0).contains(&p.accuracy));
        }
    }
}
