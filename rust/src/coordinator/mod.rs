//! The evaluation coordinator — the L3 orchestration layer of the
//! co-design framework (paper Fig. 5).
//!
//! DSE configurations flow through a bounded job queue (backpressure)
//! into a worker pool. Each worker assembles the quantized model for
//! its configuration from the per-(layer, width) quantization cache,
//! obtains accuracy from the shared [`AccuracyEval`] backend —
//! concurrently across workers; `evaluate` takes `&self`, so the
//! dominant per-config cost of the ISS backend overlaps — and
//! composes the predicted cycle/memory cost from the per-layer
//! [`CycleModel`] — which is measured once, up front, on the ISS
//! micro-op engine through the pooled
//! [`SimSession`](crate::sim::session::SimSession) and the keyed kernel
//! cache ([`crate::kernels::run`]). Results are cached per
//! configuration so repeated sweeps (Fig. 6 → Fig. 8 reuse) are free.
//!
//! Four accuracy backends implement [`AccuracyEval`] (see
//! `docs/EVALUATORS.md` for the fidelity/speed trade-offs and how to
//! pick one per experiment):
//!
//! * [`HostEval`] — the Rust integer forward pass: fast, always
//!   available, but exercises none of the emulated ISA.
//! * [`IssEval`] — whole-model execution on the simulated core via
//!   [`run_model_batch`](crate::models::sim_exec::run_model_batch):
//!   accuracy, cycles and memory traffic come from the *same*
//!   binary-level runs, and a built-in differential check reports the
//!   host-vs-ISS top-1 disagreement per configuration. Kernel images
//!   come from the shared kernel cache and simulator memories from the
//!   global session pool, so per-configuration cost during sweeps
//!   stays amortised.
//! * [`AnalyticEval`] — [`IssEval`]'s analytic sibling: kernel steps
//!   run on the ISS only until the session
//!   [`CostCache`](crate::sim::session::CostCache) knows their cost
//!   key, then replay as host kernels with cache-served counters
//!   ([`ExecMode::Analytic`](crate::models::sim_exec::ExecMode)) — a
//!   batch of N inputs costs ~1 ISS execution per distinct kernel step
//!   and a warm sweep ~0, with a seeded sampled audit
//!   (`--audit-every K`) re-checking the contract on the real ISS.
//! * [`PjrtEval`] — batched inference through the AOT model artifact
//!   (needs the `pjrt` feature plus artifacts).
//!
//! Every evaluation returns an [`EvalReport`]; the coordinator folds it
//! into the [`EvalPoint`] it hands to the DSE, so ISS-measured cycles
//! and the divergence metric ride along with accuracy through the
//! whole experiment stack.
//!
//! Sweeps run exhaustively ([`Coordinator::run_sweep`], optionally
//! sharded) or guided ([`Coordinator::sweep_guided`]): the guided
//! driver prices every configuration with the already-measured
//! [`CycleModel`], runs successive-halving rungs on eval-set prefixes,
//! and full-evaluates only what the analytic bounds cannot prove
//! dominated — same points, fewer evaluations (see
//! [`crate::dse::search`]).

use crate::dse::cycles::{ClusterCost, CycleModel};
use crate::dse::{total_mac_instructions, Config, ConfigSpace, EvalPoint};
use crate::sim::cluster::ClusterConfig;
use crate::ensure;
use crate::error::{Error, Result};
use crate::models::format::LoadedModel;
use crate::models::infer::{argmax_i32, qforward, quantize_input, QModel};
use crate::models::plan::{host_logits, plan_for};
use crate::models::sim_exec::{
    audit_indices, audit_run, baseline_modes, modes_for, run_plan_batch, ExecMode,
};
use crate::models::synthetic::Dataset;
use crate::nn::tensor::Tensor;
use crate::sim::MacUnitConfig;
use crate::store::{ResultStore, StoreKey};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Mutex;

/// What one accuracy evaluation measured. `accuracy` is always
/// populated; the ISS-only fields stay `None` for backends that do not
/// execute on the simulated core.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct EvalReport {
    /// Top-1 accuracy over the evaluated samples.
    pub accuracy: f32,
    /// Mean per-input end-to-end kernel cycles, measured on the ISS by
    /// the same runs that produced `accuracy` ([`IssEval`] only).
    pub iss_cycles: Option<u64>,
    /// Mean per-input memory accesses from the same runs ([`IssEval`]).
    pub iss_mem_accesses: Option<u64>,
    /// Host-vs-backend top-1 disagreement fraction from [`IssEval`]'s
    /// differential check (`Some(0.0)` is the healthy reading).
    pub divergence: Option<f32>,
    /// Batch elements the analytic backend replayed on the real ISS
    /// for its sampled differential audit ([`AnalyticEval`] with
    /// `audit_every > 0` only). A mismatch never reaches this report —
    /// it fails the evaluation with a typed error instead.
    pub audited: Option<u32>,
}

impl EvalReport {
    /// A report carrying only an accuracy (host/PJRT backends).
    pub fn accuracy_only(accuracy: f32) -> Self {
        EvalReport { accuracy, ..Default::default() }
    }
}

/// Accuracy-evaluation backend. `evaluate` takes `&self` so the
/// coordinator's sweep workers can score configurations **in
/// parallel** — with the ISS backend the evaluation dominates
/// per-config cost, and serialising it behind a lock would idle the
/// whole pool. Backends needing exclusive state (PJRT's raw session
/// handle) serialise internally.
pub trait AccuracyEval: Send + Sync {
    /// Evaluate `qm` over the first `n` test samples.
    fn evaluate(&self, qm: &QModel, n: usize) -> Result<EvalReport>;
    /// Backend label (metrics/logs).
    fn name(&self) -> &'static str;
    /// Size of the backend's evaluation set — the `n` a full evaluation
    /// clamps to. The guided search uses this to scale its rung
    /// prefixes so the accuracy interval bounds are computed against
    /// the true full-evaluation denominator.
    fn eval_len(&self) -> usize;
    /// MAC-unit features of the simulated core the backend runs on — a
    /// component of the content-addressed result-store key
    /// ([`crate::store::StoreKey`]). Backends that never touch the core
    /// (host/PJRT: their reports carry no ISS-measured fields) keep the
    /// default full unit.
    fn mac_config(&self) -> MacUnitConfig {
        MacUnitConfig::full()
    }
}

/// Host-reference evaluator: the Rust integer forward pass. Always
/// available (no artifacts needed); fast, but blind to any divergence
/// between the host arithmetic and the emulated ISA kernels.
pub struct HostEval {
    /// Evaluation set.
    pub test: Dataset,
}

impl AccuracyEval for HostEval {
    fn evaluate(&self, qm: &QModel, n: usize) -> Result<EvalReport> {
        let n = n.min(self.test.images.len());
        ensure!(n > 0, "HostEval: empty evaluation set");
        // Lower once per evaluation and replay the plan per image —
        // going through `qpredict`/`qforward` would re-derive the plan
        // cache key (an O(model size) content fingerprint) per input.
        // Baseline modes: host logits are mode-independent, and the
        // baseline lowering stages weights as zero-copy Arc clones
        // instead of packing nn_mac word streams this evaluator would
        // never read.
        let plan = plan_for(qm, &baseline_modes(qm))?;
        let mut correct = 0usize;
        for (img, &label) in self.test.images.iter().zip(&self.test.labels).take(n) {
            let qi = quantize_input(qm, img);
            if argmax_i32(&host_logits(&plan, &qi)) == label {
                correct += 1;
            }
        }
        Ok(EvalReport::accuracy_only(correct as f32 / n as f32))
    }
    fn name(&self) -> &'static str {
        "host"
    }
    fn eval_len(&self) -> usize {
        self.test.images.len()
    }
}

/// ISS-backed evaluator: scores **execution plans, not specs** — each
/// configuration lowers once (via the keyed plan cache,
/// [`plan_for`](crate::models::plan::plan_for)) into an
/// [`ExecutionPlan`](crate::models::plan::ExecutionPlan) whose staged
/// kernels then run for every labelled input through
/// [`run_plan_batch`](crate::models::sim_exec::run_plan_batch) —
/// whole-model execution of the generated RV32 kernels on the micro-op
/// engine. Kernel images come from the keyed kernel cache and simulator
/// memories from the pooled global
/// [`SimSession`](crate::sim::session::SimSession), so per-config
/// evaluation stays cheap during sweeps.
///
/// This is the backend that makes the paper's central numbers
/// attributable to the emulated ISA extensions: top-1 accuracy, cycle
/// counts and memory traffic all come from the *same* binary-level
/// executions. A built-in differential check additionally classifies
/// every input on the host integer reference and reports the top-1
/// disagreement fraction ([`EvalReport::divergence`]) — the
/// quantization/rounding divergence this backend exists to catch.
///
/// # Example
///
/// ```no_run
/// use mpnn::coordinator::{Coordinator, IssEval};
/// use mpnn::models::format::load_or_fallback;
/// use std::path::Path;
///
/// let model = load_or_fallback(Path::new("artifacts"), "lenet5", 7).unwrap();
/// let eval = IssEval::new(model.test.clone(), 4);
/// let coord = Coordinator::new(model, Box::new(eval), 2).unwrap();
/// let n = coord.analysis.layers.len();
/// let pts = coord.run_sweep(&[vec![8; n], vec![4; n]], 16).unwrap();
/// for p in &pts {
///     println!(
///         "bits {:?}: acc {:.2}, ISS cycles {:?}, host-vs-ISS divergence {:?}",
///         p.config, p.accuracy, p.iss_cycles, p.divergence
///     );
/// }
/// ```
pub struct IssEval {
    /// Evaluation set.
    pub test: Dataset,
    /// MAC-unit features of the simulated core.
    pub mac: MacUnitConfig,
    /// Worker threads fanning the input batch over the ISS.
    pub workers: usize,
    /// Run the host-reference differential check and report
    /// [`EvalReport::divergence`]. On by default.
    pub differential: bool,
    /// Override for the model the differential check classifies on the
    /// host. `None` (the default, and the only sensible production
    /// setting) compares against the evaluated model itself; tests
    /// inject a deliberately mismatched copy to prove the divergence
    /// metric fires.
    pub reference: Option<QModel>,
}

impl IssEval {
    /// ISS evaluator with the full MAC unit and the differential check
    /// enabled.
    pub fn new(test: Dataset, workers: usize) -> Self {
        IssEval {
            test,
            mac: MacUnitConfig::full(),
            workers: workers.max(1),
            differential: true,
            reference: None,
        }
    }
}

impl AccuracyEval for IssEval {
    fn evaluate(&self, qm: &QModel, n: usize) -> Result<EvalReport> {
        let n = n.min(self.test.images.len());
        ensure!(n > 0, "IssEval: empty evaluation set");
        let inputs: Vec<Tensor<i8>> =
            self.test.images[..n].iter().map(|im| quantize_input(qm, im)).collect();
        // The configuration lowers once into an ExecutionPlan; the ISS
        // batch and the host differential check both interpret *that*
        // plan, so the two paths agree structurally by construction —
        // any residual divergence is arithmetic, which is exactly what
        // the metric exists to catch.
        let modes = modes_for(qm);
        let plan = plan_for(qm, &modes)?;
        let runs = run_plan_batch(&plan, &inputs, self.mac, ExecMode::Iss, self.workers)?;
        let mut correct = 0usize;
        let mut disagree = 0usize;
        let mut cycles = 0u64;
        let mut accesses = 0u64;
        for ((run, input), &label) in runs.iter().zip(&inputs).zip(&self.test.labels) {
            let pred = run.argmax();
            if pred == label {
                correct += 1;
            }
            if self.differential {
                let host = match self.reference.as_ref() {
                    None => host_logits(&plan, input),
                    Some(href) => qforward(href, input),
                };
                if argmax_i32(&host) != pred {
                    disagree += 1;
                }
            }
            cycles += run.total_cycles();
            accesses += run.total_accesses();
        }
        Ok(EvalReport {
            accuracy: correct as f32 / n as f32,
            iss_cycles: Some(cycles / n as u64),
            iss_mem_accesses: Some(accesses / n as u64),
            divergence: if self.differential { Some(disagree as f32 / n as f32) } else { None },
            audited: None,
        })
    }
    fn name(&self) -> &'static str {
        "iss"
    }
    fn eval_len(&self) -> usize {
        self.test.images.len()
    }
    fn mac_config(&self) -> MacUnitConfig {
        self.mac
    }
}

/// Analytic evaluator: [`IssEval`]'s fast sibling. The batch runs under
/// [`ExecMode::Analytic`] — each distinct kernel step executes on the
/// ISS only until the session's
/// [`CostCache`](crate::sim::session::CostCache) holds its counters,
/// then every further execution runs the bit-exact host kernel and
/// takes cycles/mem/instret/macs from the cache. Accuracy, cycles and
/// memory traffic come out of the same report fields as [`IssEval`],
/// and because the per-layer counters are cache-exact, a warm analytic
/// evaluation is **byte-identical** to the full-ISS one — only the
/// evaluator label differs (CI's analytic smoke asserts exactly this
/// with `audit_every = 1`).
///
/// `audit_every = K > 0` replays every Kth batch element (seeded,
/// deterministic — [`audit_indices`]) on the real ISS and bit-compares
/// logits and per-layer counters; any disagreement fails the
/// evaluation with a typed "analytic audit mismatch" error and bumps
/// `SessionStats::audit_mismatches`.
pub struct AnalyticEval {
    /// Evaluation set.
    pub test: Dataset,
    /// MAC-unit features of the simulated core.
    pub mac: MacUnitConfig,
    /// Worker threads fanning the input batch over the executors.
    pub workers: usize,
    /// Run the host-reference differential check and report
    /// [`EvalReport::divergence`]. On by default.
    pub differential: bool,
    /// Audit cadence: replay every `audit_every`-th batch element on
    /// the real ISS (0 = off, 1 = every element).
    pub audit_every: usize,
    /// Seed for the audit phase ([`audit_indices`]).
    pub audit_seed: u64,
}

impl AnalyticEval {
    /// Analytic evaluator with the full MAC unit, the differential
    /// check enabled and auditing off.
    pub fn new(test: Dataset, workers: usize) -> Self {
        AnalyticEval {
            test,
            mac: MacUnitConfig::full(),
            workers: workers.max(1),
            differential: true,
            audit_every: 0,
            audit_seed: 0,
        }
    }
}

impl AccuracyEval for AnalyticEval {
    fn evaluate(&self, qm: &QModel, n: usize) -> Result<EvalReport> {
        let n = n.min(self.test.images.len());
        ensure!(n > 0, "AnalyticEval: empty evaluation set");
        let inputs: Vec<Tensor<i8>> =
            self.test.images[..n].iter().map(|im| quantize_input(qm, im)).collect();
        let modes = modes_for(qm);
        let plan = plan_for(qm, &modes)?;
        let runs = run_plan_batch(&plan, &inputs, self.mac, ExecMode::Analytic, self.workers)?;
        // Sampled differential audit: a mismatch is a hard, typed
        // failure — an analytic sweep must never silently drift from
        // what the ISS would have measured.
        let audits = audit_indices(self.audit_seed, n, self.audit_every);
        for &i in &audits {
            audit_run(&plan, &inputs[i], self.mac, &runs[i])?;
        }
        let mut correct = 0usize;
        let mut disagree = 0usize;
        let mut cycles = 0u64;
        let mut accesses = 0u64;
        for ((run, input), &label) in runs.iter().zip(&inputs).zip(&self.test.labels) {
            let pred = run.argmax();
            if pred == label {
                correct += 1;
            }
            if self.differential && argmax_i32(&host_logits(&plan, input)) != pred {
                disagree += 1;
            }
            cycles += run.total_cycles();
            accesses += run.total_accesses();
        }
        Ok(EvalReport {
            accuracy: correct as f32 / n as f32,
            iss_cycles: Some(cycles / n as u64),
            iss_mem_accesses: Some(accesses / n as u64),
            divergence: if self.differential { Some(disagree as f32 / n as f32) } else { None },
            audited: if self.audit_every > 0 { Some(audits.len() as u32) } else { None },
        })
    }
    fn name(&self) -> &'static str {
        "analytic"
    }
    fn eval_len(&self) -> usize {
        self.test.images.len()
    }
    fn mac_config(&self) -> MacUnitConfig {
        self.mac
    }
}

/// PJRT evaluator: batched inference through the AOT model artifact.
/// The session handle is not thread-safe, so evaluations serialise on
/// the internal mutex (the other backends run fully in parallel).
pub struct PjrtEval {
    /// PJRT session (executable cache inside), serialised internally.
    pub session: Mutex<crate::runtime::Session>,
    /// Evaluation set.
    pub test: Dataset,
    /// Artifact batch size.
    pub batch: usize,
}

impl PjrtEval {
    /// Wrap an open PJRT session for coordinator use.
    pub fn new(session: crate::runtime::Session, test: Dataset, batch: usize) -> Self {
        PjrtEval { session: Mutex::new(session), test, batch }
    }
}

// SAFETY: the `xla` crate's client/executable handles are raw C
// pointers (hence !Send/!Sync by default), but the PJRT CPU plugin has
// no thread affinity and every access goes through the internal
// `session` Mutex — the value is only ever *used* by one thread at a
// time.
unsafe impl Send for PjrtEval {}
unsafe impl Sync for PjrtEval {}

impl AccuracyEval for PjrtEval {
    fn evaluate(&self, qm: &QModel, n: usize) -> Result<EvalReport> {
        let n = n.min(self.test.images.len());
        ensure!(n > 0, "PjrtEval: empty evaluation set");
        let mut session = self.session.lock().unwrap();
        crate::runtime::evaluate_accuracy(
            &mut session,
            qm,
            &self.test.images[..n],
            &self.test.labels[..n],
            self.batch,
        )
        .map(EvalReport::accuracy_only)
    }
    fn name(&self) -> &'static str {
        "pjrt"
    }
    fn eval_len(&self) -> usize {
        self.test.images.len()
    }
}

/// Coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Configurations submitted.
    pub submitted: AtomicU64,
    /// Cache hits.
    pub cache_hits: AtomicU64,
    /// Accuracy evaluations executed.
    pub acc_evals: AtomicU64,
    /// Configurations whose evaluation reported a nonzero host-vs-ISS
    /// top-1 divergence (only the [`IssEval`] backend feeds this).
    pub diverged_configs: AtomicU64,
    /// Prefix (partial) evaluations performed by guided-search rungs
    /// ([`Coordinator::sweep_guided`]). These bypass the per-config
    /// report cache — the cache is keyed by configuration alone and
    /// must only ever hold full-length reports.
    pub partial_evals: AtomicU64,
    /// Evaluations served from the attached content-addressed result
    /// store ([`Coordinator::attach_store`]) instead of running the
    /// backend.
    pub store_hits: AtomicU64,
    /// Evaluations that consulted the attached store and missed (the
    /// backend ran, and the fresh report was persisted).
    pub store_misses: AtomicU64,
}

/// The evaluation coordinator.
pub struct Coordinator {
    /// Loaded model (spec + trained params + scales + test set).
    pub model: LoadedModel,
    /// Per-layer cycle table (ISS-measured).
    pub cycle_model: CycleModel,
    /// Model analysis (computed once).
    pub analysis: crate::models::ModelAnalysis,
    /// Per-(layer, width) quantization cache: configs assemble from
    /// these instead of re-running the MSE scale search (§Perf
    /// iteration 2 — the quantize step falls out of the sweep hot path).
    qcache: Vec<[crate::nn::QLayer; 3]>,
    /// Shared accuracy backend; `evaluate` takes `&self`, so sweep
    /// workers score configurations concurrently (no coordinator-level
    /// lock — the dominant per-config cost overlaps across the pool).
    evaluator: Box<dyn AccuracyEval>,
    cache: Mutex<HashMap<Config, EvalReport>>,
    /// Persistent content-addressed result store
    /// ([`Coordinator::attach_store`]); `None` = RAM-cache only.
    store: Option<StoreBinding>,
    /// Cluster the cost composition schedules over
    /// ([`Coordinator::set_cluster`]; single-core by default — the
    /// degenerate cluster leaves every cost path untouched).
    cluster: ClusterConfig,
    /// Worker threads for the sweep.
    pub workers: usize,
    /// Bounded-queue capacity (backpressure).
    pub queue_cap: usize,
    /// Metrics.
    pub metrics: Metrics,
}

/// An attached [`ResultStore`] plus the per-coordinator key components
/// computed once at attach time (dataset digest, resolved backend tag,
/// MAC-unit features).
struct StoreBinding {
    store: ResultStore,
    dataset_digest: u64,
    backend: &'static str,
    mac: MacUnitConfig,
}

impl Coordinator {
    /// Build a coordinator; measures the cycle model up front, fanning
    /// the per-layer ISS measurements out over the worker pool.
    pub fn new(
        model: LoadedModel,
        evaluator: Box<dyn AccuracyEval>,
        workers: usize,
    ) -> Result<Self> {
        let workers = workers.max(1);
        let analysis = crate::models::analyze(&model.spec);
        let cycle_model = CycleModel::build_with_workers(
            &analysis,
            crate::sim::MacUnitConfig::full(),
            0xC1C1E,
            workers,
        )?;
        let qcache = analysis
            .layers
            .iter()
            .zip(&model.params)
            .map(|(info, p)| {
                [8u32, 4, 2].map(|b| {
                    crate::nn::quantize_layer(
                        &p.w,
                        &p.b,
                        model.sites[info.site_in],
                        model.sites[info.site_out],
                        b,
                    )
                })
            })
            .collect();
        Ok(Coordinator {
            model,
            cycle_model,
            analysis,
            qcache,
            evaluator,
            cache: Mutex::new(HashMap::new()),
            store: None,
            cluster: ClusterConfig::single(),
            workers,
            queue_cap: 64,
            metrics: Metrics::default(),
        })
    }

    /// Schedule all cost composition over an N-core cluster
    /// ([`crate::sim::cluster`]): [`Coordinator::compose_point`] and
    /// the guided-search pricing switch to the cluster critical path,
    /// and the store keys carry the cores axis. Must be called before
    /// [`Coordinator::attach_store`] — the binding pins the MAC/cluster
    /// identity at attach time, and re-keying a live store binding
    /// would silently alias entries across machine shapes. `cores = 1`
    /// is the exact pre-cluster behaviour.
    pub fn set_cluster(&mut self, cores: usize) -> Result<()> {
        ensure!(
            self.store.is_none(),
            "set_cluster must run before attach_store (store keys pin the cores axis)"
        );
        self.cluster = ClusterConfig::new(cores);
        Ok(())
    }

    /// The cluster the cost composition is scheduled over.
    pub fn cluster(&self) -> ClusterConfig {
        self.cluster
    }

    /// Cluster-scheduled cost of one configuration — the per-core
    /// busy/stall/utilization accounting behind the sweep summaries.
    /// Well-defined for any cluster, including the single-core one.
    pub fn cluster_cost(&self, cfg: &Config) -> ClusterCost {
        self.cycle_model.cluster_config_total(cfg, &self.cluster)
    }

    /// Attach a persistent content-addressed result store: every
    /// subsequent full evaluation consults it before running the
    /// backend and persists fresh reports into it. The dataset digest
    /// and backend tag are pinned here, once — the evaluator's
    /// *resolved* label goes into the keys (never `auto`; the
    /// [`StoreKey`] constructor enforces it). Guided-search rung
    /// partials never touch the store: they call the backend directly,
    /// the same bypass that keeps them out of the RAM report cache.
    pub fn attach_store(&mut self, store: ResultStore) -> Result<()> {
        let backend = self.evaluator.name();
        // The pinned machine identity: the backend's MAC features plus
        // the coordinator's cluster axis — a cores=4 sweep must never
        // alias a cores=1 entry (the composed cost fields differ).
        let mac = self.evaluator.mac_config().with_cores(self.cluster.cores);
        // Validate the tag eagerly (a dummy fingerprint is fine — only
        // the backend string is checked) so a misconfigured attach
        // fails at setup, not mid-sweep.
        StoreKey::new(0, 0, 1, backend, mac)?;
        self.store = Some(StoreBinding {
            store,
            dataset_digest: crate::store::dataset_digest(&self.model.test),
            backend,
            mac,
        });
        Ok(())
    }

    /// `(store_hits, store_misses)` when a store is attached.
    pub fn store_counters(&self) -> Option<(u64, u64)> {
        self.store.as_ref().map(|_| {
            (
                self.metrics.store_hits.load(Ordering::Relaxed),
                self.metrics.store_misses.load(Ordering::Relaxed),
            )
        })
    }

    /// The store key for evaluating `qm` at `n_eval` samples. `n` is
    /// clamped to the backend's eval-set length exactly as the backends
    /// themselves clamp, so an oversized request maps to the same key
    /// as the computation it actually performs.
    fn store_key(&self, b: &StoreBinding, qm: &QModel, n_eval: usize) -> Result<StoreKey> {
        let n = n_eval.min(self.evaluator.eval_len());
        let fp = crate::models::plan::content_fingerprint(qm, &modes_for(qm));
        Ok(StoreKey::new(fp, b.dataset_digest, n, b.backend, b.mac)?)
    }

    fn store_lookup(&self, qm: &QModel, n_eval: usize) -> Result<Option<EvalReport>> {
        let Some(b) = &self.store else { return Ok(None) };
        let key = self.store_key(b, qm, n_eval)?;
        match b.store.get(&key) {
            Some(r) => {
                self.metrics.store_hits.fetch_add(1, Ordering::Relaxed);
                Ok(Some(r))
            }
            None => {
                self.metrics.store_misses.fetch_add(1, Ordering::Relaxed);
                Ok(None)
            }
        }
    }

    fn store_insert(&self, qm: &QModel, n_eval: usize, r: &EvalReport) -> Result<()> {
        let Some(b) = &self.store else { return Ok(()) };
        let key = self.store_key(b, qm, n_eval)?;
        Ok(b.store.put(&key, qm.spec.name, &qm.bits, r)?)
    }

    /// Drop the in-process report cache (benches use this to measure
    /// the store path without the RAM cache masking it). The attached
    /// store, the metrics and the cycle model are untouched.
    pub fn clear_report_cache(&self) {
        self.cache.lock().unwrap().clear();
    }

    /// Assemble a quantized model from the per-(layer, width) cache.
    pub fn quantized(&self, cfg: &Config) -> QModel {
        let layers = cfg
            .iter()
            .enumerate()
            .map(|(i, &b)| {
                let slot = match b {
                    8 => 0,
                    4 => 1,
                    2 => 2,
                    _ => panic!("unsupported width {b}"),
                };
                self.qcache[i][slot].clone()
            })
            .collect();
        QModel {
            spec: self.model.spec.clone(),
            analysis: self.analysis.clone(),
            layers,
            sites: self.model.sites.clone(),
            bits: cfg.clone(),
        }
    }

    /// Quantize + evaluate one configuration (cached).
    pub fn evaluate(&self, cfg: &Config, n_eval: usize) -> Result<EvalPoint> {
        self.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        let cached = self.cache.lock().unwrap().get(cfg).copied();
        let report = match cached {
            Some(r) => {
                self.metrics.cache_hits.fetch_add(1, Ordering::Relaxed);
                r
            }
            None => {
                let qm = self.quantized(cfg);
                // Consult the attached result store before paying for
                // the backend: a hit restores the persisted report (and
                // a fully-warm sweep runs zero evaluations).
                let r = match self.store_lookup(&qm, n_eval)? {
                    Some(r) => r,
                    None => {
                        self.metrics.acc_evals.fetch_add(1, Ordering::Relaxed);
                        let r = self.evaluator.evaluate(&qm, n_eval)?;
                        self.store_insert(&qm, n_eval, &r)?;
                        r
                    }
                };
                // Count divergent configs only on the fresh insert so a
                // racing duplicate evaluation can't double-count.
                let fresh = self.cache.lock().unwrap().insert(cfg.clone(), r).is_none();
                if fresh && r.divergence.is_some_and(|d| d > 0.0) {
                    self.metrics.diverged_configs.fetch_add(1, Ordering::Relaxed);
                }
                r
            }
        };
        Ok(self.compose_point(cfg, &report))
    }

    /// Compose the sweep-level [`EvalPoint`] for `cfg` from a (possibly
    /// store-restored) report: accuracy fields from the report, cost
    /// fields recomputed from the local [`CycleModel`] — the exact
    /// composition [`Coordinator::evaluate`] performs, exposed for
    /// consumers that read reports straight out of the result store
    /// (`mpnn serve`'s Pareto queries).
    pub fn compose_point(&self, cfg: &Config, report: &EvalReport) -> EvalPoint {
        // The single-core branch goes through the original flat
        // composition, not the degenerate cluster schedule — same
        // integers either way (tested), but the byte-identity contract
        // for `--cores 1` rests on the structural guarantee, not the
        // arithmetic one.
        let cost = if self.cluster.is_single() {
            self.cycle_model.config_total(cfg)
        } else {
            self.cycle_model.cluster_config_total(cfg, &self.cluster).cost
        };
        EvalPoint {
            config: cfg.clone(),
            accuracy: report.accuracy,
            mac_instructions: total_mac_instructions(&self.analysis, cfg),
            cycles: cost.cycles,
            mem_accesses: cost.mem_accesses,
            iss_cycles: report.iss_cycles,
            divergence: report.divergence,
        }
    }

    /// Label of the evaluator backend in use.
    pub fn evaluator_name(&self) -> &'static str {
        self.evaluator.name()
    }

    /// Evaluate a sweep of configurations through the worker pool
    /// (bounded queue → workers → ordered result collection).
    pub fn run_sweep(&self, configs: &[Config], n_eval: usize) -> Result<Vec<EvalPoint>> {
        self.sweep_stream(configs.len(), configs.iter().cloned(), n_eval)
    }

    /// Streaming exhaustive sweep: every configuration of a lazy
    /// [`ConfigSpace`], decoded by the producer one at a time into the
    /// bounded queue — configs in flight never exceed `queue_cap +
    /// workers`, whatever the space size. Output is bit-identical to
    /// `run_sweep(&space.iter().collect::<Vec<_>>(), ..)`.
    pub fn run_sweep_space(&self, space: &ConfigSpace, n_eval: usize) -> Result<Vec<EvalPoint>> {
        self.sweep_stream(space.len(), space.iter(), n_eval)
    }

    /// Streaming sweep of selected global `indices` of a lazy space
    /// (a shard's members, a guided driver's survivors, a resume
    /// chunk). Returns points index-aligned with `indices`.
    pub fn sweep_space_indices(
        &self,
        space: &ConfigSpace,
        indices: &[usize],
        n_eval: usize,
    ) -> Result<Vec<EvalPoint>> {
        self.sweep_stream(indices.len(), indices.iter().map(|&i| space.get(i)), n_eval)
    }

    /// The producer/worker core behind every sweep entry point: `jobs`
    /// yields exactly `count` configurations which the bounded send
    /// feeds to the workers (backpressure caps decoded configs in
    /// flight); results come back in job order.
    fn sweep_stream(
        &self,
        count: usize,
        jobs: impl Iterator<Item = Config>,
        n_eval: usize,
    ) -> Result<Vec<EvalPoint>> {
        let (job_tx, job_rx) = sync_channel::<(usize, Config)>(self.queue_cap);
        let job_rx = Mutex::new(job_rx);
        let results: Mutex<Vec<Option<EvalPoint>>> = Mutex::new(vec![None; count]);
        let first_err: Mutex<Option<Error>> = Mutex::new(None);

        std::thread::scope(|s| {
            for _ in 0..self.workers {
                s.spawn(|| loop {
                    let job = job_rx.lock().unwrap().recv();
                    let Ok((i, cfg)) = job else { break };
                    // A panicking evaluator must become a typed error on
                    // the first-error channel, not a scope-level abort:
                    // an uncaught worker panic would re-raise at scope
                    // exit and take the whole sweep (and, under `serve`,
                    // the daemon) down with it.
                    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                        || self.evaluate(&cfg, n_eval),
                    ))
                    .unwrap_or_else(|payload| {
                        let what = payload
                            .downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "opaque panic payload".to_string());
                        Err(Error::msg(format!("evaluator worker panicked: {what}")))
                    });
                    match outcome {
                        Ok(p) => results.lock().unwrap()[i] = Some(p),
                        Err(e) => {
                            let mut fe = first_err.lock().unwrap();
                            if fe.is_none() {
                                *fe = Some(e);
                            }
                        }
                    }
                });
            }
            // Producer: the bounded send blocks when workers fall behind
            // (the backpressure the architecture calls for). A closed
            // channel (all workers gone) just ends production — the
            // first-error channel reports what killed them.
            for (i, cfg) in jobs.enumerate() {
                if first_err.lock().unwrap().is_some() {
                    break;
                }
                if job_tx.send((i, cfg)).is_err() {
                    break;
                }
            }
            drop(job_tx);
        });

        if let Some(e) = first_err.into_inner().unwrap() {
            return Err(e);
        }
        results
            .into_inner()
            .unwrap()
            .into_iter()
            .enumerate()
            .map(|(i, p)| {
                p.ok_or_else(|| {
                    Error::msg(format!(
                        "sweep config {i} produced no result (evaluator worker died)"
                    ))
                })
            })
            .collect()
    }

    /// Cache size (distinct configurations evaluated).
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Evaluate only the configurations of `configs` that `shard` owns
    /// (see [`ShardSpec`](crate::dse::shard::ShardSpec)), through the
    /// same worker pool as [`Coordinator::run_sweep`]. Returns
    /// `(global enumeration index, point)` pairs in enumeration order —
    /// the payload a [`ShardArtifact`](crate::dse::shard::ShardArtifact)
    /// serialises so the merger can restore the single-sweep order
    /// bit-for-bit. The 1-way shard degenerates to `run_sweep`.
    pub fn sweep_sharded(
        &self,
        configs: &[Config],
        n_eval: usize,
        shard: &crate::dse::shard::ShardSpec,
    ) -> Result<Vec<(usize, EvalPoint)>> {
        let indices = shard.member_indices(configs);
        let mine: Vec<Config> = indices.iter().map(|&i| configs[i].clone()).collect();
        let points = self.run_sweep(&mine, n_eval)?;
        Ok(indices.into_iter().zip(points).collect())
    }

    /// Sharded sweep over a lazy [`ConfigSpace`]: the shard's members
    /// come from [`ShardSpec::member_indices_in`](crate::dse::shard::ShardSpec::member_indices_in)
    /// (O(shard) memory) and stream through the worker pool — the
    /// complement of the shard is never materialized. Bit-identical to
    /// [`Coordinator::sweep_sharded`] over the enumerated space.
    pub fn sweep_sharded_space(
        &self,
        space: &ConfigSpace,
        n_eval: usize,
        shard: &crate::dse::shard::ShardSpec,
    ) -> Result<Vec<(usize, EvalPoint)>> {
        let indices = shard.member_indices_in(space);
        let points = self.sweep_space_indices(space, &indices, n_eval)?;
        Ok(indices.into_iter().zip(points).collect())
    }

    /// Analytic cost triple of one configuration, composed exactly as
    /// [`Coordinator::compose_point`] prices points: under a cluster
    /// the pruning bounds must rank by the cluster critical path, or
    /// the guided search would prune against costs the returned points
    /// don't carry.
    fn price(&self, cfg: &Config) -> crate::dse::search::CostVec {
        let c = if self.cluster.is_single() {
            self.cycle_model.config_total(cfg)
        } else {
            self.cycle_model.cluster_config_total(cfg, &self.cluster).cost
        };
        crate::dse::search::CostVec {
            cycles: c.cycles,
            mac: total_mac_instructions(&self.analysis, cfg),
            mem: c.mem_accesses,
        }
    }

    /// Guided sweep
    /// ([`guided_search`](crate::dse::search::guided_search)): analytic
    /// cost bounds prune the space, successive halving on growing
    /// eval-set prefixes promotes
    /// the rest, and only the survivors (plus whatever the zero-regret
    /// repair pass re-admits) are evaluated on the full eval set.
    ///
    /// The analytic cost triple per configuration comes from the
    /// already-measured [`CycleModel`] — pricing the whole space costs
    /// no ISS runs. Rung prefix evaluations call the backend directly
    /// with the prefix length and **bypass the per-config report
    /// cache** (it is keyed by configuration alone, so a partial report
    /// would poison later full evaluations); full evaluations go
    /// through [`Coordinator::evaluate`], the exact path
    /// [`Coordinator::run_sweep`] uses, so every returned point is
    /// bit-identical to what the exhaustive sweep would produce for
    /// that configuration.
    pub fn sweep_guided(
        &self,
        configs: &[Config],
        n_eval: usize,
        opts: &crate::dse::search::GuidedOpts,
    ) -> Result<crate::dse::search::GuidedSweep> {
        let n = n_eval.min(self.evaluator.eval_len()).max(1);
        let costs: Vec<crate::dse::search::CostVec> =
            configs.iter().map(|cfg| self.price(cfg)).collect();
        let eval_partial = |idxs: &[usize], m: usize| -> Result<Vec<u32>> {
            self.metrics.partial_evals.fetch_add(idxs.len() as u64, Ordering::Relaxed);
            crate::par::parallel_map(idxs.len(), self.workers, |j| {
                let qm = self.quantized(&configs[idxs[j]]);
                let r = self.evaluator.evaluate(&qm, m)?;
                // The backends score `correct / m` in f32; m is far
                // below 2^24, so the hit count round-trips exactly.
                Ok((r.accuracy * m as f32).round() as u32)
            })
        };
        let eval_full = |idxs: &[usize]| -> Result<Vec<EvalPoint>> {
            let mine: Vec<Config> = idxs.iter().map(|&i| configs[i].clone()).collect();
            self.run_sweep(&mine, n_eval)
        };
        crate::dse::search::guided_search(&costs, n, opts, &eval_partial, &eval_full)
    }

    /// Guided sweep over a lazy [`ConfigSpace`] — the streaming
    /// counterpart of [`Coordinator::sweep_guided`], bit-identical to
    /// it on the materialized space. No cost table is built: the
    /// [`guided_search_stream`](crate::dse::search::guided_search_stream)
    /// engine prices configurations on demand (decode + price, then
    /// drop), rung scoring decodes each scored config transiently
    /// inside the worker, and full evaluations stream their batch
    /// through [`Coordinator::sweep_space_indices`] — so peak config
    /// storage is the driver's alive set plus the points it returns,
    /// never the space ([`GuidedStats::peak_alive`](crate::dse::search::GuidedStats)
    /// is the ledger).
    pub fn sweep_guided_space(
        &self,
        space: &ConfigSpace,
        n_eval: usize,
        opts: &crate::dse::search::GuidedOpts,
    ) -> Result<crate::dse::search::GuidedSweep> {
        let n = n_eval.min(self.evaluator.eval_len()).max(1);
        let cost_of = |i: usize| self.price(&space.get(i));
        let eval_partial = |idxs: &[usize], m: usize| -> Result<Vec<u32>> {
            self.metrics.partial_evals.fetch_add(idxs.len() as u64, Ordering::Relaxed);
            crate::par::parallel_map(idxs.len(), self.workers, |j| {
                let qm = self.quantized(&space.get(idxs[j]));
                let r = self.evaluator.evaluate(&qm, m)?;
                Ok((r.accuracy * m as f32).round() as u32)
            })
        };
        let eval_full =
            |idxs: &[usize]| -> Result<Vec<EvalPoint>> { self.sweep_space_indices(space, idxs, n_eval) };
        crate::dse::search::guided_search_stream(
            space.len(),
            &cost_of,
            n,
            opts,
            &eval_partial,
            &eval_full,
        )
    }

    /// Guided sweep over selected global `indices` of a lazy space —
    /// what a guided *shard* runs over its members. The returned
    /// `GuidedSweep` indices are positions into `indices` (the caller
    /// maps them back to global enumeration indices), matching the
    /// slice-based contract of `sweep_guided` over the gathered
    /// configs.
    pub fn sweep_guided_indices(
        &self,
        space: &ConfigSpace,
        indices: &[usize],
        n_eval: usize,
        opts: &crate::dse::search::GuidedOpts,
    ) -> Result<crate::dse::search::GuidedSweep> {
        let n = n_eval.min(self.evaluator.eval_len()).max(1);
        let cost_of = |j: usize| self.price(&space.get(indices[j]));
        let eval_partial = |js: &[usize], m: usize| -> Result<Vec<u32>> {
            self.metrics.partial_evals.fetch_add(js.len() as u64, Ordering::Relaxed);
            crate::par::parallel_map(js.len(), self.workers, |k| {
                let qm = self.quantized(&space.get(indices[js[k]]));
                let r = self.evaluator.evaluate(&qm, m)?;
                Ok((r.accuracy * m as f32).round() as u32)
            })
        };
        let eval_full = |js: &[usize]| -> Result<Vec<EvalPoint>> {
            self.sweep_stream(js.len(), js.iter().map(|&j| space.get(indices[j])), n_eval)
        };
        crate::dse::search::guided_search_stream(
            indices.len(),
            &cost_of,
            n,
            opts,
            &eval_partial,
            &eval_full,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::format::load_or_fallback;
    use std::path::Path;

    fn tiny_coordinator() -> Coordinator {
        // Fallback model (no artifacts needed) + host evaluator.
        let model = load_or_fallback(Path::new("/nonexistent"), "lenet5", 11).unwrap();
        let test = model.test.clone();
        Coordinator::new(model, Box::new(HostEval { test }), 2).unwrap()
    }

    #[test]
    fn sweep_returns_ordered_points_and_caches() {
        let c = tiny_coordinator();
        let n = crate::models::analyze(&c.model.spec).layers.len();
        let configs: Vec<Vec<u32>> =
            vec![vec![8; n], vec![4; n], vec![2; n], vec![8; n] /* dup */];
        let pts = c.run_sweep(&configs, 8).unwrap();
        assert_eq!(pts.len(), 4);
        // Order preserved.
        assert_eq!(pts[0].config, configs[0]);
        assert_eq!(pts[3].config, configs[3]);
        // The duplicate hits the cache.
        assert_eq!(c.cache_len(), 3);
        assert!(c.metrics.cache_hits.load(std::sync::atomic::Ordering::Relaxed) >= 1);
        // Cost ordering: 2-bit config must be cheapest.
        assert!(pts[2].cycles < pts[0].cycles);
        assert!(pts[2].mac_instructions < pts[0].mac_instructions);
    }

    /// Backend that panics on every evaluation — the regression fixture
    /// for the worker-pool panic path.
    struct PanickingEval {
        test: Dataset,
    }

    impl AccuracyEval for PanickingEval {
        fn evaluate(&self, _qm: &QModel, _n: usize) -> Result<EvalReport> {
            panic!("deliberate test panic");
        }
        fn name(&self) -> &'static str {
            "panicking"
        }
        fn eval_len(&self) -> usize {
            self.test.images.len()
        }
    }

    #[test]
    fn panicking_evaluator_yields_typed_error_not_abort() {
        // Regression: a panic inside an evaluator worker used to
        // re-raise at thread-scope exit (or leave a `None` slot for the
        // final `unwrap()`), aborting the whole sweep. It must surface
        // as an ordinary first-error-channel `Err` instead.
        let model = load_or_fallback(Path::new("/nonexistent"), "lenet5", 11).unwrap();
        let test = model.test.clone();
        let c = Coordinator::new(model, Box::new(PanickingEval { test }), 2).unwrap();
        let n = crate::models::analyze(&c.model.spec).layers.len();
        let err = c.run_sweep(&[vec![8; n], vec![4; n], vec![2; n]], 8).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("panicked"), "unexpected error text: {msg}");
        assert!(msg.contains("deliberate test panic"), "panic payload lost: {msg}");
        // The coordinator instance survives: the next sweep fails the
        // same typed way instead of tripping over poisoned state.
        let err2 = c.run_sweep(&[vec![8; n]], 8).unwrap_err();
        assert!(format!("{err2}").contains("panicked"), "{err2}");
    }

    #[test]
    fn accuracy_degrades_monotonically_in_aggregate() {
        // 8-bit should be at least as accurate as 2-bit on the fallback
        // (random-weights) model is NOT guaranteed — use a trained-free
        // structural check instead: accuracies are valid probabilities.
        let c = tiny_coordinator();
        let n = crate::models::analyze(&c.model.spec).layers.len();
        let pts = c.run_sweep(&[vec![8; n], vec![2; n]], 8).unwrap();
        for p in pts {
            assert!((0.0..=1.0).contains(&p.accuracy));
        }
    }
}
