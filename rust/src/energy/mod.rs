//! Power / area / energy models for the FPGA (Virtex-7) and ASIC
//! (ASAP7 7 nm) flows — the reproduction of the paper's Vivado /
//! Synopsys-DC numbers (Table 4) and the Table-5 SOTA comparison.
//!
//! Substitution (DESIGN.md §5): we cannot synthesize RTL here, so each
//! platform is an analytical model *calibrated to the paper's published
//! operating points* (clock frequencies, power, resources). Our own
//! measured cycle/op counts drive the model, so every ratio the paper
//! derives (energy-efficiency gain, overheads) is reproduced from our
//! measurements, with the published power/area as fixed anchors.

pub mod sota;

/// One synthesized platform operating point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Platform {
    /// Display name.
    pub name: &'static str,
    /// Core clock (Hz).
    pub core_clock_hz: f64,
    /// Multi-pumped MAC-unit clock (Hz) — equals the core clock on the
    /// baseline design.
    pub unit_clock_hz: f64,
    /// Total power at the operating point (W).
    pub power_w: f64,
    /// Area: LUTs (FPGA) or mm² (ASIC) — see `area_label`.
    pub area: f64,
    /// Area unit label.
    pub area_label: &'static str,
    /// Flip-flops (FPGA only; 0 for ASIC).
    pub ffs: f64,
    /// DSP blocks (FPGA only).
    pub dsps: f64,
}

/// Paper Table 4 anchors: baseline Ibex on Virtex-7 (50 MHz).
pub const FPGA_BASELINE: Platform = Platform {
    name: "fpga-baseline-ibex",
    core_clock_hz: 50e6,
    unit_clock_hz: 50e6,
    power_w: 0.256, // 256 mW (28% leakage)
    area: 5_100.0,  // LUTs
    area_label: "LUT",
    ffs: 5_500.0,
    dsps: 4.0,
};

/// Modified Ibex on Virtex-7 (50 MHz core / 100 MHz multi-pumped unit).
pub const FPGA_MODIFIED: Platform = Platform {
    name: "fpga-modified-ibex",
    core_clock_hz: 50e6,
    unit_clock_hz: 100e6,
    power_w: 0.261, // 261 mW (+2%)
    area: 6_400.0,
    area_label: "LUT",
    ffs: 7_400.0,
    dsps: 4.0,
};

/// Baseline Ibex on ASAP7 (250 MHz).
pub const ASIC_BASELINE: Platform = Platform {
    name: "asic-baseline-ibex",
    core_clock_hz: 250e6,
    unit_clock_hz: 250e6,
    power_w: 0.43e-3, // 0.43 mW
    area: 0.028,
    area_label: "mm2",
    ffs: 0.0,
    dsps: 0.0,
};

/// Modified Ibex on ASAP7 (250 MHz core / 500 MHz unit).
pub const ASIC_MODIFIED: Platform = Platform {
    name: "asic-modified-ibex",
    core_clock_hz: 250e6,
    unit_clock_hz: 500e6,
    power_w: 0.58e-3, // 0.58 mW (+25.8%)
    area: 0.038,
    area_label: "mm2",
    ffs: 0.0,
    dsps: 0.0,
};

/// An energy/performance report for one (platform, workload) pair.
#[derive(Debug, Clone, Copy)]
pub struct EnergyReport {
    /// Execution time (s).
    pub time_s: f64,
    /// Energy (J).
    pub energy_j: f64,
    /// Operations counted (2 per MAC, the GOPs convention of Table 4/5).
    pub ops: f64,
    /// Throughput (GOP/s).
    pub gops: f64,
    /// Energy efficiency (GOP/s/W).
    pub gops_per_w: f64,
}

impl Platform {
    /// Evaluate a workload of `macs` MAC operations taking `cycles`
    /// core-clock cycles on this platform (ops = 2·MACs, the
    /// multiply+accumulate counting of the paper's GOPs figures).
    pub fn evaluate(&self, macs: u64, cycles: u64) -> EnergyReport {
        let time_s = cycles as f64 / self.core_clock_hz;
        let energy_j = time_s * self.power_w;
        let ops = 2.0 * macs as f64;
        let gops = ops / time_s / 1e9;
        EnergyReport { time_s, energy_j, ops, gops, gops_per_w: gops / self.power_w }
    }

    /// Area overhead of `self` over `base`, as a fraction.
    pub fn area_overhead(&self, base: &Platform) -> f64 {
        self.area / base.area - 1.0
    }

    /// Power overhead of `self` over `base`, as a fraction.
    pub fn power_overhead(&self, base: &Platform) -> f64 {
        self.power_w / base.power_w - 1.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Calibration check: the paper's own Table-4 LeNet row must fall
    /// out of the model. LeNet: 423 K MACs, 10.4 M baseline cycles at
    /// 250 MHz / 0.43 mW → 47.1 GOP/s/W.
    #[test]
    fn asic_baseline_reproduces_table4_lenet() {
        let r = ASIC_BASELINE.evaluate(423_000, 10_400_000);
        assert!((r.gops_per_w - 47.1).abs() / 47.1 < 0.02, "got {}", r.gops_per_w);
    }

    /// Same check on the FPGA point: 50 MHz / 256 mW → 0.016 GOP/s/W.
    #[test]
    fn fpga_baseline_reproduces_table4_lenet() {
        let r = FPGA_BASELINE.evaluate(423_000, 10_400_000);
        assert!((r.gops_per_w - 0.016).abs() / 0.016 < 0.05, "got {}", r.gops_per_w);
    }

    /// Paper overhead claims: ~25% LUT/FF increase, ~2% FPGA power,
    /// ~26% ASIC area. (The paper *states* 25.8% ASIC power, but its own
    /// Table 4 values 0.43 → 0.58 mW give +34.9%; we anchor on the table.)
    #[test]
    fn overheads_match_paper() {
        assert!((FPGA_MODIFIED.area_overhead(&FPGA_BASELINE) - 0.25).abs() < 0.03);
        assert!((FPGA_MODIFIED.power_overhead(&FPGA_BASELINE) - 0.02).abs() < 0.01);
        assert!((ASIC_MODIFIED.area_overhead(&ASIC_BASELINE) - 0.357).abs() < 0.01);
        assert!((ASIC_MODIFIED.power_overhead(&ASIC_BASELINE) - 0.349).abs() < 0.005);
    }

    /// Energy-efficiency gain structure: with a speedup S and the power
    /// ratio P, the efficiency gain is S/P — e.g. S = 13× on ASIC gives
    /// ≈ 10.3×, the paper's ~11× regime.
    #[test]
    fn efficiency_gain_tracks_speedup_over_power() {
        let base = ASIC_BASELINE.evaluate(1_000_000, 20_000_000);
        let fast = ASIC_MODIFIED.evaluate(1_000_000, 20_000_000 / 13);
        let gain = fast.gops_per_w / base.gops_per_w;
        assert!(gain > 9.0 && gain < 11.0, "gain {gain}");
    }
}
