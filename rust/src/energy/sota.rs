//! Table 5: comparison against state-of-the-art mixed-precision /
//! ISA-extension solutions. The competitor rows are literature constants
//! transcribed from the paper's Table 5; our row is computed from the
//! measured cycles/MACs through the [`super::Platform`] models.

/// One comparison row.
#[derive(Debug, Clone)]
pub struct SotaEntry {
    /// Work label (venue'year).
    pub work: &'static str,
    /// Process node.
    pub platform: &'static str,
    /// Supported precisions.
    pub precision: &'static str,
    /// Clock frequency (MHz).
    pub clk_mhz: f64,
    /// Area description.
    pub area: &'static str,
    /// Power (mW).
    pub power_mw: f64,
    /// Peak throughput (GOPs); a range is (lo, hi).
    pub gops: (f64, f64),
    /// Energy efficiency (GOPs/W); a range is (lo, hi).
    pub gops_per_w: (f64, f64),
}

/// The paper's Table-5 competitor rows (literature constants).
pub fn competitors() -> Vec<SotaEntry> {
    vec![
        SotaEntry {
            work: "TC'24 [14]",
            platform: "90nm",
            precision: "32 bit",
            clk_mhz: 100.0,
            area: "6.44 mm2",
            power_mw: 5.8,
            gops: (0.23, 0.23),
            gops_per_w: (38.8, 38.8),
        },
        SotaEntry {
            work: "Mix-GEMM HPCA'23 [3]",
            platform: "22nm",
            precision: "2-8 bit",
            clk_mhz: 1200.0,
            area: "0.014 mm2",
            power_mw: 9.9,
            gops: (11.9, 11.9),
            gops_per_w: (500.0, 1166.0),
        },
        SotaEntry {
            work: "ISVLSI'20 [10]",
            platform: "22nm",
            precision: "2/4/8 bit",
            clk_mhz: 250.0,
            area: "0.002 mm2",
            power_mw: 5.5,
            gops: (3.3, 3.3),
            gops_per_w: (200.0, 600.0),
        },
        SotaEntry {
            work: "UNPU JSSC'18 [12]",
            platform: "65nm",
            precision: "1-16 bit",
            clk_mhz: 2500.0,
            area: "16 mm2",
            power_mw: 288.0,
            gops: (514.2, 514.2),
            gops_per_w: (1750.0, 1750.0),
        },
        SotaEntry {
            work: "TCAD'20 [13]",
            platform: "65nm",
            precision: "16 bit",
            clk_mhz: 200.0,
            area: "11.47 mm2",
            power_mw: 805.0,
            gops: (288.0, 288.0),
            gops_per_w: (357.8, 357.8),
        },
        SotaEntry {
            work: "XpulpNN DATE'20 [5]",
            platform: "22nm",
            precision: "2/4/8 bit",
            clk_mhz: 600.0,
            area: "0.04 mm2",
            power_mw: 43.5,
            gops: (47.9, 47.9),
            gops_per_w: (700.0, 1100.0),
        },
    ]
}

/// Build our Table-5 row from measured throughput/efficiency ranges
/// (lo = <1% accuracy loss, hi = up to 5%).
pub fn ours(gops_lo: f64, gops_hi: f64, eff_lo: f64, eff_hi: f64) -> SotaEntry {
    SotaEntry {
        work: "Ours",
        platform: "7nm",
        precision: "2/4/8 bit",
        clk_mhz: 250.0,
        area: "0.038 mm2",
        power_mw: 0.58,
        gops: (gops_lo, gops_hi),
        gops_per_w: (eff_lo, eff_hi),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_has_all_competitors() {
        let c = competitors();
        assert_eq!(c.len(), 6);
        assert!(c.iter().any(|e| e.work.contains("Mix-GEMM")));
        assert!(c.iter().any(|e| e.work.contains("XpulpNN")));
    }

    #[test]
    fn ours_row_shape() {
        let o = ours(0.24, 0.85, 415.0, 1470.0);
        assert_eq!(o.platform, "7nm");
        assert!(o.gops_per_w.0 < o.gops_per_w.1);
    }
}
