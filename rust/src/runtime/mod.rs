//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from Rust — Python is never
//! on this path.
//!
//! The real bindings live in the `pjrt` submodule and need the vendored
//! `xla` crate, so they are gated behind the **`pjrt` cargo feature**
//! (the offline build environment cannot fetch crates). Without the
//! feature, the `stub` submodule supplies the same public surface —
//! every entry point type-checks, and [`Session::open`] returns an
//! error explaining how to enable the real path. The experiment
//! harnesses fall back to the host evaluator when `Session::open`
//! fails, so the whole crate works artifact-free out of the box.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;

use std::path::PathBuf;

/// Default artifacts directory (relative to the repo root).
pub fn default_artifacts_dir() -> PathBuf {
    // Walk up from cwd until an `artifacts/` with a manifest appears;
    // fall back to ./artifacts.
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
