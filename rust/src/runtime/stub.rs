//! Featureless stand-in for the PJRT runtime (built when the `pjrt`
//! cargo feature is off). Mirrors the public surface of the real
//! bindings so every consumer compiles; [`Session::open`] always errors
//! and no [`Session`] value can exist (it wraps an uninhabited type),
//! so the remaining methods are statically unreachable.

use crate::error::Result;
use crate::models::infer::QModel;
use std::convert::Infallible;
use std::path::Path;

/// Placeholder for `xla::Literal`.
pub struct Literal(pub(super) Infallible);

/// Placeholder for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable(pub(super) Infallible);

/// A PJRT session. Uninhabited in the stub build: [`Session::open`]
/// is the only constructor and it always fails.
pub struct Session(Infallible);

const DISABLED: &str = "PJRT runtime disabled: vendor the `xla` crate, add it to Cargo.toml \
     as an optional dependency of the `pjrt` feature, then rebuild with `--features pjrt` \
     (see rust/src/runtime/ and the ROADMAP open item)";

impl Session {
    /// Always fails in the stub build.
    pub fn open(_root: &Path) -> Result<Self> {
        Err(crate::error::Error::msg(DISABLED))
    }

    /// Unreachable (no `Session` value can exist).
    pub fn load(&mut self, _stem: &str) -> Result<&PjRtLoadedExecutable> {
        match self.0 {}
    }

    /// Unreachable (no `Session` value can exist).
    pub fn cache_len(&self) -> usize {
        match self.0 {}
    }
}

/// The batched classification result of one model execution.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// Int32 logits, row-major `[B, classes]`.
    pub logits: Vec<i32>,
    /// Predicted class per sample.
    pub preds: Vec<i32>,
    /// Class count.
    pub classes: usize,
}

/// Stub: always errors (no PJRT).
pub fn lit_i8(_dims: &[usize], _data: &[i8]) -> Result<Literal> {
    Err(crate::error::Error::msg(DISABLED))
}

/// Stub: always errors (no PJRT).
pub fn lit_i32(_dims: &[usize], _data: &[i32]) -> Result<Literal> {
    Err(crate::error::Error::msg(DISABLED))
}

/// Stub: always errors (no PJRT).
pub fn lit_u32(_dims: &[usize], _data: &[u32]) -> Result<Literal> {
    Err(crate::error::Error::msg(DISABLED))
}

/// Unreachable (no `PjRtLoadedExecutable` value can exist).
pub fn execute(exe: &PjRtLoadedExecutable, _args: &[Literal]) -> Result<Vec<Literal>> {
    match exe.0 {}
}

/// Unreachable (no `PjRtLoadedExecutable` value can exist).
pub fn run_qfwd(
    exe: &PjRtLoadedExecutable,
    _qm: &QModel,
    _images: &[i8],
    _b: usize,
) -> Result<BatchOutput> {
    match exe.0 {}
}

/// Unreachable (no `Session` value can exist).
pub fn evaluate_accuracy(
    session: &mut Session,
    _qm: &QModel,
    _images: &[crate::nn::tensor::Tensor<f32>],
    _labels: &[usize],
    _batch: usize,
) -> Result<f32> {
    match session.0 {}
}
