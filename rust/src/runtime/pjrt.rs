//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from Rust — Python is never
//! on this path.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit
//! instruction ids the bundled xla_extension rejects in proto form).

//! Real PJRT bindings (compiled only with the `pjrt` cargo feature;
//! requires the vendored `xla` crate).

use crate::error::{Context, Result};
use crate::json::Json;
use crate::models::infer::QModel;
use crate::models::QKind;
use crate::{bail, ensure};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use xla::{ElementType, Literal, PjRtClient, PjRtLoadedExecutable};

/// A PJRT session: client + executable cache.
pub struct Session {
    client: PjRtClient,
    root: PathBuf,
    manifest: Option<Json>,
    cache: HashMap<String, PjRtLoadedExecutable>,
}

impl Session {
    /// Open a CPU PJRT session rooted at an artifacts directory.
    pub fn open(root: &Path) -> Result<Self> {
        let client = PjRtClient::cpu().context("create PJRT CPU client")?;
        let manifest_path = root.join("manifest.json");
        let manifest = if manifest_path.exists() {
            Some(
                Json::parse(&std::fs::read_to_string(&manifest_path)?)
                    .map_err(|e| crate::anyhow!("manifest: {e}"))?,
            )
        } else {
            None
        };
        Ok(Session { client, root: root.to_path_buf(), manifest, cache: HashMap::new() })
    }

    /// The parsed manifest (if present).
    pub fn manifest(&self) -> Option<&Json> {
        self.manifest.as_ref()
    }

    /// Artifacts root.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Compile (and cache) an HLO-text artifact by file stem.
    pub fn load(&mut self, stem: &str) -> Result<&PjRtLoadedExecutable> {
        if !self.cache.contains_key(stem) {
            let path = self.root.join(format!("{stem}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .with_context(|| format!("parse HLO text {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).with_context(|| format!("compile {stem}"))?;
            self.cache.insert(stem.to_string(), exe);
        }
        Ok(&self.cache[stem])
    }

    /// Number of compiled executables held in the cache.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }
}

/// Build an int8 literal from values.
pub fn lit_i8(dims: &[usize], data: &[i8]) -> Result<Literal> {
    let bytes: &[u8] = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len()) };
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S8, dims, bytes)?)
}

/// Build an int32 literal from values.
pub fn lit_i32(dims: &[usize], data: &[i32]) -> Result<Literal> {
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::S32, dims, &bytes)?)
}

/// Build a uint32 literal from values.
pub fn lit_u32(dims: &[usize], data: &[u32]) -> Result<Literal> {
    let bytes: Vec<u8> = data.iter().flat_map(|v| v.to_le_bytes()).collect();
    Ok(Literal::create_from_shape_and_untyped_data(ElementType::U32, dims, &bytes)?)
}

/// Execute an executable and decompose the (tupled) outputs.
pub fn execute(exe: &PjRtLoadedExecutable, args: &[Literal]) -> Result<Vec<Literal>> {
    let result = exe.execute::<Literal>(args).context("execute")?;
    let out = result[0][0].to_literal_sync()?;
    Ok(out.to_tuple()?)
}

/// The batched classification result of one model execution.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// Int32 logits, row-major `[B, classes]`.
    pub logits: Vec<i32>,
    /// Predicted class per sample.
    pub preds: Vec<i32>,
    /// Class count.
    pub classes: usize,
}

/// Assemble the canonical argument list of `<model>_qfwd_b<B>.hlo.txt`
/// for one quantized model + a batch of int8 images (padded/truncated
/// to the artifact batch `b`).
pub fn qfwd_args(qm: &QModel, images: &[i8], b: usize) -> Result<Vec<Literal>> {
    let [h, w, c] = qm.spec.input;
    let px = h * w * c;
    ensure!(images.len() == b * px, "expected {b}·{px} image bytes");
    let mut args = Vec::with_capacity(3 + 2 * qm.layers.len());
    args.push(lit_i8(&[b, h, w, c], images)?);
    for (q, info) in qm.layers.iter().zip(&qm.analysis.layers) {
        let dims: Vec<usize> = match info.kind {
            QKind::Conv => vec![info.out_shape[2], info.k, info.k, info.in_shape[2]],
            QKind::Depthwise => vec![info.in_shape[2], info.k, info.k],
            QKind::Dense => vec![info.out_shape[2], info.in_shape[2]],
        };
        args.push(lit_i8(&dims, &q.qw)?);
        args.push(lit_i32(&[q.bias.len()], &q.bias)?);
    }
    let ms: Vec<i32> = qm.layers.iter().map(|q| q.rq.m).collect();
    let ss: Vec<i32> = qm.layers.iter().map(|q| q.rq.shift).collect();
    args.push(lit_i32(&[ms.len()], &ms)?);
    args.push(lit_i32(&[ss.len()], &ss)?);
    if !qm.analysis.residuals.is_empty() {
        let r = qm.analysis.residuals.len();
        let mut rm = Vec::with_capacity(2 * r);
        let mut rs = Vec::with_capacity(2 * r);
        for i in 0..r {
            let (rq_skip, rq_branch) = crate::models::infer::residual_requants(qm, i);
            rm.push(rq_skip.m);
            rm.push(rq_branch.m);
            rs.push(rq_skip.shift);
            rs.push(rq_branch.shift);
        }
        args.push(lit_i32(&[r, 2], &rm)?);
        args.push(lit_i32(&[r, 2], &rs)?);
    }
    Ok(args)
}

/// Run one batch through a model's qfwd artifact.
pub fn run_qfwd(
    exe: &PjRtLoadedExecutable,
    qm: &QModel,
    images: &[i8],
    b: usize,
) -> Result<BatchOutput> {
    let args = qfwd_args(qm, images, b)?;
    let outs = execute(exe, &args)?;
    if outs.len() != 2 {
        bail!("expected (logits, preds), got {} outputs", outs.len());
    }
    let logits = outs[0].to_vec::<i32>()?;
    let preds = outs[1].to_vec::<i32>()?;
    Ok(BatchOutput { logits, preds, classes: qm.spec.num_classes })
}

/// Batched accuracy evaluation of a quantized model over a float test
/// set: quantizes inputs at the model's input scale, pads the final
/// batch, returns top-1 accuracy.
pub fn evaluate_accuracy(
    session: &mut Session,
    qm: &QModel,
    images: &[crate::nn::tensor::Tensor<f32>],
    labels: &[usize],
    batch: usize,
) -> Result<f32> {
    ensure!(images.len() == labels.len());
    let stem = format!("{}_qfwd_b{batch}", qm.spec.name);
    let [h, w, c] = qm.spec.input;
    let px = h * w * c;
    let s0 = qm.sites[0];
    let mut correct = 0usize;
    let mut idx = 0usize;
    // Quantize + batch on the fly.
    while idx < images.len() {
        let take = (images.len() - idx).min(batch);
        let mut buf = vec![0i8; batch * px];
        for j in 0..take {
            for (d, &v) in buf[j * px..(j + 1) * px].iter_mut().zip(&images[idx + j].data) {
                *d = crate::nn::quant::quantize_value(v, s0, 8);
            }
        }
        let exe = session.load(&stem)?;
        let out = run_qfwd(exe, qm, &buf, batch)?;
        for j in 0..take {
            if out.preds[j] as usize == labels[idx + j] {
                correct += 1;
            }
        }
        idx += take;
    }
    Ok(correct as f32 / images.len() as f32)
}
