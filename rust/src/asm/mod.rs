//! Macro-assembler: the program-builder the NN kernel code generators
//! target (the reproduction equivalent of the paper's GCC-binutils
//! intrinsics — it splices the Table-2 encodings into generated kernels).
//!
//! Features: string labels, branch/jump resolution with automatic
//! **branch relaxation** (out-of-range conditional branches are rewritten
//! as an inverted branch over a `jal`), `li` immediate splitting, and the
//! usual pseudo-instructions (`mv`, `nop`, `j`, `call`, `ret`).

use crate::isa::*;
use std::collections::HashMap;

/// Opaque label handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Label(usize);

#[derive(Debug, Clone, Copy)]
enum Item {
    /// A fully-formed instruction.
    Instr(Instr),
    /// Conditional branch to a label (may relax to 2 instructions).
    Branch { op: BranchOp, rs1: Reg, rs2: Reg, target: Label },
    /// `jal rd, label`.
    Jump { rd: Reg, target: Label },
}

/// The assembler/program builder.
#[derive(Debug, Default)]
pub struct Asm {
    items: Vec<Item>,
    /// label id -> item index it is bound to (usize::MAX = unbound).
    label_pos: Vec<usize>,
    names: HashMap<String, Label>,
}

impl Asm {
    /// New empty program.
    pub fn new() -> Self {
        Self::default()
    }

    /// Create (or look up) a named label. Labels may be referenced before
    /// they are placed.
    pub fn label(&mut self, name: &str) -> Label {
        if let Some(&l) = self.names.get(name) {
            return l;
        }
        let l = Label(self.label_pos.len());
        self.label_pos.push(usize::MAX);
        self.names.insert(name.to_string(), l);
        l
    }

    /// Create a fresh anonymous label.
    pub fn new_label(&mut self) -> Label {
        let l = Label(self.label_pos.len());
        self.label_pos.push(usize::MAX);
        l
    }

    /// Bind `label` to the current position.
    pub fn bind(&mut self, label: Label) {
        assert_eq!(self.label_pos[label.0], usize::MAX, "label bound twice");
        self.label_pos[label.0] = self.items.len();
    }

    /// Bind a named label here (creating it if needed).
    pub fn here(&mut self, name: &str) -> Label {
        let l = self.label(name);
        self.bind(l);
        l
    }

    /// Emit a raw instruction.
    pub fn emit(&mut self, i: Instr) -> &mut Self {
        self.items.push(Item::Instr(i));
        self
    }

    // ---- pseudo-instructions -------------------------------------------

    /// Load a full 32-bit immediate (1 or 2 instructions).
    pub fn li(&mut self, rd: Reg, imm: i32) -> &mut Self {
        if (-2048..=2047).contains(&imm) {
            self.addi(rd, reg::ZERO, imm)
        } else {
            let hi = imm.wrapping_add(0x800) & !0xfff;
            let lo = imm.wrapping_sub(hi);
            self.emit(Instr::Lui { rd, imm: hi });
            if lo != 0 {
                self.addi(rd, rd, lo);
            }
            self
        }
    }

    /// Register move.
    pub fn mv(&mut self, rd: Reg, rs: Reg) -> &mut Self {
        self.addi(rd, rs, 0)
    }

    /// No-op.
    pub fn nop(&mut self) -> &mut Self {
        self.addi(reg::ZERO, reg::ZERO, 0)
    }

    /// Unconditional jump to label.
    pub fn j(&mut self, target: Label) -> &mut Self {
        self.items.push(Item::Jump { rd: reg::ZERO, target });
        self
    }

    /// Call (jal ra, label).
    pub fn call(&mut self, target: Label) -> &mut Self {
        self.items.push(Item::Jump { rd: reg::RA, target });
        self
    }

    /// Return (jalr x0, 0(ra)).
    pub fn ret(&mut self) -> &mut Self {
        self.emit(Instr::Jalr { rd: reg::ZERO, rs1: reg::RA, offset: 0 })
    }

    // ---- ALU ------------------------------------------------------------

    /// `addi rd, rs1, imm`.
    pub fn addi(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.emit(Instr::OpImm { op: AluOp::Add, rd, rs1, imm })
    }

    /// `add rd, rs1, rs2`.
    pub fn add(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.emit(Instr::Op { op: AluOp::Add, rd, rs1, rs2 })
    }

    /// `sub rd, rs1, rs2`.
    pub fn sub(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.emit(Instr::Op { op: AluOp::Sub, rd, rs1, rs2 })
    }

    /// `slli rd, rs1, shamt`.
    pub fn slli(&mut self, rd: Reg, rs1: Reg, shamt: i32) -> &mut Self {
        self.emit(Instr::OpImm { op: AluOp::Sll, rd, rs1, imm: shamt })
    }

    /// `srai rd, rs1, shamt`.
    pub fn srai(&mut self, rd: Reg, rs1: Reg, shamt: i32) -> &mut Self {
        self.emit(Instr::OpImm { op: AluOp::Sra, rd, rs1, imm: shamt })
    }

    /// `srli rd, rs1, shamt`.
    pub fn srli(&mut self, rd: Reg, rs1: Reg, shamt: i32) -> &mut Self {
        self.emit(Instr::OpImm { op: AluOp::Srl, rd, rs1, imm: shamt })
    }

    /// `andi rd, rs1, imm`.
    pub fn andi(&mut self, rd: Reg, rs1: Reg, imm: i32) -> &mut Self {
        self.emit(Instr::OpImm { op: AluOp::And, rd, rs1, imm })
    }

    /// `sra rd, rs1, rs2`.
    pub fn sra(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.emit(Instr::Op { op: AluOp::Sra, rd, rs1, rs2 })
    }

    /// `slt rd, rs1, rs2`.
    pub fn slt(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.emit(Instr::Op { op: AluOp::Slt, rd, rs1, rs2 })
    }

    /// `xor rd, rs1, rs2`.
    pub fn xor(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.emit(Instr::Op { op: AluOp::Xor, rd, rs1, rs2 })
    }

    /// `and rd, rs1, rs2`.
    pub fn and(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.emit(Instr::Op { op: AluOp::And, rd, rs1, rs2 })
    }

    /// `mul rd, rs1, rs2`.
    pub fn mul(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.emit(Instr::MulDiv { op: MulOp::Mul, rd, rs1, rs2 })
    }

    /// `mulh rd, rs1, rs2` (signed high half).
    pub fn mulh(&mut self, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        self.emit(Instr::MulDiv { op: MulOp::Mulh, rd, rs1, rs2 })
    }

    // ---- memory ----------------------------------------------------------

    /// `lw rd, offset(rs1)`.
    pub fn lw(&mut self, rd: Reg, rs1: Reg, offset: i32) -> &mut Self {
        self.emit(Instr::Load { op: LoadOp::Lw, rd, rs1, offset })
    }

    /// `lb rd, offset(rs1)` (sign-extending byte load — int8 operands).
    pub fn lb(&mut self, rd: Reg, rs1: Reg, offset: i32) -> &mut Self {
        self.emit(Instr::Load { op: LoadOp::Lb, rd, rs1, offset })
    }

    /// `lbu rd, offset(rs1)`.
    pub fn lbu(&mut self, rd: Reg, rs1: Reg, offset: i32) -> &mut Self {
        self.emit(Instr::Load { op: LoadOp::Lbu, rd, rs1, offset })
    }

    /// `sw rs2, offset(rs1)`.
    pub fn sw(&mut self, rs1: Reg, rs2: Reg, offset: i32) -> &mut Self {
        self.emit(Instr::Store { op: StoreOp::Sw, rs1, rs2, offset })
    }

    /// `sb rs2, offset(rs1)`.
    pub fn sb(&mut self, rs1: Reg, rs2: Reg, offset: i32) -> &mut Self {
        self.emit(Instr::Store { op: StoreOp::Sb, rs1, rs2, offset })
    }

    // ---- control flow -----------------------------------------------------

    /// Conditional branch to a label.
    pub fn branch(&mut self, op: BranchOp, rs1: Reg, rs2: Reg, target: Label) -> &mut Self {
        self.items.push(Item::Branch { op, rs1, rs2, target });
        self
    }

    /// `bne rs1, rs2, label`.
    pub fn bne(&mut self, rs1: Reg, rs2: Reg, target: Label) -> &mut Self {
        self.branch(BranchOp::Bne, rs1, rs2, target)
    }

    /// `beq rs1, rs2, label`.
    pub fn beq(&mut self, rs1: Reg, rs2: Reg, target: Label) -> &mut Self {
        self.branch(BranchOp::Beq, rs1, rs2, target)
    }

    /// `blt rs1, rs2, label`.
    pub fn blt(&mut self, rs1: Reg, rs2: Reg, target: Label) -> &mut Self {
        self.branch(BranchOp::Blt, rs1, rs2, target)
    }

    /// `bge rs1, rs2, label`.
    pub fn bge(&mut self, rs1: Reg, rs2: Reg, target: Label) -> &mut Self {
        self.branch(BranchOp::Bge, rs1, rs2, target)
    }

    /// `bltu rs1, rs2, label`.
    pub fn bltu(&mut self, rs1: Reg, rs2: Reg, target: Label) -> &mut Self {
        self.branch(BranchOp::Bltu, rs1, rs2, target)
    }

    // ---- custom extension -------------------------------------------------

    /// `nn_mac_<x>b rd, rs1, rs2` — the paper's mixed-precision MAC.
    pub fn nn_mac(&mut self, mode: MacMode, rd: Reg, rs1: Reg, rs2: Reg) -> &mut Self {
        assert!(
            rs1 as u32 + mode.activation_regs() <= NUM_REGS as u32,
            "nn_mac activation register group x{}..x{} overruns the register file",
            rs1,
            rs1 as u32 + mode.activation_regs() - 1
        );
        self.emit(Instr::NnMac { mode, rd, rs1, rs2 })
    }

    /// CSR read: `csrrs rd, csr, x0`.
    pub fn csrr(&mut self, rd: Reg, csr: u16) -> &mut Self {
        self.emit(Instr::Csr { op: CsrOp::Rs, rd, rs1: reg::ZERO, csr })
    }

    /// Halt (`ecall`).
    pub fn halt(&mut self) -> &mut Self {
        self.emit(Instr::Ecall)
    }

    // ---- assembly ----------------------------------------------------------

    /// Resolve labels and produce the final instruction stream.
    ///
    /// Runs an iterative relaxation fixpoint: conditional branches whose
    /// resolved offset exceeds ±4 KiB become `b!cond +8; jal x0, target`.
    pub fn assemble(&mut self) -> Vec<Instr> {
        for (name, l) in &self.names {
            assert_ne!(self.label_pos[l.0], usize::MAX, "label `{name}` was never bound");
        }
        // long[i]: item i is a relaxed (2-instruction) branch.
        let mut long = vec![false; self.items.len()];
        loop {
            // addr[i] = instruction index of item i under current relaxation.
            let mut addr = Vec::with_capacity(self.items.len() + 1);
            let mut a = 0usize;
            for (i, item) in self.items.iter().enumerate() {
                addr.push(a);
                a += match item {
                    Item::Branch { .. } if long[i] => 2,
                    _ => 1,
                };
            }
            addr.push(a);
            let label_addr =
                |l: Label| -> i64 { 4 * addr[self.label_pos[l.0]] as i64 };

            let mut changed = false;
            for (i, item) in self.items.iter().enumerate() {
                if let Item::Branch { target, .. } = item {
                    if !long[i] {
                        let off = label_addr(*target) - 4 * addr[i] as i64;
                        if !(-4096..=4094).contains(&off) {
                            long[i] = true;
                            changed = true;
                        }
                    }
                }
            }
            if changed {
                continue;
            }

            // Emit.
            let mut out = Vec::with_capacity(a);
            for (i, item) in self.items.iter().enumerate() {
                let pc = 4 * addr[i] as i64;
                match *item {
                    Item::Instr(ins) => out.push(ins),
                    Item::Jump { rd, target } => {
                        let off = label_addr(target) - pc;
                        out.push(Instr::Jal { rd, offset: off as i32 });
                    }
                    Item::Branch { op, rs1, rs2, target } => {
                        let off = label_addr(target) - pc;
                        if long[i] {
                            out.push(Instr::Branch {
                                op: invert(op),
                                rs1,
                                rs2,
                                offset: 8,
                            });
                            out.push(Instr::Jal { rd: reg::ZERO, offset: (off - 4) as i32 });
                        } else {
                            out.push(Instr::Branch { op, rs1, rs2, offset: off as i32 });
                        }
                    }
                }
            }
            return out;
        }
    }

    /// Assemble and encode into machine words.
    pub fn assemble_words(&mut self) -> Vec<u32> {
        crate::isa::encode::encode_program(&self.assemble())
    }

    /// Current item count (upper bound on instruction index).
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True if no instructions were emitted.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

fn invert(op: BranchOp) -> BranchOp {
    match op {
        BranchOp::Beq => BranchOp::Bne,
        BranchOp::Bne => BranchOp::Beq,
        BranchOp::Blt => BranchOp::Bge,
        BranchOp::Bge => BranchOp::Blt,
        BranchOp::Bltu => BranchOp::Bgeu,
        BranchOp::Bgeu => BranchOp::Bltu,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Core, CoreConfig, ExitReason};

    fn run(asm: &mut Asm) -> Core {
        let prog = asm.assemble();
        let mut core = Core::new(CoreConfig { mem_size: 1 << 16, ..Default::default() }, prog, 0);
        assert_eq!(core.run(10_000_000), ExitReason::Ecall);
        core
    }

    #[test]
    fn countdown_loop() {
        let mut a = Asm::new();
        a.li(reg::T0, 10).li(reg::T1, 0);
        let top = a.here("loop");
        a.add(reg::T1, reg::T1, reg::T0);
        a.addi(reg::T0, reg::T0, -1);
        a.bne(reg::T0, reg::ZERO, top);
        a.halt();
        let core = run(&mut a);
        assert_eq!(core.regs[reg::T1 as usize], 55);
    }

    #[test]
    fn li_splits_large_immediates() {
        for imm in [0, 1, -1, 2047, -2048, 2048, -2049, 0x12345678, i32::MIN, i32::MAX, -0x800_0000]
        {
            let mut a = Asm::new();
            a.li(reg::A0, imm);
            a.halt();
            let core = run(&mut a);
            assert_eq!(core.regs[reg::A0 as usize] as i32, imm, "imm {imm:#x}");
        }
    }

    #[test]
    fn forward_references_resolve() {
        let mut a = Asm::new();
        let end = a.label("end");
        a.li(reg::A0, 1);
        a.j(end);
        a.li(reg::A0, 2); // skipped
        a.bind(end);
        a.halt();
        let core = run(&mut a);
        assert_eq!(core.regs[reg::A0 as usize], 1);
    }

    #[test]
    fn branch_relaxation_over_4k() {
        // A conditional branch across > 1024 instructions must relax.
        let mut a = Asm::new();
        let far = a.label("far");
        a.li(reg::A0, 5);
        a.beq(reg::A0, reg::A0, far); // taken, out of short range
        for _ in 0..2000 {
            a.addi(reg::A1, reg::A1, 1); // must be skipped
        }
        a.bind(far);
        a.halt();
        let core = run(&mut a);
        assert_eq!(core.regs[reg::A1 as usize], 0, "relaxed branch must skip the filler");
    }

    #[test]
    fn call_ret() {
        let mut a = Asm::new();
        let f = a.label("f");
        a.li(reg::A0, 0);
        a.call(f);
        a.call(f);
        a.halt();
        a.bind(f);
        a.addi(reg::A0, reg::A0, 7);
        a.ret();
        let core = run(&mut a);
        assert_eq!(core.regs[reg::A0 as usize], 14);
    }

    #[test]
    #[should_panic(expected = "never bound")]
    fn unbound_label_panics() {
        let mut a = Asm::new();
        let l = a.label("nowhere");
        a.j(l);
        a.assemble();
    }

    #[test]
    #[should_panic(expected = "overruns the register file")]
    fn nn_mac_register_group_checked() {
        let mut a = Asm::new();
        a.nn_mac(MacMode::W2, reg::A0, 30, reg::A1);
    }
}
