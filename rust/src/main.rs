//! `mpnn` — CLI for the mixed-precision RISC-V co-design framework.
//!
//! Experiment subcommands regenerate every table/figure of the paper
//! (results are printed and written under `results/`); utility
//! subcommands expose the ISA/simulator substrate.

use mpnn::{bail, Result};
use mpnn::dse::shard::{ShardSpec, ShardStrategy};
use mpnn::exp::{self, EvalBackend, ExpOpts};
use mpnn::json::Json;

const USAGE: &str = "\
mpnn — Mixed-precision NNs on RISC-V cores (ICCAD'24) reproduction

USAGE: mpnn <COMMAND> [OPTIONS]

Experiment commands (paper artifacts; results go to results/*.json):
  table3     Baseline model characteristics (Table 3)
  fig4       MobileNetV1 per-layer memory-access reduction (Fig. 4)
  fig6       Accuracy-vs-MAC-instructions Pareto sweep (Fig. 6)
  fig7       Per-Mode cycle breakdown, dense + conv layer (Fig. 7)
  fig8       End-to-end speedups at 1/2/5% accuracy loss (Fig. 8)
  table4     FPGA/ASIC energy-efficiency comparison (Table 4)
  table5     State-of-the-art comparison (Table 5)
  all        Everything above, sharing one DSE sweep per model

Utility commands:
  disasm <hex words...>     Decode/disassemble instruction words
  demo                      Assemble + run a small nn_mac program
  trace                     Run one model on the ISS through its compiled
                            execution plan and write a per-step JSONL
                            trace (requires --trace-steps; first --models
                            entry, default lenet5)
  xcheck                    Verify Rust arithmetic vs python xcheck.json
  serve                     Warm-evaluator daemon over the result store:
                            answers POST /eval, GET /pareto?model=..,
                            GET /stats and /shutdown as HTTP/JSON while
                            keeping simulator sessions, plan cache and
                            cost cache resident (requires --store and a
                            pinned --evaluator)

OPTIONS:
  --artifacts <dir>   Artifacts directory (default: auto-discover)
  --eval <n>          Images per accuracy evaluation (default 128)
  --budget <n>        DSE configuration budget per model (default 120)
  --evaluator <b>     Accuracy backend: auto|host|iss|analytic|pjrt
                      (default auto). `iss` runs every evaluation batch
                      through the simulated core: accuracy + cycles from
                      the same binary-level runs, with host-vs-ISS
                      divergence reported per config. `analytic` is its
                      fast path: each distinct kernel shape simulates
                      once, then replays as a host kernel with
                      cache-served counters (see docs/EVALUATORS.md)
  --audit-every <k>   (analytic) replay every kth batch element on the
                      real ISS and bit-compare logits + counters
                      (0 = off, default; 1 = check every element)
  --eval-workers <n>  ISS-evaluator batch worker threads (default 4)
  --host-eval         Shorthand for --evaluator host
  --seed <n>          Random seed (default 0xD5E)
  --models <a,b,…>    Restrict fig6/fig8 sweeps to these models
  --trace-steps <p>   (trace) JSONL output path for the per-step
                      execution-plan trace

Sharded sweeps (fig6/fig8; see docs/ARCHITECTURE.md § Sharded sweeps):
  --shard <i/n>       fig6: evaluate only shard i of an n-way split of
                      each model's config space and write a versioned
                      shard artifact instead of a full result. Every
                      shard (process/host) must use the same --seed,
                      --budget, --eval and --evaluator. Artifacts are
                      checkpointed every few configs, and re-running a
                      shard whose artifact already exists *resumes* it:
                      cleanly-parsed points are kept and only missing
                      configs are evaluated.
  --shard-strategy <s>  hash | range partitioning (default hash)
  --shard-out <dir>   Where shard artifacts go (default results/shards)
  --merge <file>      Merge shard artifacts (repeatable) instead of
                      sweeping: dedups configs, recomputes the global
                      Pareto front and fails typed on shard conflicts.
                      The merged result is bit-identical to the
                      unsharded sweep.
  --merge-dir <dir>   Merge every *.s<i>of<n>.json shard artifact found
                      in <dir> (convenience form of repeating --merge;
                      combinable with explicit --merge files)

Result store & serve (see docs/ARCHITECTURE.md § Result store & serve):
  --store <dir>       fig6/fig8/all/serve: persistent content-addressed
                      result store. Evaluation reports are keyed by plan
                      content fingerprint + dataset digest + sample
                      count + MAC config + backend tag and written
                      atomically; sweeps consult the store before
                      running the backend, so a re-run (or another
                      process sharing <dir>) re-evaluates nothing and
                      reproduces byte-identical results. Requires a
                      pinned --evaluator (not auto). Corrupt entries are
                      quarantined to `<entry>.bad` and recomputed.
  --addr <host:port>  (serve) listen address (default 127.0.0.1:7979)

Cluster execution (fig6/fig8; see docs/ARCHITECTURE.md § Cluster
execution):
  --cores <n>         Price every configuration through an n-core
                      cluster with banked-TCDM contention: each layer's
                      output channels split across cores, per-layer
                      barrier = slowest core, plus bank-conflict stall
                      cycles. 1 (the default) is the single-core paper
                      machine and reproduces existing outputs
                      byte-for-byte; n>1 adds a `cluster` block
                      (per-core utilization, bank stalls) to fig6 and
                      joins the store/shard identity key.

Guided search (fig6/fig8; see docs/ARCHITECTURE.md § Guided search):
  --search <s>        exhaustive | guided (default exhaustive). Guided
                      prunes configs whose analytic cycle lower bound is
                      dominated, then successive-halves the survivors on
                      growing input prefixes before full evaluation. The
                      Pareto front matches the exhaustive sweep exactly
                      (zero regret by construction); only the evaluation
                      count shrinks. Composes with --shard/--merge, but
                      artifacts from the two strategies never mix.
  --rungs <n>         (guided) successive-halving rung count, >= 1
                      (default 3)
  --eta <n>           (guided) halving factor, >= 2 (default 2)
  --space-budget <n>  Refuse to sweep a config space larger than n
                      configurations (typed error naming the flag).
                      The space itself is streamed by enumeration
                      index, never materialized — this caps *work*,
                      not memory (default: unlimited)
  --max-alive <n>     (guided) refuse to materialize more than n
                      configurations for full evaluation at once
                      (alive survivors + repair batches). Bounds the
                      sweep's peak memory at O(alive + front); a
                      typed error beats an OOM kill (default:
                      unlimited)
";

fn parse_opts(args: &[String]) -> Result<ExpOpts> {
    use mpnn::dse::search::SearchStrategy;
    let mut opts = ExpOpts::default();
    let mut shard_strategy = None;
    let mut rungs = None;
    let mut eta = None;
    let mut max_alive = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--artifacts" => {
                opts.artifacts = it.next().map(Into::into).unwrap_or(opts.artifacts)
            }
            "--eval" => opts.eval_n = it.next().and_then(|v| v.parse().ok()).unwrap_or(opts.eval_n),
            "--budget" => {
                opts.budget = it.next().and_then(|v| v.parse().ok()).unwrap_or(opts.budget)
            }
            "--evaluator" => {
                let v = it.next().ok_or_else(|| {
                    mpnn::anyhow!("--evaluator needs a value (auto|host|iss|analytic|pjrt)")
                })?;
                opts.backend = EvalBackend::parse(v).ok_or_else(|| {
                    mpnn::anyhow!("unknown evaluator `{v}` (auto|host|iss|analytic|pjrt)")
                })?;
            }
            "--audit-every" => {
                let v = it.next().ok_or_else(|| mpnn::anyhow!("--audit-every needs a count"))?;
                opts.audit_every =
                    v.parse().map_err(|_| mpnn::anyhow!("--audit-every: bad count `{v}`"))?;
            }
            "--eval-workers" => {
                opts.eval_workers =
                    it.next().and_then(|v| v.parse().ok()).unwrap_or(opts.eval_workers)
            }
            "--host-eval" => opts.backend = EvalBackend::Host,
            "--seed" => opts.seed = it.next().and_then(|v| v.parse().ok()).unwrap_or(opts.seed),
            "--shard" => {
                let v = it.next().ok_or_else(|| mpnn::anyhow!("--shard needs `i/n`"))?;
                opts.shard = Some(ShardSpec::parse(v).map_err(|e| mpnn::anyhow!("{e}"))?);
            }
            "--shard-strategy" => {
                let v = it
                    .next()
                    .ok_or_else(|| mpnn::anyhow!("--shard-strategy needs a value (hash|range)"))?;
                shard_strategy = Some(
                    ShardStrategy::parse(v)
                        .ok_or_else(|| mpnn::anyhow!("unknown shard strategy `{v}` (hash|range)"))?,
                );
            }
            "--shard-out" => {
                opts.shard_out = Some(
                    it.next().ok_or_else(|| mpnn::anyhow!("--shard-out needs a directory"))?.into(),
                )
            }
            "--merge" => opts
                .merge
                .push(it.next().ok_or_else(|| mpnn::anyhow!("--merge needs a file"))?.into()),
            "--merge-dir" => {
                opts.merge_dir = Some(
                    it.next()
                        .ok_or_else(|| mpnn::anyhow!("--merge-dir needs a directory"))?
                        .into(),
                )
            }
            "--trace-steps" => {
                opts.trace_steps = Some(
                    it.next().ok_or_else(|| mpnn::anyhow!("--trace-steps needs a path"))?.into(),
                )
            }
            "--models" => {
                let v = it.next().ok_or_else(|| mpnn::anyhow!("--models needs a,b,…"))?;
                opts.models =
                    Some(v.split(',').map(|m| m.trim().to_string()).filter(|m| !m.is_empty()).collect());
            }
            "--search" => {
                let v = it
                    .next()
                    .ok_or_else(|| mpnn::anyhow!("--search needs a value (exhaustive|guided)"))?;
                opts.search = SearchStrategy::parse(v).ok_or_else(|| {
                    mpnn::anyhow!("unknown search strategy `{v}` (exhaustive|guided)")
                })?;
            }
            "--store" => {
                opts.store = Some(
                    it.next().ok_or_else(|| mpnn::anyhow!("--store needs a directory"))?.into(),
                )
            }
            "--addr" => {
                opts.addr = it
                    .next()
                    .ok_or_else(|| mpnn::anyhow!("--addr needs host:port"))?
                    .to_string()
            }
            "--cores" => {
                let v = it.next().ok_or_else(|| mpnn::anyhow!("--cores needs a count"))?;
                let n: usize =
                    v.parse().map_err(|_| mpnn::anyhow!("--cores: bad count `{v}`"))?;
                mpnn::ensure!(
                    (1..=64).contains(&n),
                    "--cores must be in 1..=64 (got {n})"
                );
                opts.cores = n;
            }
            "--rungs" => {
                let v = it.next().ok_or_else(|| mpnn::anyhow!("--rungs needs a count"))?;
                rungs = Some(v.parse().map_err(|_| mpnn::anyhow!("--rungs: bad count `{v}`"))?);
            }
            "--eta" => {
                let v = it.next().ok_or_else(|| mpnn::anyhow!("--eta needs a factor"))?;
                eta = Some(v.parse().map_err(|_| mpnn::anyhow!("--eta: bad factor `{v}`"))?);
            }
            "--space-budget" => {
                let v = it.next().ok_or_else(|| mpnn::anyhow!("--space-budget needs a count"))?;
                let n: usize =
                    v.parse().map_err(|_| mpnn::anyhow!("--space-budget: bad count `{v}`"))?;
                mpnn::ensure!(n >= 1, "--space-budget must be >= 1 (got {n})");
                opts.space_budget = Some(n);
            }
            "--max-alive" => {
                let v = it.next().ok_or_else(|| mpnn::anyhow!("--max-alive needs a count"))?;
                let n: usize =
                    v.parse().map_err(|_| mpnn::anyhow!("--max-alive: bad count `{v}`"))?;
                mpnn::ensure!(n >= 1, "--max-alive must be >= 1 (got {n})");
                max_alive = Some(n);
            }
            other => bail!("unknown option `{other}`\n{USAGE}"),
        }
    }
    // Flag order must not matter: apply the strategy after the loop.
    match (&mut opts.shard, shard_strategy) {
        (Some(spec), Some(s)) => spec.strategy = s,
        (None, Some(_)) => bail!("--shard-strategy requires --shard i/n"),
        _ => {}
    }
    // Same for the guided-search knobs.
    if opts.search == SearchStrategy::Exhaustive
        && (rungs.is_some() || eta.is_some() || max_alive.is_some())
    {
        bail!("--rungs/--eta/--max-alive require --search guided");
    }
    opts.max_alive = max_alive;
    if let Some(r) = rungs {
        mpnn::ensure!(r >= 1, "--rungs must be >= 1");
        opts.rungs = r;
    }
    if let Some(e) = eta {
        mpnn::ensure!(e >= 2, "--eta must be >= 2");
        opts.eta = e;
    }
    // The store keys embed the resolved backend tag — fail the
    // ambiguous combination up front, not mid-sweep.
    if opts.store.is_some() && opts.backend == EvalBackend::Auto {
        bail!(
            "--store requires a pinned --evaluator (host|iss|analytic|pjrt); `auto` \
             resolves per machine and would key the store inconsistently"
        );
    }
    // Validate --models early so typos fail before a sweep starts.
    opts.model_names()?;
    Ok(opts)
}

fn save(name: &str, json: &Json) -> Result<()> {
    exp::write_result(name, json)?;
    println!("[saved results/{name}.json]");
    Ok(())
}

fn cmd_all(opts: &ExpOpts) -> Result<()> {
    mpnn::ensure!(
        opts.shard.is_none() && !opts.wants_merge(),
        "`all` shares one full sweep per model; shard with `fig6 --shard` and \
         merge with `fig6 --merge` / `fig8 --merge` instead"
    );
    let (_, j3) = exp::table3::run(opts)?;
    save("table3", &j3)?;
    let (_, j7) = exp::fig7::run(opts)?;
    save("fig7", &j7)?;
    // One sweep per model feeds fig6 + fig8 + table4 + table5.
    let mut sweeps = Vec::new();
    for name in opts.model_names()? {
        eprintln!("[all] sweeping {name}");
        sweeps.push(exp::fig6::sweep_model(opts, name)?);
    }
    let mut sels = Vec::new();
    for s in sweeps {
        sels.push(exp::fig8::select(s));
    }
    // Fig. 6 output from the shared sweeps (retained inside the selections).
    let mut fig6_arr = Vec::new();
    for m in &sels {
        exp::fig6::print_summary(&m.sweep);
        fig6_arr.push(exp::fig6::sweep_json(&m.sweep));
    }
    save("fig6", &Json::Arr(fig6_arr))?;
    exp::fig8::print(&sels);
    save("fig8", &exp::fig8::to_json(&sels))?;
    // Fig. 4 with the actual selected MobileNet configs (defaults when
    // `--models` filtered MobileNet out of the sweep set).
    let cfgs: Vec<(String, Vec<u32>)> = sels
        .iter()
        .find(|m| m.model == "mobilenet_v1")
        .map(|mobile| {
            mobile
                .selections
                .iter()
                .flatten()
                .map(|s| (format!("<{:.0}% loss", s.threshold * 100.0), s.bits.clone()))
                .collect()
        })
        .unwrap_or_default();
    let (_, j4) = exp::fig4::run_with(opts, if cfgs.is_empty() { None } else { Some(cfgs) })?;
    save("fig4", &j4)?;
    let (_, jt4) = exp::table4::from_selections(opts, &sels)?;
    save("table4", &jt4)?;
    let (_, jt5) = exp::table5::from_selections(opts, &sels)?;
    save("table5", &jt5)?;
    Ok(())
}

fn cmd_disasm(args: &[String]) -> Result<()> {
    for a in args {
        let w = u32::from_str_radix(a.trim_start_matches("0x"), 16)?;
        match mpnn::isa::decode::decode(w) {
            Ok(i) => println!("{w:#010x}  {}", mpnn::isa::disasm::disasm(i)),
            Err(e) => println!("{w:#010x}  <{e}>"),
        }
    }
    Ok(())
}

fn cmd_demo() -> Result<()> {
    use mpnn::asm::Asm;
    use mpnn::isa::custom::{pack_acts, pack_weights};
    use mpnn::isa::{reg, MacMode};
    use mpnn::sim::{Core, CoreConfig};

    println!("demo: 16 MACs in one nn_mac_2b instruction");
    let mut a = Asm::new();
    a.li(reg::A0, 0); // accumulator
    for (i, r) in [reg::A2, reg::A3, reg::A4, reg::A5].iter().enumerate() {
        a.li(*r, pack_acts([(i as i8 + 1); 4]) as i32);
    }
    a.li(reg::A1, pack_weights(MacMode::W2, &[1i8; 16]) as i32);
    a.nn_mac(MacMode::W2, reg::A0, reg::A2, reg::A1);
    a.halt();
    let prog = a.assemble();
    println!("--- listing ---");
    for (pc, i) in prog.iter().enumerate() {
        println!("{:4x}: {}", pc * 4, mpnn::isa::disasm::disasm(*i));
    }
    let mut core = Core::new(CoreConfig { mem_size: 4096, ..Default::default() }, prog, 0);
    core.run(10_000);
    println!("--- result ---");
    println!("acc (a0) = {}   [expect 4·(1+2+3+4) = 40]", core.regs[reg::A0 as usize]);
    println!("cycles = {}, instret = {}, MACs = {}", core.perf.cycles, core.perf.instret, core.perf.macs);
    Ok(())
}

/// Run one model on the ISS through its compiled execution plan with
/// the step-trace observer attached, writing one JSON line per step —
/// the step-granular trace surface of the plan executor (no legacy
/// interpreter involved; see docs/ARCHITECTURE.md § Execution plans).
fn cmd_trace(opts: &ExpOpts) -> Result<()> {
    use mpnn::models::infer::{quantize_input, quantize_model};
    use mpnn::models::plan::plan_for;
    use mpnn::models::sim_exec::{modes_for, run_plan, ExecMode, StepTrace};
    use mpnn::sim::MacUnitConfig;

    let path = opts
        .trace_steps
        .clone()
        .ok_or_else(|| mpnn::anyhow!("trace needs --trace-steps <path> (JSONL output)"))?;
    let name = opts
        .models
        .as_ref()
        .and_then(|m| m.first().cloned())
        .unwrap_or_else(|| "lenet5".to_string());
    let model = opts.load_model(&name)?;
    let n = mpnn::models::analyze(&model.spec).layers.len();
    // A representative mixed configuration: sensitive first layer at
    // 8-bit (the paper's pinning), 4-bit elsewhere.
    let mut bits = vec![4u32; n];
    bits[0] = 8;
    let qm = quantize_model(&model.spec, &model.params, &model.sites, &bits);
    let plan = plan_for(&qm, &modes_for(&qm))?;
    let input = quantize_input(&qm, &model.test.images[0]);

    let mut trace = StepTrace::create(&path)?;
    let run = run_plan(&plan, &input, MacUnitConfig::full(), ExecMode::Iss, Some(&mut trace))?;
    let steps = trace.steps;
    trace.finish()?;
    println!(
        "trace: {name} bits {bits:?} — {} plan steps ({} kernels), {} cycles, pred {} -> {}",
        steps,
        run.layers.len(),
        run.total_cycles(),
        run.argmax(),
        path.display()
    );
    Ok(())
}

fn cmd_xcheck(opts: &ExpOpts) -> Result<()> {
    let path = opts.artifacts.join("xcheck.json");
    let text = std::fs::read_to_string(&path)?;
    let v = Json::parse(&text).map_err(|e| mpnn::anyhow!("{e}"))?;
    let mut n = 0;
    for case in v.get("requantize").and_then(|j| j.as_arr()).unwrap_or(&[]) {
        // Schema-checked field access: a malformed vector file names
        // the offending field instead of panicking mid-loop.
        let rq = mpnn::nn::quant::Requant {
            m: case.req_i64("m")? as i32,
            shift: case.req_i64("shift")? as i32,
        };
        let got =
            mpnn::nn::quant::requantize(case.req_i64("acc")? as i32, rq, case.req_bool("relu")?);
        let want = case.req_i64("out")? as i8;
        mpnn::ensure!(got == want, "requantize mismatch: {case:?} got {got}");
        n += 1;
    }
    println!("xcheck: {n} requantize vectors OK (python == rust, bit-exact)");
    Ok(())
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first() else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        "table3" => save("table3", &exp::table3::run(&parse_opts(rest)?)?.1),
        "fig4" => save("fig4", &exp::fig4::run(&parse_opts(rest)?)?.1),
        "fig6" => {
            let opts = parse_opts(rest)?;
            let (_, json) = exp::fig6::run(&opts)?;
            // A shard run emits a shard-artifact manifest, not Fig.-6
            // data — keep it away from results/fig6.json so a sharded
            // rerun can't clobber a previously completed figure.
            save(if opts.shard.is_some() { "fig6_shard" } else { "fig6" }, &json)
        }
        "fig7" => save("fig7", &exp::fig7::run(&parse_opts(rest)?)?.1),
        "fig8" => save("fig8", &exp::fig8::run(&parse_opts(rest)?)?.1),
        "table4" => save("table4", &exp::table4::run(&parse_opts(rest)?)?.1),
        "table5" => save("table5", &exp::table5::run(&parse_opts(rest)?)?.1),
        "all" => cmd_all(&parse_opts(rest)?),
        "disasm" => cmd_disasm(rest),
        "demo" => cmd_demo(),
        "trace" => cmd_trace(&parse_opts(rest)?),
        "xcheck" => cmd_xcheck(&parse_opts(rest)?),
        "serve" => {
            let opts = parse_opts(rest)?;
            mpnn::serve::run(&opts, &opts.addr)
        }
        "--help" | "-h" | "help" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command `{other}`\n{USAGE}"),
    }
}
