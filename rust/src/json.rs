//! Minimal JSON writer (the environment is offline; serde is not in the
//! vendored crate set). Only what the experiment harnesses need: objects,
//! arrays, strings, numbers, bools — always valid UTF-8/RFC 8259 output.

/// A JSON value builder.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// Boolean.
    Bool(bool),
    /// Any finite number (NaN/inf render as `null`).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (insertion-ordered).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of numbers.
    pub fn nums<I: IntoIterator<Item = f64>>(xs: I) -> Json {
        Json::Arr(xs.into_iter().map(Json::Num).collect())
    }

    /// String value.
    pub fn s(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    /// Integer value.
    pub fn i(v: i64) -> Json {
        Json::Num(v as f64)
    }

    /// Serialize to a compact string.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    if *n == n.trunc() && n.abs() < 1e15 {
                        out.push_str(&format!("{}", *n as i64));
                    } else {
                        out.push_str(&format!("{n}"));
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested_structures() {
        let j = Json::obj(vec![
            ("name", Json::s("fig7")),
            ("speedups", Json::nums([1.0, 2.5, 30.9])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        assert_eq!(
            j.to_string(),
            r#"{"name":"fig7","speedups":[1,2.5,30.9],"ok":true,"none":null}"#
        );
    }

    #[test]
    fn escapes_strings() {
        assert_eq!(Json::s("a\"b\\c\nd").to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integers_render_without_decimals() {
        assert_eq!(Json::i(1_000_000).to_string(), "1000000");
        assert_eq!(Json::Num(0.125).to_string(), "0.125");
    }
}

// ------------------------------------------------------------- parsing ---

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// Byte offset of the error.
    pub at: usize,
    /// Message.
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, ParseError> {
        Err(ParseError { at: self.i, msg: msg.to_string() })
    }
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }
    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }
    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            self.err(&format!("expected `{}`", c as char))
        }
    }
    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            self.err(&format!("expected `{s}`"))
        }
    }
    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return self.err("bad \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| ParseError { at: self.i, msg: "bad hex".into() })?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| ParseError { at: self.i, msg: "bad hex".into() })?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // Collect a UTF-8 run.
                    let start = self.i;
                    let mut end = self.i + 1;
                    if c >= 0x80 {
                        while end < self.b.len() && self.b[end] & 0xC0 == 0x80 {
                            end += 1;
                        }
                    }
                    match std::str::from_utf8(&self.b[start..end]) {
                        Ok(chunk) => s.push_str(chunk),
                        Err(_) => return self.err("invalid utf-8"),
                    }
                    self.i = end;
                }
            }
        }
    }
    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        match s.parse::<f64>() {
            Ok(v) => Ok(Json::Num(v)),
            Err(_) => self.err("bad number"),
        }
    }
    fn value(&mut self) -> Result<Json, ParseError> {
        self.ws();
        match self.peek() {
            Some(b'{') => {
                self.i += 1;
                let mut kv = Vec::new();
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                loop {
                    self.ws();
                    let k = self.string()?;
                    self.ws();
                    self.eat(b':')?;
                    let v = self.value()?;
                    kv.push((k, v));
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(kv));
                        }
                        _ => return self.err("expected , or }"),
                    }
                }
            }
            Some(b'[') => {
                self.i += 1;
                let mut xs = Vec::new();
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                loop {
                    xs.push(self.value()?);
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(xs));
                        }
                        _ => return self.err("expected , or ]"),
                    }
                }
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => self.err("unexpected end of input"),
        }
    }
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return p.err("trailing garbage");
        }
        Ok(v)
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Number accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer accessor.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|v| v as i64)
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

// ------------------------------------------------------------- schema ---

/// Typed field-access error for schema'd documents (the shard-sweep
/// artifacts): names the offending field and what was wrong with it,
/// so a corrupted artifact surfaces as a diagnosable `Err`, never a
/// panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SchemaError {
    /// Dotted path of the field that failed.
    pub field: String,
    /// What was expected / what was found.
    pub msg: String,
}

impl std::fmt::Display for SchemaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON schema error at `{}`: {}", self.field, self.msg)
    }
}

impl std::error::Error for SchemaError {}

fn schema_err<T>(field: &str, msg: &str) -> Result<T, SchemaError> {
    Err(SchemaError { field: field.to_string(), msg: msg.to_string() })
}

impl Json {
    /// Required object field.
    pub fn req(&self, field: &str) -> Result<&Json, SchemaError> {
        match self.get(field) {
            Some(v) => Ok(v),
            None => schema_err(field, "missing required field"),
        }
    }

    /// Required finite-number field.
    pub fn req_f64(&self, field: &str) -> Result<f64, SchemaError> {
        match self.req(field)?.as_f64() {
            Some(v) if v.is_finite() => Ok(v),
            Some(_) => schema_err(field, "expected a finite number"),
            None => schema_err(field, "expected a number"),
        }
    }

    /// Required non-negative integer field (rejects fractional values).
    pub fn req_u64(&self, field: &str) -> Result<u64, SchemaError> {
        let v = self.req_f64(field)?;
        if v < 0.0 || v != v.trunc() {
            return schema_err(field, "expected a non-negative integer");
        }
        Ok(v as u64)
    }

    /// Required integer field, sign allowed (rejects fractional
    /// values).
    pub fn req_i64(&self, field: &str) -> Result<i64, SchemaError> {
        let v = self.req_f64(field)?;
        if v != v.trunc() {
            return schema_err(field, "expected an integer");
        }
        Ok(v as i64)
    }

    /// Required boolean field.
    pub fn req_bool(&self, field: &str) -> Result<bool, SchemaError> {
        match self.req(field)?.as_bool() {
            Some(b) => Ok(b),
            None => schema_err(field, "expected a boolean"),
        }
    }

    /// Required string field.
    pub fn req_str(&self, field: &str) -> Result<&str, SchemaError> {
        match self.req(field)?.as_str() {
            Some(s) => Ok(s),
            None => schema_err(field, "expected a string"),
        }
    }

    /// Required array field.
    pub fn req_arr(&self, field: &str) -> Result<&[Json], SchemaError> {
        match self.req(field)?.as_arr() {
            Some(a) => Ok(a),
            None => schema_err(field, "expected an array"),
        }
    }

    /// Optional field: `None` when absent or `null`; otherwise the
    /// value is handed to `f`, whose schema errors propagate.
    pub fn opt<T>(
        &self,
        field: &str,
        f: impl FnOnce(&Json) -> Result<T, SchemaError>,
    ) -> Result<Option<T>, SchemaError> {
        match self.get(field) {
            None | Some(Json::Null) => Ok(None),
            Some(v) => f(v).map(Some),
        }
    }
}

#[cfg(test)]
mod schema_tests {
    use super::*;

    #[test]
    fn typed_accessors_and_errors() {
        let j = Json::parse(
            r#"{"n": 3, "s": "x", "a": [1], "f": 1.5, "neg": -1, "z": null, "t": true}"#,
        )
        .unwrap();
        assert_eq!(j.req_u64("n").unwrap(), 3);
        assert_eq!(j.req_i64("neg").unwrap(), -1);
        assert!(j.req_bool("t").unwrap());
        assert_eq!(j.req_i64("f").unwrap_err().field, "f");
        assert!(j.req_bool("n").unwrap_err().msg.contains("boolean"));
        assert_eq!(j.req_str("s").unwrap(), "x");
        assert_eq!(j.req_arr("a").unwrap().len(), 1);
        assert_eq!(j.req_f64("f").unwrap(), 1.5);
        assert_eq!(j.opt("z", |v| v.req_u64("x")).unwrap(), None);
        assert_eq!(j.opt("missing", |v| v.req_u64("x")).unwrap(), None);
        assert_eq!(j.opt("n", |v| Ok(v.as_i64().unwrap())).unwrap(), Some(3));

        let e = j.req_u64("neg").unwrap_err();
        assert_eq!(e.field, "neg");
        let e = j.req_u64("f").unwrap_err();
        assert!(e.msg.contains("integer"), "{e}");
        let e = j.req_str("missing").unwrap_err();
        assert!(e.msg.contains("missing"), "{e}");
        // Display carries the field name for diagnosis.
        assert!(format!("{e}").contains("missing"));
    }
}

#[cfg(test)]
mod parse_tests {
    use super::*;

    #[test]
    fn parses_round_trip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny"}, "d": true, "e": null}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap()[2].as_f64(), Some(-300.0));
        assert_eq!(j.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
        assert_eq!(j.get("d").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("e"), Some(&Json::Null));
        // Re-parse our own output.
        let again = Json::parse(&j.to_string()).unwrap();
        assert_eq!(again, j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }
}
