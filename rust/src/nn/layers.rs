//! Float and integer layer implementations — the host-side golden
//! reference for both the RV32 kernel programs and the JAX/Pallas
//! artifacts. The integer path is bit-exact against both (tested).

use super::quant::{requantize, rounding_rshift, srdhm, Requant};
use super::tensor::Tensor;

/// Convolution geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    /// Kernel size (square).
    pub k: usize,
    /// Stride.
    pub stride: usize,
    /// Symmetric zero padding.
    pub pad: usize,
}

impl ConvGeom {
    /// Output spatial size for an input extent `n`.
    pub fn out_size(&self, n: usize) -> usize {
        (n + 2 * self.pad - self.k) / self.stride + 1
    }
}

/// Zero-pad an HWC tensor spatially.
pub fn pad_spatial<T: Copy + Default>(t: &Tensor<T>, pad: usize) -> Tensor<T> {
    if pad == 0 {
        return t.clone();
    }
    let (h, w, c) = (t.shape[0], t.shape[1], t.shape[2]);
    let mut out = Tensor::zeros(&[h + 2 * pad, w + 2 * pad, c]);
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c {
                *out.at3_mut(y + pad, x + pad, ch) = t.at3(y, x, ch);
            }
        }
    }
    out
}

// ---------------------------------------------------------------- float ---

/// Float conv2d, NHWC, weights `[Cout][K][K][Cin]` flattened.
pub fn conv2d_f32(
    input: &Tensor<f32>,
    weights: &[f32],
    bias: &[f32],
    cout: usize,
    geom: ConvGeom,
    relu: bool,
) -> Tensor<f32> {
    let x = pad_spatial(input, geom.pad);
    let (h, w, cin) = (x.shape[0], x.shape[1], x.shape[2]);
    let (ho, wo) = (geom.out_size(input.shape[0]), geom.out_size(input.shape[1]));
    assert_eq!(weights.len(), cout * geom.k * geom.k * cin);
    let mut out = Tensor::zeros(&[ho, wo, cout]);
    for oy in 0..ho {
        for ox in 0..wo {
            for oc in 0..cout {
                let mut acc = bias[oc];
                for ky in 0..geom.k {
                    for kx in 0..geom.k {
                        let (iy, ix) = (oy * geom.stride + ky, ox * geom.stride + kx);
                        debug_assert!(iy < h && ix < w);
                        for ic in 0..cin {
                            acc += x.at3(iy, ix, ic)
                                * weights[((oc * geom.k + ky) * geom.k + kx) * cin + ic];
                        }
                    }
                }
                *out.at3_mut(oy, ox, oc) = if relu { acc.max(0.0) } else { acc };
            }
        }
    }
    out
}

/// Float depthwise conv2d (channel multiplier 1), weights `[C][K][K]`.
pub fn depthwise_f32(
    input: &Tensor<f32>,
    weights: &[f32],
    bias: &[f32],
    geom: ConvGeom,
    relu: bool,
) -> Tensor<f32> {
    let x = pad_spatial(input, geom.pad);
    let c = input.shape[2];
    let (ho, wo) = (geom.out_size(input.shape[0]), geom.out_size(input.shape[1]));
    assert_eq!(weights.len(), c * geom.k * geom.k);
    let mut out = Tensor::zeros(&[ho, wo, c]);
    for oy in 0..ho {
        for ox in 0..wo {
            for ch in 0..c {
                let mut acc = bias[ch];
                for ky in 0..geom.k {
                    for kx in 0..geom.k {
                        acc += x.at3(oy * geom.stride + ky, ox * geom.stride + kx, ch)
                            * weights[(ch * geom.k + ky) * geom.k + kx];
                    }
                }
                *out.at3_mut(oy, ox, ch) = if relu { acc.max(0.0) } else { acc };
            }
        }
    }
    out
}

/// Float dense layer, weights `[O][I]` flattened.
pub fn dense_f32(input: &[f32], weights: &[f32], bias: &[f32], out_dim: usize, relu: bool) -> Vec<f32> {
    let in_dim = input.len();
    assert_eq!(weights.len(), out_dim * in_dim);
    (0..out_dim)
        .map(|o| {
            let mut acc = bias[o];
            for i in 0..in_dim {
                acc += input[i] * weights[o * in_dim + i];
            }
            if relu {
                acc.max(0.0)
            } else {
                acc
            }
        })
        .collect()
}

/// Float 2×2 stride-2 max pool.
pub fn maxpool2_f32(input: &Tensor<f32>) -> Tensor<f32> {
    let (h, w, c) = (input.shape[0], input.shape[1], input.shape[2]);
    let mut out = Tensor::zeros(&[h / 2, w / 2, c]);
    for y in 0..h / 2 {
        for x in 0..w / 2 {
            for ch in 0..c {
                let m = input
                    .at3(2 * y, 2 * x, ch)
                    .max(input.at3(2 * y, 2 * x + 1, ch))
                    .max(input.at3(2 * y + 1, 2 * x, ch))
                    .max(input.at3(2 * y + 1, 2 * x + 1, ch));
                *out.at3_mut(y, x, ch) = m;
            }
        }
    }
    out
}

/// Float global average pool: HWC → C.
pub fn avgpool_global_f32(input: &Tensor<f32>) -> Vec<f32> {
    let (h, w, c) = (input.shape[0], input.shape[1], input.shape[2]);
    let n = (h * w) as f32;
    (0..c)
        .map(|ch| {
            let mut s = 0.0;
            for y in 0..h {
                for x in 0..w {
                    s += input.at3(y, x, ch);
                }
            }
            s / n
        })
        .collect()
}

// -------------------------------------------------------------- integer ---

/// Integer conv2d: int8 in, int8 grid weights, int32 accumulate,
/// fixed-point requantize to int8. Bit-exact vs the RV32 Mode kernels
/// and the JAX artifact.
pub fn qconv2d(
    input: &Tensor<i8>,
    weights: &[i8],
    bias: &[i32],
    cout: usize,
    geom: ConvGeom,
    rq: Requant,
    relu: bool,
) -> Tensor<i8> {
    let x = pad_spatial(input, geom.pad);
    let cin = x.shape[2];
    let (ho, wo) = (geom.out_size(input.shape[0]), geom.out_size(input.shape[1]));
    assert_eq!(weights.len(), cout * geom.k * geom.k * cin);
    let mut out = Tensor::zeros(&[ho, wo, cout]);
    for oy in 0..ho {
        for ox in 0..wo {
            for oc in 0..cout {
                let mut acc = bias[oc];
                for ky in 0..geom.k {
                    for kx in 0..geom.k {
                        let (iy, ix) = (oy * geom.stride + ky, ox * geom.stride + kx);
                        for ic in 0..cin {
                            acc = acc.wrapping_add(
                                x.at3(iy, ix, ic) as i32
                                    * weights[((oc * geom.k + ky) * geom.k + kx) * cin + ic]
                                        as i32,
                            );
                        }
                    }
                }
                *out.at3_mut(oy, ox, oc) = requantize(acc, rq, relu);
            }
        }
    }
    out
}

/// Integer depthwise conv2d, weights `[C][K][K]`.
pub fn qdepthwise(
    input: &Tensor<i8>,
    weights: &[i8],
    bias: &[i32],
    geom: ConvGeom,
    rq: Requant,
    relu: bool,
) -> Tensor<i8> {
    let x = pad_spatial(input, geom.pad);
    let c = input.shape[2];
    let (ho, wo) = (geom.out_size(input.shape[0]), geom.out_size(input.shape[1]));
    assert_eq!(weights.len(), c * geom.k * geom.k);
    let mut out = Tensor::zeros(&[ho, wo, c]);
    for oy in 0..ho {
        for ox in 0..wo {
            for ch in 0..c {
                let mut acc = bias[ch];
                for ky in 0..geom.k {
                    for kx in 0..geom.k {
                        acc = acc.wrapping_add(
                            x.at3(oy * geom.stride + ky, ox * geom.stride + kx, ch) as i32
                                * weights[(ch * geom.k + ky) * geom.k + kx] as i32,
                        );
                    }
                }
                *out.at3_mut(oy, ox, ch) = requantize(acc, rq, relu);
            }
        }
    }
    out
}

/// Integer dense. When `rq` is `None` the raw int32 accumulators are
/// returned (final logits layer).
pub fn qdense(
    input: &[i8],
    weights: &[i8],
    bias: &[i32],
    out_dim: usize,
    rq: Option<Requant>,
    relu: bool,
) -> (Vec<i8>, Vec<i32>) {
    let in_dim = input.len();
    assert_eq!(weights.len(), out_dim * in_dim);
    let mut accs = Vec::with_capacity(out_dim);
    for o in 0..out_dim {
        let mut acc = bias[o];
        for i in 0..in_dim {
            acc = acc.wrapping_add(input[i] as i32 * weights[o * in_dim + i] as i32);
        }
        accs.push(acc);
    }
    let q = match rq {
        Some(rq) => accs.iter().map(|&a| requantize(a, rq, relu)).collect(),
        None => Vec::new(),
    };
    (q, accs)
}

/// Integer 2×2 stride-2 max pool.
pub fn qmaxpool2(input: &Tensor<i8>) -> Tensor<i8> {
    let (h, w, c) = (input.shape[0], input.shape[1], input.shape[2]);
    let mut out = Tensor::zeros(&[h / 2, w / 2, c]);
    for y in 0..h / 2 {
        for x in 0..w / 2 {
            for ch in 0..c {
                let m = input
                    .at3(2 * y, 2 * x, ch)
                    .max(input.at3(2 * y, 2 * x + 1, ch))
                    .max(input.at3(2 * y + 1, 2 * x, ch))
                    .max(input.at3(2 * y + 1, 2 * x + 1, ch));
                *out.at3_mut(y, x, ch) = m;
            }
        }
    }
    out
}

/// Integer global average pool with round-half-up floor division —
/// `floor((Σ + n/2) / n)` — matching `jnp.floor_divide` on the JAX side.
pub fn qavgpool_global(input: &Tensor<i8>) -> Vec<i8> {
    let (h, w, c) = (input.shape[0], input.shape[1], input.shape[2]);
    let n = (h * w) as i32;
    (0..c)
        .map(|ch| {
            let mut s = 0i32;
            for y in 0..h {
                for x in 0..w {
                    s += input.at3(y, x, ch) as i32;
                }
            }
            (s + n / 2).div_euclid(n).clamp(-128, 127) as i8
        })
        .collect()
}

/// Integer residual add with per-input rescale into the output scale:
/// `clamp(rescale_a(a) + rescale_b(b))` — the simplified TFLite ADD this
/// repo standardises on (identical in the JAX model).
pub fn qadd(a: &Tensor<i8>, rq_a: Requant, b: &Tensor<i8>, rq_b: Requant) -> Tensor<i8> {
    assert_eq!(a.shape, b.shape, "residual shapes must match");
    let mut out = Tensor::zeros(&a.shape);
    for (o, (&va, &vb)) in out.data.iter_mut().zip(a.data.iter().zip(b.data.iter())) {
        // Inputs are pre-shifted left by 8 bits so the Q31 multiply keeps
        // precision for small int8 operands (mirrored in the JAX model).
        let ra = rounding_rshift(srdhm((va as i32) << 8, rq_a.m), rq_a.shift);
        let rb = rounding_rshift(srdhm((vb as i32) << 8, rq_b.m), rq_b.shift);
        *o = (ra + rb).clamp(-128, 127) as i8;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::quant::{quantize_tensor, symmetric_scale, Requant};
    use crate::rng::Rng;

    fn rand_f32(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() * scale).collect()
    }

    #[test]
    fn conv_geometry() {
        let g = ConvGeom { k: 3, stride: 1, pad: 1 };
        assert_eq!(g.out_size(8), 8);
        let g = ConvGeom { k: 3, stride: 2, pad: 1 };
        assert_eq!(g.out_size(8), 4);
        let g = ConvGeom { k: 5, stride: 1, pad: 0 };
        assert_eq!(g.out_size(28), 24);
    }

    #[test]
    fn float_conv_identity_kernel() {
        // 1×1 conv with identity weights passes channels through.
        let input = Tensor::from_vec(&[2, 2, 2], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let weights = vec![1.0, 0.0, 0.0, 1.0]; // [Cout=2][1][1][Cin=2]
        let out = conv2d_f32(
            &input,
            &weights,
            &[0.0, 0.0],
            2,
            ConvGeom { k: 1, stride: 1, pad: 0 },
            false,
        );
        assert_eq!(out.data, input.data);
    }

    /// Quantized conv must approximate float conv within quantization noise.
    #[test]
    fn qconv_tracks_float_conv() {
        let mut rng = Rng::new(42);
        let (h, w, cin, cout, k) = (6, 6, 4, 3, 3);
        let xf = Tensor::from_vec(&[h, w, cin], rand_f32(&mut rng, h * w * cin, 1.0));
        let wf = rand_f32(&mut rng, cout * k * k * cin, 0.3);
        let bf = rand_f32(&mut rng, cout, 0.1);
        let geom = ConvGeom { k, stride: 1, pad: 1 };
        let yf = conv2d_f32(&xf, &wf, &bf, cout, geom, true);

        // Quantize: acts 8-bit, weights 8-bit.
        let s_in = symmetric_scale(xf.abs_max(), 8);
        let xq = Tensor::from_vec(
            &xf.shape,
            xf.data.iter().map(|&v| crate::nn::quant::quantize_value(v, s_in, 8)).collect(),
        );
        let (wq, s_w) = quantize_tensor(&wf, 8);
        let s_out = symmetric_scale(yf.abs_max(), 8);
        let bq: Vec<i32> = bf.iter().map(|&b| (b / (s_in * s_w)).round() as i32).collect();
        let rq = Requant::from_real_scale((s_in * s_w / s_out) as f64);
        let yq = qconv2d(&xq, &wq, &bq, cout, geom, rq, true);

        // Compare dequantized outputs.
        let mut max_err = 0.0f32;
        for (&q, &f) in yq.data.iter().zip(&yf.data) {
            max_err = max_err.max((q as f32 * s_out - f).abs());
        }
        assert!(max_err < 4.0 * s_out, "max_err {max_err} vs s_out {s_out}");
    }

    #[test]
    fn qdense_raw_accumulators() {
        let (q, accs) = qdense(&[1, 2, 3], &[1, 0, 0, 0, 1, 0], &[10, 20], 2, None, false);
        assert!(q.is_empty());
        assert_eq!(accs, vec![11, 22]);
    }

    #[test]
    fn qmaxpool_picks_max() {
        let t = Tensor::from_vec(&[2, 2, 1], vec![-5i8, 3, 7, -1]);
        assert_eq!(qmaxpool2(&t).data, vec![7]);
    }

    #[test]
    fn qavgpool_rounds_half_up_floor() {
        let t = Tensor::from_vec(&[2, 2, 1], vec![1i8, 2, 2, 2]);
        // (7 + 2) / 4 = 2 (floor)
        assert_eq!(qavgpool_global(&t), vec![2]);
        let t = Tensor::from_vec(&[2, 2, 1], vec![-1i8, -2, -2, -2]);
        // (-7 + 2).div_euclid(4) = -2 (floor of -1.25)
        assert_eq!(qavgpool_global(&t), vec![-2]);
    }

    #[test]
    fn qadd_equal_scales_is_saturating_add() {
        // rescale = 1/256 with the <<8 pre-shift → identity.
        let rq = Requant::from_real_scale(1.0 / 256.0);
        let a = Tensor::from_vec(&[1, 1, 3], vec![100i8, -100, 64]);
        let b = Tensor::from_vec(&[1, 1, 3], vec![100i8, -100, 63]);
        let out = qadd(&a, rq, &b, rq);
        assert_eq!(out.data, vec![127, -128, 127]);
    }
}
