//! Layer-level weight packing into the 32-bit word streams the Mode-1/2/3
//! kernels consume ("the initial step involves packing (up to 16)
//! operands (weights) into 32-bit registers", Section 3.2).
//!
//! Streams are zero-padded at group boundaries so a partially-filled
//! `nn_mac` word multiplies trailing (out-of-group) activation bytes by
//! zero — this is what lets the kernels stream whole words without
//! per-element tail handling. The group strides encoded here are
//! replicated by the kernel code generators; both sides are tested
//! against each other.

use crate::isa::custom::pack_weight_stream;
use crate::isa::MacMode;

/// Words per packed group of `len` weights under `mode`.
pub fn words_per_group(mode: MacMode, len: usize) -> usize {
    len.div_ceil(mode.weights_per_word() as usize)
}

/// Pack dense-layer weights `[O][I]` (row-major) into per-output-row
/// streams: output `o`'s words start at `o * words_per_group(mode, i)`.
pub fn pack_dense(mode: MacMode, qw: &[i8], o: usize, i: usize) -> Vec<u32> {
    assert_eq!(qw.len(), o * i);
    let wpg = words_per_group(mode, i);
    let mut out = Vec::with_capacity(o * wpg);
    for row in qw.chunks(i) {
        let words = pack_weight_stream(mode, row);
        debug_assert_eq!(words.len(), wpg);
        out.extend(words);
    }
    out
}

/// Pack conv weights `[Cout][K][K][Cin]` into per-`(oc, ky)` row strips:
/// each strip covers the `K·Cin` weights that multiply one contiguous
/// NHWC activation run. Strip `(oc, ky)` starts at
/// `(oc*K + ky) * words_per_group(mode, K*Cin)`.
pub fn pack_conv(mode: MacMode, qw: &[i8], cout: usize, k: usize, cin: usize) -> Vec<u32> {
    assert_eq!(qw.len(), cout * k * k * cin);
    let strip = k * cin;
    let wpg = words_per_group(mode, strip);
    let mut out = Vec::with_capacity(cout * k * wpg);
    for oc in 0..cout {
        for ky in 0..k {
            let base = ((oc * k) + ky) * k * cin;
            let words = pack_weight_stream(mode, &qw[base..base + strip]);
            debug_assert_eq!(words.len(), wpg);
            out.extend(words);
        }
    }
    out
}

/// Pack depthwise weights `[C][K][K]` into per-channel groups of
/// `words_per_group(mode, K*K)` words (taps in row-major `(ky, kx)` order,
/// matching the kernel's on-the-fly activation gather).
pub fn pack_depthwise(mode: MacMode, qw: &[i8], c: usize, k: usize) -> Vec<u32> {
    assert_eq!(qw.len(), c * k * k);
    let taps = k * k;
    let wpg = words_per_group(mode, taps);
    let mut out = Vec::with_capacity(c * wpg);
    for ch in 0..c {
        out.extend(pack_weight_stream(mode, &qw[ch * taps..(ch + 1) * taps]));
        debug_assert_eq!(out.len(), (ch + 1) * wpg);
    }
    out
}

/// Memory-footprint of a packed weight stream in bytes (the Fig. 4 /
/// Table 4 weight-traffic accounting uses this).
pub fn packed_bytes(mode: MacMode, groups: usize, group_len: usize) -> usize {
    groups * words_per_group(mode, group_len) * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::custom::unpack_weights;
    use crate::isa::MacMode::*;

    #[test]
    fn dense_rows_are_word_aligned() {
        // O=2, I=5 at 4-bit: 5 weights -> 1 word each (8 slots, 3 padded).
        let qw: Vec<i8> = vec![1, 2, 3, 4, 5, -1, -2, -3, -4, -5];
        let words = pack_dense(W4, &qw, 2, 5);
        assert_eq!(words.len(), 2);
        assert_eq!(unpack_weights(W4, words[0]), vec![1, 2, 3, 4, 5, 0, 0, 0]);
        assert_eq!(unpack_weights(W4, words[1]), vec![-1, -2, -3, -4, -5, 0, 0, 0]);
    }

    #[test]
    fn conv_strips_follow_oc_ky_order() {
        // Cout=1, K=2, Cin=4: strips of 8 weights; 8-bit -> 2 words/strip.
        let qw: Vec<i8> = (1..=16).collect();
        let words = pack_conv(W8, &qw, 1, 2, 4);
        assert_eq!(words.len(), 4);
        assert_eq!(unpack_weights(W8, words[0]), vec![1, 2, 3, 4]);
        assert_eq!(unpack_weights(W8, words[1]), vec![5, 6, 7, 8]);
        assert_eq!(unpack_weights(W8, words[2]), vec![9, 10, 11, 12]);
    }

    #[test]
    fn depthwise_groups_per_channel() {
        // C=2, K=3: 9 taps; 2-bit -> 1 word per channel.
        let qw: Vec<i8> = vec![1; 18];
        let words = pack_depthwise(W2, &qw, 2, 3);
        assert_eq!(words.len(), 2);
        let lanes = unpack_weights(W2, words[0]);
        assert_eq!(&lanes[..9], &[1i8; 9]);
        assert_eq!(&lanes[9..], &[0i8; 7]);
    }

    #[test]
    fn packed_byte_accounting() {
        // 64 weights per group, 4 groups.
        assert_eq!(packed_bytes(W8, 4, 64), 4 * 16 * 4);
        assert_eq!(packed_bytes(W4, 4, 64), 4 * 8 * 4);
        assert_eq!(packed_bytes(W2, 4, 64), 4 * 4 * 4);
    }
}
