//! Quantized-NN substrate: tensors, float/integer layers, the symmetric
//! quantizer for the 2/4/8-bit weight grids and the packed-weight
//! layouts. This module is the arithmetic ground truth of the repo —
//! the RV32 kernels, the JAX model and the Pallas kernel are all tested
//! bit-exact against it.

pub mod layers;
pub mod pack;
pub mod quant;
pub mod tensor;

pub use layers::ConvGeom;
pub use quant::Requant;
pub use tensor::Tensor;

/// A quantized layer's parameters, ready for both the host reference and
/// the kernel/PJRT paths.
#[derive(Debug, Clone)]
pub struct QLayer {
    /// Weights on the `w_bits` grid (stored as int8 values).
    pub qw: Vec<i8>,
    /// Int32 biases in the accumulator scale (`s_in · s_w`).
    pub bias: Vec<i32>,
    /// Output requantization parameters.
    pub rq: Requant,
    /// Weight bit-width ∈ {2, 4, 8}.
    pub w_bits: u32,
    /// Weight scale used for quantization (diagnostics/rebuild).
    pub s_w: f32,
}

/// Quantize one layer's float parameters to a target weight bit-width.
///
/// * `wf` — float weights, `bf` — float biases,
/// * `s_in` — input activation scale, `s_out` — output activation scale
///   (both from 8-bit calibration; activation scales are kept fixed
///   across weight-width choices, standard PTQ practice).
pub fn quantize_layer(wf: &[f32], bf: &[f32], s_in: f32, s_out: f32, w_bits: u32) -> QLayer {
    let (qw, s_w) = quant::quantize_tensor(wf, w_bits);
    let bias: Vec<i32> = bf.iter().map(|&b| (b / (s_in * s_w)).round() as i32).collect();
    let rq = Requant::from_real_scale((s_in as f64) * (s_w as f64) / (s_out as f64));
    QLayer { qw, bias, rq, w_bits, s_w }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_layer_produces_grid_weights() {
        let wf: Vec<f32> = (-8..8).map(|i| i as f32 * 0.1).collect();
        for bits in [2u32, 4, 8] {
            let l = quantize_layer(&wf, &[0.5], 0.02, 0.05, bits);
            let (lo, hi) = quant::qrange(bits);
            assert!(l.qw.iter().all(|&q| (q as i32) >= lo && (q as i32) <= hi), "bits {bits}");
            assert_eq!(l.w_bits, bits);
            assert!(l.rq.m >= 1 << 30);
        }
    }

    #[test]
    fn bias_lands_in_accumulator_scale() {
        let l = quantize_layer(&[1.0], &[0.7], 0.1, 1.0, 8);
        // bias_q = b / (s_in · s_w) with whatever scale the MSE search
        // picked.
        let want = (0.7 / (0.1 * l.s_w)).round() as i32;
        assert!((l.bias[0] - want).abs() <= 1, "bias {} want {want}", l.bias[0]);
    }
}
