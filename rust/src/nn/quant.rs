//! Quantization and requantization — the single arithmetic specification
//! shared (bit-exactly) by the host integer reference (`nn::layers`), the
//! RV32 kernels (`kernels::requant`), the JAX model and the Pallas kernel
//! (`python/compile/kernels`). Cross-checked by exported test vectors.
//!
//! Scheme: symmetric per-tensor quantization (zero point 0) for both
//! activations (always int8) and weights (int8/int4/int2 grids — the
//! paper's 8/4/2-bit weight precisions). Accumulation is int32; outputs
//! are requantized to int8 with the fixed-point multiplier+shift scheme
//! of Jacob et al. (the paper's "common requantization step [29]").

/// Quantized signed range for a bit-width: `[-2^(b-1), 2^(b-1)-1]`.
pub fn qrange(bits: u32) -> (i32, i32) {
    crate::isa::custom::weight_range(bits)
}

/// Symmetric scale for quantizing values of magnitude `abs_max` to
/// `bits`-wide signed integers.
pub fn symmetric_scale(abs_max: f32, bits: u32) -> f32 {
    let qmax = (1i64 << (bits - 1)) as f32; // use the full negative range
    if abs_max == 0.0 {
        1.0
    } else {
        abs_max / qmax
    }
}

/// Quantize one float to the `bits`-wide signed grid with scale `s`.
pub fn quantize_value(v: f32, s: f32, bits: u32) -> i8 {
    let (lo, hi) = qrange(bits);
    let q = (v / s).round() as i32;
    q.clamp(lo, hi) as i8
}

/// Candidate scale multipliers for the MSE search (order matters: ties
/// resolve to the earlier candidate in both language twins).
pub const SCALE_CANDIDATES: [f32; 6] = [1.0, 0.9, 0.8, 0.7, 0.6, 1.15];

/// Quantize a float slice to `bits`-wide signed values (returned as i8,
/// always on the grid), choosing the scale that minimises the MSE over
/// a small candidate grid around the abs-max scale.
///
/// The search matters most at 2-bit, where the asymmetric signed grid
/// {-2,-1,0,1} clips the positive range: a slightly smaller scale
/// recovers much of the paper's fine-tuning benefit without retraining
/// (our PTQ-for-QAT substitution, DESIGN.md §5).
pub fn quantize_tensor(vs: &[f32], bits: u32) -> (Vec<i8>, f32) {
    let abs_max = vs.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let base = symmetric_scale(abs_max, bits);
    let mut best_s = base;
    let mut best_mse = f32::INFINITY;
    for mult in SCALE_CANDIDATES {
        let s = base * mult;
        let mse: f32 = vs
            .iter()
            .map(|&v| {
                let q = quantize_value(v, s, bits);
                let e = v - dequantize(q, s);
                e * e
            })
            .sum();
        if mse < best_mse {
            best_mse = mse;
            best_s = s;
        }
    }
    (vs.iter().map(|&v| quantize_value(v, best_s, bits)).collect(), best_s)
}

/// Dequantize.
pub fn dequantize(q: i8, s: f32) -> f32 {
    q as f32 * s
}

/// Fixed-point requantization parameters: `real_scale ≈ m / 2^31 / 2^shift`
/// with `m` a Q31 multiplier in `[2^30, 2^31)`. A negative `shift` is a
/// *left* shift (scales ≥ 1 arise for 2-bit grids with small outputs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Requant {
    /// Q31 multiplier.
    pub m: i32,
    /// Right shift applied after the doubling-high multiply
    /// (negative = left shift).
    pub shift: i32,
}

impl Requant {
    /// Decompose `real_scale` (the effective `s_in·s_w / s_out`; values
    /// ≥ 1 arise for coarse weight grids and yield negative shifts).
    pub fn from_real_scale(real_scale: f64) -> Requant {
        assert!(real_scale > 0.0, "requant scale must be positive");
        let mut shift = 0i32;
        let mut s = real_scale;
        // Normalize into [0.5, 1): m = s · 2^31 lands in [2^30, 2^31).
        while s < 0.5 {
            s *= 2.0;
            shift += 1;
        }
        while s >= 1.0 {
            s /= 2.0;
            shift -= 1;
        }
        let mut m = (s * (1i64 << 31) as f64).round() as i64;
        if m == (1i64 << 31) {
            m /= 2;
            shift -= 1;
        }
        Requant { m: m as i32, shift }
    }

    /// The real scale this parameter pair encodes.
    pub fn real_scale(&self) -> f64 {
        self.m as f64 / (1i64 << 31) as f64 / 2f64.powi(self.shift)
    }
}

/// Saturating rounding doubling high multiply — gemmlowp semantics,
/// the exact operation the RV32 kernel implements with `mulh`/`mul`.
///
/// `SRDHM(a, b) = round_to_nearest((a·b) / 2^31)` with the single
/// saturation case `a = b = i32::MIN`.
pub fn srdhm(a: i32, b: i32) -> i32 {
    if a == i32::MIN && b == i32::MIN {
        return i32::MAX;
    }
    let p = a as i64 * b as i64;
    // +2^30 nudge then >>31 — round half away from... (half up in two's
    // complement). Identical in every implementation of this repo.
    ((p + (1i64 << 30)) >> 31) as i32
}

/// Rounding arithmetic right shift by `n` (round half up); negative `n`
/// shifts left (wrapping, like the hardware barrel shifter).
pub fn rounding_rshift(x: i32, n: i32) -> i32 {
    if n > 0 {
        (x as i64 + (1i64 << (n - 1)) >> n) as i32
    } else if n == 0 {
        x
    } else {
        // Saturating i64 left shift (identical to the JAX twin; the
        // magnitudes produced by well-formed layers never saturate).
        (((x as i64) << (-n) as u32).clamp(i32::MIN as i64, i32::MAX as i64)) as i32
    }
}

/// Requantize an int32 accumulator to int8:
/// `clamp(rounding_rshift(SRDHM(acc, m), shift))`, with optional fused
/// ReLU (clamp low bound 0).
pub fn requantize(acc: i32, rq: Requant, relu: bool) -> i8 {
    let r = rounding_rshift(srdhm(acc, rq.m), rq.shift);
    let lo = if relu { 0 } else { -128 };
    r.clamp(lo, 127) as i8
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qrange_matches_widths() {
        assert_eq!(qrange(8), (-128, 127));
        assert_eq!(qrange(4), (-8, 7));
        assert_eq!(qrange(2), (-2, 1));
    }

    #[test]
    fn quantize_round_trip_error_bounded() {
        let vs: Vec<f32> = (-100..100).map(|i| i as f32 * 0.013).collect();
        for bits in [2u32, 4, 8] {
            let (qs, s) = quantize_tensor(&vs, bits);
            let (lo, hi) = qrange(bits);
            for (&q, &v) in qs.iter().zip(&vs) {
                assert!((q as i32) >= lo && (q as i32) <= hi);
                // Quantization error ≤ s/2 inside the clip range.
                if (v / s).abs() < hi as f32 {
                    assert!(
                        (dequantize(q, s) - v).abs() <= s / 2.0 + 1e-6,
                        "bits {bits} v {v} q {q} s {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn requant_decomposition_accurate() {
        for scale in [0.5, 0.25, 0.1, 0.01, 0.0003, 0.9999, 0.7 / 3.0] {
            let rq = Requant::from_real_scale(scale);
            assert!((1 << 30) <= rq.m, "m normalised: {}", rq.m);
            let rel = (rq.real_scale() - scale).abs() / scale;
            assert!(rel < 1e-8, "scale {scale} rel err {rel}");
        }
    }

    #[test]
    fn srdhm_matches_wide_reference() {
        let cases = [
            (0, 0),
            (1, 1),
            (i32::MAX, i32::MAX),
            (i32::MIN, i32::MAX),
            (i32::MIN, i32::MIN),
            (123456789, -987654321),
            (-1, 1 << 30),
        ];
        for (a, b) in cases {
            if a == i32::MIN && b == i32::MIN {
                assert_eq!(srdhm(a, b), i32::MAX);
            } else {
                let want = (((a as i64 * b as i64) + (1 << 30)) >> 31) as i32;
                assert_eq!(srdhm(a, b), want);
            }
        }
    }

    #[test]
    fn requantize_known_values() {
        // scale 0.5 → m = 2^30, shift 0: requant(acc) ≈ acc/2.
        let rq = Requant::from_real_scale(0.5);
        assert_eq!(requantize(10, rq, false), 5);
        assert_eq!(requantize(-10, rq, false), -5);
        assert_eq!(requantize(1000, rq, false), 127); // clamps
        assert_eq!(requantize(-1000, rq, false), -128);
        assert_eq!(requantize(-10, rq, true), 0); // fused relu
        // Rounding: 0.5 rounds up.
        assert_eq!(requantize(3, rq, false), 2); // 1.5 -> 2
        assert_eq!(requantize(-3, rq, false), -1); // -1.5 -> -1 (half up)
    }

    #[test]
    fn requantize_scale_with_shift() {
        // 1/16 → s=0.5, shift=3.
        let rq = Requant::from_real_scale(1.0 / 16.0);
        assert_eq!(rq.shift, 3);
        assert_eq!(requantize(160, rq, false), 10);
        assert_eq!(requantize(-160, rq, false), -10);
    }
}
