//! Minimal NHWC tensor containers for the quantized-NN substrate.
//!
//! Two concrete element types cover the whole pipeline: `f32` for the
//! float reference path and `i8`/`i32` for the integer inference path.
//! Layout is always NHWC with C innermost — the layout the paper's
//! kernels (and ours) stream, because it makes per-pixel channel runs
//! contiguous for the packed `nn_mac` loads.

/// Dense tensor over element type `T`.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor<T> {
    /// Dimension sizes, outermost first (e.g. `[H, W, C]`).
    pub shape: Vec<usize>,
    /// Row-major (C-order) data.
    pub data: Vec<T>,
}

impl<T: Copy + Default> Tensor<T> {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: vec![T::default(); shape.iter().product()] }
    }

    /// Tensor from raw data (length-checked).
    pub fn from_vec(shape: &[usize], data: Vec<T>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// 3-D (HWC) index.
    #[inline]
    pub fn at3(&self, y: usize, x: usize, c: usize) -> T {
        debug_assert_eq!(self.shape.len(), 3);
        self.data[(y * self.shape[1] + x) * self.shape[2] + c]
    }

    /// Mutable 3-D (HWC) index.
    #[inline]
    pub fn at3_mut(&mut self, y: usize, x: usize, c: usize) -> &mut T {
        debug_assert_eq!(self.shape.len(), 3);
        &mut self.data[(y * self.shape[1] + x) * self.shape[2] + c]
    }
}

impl Tensor<f32> {
    /// Maximum absolute value (quantization calibration).
    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }
}

/// Pad the channel dimension of an HWC tensor to a multiple of `mult`,
/// filling with `fill`. The packed kernels require word-aligned channel
/// runs (see `kernels::layout`).
pub fn pad_channels<T: Copy + Default>(t: &Tensor<T>, mult: usize, fill: T) -> Tensor<T> {
    assert_eq!(t.shape.len(), 3, "pad_channels expects HWC");
    let (h, w, c) = (t.shape[0], t.shape[1], t.shape[2]);
    let cp = c.div_ceil(mult) * mult;
    if cp == c {
        return t.clone();
    }
    let mut out = Tensor::from_vec(&[h, w, cp], vec![fill; h * w * cp]);
    for y in 0..h {
        for x in 0..w {
            for ch in 0..c {
                *out.at3_mut(y, x, ch) = t.at3(y, x, ch);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn indexing_is_nhwc() {
        let t = Tensor::from_vec(&[2, 2, 3], (0..12).collect::<Vec<i32>>());
        assert_eq!(t.at3(0, 0, 0), 0);
        assert_eq!(t.at3(0, 0, 2), 2);
        assert_eq!(t.at3(0, 1, 0), 3);
        assert_eq!(t.at3(1, 0, 0), 6);
        assert_eq!(t.at3(1, 1, 2), 11);
    }

    #[test]
    fn channel_padding() {
        let t = Tensor::from_vec(&[1, 2, 3], vec![1i8, 2, 3, 4, 5, 6]);
        let p = pad_channels(&t, 4, 0);
        assert_eq!(p.shape, vec![1, 2, 4]);
        assert_eq!(p.data, vec![1, 2, 3, 0, 4, 5, 6, 0]);
        // Already aligned: untouched.
        let q = pad_channels(&p, 4, 0);
        assert_eq!(q.data, p.data);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn from_vec_checks_length() {
        Tensor::from_vec(&[2, 2], vec![1i32]);
    }
}
