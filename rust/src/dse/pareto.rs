//! Pareto-front extraction for the Fig.-6 accuracy-vs-cost spaces.

use super::EvalPoint;

/// Indices of the non-dominated points: maximize accuracy, minimize
/// `cost(point)`. A point is dominated if another is at least as good
/// on both axes and strictly better on one.
pub fn pareto_front(points: &[EvalPoint], cost: impl Fn(&EvalPoint) -> u64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    // Sort by cost ascending, accuracy descending.
    idx.sort_by(|&a, &b| {
        cost(&points[a])
            .cmp(&cost(&points[b]))
            .then(points[b].accuracy.partial_cmp(&points[a].accuracy).unwrap())
    });
    let mut front = Vec::new();
    let mut best_acc = f32::NEG_INFINITY;
    for &i in &idx {
        if points[i].accuracy > best_acc {
            front.push(i);
            best_acc = points[i].accuracy;
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(acc: f32, cycles: u64) -> EvalPoint {
        EvalPoint {
            config: vec![],
            accuracy: acc,
            mac_instructions: cycles,
            cycles,
            mem_accesses: 0,
            iss_cycles: None,
            divergence: None,
        }
    }

    #[test]
    fn extracts_non_dominated() {
        let pts = vec![p(0.9, 100), p(0.8, 50), p(0.85, 200), p(0.7, 10), p(0.9, 90)];
        let front = pareto_front(&pts, |e| e.cycles);
        let set: Vec<(f32, u64)> = front.iter().map(|&i| (pts[i].accuracy, pts[i].cycles)).collect();
        // (0.7,10) (0.8,50) (0.9,90) are the front; (0.9,100) and
        // (0.85,200) are dominated.
        assert_eq!(set, vec![(0.7, 10), (0.8, 50), (0.9, 90)]);
    }

    #[test]
    fn front_property_no_dominated_member() {
        let mut rng = crate::rng::Rng::new(9);
        let pts: Vec<EvalPoint> =
            (0..200).map(|_| p(rng.f32(), rng.below(10_000))).collect();
        let front = pareto_front(&pts, |e| e.cycles);
        for &i in &front {
            for q in &pts {
                let dominated = q.accuracy >= pts[i].accuracy
                    && q.cycles <= pts[i].cycles
                    && (q.accuracy > pts[i].accuracy || q.cycles < pts[i].cycles);
                assert!(!dominated, "front point {i} is dominated");
            }
        }
        // Front is sorted by cost and strictly increasing in accuracy.
        for w in front.windows(2) {
            assert!(pts[w[0]].cycles <= pts[w[1]].cycles);
            assert!(pts[w[0]].accuracy < pts[w[1]].accuracy);
        }
    }
}
