//! Pareto-front extraction for the Fig.-6 accuracy-vs-cost spaces.
//!
//! The cost axis is a closure so every consumer picks its own x-axis:
//! Fig. 6 ranks by `mac_instructions`, the DSE integration and Fig. 8
//! by `cycles`, and the Fig.-4-style memory views by `mem_accesses` —
//! the in-module tests exercise all three. The extraction is
//! **deterministic**: for a given `(points, cost)` input the returned
//! indices are a pure function of the values, which is what lets the
//! sharded-sweep merger ([`super::shard::merge`]) recompute the global
//! front and land on the exact single-instance indices.

use super::EvalPoint;

/// Indices of the non-dominated points: maximize accuracy, minimize
/// `cost(point)`. A point is dominated if another is at least as good
/// on both axes and strictly better on one.
///
/// Contract (relied on by the harnesses and the shard merger):
///
/// * indices come back sorted by cost ascending with **strictly**
///   increasing accuracy;
/// * every non-dominated `(cost, accuracy)` value pair is represented
///   by exactly **one** index — for exact duplicates, the lowest
///   original index (the sort is stable);
/// * among points tied on cost, only the highest-accuracy one can
///   appear (the others are dominated).
pub fn pareto_front(points: &[EvalPoint], cost: impl Fn(&EvalPoint) -> u64) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    // Sort by cost ascending, accuracy descending. `total_cmp`, not
    // `partial_cmp(..).unwrap()`: a NaN accuracy (e.g. a 0-image eval
    // dividing 0/0) must not panic the whole sweep. Under the IEEE
    // total order NaN sorts above every real, so NaN points land first
    // within their cost bucket — and the selection loop below drops
    // them anyway, since `NaN > best_acc` is always false.
    idx.sort_by(|&a, &b| {
        cost(&points[a])
            .cmp(&cost(&points[b]))
            .then(points[b].accuracy.total_cmp(&points[a].accuracy))
    });
    let mut front = Vec::new();
    let mut best_acc = f32::NEG_INFINITY;
    for &i in &idx {
        if points[i].accuracy > best_acc {
            front.push(i);
            best_acc = points[i].accuracy;
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(acc: f32, cycles: u64) -> EvalPoint {
        p2(acc, cycles, 0)
    }

    /// Point with independent cycle and memory-access costs, so the
    /// cost closure can be exercised on both axes (Fig. 6 consumes
    /// `mac_instructions`/`cycles`, the Fig.-4-style memory view
    /// `mem_accesses`).
    fn p2(acc: f32, cycles: u64, mem_accesses: u64) -> EvalPoint {
        EvalPoint {
            config: vec![],
            accuracy: acc,
            mac_instructions: cycles,
            cycles,
            mem_accesses,
            iss_cycles: None,
            divergence: None,
        }
    }

    /// O(n²) reference: indices of all non-dominated points, one
    /// representative (lowest index) per distinct `(cost, accuracy)`
    /// value pair — the contract `pareto_front` documents.
    fn oracle(points: &[EvalPoint], cost: impl Fn(&EvalPoint) -> u64) -> Vec<usize> {
        let mut front: Vec<usize> = (0..points.len())
            .filter(|&i| {
                // Not dominated by anyone…
                !points.iter().enumerate().any(|(j, q)| {
                    j != i
                        && q.accuracy >= points[i].accuracy
                        && cost(q) <= cost(&points[i])
                        && (q.accuracy > points[i].accuracy || cost(q) < cost(&points[i]))
                })
                // …and the first among exact value duplicates.
                    && !(0..i).any(|j| {
                        points[j].accuracy == points[i].accuracy
                            && cost(&points[j]) == cost(&points[i])
                    })
            })
            .collect();
        front.sort_by_key(|&i| cost(&points[i]));
        front
    }

    #[test]
    fn extracts_non_dominated() {
        let pts = vec![p(0.9, 100), p(0.8, 50), p(0.85, 200), p(0.7, 10), p(0.9, 90)];
        let front = pareto_front(&pts, |e| e.cycles);
        let set: Vec<(f32, u64)> = front.iter().map(|&i| (pts[i].accuracy, pts[i].cycles)).collect();
        // (0.7,10) (0.8,50) (0.9,90) are the front; (0.9,100) and
        // (0.85,200) are dominated.
        assert_eq!(set, vec![(0.7, 10), (0.8, 50), (0.9, 90)]);
    }

    #[test]
    fn front_property_no_dominated_member() {
        let mut rng = crate::rng::Rng::new(9);
        let pts: Vec<EvalPoint> =
            (0..200).map(|_| p(rng.f32(), rng.below(10_000))).collect();
        let front = pareto_front(&pts, |e| e.cycles);
        for &i in &front {
            for q in &pts {
                let dominated = q.accuracy >= pts[i].accuracy
                    && q.cycles <= pts[i].cycles
                    && (q.accuracy > pts[i].accuracy || q.cycles < pts[i].cycles);
                assert!(!dominated, "front point {i} is dominated");
            }
        }
        // Front is sorted by cost and strictly increasing in accuracy.
        for w in front.windows(2) {
            assert!(pts[w[0]].cycles <= pts[w[1]].cycles);
            assert!(pts[w[0]].accuracy < pts[w[1]].accuracy);
        }
    }

    #[test]
    fn single_point_and_empty() {
        assert_eq!(pareto_front(&[], |e| e.cycles), Vec::<usize>::new());
        assert_eq!(pareto_front(&[p(0.5, 100)], |e| e.cycles), vec![0]);
        // A single point is on the front whatever its values.
        assert_eq!(pareto_front(&[p(0.0, u64::MAX)], |e| e.cycles), vec![0]);
    }

    #[test]
    fn ties_on_both_axes_pick_one_stable_representative() {
        // Four exact duplicates: exactly one survives, and it is the
        // lowest original index (the extraction sort is stable).
        let pts = vec![p(0.5, 100), p(0.5, 100), p(0.5, 100), p(0.5, 100)];
        assert_eq!(pareto_front(&pts, |e| e.cycles), vec![0]);
        // Duplicates behind a distinct better point: representative
        // stability is per value pair, not global.
        let pts = vec![p(0.5, 100), p(0.9, 100), p(0.5, 100), p(0.3, 10)];
        assert_eq!(pareto_front(&pts, |e| e.cycles), vec![3, 1]);
        // Cost tie with different accuracies: only the best survives.
        let pts = vec![p(0.5, 100), p(0.7, 100), p(0.6, 100)];
        assert_eq!(pareto_front(&pts, |e| e.cycles), vec![1]);
        // Accuracy tie with different costs: only the cheapest survives.
        let pts = vec![p(0.5, 100), p(0.5, 50), p(0.5, 70)];
        assert_eq!(pareto_front(&pts, |e| e.cycles), vec![1]);
    }

    #[test]
    fn fully_dominated_chains_collapse_to_one() {
        // Strictly worse on both axes as the index grows: everything
        // after the first point is dominated.
        let pts: Vec<EvalPoint> =
            (0..10).map(|i| p(1.0 - i as f32 * 0.05, 100 + i * 10)).collect();
        assert_eq!(pareto_front(&pts, |e| e.cycles), vec![0]);
        // Same set reversed: the front member keeps its (new) index.
        let rev: Vec<EvalPoint> = pts.iter().rev().cloned().collect();
        assert_eq!(pareto_front(&rev, |e| e.cycles), vec![9]);
    }

    #[test]
    fn mem_accesses_cost_axis_is_independent_of_cycles() {
        // Cycle- and memory-cheap orderings disagree on purpose: the
        // front must follow the supplied closure, not `cycles`.
        let pts = vec![
            p2(0.9, 10, 400), // cycle-cheapest but dominated on the memory axis
            p2(0.8, 300, 20), // memory-cheapest
            p2(0.95, 200, 300),
            p2(0.5, 500, 500), // dominated on both axes
        ];
        assert_eq!(pareto_front(&pts, |e| e.cycles), vec![0, 2]);
        assert_eq!(pareto_front(&pts, |e| e.mem_accesses), vec![1, 2]);
        assert_eq!(oracle(&pts, |e| e.mem_accesses), vec![1, 2]);
    }

    #[test]
    fn nan_accuracy_does_not_panic_and_never_joins_the_front() {
        // Regression: the sort comparator used to be
        // `partial_cmp(..).unwrap()`, which panics the moment a NaN
        // accuracy enters the space (e.g. an evaluator fed 0 images
        // reporting 0/0). NaN points must be ignored, not fatal.
        let pts = vec![p(0.9, 100), p(f32::NAN, 50), p(0.8, 50), p(f32::NAN, 10)];
        let front = pareto_front(&pts, |e| e.cycles);
        assert_eq!(front, vec![2, 0], "NaN points must not appear on the front");

        // All-NaN space: empty front, still no panic.
        let pts = vec![p(f32::NAN, 1), p(f32::NAN, 2)];
        assert_eq!(pareto_front(&pts, |e| e.cycles), Vec::<usize>::new());

        // NaN tied on cost with a real point must not shadow it.
        let pts = vec![p(f32::NAN, 100), p(0.5, 100)];
        assert_eq!(pareto_front(&pts, |e| e.cycles), vec![1]);
    }

    #[test]
    fn matches_oracle_on_random_tie_heavy_spaces() {
        // Small value ranges force plenty of ties on both axes; compare
        // against the O(n²) reference on both cost closures.
        let mut rng = crate::rng::Rng::new(41);
        for round in 0..50 {
            let n = 1 + rng.below(60) as usize;
            let pts: Vec<EvalPoint> = (0..n)
                .map(|_| {
                    p2(
                        (rng.below(8) as f32) / 8.0,
                        rng.below(6) * 100,
                        rng.below(6) * 100,
                    )
                })
                .collect();
            let by_cycles = pareto_front(&pts, |e| e.cycles);
            assert_eq!(by_cycles, oracle(&pts, |e| e.cycles), "round {round} (cycles)");
            let by_mem = pareto_front(&pts, |e| e.mem_accesses);
            assert_eq!(by_mem, oracle(&pts, |e| e.mem_accesses), "round {round} (mem)");
        }
    }
}
