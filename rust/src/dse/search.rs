//! Predictor-guided DSE: successive halving over analytic cost bounds.
//!
//! The exhaustive sweep evaluates every enumerated configuration on the
//! full evaluation set — exact, but combinatorial in depth. This module
//! adds the guided driver (`--search guided`): configurations are first
//! priced with the **analytic cost model** (cycles / MAC instructions /
//! memory accesses from [`CycleModel`](super::cycles::CycleModel) — no
//! ISS runs beyond the session `CostCache` warm-up), then pass through
//! a successive-halving loop that scores them on growing deterministic
//! *prefixes* of the evaluation set and promotes only the top `1/eta`
//! per rung. Between rungs an **interval prune** drops every
//! configuration whose accuracy upper bound already sits under an alive
//! configuration's lower bound at no more cost — provably dominated, so
//! it never reaches full evaluation.
//!
//! The driver is *zero-regret by construction*: after the survivors are
//! fully evaluated, a repair pass re-admits any dropped configuration
//! the measured points cannot prove dominated (accuracy-at-optimism vs.
//! every cost axis) and iterates until none remain. At that fixpoint
//! every configuration that was never fully evaluated is dominated by a
//! fully-evaluated one on **all** cost axes, so the Pareto front of the
//! evaluated subset equals the exhaustive front exactly — same points,
//! same representatives — on any of the three cost axes. The exhaustive
//! sweep stays the default and doubles as the property-test oracle
//! (`tests/search_oracle.rs`); what the guided path buys is *fewer full
//! evaluations*, which on landscapes with cheap high-accuracy
//! configurations is most of them.
//!
//! Everything is deterministic: prefixes are leading slices of the eval
//! set, rung tie-breaks go through the shared seeded stride
//! ([`crate::rng::seeded_stride`], the same FNV-phase helper the
//! analytic audit sampler uses), and two runs with one seed are
//! byte-identical.

use super::pareto::pareto_front;
use super::EvalPoint;
use crate::error::Result;
use crate::{bail, ensure};

/// Which DSE driver a sweep runs (and which produced an artifact).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SearchStrategy {
    /// Evaluate every enumerated configuration on the full eval set
    /// (the default, and the guided path's test oracle).
    #[default]
    Exhaustive,
    /// Analytic-bound pruning + successive halving + repair
    /// ([`guided_search`]).
    Guided,
}

impl SearchStrategy {
    /// Parse a `--search` value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "exhaustive" => Some(SearchStrategy::Exhaustive),
            "guided" => Some(SearchStrategy::Guided),
            _ => None,
        }
    }

    /// Stable name (CLI value and artifact tag).
    pub fn name(&self) -> &'static str {
        match self {
            SearchStrategy::Exhaustive => "exhaustive",
            SearchStrategy::Guided => "guided",
        }
    }
}

/// Guided-search knobs (`--rungs`, `--eta`, `--max-alive`, reusing the
/// sweep `--seed`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GuidedOpts {
    /// Successive-halving rungs, counting the final full evaluation.
    /// `rungs = 3` with a 128-input eval set scores prefixes of 32 and
    /// 64 before promoting to all 128.
    pub rungs: usize,
    /// Halving factor: the top `1/eta` of each rung promotes.
    pub eta: usize,
    /// Seed for the rung-promotion tie-break stride.
    pub seed: u64,
    /// Cap on the configurations the driver may materialize for full
    /// evaluation (rung survivors plus repair re-admissions). Rung
    /// bookkeeping is index-only, so this cap is the driver's config
    /// storage bound; exceeding it is a typed error (`--max-alive`) —
    /// a sweep that cannot stay within memory fails loudly up front
    /// instead of OOMing. `None` is unbounded.
    pub max_alive: Option<usize>,
}

impl Default for GuidedOpts {
    fn default() -> Self {
        GuidedOpts { rungs: 3, eta: 2, seed: 0, max_alive: None }
    }
}

/// Spaces smaller than this skip the rung machinery entirely: the
/// partial evaluations would cost more than they save, so the guided
/// driver degenerates to a full sweep (bit-identical to exhaustive).
pub const RUNG_THRESHOLD: usize = 9;

/// Analytic cost triple of one configuration — every axis a sweep
/// consumer ranks by (Fig. 6 uses `mac`, Fig. 8 `cycles`, the memory
/// view `mem`). Pruning requires dominance on **all** of them so the
/// front on any single axis survives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CostVec {
    /// End-to-end cycles from the per-layer cycle model.
    pub cycles: u64,
    /// Total MAC instructions.
    pub mac: u64,
    /// Memory accesses from the cycle model.
    pub mem: u64,
}

impl CostVec {
    /// `self` at most `other` on every axis.
    fn le(&self, other: &CostVec) -> bool {
        self.cycles <= other.cycles && self.mac <= other.mac && self.mem <= other.mem
    }

    /// `self` strictly under `other` on every axis.
    fn lt(&self, other: &CostVec) -> bool {
        self.cycles < other.cycles && self.mac < other.mac && self.mem < other.mem
    }
}

/// Per-rung accounting.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RungReport {
    /// Rung number (0-based).
    pub rung: usize,
    /// Prefix length the rung scored.
    pub prefix: usize,
    /// Configurations alive at rung entry.
    pub entered: usize,
    /// Dropped by the interval prune at this rung.
    pub pruned: usize,
    /// Alive after the seeded promotion (what the next rung sees).
    pub promoted: usize,
}

/// What a guided run did — the savings ledger the harness logs and the
/// property tests account against.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GuidedStats {
    /// Size of the searched configuration space.
    pub space: usize,
    /// Per-rung entry/prune/promotion counts.
    pub rung_reports: Vec<RungReport>,
    /// Total configurations dropped by the interval prune.
    pub pruned: usize,
    /// Total configurations demoted by rung promotion quotas.
    pub halved: usize,
    /// Dropped configurations the repair pass re-admitted to full
    /// evaluation because the measured points could not prove them
    /// dominated.
    pub repaired: usize,
    /// Prefix (partial) evaluations performed across all rungs.
    pub partial_evals: usize,
    /// Configurations evaluated on the full eval set. `space -
    /// full_evals` is what the guided driver saved over exhaustive.
    pub full_evals: usize,
    /// High-water mark of configurations the driver held *materialized*
    /// at once: fully-evaluated points retained plus the batch in
    /// flight. Rung scoring streams configs index-by-index (the
    /// evaluator decodes and drops each one), so this — not the space
    /// size — is the driver's config-storage footprint: O(alive set +
    /// front), never O(space). [`GuidedOpts::max_alive`] caps it.
    pub peak_alive: usize,
    /// True when the space/opts were too small for rungs and the driver
    /// fell back to a plain full sweep.
    pub degenerate: bool,
}

/// A guided sweep's result: the fully-evaluated points, tagged with
/// their index into the original configuration slice (ascending), plus
/// the accounting. The Pareto front of `points` equals the exhaustive
/// front on any cost axis (see the module docs for the argument).
#[derive(Debug, Clone, PartialEq)]
pub struct GuidedSweep {
    /// `(index into the searched configs, fully-evaluated point)`,
    /// ascending by index.
    pub points: Vec<(usize, EvalPoint)>,
    /// Savings/accounting ledger.
    pub stats: GuidedStats,
}

/// Rung prefix lengths for an eval set of `n`: `n / eta^k` for the
/// non-final rungs, deduplicated, strictly below `n`. Empty means the
/// driver should degenerate to a plain full sweep.
fn rung_prefixes(space: usize, n: usize, opts: &GuidedOpts) -> Vec<usize> {
    if space < RUNG_THRESHOLD || opts.rungs <= 1 || opts.eta < 2 || n < opts.eta {
        return Vec::new();
    }
    let mut out: Vec<usize> = Vec::new();
    for r in 0..opts.rungs - 1 {
        let exp = (opts.rungs - 1 - r) as u32;
        let m = match (opts.eta as u64).checked_pow(exp) {
            Some(div) => ((n as u64 / div) as usize).max(1),
            None => 1, // eta^exp overflowed u64: the prefix floor is 1
        };
        if m >= n || out.last() == Some(&m) {
            continue;
        }
        out.push(m);
    }
    out
}

/// Accuracy upper bound after `correct` of a `prefix`-input partial
/// evaluation, on the full-eval scale of `n` inputs: even if every
/// remaining input scores, accuracy is at most `(correct + n -
/// prefix) / n`. IEEE f32 division is monotone in the integer
/// numerator, so the bound is sound against the evaluator's own
/// `correct / n` arithmetic.
fn upper_bound(correct: u32, prefix: usize, n: usize) -> f32 {
    (correct as usize + (n - prefix)) as f32 / n as f32
}

/// Matching lower bound: the prefix hits are already banked.
fn lower_bound(correct: u32, n: usize) -> f32 {
    correct as f32 / n as f32
}

/// Interval prune: drop every alive configuration whose accuracy upper
/// bound sits at/under another alive configuration's lower bound at no
/// more analytic cost — with strictness on the accuracy bound or on
/// every cost axis, so an exact tie is never pruned (the front's
/// stable-representative contract needs the lowest index alive).
/// Returns the dropped indices (ascending).
///
/// The scan streams the rung entries twice against an **incremental
/// dominator frontier**: the Pareto-minimal entries under (every cost
/// axis ascending, accuracy lower bound descending). Whenever some
/// entry prunes `i`, its frontier cover prunes `i` too — weak cover
/// composes with the strictness requirement — so the verdicts are
/// identical to the historical all-pairs scan while the state held is
/// O(|front|) cost triples, not O(alive), and the work O(alive ·
/// |front|), not O(alive²): the property that lets rung 0 of a 10^6
/// -config space finish at all.
fn interval_prune(
    alive: &mut Vec<usize>,
    cost_of: &dyn Fn(usize) -> CostVec,
    partial: &[Option<(u32, usize)>],
    n: usize,
) -> Vec<usize> {
    let bound = |i: usize| {
        let (c, m) = partial[i].expect("alive config has a rung result");
        (lower_bound(c, n), upper_bound(c, m, n))
    };
    // Pass 1: build the dominator frontier over all rung entries (a
    // pruned entry may still prune others, exactly as in the all-pairs
    // scan). `(position in alive, cost, lower bound)`; ties keep the
    // first entry seen — either member of a tie pair prunes the same
    // set, so one representative suffices.
    let mut front: Vec<(usize, CostVec, f32)> = Vec::new();
    for (a, &i) in alive.iter().enumerate() {
        let c = cost_of(i);
        let lb = bound(i).0;
        let mut covered = false;
        let mut q = 0;
        while q < front.len() {
            let (_, fc, flb) = &front[q];
            if fc.le(&c) && *flb >= lb {
                covered = true;
                break;
            }
            if c.le(fc) && lb >= *flb {
                front.swap_remove(q);
                continue;
            }
            q += 1;
        }
        if !covered {
            front.push((a, c, lb));
        }
    }
    // Pass 2: keep whatever no frontier member provably prunes.
    let keep: Vec<bool> = alive
        .iter()
        .enumerate()
        .map(|(a, &i)| {
            let c = cost_of(i);
            let ub = bound(i).1;
            !front.iter().any(|&(b, ref fc, flb)| {
                a != b && fc.le(&c) && flb >= ub && (flb > ub || fc.lt(&c))
            })
        })
        .collect();
    let dropped: Vec<usize> =
        alive.iter().zip(&keep).filter(|(_, &k)| !k).map(|(&i, _)| i).collect();
    let mut it = keep.iter();
    alive.retain(|_| *it.next().unwrap());
    dropped
}

/// Seeded rung promotion: keep the rung-level Pareto fronts (prefix
/// hits vs. each analytic cost axis — those are the configurations the
/// final front can still come from) and fill the `1/eta` quota in
/// (hits desc, cycles asc, index asc) order. When the quota boundary
/// falls inside a run of equal `(hits, cycles)` candidates, the subset
/// is chosen by the shared seeded stride — deterministic per seed, and
/// the same FNV-phase logic as the analytic audit sampler. Returns the
/// demoted indices.
fn promote(
    alive: &mut Vec<usize>,
    cost_of: &dyn Fn(usize) -> CostVec,
    partial: &[Option<(u32, usize)>],
    quota: usize,
    seed: u64,
) -> Vec<usize> {
    if alive.len() <= quota {
        return Vec::new();
    }
    let hits = |i: usize| partial[i].expect("alive config has a rung result").0;
    // Price the survivors once, aligned with `alive` — transient
    // O(alive) cost triples, freed when the rung ends.
    let costs: Vec<CostVec> = alive.iter().map(|&i| cost_of(i)).collect();
    // Rung-level fronts on each cost axis, over temporary points whose
    // "accuracy" is the prefix hit count.
    let tmp: Vec<EvalPoint> = alive
        .iter()
        .enumerate()
        .map(|(pos, &i)| EvalPoint {
            config: Vec::new(),
            accuracy: hits(i) as f32,
            mac_instructions: costs[pos].mac,
            cycles: costs[pos].cycles,
            mem_accesses: costs[pos].mem,
            iss_cycles: None,
            divergence: None,
        })
        .collect();
    let mut keep = vec![false; alive.len()];
    let mut kept = 0usize;
    let axes: [fn(&EvalPoint) -> u64; 3] =
        [|p| p.cycles, |p| p.mac_instructions, |p| p.mem_accesses];
    for axis in axes {
        for pos in pareto_front(&tmp, axis) {
            if !keep[pos] {
                keep[pos] = true;
                kept += 1;
            }
        }
    }
    let target = quota.max(kept);
    // Fill the remaining quota in (hits desc, cycles asc, index asc)
    // order, walking maximal runs of equal (hits, cycles).
    let mut order: Vec<usize> = (0..alive.len()).collect();
    let key = |pos: usize| (u32::MAX - hits(alive[pos]), costs[pos].cycles, alive[pos]);
    order.sort_by_key(|&pos| key(pos));
    let run_key = |pos: usize| (hits(alive[pos]), costs[pos].cycles);
    let mut w = 0;
    while w < order.len() && kept < target {
        let mut e = w + 1;
        while e < order.len() && run_key(order[e]) == run_key(order[w]) {
            e += 1;
        }
        let candidates: Vec<usize> =
            order[w..e].iter().copied().filter(|&pos| !keep[pos]).collect();
        let free = target - kept;
        if candidates.len() <= free {
            for pos in candidates {
                keep[pos] = true;
                kept += 1;
            }
        } else {
            // Seeded stride over the tied run, padded from the front
            // (lowest index) when the stride lands short of the quota.
            let k = candidates.len();
            let mut pick = crate::rng::seeded_stride(seed, k, k.div_ceil(free));
            pick.truncate(free);
            let mut chosen = vec![false; k];
            for &c in &pick {
                chosen[c] = true;
            }
            let mut need = free - pick.len();
            for slot in chosen.iter_mut() {
                if need == 0 {
                    break;
                }
                if !*slot {
                    *slot = true;
                    need -= 1;
                }
            }
            for (c, &sel) in chosen.iter().enumerate() {
                if sel {
                    keep[candidates[c]] = true;
                    kept += 1;
                }
            }
        }
        w = e;
    }
    let demoted: Vec<usize> =
        alive.iter().zip(&keep).filter(|(_, &k)| !k).map(|(&i, _)| i).collect();
    let mut it = keep.iter();
    alive.retain(|_| *it.next().unwrap());
    demoted
}

/// Is dropped configuration `c` provably dominated by a
/// fully-evaluated point? "Provably" means: some measured point is at
/// least as accurate as `c` could *possibly* be (its accuracy upper
/// bound, `hi`) at no more cost on **every** analytic axis, with
/// strictness on accuracy or on every cost axis. A configuration this
/// cannot certify gets repaired (fully evaluated) instead of guessed
/// about. `full_costs` is the measured points' `(cost, accuracy)`
/// table, priced once per repair round.
fn dominated_at_optimism(hi: f32, cc: &CostVec, full_costs: &[(CostVec, f32)]) -> bool {
    full_costs.iter().any(|(dc, acc)| dc.le(cc) && *acc >= hi && (*acc > hi || dc.lt(cc)))
}

/// Run the guided search over `costs.len()` configurations — the
/// slice-priced convenience wrapper over [`guided_search_stream`],
/// for callers that already hold the cost table (small spaces, the
/// property tests).
///
/// * `costs` — analytic cost triple per configuration (index-aligned
///   with whatever slice the caller is searching);
/// * `n` — full evaluation length (the caller should clamp to the
///   evaluator's set size first — prefix bounds are computed against
///   this `n`);
/// * `eval_partial(indices, m)` — score each configuration on the
///   first `m` eval inputs, returning the per-configuration *hit
///   counts* (index-aligned with `indices`);
/// * `eval_full(indices)` — fully evaluate, returning index-aligned
///   [`EvalPoint`]s. Must be the same path the exhaustive sweep uses so
///   surviving points are bit-identical to the oracle's.
///
/// The returned points carry every configuration that was fully
/// evaluated, ascending by index; their Pareto front equals the
/// exhaustive front on any cost axis.
pub fn guided_search(
    costs: &[CostVec],
    n: usize,
    opts: &GuidedOpts,
    eval_partial: &(dyn Fn(&[usize], usize) -> Result<Vec<u32>> + Sync),
    eval_full: &(dyn Fn(&[usize]) -> Result<Vec<EvalPoint>> + Sync),
) -> Result<GuidedSweep> {
    guided_search_stream(costs.len(), &|i| costs[i], n, opts, eval_partial, eval_full)
}

/// Run the guided search over a `space`-sized configuration stream —
/// the engine behind [`guided_search`] and the streaming sweep stack.
///
/// Nothing here ever holds the space: `cost_of(i)` prices
/// configuration `i` on demand (for a lazy
/// [`ConfigSpace`](super::ConfigSpace) that is decode + price, O(L)
/// and allocation-transient), the interval prune runs against an
/// incremental dominator frontier (O(|front|) state), and the only
/// O(space) structures are scalar ledgers (per-index rung results and
/// the dropped-index list). Configurations are materialized solely for
/// full evaluation — rung survivors plus repair re-admissions — so
/// peak config storage is O(alive set + front), reported in
/// [`GuidedStats::peak_alive`] and capped by
/// [`GuidedOpts::max_alive`].
pub fn guided_search_stream(
    space: usize,
    cost_of: &(dyn Fn(usize) -> CostVec + Sync),
    n: usize,
    opts: &GuidedOpts,
    eval_partial: &(dyn Fn(&[usize], usize) -> Result<Vec<u32>> + Sync),
    eval_full: &(dyn Fn(&[usize]) -> Result<Vec<EvalPoint>> + Sync),
) -> Result<GuidedSweep> {
    ensure!(n > 0, "guided search needs a non-empty eval set");
    let mut stats = GuidedStats { space, ..GuidedStats::default() };
    let check_alive = |want: usize| -> Result<()> {
        if let Some(cap) = opts.max_alive {
            ensure!(
                want <= cap,
                "guided search: alive set of {want} configurations exceeds --max-alive {cap}; \
                 raise the bound, add rungs/eta so pruning bites earlier, or shard the space"
            );
        }
        Ok(())
    };

    let full_sweep = |indices: Vec<usize>, mut stats: GuidedStats| -> Result<GuidedSweep> {
        let pts = eval_full(&indices)?;
        ensure!(pts.len() == indices.len(), "full evaluation returned a short batch");
        stats.full_evals += indices.len();
        stats.peak_alive = stats.peak_alive.max(indices.len());
        Ok(GuidedSweep { points: indices.into_iter().zip(pts).collect(), stats })
    };

    let prefixes = rung_prefixes(space, n, opts);
    if prefixes.is_empty() {
        // Space or eval set too small for rungs: plain full sweep,
        // bit-identical to exhaustive. Still a materialization of the
        // whole space, so the alive cap applies.
        check_alive(space)?;
        stats.degenerate = true;
        return full_sweep((0..space).collect(), stats);
    }

    let mut alive: Vec<usize> = (0..space).collect();
    let mut dropped: Vec<usize> = Vec::new();
    // Latest partial result per configuration: (hits, prefix length).
    // Scalar ledger — O(space) small integers, never configs.
    let mut partial: Vec<Option<(u32, usize)>> = vec![None; space];

    for (r, &m) in prefixes.iter().enumerate() {
        let entered = alive.len();
        let counts = eval_partial(&alive, m)?;
        ensure!(counts.len() == alive.len(), "rung {r} returned a short batch");
        stats.partial_evals += alive.len();
        for (&i, &c) in alive.iter().zip(&counts) {
            if c as usize > m {
                bail!("rung {r}: {c} hits out of a {m}-input prefix");
            }
            partial[i] = Some((c, m));
        }
        let pruned_now = interval_prune(&mut alive, &cost_of, &partial, n);
        let quota = alive.len().div_ceil(opts.eta);
        let demoted = promote(
            &mut alive,
            &cost_of,
            &partial,
            quota,
            opts.seed ^ (r as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        stats.pruned += pruned_now.len();
        stats.halved += demoted.len();
        stats.rung_reports.push(RungReport {
            rung: r,
            prefix: m,
            entered,
            pruned: pruned_now.len(),
            promoted: alive.len(),
        });
        dropped.extend(pruned_now);
        dropped.extend(demoted);
    }

    // Full evaluation of the survivors, through the same cached path
    // the exhaustive sweep uses. `live` tracks the materialized-config
    // high-water mark (points held + batch entering evaluation); this
    // is the counter the bounded-memory contract is asserted against.
    alive.sort_unstable();
    let mut live = 0usize;
    let mut full: std::collections::BTreeMap<usize, EvalPoint> = std::collections::BTreeMap::new();
    check_alive(live + alive.len())?;
    let pts = eval_full(&alive)?;
    ensure!(pts.len() == alive.len(), "full evaluation returned a short batch");
    stats.full_evals += alive.len();
    live += alive.len();
    stats.peak_alive = stats.peak_alive.max(live);
    for (&i, p) in alive.iter().zip(pts) {
        full.insert(i, p);
    }

    // Repair to the zero-regret fixpoint: fully evaluate every dropped
    // configuration the measured points cannot prove dominated, until
    // none remain. Each round strictly shrinks `dropped`, so this
    // terminates in at most `space` rounds. The measured points are
    // priced once per round — the dominance scan is |dropped| × |full|
    // and must not re-decode the space per pair.
    loop {
        let full_costs: Vec<(CostVec, f32)> =
            full.iter().map(|(&d, p)| (cost_of(d), p.accuracy)).collect();
        let mut need: Vec<usize> = dropped
            .iter()
            .copied()
            .filter(|&c| {
                let (cor, m) = partial[c].expect("dropped config has a rung result");
                !dominated_at_optimism(upper_bound(cor, m, n), &cost_of(c), &full_costs)
            })
            .collect();
        if need.is_empty() {
            break;
        }
        need.sort_unstable();
        check_alive(live + need.len())?;
        let pts = eval_full(&need)?;
        ensure!(pts.len() == need.len(), "repair evaluation returned a short batch");
        stats.full_evals += need.len();
        stats.repaired += need.len();
        live += need.len();
        stats.peak_alive = stats.peak_alive.max(live);
        for (&i, p) in need.iter().zip(pts) {
            full.insert(i, p);
        }
        dropped.retain(|&i| !full.contains_key(&i));
    }

    // BTreeMap iteration is ascending by key — the same order the
    // historical dense table produced.
    let points: Vec<(usize, EvalPoint)> = full.into_iter().collect();
    Ok(GuidedSweep { points, stats })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Synthetic landscape: analytic costs plus a per-(config, input)
    /// correctness table — the closed-form stand-in for an
    /// `AccuracyEval` backend (prefix evaluation is exactly a row
    /// prefix of the table).
    struct Landscape {
        costs: Vec<CostVec>,
        n: usize,
        correct: Vec<Vec<bool>>,
    }

    impl Landscape {
        fn point(&self, i: usize) -> EvalPoint {
            let hits = self.correct[i].iter().filter(|&&b| b).count();
            EvalPoint {
                config: vec![i as u32],
                accuracy: hits as f32 / self.n as f32,
                mac_instructions: self.costs[i].mac,
                cycles: self.costs[i].cycles,
                mem_accesses: self.costs[i].mem,
                iss_cycles: None,
                divergence: None,
            }
        }

        fn exhaustive(&self) -> Vec<EvalPoint> {
            (0..self.costs.len()).map(|i| self.point(i)).collect()
        }

        fn random(seed: u64, space: usize, n: usize) -> Landscape {
            let mut rng = Rng::new(seed);
            let costs = (0..space)
                .map(|_| CostVec {
                    cycles: rng.below(40) * 10,
                    mac: rng.below(40) * 10,
                    mem: rng.below(40) * 10,
                })
                .collect();
            let correct = (0..space)
                .map(|_| {
                    let p = rng.below(100);
                    (0..n).map(|_| rng.below(100) < p).collect()
                })
                .collect();
            Landscape { costs, n, correct }
        }
    }

    fn run(land: &Landscape, opts: &GuidedOpts) -> GuidedSweep {
        let ep = |idxs: &[usize], m: usize| -> Result<Vec<u32>> {
            Ok(idxs
                .iter()
                .map(|&i| land.correct[i][..m].iter().filter(|&&b| b).count() as u32)
                .collect())
        };
        let ef = |idxs: &[usize]| -> Result<Vec<EvalPoint>> {
            Ok(idxs.iter().map(|&i| land.point(i)).collect())
        };
        guided_search(&land.costs, land.n, opts, &ep, &ef).expect("guided search")
    }

    const AXES: [fn(&EvalPoint) -> u64; 3] =
        [|p| p.cycles, |p| p.mac_instructions, |p| p.mem_accesses];

    /// Assert the guided sweep's front equals the exhaustive front on
    /// every cost axis — same global indices, same point values.
    fn assert_zero_regret(land: &Landscape, g: &GuidedSweep, ctx: &str) {
        let all = land.exhaustive();
        let gpts: Vec<EvalPoint> = g.points.iter().map(|(_, p)| p.clone()).collect();
        for (ax, axis) in AXES.iter().enumerate() {
            let ex: Vec<usize> = pareto_front(&all, axis);
            let gd: Vec<usize> = pareto_front(&gpts, axis)
                .into_iter()
                .map(|pos| g.points[pos].0)
                .collect();
            assert_eq!(gd, ex, "{ctx}: guided front != exhaustive front on axis {ax}");
            for &i in &ex {
                let found = g.points.iter().find(|(gi, _)| *gi == i);
                let (_, gp) = found.unwrap_or_else(|| {
                    panic!("{ctx}: true Pareto point {i} (axis {ax}) was pruned")
                });
                assert_eq!(*gp, all[i], "{ctx}: point {i} value drifted");
            }
        }
    }

    #[test]
    fn rung_prefix_schedule() {
        let o = |rungs, eta| GuidedOpts { rungs, eta, seed: 0, max_alive: None };
        assert_eq!(rung_prefixes(100, 128, &o(3, 2)), vec![32, 64]);
        assert_eq!(rung_prefixes(100, 8, &o(4, 2)), vec![1, 2, 4]);
        assert_eq!(rung_prefixes(100, 9, &o(2, 3)), vec![3]);
        // Too small on any dimension → degenerate (no rungs).
        assert!(rung_prefixes(RUNG_THRESHOLD - 1, 128, &o(3, 2)).is_empty());
        assert!(rung_prefixes(100, 1, &o(3, 2)).is_empty());
        assert!(rung_prefixes(100, 128, &o(1, 2)).is_empty());
        // Tiny n collapses duplicate prefixes instead of repeating them.
        assert_eq!(rung_prefixes(100, 2, &o(5, 2)), vec![1]);
    }

    #[test]
    fn degenerate_small_space_is_a_full_sweep() {
        let land = Landscape::random(3, RUNG_THRESHOLD - 1, 16);
        let g = run(&land, &GuidedOpts::default());
        assert!(g.stats.degenerate);
        assert_eq!(g.stats.full_evals, land.costs.len());
        assert_eq!(g.stats.partial_evals, 0);
        let all = land.exhaustive();
        assert_eq!(g.points.len(), all.len());
        for (i, p) in &g.points {
            assert_eq!(p, &all[*i]);
        }
    }

    #[test]
    fn zero_regret_on_random_landscapes() {
        for seed in 0..12u64 {
            let space = 9 + (seed as usize * 7) % 30;
            let n = 8 + (seed as usize % 3) * 12;
            let land = Landscape::random(seed, space, n);
            let opts =
                GuidedOpts { rungs: 2 + (seed as usize % 3), eta: 2 + (seed as usize % 2), seed, max_alive: None };
            let g = run(&land, &opts);
            assert_zero_regret(&land, &g, &format!("seed {seed}"));
            assert_eq!(g.stats.full_evals, g.points.len(), "seed {seed}: eval ledger");
            assert!(g.stats.full_evals <= space, "seed {seed}: more full evals than configs");
        }
    }

    #[test]
    fn deterministic_under_a_fixed_seed() {
        let land = Landscape::random(99, 24, 16);
        let opts = GuidedOpts { rungs: 3, eta: 2, seed: 0xD5E, max_alive: None };
        let a = run(&land, &opts);
        let b = run(&land, &opts);
        assert_eq!(a, b, "two guided runs with one seed diverged");
    }

    #[test]
    fn strict_savings_when_a_cheap_config_dominates() {
        // Config 0: strictly cheapest on every axis and correct on the
        // whole eval set. Every other config costs strictly more and is
        // wrong on the entire first half, so after the half-set rung
        // its accuracy upper bound is ≤ 0.5 < 1.0 and the repair pass
        // can certify dominance without full-evaluating it.
        let space = 24;
        let n = 16;
        let costs: Vec<CostVec> = (0..space as u64)
            .map(|i| CostVec { cycles: 10 + i * 5, mac: 20 + i * 3, mem: 30 + i * 7 })
            .collect();
        let correct: Vec<Vec<bool>> = (0..space)
            .map(|i| (0..n).map(|j| i == 0 || (j >= n / 2 && (i + j) % 3 == 0)).collect())
            .collect();
        let land = Landscape { costs, n, correct };
        let g = run(&land, &GuidedOpts { rungs: 3, eta: 2, seed: 7, max_alive: None });
        assert_zero_regret(&land, &g, "designed landscape");
        assert!(
            g.stats.full_evals < space,
            "no savings: {} full evals over a {space}-config space",
            g.stats.full_evals
        );
        assert!(g.stats.pruned + g.stats.halved > 0, "nothing was ever dropped");
    }

    #[test]
    fn exact_ties_keep_the_lowest_index_representative() {
        // Two configs with identical costs and identical rows: the
        // front must keep index 1 (the lower of the pair after the
        // cheap distinct point), exactly as the exhaustive front does.
        let costs = vec![
            CostVec { cycles: 5, mac: 5, mem: 5 },
            CostVec { cycles: 9, mac: 9, mem: 9 },
            CostVec { cycles: 9, mac: 9, mem: 9 },
            CostVec { cycles: 12, mac: 12, mem: 12 },
            CostVec { cycles: 13, mac: 13, mem: 13 },
            CostVec { cycles: 14, mac: 14, mem: 14 },
            CostVec { cycles: 15, mac: 15, mem: 15 },
            CostVec { cycles: 16, mac: 16, mem: 16 },
            CostVec { cycles: 17, mac: 17, mem: 17 },
            CostVec { cycles: 18, mac: 18, mem: 18 },
        ];
        let n = 16;
        let row = |hits: usize| -> Vec<bool> { (0..n).map(|j| j < hits).collect() };
        let correct = vec![
            row(4),
            row(12),
            row(12),
            row(6),
            row(5),
            row(4),
            row(3),
            row(2),
            row(1),
            row(16),
        ];
        let land = Landscape { costs, n, correct };
        let g = run(&land, &GuidedOpts { rungs: 3, eta: 2, seed: 1, max_alive: None });
        assert_zero_regret(&land, &g, "tie landscape");
        let gpts: Vec<EvalPoint> = g.points.iter().map(|(_, p)| p.clone()).collect();
        let front: Vec<usize> =
            pareto_front(&gpts, |p| p.cycles).into_iter().map(|pos| g.points[pos].0).collect();
        assert!(front.contains(&1), "tie representative lost: front {front:?}");
        assert!(!front.contains(&2), "duplicate value pair double-counted: {front:?}");
    }

    #[test]
    fn stream_engine_is_byte_identical_to_the_slice_wrapper() {
        // `guided_search` is a wrapper over `guided_search_stream`;
        // pricing by closure must change nothing, including the stats.
        for seed in [0u64, 5, 17, 0xD5E] {
            let land = Landscape::random(seed, 9 + (seed as usize * 11) % 35, 16);
            let opts = GuidedOpts { rungs: 3, eta: 2, seed, max_alive: None };
            let ep = |idxs: &[usize], m: usize| -> Result<Vec<u32>> {
                Ok(idxs
                    .iter()
                    .map(|&i| land.correct[i][..m].iter().filter(|&&b| b).count() as u32)
                    .collect())
            };
            let ef = |idxs: &[usize]| -> Result<Vec<EvalPoint>> {
                Ok(idxs.iter().map(|&i| land.point(i)).collect())
            };
            let a = guided_search(&land.costs, land.n, &opts, &ep, &ef).unwrap();
            let b = guided_search_stream(
                land.costs.len(),
                &|i| land.costs[i],
                land.n,
                &opts,
                &ep,
                &ef,
            )
            .unwrap();
            assert_eq!(a, b, "seed {seed}: stream engine diverged from the slice wrapper");
        }
    }

    #[test]
    fn peak_alive_ledger_tracks_materialized_configs_only() {
        // Designed landscape (cheap dominant config): the driver must
        // report a peak far below the space — the bounded-memory
        // contract is this counter, not wall-clock.
        let space = 24;
        let n = 16;
        let costs: Vec<CostVec> = (0..space as u64)
            .map(|i| CostVec { cycles: 10 + i * 5, mac: 20 + i * 3, mem: 30 + i * 7 })
            .collect();
        let correct: Vec<Vec<bool>> = (0..space)
            .map(|i| (0..n).map(|j| i == 0 || (j >= n / 2 && (i + j) % 3 == 0)).collect())
            .collect();
        let land = Landscape { costs, n, correct };
        let g = run(&land, &GuidedOpts { rungs: 3, eta: 2, seed: 7, max_alive: None });
        assert_eq!(g.stats.peak_alive, g.stats.full_evals, "peak != cumulative materialized");
        assert!(
            g.stats.peak_alive < space,
            "peak alive {} not bounded below the {space}-config space",
            g.stats.peak_alive
        );
    }

    #[test]
    fn max_alive_overflow_is_a_typed_error() {
        // Flat landscape: everything ties, nothing prunes, so the
        // survivor set is ~space/eta^rungs and overflows a small cap —
        // the sweep must fail loudly, naming the knob.
        let space = 64;
        let n = 16;
        let costs = vec![CostVec { cycles: 10, mac: 10, mem: 10 }; space];
        let correct: Vec<Vec<bool>> = (0..space).map(|_| vec![true; n]).collect();
        let land = Landscape { costs, n, correct };
        let opts = GuidedOpts { rungs: 3, eta: 2, seed: 3, max_alive: Some(4) };
        let ep = |idxs: &[usize], m: usize| -> Result<Vec<u32>> {
            Ok(idxs
                .iter()
                .map(|&i| land.correct[i][..m].iter().filter(|&&b| b).count() as u32)
                .collect())
        };
        let ef = |idxs: &[usize]| -> Result<Vec<EvalPoint>> {
            Ok(idxs.iter().map(|&i| land.point(i)).collect())
        };
        let err = guided_search(&land.costs, land.n, &opts, &ep, &ef).unwrap_err();
        assert!(err.to_string().contains("--max-alive"), "untyped overflow error: {err}");
        // A generous cap changes nothing about the result.
        let loose = GuidedOpts { max_alive: Some(space), ..opts };
        let strict = GuidedOpts { max_alive: None, ..opts };
        let a = guided_search(&land.costs, land.n, &loose, &ep, &ef).unwrap();
        let b = guided_search(&land.costs, land.n, &strict, &ep, &ef).unwrap();
        assert_eq!(a.points, b.points, "a non-binding cap changed the sweep");
    }

    #[test]
    fn degenerate_sweep_respects_the_alive_cap() {
        let land = Landscape::random(4, RUNG_THRESHOLD - 1, 16);
        let opts = GuidedOpts { rungs: 3, eta: 2, seed: 0, max_alive: Some(2) };
        let err = run_result(&land, &opts).unwrap_err();
        assert!(err.to_string().contains("--max-alive"), "untyped overflow error: {err}");
    }

    fn run_result(land: &Landscape, opts: &GuidedOpts) -> Result<GuidedSweep> {
        let ep = |idxs: &[usize], m: usize| -> Result<Vec<u32>> {
            Ok(idxs
                .iter()
                .map(|&i| land.correct[i][..m].iter().filter(|&&b| b).count() as u32)
                .collect())
        };
        let ef = |idxs: &[usize]| -> Result<Vec<EvalPoint>> {
            Ok(idxs.iter().map(|&i| land.point(i)).collect())
        };
        guided_search(&land.costs, land.n, opts, &ep, &ef)
    }
}
