//! Mixed-precision design-space exploration (paper Section 4).
//!
//! Per-layer weight bit-widths ∈ {2, 4, 8} are enumerated (with the
//! paper's pruning: sensitive first layer pinned to 8-bit), each
//! configuration is quantized post-training against the calibrated
//! activation scales, accuracy is evaluated through the coordinator
//! (PJRT artifact or host reference) and cost comes from the per-layer
//! cycle model measured once on the ISS. The outputs are the Fig.-6
//! Pareto spaces and the Fig.-8 threshold-selected configurations.

pub mod cycles;
pub mod pareto;
pub mod search;
pub mod shard;

use crate::models::infer::{quantize_model, ModelParams, QModel};
use crate::models::ModelSpec;
use crate::rng::Rng;
use std::collections::HashSet;

/// A mixed-precision configuration: one weight bit-width per
/// quantizable layer.
pub type Config = Vec<u32>;

/// The candidate widths, most to least precise.
pub const WIDTHS: [u32; 3] = [8, 4, 2];

/// A lazily enumerable configuration space with the paper's pruning
/// strategy — the streaming counterpart of [`enumerate`], bit-identical
/// to it in content and order for every regime.
///
/// * layers in `pinned` (the sensitive initial layer(s)) stay at 8-bit,
/// * if the pruned space `3^(L-|pinned|)` fits in `budget`, the space
///   is **exhaustive**: configuration `i` is the mixed-radix base-3
///   decode of `i` over the free layers (ascending), so [`get`] is
///   O(L) with O(1) state and nothing is ever materialized — a 10^6+
///   space costs as much memory as one config,
/// * otherwise the space holds the **structured families** the paper's
///   large-model exploration concentrates on (uniforms, precision
///   staircases) plus a seeded random fill — at most `budget` configs
///   (never `3^L`), materialized because the random fill is
///   dedup-dependent and has no independent index decode.
///
/// Index decode contract: `space.get(i)` equals `enumerate(..)[i]` for
/// every `i < space.len()`, and [`iter`](ConfigSpace::iter) yields
/// exactly `get(0), get(1), …` — the global enumeration indices that
/// [`ShardSpec`](shard::ShardSpec) partitions and the sweep artifacts
/// record.
///
/// [`get`]: ConfigSpace::get
#[derive(Debug, Clone)]
pub struct ConfigSpace {
    n_layers: usize,
    free: Vec<usize>,
    kind: SpaceKind,
}

#[derive(Debug, Clone)]
enum SpaceKind {
    /// `3^free` fits the budget: pure mixed-radix decode, no storage.
    Exhaustive { total: usize },
    /// Structured families + seeded random fill, budget-bounded.
    Sampled { configs: Vec<Config> },
}

impl ConfigSpace {
    /// Build the space for `(n_layers, pinned, budget, seed)` — the
    /// same parameters (and the same output) as [`enumerate`].
    pub fn new(n_layers: usize, pinned: &[usize], budget: usize, seed: u64) -> ConfigSpace {
        let free: Vec<usize> = (0..n_layers).filter(|i| !pinned.contains(i)).collect();
        if let Some(total) = 3usize.checked_pow(free.len() as u32) {
            if total <= budget {
                return ConfigSpace { n_layers, free, kind: SpaceKind::Exhaustive { total } };
            }
        }

        // Structured regime. Dedup is hash-set keyed (the families
        // overlap; `contains` on the output vector would be O(n²) over
        // the budget) and keeps the first occurrence, so content and
        // order match the historical scan exactly.
        let mut seen: HashSet<Config> = HashSet::new();
        let mut out: Vec<Config> = Vec::new();
        let mut push_unique = |cfg: Config, out: &mut Vec<Config>| {
            if seen.insert(cfg.clone()) {
                out.push(cfg);
            }
        };

        // Uniform configurations.
        for w in WIDTHS {
            let mut cfg = vec![w; n_layers];
            for &p in pinned {
                cfg[p] = 8;
            }
            push_unique(cfg, &mut out);
        }
        // Staircases: layers < split stay high, the tail drops to `low`
        // (monotone-precision families, O(L²) of them).
        for split in 0..=free.len() {
            for (high, low) in [(8u32, 4u32), (8, 2), (4, 2)] {
                let mut cfg = vec![8u32; n_layers];
                for (j, &l) in free.iter().enumerate() {
                    cfg[l] = if j < split { high } else { low };
                }
                for &p in pinned {
                    cfg[p] = 8;
                }
                push_unique(cfg, &mut out);
            }
        }
        // Random fill to budget.
        let mut rng = Rng::new(seed);
        while out.len() < budget {
            let mut cfg = vec![8u32; n_layers];
            for &l in &free {
                cfg[l] = WIDTHS[rng.below(3) as usize];
            }
            push_unique(cfg, &mut out);
        }
        out.truncate(budget);
        ConfigSpace { n_layers, free, kind: SpaceKind::Sampled { configs: out } }
    }

    /// Number of configurations in the space.
    pub fn len(&self) -> usize {
        match &self.kind {
            SpaceKind::Exhaustive { total } => *total,
            SpaceKind::Sampled { configs } => configs.len(),
        }
    }

    /// True when the space holds no configurations (a zero budget).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True in the exhaustive (index-decoded) regime — the regime a
    /// merged artifact needs for the coverage check, and the one where
    /// streaming beats materializing by the full `3^free` factor.
    pub fn is_exhaustive(&self) -> bool {
        matches!(self.kind, SpaceKind::Exhaustive { .. })
    }

    /// Decode the configuration at global enumeration index `i`.
    ///
    /// Exhaustive regime: mixed-radix base-3 decode over the free
    /// layers ascending, pinned layers at 8 — O(L), no lookup.
    /// Structured regime: the stored sequence. Panics when `i` is out
    /// of range (callers hold `i < len()` by construction).
    pub fn get(&self, i: usize) -> Config {
        match &self.kind {
            SpaceKind::Exhaustive { total } => {
                assert!(i < *total, "config index {i} out of a {total}-config space");
                let mut cfg = vec![8u32; self.n_layers];
                let mut rest = i;
                for &l in &self.free {
                    cfg[l] = WIDTHS[rest % 3];
                    rest /= 3;
                }
                cfg
            }
            SpaceKind::Sampled { configs } => configs[i].clone(),
        }
    }

    /// Stream the space in enumeration order: yields `get(0), get(1),
    /// …` — one configuration materialized at a time.
    pub fn iter(&self) -> ConfigSpaceIter<'_> {
        ConfigSpaceIter { space: self, next: 0 }
    }
}

/// Streaming iterator over a [`ConfigSpace`] (see
/// [`ConfigSpace::iter`]).
pub struct ConfigSpaceIter<'a> {
    space: &'a ConfigSpace,
    next: usize,
}

impl Iterator for ConfigSpaceIter<'_> {
    type Item = Config;

    fn next(&mut self) -> Option<Config> {
        if self.next >= self.space.len() {
            return None;
        }
        let cfg = self.space.get(self.next);
        self.next += 1;
        Some(cfg)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.space.len() - self.next;
        (left, Some(left))
    }
}

impl ExactSizeIterator for ConfigSpaceIter<'_> {}

/// Enumerate configurations with the paper's pruning strategy — the
/// materialized view of [`ConfigSpace`] (see there for the regimes).
/// Prefer streaming the space for anything sized by `3^L`; this is the
/// small-space convenience the harness tests and examples use.
pub fn enumerate(n_layers: usize, pinned: &[usize], budget: usize, seed: u64) -> Vec<Config> {
    ConfigSpace::new(n_layers, pinned, budget, seed).iter().collect()
}

/// Default pinning: the first quantizable layer (the paper pins the
/// sensitive initial layers to 8-bit).
pub fn default_pinned() -> Vec<usize> {
    vec![0]
}

/// MAC-*instruction* count of one layer under a bit-width (the Fig.-6
/// x-axis): baseline scalar code issues one MAC instruction (mul) per
/// MAC, the extension retires `32/bits` MACs per `nn_mac` instruction,
/// with per-group packing boundaries exactly as the kernels stream them.
pub fn mac_instructions(info: &crate::models::QLayerInfo, bits: Option<u32>) -> u64 {
    use crate::models::QKind;
    match bits {
        None => info.macs, // baseline: one mul per MAC
        Some(b) => {
            let lanes = (32 / b) as usize;
            match info.kind {
                QKind::Conv => {
                    let strip = info.k * info.in_shape[2];
                    let wpg = strip.div_ceil(lanes);
                    (info.out_shape[0] * info.out_shape[1] * info.out_shape[2] * info.k * wpg)
                        as u64
                }
                QKind::Depthwise => {
                    let wpg = (info.k * info.k).div_ceil(lanes);
                    (info.out_shape[0] * info.out_shape[1] * info.in_shape[2] * wpg) as u64
                }
                QKind::Dense => {
                    let wpg = info.in_shape[2].div_ceil(lanes);
                    (info.out_shape[2] * wpg) as u64
                }
            }
        }
    }
}

/// Total MAC instructions of a configuration.
pub fn total_mac_instructions(analysis: &crate::models::ModelAnalysis, cfg: &Config) -> u64 {
    analysis.layers.iter().zip(cfg).map(|(info, &b)| mac_instructions(info, Some(b))).sum()
}

/// One evaluated design point. `PartialEq` compares every field
/// exactly (the shard merger bit-compares floats separately via
/// [`shard::point_divergence`]).
#[derive(Debug, Clone, PartialEq)]
pub struct EvalPoint {
    /// The configuration.
    pub config: Config,
    /// Top-1 accuracy on the evaluation set.
    pub accuracy: f32,
    /// MAC instructions (Fig. 6 x-axis).
    pub mac_instructions: u64,
    /// End-to-end cycles from the per-layer cycle model.
    pub cycles: u64,
    /// Memory accesses from the cycle model.
    pub mem_accesses: u64,
    /// Mean per-input cycles measured by the evaluator's own ISS runs —
    /// populated by the `IssEval` backend, whose accuracy and cycles
    /// come from the same `run_model_batch` executions. `None` for the
    /// host/PJRT backends.
    pub iss_cycles: Option<u64>,
    /// Host-vs-backend top-1 disagreement fraction (the `IssEval`
    /// differential check; `None` when the backend doesn't compute it).
    pub divergence: Option<f32>,
}

/// Quantize a model under a configuration (helper shared by the
/// coordinator and the harnesses).
pub fn quantize_config(
    spec: &ModelSpec,
    params: &ModelParams,
    sites: &[f32],
    cfg: &Config,
) -> QModel {
    quantize_model(spec, params, sites, cfg)
}

/// Select the fastest configuration whose accuracy stays within
/// `loss_threshold` of `float_acc` (the Fig.-8 selection rule). Returns
/// the index into `points`.
pub fn select_under_threshold(
    points: &[EvalPoint],
    float_acc: f32,
    loss_threshold: f32,
) -> Option<usize> {
    points
        .iter()
        .enumerate()
        .filter(|(_, p)| p.accuracy >= float_acc - loss_threshold)
        .min_by_key(|(_, p)| p.cycles)
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{analyze, zoo};

    #[test]
    fn exhaustive_when_small() {
        let cfgs = enumerate(4, &[0], 100, 1);
        // 3^3 = 27 free combinations, first layer pinned at 8.
        assert_eq!(cfgs.len(), 27);
        assert!(cfgs.iter().all(|c| c[0] == 8));
        // All unique.
        let mut sorted = cfgs.clone();
        sorted.sort();
        sorted.dedup();
        assert_eq!(sorted.len(), 27);
    }

    #[test]
    fn structured_sampling_when_large() {
        let cfgs = enumerate(28, &[0], 200, 7);
        assert_eq!(cfgs.len(), 200);
        assert!(cfgs.iter().all(|c| c[0] == 8));
        // Contains the uniform configs.
        assert!(cfgs.iter().any(|c| c[1..].iter().all(|&b| b == 2)));
        assert!(cfgs.iter().any(|c| c[1..].iter().all(|&b| b == 4)));
        // Deterministic.
        assert_eq!(cfgs, enumerate(28, &[0], 200, 7));
    }

    #[test]
    fn space_streams_bit_identical_to_enumerate() {
        for (n_layers, pinned, budget, seed) in [
            (4usize, vec![0usize], 100usize, 1u64), // exhaustive
            (6, vec![0, 3], 100, 9),                // structured (3^4 > 100)
            (28, vec![0], 200, 7),                  // structured + random fill
            (3, vec![], 27, 0),                     // exhaustive, nothing pinned
        ] {
            let space = ConfigSpace::new(n_layers, &pinned, budget, seed);
            let materialized = enumerate(n_layers, &pinned, budget, seed);
            assert_eq!(space.len(), materialized.len());
            let streamed: Vec<Config> = space.iter().collect();
            assert_eq!(streamed, materialized, "stream != enumerate for n={n_layers}");
            for (i, cfg) in materialized.iter().enumerate() {
                assert_eq!(&space.get(i), cfg, "get({i}) drifted for n={n_layers}");
            }
        }
    }

    #[test]
    fn hash_dedup_matches_the_quadratic_scan() {
        // The structured regime's dedup moved from `Vec::contains` to a
        // first-occurrence hash set; this re-runs the historical O(n²)
        // scan as the oracle so structured+random output provably did
        // not change.
        let (n_layers, pinned, budget, seed) = (28usize, vec![0usize], 200usize, 7u64);
        let free: Vec<usize> = (0..n_layers).filter(|i| !pinned.contains(i)).collect();
        let mut out: Vec<Config> = Vec::new();
        let push_unique = |cfg: Config, out: &mut Vec<Config>| {
            if !out.contains(&cfg) {
                out.push(cfg);
            }
        };
        for w in WIDTHS {
            let mut cfg = vec![w; n_layers];
            cfg[0] = 8;
            push_unique(cfg, &mut out);
        }
        for split in 0..=free.len() {
            for (high, low) in [(8u32, 4u32), (8, 2), (4, 2)] {
                let mut cfg = vec![8u32; n_layers];
                for (j, &l) in free.iter().enumerate() {
                    cfg[l] = if j < split { high } else { low };
                }
                cfg[0] = 8;
                push_unique(cfg, &mut out);
            }
        }
        let mut rng = Rng::new(seed);
        while out.len() < budget {
            let mut cfg = vec![8u32; n_layers];
            for &l in &free {
                cfg[l] = WIDTHS[rng.below(3) as usize];
            }
            push_unique(cfg, &mut out);
        }
        out.truncate(budget);
        assert_eq!(enumerate(n_layers, &pinned, budget, seed), out);
    }

    #[test]
    fn mac_instruction_reduction_ge_86_percent() {
        // Fig.-6 claim: >86% MAC-instruction reduction at mixed precision.
        for spec in zoo::all_models() {
            let a = analyze(&spec);
            let baseline: u64 = a.layers.iter().map(|l| mac_instructions(l, None)).sum();
            let all4 = total_mac_instructions(&a, &vec![4; a.layers.len()]);
            let all2 = total_mac_instructions(&a, &vec![2; a.layers.len()]);
            let red4 = 1.0 - all4 as f64 / baseline as f64;
            let red2 = 1.0 - all2 as f64 / baseline as f64;
            // Paper: >86% at <1% loss, 93% at 5% loss. Our scaled models
            // have narrower channels (more packing slack at group
            // boundaries), so the bound is slightly looser here.
            assert!(red4 > 0.80, "{}: 4-bit reduction {red4}", spec.name);
            assert!(red2 > 0.88, "{}: 2-bit reduction {red2}", spec.name);
        }
    }

    #[test]
    fn threshold_selection_prefers_fast_within_budget() {
        let mk = |acc: f32, cyc: u64| EvalPoint {
            config: vec![8],
            accuracy: acc,
            mac_instructions: 0,
            cycles: cyc,
            mem_accesses: 0,
            iss_cycles: None,
            divergence: None,
        };
        let pts = vec![mk(0.90, 100), mk(0.89, 50), mk(0.70, 10)];
        assert_eq!(select_under_threshold(&pts, 0.90, 0.01), Some(1));
        assert_eq!(select_under_threshold(&pts, 0.90, 0.25), Some(2));
        assert_eq!(select_under_threshold(&pts, 0.99, 0.01), None);
    }
}
