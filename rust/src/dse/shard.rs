//! Sharded DSE sweeps: deterministic config-space partitioning, a
//! versioned per-shard sweep artifact, and a merger whose output is
//! **bit-identical** to the single-instance sweep (the invariant
//! `tests/sweep_sharding.rs` property-tests).
//!
//! The co-design loop (paper Fig. 5) sweeps a per-network configuration
//! space that PR 1–3 made cheap to evaluate *per config*; the next
//! scale step is splitting one sweep across processes/hosts. The
//! pipeline is partition → evaluate → merge:
//!
//! * [`ShardSpec`] names one shard of an N-way split and owns the
//!   partitioning rule. Both strategies are pure functions of the
//!   enumerated space (never of runtime state), so every instance
//!   computes the same split from the same `(model, seed, budget)`
//!   inputs with no coordination channel.
//! * [`ShardArtifact`] is what one shard run serialises: its evaluated
//!   points tagged with their **global enumeration index**, plus the
//!   [`SessionSnapshot`] delta attributing engine/session activity to
//!   this sweep. The JSON schema is versioned
//!   ([`SHARD_SCHEMA_VERSION`]); corrupted or mismatched files fail
//!   with a typed [`ShardError`], never a panic.
//! * [`merge`] recombines shard artifacts: deduplicates configs
//!   (bit-compare — two shards disagreeing on the same config is a
//!   divergence-style [`ShardError::Conflict`], mirroring the
//!   host-vs-ISS differential check), verifies full coverage of the
//!   space, restores enumeration order from the global indices,
//!   recomputes the Pareto front via [`pareto_front`] and sums the
//!   per-shard stats. Merging is order- and duplicate-insensitive.
//!
//! `docs/ARCHITECTURE.md` § "Sharded sweeps" documents the dataflow and
//! the determinism contract end to end.

use super::pareto::pareto_front;
use super::search::SearchStrategy;
use super::{Config, EvalPoint};
use crate::json::{Json, ParseError, SchemaError};
use crate::sim::session::SessionSnapshot;
use std::collections::BTreeMap;
use std::fmt;

// ------------------------------------------------------- partitioning ---

/// How a config space is split across shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShardStrategy {
    /// FNV-1a hash of the per-layer widths, mod shard count. A config's
    /// hash never depends on the shard count, so membership is stable
    /// under resharding (only the modulus changes) and insensitive to
    /// enumeration order. Shard sizes are balanced in expectation.
    #[default]
    Hash,
    /// Contiguous index ranges over the enumeration order (shard `i` of
    /// `n` owns `[i·T/n, (i+1)·T/n)` of `T` configs). Sizes differ by
    /// at most one, and a shard maps to a contiguous slice of the
    /// deterministic [`enumerate`](super::enumerate) output — the
    /// easiest split to reason about in logs.
    Range,
}

impl ShardStrategy {
    /// Parse a CLI name (`hash | range`).
    pub fn parse(s: &str) -> Option<ShardStrategy> {
        match s {
            "hash" => Some(ShardStrategy::Hash),
            "range" => Some(ShardStrategy::Range),
            _ => None,
        }
    }

    /// Label for logs/artifacts.
    pub fn name(self) -> &'static str {
        match self {
            ShardStrategy::Hash => "hash",
            ShardStrategy::Range => "range",
        }
    }
}

/// One shard of an N-way sweep split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    /// This shard's index, `0 <= index < count`.
    pub index: usize,
    /// Total number of shards.
    pub count: usize,
    /// Partitioning rule.
    pub strategy: ShardStrategy,
}

impl ShardSpec {
    /// A validated shard spec.
    pub fn new(index: usize, count: usize, strategy: ShardStrategy) -> Result<Self, ShardError> {
        if count == 0 {
            return Err(ShardError::BadSpec("shard count must be >= 1".to_string()));
        }
        if index >= count {
            return Err(ShardError::BadSpec(format!(
                "shard index {index} out of range for {count} shard(s)"
            )));
        }
        Ok(ShardSpec { index, count, strategy })
    }

    /// Parse the CLI form `i/n` (e.g. `--shard 0/4`), hash strategy.
    pub fn parse(s: &str) -> Result<Self, ShardError> {
        let bad = || ShardError::BadSpec(format!("expected `i/n` (e.g. `0/4`), got `{s}`"));
        let (i, n) = s.split_once('/').ok_or_else(bad)?;
        let index: usize = i.trim().parse().map_err(|_| bad())?;
        let count: usize = n.trim().parse().map_err(|_| bad())?;
        ShardSpec::new(index, count, ShardStrategy::default())
    }

    /// The trivial 1-way "split" (sharding disabled).
    pub fn whole() -> Self {
        ShardSpec { index: 0, count: 1, strategy: ShardStrategy::default() }
    }

    /// Does this shard own the config at `global_index` of a
    /// `total`-config space?
    pub fn owns(&self, global_index: usize, cfg: &Config, total: usize) -> bool {
        match self.strategy {
            ShardStrategy::Hash => config_hash(cfg) as usize % self.count == self.index,
            ShardStrategy::Range => {
                let (lo, hi) = range_bounds(total, self.count, self.index);
                (lo..hi).contains(&global_index)
            }
        }
    }

    /// The global enumeration indices this shard owns, in order.
    pub fn member_indices(&self, configs: &[Config]) -> Vec<usize> {
        (0..configs.len()).filter(|&i| self.owns(i, &configs[i], configs.len())).collect()
    }

    /// The global enumeration indices this shard owns of a lazily
    /// enumerated space, in order — the streaming counterpart of
    /// [`ShardSpec::member_indices`], identical in output. Range shards
    /// are pure index arithmetic (no config is ever decoded); hash
    /// shards decode each config transiently for its key. Either way
    /// only the owned indices are collected, so partitioning a
    /// 10^6-config space costs O(shard), never the complement.
    pub fn member_indices_in(&self, space: &super::ConfigSpace) -> Vec<usize> {
        match self.strategy {
            ShardStrategy::Hash => (0..space.len())
                .filter(|&i| config_hash(&space.get(i)) as usize % self.count == self.index)
                .collect(),
            ShardStrategy::Range => {
                let (lo, hi) = range_bounds(space.len(), self.count, self.index);
                (lo..hi).collect()
            }
        }
    }
}

impl fmt::Display for ShardSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{} ({})", self.index, self.count, self.strategy.name())
    }
}

/// FNV-1a over the per-layer widths — the hash-strategy shard key.
/// Deliberately independent of the shard count and of the config's
/// position in the enumeration.
pub fn config_hash(cfg: &Config) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &w in cfg {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

/// `[lo, hi)` bounds of range-shard `index` of `count` over `total`
/// configs (balanced: sizes differ by at most one).
fn range_bounds(total: usize, count: usize, index: usize) -> (usize, usize) {
    (index * total / count, (index + 1) * total / count)
}

// ------------------------------------------------------- typed errors ---

/// Everything that can go wrong loading or merging shard artifacts. A
/// dedicated error type (not the crate's opaque [`Error`](crate::Error))
/// so callers — and the property tests — can match on the failure class;
/// it converts into the crate error via the blanket
/// `From<E: std::error::Error>`.
#[derive(Debug, Clone, PartialEq)]
pub enum ShardError {
    /// The file is not JSON at all.
    Parse(ParseError),
    /// The JSON is well-formed but a schema field is missing/mistyped.
    Schema(SchemaError),
    /// The artifact was written by a different schema generation.
    SchemaVersion {
        /// Version recorded in the file.
        found: u64,
        /// Version this build reads/writes.
        expected: u64,
    },
    /// An invalid shard spec (bad index/count or CLI syntax).
    BadSpec(String),
    /// Two artifacts describe different sweeps (model/seed/… mismatch)
    /// and cannot be merged.
    Incompatible {
        /// The metadata field that differs.
        field: &'static str,
        /// Value in the first artifact.
        a: String,
        /// Conflicting value.
        b: String,
    },
    /// Two shards evaluated the same config and **disagree** — the
    /// sharded analogue of the host-vs-ISS divergence report. This is
    /// always a bug (non-deterministic evaluator or mixed backends) and
    /// the merge refuses to pick a winner silently.
    Conflict {
        /// Global enumeration index of the conflicting config.
        global_index: usize,
        /// The config both shards evaluated.
        config: Config,
        /// First [`EvalPoint`] field that differs.
        field: &'static str,
        /// Value from the shard merged first.
        a: String,
        /// Conflicting value.
        b: String,
    },
    /// The merged shards do not cover the whole space.
    Coverage {
        /// Configs the space enumerates.
        expected: usize,
        /// Distinct configs the shards delivered.
        got: usize,
        /// Lowest uncovered global index, if any.
        first_missing: Option<usize>,
    },
    /// No artifacts were given to merge.
    Empty,
}

impl fmt::Display for ShardError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShardError::Parse(e) => write!(f, "shard artifact: {e}"),
            ShardError::Schema(e) => write!(f, "shard artifact: {e}"),
            ShardError::SchemaVersion { found, expected } => write!(
                f,
                "shard artifact schema version {found} (this build reads version {expected})"
            ),
            ShardError::BadSpec(m) => write!(f, "bad shard spec: {m}"),
            ShardError::Incompatible { field, a, b } => {
                write!(f, "shard artifacts disagree on `{field}`: `{a}` vs `{b}`")
            }
            ShardError::Conflict { global_index, config, field, a, b } => write!(
                f,
                "shard conflict at config #{global_index} {config:?}: `{field}` {a} vs {b} \
                 (non-deterministic evaluator or mixed backends?)"
            ),
            ShardError::Coverage { expected, got, first_missing } => write!(
                f,
                "merged shards cover {got}/{expected} configs{}",
                match first_missing {
                    Some(i) => format!(" (first missing: #{i})"),
                    None => String::new(),
                }
            ),
            ShardError::Empty => write!(f, "no shard artifacts to merge"),
        }
    }
}

impl std::error::Error for ShardError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ShardError::Parse(e) => Some(e),
            ShardError::Schema(e) => Some(e),
            _ => None,
        }
    }
}

impl From<SchemaError> for ShardError {
    fn from(e: SchemaError) -> Self {
        ShardError::Schema(e)
    }
}

// ------------------------------------------------------- the artifact ---

/// Version of the shard-artifact JSON schema this build reads/writes.
/// Bump on any incompatible change; readers reject other versions with
/// [`ShardError::SchemaVersion`].
pub const SHARD_SCHEMA_VERSION: u64 = 1;

/// What one shard run serialises: sweep identity (enough to prove two
/// artifacts partition the *same* space), the evaluated points tagged
/// with their global enumeration indices, and the session/engine stats
/// delta attributable to this sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardArtifact {
    /// Model name.
    pub model: String,
    /// Accuracy backend that scored the points (`host`/`iss`/`pjrt`).
    pub evaluator: String,
    /// Which shard of which split this is.
    pub spec: ShardSpec,
    /// Size of the full enumerated space.
    pub total_configs: usize,
    /// Enumeration seed.
    pub seed: u64,
    /// Images per accuracy evaluation.
    pub eval_n: usize,
    /// Float baseline accuracy (bit-compared on merge).
    pub float_acc: f32,
    /// Baseline MAC-instruction count.
    pub baseline_instrs: u64,
    /// Search strategy that produced the points. Part of the sweep
    /// identity: an `exhaustive` shard carries every config it owns, a
    /// `guided` shard only the subset its search fully evaluated, so
    /// the two kinds never merge together.
    pub search: SearchStrategy,
    /// Successive-halving rung count of a guided sweep (0 when
    /// exhaustive — the knob has no meaning there).
    pub rungs: u64,
    /// Halving factor of a guided sweep (0 when exhaustive).
    pub eta: u64,
    /// Cluster core count the points were priced for (1 = single-core,
    /// the default machine). Part of the sweep identity: cycle totals
    /// from different cluster geometries are not comparable, so shards
    /// priced for different `--cores` never merge.
    pub cores: u64,
    /// `(global enumeration index, evaluated point)` — exactly the
    /// configs this shard owns (exhaustive) or the owned configs its
    /// guided search fully evaluated, in enumeration order.
    pub points: Vec<(usize, EvalPoint)>,
    /// Session/engine activity attributed to this sweep (before/after
    /// delta on the global [`SimSession`](crate::sim::session::SimSession)).
    pub stats: SessionSnapshot,
}

fn point_json(p: &EvalPoint) -> Json {
    Json::obj(vec![
        ("bits", Json::Arr(p.config.iter().map(|&b| Json::i(b as i64)).collect())),
        ("acc", Json::Num(p.accuracy as f64)),
        ("mac_instrs", Json::i(p.mac_instructions as i64)),
        ("cycles", Json::i(p.cycles as i64)),
        ("mem_accesses", Json::i(p.mem_accesses as i64)),
        ("iss_cycles", p.iss_cycles.map_or(Json::Null, |c| Json::i(c as i64))),
        ("divergence", p.divergence.map_or(Json::Null, |d| Json::Num(d as f64))),
    ])
}

fn point_from_json(j: &Json) -> Result<EvalPoint, SchemaError> {
    let config: Config = j
        .req_arr("bits")?
        .iter()
        .map(|b| match b.as_i64() {
            Some(v) if (0..=32).contains(&v) => Ok(v as u32),
            _ => Err(SchemaError { field: "bits".to_string(), msg: "bad width".to_string() }),
        })
        .collect::<Result<_, _>>()?;
    Ok(EvalPoint {
        config,
        accuracy: j.req_f64("acc")? as f32,
        mac_instructions: j.req_u64("mac_instrs")?,
        cycles: j.req_u64("cycles")?,
        mem_accesses: j.req_u64("mem_accesses")?,
        iss_cycles: j.opt("iss_cycles", |v| match v.as_f64() {
            Some(c) if c.is_finite() && c >= 0.0 && c == c.trunc() => Ok(c as u64),
            _ => Err(SchemaError {
                field: "iss_cycles".to_string(),
                msg: "expected a non-negative integer".to_string(),
            }),
        })?,
        divergence: j.opt("divergence", |v| match v.as_f64() {
            Some(d) if d.is_finite() => Ok(d as f32),
            _ => Err(SchemaError {
                field: "divergence".to_string(),
                msg: "expected a finite number".to_string(),
            }),
        })?,
    })
}

fn stats_json(s: &SessionSnapshot) -> Json {
    Json::obj(vec![
        ("mem_reuses", Json::i(s.mem_reuses as i64)),
        ("mem_allocs", Json::i(s.mem_allocs as i64)),
        ("runs", Json::i(s.runs as i64)),
        ("load_mac", Json::i(s.engine.load_mac as i64)),
        ("scalar_mac", Json::i(s.engine.scalar_mac as i64)),
        ("latch", Json::i(s.engine.latch as i64)),
        ("requant", Json::i(s.engine.requant as i64)),
        ("counted_loops", Json::i(s.engine.counted_loops as i64)),
        ("counted_iters", Json::i(s.engine.counted_iters as i64)),
        ("fallbacks", Json::i(s.engine.fallbacks as i64)),
    ])
}

fn stats_from_json(j: &Json) -> Result<SessionSnapshot, SchemaError> {
    Ok(SessionSnapshot {
        mem_reuses: j.req_u64("mem_reuses")?,
        mem_allocs: j.req_u64("mem_allocs")?,
        runs: j.req_u64("runs")?,
        engine: crate::sim::engine::EngineStats {
            load_mac: j.req_u64("load_mac")?,
            scalar_mac: j.req_u64("scalar_mac")?,
            latch: j.req_u64("latch")?,
            requant: j.req_u64("requant")?,
            counted_loops: j.req_u64("counted_loops")?,
            counted_iters: j.req_u64("counted_iters")?,
            fallbacks: j.req_u64("fallbacks")?,
        },
    })
}

impl ShardArtifact {
    /// Serialise to the versioned JSON schema. The `search` tag is
    /// always emitted; the guided knobs (`rungs`/`eta`) only under
    /// `search: guided` — readers default all three, so pre-guided
    /// version-1 artifacts keep parsing as exhaustive sweeps.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("schema_version", Json::i(SHARD_SCHEMA_VERSION as i64)),
            ("kind", Json::s("mpnn_shard_sweep")),
            ("model", Json::s(&self.model)),
            ("evaluator", Json::s(&self.evaluator)),
            ("search", Json::s(self.search.name())),
        ];
        if self.search == SearchStrategy::Guided {
            fields.push(("rungs", Json::i(self.rungs as i64)));
            fields.push(("eta", Json::i(self.eta as i64)));
        }
        // Like the guided knobs: emitted only off the default, so
        // single-core artifacts stay byte-identical to pre-cluster ones.
        if self.cores > 1 {
            fields.push(("cores", Json::i(self.cores as i64)));
        }
        fields.extend(vec![
            ("strategy", Json::s(self.spec.strategy.name())),
            ("shard_index", Json::i(self.spec.index as i64)),
            ("shard_count", Json::i(self.spec.count as i64)),
            ("total_configs", Json::i(self.total_configs as i64)),
            // Decimal string, not a JSON number: seeds are full-range
            // u64 and must survive the round trip bit-exactly (numbers
            // travel through f64 and lose precision past 2^53).
            ("seed", Json::s(&self.seed.to_string())),
            ("eval_n", Json::i(self.eval_n as i64)),
            ("float_acc", Json::Num(self.float_acc as f64)),
            ("baseline_mac_instrs", Json::i(self.baseline_instrs as i64)),
            (
                "points",
                Json::Arr(
                    self.points
                        .iter()
                        .map(|(i, p)| {
                            let mut obj = point_json(p);
                            if let Json::Obj(kv) = &mut obj {
                                kv.insert(0, ("index".to_string(), Json::i(*i as i64)));
                            }
                            obj
                        })
                        .collect(),
                ),
            ),
            ("stats", stats_json(&self.stats)),
        ]);
        Json::obj(fields)
    }

    /// Deserialise from a parsed document, rejecting unknown schema
    /// versions and malformed fields with typed errors.
    pub fn from_json(j: &Json) -> Result<Self, ShardError> {
        let version = j.req_u64("schema_version")?;
        if version != SHARD_SCHEMA_VERSION {
            return Err(ShardError::SchemaVersion {
                found: version,
                expected: SHARD_SCHEMA_VERSION,
            });
        }
        let strategy_name = j.req_str("strategy")?;
        let strategy = ShardStrategy::parse(strategy_name).ok_or_else(|| {
            ShardError::Schema(SchemaError {
                field: "strategy".to_string(),
                msg: format!("unknown strategy `{strategy_name}`"),
            })
        })?;
        let spec =
            ShardSpec::new(j.req_u64("shard_index")? as usize, j.req_u64("shard_count")? as usize, strategy)?;
        // Optional with defaults: version-1 artifacts written before
        // guided search carry no `search`/`rungs`/`eta` fields and are
        // exhaustive sweeps by definition.
        let search = j
            .opt("search", |v| {
                v.as_str().and_then(SearchStrategy::parse).ok_or_else(|| SchemaError {
                    field: "search".to_string(),
                    msg: "expected `exhaustive` or `guided`".to_string(),
                })
            })?
            .unwrap_or_default();
        let guided_knob = |field: &'static str| -> Result<u64, ShardError> {
            Ok(j.opt(field, |v| match v.as_f64() {
                Some(x) if x.is_finite() && x >= 0.0 && x == x.trunc() => Ok(x as u64),
                _ => Err(SchemaError {
                    field: field.to_string(),
                    msg: "expected a non-negative integer".to_string(),
                }),
            })?
            .unwrap_or(0))
        };
        let mut points = Vec::new();
        for pj in j.req_arr("points")? {
            let idx = pj.req_u64("index")? as usize;
            points.push((idx, point_from_json(pj)?));
        }
        Ok(ShardArtifact {
            model: j.req_str("model")?.to_string(),
            evaluator: j.req_str("evaluator")?.to_string(),
            spec,
            total_configs: j.req_u64("total_configs")? as usize,
            seed: j.req_str("seed")?.parse().map_err(|_| {
                ShardError::Schema(SchemaError {
                    field: "seed".to_string(),
                    msg: "expected a u64 decimal string".to_string(),
                })
            })?,
            eval_n: j.req_u64("eval_n")? as usize,
            float_acc: j.req_f64("float_acc")? as f32,
            baseline_instrs: j.req_u64("baseline_mac_instrs")?,
            search,
            rungs: guided_knob("rungs")?,
            eta: guided_knob("eta")?,
            // Absent in pre-cluster (and all single-core) artifacts:
            // those were priced for one core by definition.
            cores: j
                .opt("cores", |v| match v.as_f64() {
                    Some(x) if x.is_finite() && x >= 1.0 && x == x.trunc() => Ok(x as u64),
                    _ => Err(SchemaError {
                        field: "cores".to_string(),
                        msg: "expected a positive integer".to_string(),
                    }),
                })?
                .unwrap_or(1),
            points,
            stats: stats_from_json(j.req("stats")?)?,
        })
    }

    /// Parse an artifact from JSON text.
    pub fn from_str(text: &str) -> Result<Self, ShardError> {
        let j = Json::parse(text).map_err(ShardError::Parse)?;
        ShardArtifact::from_json(&j)
    }

    /// Load an artifact file. IO errors surface as the crate error;
    /// format errors keep their [`ShardError`] class in the chain.
    pub fn load(path: &std::path::Path) -> crate::error::Result<Self> {
        use crate::error::Context;
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading shard artifact {}", path.display()))?;
        ShardArtifact::from_str(&text)
            .map_err(crate::error::Error::from)
            .with_context(|| format!("loading shard artifact {}", path.display()))
    }

    /// Write the artifact to `path` (parent directories created).
    pub fn save(&self, path: &std::path::Path) -> crate::error::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }
}

// ------------------------------------------------------------ merging ---

/// The result of merging shard artifacts back into one sweep.
#[derive(Debug, Clone)]
pub struct MergedSweep {
    /// Model name.
    pub model: String,
    /// Accuracy backend label.
    pub evaluator: String,
    /// Enumeration seed.
    pub seed: u64,
    /// Images per accuracy evaluation.
    pub eval_n: usize,
    /// Float baseline accuracy.
    pub float_acc: f32,
    /// Baseline MAC-instruction count.
    pub baseline_instrs: u64,
    /// Search strategy the shards ran under ([`merge`] refuses to mix).
    pub search: SearchStrategy,
    /// Cluster core count the shards priced cycles for (1 =
    /// single-core; [`merge`] refuses to mix geometries).
    pub cores: u64,
    /// Global enumeration index of each entry in `points` (same order).
    /// Exhaustive merges always cover `0..total_configs`; guided merges
    /// carry only the configs the search fully evaluated.
    pub indices: Vec<usize>,
    /// Every evaluated point, restored to global enumeration order —
    /// bit-identical to what a single-instance sweep returns.
    pub points: Vec<EvalPoint>,
    /// Global Pareto front by MAC instructions (recomputed; matches the
    /// single-instance front index-for-index).
    pub front: Vec<usize>,
    /// Summed per-shard session/engine stats.
    pub stats: SessionSnapshot,
    /// Distinct shard artifacts merged (after dropping exact duplicates).
    pub shards: usize,
    /// Configs delivered identically by more than one shard (expected
    /// with overlapping hash/range splits; conflicts are errors).
    pub duplicate_points: usize,
}

/// First [`EvalPoint`] field on which `a` and `b` differ, bit-compared
/// (floats via `to_bits`, so `-0.0 != 0.0` and NaNs never compare
/// equal-by-accident).
pub fn point_divergence(a: &EvalPoint, b: &EvalPoint) -> Option<(&'static str, String, String)> {
    if a.config != b.config {
        return Some(("config", format!("{:?}", a.config), format!("{:?}", b.config)));
    }
    if a.accuracy.to_bits() != b.accuracy.to_bits() {
        return Some(("accuracy", format!("{}", a.accuracy), format!("{}", b.accuracy)));
    }
    if a.mac_instructions != b.mac_instructions {
        return Some((
            "mac_instructions",
            a.mac_instructions.to_string(),
            b.mac_instructions.to_string(),
        ));
    }
    if a.cycles != b.cycles {
        return Some(("cycles", a.cycles.to_string(), b.cycles.to_string()));
    }
    if a.mem_accesses != b.mem_accesses {
        return Some(("mem_accesses", a.mem_accesses.to_string(), b.mem_accesses.to_string()));
    }
    if a.iss_cycles != b.iss_cycles {
        return Some(("iss_cycles", format!("{:?}", a.iss_cycles), format!("{:?}", b.iss_cycles)));
    }
    if a.divergence.map(f32::to_bits) != b.divergence.map(f32::to_bits) {
        return Some(("divergence", format!("{:?}", a.divergence), format!("{:?}", b.divergence)));
    }
    None
}

fn incompatible(field: &'static str, a: impl fmt::Display, b: impl fmt::Display) -> ShardError {
    ShardError::Incompatible { field, a: a.to_string(), b: b.to_string() }
}

/// Same shard run: identical identity, spec and evaluated points —
/// everything except the [`SessionSnapshot`], which legitimately
/// differs between a shard and its retry (warm caches change the pool
/// counters). Such artifacts must count **once** toward merged stats.
fn same_run(a: &ShardArtifact, b: &ShardArtifact) -> bool {
    a.spec == b.spec
        && a.model == b.model
        && a.evaluator == b.evaluator
        && a.search == b.search
        && a.rungs == b.rungs
        && a.eta == b.eta
        && a.cores == b.cores
        && a.total_configs == b.total_configs
        && a.seed == b.seed
        && a.eval_n == b.eval_n
        && a.float_acc.to_bits() == b.float_acc.to_bits()
        && a.baseline_instrs == b.baseline_instrs
        && a.points.len() == b.points.len()
        && a.points
            .iter()
            .zip(&b.points)
            .all(|((ia, pa), (ib, pb))| ia == ib && point_divergence(pa, pb).is_none())
}

/// Total order over stats snapshots — the deterministic tie-break for
/// which of a shard's retries contributes its stats to the merge.
fn stats_key(s: &SessionSnapshot) -> [u64; 10] {
    [
        s.mem_reuses,
        s.mem_allocs,
        s.runs,
        s.engine.load_mac,
        s.engine.scalar_mac,
        s.engine.latch,
        s.engine.requant,
        s.engine.counted_loops,
        s.engine.counted_iters,
        s.engine.fallbacks,
    ]
}

/// Merge shard artifacts into the exact single-instance sweep result.
///
/// Deterministic, order-insensitive (inputs are canonically reordered)
/// and duplicate-insensitive: duplicate artifacts — byte-identical
/// copies *and* retries of the same shard whose only difference is the
/// stats snapshot — collapse to one (smallest stats snapshot wins, so
/// the result is order-independent), as do identically-evaluated
/// duplicate configs across overlapping splits; *disagreeing*
/// duplicates are [`ShardError::Conflict`]s. Fails typed when the
/// artifacts describe different sweeps or leave part of the space
/// uncovered.
pub fn merge(artifacts: &[ShardArtifact]) -> Result<MergedSweep, ShardError> {
    if artifacts.is_empty() {
        return Err(ShardError::Empty);
    }
    // Collapse duplicate runs so stats are not double-counted: the
    // same file merged twice, and also a shard plus its *retry* — same
    // identity/points, different pool-stats snapshot (warm caches).
    // Among retries the smallest stats snapshot wins, so the outcome
    // is independent of input order. An artifact whose *points* differ
    // for the same slot stays in and is caught by the point-level
    // conflict check below.
    let mut arts: Vec<&ShardArtifact> = Vec::new();
    for a in artifacts {
        match arts.iter_mut().find(|kept| same_run(kept, a)) {
            Some(kept) => {
                if stats_key(&a.stats) < stats_key(&kept.stats) {
                    *kept = a;
                }
            }
            None => arts.push(a),
        }
    }
    // Canonical order: (strategy, count, index). Sums are commutative
    // anyway; this pins the error *reporting* order too.
    arts.sort_by_key(|a| (a.spec.strategy.name(), a.spec.count, a.spec.index));

    let first = arts[0];
    for a in &arts[1..] {
        if a.model != first.model {
            return Err(incompatible("model", &first.model, &a.model));
        }
        if a.evaluator != first.evaluator {
            return Err(incompatible("evaluator", &first.evaluator, &a.evaluator));
        }
        // Guided and exhaustive artifacts never mix, and neither do
        // guided runs with different rung schedules: a guided shard
        // carries only a subset of its slice, so treating it as part of
        // an exhaustive sweep (or of a differently-scheduled guided
        // one) would silently change what the merge means.
        if (a.search, a.rungs, a.eta) != (first.search, first.rungs, first.eta) {
            let show = |x: &ShardArtifact| match x.search {
                SearchStrategy::Exhaustive => x.search.name().to_string(),
                SearchStrategy::Guided => {
                    format!("{} (rungs {}, eta {})", x.search.name(), x.rungs, x.eta)
                }
            };
            return Err(incompatible("search", show(first), show(a)));
        }
        // Cycle totals priced for different cluster geometries are not
        // comparable — a mixed merge would silently blend machines.
        if a.cores != first.cores {
            return Err(incompatible("cores", first.cores, a.cores));
        }
        if a.seed != first.seed {
            return Err(incompatible("seed", first.seed, a.seed));
        }
        if a.eval_n != first.eval_n {
            return Err(incompatible("eval_n", first.eval_n, a.eval_n));
        }
        if a.total_configs != first.total_configs {
            return Err(incompatible("total_configs", first.total_configs, a.total_configs));
        }
        if a.float_acc.to_bits() != first.float_acc.to_bits() {
            return Err(incompatible("float_acc", first.float_acc, a.float_acc));
        }
        if a.baseline_instrs != first.baseline_instrs {
            return Err(incompatible("baseline_mac_instrs", first.baseline_instrs, a.baseline_instrs));
        }
    }

    let mut by_index: BTreeMap<usize, &EvalPoint> = BTreeMap::new();
    let mut duplicate_points = 0usize;
    let mut stats = SessionSnapshot::default();
    for a in &arts {
        stats.add(&a.stats);
        for (i, p) in &a.points {
            match by_index.get(i) {
                None => {
                    by_index.insert(*i, p);
                }
                Some(existing) => match point_divergence(existing, p) {
                    None => duplicate_points += 1,
                    Some((field, va, vb)) => {
                        return Err(ShardError::Conflict {
                            global_index: *i,
                            config: p.config.clone(),
                            field,
                            a: va,
                            b: vb,
                        })
                    }
                },
            }
        }
    }

    let expected = first.total_configs;
    let covered = by_index.len();
    match first.search {
        SearchStrategy::Exhaustive => {
            // An exhaustive merge must restore the whole space, gap-free.
            let contiguous = match by_index.keys().next_back() {
                None => true,
                Some(&last) => last + 1 == covered,
            };
            if covered != expected || !contiguous {
                let first_missing = (0..expected).find(|i| !by_index.contains_key(i));
                return Err(ShardError::Coverage { expected, got: covered, first_missing });
            }
        }
        SearchStrategy::Guided => {
            // Guided shards legitimately carry only the configs their
            // search fully evaluated — no coverage requirement, but
            // every index must still fit the declared space.
            if by_index.keys().next_back().is_some_and(|&last| last >= expected) {
                return Err(ShardError::Coverage { expected, got: covered, first_missing: None });
            }
        }
    }

    let (indices, points): (Vec<usize>, Vec<EvalPoint>) =
        by_index.into_iter().map(|(i, p)| (i, p.clone())).unzip();
    let front = pareto_front(&points, |p| p.mac_instructions);
    Ok(MergedSweep {
        model: first.model.clone(),
        evaluator: first.evaluator.clone(),
        seed: first.seed,
        eval_n: first.eval_n,
        float_acc: first.float_acc,
        baseline_instrs: first.baseline_instrs,
        search: first.search,
        cores: first.cores,
        indices,
        points,
        front,
        stats,
        shards: arts.len(),
        duplicate_points,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(ws: &[u32]) -> Config {
        ws.to_vec()
    }

    fn point(ws: &[u32], acc: f32, cycles: u64) -> EvalPoint {
        EvalPoint {
            config: cfg(ws),
            accuracy: acc,
            mac_instructions: cycles / 2,
            cycles,
            mem_accesses: cycles / 3,
            iss_cycles: (cycles % 2 == 0).then_some(cycles * 10),
            divergence: (cycles % 3 == 0).then_some(0.25),
        }
    }

    fn artifact(spec: ShardSpec, total: usize, points: Vec<(usize, EvalPoint)>) -> ShardArtifact {
        ShardArtifact {
            model: "lenet5".to_string(),
            evaluator: "host".to_string(),
            spec,
            total_configs: total,
            seed: 7,
            eval_n: 16,
            float_acc: 0.875,
            baseline_instrs: 1234,
            search: SearchStrategy::Exhaustive,
            rungs: 0,
            eta: 0,
            cores: 1,
            points,
            stats: SessionSnapshot { mem_reuses: 1, mem_allocs: 2, runs: 3, ..Default::default() },
        }
    }

    #[test]
    fn spec_validation_and_parse() {
        assert!(ShardSpec::new(0, 1, ShardStrategy::Hash).is_ok());
        assert!(matches!(ShardSpec::new(2, 2, ShardStrategy::Hash), Err(ShardError::BadSpec(_))));
        assert!(matches!(ShardSpec::new(0, 0, ShardStrategy::Range), Err(ShardError::BadSpec(_))));
        let s = ShardSpec::parse("1/4").unwrap();
        assert_eq!((s.index, s.count), (1, 4));
        assert!(ShardSpec::parse("4/4").is_err());
        assert!(ShardSpec::parse("x/4").is_err());
        assert!(ShardSpec::parse("14").is_err());
    }

    #[test]
    fn partitions_are_disjoint_and_cover() {
        let configs: Vec<Config> =
            (0..50u32).map(|i| vec![8, [2, 4, 8][i as usize % 3], [2, 4][i as usize % 2]]).collect();
        for strategy in [ShardStrategy::Hash, ShardStrategy::Range] {
            for count in 1..=8 {
                let mut seen = vec![0usize; configs.len()];
                for index in 0..count {
                    let spec = ShardSpec::new(index, count, strategy).unwrap();
                    for i in spec.member_indices(&configs) {
                        seen[i] += 1;
                    }
                }
                assert!(
                    seen.iter().all(|&c| c == 1),
                    "{strategy:?} x{count}: ownership counts {seen:?}"
                );
            }
        }
    }

    #[test]
    fn streaming_partition_matches_the_materialized_one() {
        // `member_indices_in` over a lazy space must agree exactly with
        // `member_indices` over the materialized enumeration, in both
        // regimes and under both strategies.
        for (n_layers, budget, seed) in [(4usize, 100usize, 1u64), (28, 120, 7)] {
            let space = crate::dse::ConfigSpace::new(n_layers, &[0], budget, seed);
            let configs = crate::dse::enumerate(n_layers, &[0], budget, seed);
            for strategy in [ShardStrategy::Hash, ShardStrategy::Range] {
                for count in 1..=5 {
                    for index in 0..count {
                        let spec = ShardSpec::new(index, count, strategy).unwrap();
                        assert_eq!(
                            spec.member_indices_in(&space),
                            spec.member_indices(&configs),
                            "{strategy:?} {index}/{count} over n={n_layers}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn hash_is_independent_of_count_and_position() {
        let c = cfg(&[8, 4, 2]);
        let h = config_hash(&c);
        assert_eq!(h, config_hash(&c.clone()));
        // Same config owned by the same residue class whatever the count.
        for count in 1..=8 {
            let owner = (0..count)
                .filter(|&i| {
                    ShardSpec::new(i, count, ShardStrategy::Hash).unwrap().owns(17, &c, 100)
                })
                .count();
            assert_eq!(owner, 1);
        }
        assert_ne!(config_hash(&cfg(&[8, 4, 2])), config_hash(&cfg(&[8, 2, 4])));
    }

    #[test]
    fn artifact_round_trips_bit_exactly() {
        let spec = ShardSpec::new(1, 3, ShardStrategy::Range).unwrap();
        let a = artifact(spec, 9, vec![(3, point(&[8, 4], 0.5, 100)), (4, point(&[8, 2], 0.25, 60))]);
        let text = a.to_json().to_string();
        let back = ShardArtifact::from_str(&text).unwrap();
        assert_eq!(back, a);
        // Re-emission is byte-stable.
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn version_mismatch_and_corruption_are_typed_errors() {
        let spec = ShardSpec::whole();
        let a = artifact(spec, 1, vec![(0, point(&[8], 0.5, 100))]);
        let text = a.to_json().to_string();

        let bumped = text.replace("\"schema_version\":1", "\"schema_version\":999");
        assert!(matches!(
            ShardArtifact::from_str(&bumped),
            Err(ShardError::SchemaVersion { found: 999, expected: SHARD_SCHEMA_VERSION })
        ));

        let truncated = &text[..text.len() / 2];
        assert!(matches!(ShardArtifact::from_str(truncated), Err(ShardError::Parse(_))));

        let missing = text.replace("\"model\":\"lenet5\",", "");
        match ShardArtifact::from_str(&missing) {
            Err(ShardError::Schema(e)) => assert_eq!(e.field, "model"),
            other => panic!("expected Schema error, got {other:?}"),
        }
    }

    #[test]
    fn merge_detects_conflicts_and_coverage_gaps() {
        let total = 2;
        let s0 = ShardSpec::new(0, 2, ShardStrategy::Range).unwrap();
        let s1 = ShardSpec::new(1, 2, ShardStrategy::Range).unwrap();
        let a0 = artifact(s0, total, vec![(0, point(&[8, 8], 0.9, 100))]);
        let a1 = artifact(s1, total, vec![(1, point(&[8, 4], 0.8, 50))]);

        let m = merge(&[a1.clone(), a0.clone()]).unwrap();
        assert_eq!(m.points.len(), 2);
        assert_eq!(m.points[0].config, cfg(&[8, 8]));
        assert_eq!(m.stats.runs, 6);

        // Coverage gap.
        match merge(&[a0.clone()]) {
            Err(ShardError::Coverage { expected: 2, got: 1, first_missing: Some(1) }) => {}
            other => panic!("expected Coverage, got {other:?}"),
        }

        // Conflict: same index, different accuracy.
        let mut evil = a1.clone();
        evil.spec = ShardSpec::new(1, 2, ShardStrategy::Hash).unwrap();
        evil.points[0].1.accuracy = 0.5;
        match merge(&[a0.clone(), a1.clone(), evil]) {
            Err(ShardError::Conflict { global_index: 1, field: "accuracy", .. }) => {}
            other => panic!("expected Conflict, got {other:?}"),
        }

        // Incompatible sweeps refuse to merge.
        let mut other_model = a1.clone();
        other_model.model = "cifar_cnn".to_string();
        assert!(matches!(
            merge(&[a0, other_model]),
            Err(ShardError::Incompatible { field: "model", .. })
        ));
    }

    #[test]
    fn retried_shard_counts_its_stats_once() {
        // Same shard re-run after a flaky failure: identical identity
        // and points, different pool-stats snapshot (warm caches). The
        // merge must count the slot once, pick the retry
        // deterministically (smallest stats snapshot), and be
        // order-independent about it.
        let s0 = ShardSpec::new(0, 2, ShardStrategy::Range).unwrap();
        let s1 = ShardSpec::new(1, 2, ShardStrategy::Range).unwrap();
        let a0 = artifact(s0, 2, vec![(0, point(&[8, 8], 0.9, 100))]);
        let a1 = artifact(s1, 2, vec![(1, point(&[8, 4], 0.8, 50))]);
        let mut retry = a0.clone();
        retry.stats.mem_reuses = 99;

        let m1 = merge(&[a0.clone(), a1.clone(), retry.clone()]).unwrap();
        let m2 = merge(&[retry.clone(), a1.clone(), a0.clone()]).unwrap();
        assert_eq!(m1.stats, m2.stats);
        assert_eq!(m1.shards, 2);
        // One sweep's worth: a0 (mem_reuses 1, wins over the retry's
        // 99) + a1 (mem_reuses 1).
        assert_eq!(m1.stats.runs, 6);
        assert_eq!(m1.stats.mem_reuses, 2);
        // A retry whose *points* differ is not a retry — it conflicts.
        let mut evil = a0.clone();
        evil.stats.mem_reuses = 99;
        evil.points[0].1.cycles += 1;
        assert!(matches!(
            merge(&[a0, a1, evil]),
            Err(ShardError::Conflict { field: "cycles", .. })
        ));
    }

    #[test]
    fn cores_joins_the_sweep_identity() {
        // Single-core artifacts serialise without the field (byte
        // compatibility with pre-cluster files) and read back as 1.
        let spec = ShardSpec::whole();
        let single = artifact(spec, 1, vec![(0, point(&[8], 0.5, 100))]);
        let text = single.to_json().to_string();
        assert!(!text.contains("\"cores\""));
        assert_eq!(ShardArtifact::from_str(&text).unwrap().cores, 1);

        // A cluster artifact round-trips its core count bit-exactly.
        let mut clustered = single.clone();
        clustered.cores = 4;
        let text4 = clustered.to_json().to_string();
        assert!(text4.contains("\"cores\":4"));
        assert_eq!(ShardArtifact::from_str(&text4).unwrap(), clustered);

        // Shards priced for different cluster geometries refuse to
        // merge: their cycle totals describe different machines.
        let s0 = ShardSpec::new(0, 2, ShardStrategy::Range).unwrap();
        let s1 = ShardSpec::new(1, 2, ShardStrategy::Range).unwrap();
        let a0 = artifact(s0, 2, vec![(0, point(&[8, 8], 0.9, 100))]);
        let mut a1 = artifact(s1, 2, vec![(1, point(&[8, 4], 0.8, 50))]);
        a1.cores = 4;
        match merge(&[a0, a1]) {
            Err(ShardError::Incompatible { field: "cores", a, b }) => {
                assert_eq!((a.as_str(), b.as_str()), ("1", "4"));
            }
            other => panic!("expected Incompatible(cores), got {other:?}"),
        }
    }

    #[test]
    fn seed_round_trips_full_u64_range() {
        let spec = ShardSpec::whole();
        let mut a = artifact(spec, 1, vec![(0, point(&[8], 0.5, 100))]);
        a.seed = u64::MAX;
        let back = ShardArtifact::from_str(&a.to_json().to_string()).unwrap();
        assert_eq!(back.seed, u64::MAX);
        assert_eq!(back, a);
        // A non-numeric seed is a typed schema error.
        let mangled = a.to_json().to_string().replace(&u64::MAX.to_string(), "not-a-seed");
        match ShardArtifact::from_str(&mangled) {
            Err(ShardError::Schema(e)) => assert_eq!(e.field, "seed"),
            other => panic!("expected Schema(seed), got {other:?}"),
        }
    }

    #[test]
    fn merge_is_duplicate_insensitive() {
        let total = 2;
        let s0 = ShardSpec::new(0, 2, ShardStrategy::Range).unwrap();
        let s1 = ShardSpec::new(1, 2, ShardStrategy::Range).unwrap();
        let a0 = artifact(s0, total, vec![(0, point(&[8, 8], 0.9, 100))]);
        let a1 = artifact(s1, total, vec![(1, point(&[8, 4], 0.8, 50))]);
        let once = merge(&[a0.clone(), a1.clone()]).unwrap();
        let twice = merge(&[a1.clone(), a0.clone(), a0.clone(), a1.clone()]).unwrap();
        assert_eq!(once.points, twice.points);
        assert_eq!(once.front, twice.front);
        // Byte-identical duplicates collapse: stats are not double-counted.
        assert_eq!(once.stats, twice.stats);
        assert_eq!(twice.shards, 2);
    }
}
