//! Per-layer cycle model: every (layer, kernel-variant) pair is measured
//! **once** on the cycle-accurate ISS and cached; configuration costs
//! compose from the table. This mirrors the paper's methodology — layer
//! cycle counts are data-independent (the kernels have no data-dependent
//! control flow at all since the requant clamp went branchless), so one
//! Verilator-style measurement per layer/mode suffices exactly.
//!
//! Measurements run on the micro-op engine through the global
//! [`crate::sim::session::SimSession`] (kernel images cached, memories
//! pooled), and [`CycleModel::build`] fans the independent
//! (layer × variant) measurements out over a worker pool — the
//! measurement matrix is embarrassingly parallel.
//!
//! [`measure_layer`] shares the session-level analytic
//! [`CostCache`](crate::sim::session::CostCache) with the analytic
//! execution backend
//! ([`ExecMode::Analytic`](crate::models::sim_exec::ExecMode)): both
//! consult and populate the same `(shape, mode, mac)`-keyed counters,
//! so the per-layer table and whole-model analytic runs can never
//! disagree — and a table built after an analytic sweep (or vice versa)
//! measures nothing twice.

use crate::error::Result;
use crate::isa::MacMode;
use crate::kernels::conv::ConvSpec;
use crate::kernels::dense::DenseSpec;
use crate::kernels::depthwise::DwSpec;
use crate::kernels::run::{
    conv_cost_key, dense_cost_key, depthwise_cost_key, run_conv_staged, run_dense_staged,
    run_depthwise_staged, ExecBackend, StagedWeights,
};
use crate::models::{ModelAnalysis, QKind, QLayerInfo};
use crate::nn::pack::{pack_conv, pack_dense, pack_depthwise};
use crate::nn::quant::Requant;
use crate::rng::Rng;
use crate::sim::cluster::{split_layer, ClusterConfig, ClusterPerf};
use crate::sim::session::{CostKey, SimSession};
use crate::sim::{MacUnitConfig, PerfCounters};
use std::sync::atomic::Ordering;

/// Measured cost of one layer kernel execution.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerCost {
    /// Core cycles.
    pub cycles: u64,
    /// Loads + stores.
    pub mem_accesses: u64,
    /// Retired instructions.
    pub instret: u64,
    /// MACs retired.
    pub macs: u64,
}

impl LayerCost {
    fn from_perf(p: &crate::sim::PerfCounters) -> Self {
        LayerCost {
            cycles: p.cycles,
            mem_accesses: p.mem_accesses(),
            instret: p.instret,
            macs: p.macs,
        }
    }

    /// Elementwise sum.
    pub fn add(&self, o: &LayerCost) -> LayerCost {
        LayerCost {
            cycles: self.cycles + o.cycles,
            mem_accesses: self.mem_accesses + o.mem_accesses,
            instret: self.instret + o.instret,
            macs: self.macs + o.macs,
        }
    }
}

/// The fully-resolved kernel spec a layer/variant measurement runs —
/// derived once and shared between the measurement and its analytic
/// [`CostKey`], so the two can never drift apart.
enum MeasuredSpec {
    Conv(ConvSpec),
    Dw(DwSpec),
    Dense(DenseSpec),
}

fn measured_spec(info: &QLayerInfo, mode: Option<MacMode>) -> MeasuredSpec {
    let rq = Requant::from_real_scale(0.01);
    match info.kind {
        QKind::Conv => {
            // Pre-padded input; channel-pad to 4 for the mode kernels
            // (exactly what `sim_exec` does at model level).
            let cin = if mode.is_some() {
                info.in_shape[2].next_multiple_of(4)
            } else {
                info.in_shape[2]
            };
            let (h, w) = (info.in_shape[0] + 2 * info.pad, info.in_shape[1] + 2 * info.pad);
            MeasuredSpec::Conv(ConvSpec {
                h,
                w,
                cin,
                cout: info.out_shape[2],
                k: info.k,
                stride: info.stride,
                rq,
                relu: info.relu,
            })
        }
        QKind::Depthwise => {
            let (h, w) = (info.in_shape[0] + 2 * info.pad, info.in_shape[1] + 2 * info.pad);
            MeasuredSpec::Dw(DwSpec {
                h,
                w,
                c: info.in_shape[2],
                k: info.k,
                stride: info.stride,
                rq,
                relu: info.relu,
            })
        }
        QKind::Dense => MeasuredSpec::Dense(DenseSpec {
            in_dim: info.in_shape[2],
            out_dim: info.out_shape[2],
            rq,
            relu: info.relu,
            out_i32: info.is_last,
        }),
    }
}

fn spec_cost_key(spec: &MeasuredSpec, mode: Option<MacMode>, mac: MacUnitConfig) -> CostKey {
    match spec {
        MeasuredSpec::Conv(s) => conv_cost_key(s, mode, mac),
        MeasuredSpec::Dw(s) => depthwise_cost_key(s, mode, mac),
        MeasuredSpec::Dense(s) => dense_cost_key(s, mode, mac),
    }
}

/// Run the measurement for real: random operands at the right shapes
/// (timing is value-independent), weights staged once through the
/// `run_*_staged` entry points — no pack-per-call wrapper in the
/// measurement matrix.
fn measure_spec_perf(
    spec: &MeasuredSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    seed: u64,
    backend: ExecBackend,
) -> Result<PerfCounters> {
    let mut rng = Rng::new(seed);
    let bits = mode.map_or(8, |m| m.weight_bits());
    match spec {
        MeasuredSpec::Conv(s) => {
            let acts: Vec<i8> = (0..s.h * s.w * s.cin).map(|_| rng.i8()).collect();
            let wts: Vec<i8> = (0..s.cout * s.k * s.k * s.cin).map(|_| rng.int_bits(bits)).collect();
            let bias: Vec<i32> = (0..s.cout).map(|_| rng.range_i32(-100, 100)).collect();
            let (_, perf) = match mode {
                None => {
                    run_conv_staged(*s, mode, mac, backend, &acts, StagedWeights::Bytes(&wts), &bias)?
                }
                Some(m) => {
                    let words = pack_conv(m, &wts, s.cout, s.k, s.cin);
                    run_conv_staged(
                        *s,
                        mode,
                        mac,
                        backend,
                        &acts,
                        StagedWeights::Words(&words),
                        &bias,
                    )?
                }
            };
            Ok(perf)
        }
        MeasuredSpec::Dw(s) => {
            let acts: Vec<i8> = (0..s.h * s.w * s.c).map(|_| rng.i8()).collect();
            let wts: Vec<i8> = (0..s.c * s.k * s.k).map(|_| rng.int_bits(bits)).collect();
            let bias: Vec<i32> = (0..s.c).map(|_| rng.range_i32(-100, 100)).collect();
            let (_, perf) = match mode {
                None => run_depthwise_staged(
                    *s,
                    mode,
                    mac,
                    backend,
                    &acts,
                    StagedWeights::Bytes(&wts),
                    &bias,
                )?,
                Some(m) => {
                    let words = pack_depthwise(m, &wts, s.c, s.k);
                    run_depthwise_staged(
                        *s,
                        mode,
                        mac,
                        backend,
                        &acts,
                        StagedWeights::Words(&words),
                        &bias,
                    )?
                }
            };
            Ok(perf)
        }
        MeasuredSpec::Dense(s) => {
            let acts: Vec<i8> = (0..s.in_dim).map(|_| rng.i8()).collect();
            let wts: Vec<i8> = (0..s.in_dim * s.out_dim).map(|_| rng.int_bits(bits)).collect();
            let bias: Vec<i32> = (0..s.out_dim).map(|_| rng.range_i32(-100, 100)).collect();
            let (_, _, perf) = match mode {
                None => run_dense_staged(
                    *s,
                    mode,
                    mac,
                    backend,
                    &acts,
                    StagedWeights::Bytes(&wts),
                    &bias,
                )?,
                Some(m) => {
                    let words = pack_dense(m, &wts, s.out_dim, s.in_dim);
                    run_dense_staged(
                        *s,
                        mode,
                        mac,
                        backend,
                        &acts,
                        StagedWeights::Words(&words),
                        &bias,
                    )?
                }
            };
            Ok(perf)
        }
    }
}

/// Measure one layer under a kernel variant on the ISS.
///
/// `mode = None` measures the scalar baseline. Timing is
/// value-independent, so operands are random at the right shapes.
///
/// The measurement goes through the session-level analytic
/// [`CostCache`](crate::sim::session::CostCache): a key already
/// measured — by a previous table build *or* by an analytic-mode plan
/// execution — is served from the cache (counted in
/// `SessionStats::analytic_hits`); a miss runs the micro-op engine and
/// populates it.
pub fn measure_layer(
    info: &QLayerInfo,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    seed: u64,
) -> Result<LayerCost> {
    let spec = measured_spec(info, mode);
    let key = spec_cost_key(&spec, mode, mac);
    let session = SimSession::global();
    if let Some(p) = session.costs.get(&key) {
        session.stats.analytic_hits.fetch_add(1, Ordering::Relaxed);
        return Ok(LayerCost::from_perf(&p));
    }
    let p = measure_spec_perf(&spec, mode, mac, seed, ExecBackend::Engine)?;
    session.costs.insert(key, p);
    Ok(LayerCost::from_perf(&p))
}

/// [`measure_layer`] with an explicit interpreter choice — the
/// throughput bench uses this to report the engine-vs-legacy gap.
/// Always measures for real (never consults the cost cache): the
/// engine-vs-legacy comparisons need two genuine executions.
pub fn measure_layer_backend(
    info: &QLayerInfo,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    seed: u64,
    backend: ExecBackend,
) -> Result<LayerCost> {
    let spec = measured_spec(info, mode);
    Ok(LayerCost::from_perf(&measure_spec_perf(&spec, mode, mac, seed, backend)?))
}

/// Parallel units of a layer — the outermost dependence-free kernel
/// loop the cluster scheduler ([`crate::sim::cluster`]) splits across
/// cores: output channels for conv/dense layers, channels for
/// depthwise (each channel's spatial filter is independent).
pub fn layer_units(info: &QLayerInfo) -> usize {
    match info.kind {
        QKind::Conv | QKind::Dense => info.out_shape[2].max(1),
        QKind::Depthwise => info.in_shape[2].max(1),
    }
}

/// The per-model cycle table: baseline + one entry per mode per layer.
#[derive(Debug, Clone)]
pub struct CycleModel {
    /// Baseline (scalar RV32IM kernel) cost per layer.
    pub baseline: Vec<LayerCost>,
    /// Extended-kernel cost per layer for widths 8 / 4 / 2.
    pub modes: Vec<[LayerCost; 3]>,
    /// Parallel units per layer ([`layer_units`]) — recorded at build
    /// so cluster totals compose from the measured table without
    /// re-touching the model analysis.
    pub units: Vec<usize>,
}

/// Cluster-scheduled total of a configuration
/// ([`CycleModel::cluster_config_total`]).
#[derive(Debug, Clone)]
pub struct ClusterCost {
    /// Composed cost: `cycles` is the cluster critical path (per-layer
    /// barrier sum, contention stalls included);
    /// `mem_accesses`/`instret`/`macs` are the total work, which the
    /// split conserves.
    pub cost: LayerCost,
    /// Per-core busy/stall accounting for the whole run.
    pub perf: ClusterPerf,
}

fn width_index(bits: u32) -> usize {
    match bits {
        8 => 0,
        4 => 1,
        2 => 2,
        _ => panic!("unsupported width {bits}"),
    }
}

/// Worker count for the measurement fan-out.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map_or(4, |n| n.get()).min(8)
}

impl CycleModel {
    /// Measure every layer of a model under all four kernel variants,
    /// fanned out over [`default_workers`] threads.
    pub fn build(analysis: &ModelAnalysis, mac: MacUnitConfig, seed: u64) -> Result<Self> {
        Self::build_with_workers(analysis, mac, seed, default_workers())
    }

    /// [`CycleModel::build`] with an explicit worker count. Seeds are
    /// derived per (layer, variant), so the result is deterministic
    /// regardless of scheduling.
    pub fn build_with_workers(
        analysis: &ModelAnalysis,
        mac: MacUnitConfig,
        seed: u64,
        workers: usize,
    ) -> Result<Self> {
        let n = analysis.layers.len();
        // Job matrix: (layer, variant slot 0..4) — slot 0 is baseline.
        let variants = [None, Some(MacMode::W8), Some(MacMode::W4), Some(MacMode::W2)];
        let measured = crate::par::parallel_map(n * 4, workers, |j| {
            let (li, v) = (j / 4, j % 4);
            let base_seed = seed.wrapping_add(li as u64 * 1313);
            let s_v = if v == 0 { base_seed } else { base_seed ^ v as u64 };
            measure_layer(&analysis.layers[li], variants[v], mac, s_v)
        })?;

        let mut baseline = Vec::with_capacity(n);
        let mut modes = Vec::with_capacity(n);
        for i in 0..n {
            baseline.push(measured[i * 4]);
            modes.push([measured[i * 4 + 1], measured[i * 4 + 2], measured[i * 4 + 3]]);
        }
        let units = analysis.layers.iter().map(layer_units).collect();
        Ok(CycleModel { baseline, modes, units })
    }

    /// Total baseline cost.
    pub fn baseline_total(&self) -> LayerCost {
        self.baseline.iter().fold(LayerCost::default(), |a, b| a.add(b))
    }

    /// Total cost of a mixed-precision configuration.
    pub fn config_total(&self, cfg: &[u32]) -> LayerCost {
        assert_eq!(cfg.len(), self.modes.len());
        cfg.iter()
            .enumerate()
            .map(|(i, &b)| self.modes[i][width_index(b)])
            .fold(LayerCost::default(), |a, b| a.add(&b))
    }

    /// Per-layer cost of a configuration.
    pub fn layer_cost(&self, layer: usize, bits: u32) -> LayerCost {
        self.modes[layer][width_index(bits)]
    }

    /// End-to-end speedup of a configuration over the baseline.
    pub fn speedup(&self, cfg: &[u32]) -> f64 {
        self.baseline_total().cycles as f64 / self.config_total(cfg).cycles as f64
    }

    /// Total cost of a configuration scheduled over an N-core cluster:
    /// every layer's measured single-core cost splits along its
    /// parallel units ([`layer_units`]), each active core is charged
    /// banked-TCDM contention stalls, and layers synchronise at
    /// barriers (see [`crate::sim::cluster`]). On the single-core
    /// cluster the composed `cost` equals [`CycleModel::config_total`]
    /// **exactly** — same integers, no approximation — which is what
    /// keeps `--cores 1` sweep outputs byte-identical.
    pub fn cluster_config_total(&self, cfg: &[u32], cluster: &ClusterConfig) -> ClusterCost {
        assert_eq!(cfg.len(), self.modes.len());
        let mut perf = ClusterPerf::new(*cluster);
        let mut total = LayerCost::default();
        for (i, &b) in cfg.iter().enumerate() {
            let c = self.modes[i][width_index(b)];
            perf.add_layer(&split_layer(c.cycles, c.mem_accesses, self.units[i], cluster));
            total.mem_accesses += c.mem_accesses;
            total.instret += c.instret;
            total.macs += c.macs;
        }
        total.cycles = perf.cycles;
        ClusterCost { cost: total, perf }
    }

    /// [`CycleModel::cluster_config_total`] for the scalar baseline
    /// kernels (the Fig.-8 denominators under cluster scaling).
    pub fn cluster_baseline_total(&self, cluster: &ClusterConfig) -> ClusterCost {
        let mut perf = ClusterPerf::new(*cluster);
        let mut total = LayerCost::default();
        for (i, c) in self.baseline.iter().enumerate() {
            perf.add_layer(&split_layer(c.cycles, c.mem_accesses, self.units[i], cluster));
            total.mem_accesses += c.mem_accesses;
            total.instret += c.instret;
            total.macs += c.macs;
        }
        total.cycles = perf.cycles;
        ClusterCost { cost: total, perf }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{analyze, zoo};

    #[test]
    fn lenet_cycle_model_ordering() {
        let a = analyze(&zoo::lenet5());
        let cm = CycleModel::build(&a, MacUnitConfig::full(), 42).unwrap();
        let n = a.layers.len();
        let base = cm.baseline_total();
        let all8 = cm.config_total(&vec![8; n]);
        let all4 = cm.config_total(&vec![4; n]);
        let all2 = cm.config_total(&vec![2; n]);
        assert!(base.cycles > all8.cycles, "{} vs {}", base.cycles, all8.cycles);
        assert!(all8.cycles > all4.cycles);
        assert!(all4.cycles > all2.cycles);
        // Memory accesses shrink monotonically too (Fig. 4).
        assert!(base.mem_accesses > all8.mem_accesses);
        assert!(all8.mem_accesses > all2.mem_accesses);
        // Mode kernels retire at least the baseline's MACs: packed words
        // are zero-padded at group boundaries and conv channels pad to 4,
        // so the packed lanes over-count (bounded by the padding factor).
        assert!(all2.macs >= base.macs);
        assert!(all2.macs < 4 * base.macs, "{} vs {}", all2.macs, base.macs);
    }

    #[test]
    fn cluster_single_core_total_is_bit_identical() {
        // The cores=1 schedule must be the *same integers* as the flat
        // composition — the invariant behind byte-identical `--cores 1`
        // sweep outputs.
        let a = analyze(&zoo::lenet5());
        let cm = CycleModel::build(&a, MacUnitConfig::full(), 42).unwrap();
        let n = a.layers.len();
        let single = ClusterConfig::single();
        for cfg in [vec![8; n], vec![4; n], vec![2; n]] {
            let flat = cm.config_total(&cfg);
            let clu = cm.cluster_config_total(&cfg, &single);
            assert_eq!(clu.cost.cycles, flat.cycles);
            assert_eq!(clu.cost.mem_accesses, flat.mem_accesses);
            assert_eq!(clu.cost.instret, flat.instret);
            assert_eq!(clu.cost.macs, flat.macs);
            assert_eq!(clu.perf.total_bank_stalls(), 0);
            assert_eq!(clu.perf.utilization(), vec![1.0]);
        }
        let base = cm.baseline_total();
        let cbase = cm.cluster_baseline_total(&single);
        assert_eq!(cbase.cost.cycles, base.cycles);
        assert_eq!(cbase.cost.mem_accesses, base.mem_accesses);
    }

    #[test]
    fn cluster_scaling_shrinks_cycles_and_conserves_work() {
        let a = analyze(&zoo::lenet5());
        let cm = CycleModel::build(&a, MacUnitConfig::full(), 42).unwrap();
        let n = a.layers.len();
        for cfg in [vec![8; n], vec![2; n]] {
            let flat = cm.config_total(&cfg);
            for cores in [2usize, 4, 8] {
                let clu = cm.cluster_config_total(&cfg, &ClusterConfig::new(cores));
                // Cycles never regress vs the single core, even with
                // contention charged.
                assert!(
                    clu.cost.cycles <= flat.cycles,
                    "cores {cores}: {} > {}",
                    clu.cost.cycles,
                    flat.cycles
                );
                // The split conserves work: accesses/instret/macs are
                // totals, not critical-path quantities.
                assert_eq!(clu.cost.mem_accesses, flat.mem_accesses);
                assert_eq!(clu.cost.instret, flat.instret);
                assert_eq!(clu.cost.macs, flat.macs);
                // Contention is being accounted (lenet5 layers have
                // enough channels to keep ≥ 2 cores active).
                assert!(clu.perf.total_bank_stalls() > 0, "cores {cores}");
                let u = clu.perf.utilization();
                assert_eq!(u.len(), cores);
                assert!(u.iter().all(|&x| (0.0..=1.0 + 1e-12).contains(&x)));
                assert!(u[0] > 0.0, "core 0 always owns the largest share");
            }
        }
    }

    #[test]
    fn measurement_is_deterministic() {
        let a = analyze(&zoo::lenet5());
        let c1 = measure_layer(&a.layers[1], Some(MacMode::W4), MacUnitConfig::full(), 7).unwrap();
        let c2 = measure_layer(&a.layers[1], Some(MacMode::W4), MacUnitConfig::full(), 7).unwrap();
        assert_eq!(c1.cycles, c2.cycles);
        assert_eq!(c1.mem_accesses, c2.mem_accesses);
    }

    #[test]
    fn parallel_build_matches_serial_build() {
        let a = analyze(&zoo::lenet5());
        let p = CycleModel::build_with_workers(&a, MacUnitConfig::full(), 42, 4).unwrap();
        let s = CycleModel::build_with_workers(&a, MacUnitConfig::full(), 42, 1).unwrap();
        for i in 0..a.layers.len() {
            assert_eq!(p.baseline[i].cycles, s.baseline[i].cycles, "layer {i}");
            for v in 0..3 {
                assert_eq!(p.modes[i][v].cycles, s.modes[i][v].cycles, "layer {i} mode {v}");
                assert_eq!(p.modes[i][v].macs, s.modes[i][v].macs, "layer {i} mode {v}");
            }
        }
    }

    #[test]
    fn engine_and_legacy_measurements_agree() {
        let a = analyze(&zoo::lenet5());
        for mode in [None, Some(MacMode::W8), Some(MacMode::W2)] {
            let e = measure_layer_backend(
                &a.layers[1], mode, MacUnitConfig::full(), 7, ExecBackend::Engine,
            )
            .unwrap();
            let l = measure_layer_backend(
                &a.layers[1], mode, MacUnitConfig::full(), 7, ExecBackend::Legacy,
            )
            .unwrap();
            assert_eq!(e.cycles, l.cycles, "{mode:?}");
            assert_eq!(e.mem_accesses, l.mem_accesses, "{mode:?}");
            assert_eq!(e.instret, l.instret, "{mode:?}");
            assert_eq!(e.macs, l.macs, "{mode:?}");
        }
    }
}
