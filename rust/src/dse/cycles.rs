//! Per-layer cycle model: every (layer, kernel-variant) pair is measured
//! **once** on the cycle-accurate ISS and cached; configuration costs
//! compose from the table. This mirrors the paper's methodology — layer
//! cycle counts are data-independent (the kernels have no data-dependent
//! control flow except the requant clamps, a ±2-cycle effect), so one
//! Verilator-style measurement per layer/mode suffices.

use crate::isa::MacMode;
use crate::kernels::conv::ConvSpec;
use crate::kernels::dense::DenseSpec;
use crate::kernels::depthwise::DwSpec;
use crate::kernels::run::{run_conv_with, run_dense_with, run_depthwise_with};
use crate::models::{ModelAnalysis, QKind, QLayerInfo};
use crate::nn::quant::Requant;
use crate::rng::Rng;
use crate::sim::MacUnitConfig;

/// Measured cost of one layer kernel execution.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerCost {
    /// Core cycles.
    pub cycles: u64,
    /// Loads + stores.
    pub mem_accesses: u64,
    /// Retired instructions.
    pub instret: u64,
    /// MACs retired.
    pub macs: u64,
}

impl LayerCost {
    fn from_perf(p: &crate::sim::PerfCounters) -> Self {
        LayerCost {
            cycles: p.cycles,
            mem_accesses: p.mem_accesses(),
            instret: p.instret,
            macs: p.macs,
        }
    }

    /// Elementwise sum.
    pub fn add(&self, o: &LayerCost) -> LayerCost {
        LayerCost {
            cycles: self.cycles + o.cycles,
            mem_accesses: self.mem_accesses + o.mem_accesses,
            instret: self.instret + o.instret,
            macs: self.macs + o.macs,
        }
    }
}

/// Measure one layer under a kernel variant on the ISS.
///
/// `mode = None` measures the scalar baseline. Timing is
/// value-independent, so operands are random at the right shapes.
pub fn measure_layer(
    info: &QLayerInfo,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    seed: u64,
) -> LayerCost {
    let mut rng = Rng::new(seed);
    let bits = mode.map_or(8, |m| m.weight_bits());
    let rq = Requant::from_real_scale(0.01);
    match info.kind {
        QKind::Conv => {
            // Pre-padded input; channel-pad to 4 for the mode kernels
            // (exactly what `sim_exec` does at model level).
            let cin = if mode.is_some() {
                info.in_shape[2].next_multiple_of(4)
            } else {
                info.in_shape[2]
            };
            let (h, w) = (info.in_shape[0] + 2 * info.pad, info.in_shape[1] + 2 * info.pad);
            let cout = info.out_shape[2];
            let spec = ConvSpec { h, w, cin, cout, k: info.k, stride: info.stride, rq, relu: info.relu };
            let acts: Vec<i8> = (0..h * w * cin).map(|_| rng.i8()).collect();
            let wts: Vec<i8> =
                (0..cout * info.k * info.k * cin).map(|_| rng.int_bits(bits)).collect();
            let bias: Vec<i32> = (0..cout).map(|_| rng.range_i32(-100, 100)).collect();
            let (_, perf) = run_conv_with(spec, mode, mac, &acts, &wts, &bias);
            LayerCost::from_perf(&perf)
        }
        QKind::Depthwise => {
            let c = info.in_shape[2];
            let (h, w) = (info.in_shape[0] + 2 * info.pad, info.in_shape[1] + 2 * info.pad);
            let spec = DwSpec { h, w, c, k: info.k, stride: info.stride, rq, relu: info.relu };
            let acts: Vec<i8> = (0..h * w * c).map(|_| rng.i8()).collect();
            let wts: Vec<i8> = (0..c * info.k * info.k).map(|_| rng.int_bits(bits)).collect();
            let bias: Vec<i32> = (0..c).map(|_| rng.range_i32(-100, 100)).collect();
            let (_, perf) = run_depthwise_with(spec, mode, mac, &acts, &wts, &bias);
            LayerCost::from_perf(&perf)
        }
        QKind::Dense => {
            let (i, o) = (info.in_shape[2], info.out_shape[2]);
            let spec = DenseSpec { in_dim: i, out_dim: o, rq, relu: info.relu, out_i32: info.is_last };
            let acts: Vec<i8> = (0..i).map(|_| rng.i8()).collect();
            let wts: Vec<i8> = (0..i * o).map(|_| rng.int_bits(bits)).collect();
            let bias: Vec<i32> = (0..o).map(|_| rng.range_i32(-100, 100)).collect();
            let (_, _, perf) = run_dense_with(spec, mode, mac, &acts, &wts, &bias);
            LayerCost::from_perf(&perf)
        }
    }
}

/// The per-model cycle table: baseline + one entry per mode per layer.
#[derive(Debug, Clone)]
pub struct CycleModel {
    /// Baseline (scalar RV32IM kernel) cost per layer.
    pub baseline: Vec<LayerCost>,
    /// Extended-kernel cost per layer for widths 8 / 4 / 2.
    pub modes: Vec<[LayerCost; 3]>,
}

fn width_index(bits: u32) -> usize {
    match bits {
        8 => 0,
        4 => 1,
        2 => 2,
        _ => panic!("unsupported width {bits}"),
    }
}

impl CycleModel {
    /// Measure every layer of a model under all four kernel variants.
    pub fn build(analysis: &ModelAnalysis, mac: MacUnitConfig, seed: u64) -> Self {
        let mut baseline = Vec::with_capacity(analysis.layers.len());
        let mut modes = Vec::with_capacity(analysis.layers.len());
        for (i, info) in analysis.layers.iter().enumerate() {
            let s = seed.wrapping_add(i as u64 * 1313);
            baseline.push(measure_layer(info, None, mac, s));
            modes.push([
                measure_layer(info, Some(MacMode::W8), mac, s ^ 1),
                measure_layer(info, Some(MacMode::W4), mac, s ^ 2),
                measure_layer(info, Some(MacMode::W2), mac, s ^ 3),
            ]);
        }
        CycleModel { baseline, modes }
    }

    /// Total baseline cost.
    pub fn baseline_total(&self) -> LayerCost {
        self.baseline.iter().fold(LayerCost::default(), |a, b| a.add(b))
    }

    /// Total cost of a mixed-precision configuration.
    pub fn config_total(&self, cfg: &[u32]) -> LayerCost {
        assert_eq!(cfg.len(), self.modes.len());
        cfg.iter()
            .enumerate()
            .map(|(i, &b)| self.modes[i][width_index(b)])
            .fold(LayerCost::default(), |a, b| a.add(&b))
    }

    /// Per-layer cost of a configuration.
    pub fn layer_cost(&self, layer: usize, bits: u32) -> LayerCost {
        self.modes[layer][width_index(bits)]
    }

    /// End-to-end speedup of a configuration over the baseline.
    pub fn speedup(&self, cfg: &[u32]) -> f64 {
        self.baseline_total().cycles as f64 / self.config_total(cfg).cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{analyze, zoo};

    #[test]
    fn lenet_cycle_model_ordering() {
        let a = analyze(&zoo::lenet5());
        let cm = CycleModel::build(&a, MacUnitConfig::full(), 42);
        let n = a.layers.len();
        let base = cm.baseline_total();
        let all8 = cm.config_total(&vec![8; n]);
        let all4 = cm.config_total(&vec![4; n]);
        let all2 = cm.config_total(&vec![2; n]);
        assert!(base.cycles > all8.cycles, "{} vs {}", base.cycles, all8.cycles);
        assert!(all8.cycles > all4.cycles);
        assert!(all4.cycles > all2.cycles);
        // Memory accesses shrink monotonically too (Fig. 4).
        assert!(base.mem_accesses > all8.mem_accesses);
        assert!(all8.mem_accesses > all2.mem_accesses);
        // Mode kernels retire at least the baseline's MACs: packed words
        // are zero-padded at group boundaries and conv channels pad to 4,
        // so the packed lanes over-count (bounded by the padding factor).
        assert!(all2.macs >= base.macs);
        assert!(all2.macs < 4 * base.macs, "{} vs {}", all2.macs, base.macs);
    }

    #[test]
    fn measurement_is_deterministic() {
        let a = analyze(&zoo::lenet5());
        let c1 = measure_layer(&a.layers[1], Some(MacMode::W4), MacUnitConfig::full(), 7);
        let c2 = measure_layer(&a.layers[1], Some(MacMode::W4), MacUnitConfig::full(), 7);
        assert_eq!(c1.cycles, c2.cycles);
        assert_eq!(c1.mem_accesses, c2.mem_accesses);
    }
}
