//! Per-layer cycle model: every (layer, kernel-variant) pair is measured
//! **once** on the cycle-accurate ISS and cached; configuration costs
//! compose from the table. This mirrors the paper's methodology — layer
//! cycle counts are data-independent (the kernels have no data-dependent
//! control flow at all since the requant clamp went branchless), so one
//! Verilator-style measurement per layer/mode suffices exactly.
//!
//! Measurements run on the micro-op engine through the global
//! [`crate::sim::session::SimSession`] (kernel images cached, memories
//! pooled), and [`CycleModel::build`] fans the independent
//! (layer × variant) measurements out over a worker pool — the
//! measurement matrix is embarrassingly parallel.

use crate::error::Result;
use crate::isa::MacMode;
use crate::kernels::conv::ConvSpec;
use crate::kernels::dense::DenseSpec;
use crate::kernels::depthwise::DwSpec;
use crate::kernels::run::{run_conv_backend, run_dense_backend, run_depthwise_backend, ExecBackend};
use crate::models::{ModelAnalysis, QKind, QLayerInfo};
use crate::nn::quant::Requant;
use crate::rng::Rng;
use crate::sim::MacUnitConfig;

/// Measured cost of one layer kernel execution.
#[derive(Debug, Clone, Copy, Default)]
pub struct LayerCost {
    /// Core cycles.
    pub cycles: u64,
    /// Loads + stores.
    pub mem_accesses: u64,
    /// Retired instructions.
    pub instret: u64,
    /// MACs retired.
    pub macs: u64,
}

impl LayerCost {
    fn from_perf(p: &crate::sim::PerfCounters) -> Self {
        LayerCost {
            cycles: p.cycles,
            mem_accesses: p.mem_accesses(),
            instret: p.instret,
            macs: p.macs,
        }
    }

    /// Elementwise sum.
    pub fn add(&self, o: &LayerCost) -> LayerCost {
        LayerCost {
            cycles: self.cycles + o.cycles,
            mem_accesses: self.mem_accesses + o.mem_accesses,
            instret: self.instret + o.instret,
            macs: self.macs + o.macs,
        }
    }
}

/// Measure one layer under a kernel variant on the ISS.
///
/// `mode = None` measures the scalar baseline. Timing is
/// value-independent, so operands are random at the right shapes.
pub fn measure_layer(
    info: &QLayerInfo,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    seed: u64,
) -> Result<LayerCost> {
    measure_layer_backend(info, mode, mac, seed, ExecBackend::Engine)
}

/// [`measure_layer`] with an explicit interpreter choice — the
/// throughput bench uses this to report the engine-vs-legacy gap.
pub fn measure_layer_backend(
    info: &QLayerInfo,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    seed: u64,
    backend: ExecBackend,
) -> Result<LayerCost> {
    let mut rng = Rng::new(seed);
    let bits = mode.map_or(8, |m| m.weight_bits());
    let rq = Requant::from_real_scale(0.01);
    match info.kind {
        QKind::Conv => {
            // Pre-padded input; channel-pad to 4 for the mode kernels
            // (exactly what `sim_exec` does at model level).
            let cin = if mode.is_some() {
                info.in_shape[2].next_multiple_of(4)
            } else {
                info.in_shape[2]
            };
            let (h, w) = (info.in_shape[0] + 2 * info.pad, info.in_shape[1] + 2 * info.pad);
            let cout = info.out_shape[2];
            let spec =
                ConvSpec { h, w, cin, cout, k: info.k, stride: info.stride, rq, relu: info.relu };
            let acts: Vec<i8> = (0..h * w * cin).map(|_| rng.i8()).collect();
            let wts: Vec<i8> =
                (0..cout * info.k * info.k * cin).map(|_| rng.int_bits(bits)).collect();
            let bias: Vec<i32> = (0..cout).map(|_| rng.range_i32(-100, 100)).collect();
            let (_, perf) = run_conv_backend(spec, mode, mac, backend, &acts, &wts, &bias)?;
            Ok(LayerCost::from_perf(&perf))
        }
        QKind::Depthwise => {
            let c = info.in_shape[2];
            let (h, w) = (info.in_shape[0] + 2 * info.pad, info.in_shape[1] + 2 * info.pad);
            let spec = DwSpec { h, w, c, k: info.k, stride: info.stride, rq, relu: info.relu };
            let acts: Vec<i8> = (0..h * w * c).map(|_| rng.i8()).collect();
            let wts: Vec<i8> = (0..c * info.k * info.k).map(|_| rng.int_bits(bits)).collect();
            let bias: Vec<i32> = (0..c).map(|_| rng.range_i32(-100, 100)).collect();
            let (_, perf) = run_depthwise_backend(spec, mode, mac, backend, &acts, &wts, &bias)?;
            Ok(LayerCost::from_perf(&perf))
        }
        QKind::Dense => {
            let (i, o) = (info.in_shape[2], info.out_shape[2]);
            let spec =
                DenseSpec { in_dim: i, out_dim: o, rq, relu: info.relu, out_i32: info.is_last };
            let acts: Vec<i8> = (0..i).map(|_| rng.i8()).collect();
            let wts: Vec<i8> = (0..i * o).map(|_| rng.int_bits(bits)).collect();
            let bias: Vec<i32> = (0..o).map(|_| rng.range_i32(-100, 100)).collect();
            let (_, _, perf) = run_dense_backend(spec, mode, mac, backend, &acts, &wts, &bias)?;
            Ok(LayerCost::from_perf(&perf))
        }
    }
}

/// The per-model cycle table: baseline + one entry per mode per layer.
#[derive(Debug, Clone)]
pub struct CycleModel {
    /// Baseline (scalar RV32IM kernel) cost per layer.
    pub baseline: Vec<LayerCost>,
    /// Extended-kernel cost per layer for widths 8 / 4 / 2.
    pub modes: Vec<[LayerCost; 3]>,
}

fn width_index(bits: u32) -> usize {
    match bits {
        8 => 0,
        4 => 1,
        2 => 2,
        _ => panic!("unsupported width {bits}"),
    }
}

/// Worker count for the measurement fan-out.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map_or(4, |n| n.get()).min(8)
}

impl CycleModel {
    /// Measure every layer of a model under all four kernel variants,
    /// fanned out over [`default_workers`] threads.
    pub fn build(analysis: &ModelAnalysis, mac: MacUnitConfig, seed: u64) -> Result<Self> {
        Self::build_with_workers(analysis, mac, seed, default_workers())
    }

    /// [`CycleModel::build`] with an explicit worker count. Seeds are
    /// derived per (layer, variant), so the result is deterministic
    /// regardless of scheduling.
    pub fn build_with_workers(
        analysis: &ModelAnalysis,
        mac: MacUnitConfig,
        seed: u64,
        workers: usize,
    ) -> Result<Self> {
        let n = analysis.layers.len();
        // Job matrix: (layer, variant slot 0..4) — slot 0 is baseline.
        let variants = [None, Some(MacMode::W8), Some(MacMode::W4), Some(MacMode::W2)];
        let measured = crate::par::parallel_map(n * 4, workers, |j| {
            let (li, v) = (j / 4, j % 4);
            let base_seed = seed.wrapping_add(li as u64 * 1313);
            let s_v = if v == 0 { base_seed } else { base_seed ^ v as u64 };
            measure_layer(&analysis.layers[li], variants[v], mac, s_v)
        })?;

        let mut baseline = Vec::with_capacity(n);
        let mut modes = Vec::with_capacity(n);
        for i in 0..n {
            baseline.push(measured[i * 4]);
            modes.push([measured[i * 4 + 1], measured[i * 4 + 2], measured[i * 4 + 3]]);
        }
        Ok(CycleModel { baseline, modes })
    }

    /// Total baseline cost.
    pub fn baseline_total(&self) -> LayerCost {
        self.baseline.iter().fold(LayerCost::default(), |a, b| a.add(b))
    }

    /// Total cost of a mixed-precision configuration.
    pub fn config_total(&self, cfg: &[u32]) -> LayerCost {
        assert_eq!(cfg.len(), self.modes.len());
        cfg.iter()
            .enumerate()
            .map(|(i, &b)| self.modes[i][width_index(b)])
            .fold(LayerCost::default(), |a, b| a.add(&b))
    }

    /// Per-layer cost of a configuration.
    pub fn layer_cost(&self, layer: usize, bits: u32) -> LayerCost {
        self.modes[layer][width_index(bits)]
    }

    /// End-to-end speedup of a configuration over the baseline.
    pub fn speedup(&self, cfg: &[u32]) -> f64 {
        self.baseline_total().cycles as f64 / self.config_total(cfg).cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{analyze, zoo};

    #[test]
    fn lenet_cycle_model_ordering() {
        let a = analyze(&zoo::lenet5());
        let cm = CycleModel::build(&a, MacUnitConfig::full(), 42).unwrap();
        let n = a.layers.len();
        let base = cm.baseline_total();
        let all8 = cm.config_total(&vec![8; n]);
        let all4 = cm.config_total(&vec![4; n]);
        let all2 = cm.config_total(&vec![2; n]);
        assert!(base.cycles > all8.cycles, "{} vs {}", base.cycles, all8.cycles);
        assert!(all8.cycles > all4.cycles);
        assert!(all4.cycles > all2.cycles);
        // Memory accesses shrink monotonically too (Fig. 4).
        assert!(base.mem_accesses > all8.mem_accesses);
        assert!(all8.mem_accesses > all2.mem_accesses);
        // Mode kernels retire at least the baseline's MACs: packed words
        // are zero-padded at group boundaries and conv channels pad to 4,
        // so the packed lanes over-count (bounded by the padding factor).
        assert!(all2.macs >= base.macs);
        assert!(all2.macs < 4 * base.macs, "{} vs {}", all2.macs, base.macs);
    }

    #[test]
    fn measurement_is_deterministic() {
        let a = analyze(&zoo::lenet5());
        let c1 = measure_layer(&a.layers[1], Some(MacMode::W4), MacUnitConfig::full(), 7).unwrap();
        let c2 = measure_layer(&a.layers[1], Some(MacMode::W4), MacUnitConfig::full(), 7).unwrap();
        assert_eq!(c1.cycles, c2.cycles);
        assert_eq!(c1.mem_accesses, c2.mem_accesses);
    }

    #[test]
    fn parallel_build_matches_serial_build() {
        let a = analyze(&zoo::lenet5());
        let p = CycleModel::build_with_workers(&a, MacUnitConfig::full(), 42, 4).unwrap();
        let s = CycleModel::build_with_workers(&a, MacUnitConfig::full(), 42, 1).unwrap();
        for i in 0..a.layers.len() {
            assert_eq!(p.baseline[i].cycles, s.baseline[i].cycles, "layer {i}");
            for v in 0..3 {
                assert_eq!(p.modes[i][v].cycles, s.modes[i][v].cycles, "layer {i} mode {v}");
                assert_eq!(p.modes[i][v].macs, s.modes[i][v].macs, "layer {i} mode {v}");
            }
        }
    }

    #[test]
    fn engine_and_legacy_measurements_agree() {
        let a = analyze(&zoo::lenet5());
        for mode in [None, Some(MacMode::W8), Some(MacMode::W2)] {
            let e = measure_layer_backend(
                &a.layers[1], mode, MacUnitConfig::full(), 7, ExecBackend::Engine,
            )
            .unwrap();
            let l = measure_layer_backend(
                &a.layers[1], mode, MacUnitConfig::full(), 7, ExecBackend::Legacy,
            )
            .unwrap();
            assert_eq!(e.cycles, l.cycles, "{mode:?}");
            assert_eq!(e.mem_accesses, l.mem_accesses, "{mode:?}");
            assert_eq!(e.instret, l.instret, "{mode:?}");
            assert_eq!(e.macs, l.macs, "{mode:?}");
        }
    }
}
