//! Deterministic SplitMix64 PRNG — the repo's single randomness source.
//!
//! Every experiment harness seeds one of these explicitly so all tables
//! and figures are exactly reproducible run-to-run (no ambient entropy).

/// SplitMix64 state.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from an explicit seed.
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Next 32-bit value.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        lo + (self.below((hi - lo) as u64 + 1) as i32)
    }

    /// Uniform float in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = (self.f32() + f32::EPSILON).min(1.0);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// A random int8 in the full range.
    pub fn i8(&mut self) -> i8 {
        self.next_u32() as i8
    }

    /// A random signed value representable in `bits` bits.
    pub fn int_bits(&mut self, bits: u32) -> i8 {
        let (lo, hi) = crate::isa::custom::weight_range(bits);
        self.range_i32(lo, hi) as i8
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Deterministic strided selection over `0..n`: every `every`-th index
/// starting from an FNV-1a-seeded phase in `[0, every)`. `every == 0`
/// or `n == 0` selects nothing; `every == 1` selects everything.
///
/// This is the one place the seeded-phase stride logic lives. The
/// analytic audit sampler (`models::sim_exec::audit_indices`) and the
/// guided-search rung promotion tie-break (`dse::search`) both delegate
/// here, so the two stay phase-compatible by construction.
pub fn seeded_stride(seed: u64, n: usize, every: usize) -> Vec<usize> {
    if every == 0 || n == 0 {
        return Vec::new();
    }
    // FNV-1a over the seed bytes → phase in [0, every).
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in seed.to_le_bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(0x0000_0100_0000_01b3);
    }
    let phase = (h % every as u64) as usize;
    (phase..n).step_by(every).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments_plausible() {
        let mut r = Rng::new(123);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    /// Pin the exact seeded-stride sequences the analytic audit has
    /// shipped with since the fast path landed: `audit_indices` now
    /// delegates here, and these hardcoded expectations keep the
    /// refactor from shifting any audit phase.
    #[test]
    fn seeded_stride_pins_audit_sequences() {
        // (seed, every) → selection over n = 16.
        let cases: [(u64, usize, &[usize]); 6] = [
            (0, 3, &[1, 4, 7, 10, 13]),
            (0, 7, &[5, 12]),
            (9, 7, &[3, 10]),
            (0xD5E, 7, &[1, 8, 15]),
            (77, 3, &[0, 3, 6, 9, 12, 15]),
            (77, 7, &[5, 12]),
        ];
        for (seed, every, want) in cases {
            assert_eq!(
                seeded_stride(seed, 16, every),
                want,
                "seed {seed} every {every}: audit phase shifted"
            );
        }
    }

    #[test]
    fn seeded_stride_degenerate_cases() {
        for seed in [0u64, 1, 99, u64::MAX] {
            // every == 1 selects the whole range regardless of phase.
            assert_eq!(seeded_stride(seed, 16, 1), (0..16).collect::<Vec<_>>());
            assert!(seeded_stride(seed, 16, 0).is_empty());
            assert!(seeded_stride(seed, 0, 3).is_empty());
        }
    }
}
