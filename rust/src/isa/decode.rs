//! RV32IM (+ custom) instruction decoder — the exact inverse of
//! [`super::encode`], property-tested for round-trip equality.

use super::*;

/// Decode error: the word is not a recognised RV32IM / extension encoding.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeError {
    /// The offending machine word.
    pub word: u32,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "illegal instruction: {:#010x}", self.word)
    }
}

impl std::error::Error for DecodeError {}

#[inline]
fn rd(w: u32) -> Reg {
    ((w >> 7) & 31) as Reg
}
#[inline]
fn rs1(w: u32) -> Reg {
    ((w >> 15) & 31) as Reg
}
#[inline]
fn rs2(w: u32) -> Reg {
    ((w >> 20) & 31) as Reg
}
#[inline]
fn f3(w: u32) -> u32 {
    (w >> 12) & 7
}
#[inline]
fn f7(w: u32) -> u32 {
    w >> 25
}

/// Sign-extended I-type immediate.
#[inline]
fn imm_i(w: u32) -> i32 {
    (w as i32) >> 20
}

/// Sign-extended S-type immediate.
#[inline]
fn imm_s(w: u32) -> i32 {
    (((w as i32) >> 25) << 5) | (((w >> 7) & 0x1f) as i32)
}

/// Sign-extended B-type branch offset.
#[inline]
fn imm_b(w: u32) -> i32 {
    let sign = (w as i32) >> 31; // bit 31 -> offset bit 12, sign
    ((sign << 12)
        | ((((w >> 7) & 1) as i32) << 11)
        | ((((w >> 25) & 0x3f) as i32) << 5)
        | ((((w >> 8) & 0xf) as i32) << 1)) as i32
}

/// U-type immediate (pre-shifted, low 12 bits zero).
#[inline]
fn imm_u(w: u32) -> i32 {
    (w & 0xfffff000) as i32
}

/// Sign-extended J-type jump offset.
#[inline]
fn imm_j(w: u32) -> i32 {
    let sign = (w as i32) >> 31; // bit 31 -> offset bit 20, sign
    (sign << 20)
        | ((((w >> 12) & 0xff) as i32) << 12)
        | ((((w >> 20) & 1) as i32) << 11)
        | ((((w >> 21) & 0x3ff) as i32) << 1)
}

/// Decode one 32-bit machine word.
pub fn decode(w: u32) -> Result<Instr, DecodeError> {
    let err = Err(DecodeError { word: w });
    Ok(match w & 0x7f {
        opcodes::LUI => Instr::Lui { rd: rd(w), imm: imm_u(w) },
        opcodes::AUIPC => Instr::Auipc { rd: rd(w), imm: imm_u(w) },
        opcodes::JAL => Instr::Jal { rd: rd(w), offset: imm_j(w) },
        opcodes::JALR => {
            if f3(w) != 0 {
                return err;
            }
            Instr::Jalr { rd: rd(w), rs1: rs1(w), offset: imm_i(w) }
        }
        opcodes::BRANCH => {
            let op = match f3(w) {
                0b000 => BranchOp::Beq,
                0b001 => BranchOp::Bne,
                0b100 => BranchOp::Blt,
                0b101 => BranchOp::Bge,
                0b110 => BranchOp::Bltu,
                0b111 => BranchOp::Bgeu,
                _ => return err,
            };
            Instr::Branch { op, rs1: rs1(w), rs2: rs2(w), offset: imm_b(w) }
        }
        opcodes::LOAD => {
            let op = match f3(w) {
                0b000 => LoadOp::Lb,
                0b001 => LoadOp::Lh,
                0b010 => LoadOp::Lw,
                0b100 => LoadOp::Lbu,
                0b101 => LoadOp::Lhu,
                _ => return err,
            };
            Instr::Load { op, rd: rd(w), rs1: rs1(w), offset: imm_i(w) }
        }
        opcodes::STORE => {
            let op = match f3(w) {
                0b000 => StoreOp::Sb,
                0b001 => StoreOp::Sh,
                0b010 => StoreOp::Sw,
                _ => return err,
            };
            Instr::Store { op, rs1: rs1(w), rs2: rs2(w), offset: imm_s(w) }
        }
        opcodes::OP_IMM => {
            let op = match f3(w) {
                0b000 => AluOp::Add,
                0b001 => {
                    if f7(w) != 0 {
                        return err;
                    }
                    return Ok(Instr::OpImm {
                        op: AluOp::Sll,
                        rd: rd(w),
                        rs1: rs1(w),
                        imm: rs2(w) as i32,
                    });
                }
                0b010 => AluOp::Slt,
                0b011 => AluOp::Sltu,
                0b100 => AluOp::Xor,
                0b101 => {
                    let op = match f7(w) {
                        0b0000000 => AluOp::Srl,
                        0b0100000 => AluOp::Sra,
                        _ => return err,
                    };
                    return Ok(Instr::OpImm { op, rd: rd(w), rs1: rs1(w), imm: rs2(w) as i32 });
                }
                0b110 => AluOp::Or,
                0b111 => AluOp::And,
                _ => unreachable!(),
            };
            Instr::OpImm { op, rd: rd(w), rs1: rs1(w), imm: imm_i(w) }
        }
        opcodes::OP => match f7(w) {
            0b0000001 => {
                let op = match f3(w) {
                    0b000 => MulOp::Mul,
                    0b001 => MulOp::Mulh,
                    0b010 => MulOp::Mulhsu,
                    0b011 => MulOp::Mulhu,
                    0b100 => MulOp::Div,
                    0b101 => MulOp::Divu,
                    0b110 => MulOp::Rem,
                    0b111 => MulOp::Remu,
                    _ => unreachable!(),
                };
                Instr::MulDiv { op, rd: rd(w), rs1: rs1(w), rs2: rs2(w) }
            }
            0b0000000 => {
                let op = match f3(w) {
                    0b000 => AluOp::Add,
                    0b001 => AluOp::Sll,
                    0b010 => AluOp::Slt,
                    0b011 => AluOp::Sltu,
                    0b100 => AluOp::Xor,
                    0b101 => AluOp::Srl,
                    0b110 => AluOp::Or,
                    0b111 => AluOp::And,
                    _ => unreachable!(),
                };
                Instr::Op { op, rd: rd(w), rs1: rs1(w), rs2: rs2(w) }
            }
            0b0100000 => {
                let op = match f3(w) {
                    0b000 => AluOp::Sub,
                    0b101 => AluOp::Sra,
                    _ => return err,
                };
                Instr::Op { op, rd: rd(w), rs1: rs1(w), rs2: rs2(w) }
            }
            _ => return err,
        },
        opcodes::CUSTOM0 => {
            // The paper's mixed-precision extension: func3=010, one-hot func7.
            if f3(w) != 0b010 {
                return err;
            }
            match MacMode::from_func7(f7(w)) {
                Some(mode) => Instr::NnMac { mode, rd: rd(w), rs1: rs1(w), rs2: rs2(w) },
                None => return err,
            }
        }
        opcodes::MISC_MEM => Instr::Fence,
        opcodes::SYSTEM => match f3(w) {
            0b000 => match w >> 20 {
                0 => Instr::Ecall,
                1 => Instr::Ebreak,
                _ => return err,
            },
            0b001 => Instr::Csr { op: CsrOp::Rw, rd: rd(w), rs1: rs1(w), csr: (w >> 20) as u16 },
            0b010 => Instr::Csr { op: CsrOp::Rs, rd: rd(w), rs1: rs1(w), csr: (w >> 20) as u16 },
            0b011 => Instr::Csr { op: CsrOp::Rc, rd: rd(w), rs1: rs1(w), csr: (w >> 20) as u16 },
            _ => return err,
        },
        _ => return err,
    })
}

#[cfg(test)]
mod tests {
    use super::super::encode::encode;
    use super::*;

    #[test]
    fn round_trips_hand_picked() {
        let cases = [
            Instr::Lui { rd: 5, imm: 0x7ffff << 12 },
            Instr::Auipc { rd: 1, imm: -4096 },
            Instr::Jal { rd: 1, offset: -2048 },
            Instr::Jalr { rd: 0, rs1: 1, offset: 0 },
            Instr::Branch { op: BranchOp::Bge, rs1: 10, rs2: 11, offset: 4094 },
            Instr::Branch { op: BranchOp::Bltu, rs1: 10, rs2: 11, offset: -4096 },
            Instr::Load { op: LoadOp::Lbu, rd: 12, rs1: 13, offset: -1 },
            Instr::Store { op: StoreOp::Sb, rs1: 2, rs2: 3, offset: -2048 },
            Instr::OpImm { op: AluOp::Sra, rd: 4, rs1: 5, imm: 31 },
            Instr::OpImm { op: AluOp::Add, rd: 4, rs1: 5, imm: -2048 },
            Instr::Op { op: AluOp::Sub, rd: 6, rs1: 7, rs2: 8 },
            Instr::MulDiv { op: MulOp::Mulhsu, rd: 9, rs1: 10, rs2: 11 },
            Instr::NnMac { mode: MacMode::W8, rd: 10, rs1: 11, rs2: 12 },
            Instr::NnMac { mode: MacMode::W4, rd: 10, rs1: 12, rs2: 14 },
            Instr::NnMac { mode: MacMode::W2, rd: 10, rs1: 16, rs2: 20 },
            Instr::Csr { op: CsrOp::Rs, rd: 10, rs1: 0, csr: csr::MCYCLE },
            Instr::Ecall,
            Instr::Ebreak,
            Instr::Fence,
        ];
        for c in cases {
            assert_eq!(decode(encode(c)).unwrap(), c, "round-trip failed for {c:?}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(decode(0xffff_ffff).is_err());
        assert!(decode(0x0000_0000).is_err());
        // custom-0 with wrong func3
        assert!(decode(0x0000_000b).is_err());
        // custom-0 with non-one-hot func7
        let bad = (0b1111111 << 25) | (0b010 << 12) | opcodes::CUSTOM0;
        assert!(decode(bad).is_err());
    }
}
