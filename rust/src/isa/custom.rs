//! Packed-operand semantics of the mixed-precision extension.
//!
//! This module is the single source of truth for *what the bits mean*:
//! activation/weight packing layouts, sign-extension rules and the scalar
//! reference semantics (`nn_mac_ref`) every other implementation — the
//! cycle-accurate MAC unit, the kernel code generators, the Pallas kernel
//! (via exported test vectors) — is tested against.
//!
//! Lane layout is little-endian: lane 0 occupies the least-significant
//! bits. All operands are signed two's complement:
//!
//! * activations: always 4 × int8 per 32-bit word,
//! * weights: 4 × int8 (Mode-1), 8 × int4 (Mode-2) or 16 × int2 (Mode-3)
//!   per 32-bit word.

use super::MacMode;

/// Value range of a signed `bits`-wide weight: `[-2^(bits-1), 2^(bits-1)-1]`.
pub fn weight_range(bits: u32) -> (i32, i32) {
    (-(1 << (bits - 1)), (1 << (bits - 1)) - 1)
}

/// Pack four int8 activations into one 32-bit word (lane 0 = LSB).
pub fn pack_acts(a: [i8; 4]) -> u32 {
    u32::from_le_bytes([a[0] as u8, a[1] as u8, a[2] as u8, a[3] as u8])
}

/// Unpack four int8 activations from one 32-bit word.
pub fn unpack_acts(w: u32) -> [i8; 4] {
    let b = w.to_le_bytes();
    [b[0] as i8, b[1] as i8, b[2] as i8, b[3] as i8]
}

/// Pack `32/bits` signed weights into a 32-bit word.
///
/// Panics if a value falls outside the `bits`-wide signed range — the
/// quantizer must have clamped to the grid first.
pub fn pack_weights(mode: MacMode, w: &[i8]) -> u32 {
    let bits = mode.weight_bits();
    let n = mode.weights_per_word() as usize;
    assert_eq!(w.len(), n, "expected {n} weights for {mode:?}, got {}", w.len());
    let (lo, hi) = weight_range(bits);
    let mask = (1u32 << bits) - 1;
    let mut word = 0u32;
    for (i, &v) in w.iter().enumerate() {
        assert!(
            (v as i32) >= lo && (v as i32) <= hi,
            "weight {v} out of int{bits} range [{lo}, {hi}]"
        );
        word |= ((v as u32) & mask) << (i as u32 * bits);
    }
    word
}

/// Unpack the `32/bits` signed weights of a 32-bit word (sign-extended).
pub fn unpack_weights(mode: MacMode, word: u32) -> Vec<i8> {
    let bits = mode.weight_bits();
    let n = mode.weights_per_word();
    let shift = 32 - bits;
    (0..n)
        .map(|i| {
            let field = (word >> (i * bits)) as i32;
            (((field << shift) as i32) >> shift) as i8
        })
        .collect()
}

/// Scalar reference semantics of `nn_mac_<x>b rd, rs1, rs2`.
///
/// `acc` is the incoming `rd` value, `act_words` are the register-pair /
/// quad activation words (`rs1`, `rs1+1`, ...; exactly
/// [`MacMode::activation_regs`] of them) and `w_word` is `rs2`. Returns
/// the new accumulator: `acc + Σᵢ aᵢ·wᵢ` with wrapping 32-bit arithmetic
/// (the hardware accumulator wraps, and the requantization range analysis
/// in `nn::quant` guarantees no wrap for well-formed layers).
pub fn nn_mac_ref(mode: MacMode, acc: u32, act_words: &[u32], w_word: u32) -> u32 {
    assert_eq!(
        act_words.len(),
        mode.activation_regs() as usize,
        "mode {mode:?} consumes {} activation words",
        mode.activation_regs()
    );
    let weights = unpack_weights(mode, w_word);
    let mut sum = acc as i32;
    for (i, &w) in weights.iter().enumerate() {
        let a = unpack_acts(act_words[i / 4])[i % 4];
        sum = sum.wrapping_add((a as i32).wrapping_mul(w as i32));
    }
    sum as u32
}

/// Guard-bit field offset of the soft-SIMD dual product (paper Eq. 2).
///
/// The low product `A·W_lo` of an int8 × int2 multiply spans 10 bits
/// (|A·W| ≤ 256), so the high weight is placed at bit 11 — 10 product
/// bits + 1 guard bit inside the 17-bit multiplier port; the second
/// guard bit of the paper sits above the high product within the
/// multiplier's 34-bit output.
pub const SOFT_SIMD_SHIFT: u32 = 11;

/// One 17×17 multiplier executing the paper's Eq. (2): a *single*
/// multiplication producing two int8×int2 products.
///
/// `P = A · (W_hi·2¹¹ + W_lo)`; the low product is recovered by
/// interpreting the low 11 bits (10 product bits + guard) as a signed
/// field — exact because `|A·W_lo| ≤ 256 < 2¹⁰` — and the high product
/// as the remaining (signed) upper part. Returns `(lo, hi)` products.
pub fn soft_simd_dual_product(a: i8, w_lo: i8, w_hi: i8) -> (i32, i32) {
    debug_assert!((-2..=1).contains(&(w_lo as i32)) && (-2..=1).contains(&(w_hi as i32)));
    // The composed 17-bit operand: W_hi·2^11 + W_lo, a signed value that
    // fits in 14 bits — well inside the 17-bit port.
    let composed = ((w_hi as i32) << SOFT_SIMD_SHIFT) + (w_lo as i32);
    let p = (a as i32) * composed;
    // Field extraction with guard-bit sign correction: the low field is
    // exactly SOFT_SIMD_SHIFT bits wide (bit 11 upward belongs to the
    // high product), and |A·W_lo| ≤ 256 < 2¹⁰ so the sign-extended low
    // field recovers the low product exactly.
    let lo = (p << (32 - SOFT_SIMD_SHIFT)) >> (32 - SOFT_SIMD_SHIFT);
    let hi = (p - lo) >> SOFT_SIMD_SHIFT;
    (lo, hi)
}

/// Pack a flat signed-weight slice into 32-bit words for a given mode,
/// zero-padding the tail. This is the memory layout the Mode-1/2/3
/// kernels stream (`nn/pack.rs` builds full layer layouts on top).
pub fn pack_weight_stream(mode: MacMode, w: &[i8]) -> Vec<u32> {
    let n = mode.weights_per_word() as usize;
    w.chunks(n)
        .map(|c| {
            if c.len() == n {
                pack_weights(mode, c)
            } else {
                let mut padded = vec![0i8; n];
                padded[..c.len()].copy_from_slice(c);
                pack_weights(mode, &padded)
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::MacMode::*;

    #[test]
    fn pack_unpack_round_trip() {
        for mode in [W8, W4, W2] {
            let (lo, hi) = weight_range(mode.weight_bits());
            let n = mode.weights_per_word() as usize;
            let w: Vec<i8> = (0..n).map(|i| (lo + (i as i32 * 3) % (hi - lo + 1)) as i8).collect();
            let packed = pack_weights(mode, &w);
            assert_eq!(unpack_weights(mode, packed), w, "{mode:?}");
        }
    }

    #[test]
    fn acts_round_trip() {
        let a = [-128i8, -1, 0, 127];
        assert_eq!(unpack_acts(pack_acts(a)), a);
    }

    #[test]
    fn mac_ref_mode1_matches_manual() {
        let acts = pack_acts([1, -2, 3, -4]);
        let w = pack_weights(W8, &[10, 20, -30, 40]);
        // 1*10 + (-2)*20 + 3*(-30) + (-4)*40 = 10 - 40 - 90 - 160 = -280
        assert_eq!(nn_mac_ref(W8, 0, &[acts], w) as i32, -280);
        // Accumulation wraps on top of the incoming rd.
        assert_eq!(nn_mac_ref(W8, 1000, &[acts], w) as i32, 720);
    }

    #[test]
    fn mac_ref_mode2_uses_register_pair() {
        let a0 = pack_acts([1, 1, 1, 1]);
        let a1 = pack_acts([2, 2, 2, 2]);
        let w = pack_weights(W4, &[1, 1, 1, 1, 1, 1, 1, 1]);
        // 4·(1·1) + 4·(2·1) = 12
        assert_eq!(nn_mac_ref(W4, 0, &[a0, a1], w) as i32, 12);
    }

    #[test]
    fn mac_ref_mode3_sixteen_macs() {
        let acts: Vec<u32> = (0..4).map(|j| pack_acts([j as i8 + 1; 4])).collect();
        let w = pack_weights(W2, &[-2i8; 16]);
        // Σ_j 4·(j+1)·(−2) = −2·4·(1+2+3+4) = −80
        assert_eq!(nn_mac_ref(W2, 0, &acts, w) as i32, -80);
    }

    #[test]
    fn soft_simd_exact_over_full_range() {
        // Exhaustive: every (a, w_lo, w_hi) — the Eq.(2) decomposition must
        // be bit-exact including worst-case negative borrows.
        for a in i8::MIN..=i8::MAX {
            for w_lo in -2i8..=1 {
                for w_hi in -2i8..=1 {
                    let (lo, hi) = soft_simd_dual_product(a, w_lo, w_hi);
                    assert_eq!(lo, a as i32 * w_lo as i32, "lo a={a} wl={w_lo} wh={w_hi}");
                    assert_eq!(hi, a as i32 * w_hi as i32, "hi a={a} wl={w_lo} wh={w_hi}");
                }
            }
        }
    }

    #[test]
    fn stream_pads_tail() {
        let words = pack_weight_stream(W4, &[1, 2, 3]);
        assert_eq!(words.len(), 1);
        assert_eq!(unpack_weights(W4, words[0]), vec![1, 2, 3, 0, 0, 0, 0, 0]);
    }

    #[test]
    #[should_panic(expected = "out of int2 range")]
    fn rejects_out_of_grid_weights() {
        pack_weights(W2, &[2i8; 16]);
    }
}
