//! Bit-exact RV32IM (+ custom) instruction encoder.
//!
//! Every encoder asserts the immediate ranges required by the format so
//! kernel-codegen bugs fail loudly at emit time instead of silently
//! mis-executing on the core simulator.

use super::*;

#[inline]
fn r(rd: Reg, rs1: Reg, rs2: Reg, f3: u32, f7: u32, opcode: u32) -> u32 {
    debug_assert!(rd < 32 && rs1 < 32 && rs2 < 32);
    (f7 << 25) | ((rs2 as u32) << 20) | ((rs1 as u32) << 15) | (f3 << 12) | ((rd as u32) << 7) | opcode
}

#[inline]
fn i(rd: Reg, rs1: Reg, imm: i32, f3: u32, opcode: u32) -> u32 {
    assert!((-2048..=2047).contains(&imm), "I-type imm out of range: {imm}");
    ((imm as u32 & 0xfff) << 20) | ((rs1 as u32) << 15) | (f3 << 12) | ((rd as u32) << 7) | opcode
}

#[inline]
fn s(rs1: Reg, rs2: Reg, imm: i32, f3: u32, opcode: u32) -> u32 {
    assert!((-2048..=2047).contains(&imm), "S-type imm out of range: {imm}");
    let imm = imm as u32 & 0xfff;
    ((imm >> 5) << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (f3 << 12)
        | ((imm & 0x1f) << 7)
        | opcode
}

#[inline]
fn b(rs1: Reg, rs2: Reg, offset: i32, f3: u32) -> u32 {
    assert!(
        (-4096..=4094).contains(&offset) && offset % 2 == 0,
        "B-type offset out of range or misaligned: {offset}"
    );
    let o = offset as u32;
    (((o >> 12) & 1) << 31)
        | (((o >> 5) & 0x3f) << 25)
        | ((rs2 as u32) << 20)
        | ((rs1 as u32) << 15)
        | (f3 << 12)
        | (((o >> 1) & 0xf) << 8)
        | (((o >> 11) & 1) << 7)
        | opcodes::BRANCH
}

#[inline]
fn u(rd: Reg, imm: i32, opcode: u32) -> u32 {
    assert_eq!(imm & 0xfff, 0, "U-type imm must be 4KiB aligned (pre-shifted): {imm:#x}");
    (imm as u32) | ((rd as u32) << 7) | opcode
}

#[inline]
fn j(rd: Reg, offset: i32) -> u32 {
    assert!(
        (-(1 << 20)..(1 << 20)).contains(&offset) && offset % 2 == 0,
        "J-type offset out of range or misaligned: {offset}"
    );
    let o = offset as u32;
    (((o >> 20) & 1) << 31)
        | (((o >> 1) & 0x3ff) << 21)
        | (((o >> 11) & 1) << 20)
        | (((o >> 12) & 0xff) << 12)
        | ((rd as u32) << 7)
        | opcodes::JAL
}

fn alu_f3(op: AluOp) -> u32 {
    match op {
        AluOp::Add | AluOp::Sub => 0b000,
        AluOp::Sll => 0b001,
        AluOp::Slt => 0b010,
        AluOp::Sltu => 0b011,
        AluOp::Xor => 0b100,
        AluOp::Srl | AluOp::Sra => 0b101,
        AluOp::Or => 0b110,
        AluOp::And => 0b111,
    }
}

fn mul_f3(op: MulOp) -> u32 {
    match op {
        MulOp::Mul => 0b000,
        MulOp::Mulh => 0b001,
        MulOp::Mulhsu => 0b010,
        MulOp::Mulhu => 0b011,
        MulOp::Div => 0b100,
        MulOp::Divu => 0b101,
        MulOp::Rem => 0b110,
        MulOp::Remu => 0b111,
    }
}

fn branch_f3(op: BranchOp) -> u32 {
    match op {
        BranchOp::Beq => 0b000,
        BranchOp::Bne => 0b001,
        BranchOp::Blt => 0b100,
        BranchOp::Bge => 0b101,
        BranchOp::Bltu => 0b110,
        BranchOp::Bgeu => 0b111,
    }
}

fn load_f3(op: LoadOp) -> u32 {
    match op {
        LoadOp::Lb => 0b000,
        LoadOp::Lh => 0b001,
        LoadOp::Lw => 0b010,
        LoadOp::Lbu => 0b100,
        LoadOp::Lhu => 0b101,
    }
}

fn store_f3(op: StoreOp) -> u32 {
    match op {
        StoreOp::Sb => 0b000,
        StoreOp::Sh => 0b001,
        StoreOp::Sw => 0b010,
    }
}

fn csr_f3(op: CsrOp) -> u32 {
    match op {
        CsrOp::Rw => 0b001,
        CsrOp::Rs => 0b010,
        CsrOp::Rc => 0b011,
    }
}

/// Encode an instruction into its 32-bit machine word.
///
/// Panics on out-of-range immediates — codegen is expected to have
/// range-split them (the assembler's `li`/`la` handle the general case).
pub fn encode(instr: Instr) -> u32 {
    match instr {
        Instr::Lui { rd, imm } => u(rd, imm, opcodes::LUI),
        Instr::Auipc { rd, imm } => u(rd, imm, opcodes::AUIPC),
        Instr::Jal { rd, offset } => j(rd, offset),
        Instr::Jalr { rd, rs1, offset } => i(rd, rs1, offset, 0b000, opcodes::JALR),
        Instr::Branch { op, rs1, rs2, offset } => b(rs1, rs2, offset, branch_f3(op)),
        Instr::Load { op, rd, rs1, offset } => i(rd, rs1, offset, load_f3(op), opcodes::LOAD),
        Instr::Store { op, rs1, rs2, offset } => s(rs1, rs2, offset, store_f3(op), opcodes::STORE),
        Instr::OpImm { op, rd, rs1, imm } => {
            assert!(op != AluOp::Sub, "subi does not exist; encode addi with negated imm");
            match op {
                AluOp::Sll => {
                    assert!((0..32).contains(&imm), "slli shamt out of range: {imm}");
                    r(rd, rs1, imm as Reg, alu_f3(op), 0, opcodes::OP_IMM)
                }
                AluOp::Srl => {
                    assert!((0..32).contains(&imm), "srli shamt out of range: {imm}");
                    r(rd, rs1, imm as Reg, alu_f3(op), 0, opcodes::OP_IMM)
                }
                AluOp::Sra => {
                    assert!((0..32).contains(&imm), "srai shamt out of range: {imm}");
                    r(rd, rs1, imm as Reg, alu_f3(op), 0b0100000, opcodes::OP_IMM)
                }
                _ => i(rd, rs1, imm, alu_f3(op), opcodes::OP_IMM),
            }
        }
        Instr::Op { op, rd, rs1, rs2 } => {
            let f7 = match op {
                AluOp::Sub | AluOp::Sra => 0b0100000,
                _ => 0,
            };
            r(rd, rs1, rs2, alu_f3(op), f7, opcodes::OP)
        }
        Instr::MulDiv { op, rd, rs1, rs2 } => r(rd, rs1, rs2, mul_f3(op), 0b0000001, opcodes::OP),
        Instr::NnMac { mode, rd, rs1, rs2 } => {
            // Table 2: custom-0, func3 = 010, one-hot func7 per mode.
            r(rd, rs1, rs2, 0b010, mode.func7(), opcodes::CUSTOM0)
        }
        Instr::Csr { op, rd, rs1, csr } => {
            ((csr as u32) << 20)
                | ((rs1 as u32) << 15)
                | (csr_f3(op) << 12)
                | ((rd as u32) << 7)
                | opcodes::SYSTEM
        }
        Instr::Fence => (0b000 << 12) | opcodes::MISC_MEM,
        Instr::Ecall => opcodes::SYSTEM,
        Instr::Ebreak => (1 << 20) | opcodes::SYSTEM,
    }
}

/// Encode a whole program (one word per instruction).
pub fn encode_program(instrs: &[Instr]) -> Vec<u32> {
    instrs.iter().map(|&i| encode(i)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encodes_known_words() {
        // Cross-checked against riscv-tests / GNU as output.
        // addi a0, a0, 1  -> 0x00150513
        assert_eq!(
            encode(Instr::OpImm { op: AluOp::Add, rd: reg::A0, rs1: reg::A0, imm: 1 }),
            0x00150513
        );
        // add a0, a1, a2 -> 0x00c58533
        assert_eq!(
            encode(Instr::Op { op: AluOp::Add, rd: reg::A0, rs1: reg::A1, rs2: reg::A2 }),
            0x00c58533
        );
        // sub a0, a1, a2 -> 0x40c58533
        assert_eq!(
            encode(Instr::Op { op: AluOp::Sub, rd: reg::A0, rs1: reg::A1, rs2: reg::A2 }),
            0x40c58533
        );
        // lw a0, 4(sp) -> 0x00412503
        assert_eq!(
            encode(Instr::Load { op: LoadOp::Lw, rd: reg::A0, rs1: reg::SP, offset: 4 }),
            0x00412503
        );
        // sw a0, 8(sp) -> 0x00a12423
        assert_eq!(
            encode(Instr::Store { op: StoreOp::Sw, rs1: reg::SP, rs2: reg::A0, offset: 8 }),
            0x00a12423
        );
        // mul a0, a1, a2 -> 0x02c58533
        assert_eq!(
            encode(Instr::MulDiv { op: MulOp::Mul, rd: reg::A0, rs1: reg::A1, rs2: reg::A2 }),
            0x02c58533
        );
        // lui a0, 0x12345 -> 0x12345537
        assert_eq!(encode(Instr::Lui { rd: reg::A0, imm: 0x12345 << 12 }), 0x12345537);
        // jal ra, +8 -> 0x008000ef
        assert_eq!(encode(Instr::Jal { rd: reg::RA, offset: 8 }), 0x008000ef);
        // ecall -> 0x00000073
        assert_eq!(encode(Instr::Ecall), 0x00000073);
    }

    #[test]
    fn encodes_nn_mac_table2() {
        // nn_mac_8b a0, a1, a2: opcode custom-0 (0001011), f3=010, f7=0001000
        let w = encode(Instr::NnMac { mode: MacMode::W8, rd: reg::A0, rs1: reg::A1, rs2: reg::A2 });
        assert_eq!(w & 0x7f, opcodes::CUSTOM0);
        assert_eq!((w >> 12) & 0x7, 0b010);
        assert_eq!(w >> 25, 0b0001000);
        let w4 = encode(Instr::NnMac { mode: MacMode::W4, rd: reg::A0, rs1: reg::A1, rs2: reg::A2 });
        assert_eq!(w4 >> 25, 0b0000100);
        let w2 = encode(Instr::NnMac { mode: MacMode::W2, rd: reg::A0, rs1: reg::A1, rs2: reg::A2 });
        assert_eq!(w2 >> 25, 0b0000010);
    }

    #[test]
    fn branch_offset_scatter() {
        // beq x0, x0, -4 -> 0xfe000ee3
        let w = encode(Instr::Branch { op: BranchOp::Beq, rs1: 0, rs2: 0, offset: -4 });
        assert_eq!(w, 0xfe000ee3);
    }

    #[test]
    #[should_panic(expected = "I-type imm out of range")]
    fn rejects_oversized_imm() {
        encode(Instr::OpImm { op: AluOp::Add, rd: 1, rs1: 1, imm: 4096 });
    }
}
