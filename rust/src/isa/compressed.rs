//! RV32C (compressed) instruction decoder — completes the RV32IMC
//! baseline ISA the paper compares against. Each 16-bit encoding
//! expands to its canonical 32-bit [`Instr`] (the standard expansion
//! from the RISC-V spec); the core executes expansions with identical
//! semantics and timing, as Ibex does (its decoder expands C
//! instructions before the ID stage — compression affects fetch
//! bandwidth/code size, not per-instruction cycles).
//!
//! Our kernel codegen emits 32-bit forms only; this decoder exists so
//! externally-assembled RV32IMC streams run on the ISS.

use super::*;

/// Decode error for compressed encodings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CDecodeError {
    /// The offending halfword.
    pub half: u16,
}

impl std::fmt::Display for CDecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "illegal compressed instruction: {:#06x}", self.half)
    }
}

impl std::error::Error for CDecodeError {}

/// True if a halfword is a compressed (16-bit) encoding.
pub fn is_compressed(half: u16) -> bool {
    half & 0b11 != 0b11
}

#[inline]
fn rp(bits: u16) -> Reg {
    // x8..x15 register-prime field.
    (8 + (bits & 0x7)) as Reg
}

/// Decode one 16-bit compressed instruction into its 32-bit expansion.
pub fn decode_compressed(h: u16) -> Result<Instr, CDecodeError> {
    let err = Err(CDecodeError { half: h });
    let op = h & 0b11;
    let f3 = (h >> 13) & 0b111;
    let rd = ((h >> 7) & 31) as Reg;
    let rs2 = ((h >> 2) & 31) as Reg;
    Ok(match (op, f3) {
        // C.ADDI4SPN: addi rd', sp, nzuimm
        (0b00, 0b000) => {
            let imm = (((h >> 7) & 0x30) | ((h >> 1) & 0x3c0) | ((h >> 4) & 0x4) | ((h >> 2) & 0x8))
                as i32;
            if imm == 0 {
                return err;
            }
            Instr::OpImm { op: AluOp::Add, rd: rp(h >> 2), rs1: reg::SP, imm }
        }
        // C.LW: lw rd', offset(rs1')
        (0b00, 0b010) => {
            let imm = (((h >> 7) & 0x38) | ((h << 1) & 0x40) | ((h >> 4) & 0x4)) as i32;
            Instr::Load { op: LoadOp::Lw, rd: rp(h >> 2), rs1: rp(h >> 7), offset: imm }
        }
        // C.SW: sw rs2', offset(rs1')
        (0b00, 0b110) => {
            let imm = (((h >> 7) & 0x38) | ((h << 1) & 0x40) | ((h >> 4) & 0x4)) as i32;
            Instr::Store { op: StoreOp::Sw, rs1: rp(h >> 7), rs2: rp(h >> 2), offset: imm }
        }
        // C.ADDI / C.NOP
        (0b01, 0b000) => {
            let imm = sext6(((h >> 7) & 0x20) | ((h >> 2) & 0x1f));
            Instr::OpImm { op: AluOp::Add, rd, rs1: rd, imm }
        }
        // C.JAL (RV32): jal ra, offset
        (0b01, 0b001) => Instr::Jal { rd: reg::RA, offset: cj_offset(h) },
        // C.LI: addi rd, x0, imm
        (0b01, 0b010) => {
            let imm = sext6(((h >> 7) & 0x20) | ((h >> 2) & 0x1f));
            Instr::OpImm { op: AluOp::Add, rd, rs1: reg::ZERO, imm }
        }
        // C.ADDI16SP / C.LUI
        (0b01, 0b011) => {
            if rd == 2 {
                let imm = sext10(
                    ((h >> 3) & 0x200)
                        | ((h >> 2) & 0x10)
                        | ((h << 1) & 0x40)
                        | ((h << 4) & 0x180)
                        | ((h << 3) & 0x20),
                );
                if imm == 0 {
                    return err;
                }
                Instr::OpImm { op: AluOp::Add, rd: reg::SP, rs1: reg::SP, imm }
            } else {
                let imm = sext6(((h >> 7) & 0x20) | ((h >> 2) & 0x1f));
                if imm == 0 {
                    return err;
                }
                Instr::Lui { rd, imm: imm << 12 }
            }
        }
        // C.SRLI / C.SRAI / C.ANDI / register-register ops
        (0b01, 0b100) => {
            let rd = rp(h >> 7);
            match (h >> 10) & 0b11 {
                0b00 => Instr::OpImm { op: AluOp::Srl, rd, rs1: rd, imm: shamt(h)? },
                0b01 => Instr::OpImm { op: AluOp::Sra, rd, rs1: rd, imm: shamt(h)? },
                0b10 => Instr::OpImm {
                    op: AluOp::And,
                    rd,
                    rs1: rd,
                    imm: sext6(((h >> 7) & 0x20) | ((h >> 2) & 0x1f)),
                },
                _ => {
                    let rs2 = rp(h >> 2);
                    let op = match ((h >> 12) & 1, (h >> 5) & 0b11) {
                        (0, 0b00) => AluOp::Sub,
                        (0, 0b01) => AluOp::Xor,
                        (0, 0b10) => AluOp::Or,
                        (0, 0b11) => AluOp::And,
                        _ => return err,
                    };
                    Instr::Op { op, rd, rs1: rd, rs2 }
                }
            }
        }
        // C.J: jal x0, offset
        (0b01, 0b101) => Instr::Jal { rd: reg::ZERO, offset: cj_offset(h) },
        // C.BEQZ / C.BNEZ
        (0b01, 0b110) | (0b01, 0b111) => {
            let imm = sext9(
                ((h >> 4) & 0x100)
                    | ((h << 1) & 0xc0)
                    | ((h << 3) & 0x20)
                    | ((h >> 7) & 0x18)
                    | ((h >> 2) & 0x6),
            );
            let op = if f3 == 0b110 { BranchOp::Beq } else { BranchOp::Bne };
            Instr::Branch { op, rs1: rp(h >> 7), rs2: reg::ZERO, offset: imm }
        }
        // C.SLLI
        (0b10, 0b000) => Instr::OpImm { op: AluOp::Sll, rd, rs1: rd, imm: shamt(h)? },
        // C.LWSP
        (0b10, 0b010) => {
            if rd == 0 {
                return err;
            }
            let imm = (((h >> 7) & 0x20) | ((h >> 2) & 0x1c) | ((h << 4) & 0xc0)) as i32;
            Instr::Load { op: LoadOp::Lw, rd, rs1: reg::SP, offset: imm }
        }
        // C.JR / C.MV / C.JALR / C.ADD / C.EBREAK
        (0b10, 0b100) => {
            let bit12 = (h >> 12) & 1;
            match (bit12, rd, rs2) {
                (0, 0, _) => return err,
                (0, _, 0) => Instr::Jalr { rd: reg::ZERO, rs1: rd, offset: 0 }, // c.jr
                (0, _, _) => Instr::Op { op: AluOp::Add, rd, rs1: reg::ZERO, rs2 }, // c.mv
                (1, 0, 0) => Instr::Ebreak,
                (1, _, 0) => Instr::Jalr { rd: reg::RA, rs1: rd, offset: 0 }, // c.jalr
                (1, _, _) => Instr::Op { op: AluOp::Add, rd, rs1: rd, rs2 },  // c.add
                _ => return err,
            }
        }
        // C.SWSP
        (0b10, 0b110) => {
            let imm = (((h >> 7) & 0x3c) | ((h >> 1) & 0xc0)) as i32;
            Instr::Store { op: StoreOp::Sw, rs1: reg::SP, rs2, offset: imm }
        }
        _ => return err,
    })
}

fn sext6(v: u16) -> i32 {
    ((v as i32) << 26) >> 26
}
fn sext9(v: u16) -> i32 {
    ((v as i32) << 23) >> 23
}
fn sext10(v: u16) -> i32 {
    ((v as i32) << 22) >> 22
}
fn shamt(h: u16) -> Result<i32, CDecodeError> {
    if (h >> 12) & 1 != 0 {
        return Err(CDecodeError { half: h }); // RV32: shamt[5] must be 0
    }
    Ok(((h >> 2) & 0x1f) as i32)
}

/// CJ-format jump offset.
fn cj_offset(h: u16) -> i32 {
    let b = |i: u16| ((h >> i) & 1) as i32;
    let off = (b(12) << 11)
        | (b(11) << 4)
        | (b(10) << 9)
        | (b(9) << 8)
        | (b(8) << 10)
        | (b(7) << 6)
        | (b(6) << 7)
        | (b(5) << 2)
        | (b(4) << 3)
        | (b(3) << 1)
        | (b(2) << 5);
    (off << 20) >> 20
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Cross-checked against GNU as output for RV32C.
    #[test]
    fn decodes_known_compressed_words() {
        // c.addi a0, 1 -> 0x0505
        assert_eq!(
            decode_compressed(0x0505).unwrap(),
            Instr::OpImm { op: AluOp::Add, rd: reg::A0, rs1: reg::A0, imm: 1 }
        );
        // c.li a0, -1 -> 0x557d
        assert_eq!(
            decode_compressed(0x557d).unwrap(),
            Instr::OpImm { op: AluOp::Add, rd: reg::A0, rs1: reg::ZERO, imm: -1 }
        );
        // c.mv a0, a1 -> 0x852e
        assert_eq!(
            decode_compressed(0x852e).unwrap(),
            Instr::Op { op: AluOp::Add, rd: reg::A0, rs1: reg::ZERO, rs2: reg::A1 }
        );
        // c.add a0, a1 -> 0x952e
        assert_eq!(
            decode_compressed(0x952e).unwrap(),
            Instr::Op { op: AluOp::Add, rd: reg::A0, rs1: reg::A0, rs2: reg::A1 }
        );
        // c.lw a0, 0(a1) -> 0x4188
        assert_eq!(
            decode_compressed(0x4188).unwrap(),
            Instr::Load { op: LoadOp::Lw, rd: reg::A0, rs1: reg::A1, offset: 0 }
        );
        // c.sw a0, 4(a1) -> 0xc1c8
        assert_eq!(
            decode_compressed(0xc1c8).unwrap(),
            Instr::Store { op: StoreOp::Sw, rs1: reg::A1, rs2: reg::A0, offset: 4 }
        );
        // c.slli a0, 4 -> 0x0512
        assert_eq!(
            decode_compressed(0x0512).unwrap(),
            Instr::OpImm { op: AluOp::Sll, rd: reg::A0, rs1: reg::A0, imm: 4 }
        );
        // c.jr a0 -> 0x8502
        assert_eq!(
            decode_compressed(0x8502).unwrap(),
            Instr::Jalr { rd: reg::ZERO, rs1: reg::A0, offset: 0 }
        );
        // c.ebreak -> 0x9002
        assert_eq!(decode_compressed(0x9002).unwrap(), Instr::Ebreak);
        // c.sub s0, s1 -> 0x8c05
        assert_eq!(
            decode_compressed(0x8c05).unwrap(),
            Instr::Op { op: AluOp::Sub, rd: reg::S0, rs1: reg::S0, rs2: reg::S1 }
        );
        // c.andi s0, 10 -> 0x8829
        assert_eq!(
            decode_compressed(0x8829).unwrap(),
            Instr::OpImm { op: AluOp::And, rd: reg::S0, rs1: reg::S0, imm: 10 }
        );
    }

    #[test]
    fn jump_and_branch_offsets() {
        // c.j . (offset 0) -> 0xa001
        assert_eq!(decode_compressed(0xa001).unwrap(), Instr::Jal { rd: reg::ZERO, offset: 0 });
        // c.j -2 -> 0xbffd
        assert_eq!(decode_compressed(0xbffd).unwrap(), Instr::Jal { rd: reg::ZERO, offset: -2 });
        // c.beqz s0, +8 -> 0xc401
        assert_eq!(
            decode_compressed(0xc401).unwrap(),
            Instr::Branch { op: BranchOp::Beq, rs1: reg::S0, rs2: reg::ZERO, offset: 8 }
        );
    }

    #[test]
    fn rejects_reserved_encodings() {
        assert!(decode_compressed(0x0000).is_err()); // all-zero is illegal
        assert!(decode_compressed(0x9002 | (1 << 2)).is_ok()); // c.add form
        // shamt[5]=1 is reserved on RV32.
        assert!(decode_compressed(0x1512).is_err()); // c.slli a0, 36
    }

    #[test]
    fn is_compressed_discriminates() {
        assert!(is_compressed(0x0505));
        assert!(!is_compressed(0x0003)); // 32-bit opcode low bits 11
    }

    #[test]
    fn expansions_execute_on_the_core() {
        use crate::sim::{Core, CoreConfig, ExitReason};
        // li a0,5 ; addi a0,3 ; mv a1,a0 ; add a1,a0 via expansions.
        let prog: Vec<Instr> = [0x4515u16, 0x050d, 0x85aa, 0x95aa]
            .iter()
            .map(|&h| decode_compressed(h).unwrap())
            .chain([Instr::Ecall])
            .collect();
        let mut core = Core::new(CoreConfig { mem_size: 4096, ..Default::default() }, prog, 0);
        assert_eq!(core.run(100), ExitReason::Ecall);
        assert_eq!(core.regs[reg::A0 as usize], 8);
        assert_eq!(core.regs[reg::A1 as usize], 16);
    }
}
