//! Disassembler: `Instr` → GNU-as-compatible text (custom instructions use
//! the paper's mnemonics). Used by the CLI `disasm` subcommand, the
//! assembler's listing output and the simulator's trace mode.

use super::reg::name;
use super::*;

fn alu_name(op: AluOp) -> &'static str {
    match op {
        AluOp::Add => "add",
        AluOp::Sub => "sub",
        AluOp::Sll => "sll",
        AluOp::Slt => "slt",
        AluOp::Sltu => "sltu",
        AluOp::Xor => "xor",
        AluOp::Srl => "srl",
        AluOp::Sra => "sra",
        AluOp::Or => "or",
        AluOp::And => "and",
    }
}

fn mul_name(op: MulOp) -> &'static str {
    match op {
        MulOp::Mul => "mul",
        MulOp::Mulh => "mulh",
        MulOp::Mulhsu => "mulhsu",
        MulOp::Mulhu => "mulhu",
        MulOp::Div => "div",
        MulOp::Divu => "divu",
        MulOp::Rem => "rem",
        MulOp::Remu => "remu",
    }
}

fn branch_name(op: BranchOp) -> &'static str {
    match op {
        BranchOp::Beq => "beq",
        BranchOp::Bne => "bne",
        BranchOp::Blt => "blt",
        BranchOp::Bge => "bge",
        BranchOp::Bltu => "bltu",
        BranchOp::Bgeu => "bgeu",
    }
}

fn load_name(op: LoadOp) -> &'static str {
    match op {
        LoadOp::Lb => "lb",
        LoadOp::Lh => "lh",
        LoadOp::Lw => "lw",
        LoadOp::Lbu => "lbu",
        LoadOp::Lhu => "lhu",
    }
}

fn store_name(op: StoreOp) -> &'static str {
    match op {
        StoreOp::Sb => "sb",
        StoreOp::Sh => "sh",
        StoreOp::Sw => "sw",
    }
}

/// Render one instruction as assembly text.
pub fn disasm(instr: Instr) -> String {
    match instr {
        Instr::Lui { rd, imm } => format!("lui {}, {:#x}", name(rd), (imm as u32) >> 12),
        Instr::Auipc { rd, imm } => format!("auipc {}, {:#x}", name(rd), (imm as u32) >> 12),
        Instr::Jal { rd, offset } => format!("jal {}, {}", name(rd), offset),
        Instr::Jalr { rd, rs1, offset } => format!("jalr {}, {}({})", name(rd), offset, name(rs1)),
        Instr::Branch { op, rs1, rs2, offset } => {
            format!("{} {}, {}, {}", branch_name(op), name(rs1), name(rs2), offset)
        }
        Instr::Load { op, rd, rs1, offset } => {
            format!("{} {}, {}({})", load_name(op), name(rd), offset, name(rs1))
        }
        Instr::Store { op, rs1, rs2, offset } => {
            format!("{} {}, {}({})", store_name(op), name(rs2), offset, name(rs1))
        }
        Instr::OpImm { op, rd, rs1, imm } => {
            let mn = match op {
                AluOp::Add => "addi",
                AluOp::Sll => "slli",
                AluOp::Slt => "slti",
                AluOp::Sltu => "sltiu",
                AluOp::Xor => "xori",
                AluOp::Srl => "srli",
                AluOp::Sra => "srai",
                AluOp::Or => "ori",
                AluOp::And => "andi",
                AluOp::Sub => unreachable!("subi does not exist"),
            };
            format!("{} {}, {}, {}", mn, name(rd), name(rs1), imm)
        }
        Instr::Op { op, rd, rs1, rs2 } => {
            format!("{} {}, {}, {}", alu_name(op), name(rd), name(rs1), name(rs2))
        }
        Instr::MulDiv { op, rd, rs1, rs2 } => {
            format!("{} {}, {}, {}", mul_name(op), name(rd), name(rs1), name(rs2))
        }
        Instr::NnMac { mode, rd, rs1, rs2 } => {
            format!("{} {}, {}, {}", mode.mnemonic(), name(rd), name(rs1), name(rs2))
        }
        Instr::Csr { op, rd, rs1, csr } => {
            let mn = match op {
                CsrOp::Rw => "csrrw",
                CsrOp::Rs => "csrrs",
                CsrOp::Rc => "csrrc",
            };
            format!("{} {}, {:#x}, {}", mn, name(rd), csr, name(rs1))
        }
        Instr::Fence => "fence".to_string(),
        Instr::Ecall => "ecall".to_string(),
        Instr::Ebreak => "ebreak".to_string(),
    }
}

/// Disassemble a sequence of machine words into an annotated listing.
pub fn disasm_words(words: &[u32], base: u32) -> String {
    use super::decode::decode;
    let mut out = String::new();
    for (i, &w) in words.iter().enumerate() {
        let pc = base + 4 * i as u32;
        match decode(w) {
            Ok(ins) => out.push_str(&format!("{pc:8x}: {w:08x}  {}\n", disasm(ins))),
            Err(_) => out.push_str(&format!("{pc:8x}: {w:08x}  <illegal>\n")),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_custom_mnemonics() {
        let s = disasm(Instr::NnMac { mode: MacMode::W2, rd: reg::A0, rs1: reg::A2, rs2: reg::A6 });
        assert_eq!(s, "nn_mac_2b a0, a2, a6");
    }

    #[test]
    fn renders_loads_gnu_style() {
        let s = disasm(Instr::Load { op: LoadOp::Lbu, rd: reg::T0, rs1: reg::A0, offset: -3 });
        assert_eq!(s, "lbu t0, -3(a0)");
    }
}
