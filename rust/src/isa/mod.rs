//! RV32IM instruction set plus the paper's mixed-precision extension.
//!
//! The instruction model is bit-exact: [`encode::encode`] produces the
//! 32-bit machine word and [`decode::decode`] inverts it; both are
//! round-trip property-tested. The three custom instructions follow the
//! paper's Table 2 — R-type format on the RISC-V *custom-0* opcode with
//! `func3 = 0b010` and a one-hot `func7` selecting the operational mode:
//!
//! | mnemonic    | func7     | rs1                 | rs2            | semantics |
//! |-------------|-----------|---------------------|----------------|-----------|
//! | `nn_mac_8b` | `0001000` | 4 × int8 activation | 4 × int8 wgt   | 4 MACs (Mode-1) |
//! | `nn_mac_4b` | `0000100` | 4 × int8 activation | 8 × int4 wgt   | 8 MACs (Mode-2) |
//! | `nn_mac_2b` | `0000010` | 4 × int8 activation | 16 × int2 wgt  | 16 MACs (Mode-3) |
//!
//! ## ISA interpretation note (documented reproduction decision)
//!
//! The paper packs 8 (Mode-2) / 16 (Mode-3) weights into `rs2` while `rs1`
//! holds only four 8-bit activations, and states that one instruction
//! performs 8 / 16 MAC operations with a single 32-bit accumulator in `rd`.
//! A dot product of N weights needs N activations, so the extra activation
//! words must reach the unit somehow; the paper's enabler is precisely the
//! **2× multi-pumped clock**, which gives the MAC block two register-file
//! access slots per core cycle. We therefore adopt *register-pair reads*:
//! `nn_mac_4b` reads activations from the register pair `rs1, rs1+1`
//! (second read on the pumped phase) and `nn_mac_2b` from the quad
//! `rs1..rs1+3` (two pumped phases × two soft-SIMD products per 17-bit
//! multiplier). This preserves every quantitative claim the paper makes:
//! one instruction retires 4/8/16 MACs, weight memory traffic shrinks by
//! 4/8/16×, and all modes sustain one instruction per core cycle.

pub mod compressed;
pub mod custom;
pub mod decode;
pub mod disasm;
pub mod encode;

/// Architectural register index (`x0`..`x31`).
pub type Reg = u8;

/// Number of architectural registers.
pub const NUM_REGS: usize = 32;

/// RISC-V base opcodes used by this implementation.
pub mod opcodes {
    pub const LUI: u32 = 0b0110111;
    pub const AUIPC: u32 = 0b0010111;
    pub const JAL: u32 = 0b1101111;
    pub const JALR: u32 = 0b1100111;
    pub const BRANCH: u32 = 0b1100011;
    pub const LOAD: u32 = 0b0000011;
    pub const STORE: u32 = 0b0100011;
    pub const OP_IMM: u32 = 0b0010011;
    pub const OP: u32 = 0b0110011;
    pub const MISC_MEM: u32 = 0b0001111;
    pub const SYSTEM: u32 = 0b1110011;
    /// RISC-V *custom-0* opcode space reserved for vendor extensions —
    /// the paper's `nn_mac_*` instructions live here.
    pub const CUSTOM0: u32 = 0b0001011;
}

/// Register-register ALU operation (OP and OP-IMM encodings).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AluOp {
    Add,
    Sub,
    Sll,
    Slt,
    Sltu,
    Xor,
    Srl,
    Sra,
    Or,
    And,
}

/// RV32M multiply/divide operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MulOp {
    Mul,
    Mulh,
    Mulhsu,
    Mulhu,
    Div,
    Divu,
    Rem,
    Remu,
}

/// Conditional branch comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BranchOp {
    Beq,
    Bne,
    Blt,
    Bge,
    Bltu,
    Bgeu,
}

/// Load width/signedness.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoadOp {
    Lb,
    Lh,
    Lw,
    Lbu,
    Lhu,
}

/// Store width.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StoreOp {
    Sb,
    Sh,
    Sw,
}

/// CSR access operation (Zicsr subset used by the perf-counter reads).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CsrOp {
    Rw,
    Rs,
    Rc,
}

/// The paper's three operational modes (Section 3.2).
///
/// The discriminant order encodes increasing aggressiveness: Mode-1 packs
/// 8-bit weights (parallelisation only), Mode-2 adds multi-pumping for
/// 4-bit weights, Mode-3 additionally applies the guard-bit soft-SIMD
/// trick for 2-bit weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MacMode {
    /// `nn_mac_8b` — 4 packed 8-bit weights, 4 parallel MACs (Mode-1).
    W8,
    /// `nn_mac_4b` — 8 packed 4-bit weights, 8 parallel MACs (Mode-2).
    W4,
    /// `nn_mac_2b` — 16 packed 2-bit weights, 16 parallel MACs (Mode-3).
    W2,
}

impl MacMode {
    /// Weight bit-width processed by this mode.
    pub fn weight_bits(self) -> u32 {
        match self {
            MacMode::W8 => 8,
            MacMode::W4 => 4,
            MacMode::W2 => 2,
        }
    }

    /// Number of weights packed into one 32-bit source register.
    pub fn weights_per_word(self) -> u32 {
        32 / self.weight_bits()
    }

    /// MAC operations retired by one instruction (= packed weights).
    pub fn macs_per_instr(self) -> u32 {
        self.weights_per_word()
    }

    /// Number of consecutive activation registers consumed
    /// (`rs1 .. rs1 + n`), see the module-level interpretation note.
    pub fn activation_regs(self) -> u32 {
        self.weights_per_word() / 4
    }

    /// `func7` encoding from the paper's Table 2.
    pub fn func7(self) -> u32 {
        match self {
            MacMode::W8 => 0b0001000,
            MacMode::W4 => 0b0000100,
            MacMode::W2 => 0b0000010,
        }
    }

    /// Inverse of [`MacMode::func7`].
    pub fn from_func7(f7: u32) -> Option<Self> {
        match f7 {
            0b0001000 => Some(MacMode::W8),
            0b0000100 => Some(MacMode::W4),
            0b0000010 => Some(MacMode::W2),
            _ => None,
        }
    }

    /// Mode from a weight bit-width.
    pub fn from_weight_bits(bits: u32) -> Option<Self> {
        match bits {
            8 => Some(MacMode::W8),
            4 => Some(MacMode::W4),
            2 => Some(MacMode::W2),
            _ => None,
        }
    }

    /// Assembly mnemonic.
    pub fn mnemonic(self) -> &'static str {
        match self {
            MacMode::W8 => "nn_mac_8b",
            MacMode::W4 => "nn_mac_4b",
            MacMode::W2 => "nn_mac_2b",
        }
    }

    /// Paper-facing mode index (1, 2, 3).
    pub fn mode_index(self) -> u32 {
        match self {
            MacMode::W8 => 1,
            MacMode::W4 => 2,
            MacMode::W2 => 3,
        }
    }
}

/// A decoded RV32IM (+ mixed-precision extension) instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Instr {
    /// Load upper immediate: `rd = imm << 12` (imm stored pre-shifted).
    Lui { rd: Reg, imm: i32 },
    /// Add upper immediate to PC.
    Auipc { rd: Reg, imm: i32 },
    /// Jump and link; `offset` is relative to the instruction address.
    Jal { rd: Reg, offset: i32 },
    /// Indirect jump and link.
    Jalr { rd: Reg, rs1: Reg, offset: i32 },
    /// Conditional branch.
    Branch { op: BranchOp, rs1: Reg, rs2: Reg, offset: i32 },
    /// Memory load.
    Load { op: LoadOp, rd: Reg, rs1: Reg, offset: i32 },
    /// Memory store.
    Store { op: StoreOp, rs1: Reg, rs2: Reg, offset: i32 },
    /// ALU with immediate operand (`Sub` is not encodable here).
    OpImm { op: AluOp, rd: Reg, rs1: Reg, imm: i32 },
    /// Register-register ALU.
    Op { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg },
    /// RV32M multiply/divide.
    MulDiv { op: MulOp, rd: Reg, rs1: Reg, rs2: Reg },
    /// The paper's mixed-precision MAC: `rd += Σ aᵢ·wᵢ` over the packed
    /// operands selected by `mode` (see module docs for the register-pair
    /// activation sourcing).
    NnMac { mode: MacMode, rd: Reg, rs1: Reg, rs2: Reg },
    /// CSR access (used to read `mcycle`/`minstret`/custom counters).
    Csr { op: CsrOp, rd: Reg, rs1: Reg, csr: u16 },
    /// Memory ordering fence (a timing no-op on the in-order core).
    Fence,
    /// Environment call — terminates simulation (the ISS "exit").
    Ecall,
    /// Breakpoint.
    Ebreak,
}

impl Instr {
    /// Destination register written by this instruction, if any.
    pub fn rd(&self) -> Option<Reg> {
        match *self {
            Instr::Lui { rd, .. }
            | Instr::Auipc { rd, .. }
            | Instr::Jal { rd, .. }
            | Instr::Jalr { rd, .. }
            | Instr::Load { rd, .. }
            | Instr::OpImm { rd, .. }
            | Instr::Op { rd, .. }
            | Instr::MulDiv { rd, .. }
            | Instr::NnMac { rd, .. }
            | Instr::Csr { rd, .. } => Some(rd),
            _ => None,
        }
    }

    /// True for the custom mixed-precision MAC instructions.
    pub fn is_nn_mac(&self) -> bool {
        matches!(self, Instr::NnMac { .. })
    }

    /// True for loads and stores (memory-access accounting, Fig. 4).
    pub fn is_mem(&self) -> bool {
        matches!(self, Instr::Load { .. } | Instr::Store { .. })
    }
}

/// Well-known CSR addresses (machine counters as in Ibex).
pub mod csr {
    /// Cycle counter, low 32 bits.
    pub const MCYCLE: u16 = 0xB00;
    /// Retired-instruction counter, low 32 bits.
    pub const MINSTRET: u16 = 0xB02;
    /// Cycle counter, high 32 bits.
    pub const MCYCLEH: u16 = 0xB80;
    /// Retired-instruction counter, high 32 bits.
    pub const MINSTRETH: u16 = 0xB82;
    /// Custom: total data-memory loads (mhpmcounter3 slot).
    pub const MHPM_LOADS: u16 = 0xB03;
    /// Custom: total data-memory stores (mhpmcounter4 slot).
    pub const MHPM_STORES: u16 = 0xB04;
    /// Custom: total MAC operations retired (mhpmcounter5 slot).
    pub const MHPM_MACS: u16 = 0xB05;
}

/// Common ABI register names.
pub mod reg {
    use super::Reg;
    pub const ZERO: Reg = 0;
    pub const RA: Reg = 1;
    pub const SP: Reg = 2;
    pub const GP: Reg = 3;
    pub const TP: Reg = 4;
    pub const T0: Reg = 5;
    pub const T1: Reg = 6;
    pub const T2: Reg = 7;
    pub const S0: Reg = 8;
    pub const S1: Reg = 9;
    pub const A0: Reg = 10;
    pub const A1: Reg = 11;
    pub const A2: Reg = 12;
    pub const A3: Reg = 13;
    pub const A4: Reg = 14;
    pub const A5: Reg = 15;
    pub const A6: Reg = 16;
    pub const A7: Reg = 17;
    pub const S2: Reg = 18;
    pub const S3: Reg = 19;
    pub const S4: Reg = 20;
    pub const S5: Reg = 21;
    pub const S6: Reg = 22;
    pub const S7: Reg = 23;
    pub const S8: Reg = 24;
    pub const S9: Reg = 25;
    pub const S10: Reg = 26;
    pub const S11: Reg = 27;
    pub const T3: Reg = 28;
    pub const T4: Reg = 29;
    pub const T5: Reg = 30;
    pub const T6: Reg = 31;

    /// ABI name for a register index (used by the disassembler).
    pub fn name(r: Reg) -> &'static str {
        const NAMES: [&str; 32] = [
            "zero", "ra", "sp", "gp", "tp", "t0", "t1", "t2", "s0", "s1", "a0", "a1", "a2", "a3",
            "a4", "a5", "a6", "a7", "s2", "s3", "s4", "s5", "s6", "s7", "s8", "s9", "s10", "s11",
            "t3", "t4", "t5", "t6",
        ];
        NAMES[(r & 31) as usize]
    }
}
