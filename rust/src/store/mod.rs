//! Content-addressed persistent result store for evaluation reports.
//!
//! A DSE sweep's dominant cost is the accuracy evaluation; everything
//! an evaluation produces is a pure function of *what was evaluated*:
//! the model content + configuration + kernel modes (the plan content
//! fingerprint, [`crate::models::plan::content_fingerprint`]), the
//! evaluation dataset, the sample count, the MAC-unit features of the
//! simulated core, and the backend that ran it. [`StoreKey`] is
//! exactly that tuple; [`ResultStore`] maps it to the backend's
//! [`EvalReport`] on disk, so a result computed once — by any process,
//! on any host sharing the directory — is served everywhere else as a
//! file read.
//!
//! Only the `EvalReport` is persisted. The cycle/MAC-cost fields of an
//! [`EvalPoint`](crate::dse::EvalPoint) are recomputed locally by the
//! coordinator from its `CycleModel` (deterministic), so a warm
//! store-backed sweep writes byte-identical figure JSON by
//! construction — the same mechanism that makes shard merges bit-exact.
//!
//! Durability contract:
//!
//! * **Atomic writes** — entries are written to a temp file in the
//!   fan-out directory and `rename`d into place; readers never observe
//!   a half-written entry, and a crash leaves only an ignorable
//!   `.tmp.*` file.
//! * **Quarantine, never garbage** — a corrupt/truncated/mistagged
//!   entry surfaces as a typed [`StoreError`] on the strict
//!   [`ResultStore::load`] path; the lenient [`ResultStore::get`] path
//!   renames it aside to `<entry>.json.bad`, counts a miss, and lets
//!   the caller recompute. The store never panics and never silently
//!   serves a wrong report.
//! * **Pinned backends only** — `auto` resolves per machine (see
//!   `docs/EVALUATORS.md` § backend choice under sharded sweeps), so a
//!   key carrying it would alias results from different backends
//!   across hosts. [`StoreKey::new`] rejects it.
//!
//! Layout: `<root>/<hh>/<key16>.json` where `hh` is the first two hex
//! digits of the 16-hex-digit key hash (256-way fan-out keeps
//! directories small under large sweeps).

use crate::coordinator::EvalReport;
use crate::json::{Json, SchemaError};
use crate::models::synthetic::Dataset;
use crate::sim::MacUnitConfig;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Schema version of on-disk entries. Bump on any incompatible change
/// to the record shape; readers treat other versions as typed errors
/// (quarantined on the lenient path), never as silently-parsed data.
pub const STORE_SCHEMA_VERSION: u64 = 1;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x1000_0000_01b3;

/// FNV-1a digest of an evaluation dataset: image shapes + pixel bit
/// patterns, labels, and the class count. Two datasets that differ in
/// any sample (or sample order — evaluations take prefixes) never
/// share a digest, so results from different eval sets never alias in
/// the store.
pub fn dataset_digest(ds: &Dataset) -> u64 {
    let mut h = FNV_OFFSET;
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    };
    for b in (ds.num_classes as u64).to_le_bytes() {
        eat(b);
    }
    for b in (ds.images.len() as u64).to_le_bytes() {
        eat(b);
    }
    for img in &ds.images {
        for &d in &img.shape {
            for b in (d as u64).to_le_bytes() {
                eat(b);
            }
        }
        for &v in &img.data {
            for b in v.to_bits().to_le_bytes() {
                eat(b);
            }
        }
    }
    for &l in &ds.labels {
        for b in (l as u64).to_le_bytes() {
            eat(b);
        }
    }
    h
}

/// The content-addressed identity of one evaluation result. Every
/// component participates in the key hash — flipping any of model
/// content, bits, modes, dataset, sample count, backend, or MAC-unit
/// features produces a different key (`tests/store.rs` pins the
/// sensitivity matrix).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreKey {
    /// Plan content fingerprint
    /// ([`crate::models::plan::content_fingerprint`]): model content +
    /// bit vector + per-layer kernel modes.
    pub plan_fingerprint: u64,
    /// Evaluation-dataset digest ([`dataset_digest`]).
    pub dataset_digest: u64,
    /// Samples the evaluation scored (after clamping to the backend's
    /// eval-set length — the *effective* n, so requesting more samples
    /// than exist doesn't mint a second key for the same computation).
    pub n_eval: usize,
    /// Resolved backend label (`host`/`iss`/`analytic`/`pjrt`). Never
    /// `auto` — [`StoreKey::new`] rejects unpinned tags.
    pub backend: String,
    /// MAC-unit features of the simulated core the backend ran,
    /// including the cluster `cores` axis (machine identity — results
    /// priced for different cluster geometries never alias).
    pub mac: MacUnitConfig,
}

impl StoreKey {
    /// Build a key; rejects an unpinned (`auto`) or empty backend tag
    /// with [`StoreError::UnpinnedBackend`] — `auto` resolves per
    /// machine, so it would key the same logical result inconsistently
    /// across hosts sharing the store.
    pub fn new(
        plan_fingerprint: u64,
        dataset_digest: u64,
        n_eval: usize,
        backend: &str,
        mac: MacUnitConfig,
    ) -> Result<StoreKey, StoreError> {
        if backend == "auto" || backend.is_empty() {
            return Err(StoreError::UnpinnedBackend { tag: backend.to_string() });
        }
        Ok(StoreKey {
            plan_fingerprint,
            dataset_digest,
            n_eval,
            backend: backend.to_string(),
            mac,
        })
    }

    /// FNV-1a hash over every key component.
    pub fn hash(&self) -> u64 {
        let mut h = FNV_OFFSET;
        let mut eat = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        };
        for b in self.plan_fingerprint.to_le_bytes() {
            eat(b);
        }
        for b in self.dataset_digest.to_le_bytes() {
            eat(b);
        }
        for b in (self.n_eval as u64).to_le_bytes() {
            eat(b);
        }
        for b in self.backend.bytes() {
            eat(b);
        }
        eat(0xff); // backend / mac separator
        eat(self.mac.multipump as u8);
        eat(self.mac.soft_simd as u8);
        // The cluster axis joins the key only when it departs from the
        // single-core default: cores=1 keys (and on-disk entries) stay
        // byte-identical to stores written before the axis existed.
        if self.mac.cores > 1 {
            eat(0xfe); // mac / cluster separator
            for b in (self.mac.cores as u64).to_le_bytes() {
                eat(b);
            }
        }
        h
    }

    /// 16-hex-digit entry name.
    pub fn hex(&self) -> String {
        format!("{:016x}", self.hash())
    }
}

/// Typed store failure. The strict read path ([`ResultStore::load`])
/// returns these; the lenient path ([`ResultStore::get`]) converts
/// everything except `Missing` into quarantine + miss.
#[derive(Debug)]
pub enum StoreError {
    /// No entry for the key (a plain miss, not a fault).
    Missing {
        /// Entry path probed.
        path: PathBuf,
    },
    /// Filesystem failure reading or writing an entry.
    Io {
        /// Path involved.
        path: PathBuf,
        /// Underlying error text.
        err: String,
    },
    /// Entry is not parseable JSON (truncated write, bit rot).
    Parse {
        /// Entry path.
        path: PathBuf,
        /// Parser diagnosis.
        msg: String,
    },
    /// Entry parses but violates the record schema.
    Schema {
        /// Entry path.
        path: PathBuf,
        /// Field-level diagnosis.
        err: SchemaError,
    },
    /// Entry was written under a different schema version.
    Version {
        /// Entry path.
        path: PathBuf,
        /// Version found in the file.
        found: u64,
    },
    /// Entry's stored key components disagree with the requested key
    /// (hash collision or a mistagged/hand-edited file) — served as a
    /// typed error, never as a wrong report.
    KeyMismatch {
        /// Entry path.
        path: PathBuf,
    },
    /// Key construction refused an unpinned backend tag.
    UnpinnedBackend {
        /// The offending tag (`auto` or empty).
        tag: String,
    },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::Missing { path } => write!(f, "no store entry at {}", path.display()),
            StoreError::Io { path, err } => {
                write!(f, "store I/O error at {}: {err}", path.display())
            }
            StoreError::Parse { path, msg } => {
                write!(f, "corrupt store entry {}: {msg}", path.display())
            }
            StoreError::Schema { path, err } => {
                write!(f, "malformed store entry {}: {err}", path.display())
            }
            StoreError::Version { path, found } => write!(
                f,
                "store entry {} has schema version {found} (this build reads {})",
                path.display(),
                STORE_SCHEMA_VERSION
            ),
            StoreError::KeyMismatch { path } => write!(
                f,
                "store entry {} does not match the requested key (collision or mistagged file)",
                path.display()
            ),
            StoreError::UnpinnedBackend { tag } => write!(
                f,
                "store keys need a pinned backend, got `{tag}`: `auto` resolves per machine \
                 (pass --evaluator host|iss|analytic|pjrt; see docs/EVALUATORS.md)"
            ),
        }
    }
}

impl std::error::Error for StoreError {}

/// One entry as returned by [`ResultStore::scan`]: the informational
/// fields recorded alongside the report (enough to recompose
/// [`EvalPoint`](crate::dse::EvalPoint)s for Pareto queries without
/// re-deriving any key).
#[derive(Debug, Clone, PartialEq)]
pub struct StoredEntry {
    /// 16-hex entry name (the key hash).
    pub key: String,
    /// Model name the result was computed for.
    pub model: String,
    /// Per-layer bit-width configuration.
    pub bits: Vec<u32>,
    /// Backend that produced the report.
    pub backend: String,
    /// Effective evaluation sample count.
    pub n_eval: usize,
    /// The stored report.
    pub report: EvalReport,
}

/// The on-disk content-addressed store. Counters are process-local
/// observability (the coordinator's `Metrics` mirror them per sweep).
pub struct ResultStore {
    root: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
    quarantined: AtomicU64,
}

fn io_err(path: &Path, e: std::io::Error) -> StoreError {
    StoreError::Io { path: path.to_path_buf(), err: e.to_string() }
}

impl ResultStore {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: &Path) -> Result<ResultStore, StoreError> {
        std::fs::create_dir_all(root).map_err(|e| io_err(root, e))?;
        Ok(ResultStore {
            root: root.to_path_buf(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
        })
    }

    /// Store root directory.
    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Entry path for a key: `<root>/<hh>/<key16>.json`.
    pub fn path_for(&self, key: &StoreKey) -> PathBuf {
        let hex = key.hex();
        self.root.join(&hex[..2]).join(format!("{hex}.json"))
    }

    /// `(hits, misses, quarantined)` since this handle opened.
    pub fn counters(&self) -> (u64, u64, u64) {
        (
            self.hits.load(Ordering::Relaxed),
            self.misses.load(Ordering::Relaxed),
            self.quarantined.load(Ordering::Relaxed),
        )
    }

    /// Strict read: the report for `key`, or a typed error saying
    /// exactly what is wrong with the entry ([`StoreError::Missing`]
    /// for a plain absence). Does not touch the counters.
    pub fn load(&self, key: &StoreKey) -> Result<EvalReport, StoreError> {
        let path = self.path_for(key);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(StoreError::Missing { path })
            }
            Err(e) => return Err(io_err(&path, e)),
        };
        let j = Json::parse(&text)
            .map_err(|e| StoreError::Parse { path: path.clone(), msg: e.to_string() })?;
        let schema = |err| StoreError::Schema { path: path.clone(), err };
        let version = j.req_u64("schema").map_err(schema)?;
        if version != STORE_SCHEMA_VERSION {
            return Err(StoreError::Version { path: path.clone(), found: version });
        }
        // Cross-check every stored key component against the request: a
        // hash collision or a mistagged file must fail typed, never
        // serve someone else's report.
        let fp = parse_u64_str(&j, "plan_fingerprint").map_err(schema)?;
        let dd = parse_u64_str(&j, "dataset_digest").map_err(schema)?;
        let matches = j.req_str("key").map_err(schema)? == key.hex()
            && j.req_str("backend").map_err(schema)? == key.backend
            && j.req_u64("n_eval").map_err(schema)? as usize == key.n_eval
            && fp == key.plan_fingerprint
            && dd == key.dataset_digest
            && j.req_bool("multipump").map_err(schema)? == key.mac.multipump
            && j.req_bool("soft_simd").map_err(schema)? == key.mac.soft_simd
            && parse_cores(&j).map_err(schema)? == key.mac.cores;
        if !matches {
            return Err(StoreError::KeyMismatch { path: path.clone() });
        }
        report_from_json(&j).map_err(schema)
    }

    /// Lenient read for the evaluation hot path: `Some(report)` on a
    /// hit, `None` on a miss. Any fault (corrupt, truncated, wrong
    /// schema, mistagged) quarantines the entry to `<entry>.json.bad`,
    /// logs it, and counts as a miss — the caller recomputes and the
    /// next `put` re-creates a clean entry.
    pub fn get(&self, key: &StoreKey) -> Option<EvalReport> {
        match self.load(key) {
            Ok(r) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(r)
            }
            Err(StoreError::Missing { .. }) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
            Err(e) => {
                let path = self.path_for(key);
                let bad = PathBuf::from(format!("{}.bad", path.display()));
                if std::fs::rename(&path, &bad).is_ok() {
                    self.quarantined.fetch_add(1, Ordering::Relaxed);
                }
                eprintln!("[store] quarantined {} -> .bad ({e})", path.display());
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Write (or overwrite) the entry for `key` atomically: the record
    /// is serialized to a `.tmp.*` file in the fan-out directory and
    /// renamed into place, so concurrent readers (and crash leftovers)
    /// never see a partial entry. `model`/`bits` are informational
    /// fields for [`ResultStore::scan`] consumers.
    pub fn put(
        &self,
        key: &StoreKey,
        model: &str,
        bits: &[u32],
        report: &EvalReport,
    ) -> Result<(), StoreError> {
        let path = self.path_for(key);
        let dir = path.parent().expect("entry path has a fan-out parent");
        std::fs::create_dir_all(dir).map_err(|e| io_err(dir, e))?;
        let tmp = dir.join(format!(".tmp.{}.{}", key.hex(), std::process::id()));
        std::fs::write(&tmp, entry_json(key, model, bits, report).to_string())
            .map_err(|e| io_err(&tmp, e))?;
        std::fs::rename(&tmp, &path).map_err(|e| io_err(&path, e))?;
        Ok(())
    }

    /// Walk every well-formed entry in the store, sorted by key for
    /// deterministic output. Temp files, quarantined `.bad` files and
    /// unparseable entries are skipped (a scan is a query, not an
    /// integrity pass — keyed `get` owns the quarantine policy).
    pub fn scan(&self) -> Result<Vec<StoredEntry>, StoreError> {
        let mut out = Vec::new();
        let fans = std::fs::read_dir(&self.root).map_err(|e| io_err(&self.root, e))?;
        for fan in fans.filter_map(|e| e.ok()) {
            if !fan.path().is_dir() {
                continue;
            }
            let files = match std::fs::read_dir(fan.path()) {
                Ok(f) => f,
                Err(_) => continue,
            };
            for f in files.filter_map(|e| e.ok()) {
                let path = f.path();
                let name = match path.file_name().and_then(|n| n.to_str()) {
                    Some(n) => n,
                    None => continue,
                };
                if !name.ends_with(".json") || name.starts_with(".tmp.") {
                    continue;
                }
                let Ok(text) = std::fs::read_to_string(&path) else { continue };
                let Ok(j) = Json::parse(&text) else { continue };
                if j.req_u64("schema").ok() != Some(STORE_SCHEMA_VERSION) {
                    continue;
                }
                let entry = (|| -> Result<StoredEntry, SchemaError> {
                    Ok(StoredEntry {
                        key: j.req_str("key")?.to_string(),
                        model: j.req_str("model")?.to_string(),
                        bits: parse_bits(&j)?,
                        backend: j.req_str("backend")?.to_string(),
                        n_eval: j.req_u64("n_eval")? as usize,
                        report: report_from_json(&j)?,
                    })
                })();
                if let Ok(e) = entry {
                    out.push(e);
                }
            }
        }
        out.sort_by(|a, b| a.key.cmp(&b.key));
        Ok(out)
    }
}

/// u64 stored as a decimal string (the shard-artifact convention:
/// fingerprints do not survive the JSON number path, which is f64).
fn parse_u64_str(j: &Json, field: &str) -> Result<u64, SchemaError> {
    j.req_str(field)?.parse::<u64>().map_err(|_| SchemaError {
        field: field.to_string(),
        msg: "expected a decimal u64 string".to_string(),
    })
}

fn parse_bits(j: &Json) -> Result<Vec<u32>, SchemaError> {
    j.req_arr("bits")?
        .iter()
        .map(|b| match b.as_f64() {
            Some(v) if v >= 0.0 && v == v.trunc() => Ok(v as u32),
            _ => Err(SchemaError {
                field: "bits".to_string(),
                msg: "expected non-negative integers".to_string(),
            }),
        })
        .collect()
}

/// The stored cluster-cores component: emitted only when it departs
/// from the single-core default, so pre-cluster entries (no `cores`
/// field) parse as cores=1 and cores=1 entries stay byte-identical to
/// what older builds wrote.
fn parse_cores(j: &Json) -> Result<usize, SchemaError> {
    Ok(j.opt("cores", |v| match v.as_f64() {
        Some(x) if x.is_finite() && x >= 1.0 && x == x.trunc() => Ok(x as usize),
        _ => Err(SchemaError {
            field: "cores".to_string(),
            msg: "expected a positive integer".to_string(),
        }),
    })?
    .unwrap_or(1))
}

fn entry_json(key: &StoreKey, model: &str, bits: &[u32], r: &EvalReport) -> Json {
    let mut fields = vec![
        ("schema", Json::i(STORE_SCHEMA_VERSION as i64)),
        ("key", Json::s(&key.hex())),
        ("model", Json::s(model)),
        ("bits", Json::Arr(bits.iter().map(|&b| Json::i(b as i64)).collect())),
        ("backend", Json::s(&key.backend)),
        ("n_eval", Json::i(key.n_eval as i64)),
        ("plan_fingerprint", Json::s(&key.plan_fingerprint.to_string())),
        ("dataset_digest", Json::s(&key.dataset_digest.to_string())),
        ("multipump", Json::Bool(key.mac.multipump)),
        ("soft_simd", Json::Bool(key.mac.soft_simd)),
    ];
    // Conditional like the key hash: cores=1 entries match pre-cluster
    // builds byte-for-byte (see `parse_cores`).
    if key.mac.cores > 1 {
        fields.push(("cores", Json::i(key.mac.cores as i64)));
    }
    fields.extend([
        // f32 -> f64 -> JSON -> f64 -> f32 round-trips exactly (Rust's
        // shortest-round-trip float printing), so warm reads restore
        // bit-identical accuracy/divergence values.
        ("accuracy", Json::Num(r.accuracy as f64)),
        ("iss_cycles", r.iss_cycles.map_or(Json::Null, |c| Json::i(c as i64))),
        ("iss_mem_accesses", r.iss_mem_accesses.map_or(Json::Null, |c| Json::i(c as i64))),
        ("divergence", r.divergence.map_or(Json::Null, |d| Json::Num(d as f64))),
        ("audited", r.audited.map_or(Json::Null, |a| Json::i(a as i64))),
    ]);
    Json::obj(fields)
}

fn report_from_json(j: &Json) -> Result<EvalReport, SchemaError> {
    let opt_u64 = |field: &str| {
        j.opt(field, |v| match v.as_f64() {
            Some(x) if x >= 0.0 && x.is_finite() && x == x.trunc() => Ok(x as u64),
            _ => Err(SchemaError {
                field: field.to_string(),
                msg: "expected a non-negative integer".to_string(),
            }),
        })
    };
    Ok(EvalReport {
        accuracy: j.req_f64("accuracy")? as f32,
        iss_cycles: opt_u64("iss_cycles")?,
        iss_mem_accesses: opt_u64("iss_mem_accesses")?,
        divergence: j.opt("divergence", |v| match v.as_f64() {
            Some(x) if x.is_finite() => Ok(x as f32),
            _ => Err(SchemaError {
                field: "divergence".to_string(),
                msg: "expected a finite number".to_string(),
            }),
        })?,
        audited: opt_u64("audited")?.map(|a| a as u32),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(n: usize, backend: &str) -> StoreKey {
        StoreKey::new(0x1111, 0x2222, n, backend, MacUnitConfig::full()).unwrap()
    }

    #[test]
    fn unpinned_backend_is_rejected() {
        for tag in ["auto", ""] {
            match StoreKey::new(1, 2, 3, tag, MacUnitConfig::full()) {
                Err(StoreError::UnpinnedBackend { tag: t }) => assert_eq!(t, tag),
                other => panic!("expected UnpinnedBackend, got {other:?}"),
            }
        }
    }

    #[test]
    fn key_hash_is_component_sensitive() {
        let base = key(8, "host");
        assert_ne!(base.hash(), key(9, "host").hash());
        assert_ne!(base.hash(), key(8, "iss").hash());
        let mut mac = base.clone();
        mac.mac = MacUnitConfig::packing_only();
        assert_ne!(base.hash(), mac.hash());
        // The cluster axis: cores=1 is the pre-cluster key (explicit
        // with_cores(1) must not mint a new hash), any other count must.
        let mut one = base.clone();
        one.mac = MacUnitConfig::full().with_cores(1);
        assert_eq!(base.hash(), one.hash());
        let mut four = base.clone();
        four.mac = MacUnitConfig::full().with_cores(4);
        assert_ne!(base.hash(), four.hash());
        let mut two = base.clone();
        two.mac = MacUnitConfig::full().with_cores(2);
        assert_ne!(four.hash(), two.hash());
        // Stable across calls (the fan-out layout depends on it).
        assert_eq!(base.hex(), key(8, "host").hex());
        assert_eq!(base.hex().len(), 16);
    }

    #[test]
    fn put_get_round_trips_and_counts() {
        let dir = std::env::temp_dir().join(format!("mpnn_store_unit_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = ResultStore::open(&dir).unwrap();
        let k = key(8, "iss");
        assert!(store.get(&k).is_none());
        let r = EvalReport {
            accuracy: 0.8125,
            iss_cycles: Some(1234),
            iss_mem_accesses: Some(567),
            divergence: Some(0.0),
            audited: None,
        };
        store.put(&k, "lenet5", &[8, 4, 4, 2, 8], &r).unwrap();
        assert_eq!(store.get(&k), Some(r));
        let entries = store.scan().unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].model, "lenet5");
        assert_eq!(entries[0].bits, vec![8, 4, 4, 2, 8]);
        assert_eq!(entries[0].report, r);
        assert_eq!(store.counters(), (1, 1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
