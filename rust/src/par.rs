//! Tiny shared worker-pool primitive: run `n_jobs` independent
//! fallible jobs over scoped threads, preserving job order.
//!
//! Used by the DSE cycle-model build and the whole-model batch runner;
//! the coordinator keeps its own bounded-queue pool because it needs
//! backpressure against a producer, which this fan-out does not model.

use crate::error::{Error, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Run `f(0..n_jobs)` over `workers` scoped threads and collect the
/// results in job order. The first job error wins (remaining queued
/// jobs are abandoned) and is returned after all workers stop.
pub fn parallel_map<T, F>(n_jobs: usize, workers: usize, f: F) -> Result<Vec<T>>
where
    T: Send,
    F: Fn(usize) -> Result<T> + Sync,
{
    let results: Mutex<Vec<Option<T>>> = Mutex::new((0..n_jobs).map(|_| None).collect());
    let first_err: Mutex<Option<Error>> = Mutex::new(None);
    let next = AtomicUsize::new(0);
    let workers = workers.clamp(1, n_jobs.max(1));

    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let j = next.fetch_add(1, Ordering::Relaxed);
                if j >= n_jobs || first_err.lock().unwrap().is_some() {
                    break;
                }
                match f(j) {
                    Ok(v) => results.lock().unwrap()[j] = Some(v),
                    Err(e) => {
                        let mut fe = first_err.lock().unwrap();
                        if fe.is_none() {
                            *fe = Some(e);
                        }
                    }
                }
            });
        }
    });

    if let Some(e) = first_err.into_inner().unwrap() {
        return Err(e);
    }
    Ok(results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every job ran"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order_across_workers() {
        let out = parallel_map(100, 7, |j| Ok(j * j)).unwrap();
        assert_eq!(out.len(), 100);
        for (j, v) in out.iter().enumerate() {
            assert_eq!(*v, j * j);
        }
    }

    #[test]
    fn propagates_the_first_error() {
        let r: Result<Vec<usize>> = parallel_map(50, 4, |j| {
            if j == 17 {
                Err(Error::msg("boom"))
            } else {
                Ok(j)
            }
        });
        assert_eq!(r.unwrap_err().to_msg(), "boom");
    }

    #[test]
    fn zero_jobs_is_fine() {
        let out: Vec<u32> = parallel_map(0, 4, |_| Ok(1)).unwrap();
        assert!(out.is_empty());
    }
}
