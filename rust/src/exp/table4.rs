//! Table 4 — FPGA (Virtex-7) and ASIC (ASAP7) comparison of the
//! baseline vs modified Ibex: clocks, power, area and per-model energy
//! efficiency (GOP/s/W) for <1%-accuracy-loss configurations.

use super::fig8::ModelSelections;
use super::ExpOpts;
use crate::energy::{EnergyReport, ASIC_BASELINE, ASIC_MODIFIED, FPGA_BASELINE, FPGA_MODIFIED};
use crate::json::Json;
use crate::error::Result;

/// Per-model Table-4 energy row.
#[derive(Debug, Clone)]
pub struct Row {
    /// Model name.
    pub model: String,
    /// MACs per inference.
    pub macs: u64,
    /// Baseline / modified cycles.
    pub cycles: (u64, u64),
    /// FPGA baseline / modified reports.
    pub fpga: (EnergyReport, EnergyReport),
    /// ASIC baseline / modified reports.
    pub asic: (EnergyReport, EnergyReport),
}

/// Build Table 4 from Fig.-8 selections (uses each model's <1% config;
/// falls back to the least-aggressive available selection).
pub fn from_selections(opts: &ExpOpts, sels: &[ModelSelections]) -> Result<(Vec<Row>, Json)> {
    let mut rows = Vec::new();
    for m in sels {
        let model = opts.load_model(&m.model)?;
        let analysis = crate::models::analyze(&model.spec);
        let sel = m
            .selections
            .iter()
            .flatten()
            .next()
            .or_else(|| m.selections.iter().flatten().last());
        let Some(sel) = sel else { continue };
        let macs = analysis.total_macs;
        let cycles = (m.baseline_cycles, sel.cycles);
        rows.push(Row {
            model: m.model.clone(),
            macs,
            cycles,
            fpga: (FPGA_BASELINE.evaluate(macs, cycles.0), FPGA_MODIFIED.evaluate(macs, cycles.1)),
            asic: (ASIC_BASELINE.evaluate(macs, cycles.0), ASIC_MODIFIED.evaluate(macs, cycles.1)),
        });
    }
    print(&rows);
    Ok((rows.clone(), to_json(&rows)))
}

/// Print the Table-4 report.
pub fn print(rows: &[Row]) {
    println!("Table 4 — platform comparison (models with <1% accuracy loss)");
    println!(
        "  FPGA: baseline {:.0} MHz / {:.0} mW vs modified {:.0}/{:.0} MHz / {:.0} mW (area +{:.0}% LUT)",
        FPGA_BASELINE.core_clock_hz / 1e6,
        FPGA_BASELINE.power_w * 1e3,
        FPGA_MODIFIED.core_clock_hz / 1e6,
        FPGA_MODIFIED.unit_clock_hz / 1e6,
        FPGA_MODIFIED.power_w * 1e3,
        FPGA_MODIFIED.area_overhead(&FPGA_BASELINE) * 100.0
    );
    println!(
        "  ASIC: baseline {:.0} MHz / {:.2} mW vs modified {:.0}/{:.0} MHz / {:.2} mW (area +{:.0}%)",
        ASIC_BASELINE.core_clock_hz / 1e6,
        ASIC_BASELINE.power_w * 1e3,
        ASIC_MODIFIED.core_clock_hz / 1e6,
        ASIC_MODIFIED.unit_clock_hz / 1e6,
        ASIC_MODIFIED.power_w * 1e3,
        ASIC_MODIFIED.area_overhead(&ASIC_BASELINE) * 100.0
    );
    println!(
        "{:<14} {:>10} {:>22} {:>22} {:>8}",
        "Model", "speedup", "FPGA GOP/s/W (b→m)", "ASIC GOP/s/W (b→m)", "gain"
    );
    for r in rows {
        let gain = r.asic.1.gops_per_w / r.asic.0.gops_per_w;
        println!(
            "{:<14} {:>9.1}x {:>10.3} → {:>8.2} {:>10.1} → {:>8.1} {:>7.1}x",
            r.model,
            r.cycles.0 as f64 / r.cycles.1 as f64,
            r.fpga.0.gops_per_w,
            r.fpga.1.gops_per_w,
            r.asic.0.gops_per_w,
            r.asic.1.gops_per_w,
            gain
        );
    }
}

/// JSON encoding.
pub fn to_json(rows: &[Row]) -> Json {
    Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("model", Json::s(&r.model)),
                    ("macs", Json::i(r.macs as i64)),
                    ("baseline_cycles", Json::i(r.cycles.0 as i64)),
                    ("modified_cycles", Json::i(r.cycles.1 as i64)),
                    ("fpga_gopsw_base", Json::Num(r.fpga.0.gops_per_w)),
                    ("fpga_gopsw_mod", Json::Num(r.fpga.1.gops_per_w)),
                    ("asic_gopsw_base", Json::Num(r.asic.0.gops_per_w)),
                    ("asic_gopsw_mod", Json::Num(r.asic.1.gops_per_w)),
                    ("asic_gain", Json::Num(r.asic.1.gops_per_w / r.asic.0.gops_per_w)),
                ])
            })
            .collect(),
    )
}

/// Standalone run (performs its own sweeps).
pub fn run(opts: &ExpOpts) -> Result<(Vec<Row>, Json)> {
    let (sels, _) = super::fig8::run(opts)?;
    from_selections(opts, &sels)
}
