//! Table 3 — baseline model characteristics: accuracy, topology,
//! baseline cycles (original Ibex running the scalar kernels) and MACs.

use super::{topology_string, ExpOpts, MODEL_NAMES};
use crate::dse::cycles::measure_layer;
use crate::json::Json;
use crate::models::analyze;
use crate::sim::MacUnitConfig;
use crate::error::Result;

/// One Table-3 row.
#[derive(Debug, Clone)]
pub struct Row {
    /// Model name.
    pub model: String,
    /// Float-model accuracy (%).
    pub acc: f32,
    /// Topology (paper notation).
    pub topology: String,
    /// Baseline cycles for one inference.
    pub cycles: u64,
    /// MAC count for one inference.
    pub macs: u64,
}

/// Run the Table-3 harness.
pub fn run(opts: &ExpOpts) -> Result<(Vec<Row>, Json)> {
    let mut rows = Vec::new();
    for name in MODEL_NAMES {
        let model = opts.load_model(name)?;
        let a = analyze(&model.spec);
        let mut cycles = 0u64;
        for (i, l) in a.layers.iter().enumerate() {
            cycles += measure_layer(l, None, MacUnitConfig::full(), opts.seed + i as u64)?.cycles;
        }
        rows.push(Row {
            model: name.to_string(),
            acc: model.float_acc * 100.0,
            topology: topology_string(&model.spec),
            cycles,
            macs: a.total_macs,
        });
    }
    println!("Table 3: baseline models (scaled reproductions — see DESIGN.md §5)");
    println!("{:<14} {:>8} {:>12} {:>14} {:>12}", "Model", "Acc(%)", "Topology", "#cycles", "#MAC");
    for r in &rows {
        println!(
            "{:<14} {:>8.1} {:>12} {:>14} {:>12}",
            r.model, r.acc, r.topology, r.cycles, r.macs
        );
    }
    let json = Json::Arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("model", Json::s(&r.model)),
                    ("acc_pct", Json::Num(r.acc as f64)),
                    ("topology", Json::s(&r.topology)),
                    ("cycles", Json::i(r.cycles as i64)),
                    ("macs", Json::i(r.macs as i64)),
                ])
            })
            .collect(),
    );
    Ok((rows, json))
}
