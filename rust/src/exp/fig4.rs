//! Fig. 4 — per-layer memory-access reduction on MobileNetV1 delivered
//! by the new instructions, for three mixed-precision models of
//! increasing aggressiveness (<1%, ~2%, ~5% accuracy loss).

use super::ExpOpts;
use crate::dse::cycles::CycleModel;
use crate::json::Json;
use crate::models::analyze;
use crate::error::Result;

/// Per-layer reductions for one configuration.
#[derive(Debug, Clone)]
pub struct ConfigReduction {
    /// Configuration label.
    pub label: String,
    /// Per-layer bit-widths.
    pub bits: Vec<u32>,
    /// Per-layer access reduction (fraction).
    pub per_layer: Vec<f64>,
    /// Average reduction across layers.
    pub average: f64,
}

/// Representative configurations when no sweep selections are supplied:
/// conservative (mostly 8/4), medium (4), aggressive (4/2) — mirroring
/// the three models the paper examines.
pub fn default_configs(n: usize) -> Vec<(String, Vec<u32>)> {
    let mut conservative = vec![4u32; n];
    conservative[0] = 8;
    for i in 1..n / 3 {
        conservative[i] = 8;
    }
    let mut medium = vec![4u32; n];
    medium[0] = 8;
    let mut aggressive = vec![2u32; n];
    aggressive[0] = 8;
    for i in 1..n / 4 {
        aggressive[i] = 4;
    }
    vec![
        ("<1% loss".to_string(), conservative),
        ("~2% loss".to_string(), medium),
        ("~5% loss".to_string(), aggressive),
    ]
}

/// Run the Fig.-4 harness with explicit configurations (e.g. the Fig.-8
/// selections) or the defaults.
pub fn run_with(
    opts: &ExpOpts,
    configs: Option<Vec<(String, Vec<u32>)>>,
) -> Result<(Vec<ConfigReduction>, Json)> {
    let model = opts.load_model("mobilenet_v1")?;
    let analysis = analyze(&model.spec);
    let cm = CycleModel::build(&analysis, crate::sim::MacUnitConfig::full(), opts.seed)?;
    let configs = configs.unwrap_or_else(|| default_configs(analysis.layers.len()));
    let mut out = Vec::new();
    for (label, bits) in configs {
        let per_layer: Vec<f64> = (0..analysis.layers.len())
            .map(|i| {
                let base = cm.baseline[i].mem_accesses as f64;
                let ext = cm.layer_cost(i, bits[i]).mem_accesses as f64;
                1.0 - ext / base
            })
            .collect();
        let average = per_layer.iter().sum::<f64>() / per_layer.len() as f64;
        out.push(ConfigReduction { label, bits, per_layer, average });
    }
    println!("Fig. 4 — MobileNetV1 per-layer memory-access reduction");
    for c in &out {
        println!("  {}: average {:.1}%", c.label, c.average * 100.0);
        let cells: Vec<String> =
            c.per_layer.iter().map(|r| format!("{:.0}", r * 100.0)).collect();
        println!("    per-layer %: [{}]", cells.join(" "));
    }
    let json = Json::Arr(
        out.iter()
            .map(|c| {
                Json::obj(vec![
                    ("label", Json::s(&c.label)),
                    ("bits", Json::Arr(c.bits.iter().map(|&b| Json::i(b as i64)).collect())),
                    ("per_layer", Json::nums(c.per_layer.iter().copied())),
                    ("average", Json::Num(c.average)),
                ])
            })
            .collect(),
    );
    Ok((out, json))
}

/// Run with the default representative configurations.
pub fn run(opts: &ExpOpts) -> Result<(Vec<ConfigReduction>, Json)> {
    run_with(opts, None)
}
