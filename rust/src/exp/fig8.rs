//! Fig. 8 — end-to-end speedup over the baseline Ibex for the DSE
//! configurations selected under 1% / 2% / 5% accuracy-loss thresholds,
//! with the per-layer bit-widths of each selection.
//!
//! Under `--search guided` the selection runs on the guided sweep's
//! fully-evaluated subset. The selected *speedup* is never worse than
//! the exhaustive selection's — the threshold rule minimises cycles,
//! the guided subset contains the exhaustive cycle front, and every
//! config missing from the subset is dominated on cycles at no less
//! accuracy — but when several configs tie on cycles within the
//! threshold, the guided run may report a different (equal-cycles)
//! representative than exhaustive does.

use super::fig6::{sweep_model, Sweep};
use super::ExpOpts;
use crate::dse::select_under_threshold;
use crate::json::Json;
use crate::error::Result;

/// The paper's accuracy-loss thresholds.
pub const THRESHOLDS: [f32; 3] = [0.01, 0.02, 0.05];

/// One selected configuration.
#[derive(Debug, Clone)]
pub struct Selection {
    /// Threshold used.
    pub threshold: f32,
    /// Selected per-layer bit-widths.
    pub bits: Vec<u32>,
    /// Accuracy at the selection.
    pub accuracy: f32,
    /// End-to-end speedup vs baseline.
    pub speedup: f64,
    /// Memory-access reduction vs baseline.
    pub mem_reduction: f64,
    /// Cycles.
    pub cycles: u64,
    /// Average of per-layer speedups — the metric behind the paper's
    /// "13.1×–17.8× on average for all layers" claim (conv/dense layers
    /// dominate; depthwise layers drag the mean down exactly as the
    /// paper observes for MCUNet/MobileNet).
    pub layer_avg_speedup: f64,
    /// Host-vs-ISS top-1 divergence at the selected configuration
    /// (populated when the sweep ran on the `iss` evaluator).
    pub divergence: Option<f32>,
}

/// Per-model Fig.-8 result.
pub struct ModelSelections {
    /// Model name.
    pub model: String,
    /// Float accuracy.
    pub float_acc: f32,
    /// Baseline cycles / accesses.
    pub baseline_cycles: u64,
    /// Baseline memory accesses.
    pub baseline_accesses: u64,
    /// One selection per threshold (None if nothing met it).
    pub selections: Vec<Option<Selection>>,
    /// The sweep this came from.
    pub sweep: Sweep,
}

/// Select under thresholds from an existing sweep. On a multi-core
/// sweep (`--cores` > 1) the points' cycle totals were priced through
/// the cluster overlay, so the baseline is priced the same way — e2e
/// speedups always compare like machine against like machine. The
/// per-layer average stays a single-core kernel metric (the paper's
/// Fig.-8 per-layer claim); cluster scaling applies to both sides of
/// that ratio and would only add partition-rounding noise.
pub fn select(sweep: Sweep) -> ModelSelections {
    let cluster = sweep.coordinator.cluster();
    let base = if cluster.is_single() {
        sweep.coordinator.cycle_model.baseline_total()
    } else {
        sweep.coordinator.cycle_model.cluster_baseline_total(&cluster).cost
    };
    let cm = &sweep.coordinator.cycle_model;
    let selections = THRESHOLDS
        .iter()
        .map(|&t| {
            select_under_threshold(&sweep.points, sweep.float_acc, t).map(|i| {
                let p = &sweep.points[i];
                let layer_avg = p
                    .config
                    .iter()
                    .enumerate()
                    .map(|(l, &b)| {
                        cm.baseline[l].cycles as f64 / cm.layer_cost(l, b).cycles as f64
                    })
                    .sum::<f64>()
                    / p.config.len() as f64;
                Selection {
                    threshold: t,
                    bits: p.config.clone(),
                    accuracy: p.accuracy,
                    speedup: base.cycles as f64 / p.cycles as f64,
                    mem_reduction: 1.0 - p.mem_accesses as f64 / base.mem_accesses as f64,
                    cycles: p.cycles,
                    layer_avg_speedup: layer_avg,
                    divergence: p.divergence,
                }
            })
        })
        .collect();
    ModelSelections {
        model: sweep.model.clone(),
        float_acc: sweep.float_acc,
        baseline_cycles: base.cycles,
        baseline_accesses: base.mem_accesses,
        selections,
        sweep,
    }
}

/// Run the Fig.-8 harness (shares sweeps with Fig. 6 in the CLI's
/// `all`). With `--merge <shard files…>` the threshold selection runs
/// on sweeps recombined from shard artifacts instead of re-evaluating
/// — and since the merge is bit-identical to the single-instance
/// sweep, the selections are too. `--shard` is rejected here: the
/// selection rule needs the *whole* Pareto space, so shards are
/// produced by `fig6 --shard` and consumed here via `--merge`.
pub fn run(opts: &ExpOpts) -> Result<(Vec<ModelSelections>, Json)> {
    crate::ensure!(
        opts.shard.is_none(),
        "fig8 needs the full config space; run `fig6 --shard i/n` per shard, \
         then `fig8 --merge <artifacts…>`"
    );
    let mut out = Vec::new();
    if !opts.wants_merge() {
        for name in opts.model_names()? {
            eprintln!("[fig8] {name}");
            let sweep = sweep_model(opts, name)?;
            out.push(select(sweep));
        }
    } else {
        for sweep in super::fig6::sweeps_from_merge(opts)? {
            eprintln!("[fig8] {} (from merged shards)", sweep.model);
            out.push(select(sweep));
        }
    }
    let json = to_json(&out);
    print(&out);
    Ok((out, json))
}

/// Print the Fig.-8 table.
pub fn print(out: &[ModelSelections]) {
    for m in out {
        println!(
            "Fig. 8 — {} (float acc {:.1}%, baseline {} cycles)",
            m.model,
            m.float_acc * 100.0,
            m.baseline_cycles
        );
        for sel in m.selections.iter().flatten() {
            let bits: Vec<String> = sel.bits.iter().map(|b| b.to_string()).collect();
            let div = match sel.divergence {
                Some(d) => format!("  div {:>4.1}%", d * 100.0),
                None => String::new(),
            };
            println!(
                "  <{:>2.0}% loss: e2e {:>5.1}x  layer-avg {:>5.1}x  acc {:>5.1}%  mem-red {:>4.1}%  bits [{}]{}",
                sel.threshold * 100.0,
                sel.speedup,
                sel.layer_avg_speedup,
                sel.accuracy * 100.0,
                sel.mem_reduction * 100.0,
                bits.join(","),
                div
            );
        }
    }
}

/// JSON encoding.
pub fn to_json(out: &[ModelSelections]) -> Json {
    Json::Arr(
        out.iter()
            .map(|m| {
                let mut fields = vec![
                    ("model", Json::s(&m.model)),
                    ("float_acc", Json::Num(m.float_acc as f64)),
                    ("baseline_cycles", Json::i(m.baseline_cycles as i64)),
                ];
                // Conditional like fig6's sweep JSON: single-core
                // output stays byte-identical to pre-cluster builds.
                if let Some(r) = &m.sweep.cluster {
                    fields.push(("cores", Json::i(r.cores as i64)));
                }
                fields.push((
                        "selections",
                        Json::Arr(
                            m.selections
                                .iter()
                                .map(|s| match s {
                                    None => Json::Null,
                                    Some(s) => Json::obj(vec![
                                        ("threshold", Json::Num(s.threshold as f64)),
                                        ("speedup", Json::Num(s.speedup)),
                                        ("layer_avg_speedup", Json::Num(s.layer_avg_speedup)),
                                        ("accuracy", Json::Num(s.accuracy as f64)),
                                        ("mem_reduction", Json::Num(s.mem_reduction)),
                                        ("cycles", Json::i(s.cycles as i64)),
                                        (
                                            "divergence",
                                            s.divergence
                                                .map_or(Json::Null, |d| Json::Num(d as f64)),
                                        ),
                                        (
                                            "bits",
                                            Json::Arr(
                                                s.bits
                                                    .iter()
                                                    .map(|&b| Json::i(b as i64))
                                                    .collect(),
                                            ),
                                        ),
                                    ]),
                                })
                                .collect(),
                        ),
                    ));
                Json::obj(fields)
            })
            .collect(),
    )
}
