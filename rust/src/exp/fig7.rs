//! Fig. 7 — per-Mode cycle breakdown on one dense layer (MobileNetV1's
//! final classifier) and one convolution layer (the CIFAR-10 CNN's 2nd
//! conv), decomposing the contribution of each optimisation:
//! packing/parallelisation (Mode-1 technique), + multi-pumping (Mode-2),
//! + soft SIMD (Mode-3), each evaluated at all three weight widths.

use super::ExpOpts;
use crate::dse::cycles::measure_layer;
use crate::isa::MacMode;
use crate::json::Json;
use crate::models::{analyze, QKind, QLayerInfo};
use crate::sim::MacUnitConfig;
use crate::error::Result;

/// Cycle measurements for one layer at one weight width.
#[derive(Debug, Clone)]
pub struct WidthRow {
    /// Weight bits.
    pub bits: u32,
    /// Baseline scalar-kernel cycles.
    pub baseline: u64,
    /// Packing/parallelisation only (standalone Mode-1 technique).
    pub packing: u64,
    /// Packing + multi-pumping (standalone Mode-2).
    pub multipump: u64,
    /// Packing + multi-pumping + soft SIMD (full Mode-3 datapath).
    pub soft_simd: u64,
}

/// Results for one layer.
#[derive(Debug, Clone)]
pub struct LayerBreakdown {
    /// Display label.
    pub label: String,
    /// Per-width rows.
    pub rows: Vec<WidthRow>,
}

fn breakdown(label: &str, info: &QLayerInfo, seed: u64) -> Result<LayerBreakdown> {
    let mut rows = Vec::new();
    let base = measure_layer(info, None, MacUnitConfig::full(), seed)?.cycles;
    for bits in [8u32, 4, 2] {
        let mode = MacMode::from_weight_bits(bits).unwrap();
        let p = measure_layer(info, Some(mode), MacUnitConfig::packing_only(), seed)?.cycles;
        let mp = measure_layer(info, Some(mode), MacUnitConfig::multipump_only(), seed)?.cycles;
        let ss = measure_layer(info, Some(mode), MacUnitConfig::full(), seed)?.cycles;
        rows.push(WidthRow { bits, baseline: base, packing: p, multipump: mp, soft_simd: ss });
    }
    Ok(LayerBreakdown { label: label.to_string(), rows })
}

/// Run the Fig.-7 harness.
pub fn run(opts: &ExpOpts) -> Result<(Vec<LayerBreakdown>, Json)> {
    let mobilenet = opts.load_model("mobilenet_v1")?;
    let cifar = opts.load_model("cifar_cnn")?;
    let ma = analyze(&mobilenet.spec);
    let ca = analyze(&cifar.spec);
    let dense = ma.layers.iter().find(|l| l.kind == QKind::Dense).unwrap();
    let conv2 = ca.layers.iter().filter(|l| l.kind == QKind::Conv).nth(1).unwrap();
    let out = vec![
        breakdown("dense (MobileNetV1 classifier)", dense, opts.seed)?,
        breakdown("conv (CIFAR10 CNN layer 2)", conv2, opts.seed ^ 1)?,
    ];
    for lb in &out {
        println!("Fig. 7 — {}", lb.label);
        println!(
            "{:>5} {:>12} {:>12} {:>12} {:>12}   speedups: P / +MP / +SS",
            "bits", "baseline", "packing", "+multipump", "+softSIMD"
        );
        for r in &lb.rows {
            println!(
                "{:>5} {:>12} {:>12} {:>12} {:>12}   {:.1}x / {:.1}x / {:.1}x",
                r.bits,
                r.baseline,
                r.packing,
                r.multipump,
                r.soft_simd,
                r.baseline as f64 / r.packing as f64,
                r.baseline as f64 / r.multipump as f64,
                r.baseline as f64 / r.soft_simd as f64,
            );
        }
    }
    let json = Json::Arr(
        out.iter()
            .map(|lb| {
                Json::obj(vec![
                    ("layer", Json::s(&lb.label)),
                    (
                        "rows",
                        Json::Arr(
                            lb.rows
                                .iter()
                                .map(|r| {
                                    Json::obj(vec![
                                        ("bits", Json::i(r.bits as i64)),
                                        ("baseline", Json::i(r.baseline as i64)),
                                        ("packing", Json::i(r.packing as i64)),
                                        ("multipump", Json::i(r.multipump as i64)),
                                        ("soft_simd", Json::i(r.soft_simd as i64)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    );
    Ok((out, json))
}
