//! Experiment harnesses — one per table/figure of the paper's evaluation
//! section (the DESIGN.md experiment index maps each to its module):
//!
//! | module   | reproduces |
//! |----------|------------|
//! | [`table3`] | Table 3 — baseline model characteristics |
//! | [`fig4`]   | Fig. 4 — per-layer memory-access reduction (MobileNetV1) |
//! | [`fig6`]   | Fig. 6 — accuracy-vs-MAC-instruction Pareto spaces |
//! | [`fig7`]   | Fig. 7 — per-Mode cycle breakdown (dense + conv layer) |
//! | [`fig8`]   | Fig. 8 — end-to-end speedup at 1/2/5% accuracy loss |
//! | [`table4`] | Table 4 — FPGA/ASIC energy-efficiency comparison |
//! | [`table5`] | Table 5 — state-of-the-art comparison |
//!
//! Every harness prints a human-readable table and returns a JSON value
//! that the CLI writes under `results/`.

pub mod fig4;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod table3;
pub mod table4;
pub mod table5;

use crate::coordinator::{AccuracyEval, AnalyticEval, Coordinator, HostEval, IssEval, PjrtEval};
use crate::json::Json;
use crate::models::format::{load_or_fallback, LoadedModel};
use crate::error::Result;
use std::path::{Path, PathBuf};

/// Accuracy-backend selector threaded from the CLI through the
/// experiment harnesses into the coordinator (see `docs/EVALUATORS.md`
/// for the trade-offs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EvalBackend {
    /// PJRT when the model's AOT artifact exists, host reference
    /// otherwise — the zero-configuration default.
    #[default]
    Auto,
    /// Host integer forward pass (fast; no ISA-level fidelity).
    Host,
    /// Whole-model execution on the ISS: accuracy and cycles from the
    /// same binary-level runs, plus the host-vs-ISS divergence metric.
    Iss,
    /// The ISS evaluator's analytic fast path: each distinct kernel
    /// shape runs on the ISS once, then replays as a host kernel with
    /// cache-served counters; `--audit-every K` samples real-ISS
    /// replays to re-check the contract.
    Analytic,
    /// Batched PJRT inference (needs artifacts + the `pjrt` feature;
    /// degrades to the host evaluator with a note).
    Pjrt,
}

impl EvalBackend {
    /// Parse a CLI name (`auto | host | iss | analytic | pjrt`).
    pub fn parse(s: &str) -> Option<EvalBackend> {
        match s {
            "auto" => Some(EvalBackend::Auto),
            "host" => Some(EvalBackend::Host),
            "iss" => Some(EvalBackend::Iss),
            "analytic" => Some(EvalBackend::Analytic),
            "pjrt" => Some(EvalBackend::Pjrt),
            _ => None,
        }
    }

    /// Label for logs/usage text.
    pub fn name(self) -> &'static str {
        match self {
            EvalBackend::Auto => "auto",
            EvalBackend::Host => "host",
            EvalBackend::Iss => "iss",
            EvalBackend::Analytic => "analytic",
            EvalBackend::Pjrt => "pjrt",
        }
    }
}

/// Experiment options shared by the CLI and the benches.
#[derive(Debug, Clone)]
pub struct ExpOpts {
    /// Artifacts directory.
    pub artifacts: PathBuf,
    /// Images per accuracy evaluation during sweeps.
    pub eval_n: usize,
    /// Configuration budget per model for the DSE sweeps.
    pub budget: usize,
    /// Accuracy backend for the sweeps.
    pub backend: EvalBackend,
    /// Worker threads the ISS evaluator fans each input batch over.
    pub eval_workers: usize,
    /// Random seed.
    pub seed: u64,
    /// Run only this shard of each model's sweep (`--shard i/n`): the
    /// harness evaluates the shard's slice of the config space and
    /// writes a [`ShardArtifact`](crate::dse::shard::ShardArtifact)
    /// instead of a full result. `None` = unsharded.
    pub shard: Option<crate::dse::shard::ShardSpec>,
    /// Directory shard artifacts are written into (`--shard-out`,
    /// default `results/shards`).
    pub shard_out: Option<PathBuf>,
    /// Shard artifacts to merge (`--merge <file>`, repeatable): the
    /// sweep harnesses recombine these instead of re-evaluating.
    pub merge: Vec<PathBuf>,
    /// Directory whose `*.s<i>of<n>.json` shard artifacts are all
    /// merged (`--merge-dir`, the convenience form of repeating
    /// `--merge`; combinable with explicit `--merge` files).
    pub merge_dir: Option<PathBuf>,
    /// Restrict the sweep harnesses to these models (`--models a,b`);
    /// `None` = all of [`MODEL_NAMES`].
    pub models: Option<Vec<String>>,
    /// JSONL output path for the `trace` command's per-step plan trace
    /// (`--trace-steps`).
    pub trace_steps: Option<PathBuf>,
    /// Audit cadence for the analytic evaluator (`--audit-every <k>`):
    /// replay every kth batch element on the real ISS and bit-compare.
    /// 0 (the default) disables auditing; 1 degenerates to a full ISS
    /// check of every element.
    pub audit_every: usize,
    /// Sweep search strategy (`--search exhaustive|guided`). Exhaustive
    /// (the default) evaluates every enumerated configuration and is
    /// the oracle the guided search is property-checked against.
    pub search: crate::dse::search::SearchStrategy,
    /// Successive-halving rung count for `--search guided` (`--rungs`).
    pub rungs: usize,
    /// Halving factor for `--search guided` (`--eta`).
    pub eta: usize,
    /// Hard cap on the enumerated space size a sweep harness will run
    /// (`--space-budget`). The config space streams lazily, but an
    /// exhaustive sweep still *evaluates* (and holds a point for)
    /// every configuration — this knob makes an accidentally huge
    /// sweep fail with a typed error up front, pointing at `--search
    /// guided` / sharding, instead of grinding or OOMing. `None` (the
    /// default) is unbounded.
    pub space_budget: Option<usize>,
    /// Cap on the configurations the guided driver may materialize for
    /// full evaluation (`--max-alive`; see
    /// [`GuidedOpts::max_alive`](crate::dse::search::GuidedOpts)).
    pub max_alive: Option<usize>,
    /// Cluster core count for the multi-core cost overlay (`--cores`).
    /// 1 (the default) is the single-core paper configuration and
    /// reproduces the existing outputs byte-for-byte; N>1 prices every
    /// configuration through the banked-TCDM cluster model
    /// (`sim::cluster`) and adds per-core utilization / bank-conflict
    /// stall reporting to the sweep harnesses.
    pub cores: usize,
    /// Root of the persistent content-addressed result store
    /// (`--store <dir>`): evaluation reports are looked up before the
    /// backend runs and written back after, so repeated sweeps — and
    /// concurrent shard workers pointed at a shared directory — pay
    /// each unique configuration once. Requires a pinned `--evaluator`
    /// (`auto` would key the store inconsistently across machines).
    pub store: Option<PathBuf>,
    /// Listen address for `mpnn serve` (`--addr`).
    pub addr: String,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            artifacts: crate::runtime::default_artifacts_dir(),
            eval_n: 128,
            budget: 120,
            backend: EvalBackend::Auto,
            eval_workers: 4,
            seed: 0xD5E,
            shard: None,
            shard_out: None,
            merge: Vec::new(),
            merge_dir: None,
            models: None,
            trace_steps: None,
            audit_every: 0,
            search: crate::dse::search::SearchStrategy::Exhaustive,
            rungs: 3,
            eta: 2,
            space_budget: None,
            max_alive: None,
            cores: 1,
            store: None,
            addr: "127.0.0.1:7979".to_string(),
        }
    }
}

/// Does `name` look like a shard-artifact filename,
/// `<stem>.s<i>of<n>.json` (the shape [`crate::exp::fig6::shard_artifact_path`]
/// writes)? The `--merge-dir` glob admits exactly these.
pub fn is_shard_artifact_name(name: &str) -> bool {
    let Some(stem) = name.strip_suffix(".json") else { return false };
    let Some(pos) = stem.rfind(".s") else { return false };
    let tail = &stem[pos + 2..];
    let Some((i, n)) = tail.split_once("of") else { return false };
    !i.is_empty()
        && !n.is_empty()
        && i.bytes().all(|b| b.is_ascii_digit())
        && n.bytes().all(|b| b.is_ascii_digit())
}

impl ExpOpts {
    /// Load a model artifact (or the random-init fallback).
    pub fn load_model(&self, name: &str) -> Result<LoadedModel> {
        load_or_fallback(&self.artifacts, name, self.seed)
    }

    /// The guided-search knobs as a [`GuidedOpts`](crate::dse::search::GuidedOpts)
    /// (rung promotion reuses the sweep seed).
    pub fn guided_opts(&self) -> crate::dse::search::GuidedOpts {
        crate::dse::search::GuidedOpts {
            rungs: self.rungs,
            eta: self.eta,
            seed: self.seed,
            max_alive: self.max_alive,
        }
    }

    /// Enforce `--space-budget` against a lazily enumerated space —
    /// every sweep harness calls this before streaming a single
    /// config, so an over-budget sweep degrades loudly, never by OOM
    /// or a surprise multi-hour run.
    pub fn check_space(&self, space: &crate::dse::ConfigSpace) -> Result<()> {
        if let Some(cap) = self.space_budget {
            crate::ensure!(
                space.len() <= cap,
                "config space of {} exceeds --space-budget {cap}; raise the cap, lower \
                 --budget, or split the sweep (--shard / --search guided)",
                space.len()
            );
        }
        Ok(())
    }

    /// Build the accuracy evaluator selected by [`ExpOpts::backend`].
    /// `Auto` prefers PJRT when the model artifact exists and quietly
    /// uses the host reference otherwise; an explicit `pjrt` request
    /// that cannot be satisfied (missing artifact, or the crate was
    /// built without the `pjrt` feature) degrades to the host evaluator
    /// with a note.
    pub fn evaluator(&self, model: &LoadedModel, batch: usize) -> Result<Box<dyn AccuracyEval>> {
        match self.backend {
            EvalBackend::Host => Ok(Box::new(HostEval { test: model.test.clone() })),
            EvalBackend::Iss => {
                Ok(Box::new(IssEval::new(model.test.clone(), self.eval_workers)))
            }
            EvalBackend::Analytic => {
                let mut ev = AnalyticEval::new(model.test.clone(), self.eval_workers);
                ev.audit_every = self.audit_every;
                ev.audit_seed = self.seed;
                Ok(Box::new(ev))
            }
            EvalBackend::Auto | EvalBackend::Pjrt => {
                let stem =
                    self.artifacts.join(format!("{}_qfwd_b{batch}.hlo.txt", model.spec.name));
                if stem.exists() {
                    match crate::runtime::Session::open(&self.artifacts) {
                        Ok(session) => {
                            return Ok(Box::new(PjrtEval::new(
                                session,
                                model.test.clone(),
                                batch,
                            )))
                        }
                        Err(e) => {
                            eprintln!("[exp] PJRT unavailable ({e}); using the host evaluator");
                        }
                    }
                } else if self.backend == EvalBackend::Pjrt {
                    eprintln!(
                        "[exp] no PJRT artifact for `{}`; using the host evaluator",
                        model.spec.name
                    );
                }
                Ok(Box::new(HostEval { test: model.test.clone() }))
            }
        }
    }

    /// Build a coordinator for a model, attaching the persistent
    /// result store when `--store` is set. The store keys include the
    /// resolved backend tag, so a pinned `--evaluator` is required —
    /// `auto` resolves differently per machine and would silently
    /// split (or worse, mix) the shared store.
    pub fn coordinator(&self, name: &str) -> Result<Coordinator> {
        let model = self.load_model(name)?;
        let eval = self.evaluator(&model, 64)?;
        let mut c = Coordinator::new(model, eval, 2)?;
        // Cluster geometry must be pinned before the store attaches:
        // the store key carries the cores axis.
        c.set_cluster(self.cores)?;
        if let Some(dir) = &self.store {
            crate::ensure!(
                self.backend != EvalBackend::Auto,
                "--store requires a pinned --evaluator (host|iss|analytic|pjrt); `auto` \
                 resolves per machine and would key the store inconsistently"
            );
            c.attach_store(crate::store::ResultStore::open(dir)?)?;
        }
        Ok(c)
    }

    /// The models the sweep harnesses (fig6/fig8) iterate: the
    /// `--models` subset when given (validated against
    /// [`MODEL_NAMES`], in paper order), all four otherwise.
    pub fn model_names(&self) -> Result<Vec<&'static str>> {
        match &self.models {
            None => Ok(MODEL_NAMES.to_vec()),
            Some(wanted) => {
                for w in wanted {
                    crate::ensure!(
                        MODEL_NAMES.contains(&w.as_str()),
                        "unknown model `{w}` (known: {})",
                        MODEL_NAMES.join(", ")
                    );
                }
                Ok(MODEL_NAMES
                    .into_iter()
                    .filter(|n| wanted.iter().any(|w| w == n))
                    .collect())
            }
        }
    }

    /// Directory shard artifacts are written into.
    pub fn shard_dir(&self) -> PathBuf {
        self.shard_out.clone().unwrap_or_else(|| Path::new("results").join("shards"))
    }

    /// Was any merge input given (`--merge` and/or `--merge-dir`)?
    pub fn wants_merge(&self) -> bool {
        !self.merge.is_empty() || self.merge_dir.is_some()
    }

    /// Every shard artifact to merge: the explicit `--merge` files plus
    /// the `--merge-dir` directory's `*.s<i>of<n>.json` files (sorted
    /// by path for determinism; the merge itself is order-insensitive).
    /// An empty `--merge-dir` is an error — silently merging nothing
    /// would mask a typo'd directory.
    pub fn merge_inputs(&self) -> Result<Vec<PathBuf>> {
        use crate::error::Context;
        let mut files = self.merge.clone();
        if let Some(dir) = &self.merge_dir {
            let mut found: Vec<PathBuf> = std::fs::read_dir(dir)
                .with_context(|| format!("reading --merge-dir {}", dir.display()))?
                .filter_map(|e| e.ok())
                .map(|e| e.path())
                .filter(|p| {
                    p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(is_shard_artifact_name)
                })
                .collect();
            found.sort();
            crate::ensure!(
                !found.is_empty(),
                "--merge-dir {}: no `*.s<i>of<n>.json` shard artifacts found",
                dir.display()
            );
            files.extend(found);
        }
        Ok(files)
    }
}

/// Write an experiment result under `results/<name>.json`.
pub fn write_result(name: &str, value: &Json) -> Result<()> {
    let dir = Path::new("results");
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{name}.json")), value.to_string())?;
    Ok(())
}

/// The four Table-3 benchmark names in paper order.
pub const MODEL_NAMES: [&str; 4] = ["cifar_cnn", "lenet5", "mcunet_vww", "mobilenet_v1"];

/// Topology string in the paper's C/R/D notation.
pub fn topology_string(spec: &crate::models::ModelSpec) -> String {
    use crate::models::{LayerSpec, Node};
    let mut convs = 0;
    let mut dense = 0;
    let mut res = 0;
    for n in &spec.nodes {
        match n {
            Node::Residual(_) => res += 1,
            Node::Layer(LayerSpec::Conv { .. }) | Node::Layer(LayerSpec::Depthwise { .. }) => {
                convs += 1
            }
            Node::Layer(LayerSpec::Dense { .. }) => dense += 1,
            _ => {}
        }
    }
    // MobileNet counts dw+pw pairs as one "C" in the paper's notation;
    // MCUNet counts every inverted-residual block as an "R" whether or
    // not the skip connection applies.
    if spec.name == "mobilenet_v1" {
        convs = 1 + (convs - 1) / 2;
    }
    if spec.name == "mcunet_vww" {
        res += (convs - 1) / 3;
        convs = 1;
    }
    if res > 0 {
        format!("{convs}C-{res}R-{dense}D")
    } else {
        format!("{convs}C-{dense}D")
    }
}
