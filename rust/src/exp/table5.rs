//! Table 5 — comparison with state-of-the-art solutions: literature
//! rows (constants from the paper) + our row computed from measured
//! cycles through the ASAP7 platform model. The efficiency range spans
//! <1% to ≤5% accuracy-loss configurations.

use super::fig8::ModelSelections;
use super::ExpOpts;
use crate::energy::sota::{competitors, ours, SotaEntry};
use crate::energy::ASIC_MODIFIED;
use crate::json::Json;
use crate::error::Result;

/// Build Table 5 from Fig.-8 selections.
pub fn from_selections(opts: &ExpOpts, sels: &[ModelSelections]) -> Result<(Vec<SotaEntry>, Json)> {
    // Our GOPs / GOPs/W across models: lo = <1% selections, hi = 5%.
    let mut lo_eff: Vec<f64> = Vec::new();
    let mut hi_eff: Vec<f64> = Vec::new();
    let mut lo_gops: Vec<f64> = Vec::new();
    let mut hi_gops: Vec<f64> = Vec::new();
    for m in sels {
        let model = opts.load_model(&m.model)?;
        let macs = crate::models::analyze(&model.spec).total_macs;
        if let Some(s) = m.selections.first().and_then(|s| s.as_ref()) {
            let r = ASIC_MODIFIED.evaluate(macs, s.cycles);
            lo_eff.push(r.gops_per_w);
            lo_gops.push(r.gops);
        }
        if let Some(s) = m.selections.last().and_then(|s| s.as_ref()) {
            let r = ASIC_MODIFIED.evaluate(macs, s.cycles);
            hi_eff.push(r.gops_per_w);
            hi_gops.push(r.gops);
        }
    }
    let min = |v: &[f64]| v.iter().copied().fold(f64::INFINITY, f64::min);
    let max = |v: &[f64]| v.iter().copied().fold(0.0f64, f64::max);
    let mut table = competitors();
    table.push(ours(min(&lo_gops), max(&hi_gops), min(&lo_eff), max(&hi_eff)));
    print(&table);
    let json = to_json(&table);
    Ok((table, json))
}

/// Print the Table-5 report.
pub fn print(table: &[SotaEntry]) {
    println!("Table 5 — comparison with state-of-the-art");
    println!(
        "{:<22} {:>6} {:>10} {:>9} {:>10} {:>16} {:>20}",
        "Work", "node", "precision", "clk MHz", "power mW", "GOPs", "GOPs/W"
    );
    for e in table {
        let fmt_range = |(lo, hi): (f64, f64)| {
            if (lo - hi).abs() < 1e-9 {
                format!("{lo:.2}")
            } else {
                format!("{lo:.2}-{hi:.2}")
            }
        };
        println!(
            "{:<22} {:>6} {:>10} {:>9.0} {:>10.2} {:>16} {:>20}",
            e.work,
            e.platform,
            e.precision,
            e.clk_mhz,
            e.power_mw,
            fmt_range(e.gops),
            fmt_range(e.gops_per_w)
        );
    }
}

/// JSON encoding.
pub fn to_json(table: &[SotaEntry]) -> Json {
    Json::Arr(
        table
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("work", Json::s(e.work)),
                    ("platform", Json::s(e.platform)),
                    ("precision", Json::s(e.precision)),
                    ("clk_mhz", Json::Num(e.clk_mhz)),
                    ("power_mw", Json::Num(e.power_mw)),
                    ("gops_lo", Json::Num(e.gops.0)),
                    ("gops_hi", Json::Num(e.gops.1)),
                    ("gopsw_lo", Json::Num(e.gops_per_w.0)),
                    ("gopsw_hi", Json::Num(e.gops_per_w.1)),
                ])
            })
            .collect(),
    )
}

/// Standalone run.
pub fn run(opts: &ExpOpts) -> Result<(Vec<SotaEntry>, Json)> {
    let (sels, _) = super::fig8::run(opts)?;
    from_selections(opts, &sels)
}
