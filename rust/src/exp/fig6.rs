//! Fig. 6 — the accuracy-vs-MAC-instruction Pareto spaces from the
//! mixed-precision DSE (gray points = all configurations, squares = the
//! Pareto front, star = the float baseline).
//!
//! The sweep also runs **sharded**: `--shard i/n` evaluates only shard
//! `i`'s slice of each model's config space and writes a versioned
//! [`ShardArtifact`] instead of a full result; `--merge <files…>`
//! recombines shard artifacts into the exact single-instance sweep
//! (same points, same Pareto indices — see [`crate::dse::shard`]) and
//! then prints/serialises through the identical code path, so the
//! merged `results/fig6.json` is byte-for-byte what an unsharded run
//! writes. The CI smoke job and `tests/sweep_sharding.rs` hold that
//! equality.
//!
//! With `--search guided` the sweep goes through the predictor-guided
//! driver ([`crate::dse::search`]): analytic-bound pruning plus
//! successive halving cut the number of full evaluations while the
//! Pareto front stays exactly the exhaustive one (zero regret by
//! construction — `tests/search_oracle.rs` property-checks this
//! against the exhaustive oracle). Guided sweeps shard and merge too;
//! their artifacts are tagged with the strategy and the merge refuses
//! to mix guided and exhaustive shards.

use super::ExpOpts;
use crate::coordinator::Coordinator;
use crate::dse::pareto::pareto_front;
use crate::dse::search::SearchStrategy;
use crate::dse::shard::{merge, ShardArtifact, ShardSpec};
use crate::dse::{default_pinned, ConfigSpace, EvalPoint};
use crate::json::Json;
use crate::error::Result;
use std::path::{Path, PathBuf};

/// Sweep result for one model.
pub struct Sweep {
    /// Model name.
    pub model: String,
    /// Float baseline accuracy.
    pub float_acc: f32,
    /// Baseline MAC-instruction count (one mul per MAC).
    pub baseline_instrs: u64,
    /// Every evaluated point.
    pub points: Vec<EvalPoint>,
    /// Global enumeration index of each entry in `points` (same order).
    /// Exhaustive sweeps carry `0..points.len()`; guided sweeps carry
    /// only the fully-evaluated subset's indices.
    pub indices: Vec<usize>,
    /// Indices **into `points`** of the Pareto front (by MAC
    /// instructions).
    pub front: Vec<usize>,
    /// Accuracy backend that scored the points (`host`/`iss`/`pjrt`).
    pub evaluator: &'static str,
    /// Search strategy that produced the points.
    pub search: SearchStrategy,
    /// Cluster-execution summary when the sweep priced for a multi-core
    /// cluster (`--cores` > 1); `None` on the single-core machine, which
    /// keeps the serialised sweep byte-identical to pre-cluster output.
    pub cluster: Option<ClusterReport>,
    /// The coordinator (kept for downstream reuse, e.g. Fig. 8).
    pub coordinator: Coordinator,
}

/// How the cluster executed the sweep's baseline (all-8-bit)
/// configuration: the headline scaling numbers the `cluster` JSON block
/// and the stderr ledger report.
pub struct ClusterReport {
    /// Cores the sweep priced for.
    pub cores: usize,
    /// Shared TCDM banks.
    pub banks: usize,
    /// Single-core baseline cycles (the denominator of the scaling).
    pub cycles_single: u64,
    /// Cluster baseline cycles (sum of per-layer barriers).
    pub cycles: u64,
    /// Per-core utilization over the critical path, in `[0, 1]`.
    pub utilization: Vec<f64>,
    /// Bank-conflict stall cycles summed over cores and layers.
    pub bank_stalls: u64,
}

/// The sweep's cluster summary: the baseline (all-8-bit) model priced
/// through the cluster overlay. `None` on the single-core machine.
fn cluster_report(coordinator: &Coordinator) -> Option<ClusterReport> {
    let cluster = coordinator.cluster();
    if cluster.is_single() {
        return None;
    }
    let clustered = coordinator.cycle_model.cluster_baseline_total(&cluster);
    Some(ClusterReport {
        cores: cluster.cores,
        banks: cluster.banks,
        cycles_single: coordinator.cycle_model.baseline_total().cycles,
        cycles: clustered.cost.cycles,
        utilization: clustered.perf.utilization(),
        bank_stalls: clustered.perf.total_bank_stalls(),
    })
}

/// The stderr cluster ledger (one line per model, grepped by the CI
/// cluster-smoke job): core count, baseline scaling, stalls and the
/// per-core utilization vector.
fn print_cluster_ledger(model: &str, r: &ClusterReport) {
    eprintln!(
        "[fig6] cluster ({model}): {} cores / {} banks, baseline {} -> {} cycles \
         ({:.2}x), {} bank-conflict stalls, utilization [{}]",
        r.cores,
        r.banks,
        r.cycles_single,
        r.cycles,
        r.cycles_single as f64 / r.cycles.max(1) as f64,
        r.bank_stalls,
        r.utilization.iter().map(|u| format!("{u:.3}")).collect::<Vec<_>>().join(", "),
    );
}

impl Sweep {
    /// Largest host-vs-ISS top-1 divergence across the sweep, when the
    /// backend computed it (the `iss` evaluator's differential check).
    pub fn max_divergence(&self) -> Option<f32> {
        self.points.iter().filter_map(|p| p.divergence).reduce(f32::max)
    }
}

/// Run the DSE sweep for one model — exhaustive, or through the guided
/// driver ([`crate::dse::search::guided_search`]) under `--search
/// guided`. The guided sweep's Pareto front is identical to the
/// exhaustive one (zero regret by construction); only the set of
/// evaluated points shrinks, which the stderr ledger line reports.
pub fn sweep_model(opts: &ExpOpts, name: &str) -> Result<Sweep> {
    let coordinator = opts.coordinator(name)?;
    let analysis = crate::models::analyze(&coordinator.model.spec);
    let n = analysis.layers.len();
    // The space stays lazy: both drivers stream configs by global
    // enumeration index and decode one at a time.
    let space = ConfigSpace::new(n, &default_pinned(), opts.budget, opts.seed);
    opts.check_space(&space)?;
    let (indices, points): (Vec<usize>, Vec<EvalPoint>) = match opts.search {
        SearchStrategy::Exhaustive => {
            let points = coordinator.run_sweep_space(&space, opts.eval_n)?;
            ((0..points.len()).collect(), points)
        }
        SearchStrategy::Guided => {
            let g = coordinator.sweep_guided_space(&space, opts.eval_n, &opts.guided_opts())?;
            eprintln!(
                "[fig6] guided search ({name}): {}/{} configs fully evaluated \
                 ({} partial evals, {} pruned, {} halved, {} repaired, peak alive {})",
                g.stats.full_evals,
                g.stats.space,
                g.stats.partial_evals,
                g.stats.pruned,
                g.stats.halved,
                g.stats.repaired,
                g.stats.peak_alive,
            );
            g.points.into_iter().unzip()
        }
    };
    if let Some((hits, misses)) = coordinator.store_counters() {
        eprintln!(
            "[fig6] result store ({name}): {hits} hits, {misses} misses, {} evaluator runs",
            coordinator.metrics.acc_evals.load(std::sync::atomic::Ordering::Relaxed),
        );
    }
    let front = pareto_front(&points, |p| p.mac_instructions);
    let baseline_instrs =
        analysis.layers.iter().map(|l| crate::dse::mac_instructions(l, None)).sum();
    let cluster = cluster_report(&coordinator);
    if let Some(r) = &cluster {
        print_cluster_ledger(name, r);
    }
    Ok(Sweep {
        model: name.to_string(),
        float_acc: coordinator.model.float_acc,
        baseline_instrs,
        points,
        indices,
        front,
        evaluator: coordinator.evaluator_name(),
        search: opts.search,
        cluster,
        coordinator,
    })
}

/// Print the one-line sweep summary (shared by `fig6` and the CLI's
/// `all` command, which reuses the sweeps).
pub fn print_summary(s: &Sweep) {
    println!(
        "Fig. 6 — {}: float acc {:.1}%, {} configs, {} on the Pareto front [{} evaluator{}]",
        s.model,
        s.float_acc * 100.0,
        s.points.len(),
        s.front.len(),
        s.evaluator,
        if s.search == SearchStrategy::Guided { ", guided search" } else { "" },
    );
    if let Some(d) = s.max_divergence() {
        println!("         host-vs-ISS top-1 divergence: max {:.2}% across configs", d * 100.0);
    }
}

/// JSON encoding of one sweep (shared by `fig6` and the CLI's `all`).
/// The `search` tag is always present; `indices` (global enumeration
/// index per point) only under guided search, where the point list is
/// a subset of the space.
pub fn sweep_json(s: &Sweep) -> Json {
    let mut fields = vec![
        ("model", Json::s(&s.model)),
        ("evaluator", Json::s(s.evaluator)),
        ("search", Json::s(s.search.name())),
    ];
    if s.search == SearchStrategy::Guided {
        fields.push((
            "indices",
            Json::Arr(s.indices.iter().map(|&i| Json::i(i as i64)).collect()),
        ));
    }
    // Emitted only off the single-core default, like the guided knobs:
    // a `--cores 1` run writes byte-identical pre-cluster JSON.
    if let Some(r) = &s.cluster {
        fields.push(("cores", Json::i(r.cores as i64)));
        fields.push((
            "cluster",
            Json::obj(vec![
                ("cores", Json::i(r.cores as i64)),
                ("banks", Json::i(r.banks as i64)),
                ("baseline_cycles_single", Json::i(r.cycles_single as i64)),
                ("baseline_cycles", Json::i(r.cycles as i64)),
                ("bank_conflict_stalls", Json::i(r.bank_stalls as i64)),
                (
                    "utilization",
                    Json::Arr(r.utilization.iter().map(|&u| Json::Num(u)).collect()),
                ),
            ]),
        ));
    }
    fields.extend(vec![
        ("float_acc", Json::Num(s.float_acc as f64)),
        ("baseline_mac_instrs", Json::i(s.baseline_instrs as i64)),
        ("points", Json::Arr(s.points.iter().map(point_json).collect())),
        ("front", Json::Arr(s.front.iter().map(|&i| Json::i(i as i64)).collect())),
    ]);
    Json::obj(fields)
}

fn point_json(p: &EvalPoint) -> Json {
    Json::obj(vec![
        ("acc", Json::Num(p.accuracy as f64)),
        ("mac_instrs", Json::i(p.mac_instructions as i64)),
        ("cycles", Json::i(p.cycles as i64)),
        ("iss_cycles", p.iss_cycles.map_or(Json::Null, |c| Json::i(c as i64))),
        ("divergence", p.divergence.map_or(Json::Null, |d| Json::Num(d as f64))),
        ("bits", Json::Arr(p.config.iter().map(|&b| Json::i(b as i64)).collect())),
    ])
}

/// Run one shard of a model's sweep: open the full space lazily (the
/// enumeration is deterministic, so every shard sees the same order),
/// evaluate only the configs the shard owns, and package the points —
/// tagged with their global enumeration indices — plus the session/
/// engine stats delta attributable to this sweep into a versioned
/// [`ShardArtifact`]. (The stats delta is read off the global
/// [`SimSession`](crate::sim::SimSession) after the coordinator's
/// cycle-model build, so it covers the sweep itself; concurrent
/// unrelated simulation in the same process would fold in too.)
pub fn sweep_shard(opts: &ExpOpts, name: &str, shard: &ShardSpec) -> Result<ShardArtifact> {
    sweep_shard_resume(opts, name, shard, None, None)
}

/// Evaluated configs between checkpoint writes of a resumable shard
/// run (see [`sweep_shard_resume`]): small enough that a killed run
/// loses little work, large enough that artifact rewrites stay noise.
pub const SHARD_CHECKPOINT_EVERY: usize = 8;

/// [`sweep_shard`] resuming from a previously written artifact of the
/// **same** shard run: configs whose global enumeration indices are
/// already present in `prior` are skipped, only the missing points are
/// evaluated, and the returned artifact carries the union (points
/// restored to enumeration order, stats = prior stats + this run's
/// delta). A prior artifact from a *different* sweep — other seed,
/// budget, evaluator, shard spec or model state — is refused with an
/// error rather than silently mixed; delete the stale file (or point
/// `--shard-out` elsewhere) to start over.
///
/// `checkpoint`, when given, makes the run **incrementally durable**:
/// the missing configs are evaluated in chunks of
/// [`SHARD_CHECKPOINT_EVERY`] and the artifact is rewritten after each
/// chunk, so a killed run leaves a cleanly-parsing partial artifact
/// the next invocation resumes from — this is what turns the resume
/// reader into actual crash recovery rather than a no-op rewriter of
/// complete artifacts. The evaluated **points** of the final file are
/// byte-identical to an uninterrupted run's (order-restored,
/// deterministic evaluation), so merged figures come out bit-exact;
/// the `stats` block records the *actual* session activity and may
/// legitimately differ across a process restart (a resumed process
/// starts with a cold memory pool, recording allocs where a warm one
/// recorded reuses).
pub fn sweep_shard_resume(
    opts: &ExpOpts,
    name: &str,
    shard: &ShardSpec,
    prior: Option<&ShardArtifact>,
    checkpoint: Option<&Path>,
) -> Result<ShardArtifact> {
    let coordinator = opts.coordinator(name)?;
    let analysis = crate::models::analyze(&coordinator.model.spec);
    let n = analysis.layers.len();
    let space = ConfigSpace::new(n, &default_pinned(), opts.budget, opts.seed);
    opts.check_space(&space)?;
    let baseline_instrs: u64 =
        analysis.layers.iter().map(|l| crate::dse::mac_instructions(l, None)).sum();

    // Guided artifacts are tagged with their rung knobs; exhaustive
    // ones carry zeros (the knobs don't apply).
    let (rungs_tag, eta_tag) = match opts.search {
        SearchStrategy::Guided => (opts.rungs as u64, opts.eta as u64),
        SearchStrategy::Exhaustive => (0, 0),
    };
    // The cluster geometry the points are priced for — part of the
    // artifact's sweep identity (shards from different `--cores` never
    // merge or resume into each other).
    let cores_tag = coordinator.cluster().cores as u64;

    let mut done: std::collections::HashSet<usize> = std::collections::HashSet::new();
    if let Some(p) = prior {
        // The artifact must describe exactly this shard of exactly this
        // sweep, or resuming would splice two different runs together.
        crate::ensure!(
            p.model == name
                && p.spec == *shard
                && p.total_configs == space.len()
                && p.seed == opts.seed
                && p.eval_n == opts.eval_n
                && p.evaluator == coordinator.evaluator_name()
                && p.baseline_instrs == baseline_instrs
                && p.float_acc.to_bits() == coordinator.model.float_acc.to_bits()
                && p.search == opts.search
                && p.rungs == rungs_tag
                && p.eta == eta_tag
                && p.cores == cores_tag,
            "existing shard artifact for `{name}` was produced by a different sweep \
             (model/shard/seed/budget/eval/evaluator/search/cores mismatch); delete it or \
             change --shard-out to start a fresh shard run"
        );
        for (i, pt) in &p.points {
            crate::ensure!(
                *i < space.len() && space.get(*i) == pt.config,
                "existing shard artifact for `{name}` is mistagged at config #{i}; \
                 delete it to re-evaluate the shard"
            );
            done.insert(*i);
        }
    }

    let owned = shard.member_indices_in(&space);
    let missing: Vec<usize> = owned.iter().copied().filter(|i| !done.contains(i)).collect();

    let mut points: Vec<(usize, crate::dse::EvalPoint)> =
        prior.map(|p| p.points.clone()).unwrap_or_default();
    let mut stats = prior.map(|p| p.stats).unwrap_or_default();
    let mk_art = |points: Vec<(usize, crate::dse::EvalPoint)>,
                  stats: crate::sim::session::SessionSnapshot| ShardArtifact {
        model: name.to_string(),
        evaluator: coordinator.evaluator_name().to_string(),
        spec: *shard,
        total_configs: space.len(),
        seed: opts.seed,
        eval_n: opts.eval_n,
        float_acc: coordinator.model.float_acc,
        baseline_instrs,
        search: opts.search,
        rungs: rungs_tag,
        eta: eta_tag,
        cores: cores_tag,
        points,
        stats,
    };

    if opts.search == SearchStrategy::Guided {
        // Guided shards are written complete-in-one-shot: the search is
        // holistic over the shard's slice (rung promotion compares the
        // slice's configs against each other), so there is no per-config
        // checkpoint — a cleanly-parsing prior artifact of the same run
        // *is* the finished shard and is returned unchanged.
        if let Some(p) = prior {
            return Ok(p.clone());
        }
        let before = crate::sim::SimSession::global().stats.snapshot();
        let g = coordinator.sweep_guided_indices(&space, &owned, opts.eval_n, &opts.guided_opts())?;
        let delta = crate::sim::SimSession::global().stats.snapshot().delta_since(&before);
        stats.add(&delta);
        eprintln!(
            "[fig6] guided search ({name} shard {shard}): {}/{} configs fully evaluated \
             ({} partial evals, {} pruned, {} halved, {} repaired, peak alive {})",
            g.stats.full_evals,
            g.stats.space,
            g.stats.partial_evals,
            g.stats.pruned,
            g.stats.halved,
            g.stats.repaired,
            g.stats.peak_alive,
        );
        // Map the search's slice-local indices back to global
        // enumeration indices.
        let points: Vec<(usize, crate::dse::EvalPoint)> =
            g.points.into_iter().map(|(local, p)| (owned[local], p)).collect();
        return Ok(mk_art(points, stats));
    }

    for chunk in missing.chunks(SHARD_CHECKPOINT_EVERY) {
        let before = crate::sim::SimSession::global().stats.snapshot();
        let new_points = coordinator.sweep_space_indices(&space, chunk, opts.eval_n)?;
        let delta = crate::sim::SimSession::global().stats.snapshot().delta_since(&before);
        stats.add(&delta);
        points.extend(chunk.iter().copied().zip(new_points));
        points.sort_by_key(|(i, _)| *i);
        if let Some(path) = checkpoint {
            mk_art(points.clone(), stats).save(path)?;
        }
    }

    Ok(mk_art(points, stats))
}

/// Canonical artifact filename for one model's shard:
/// `<dir>/fig6_<model>.s<i>of<n>.json`.
pub fn shard_artifact_path(dir: &Path, model: &str, shard: &ShardSpec) -> PathBuf {
    dir.join(format!("fig6_{model}.s{}of{}.json", shard.index, shard.count))
}

/// Map an artifact's evaluator label back to the static str [`Sweep`]
/// carries (unknown labels — a future backend — read as themselves
/// semantically but print as `merged`).
fn evaluator_static(name: &str) -> &'static str {
    match name {
        "host" => "host",
        "iss" => "iss",
        "analytic" => "analytic",
        "pjrt" => "pjrt",
        _ => "merged",
    }
}

/// Rebuild a full [`Sweep`] from one model's shard artifacts: merge
/// (dedup + conflict check + coverage check + global Pareto front),
/// then rebuild the coordinator so downstream consumers (Fig. 8's
/// threshold selection needs the cycle model) work unchanged. The
/// local model must match the artifacts — a differing float baseline
/// accuracy means a different seed or artifacts directory, and the
/// merge refuses rather than mixing sweeps.
pub fn sweep_from_artifacts(opts: &ExpOpts, arts: &[ShardArtifact]) -> Result<Sweep> {
    let merged = merge(arts)?;
    let coordinator = opts.coordinator(&merged.model)?;
    crate::ensure!(
        coordinator.cluster().cores as u64 == merged.cores,
        "shard artifacts for `{}` were priced for a {}-core cluster but this merge runs \
         with --cores {}; pass the shard run's --cores",
        merged.model,
        merged.cores,
        coordinator.cluster().cores,
    );
    crate::ensure!(
        coordinator.model.float_acc.to_bits() == merged.float_acc.to_bits(),
        "shard artifacts for `{}` were produced from a different model state \
         (float acc {} vs local {}); check --seed/--artifacts",
        merged.model,
        merged.float_acc,
        coordinator.model.float_acc,
    );
    // Cross-check the merged points against a local re-enumeration:
    // the coverage check inside `merge` proves the *indices* are sane,
    // but only the enumeration itself can prove each index carries the
    // right *config* — a mistagged artifact (hand-edited, bit-flipped,
    // buggy writer) must fail here, not merge silently into a reordered
    // sweep. Exhaustive merges must additionally cover the whole space;
    // guided merges legitimately carry a subset.
    let n = crate::models::analyze(&coordinator.model.spec).layers.len();
    let space = ConfigSpace::new(n, &default_pinned(), opts.budget, merged.seed);
    if merged.search == SearchStrategy::Exhaustive {
        crate::ensure!(
            space.len() == merged.points.len(),
            "merged artifacts for `{}` carry {} configs but --budget {} with seed {} \
             enumerates {}; rerun the merge with the shard run's --budget",
            merged.model,
            merged.points.len(),
            opts.budget,
            merged.seed,
            space.len(),
        );
    }
    for (&i, p) in merged.indices.iter().zip(&merged.points) {
        crate::ensure!(
            i < space.len(),
            "merged artifacts for `{}` reference config #{i} but --budget {} with seed {} \
             enumerates only {}; rerun the merge with the shard run's --budget",
            merged.model,
            opts.budget,
            merged.seed,
            space.len(),
        );
        let want = space.get(i);
        crate::ensure!(
            want == p.config,
            "shard artifacts for `{}` are mistagged: config #{i} should be {:?} \
             but the merged point carries {:?}",
            merged.model,
            want,
            p.config,
        );
    }
    eprintln!(
        "[fig6] merged {} shard artifact(s) for {}: {} points, {} duplicate(s), {} engine runs",
        merged.shards,
        merged.model,
        merged.points.len(),
        merged.duplicate_points,
        merged.stats.runs,
    );
    let cluster = cluster_report(&coordinator);
    if let Some(r) = &cluster {
        print_cluster_ledger(&merged.model, r);
    }
    Ok(Sweep {
        model: merged.model,
        float_acc: merged.float_acc,
        baseline_instrs: merged.baseline_instrs,
        points: merged.points,
        indices: merged.indices,
        front: merged.front,
        evaluator: evaluator_static(&merged.evaluator),
        search: merged.search,
        cluster,
        coordinator,
    })
}

/// Load the merge inputs (`--merge` files plus the `--merge-dir`
/// glob) and rebuild one [`Sweep`] per model, in paper model order
/// (shared by `fig6 --merge` and `fig8 --merge`).
pub fn sweeps_from_merge(opts: &ExpOpts) -> Result<Vec<Sweep>> {
    let files = opts.merge_inputs()?;
    crate::ensure!(!files.is_empty(), "--merge/--merge-dir needs at least one shard artifact");
    let mut groups: Vec<(String, Vec<ShardArtifact>)> = Vec::new();
    for path in &files {
        let art = ShardArtifact::load(path)?;
        match groups.iter_mut().find(|(m, _)| *m == art.model) {
            Some((_, g)) => g.push(art),
            None => groups.push((art.model.clone(), vec![art])),
        }
    }
    // Deterministic model order: paper order first, then anything else
    // alphabetically.
    groups.sort_by_key(|(m, _)| {
        (super::MODEL_NAMES.iter().position(|n| n == m).unwrap_or(usize::MAX), m.clone())
    });
    groups.iter().map(|(_, arts)| sweep_from_artifacts(opts, arts)).collect()
}

/// Run the Fig.-6 harness: merge shard artifacts when `--merge` is
/// given, write one shard's artifact(s) when `--shard` is given,
/// full sweep over the selected models otherwise.
pub fn run(opts: &ExpOpts) -> Result<(Vec<Sweep>, Json)> {
    if opts.wants_merge() {
        crate::ensure!(
            opts.shard.is_none(),
            "--shard and --merge/--merge-dir are mutually exclusive \
             (run shards first, then merge)"
        );
        return finish(sweeps_from_merge(opts)?);
    }
    if let Some(shard) = opts.shard {
        let dir = opts.shard_dir();
        let mut arr = Vec::new();
        for name in opts.model_names()? {
            let path = shard_artifact_path(&dir, name, &shard);
            // Resumable shards: a cleanly-parsing artifact at the
            // target path contributes its already-evaluated points; a
            // corrupt/truncated file (killed run) is re-swept whole.
            let prior = if path.exists() {
                match ShardArtifact::load(&path) {
                    Ok(a) => Some(a),
                    Err(e) => {
                        eprintln!(
                            "[fig6] ignoring unreadable shard artifact {} ({e}); re-sweeping",
                            path.display()
                        );
                        None
                    }
                }
            } else {
                None
            };
            let resumed_from = prior.as_ref().map_or(0, |p| p.points.len());
            eprintln!(
                "[fig6] sweeping shard {shard} of {name} ({} configs total, {} eval images{})",
                opts.budget,
                opts.eval_n,
                if resumed_from > 0 {
                    format!(", resuming past {resumed_from} done")
                } else {
                    String::new()
                }
            );
            let art = sweep_shard_resume(opts, name, &shard, prior.as_ref(), Some(&path))?;
            art.save(&path)?;
            println!(
                "Fig. 6 — {name}: shard {shard} evaluated {}/{} configs ({} resumed) -> {}",
                art.points.len(),
                art.total_configs,
                resumed_from,
                path.display()
            );
            arr.push(Json::obj(vec![
                ("model", Json::s(name)),
                ("path", Json::s(&path.display().to_string())),
                ("strategy", Json::s(shard.strategy.name())),
                ("shard_index", Json::i(shard.index as i64)),
                ("shard_count", Json::i(shard.count as i64)),
                ("points", Json::i(art.points.len() as i64)),
                ("resumed_points", Json::i(resumed_from as i64)),
                ("total_configs", Json::i(art.total_configs as i64)),
            ]));
        }
        return Ok((Vec::new(), Json::Arr(arr)));
    }
    let st = &crate::sim::SimSession::global().stats;
    let compiles0 = st.plan_compiles.load(std::sync::atomic::Ordering::Relaxed);
    let hits0 = st.plan_hits.load(std::sync::atomic::Ordering::Relaxed);
    let mut sweeps = Vec::new();
    for name in opts.model_names()? {
        eprintln!("[fig6] sweeping {name} ({} configs, {} eval images)", opts.budget, opts.eval_n);
        sweeps.push(sweep_model(opts, name)?);
    }
    // Plan-cache observability, as a delta over this sweep so earlier
    // commands in the same process (`all` runs fig4/fig7 first) don't
    // inflate it: every configuration lowers exactly once (assertable
    // — see SessionStats::plan_compiles and tests/plan_cache_stats.rs).
    eprintln!(
        "[fig6] plan cache: {} compiled, {} hits",
        st.plan_compiles.load(std::sync::atomic::Ordering::Relaxed) - compiles0,
        st.plan_hits.load(std::sync::atomic::Ordering::Relaxed) - hits0,
    );
    finish(sweeps)
}

/// Print + serialise sweeps — the single exit path for both the full
/// and the merged run, which is what makes `results/fig6.json` from a
/// merge byte-identical to the unsharded file.
fn finish(sweeps: Vec<Sweep>) -> Result<(Vec<Sweep>, Json)> {
    let mut arr = Vec::new();
    for s in &sweeps {
        print_summary(s);
        println!(
            "{:>10} {:>8} {:>14} {:>10}  (front points)",
            "acc(%)", "Δacc", "MAC instrs", "reduction"
        );
        for &i in &s.front {
            let p = &s.points[i];
            println!(
                "{:>10.1} {:>8.2} {:>14} {:>9.1}%",
                p.accuracy * 100.0,
                (s.float_acc - p.accuracy) * 100.0,
                p.mac_instructions,
                (1.0 - p.mac_instructions as f64 / s.baseline_instrs as f64) * 100.0
            );
        }
        arr.push(sweep_json(s));
    }
    Ok((sweeps, Json::Arr(arr)))
}
