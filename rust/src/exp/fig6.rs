//! Fig. 6 — the accuracy-vs-MAC-instruction Pareto spaces from the
//! mixed-precision DSE (gray points = all configurations, squares = the
//! Pareto front, star = the float baseline).

use super::ExpOpts;
use crate::coordinator::Coordinator;
use crate::dse::pareto::pareto_front;
use crate::dse::{default_pinned, enumerate, EvalPoint};
use crate::json::Json;
use crate::error::Result;

/// Sweep result for one model.
pub struct Sweep {
    /// Model name.
    pub model: String,
    /// Float baseline accuracy.
    pub float_acc: f32,
    /// Baseline MAC-instruction count (one mul per MAC).
    pub baseline_instrs: u64,
    /// Every evaluated point.
    pub points: Vec<EvalPoint>,
    /// Indices of the Pareto front (by MAC instructions).
    pub front: Vec<usize>,
    /// Accuracy backend that scored the points (`host`/`iss`/`pjrt`).
    pub evaluator: &'static str,
    /// The coordinator (kept for downstream reuse, e.g. Fig. 8).
    pub coordinator: Coordinator,
}

impl Sweep {
    /// Largest host-vs-ISS top-1 divergence across the sweep, when the
    /// backend computed it (the `iss` evaluator's differential check).
    pub fn max_divergence(&self) -> Option<f32> {
        self.points.iter().filter_map(|p| p.divergence).reduce(f32::max)
    }
}

/// Run the DSE sweep for one model.
pub fn sweep_model(opts: &ExpOpts, name: &str) -> Result<Sweep> {
    let coordinator = opts.coordinator(name)?;
    let analysis = crate::models::analyze(&coordinator.model.spec);
    let n = analysis.layers.len();
    let configs = enumerate(n, &default_pinned(), opts.budget, opts.seed);
    let points = coordinator.run_sweep(&configs, opts.eval_n)?;
    let front = pareto_front(&points, |p| p.mac_instructions);
    let baseline_instrs =
        analysis.layers.iter().map(|l| crate::dse::mac_instructions(l, None)).sum();
    Ok(Sweep {
        model: name.to_string(),
        float_acc: coordinator.model.float_acc,
        baseline_instrs,
        points,
        front,
        evaluator: coordinator.evaluator_name(),
        coordinator,
    })
}

/// Print the one-line sweep summary (shared by `fig6` and the CLI's
/// `all` command, which reuses the sweeps).
pub fn print_summary(s: &Sweep) {
    println!(
        "Fig. 6 — {}: float acc {:.1}%, {} configs, {} on the Pareto front [{} evaluator]",
        s.model,
        s.float_acc * 100.0,
        s.points.len(),
        s.front.len(),
        s.evaluator,
    );
    if let Some(d) = s.max_divergence() {
        println!("         host-vs-ISS top-1 divergence: max {:.2}% across configs", d * 100.0);
    }
}

/// JSON encoding of one sweep (shared by `fig6` and the CLI's `all`).
pub fn sweep_json(s: &Sweep) -> Json {
    Json::obj(vec![
        ("model", Json::s(&s.model)),
        ("evaluator", Json::s(s.evaluator)),
        ("float_acc", Json::Num(s.float_acc as f64)),
        ("baseline_mac_instrs", Json::i(s.baseline_instrs as i64)),
        ("points", Json::Arr(s.points.iter().map(point_json).collect())),
        ("front", Json::Arr(s.front.iter().map(|&i| Json::i(i as i64)).collect())),
    ])
}

fn point_json(p: &EvalPoint) -> Json {
    Json::obj(vec![
        ("acc", Json::Num(p.accuracy as f64)),
        ("mac_instrs", Json::i(p.mac_instructions as i64)),
        ("cycles", Json::i(p.cycles as i64)),
        ("iss_cycles", p.iss_cycles.map_or(Json::Null, |c| Json::i(c as i64))),
        ("divergence", p.divergence.map_or(Json::Null, |d| Json::Num(d as f64))),
        ("bits", Json::Arr(p.config.iter().map(|&b| Json::i(b as i64)).collect())),
    ])
}

/// Run the Fig.-6 harness over all four models.
pub fn run(opts: &ExpOpts) -> Result<(Vec<Sweep>, Json)> {
    let mut sweeps = Vec::new();
    for name in super::MODEL_NAMES {
        eprintln!("[fig6] sweeping {name} ({} configs, {} eval images)", opts.budget, opts.eval_n);
        sweeps.push(sweep_model(opts, name)?);
    }
    let mut arr = Vec::new();
    for s in &sweeps {
        print_summary(s);
        println!(
            "{:>10} {:>8} {:>14} {:>10}  (front points)",
            "acc(%)", "Δacc", "MAC instrs", "reduction"
        );
        for &i in &s.front {
            let p = &s.points[i];
            println!(
                "{:>10.1} {:>8.2} {:>14} {:>9.1}%",
                p.accuracy * 100.0,
                (s.float_acc - p.accuracy) * 100.0,
                p.mac_instructions,
                (1.0 - p.mac_instructions as f64 / s.baseline_instrs as f64) * 100.0
            );
        }
        arr.push(sweep_json(s));
    }
    Ok((sweeps, Json::Arr(arr)))
}
