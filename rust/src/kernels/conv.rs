//! Standard (dense-channel) convolution kernels, NHWC, valid geometry —
//! the host pre-pads spatially, so the kernel sees `Hp×Wp×Cin` input and
//! produces `Ho×Wo×Cout`.
//!
//! The mode kernels exploit the paper's key reuse structure: in NHWC one
//! kernel row `(ky)` touches a *contiguous* run of `K·Cin` activation
//! bytes, so word loads feed `nn_mac` directly with no repacking. `Cin`
//! must be a multiple of 4 (the model zoo channel-pads with zero weights)
//! so every strip base is word-aligned.

use super::requant::{emit_prologue, emit_requantize};
use super::{emit_advance, Arena, KernelProgram};
use crate::asm::Asm;
use crate::isa::reg::*;
use crate::isa::MacMode;
use crate::nn::pack::words_per_group;
use crate::nn::quant::Requant;

/// Convolution kernel shape parameters (valid conv over pre-padded input).
#[derive(Debug, Clone, Copy)]
pub struct ConvSpec {
    /// Pre-padded input height.
    pub h: usize,
    /// Pre-padded input width.
    pub w: usize,
    /// Input channels (mode kernels require a multiple of 4).
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
    /// Kernel size (square).
    pub k: usize,
    /// Stride.
    pub stride: usize,
    /// Requantization parameters.
    pub rq: Requant,
    /// Fused ReLU.
    pub relu: bool,
}

impl ConvSpec {
    /// Output height.
    pub fn ho(&self) -> usize {
        (self.h - self.k) / self.stride + 1
    }
    /// Output width.
    pub fn wo(&self) -> usize {
        (self.w - self.k) / self.stride + 1
    }
    /// Total MAC operations.
    pub fn macs(&self) -> u64 {
        (self.ho() * self.wo() * self.cout * self.k * self.k * self.cin) as u64
    }
}

fn alloc(spec: &ConvSpec, w_bytes: u32) -> (Arena, u32, u32, u32, u32) {
    let mut ar = Arena::new();
    let act = ar.alloc_act((spec.h * spec.w * spec.cin) as u32);
    let w = ar.alloc(w_bytes, 4);
    let bias = ar.alloc(4 * spec.cout as u32, 4);
    let out = ar.alloc((spec.ho() * spec.wo() * spec.cout) as u32, 4);
    (ar, act, w, bias, out)
}

/// Scalar baseline conv kernel. Weights int8 `[Cout][K][K][Cin]`.
pub fn build_baseline(spec: ConvSpec) -> KernelProgram {
    let (ar, act, w, bias, out) =
        alloc(&spec, (spec.cout * spec.k * spec.k * spec.cin) as u32);
    let rowstride = (spec.w * spec.cin) as i32;

    let mut a = Asm::new();
    a.li(S0, act as i32);
    a.li(S1, w as i32);
    a.li(S2, bias as i32);
    a.li(S3, out as i32);
    emit_prologue(&mut a, spec.rq, spec.relu);
    a.mv(T5, S3); // out cursor
    a.li(GP, spec.ho() as i32);
    a.mv(S7, S0); // row base

    let oy_l = a.new_label();
    a.bind(oy_l);
    a.li(TP, spec.wo() as i32);
    a.mv(S8, S7); // col base
    let ox_l = a.new_label();
    a.bind(ox_l);
    a.mv(S11, S1); // weight cursor (stream restarts per pixel)
    a.mv(T4, S2); // bias cursor
    a.li(A6, spec.cout as i32);
    let oc_l = a.new_label();
    a.bind(oc_l);
    a.lw(A0, T4, 0);
    a.mv(S9, S8); // tap row base
    a.li(A7, spec.k as i32);
    let ky_l = a.new_label();
    a.bind(ky_l);
    a.mv(S10, S9); // tap cursor
    a.li(T6, (spec.k * spec.cin) as i32);
    let ic_l = a.new_label();
    a.bind(ic_l);
    a.lb(T0, S10, 0);
    a.lb(T1, S11, 0);
    a.mul(T0, T0, T1);
    a.add(A0, A0, T0);
    a.addi(S10, S10, 1);
    a.addi(S11, S11, 1);
    a.addi(T6, T6, -1);
    a.bne(T6, ZERO, ic_l);
    emit_advance(&mut a, S9, S9, rowstride);
    a.addi(A7, A7, -1);
    a.bne(A7, ZERO, ky_l);
    emit_requantize(&mut a, spec.rq);
    a.sb(T5, A0, 0);
    a.addi(T5, T5, 1);
    a.addi(T4, T4, 4);
    a.addi(A6, A6, -1);
    a.bne(A6, ZERO, oc_l);
    emit_advance(&mut a, S8, S8, (spec.stride * spec.cin) as i32);
    a.addi(TP, TP, -1);
    a.bne(TP, ZERO, ox_l);
    emit_advance(&mut a, S7, S7, spec.stride as i32 * rowstride);
    a.addi(GP, GP, -1);
    a.bne(GP, ZERO, oy_l);
    a.halt();

    KernelProgram {
        prog: a.assemble(),
        act_addr: act,
        w_addr: w,
        bias_addr: bias,
        out_addr: out,
        mem_size: ar.high_water() + 4096,
    }
}

/// Packed `nn_mac` conv kernel. Weights packed per `(oc, ky)` strip —
/// see [`crate::nn::pack::pack_conv`]. Requires `Cin % 4 == 0`.
pub fn build_mode(mode: MacMode, spec: ConvSpec) -> KernelProgram {
    assert_eq!(spec.cin % 4, 0, "mode conv kernels require channel-padded input (Cin % 4 == 0)");
    let n = mode.weights_per_word() as usize;
    let strip = spec.k * spec.cin;
    let wpg = words_per_group(mode, strip); // words per (oc, ky) strip
    let oc_w_bytes = (spec.k * wpg * 4) as i32; // weight bytes per oc
    assert!(strip <= 2000, "strip too long for immediate offsets: {strip}");
    assert!(oc_w_bytes <= 2000, "per-oc weight block too large: {oc_w_bytes}");
    let (ar, act, w, bias, out) = alloc(&spec, (spec.cout * spec.k * wpg * 4) as u32);
    let rowstride = (spec.w * spec.cin) as i32;
    let act_regs = mode.activation_regs() as usize;

    let mut a = Asm::new();
    a.li(S0, act as i32);
    a.li(S1, w as i32);
    a.li(S2, bias as i32);
    a.li(S3, out as i32);
    emit_prologue(&mut a, spec.rq, spec.relu);
    a.mv(T5, S3);
    a.li(GP, spec.ho() as i32);
    a.mv(S7, S0);

    let oy_l = a.new_label();
    a.bind(oy_l);
    a.li(TP, spec.wo() as i32);
    a.mv(S8, S7);
    let ox_l = a.new_label();
    a.bind(ox_l);
    a.mv(S11, S1);
    a.mv(T4, S2);
    a.li(A6, spec.cout as i32);
    let oc_l = a.new_label();
    a.bind(oc_l);
    a.lw(A0, T4, 0);
    // K strips, fully unrolled with immediate offsets.
    for ky in 0..spec.k {
        if ky == 0 {
            a.mv(S9, S8);
        } else {
            emit_advance(&mut a, S9, S9, rowstride);
        }
        for c in 0..wpg {
            for k in 0..act_regs {
                a.lw(A2 + k as u8, S9, (c * n + 4 * k) as i32);
            }
            a.lw(A1, S11, ((ky * wpg + c) * 4) as i32);
            a.nn_mac(mode, A0, A2, A1);
        }
    }
    a.addi(S11, S11, oc_w_bytes);
    emit_requantize(&mut a, spec.rq);
    a.sb(T5, A0, 0);
    a.addi(T5, T5, 1);
    a.addi(T4, T4, 4);
    a.addi(A6, A6, -1);
    a.bne(A6, ZERO, oc_l);
    emit_advance(&mut a, S8, S8, (spec.stride * spec.cin) as i32);
    a.addi(TP, TP, -1);
    a.bne(TP, ZERO, ox_l);
    emit_advance(&mut a, S7, S7, spec.stride as i32 * rowstride);
    a.addi(GP, GP, -1);
    a.bne(GP, ZERO, oy_l);
    a.halt();

    KernelProgram {
        prog: a.assemble(),
        act_addr: act,
        w_addr: w,
        bias_addr: bias,
        out_addr: out,
        mem_size: ar.high_water() + 4096,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::MacMode::*;
    use crate::kernels::run::run_conv;
    use crate::nn::layers::{qconv2d, ConvGeom};
    use crate::nn::tensor::Tensor;
    use crate::rng::Rng;

    fn spec(h: usize, w: usize, cin: usize, cout: usize, k: usize, stride: usize) -> ConvSpec {
        ConvSpec {
            h,
            w,
            cin,
            cout,
            k,
            stride,
            rq: Requant::from_real_scale(0.002),
            relu: true,
        }
    }

    fn check(spec: ConvSpec, mode: Option<MacMode>, seed: u64) {
        let mut rng = Rng::new(seed);
        let bits = mode.map_or(8, |m| m.weight_bits());
        let acts: Vec<i8> = (0..spec.h * spec.w * spec.cin).map(|_| rng.i8()).collect();
        let wts: Vec<i8> =
            (0..spec.cout * spec.k * spec.k * spec.cin).map(|_| rng.int_bits(bits)).collect();
        let bias: Vec<i32> = (0..spec.cout).map(|_| rng.range_i32(-300, 300)).collect();
        let input = Tensor::from_vec(&[spec.h, spec.w, spec.cin], acts.clone());
        let want = qconv2d(
            &input,
            &wts,
            &bias,
            spec.cout,
            ConvGeom { k: spec.k, stride: spec.stride, pad: 0 },
            spec.rq,
            spec.relu,
        );
        let (got, _) = run_conv(spec, mode, &acts, &wts, &bias).unwrap();
        assert_eq!(got, want.data, "{mode:?} spec {spec:?}");
    }

    #[test]
    fn baseline_matches_reference() {
        check(spec(6, 6, 4, 3, 3, 1), None, 10);
        check(spec(8, 8, 3, 2, 3, 2), None, 11); // odd Cin fine for baseline
        check(spec(7, 7, 4, 2, 5, 1), None, 12);
    }

    #[test]
    fn mode_kernels_match_reference() {
        for m in [W8, W4, W2] {
            check(spec(6, 6, 4, 3, 3, 1), Some(m), 20); // strip 12: not word-multiple for W2/W4
            check(spec(8, 8, 8, 4, 3, 2), Some(m), 21); // strided
            check(spec(6, 6, 16, 2, 1, 1), Some(m), 22); // pointwise
            check(spec(9, 9, 4, 2, 5, 1), Some(m), 23); // 5×5 (LeNet-style)
        }
    }

    #[test]
    fn mode_speedup_ordering_matches_fig7() {
        let s = spec(10, 10, 16, 8, 3, 1);
        let mut rng = Rng::new(33);
        let acts: Vec<i8> = (0..s.h * s.w * s.cin).map(|_| rng.i8()).collect();
        let bias = vec![0i32; s.cout];
        let mk = |bits: u32, rng: &mut Rng| -> Vec<i8> {
            (0..s.cout * s.k * s.k * s.cin).map(|_| rng.int_bits(bits)).collect()
        };
        let w8 = mk(8, &mut rng);
        let w4 = mk(4, &mut rng);
        let w2 = mk(2, &mut rng);
        let (_, base) = run_conv(s, None, &acts, &w8, &bias).unwrap();
        let (_, m1) = run_conv(s, Some(W8), &acts, &w8, &bias).unwrap();
        let (_, m2) = run_conv(s, Some(W4), &acts, &w4, &bias).unwrap();
        let (_, m3) = run_conv(s, Some(W2), &acts, &w2, &bias).unwrap();
        let su = |p: &crate::sim::PerfCounters| base.cycles as f64 / p.cycles as f64;
        assert!(su(&m1) > 5.0, "Mode-1 {:.2}", su(&m1));
        assert!(su(&m2) > su(&m1), "Mode-2 {:.2} vs Mode-1 {:.2}", su(&m2), su(&m1));
        assert!(su(&m3) > su(&m2), "Mode-3 {:.2} vs Mode-2 {:.2}", su(&m3), su(&m2));
    }
}
