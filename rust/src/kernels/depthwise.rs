//! Depthwise convolution kernels (channel multiplier 1).
//!
//! Depthwise taps for one output channel are *strided* by `Cin` in NHWC,
//! so the packed kernels must gather activation bytes and assemble the
//! `nn_mac` words on the fly (`lbu` + shift + `or`). This is exactly the
//! structural disadvantage the paper observes for MCUNet/MobileNet:
//! "[depthwise convolutions] do not enable the same degree of input
//! reuse as in standard point-wise convolutions" — the measured gain of
//! these kernels is correspondingly modest, while weight traffic still
//! shrinks by the packing factor.

use super::requant::{emit_prologue, emit_requantize};
use super::{emit_advance, Arena, KernelProgram};
use crate::asm::Asm;
use crate::isa::reg::*;
use crate::isa::MacMode;
use crate::nn::pack::words_per_group;
use crate::nn::quant::Requant;

/// Depthwise kernel shape parameters (valid conv over pre-padded input).
#[derive(Debug, Clone, Copy)]
pub struct DwSpec {
    /// Pre-padded input height.
    pub h: usize,
    /// Pre-padded input width.
    pub w: usize,
    /// Channels.
    pub c: usize,
    /// Kernel size.
    pub k: usize,
    /// Stride.
    pub stride: usize,
    /// Requantization parameters.
    pub rq: Requant,
    /// Fused ReLU.
    pub relu: bool,
}

impl DwSpec {
    /// Output height.
    pub fn ho(&self) -> usize {
        (self.h - self.k) / self.stride + 1
    }
    /// Output width.
    pub fn wo(&self) -> usize {
        (self.w - self.k) / self.stride + 1
    }
    /// Total MACs.
    pub fn macs(&self) -> u64 {
        (self.ho() * self.wo() * self.c * self.k * self.k) as u64
    }
}

fn alloc(spec: &DwSpec, w_bytes: u32) -> (Arena, u32, u32, u32, u32) {
    let mut ar = Arena::new();
    let act = ar.alloc_act((spec.h * spec.w * spec.c) as u32);
    let w = ar.alloc(w_bytes, 4);
    let bias = ar.alloc(4 * spec.c as u32, 4);
    let out = ar.alloc((spec.ho() * spec.wo() * spec.c) as u32, 4);
    (ar, act, w, bias, out)
}

/// Scalar baseline depthwise kernel. Weights int8 `[C][K][K]`.
pub fn build_baseline(spec: DwSpec) -> KernelProgram {
    let (ar, act, w, bias, out) = alloc(&spec, (spec.c * spec.k * spec.k) as u32);
    let rowstride = (spec.w * spec.c) as i32;

    let mut a = Asm::new();
    a.li(S0, act as i32);
    a.li(S1, w as i32);
    a.li(S2, bias as i32);
    a.li(S3, out as i32);
    emit_prologue(&mut a, spec.rq, spec.relu);
    a.mv(T5, S3);
    a.li(GP, spec.ho() as i32);
    a.mv(S7, S0);

    let oy_l = a.new_label();
    a.bind(oy_l);
    a.li(TP, spec.wo() as i32);
    a.mv(S8, S7);
    let ox_l = a.new_label();
    a.bind(ox_l);
    a.mv(S11, S1); // weight cursor, streams per channel
    a.mv(T4, S2);
    a.mv(S9, S8); // channel tap base
    a.li(A6, spec.c as i32);
    let c_l = a.new_label();
    a.bind(c_l);
    a.lw(A0, T4, 0);
    // K×K taps: per-ky base advance, kx via immediate offsets.
    for ky in 0..spec.k {
        if ky == 0 {
            a.mv(S10, S9);
        } else {
            emit_advance(&mut a, S10, S10, rowstride);
        }
        for kx in 0..spec.k {
            a.lb(T0, S10, (kx * spec.c) as i32);
            a.lb(T1, S11, (ky * spec.k + kx) as i32);
            a.mul(T0, T0, T1);
            a.add(A0, A0, T0);
        }
    }
    a.addi(S11, S11, (spec.k * spec.k) as i32);
    emit_requantize(&mut a, spec.rq);
    a.sb(T5, A0, 0);
    a.addi(T5, T5, 1);
    a.addi(T4, T4, 4);
    a.addi(S9, S9, 1);
    a.addi(A6, A6, -1);
    a.bne(A6, ZERO, c_l);
    emit_advance(&mut a, S8, S8, (spec.stride * spec.c) as i32);
    a.addi(TP, TP, -1);
    a.bne(TP, ZERO, ox_l);
    emit_advance(&mut a, S7, S7, spec.stride as i32 * rowstride);
    a.addi(GP, GP, -1);
    a.bne(GP, ZERO, oy_l);
    a.halt();

    KernelProgram {
        prog: a.assemble(),
        act_addr: act,
        w_addr: w,
        bias_addr: bias,
        out_addr: out,
        mem_size: ar.high_water() + 4096,
    }
}

/// Packed `nn_mac` depthwise kernel with on-the-fly activation packing.
/// Weights packed per channel — see [`crate::nn::pack::pack_depthwise`].
pub fn build_mode(mode: MacMode, spec: DwSpec) -> KernelProgram {
    let taps = spec.k * spec.k;
    let wpg = words_per_group(mode, taps);
    let act_regs = mode.activation_regs() as usize;
    let (ar, act, w, bias, out) = alloc(&spec, (spec.c * wpg * 4) as u32);
    let rowstride = (spec.w * spec.c) as i32;

    let mut a = Asm::new();
    a.li(S0, act as i32);
    a.li(S1, w as i32);
    a.li(S2, bias as i32);
    a.li(S3, out as i32);
    emit_prologue(&mut a, spec.rq, spec.relu);
    a.mv(T5, S3);
    a.li(GP, spec.ho() as i32);
    a.mv(S7, S0);

    let oy_l = a.new_label();
    a.bind(oy_l);
    a.li(TP, spec.wo() as i32);
    a.mv(S8, S7);
    let ox_l = a.new_label();
    a.bind(ox_l);
    a.mv(S11, S1);
    a.mv(T4, S2);
    a.mv(S9, S8);
    a.li(A6, spec.c as i32);
    let c_l = a.new_label();
    a.bind(c_l);
    a.lw(A0, T4, 0);
    // Assemble activation words tap-by-tap; per-ky tap base in s10.
    let mut cur_ky = usize::MAX;
    for chunk in 0..wpg {
        for reg in 0..act_regs {
            let word_idx = chunk * act_regs + reg;
            let dst = A2 + reg as u8;
            let mut lane_filled = false;
            for j in 0..4 {
                let t = word_idx * 4 + j;
                if t >= taps {
                    break;
                }
                let (ky, kx) = (t / spec.k, t % spec.k);
                if ky != cur_ky {
                    // (Re)derive the ky row base. Taps are visited in
                    // row-major order so ky only moves forward.
                    if ky == 0 {
                        a.mv(S10, S9);
                    } else {
                        debug_assert_eq!(ky, cur_ky.wrapping_add(1));
                        emit_advance(&mut a, S10, S10, rowstride);
                    }
                    cur_ky = ky;
                }
                let off = (kx * spec.c) as i32;
                if j == 0 {
                    a.lbu(dst, S10, off);
                    lane_filled = true;
                } else {
                    a.lbu(T1, S10, off);
                    a.slli(T1, T1, (8 * j) as i32);
                    a.emit(crate::isa::Instr::Op {
                        op: crate::isa::AluOp::Or,
                        rd: dst,
                        rs1: dst,
                        rs2: T1,
                    });
                }
            }
            if !lane_filled {
                // Word entirely past the tap count: zero it (its weights
                // are zero-padded, but the register must hold *something*
                // deterministic).
                a.li(dst, 0);
            }
        }
        a.lw(A1, S11, (chunk * 4) as i32);
        a.nn_mac(mode, A0, A2, A1);
    }
    a.addi(S11, S11, (wpg * 4) as i32);
    emit_requantize(&mut a, spec.rq);
    a.sb(T5, A0, 0);
    a.addi(T5, T5, 1);
    a.addi(T4, T4, 4);
    a.addi(S9, S9, 1);
    a.addi(A6, A6, -1);
    a.bne(A6, ZERO, c_l);
    emit_advance(&mut a, S8, S8, (spec.stride * spec.c) as i32);
    a.addi(TP, TP, -1);
    a.bne(TP, ZERO, ox_l);
    emit_advance(&mut a, S7, S7, spec.stride as i32 * rowstride);
    a.addi(GP, GP, -1);
    a.bne(GP, ZERO, oy_l);
    a.halt();

    KernelProgram {
        prog: a.assemble(),
        act_addr: act,
        w_addr: w,
        bias_addr: bias,
        out_addr: out,
        mem_size: ar.high_water() + 4096,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::MacMode::*;
    use crate::kernels::run::run_depthwise;
    use crate::nn::layers::{qdepthwise, ConvGeom};
    use crate::nn::tensor::Tensor;
    use crate::rng::Rng;

    fn spec(h: usize, w: usize, c: usize, k: usize, stride: usize) -> DwSpec {
        DwSpec { h, w, c, k, stride, rq: Requant::from_real_scale(0.003), relu: true }
    }

    fn check(spec: DwSpec, mode: Option<MacMode>, seed: u64) {
        let mut rng = Rng::new(seed);
        let bits = mode.map_or(8, |m| m.weight_bits());
        let acts: Vec<i8> = (0..spec.h * spec.w * spec.c).map(|_| rng.i8()).collect();
        let wts: Vec<i8> = (0..spec.c * spec.k * spec.k).map(|_| rng.int_bits(bits)).collect();
        let bias: Vec<i32> = (0..spec.c).map(|_| rng.range_i32(-200, 200)).collect();
        let input = Tensor::from_vec(&[spec.h, spec.w, spec.c], acts.clone());
        let want = qdepthwise(
            &input,
            &wts,
            &bias,
            ConvGeom { k: spec.k, stride: spec.stride, pad: 0 },
            spec.rq,
            spec.relu,
        );
        let (got, _) = run_depthwise(spec, mode, &acts, &wts, &bias).unwrap();
        assert_eq!(got, want.data, "{mode:?} {spec:?}");
    }

    #[test]
    fn baseline_matches_reference() {
        check(spec(6, 6, 8, 3, 1), None, 40);
        check(spec(8, 8, 5, 3, 2), None, 41);
    }

    #[test]
    fn mode_kernels_match_reference() {
        for m in [W8, W4, W2] {
            check(spec(6, 6, 8, 3, 1), Some(m), 50);
            check(spec(8, 8, 6, 3, 2), Some(m), 51); // strided
            check(spec(7, 7, 4, 5, 1), Some(m), 52); // 5×5: 25 taps, multi-chunk
        }
    }

    #[test]
    fn depthwise_gains_modest_but_weight_traffic_cut() {
        // The paper's depthwise observation: cycle gains are small, but
        // weight loads still shrink with the packing factor.
        let s = spec(10, 10, 16, 3, 1);
        let mut rng = Rng::new(60);
        let acts: Vec<i8> = (0..s.h * s.w * s.c).map(|_| rng.i8()).collect();
        let bias = vec![0i32; s.c];
        let w8: Vec<i8> = (0..s.c * 9).map(|_| rng.int_bits(8)).collect();
        let w2: Vec<i8> = (0..s.c * 9).map(|_| rng.int_bits(2)).collect();
        let (_, base) = run_depthwise(s, None, &acts, &w8, &bias).unwrap();
        let (_, m3) = run_depthwise(s, Some(W2), &acts, &w2, &bias).unwrap();
        let su = base.cycles as f64 / m3.cycles as f64;
        assert!(su > 1.05, "depthwise Mode-3 should still win: {su:.2}");
        assert!(su < 6.0, "depthwise gains should be modest: {su:.2}");
        assert!(m3.loads < base.loads, "weight loads must shrink");
    }
}
