//! In-kernel requantization: the RV32 instruction sequence computing
//! `clamp(rounding_rshift(SRDHM(acc, m), shift))` — bit-exact against
//! [`crate::nn::quant::requantize`] (property-tested in `tests/`).
//!
//! `SRDHM` on RV32 without 64-bit registers: with `p = acc·m = H·2³² + L`
//! (`mulh`/`mul`),
//!
//! ```text
//! SRDHM(acc, m) = (p + 2³⁰) >> 31
//!               = 2·H + 2·carry + ((L + 2³⁰ mod 2³²) >> 31)
//! ```
//!
//! where `carry = (L + 2³⁰) overflowed`. The sequence costs 10 ALU ops +
//! the `mulh`/`mul` pair, followed by a **branchless** clamp (slt/mask
//! min–max, 11 ALU ops), amortised over one output feature.
//!
//! The emitted shape is a **canonical form contract** with the micro-op
//! engine: `sim::engine`'s `try_requant` matcher recognises exactly this
//! sequence (plus the kernel's trailing `sb` of the result, where
//! present) and collapses it into a single fused `Requant`
//! superinstruction — straight-line code with no labels is what makes
//! the whole epilogue fusible. Keep the two in sync.

use crate::asm::Asm;
use crate::isa::reg::*;
use crate::nn::quant::Requant;

/// Emit the requant prologue: loads the per-layer constants into
/// `s4` (Q31 multiplier), `s5` (rounding constant) and `s6` (clamp low).
pub fn emit_prologue(a: &mut Asm, rq: Requant, relu: bool) {
    a.li(S4, rq.m);
    a.li(S5, if rq.shift > 0 { 1 << (rq.shift - 1) } else { 0 });
    a.li(S6, if relu { 0 } else { -128 });
}

/// Emit requantization of the accumulator in `a0` into an int8 in `a0`.
/// Clobbers `t0..t3`. Requires [`emit_prologue`] constants.
pub fn emit_requantize(a: &mut Asm, rq: Requant) {
    // SRDHM(a0, s4)
    a.mulh(T0, A0, S4); // H
    a.mul(T1, A0, S4); // L (low 32 bits)
    a.emit(crate::isa::Instr::Lui { rd: T2, imm: 0x4000_0000 }); // 2^30
    a.add(T3, T1, T2); // Lr = L + 2^30 (mod 2^32)
    a.emit(crate::isa::Instr::Op { op: crate::isa::AluOp::Sltu, rd: T1, rs1: T3, rs2: T1 }); // carry
    a.srli(T3, T3, 31);
    a.slli(T0, T0, 1);
    a.add(T0, T0, T3);
    a.slli(T1, T1, 1);
    a.add(T0, T0, T1); // t0 = SRDHM
    // Rounding right shift (negative = left shift, scales ≥ 1).
    if rq.shift > 0 {
        a.add(T0, T0, S5);
        a.srai(T0, T0, rq.shift);
    } else if rq.shift < 0 {
        a.slli(T0, T0, -rq.shift);
    }
    // Branchless clamp to [s6, 127]: min then max via slt + mask
    // (`min(a,b) = a ^ ((a^b) & -(b<a))`). Fixed-length straight-line
    // code — no data-dependent control flow, and the engine can fuse
    // the whole epilogue into one micro-op.
    a.li(T1, 127);
    a.slt(T2, T1, T0); // t2 = (127 < t0)
    a.sub(T2, ZERO, T2); // mask = -(127 < t0)
    a.xor(T3, T0, T1);
    a.and(T3, T3, T2);
    a.xor(T0, T0, T3); // t0 = min(t0, 127)
    a.slt(T2, T0, S6); // t2 = (t0 < lo)
    a.sub(T2, ZERO, T2);
    a.xor(T3, T0, S6);
    a.and(T3, T3, T2);
    a.xor(T0, T0, T3); // t0 = max(t0, lo)
    a.mv(A0, T0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::quant::requantize;
    use crate::rng::Rng;
    use crate::sim::{Core, CoreConfig, ExitReason};

    /// Run the emitted sequence on the ISS for one accumulator value.
    fn run_requant(acc: i32, rq: Requant, relu: bool) -> i8 {
        let mut a = Asm::new();
        emit_prologue(&mut a, rq, relu);
        a.li(A0, acc);
        emit_requantize(&mut a, rq);
        a.halt();
        let mut core =
            Core::new(CoreConfig { mem_size: 4096, ..Default::default() }, a.assemble(), 0);
        assert_eq!(core.run(10_000), ExitReason::Ecall);
        core.regs[A0 as usize] as i8
    }

    #[test]
    fn matches_host_reference_randomised() {
        let mut rng = Rng::new(0xBEEF);
        for _ in 0..200 {
            let scale = 2f64.powf(-(rng.f32() as f64) * 14.0 - 0.01);
            let rq = Requant::from_real_scale(scale);
            let acc = rng.next_u32() as i32 >> (rng.below(8) as u32); // vary magnitude
            let relu = rng.below(2) == 0;
            let want = requantize(acc, rq, relu);
            let got = run_requant(acc, rq, relu);
            assert_eq!(got, want, "acc {acc} scale {scale} relu {relu}");
        }
    }

    #[test]
    fn clamps_both_rails() {
        let rq = Requant::from_real_scale(0.5);
        assert_eq!(run_requant(10_000, rq, false), 127);
        assert_eq!(run_requant(-10_000, rq, false), -128);
        assert_eq!(run_requant(-10_000, rq, true), 0);
    }

    /// Canonical-form contract: the exact sequence this module emits
    /// must fuse into the engine's single `Requant` micro-op — for
    /// positive, negative and zero shifts — and execute bit-identically
    /// to the host reference on the fused path.
    #[test]
    fn epilogue_fuses_into_engine_superinstruction() {
        for (scale, acc) in [(0.004, 123_456), (0.6, 37), (1.7, -95)] {
            let rq = Requant::from_real_scale(scale);
            let mut a = Asm::new();
            emit_prologue(&mut a, rq, false);
            a.li(A0, acc);
            emit_requantize(&mut a, rq);
            a.halt();
            let mut core =
                Core::new(CoreConfig { mem_size: 4096, ..Default::default() }, a.assemble(), 0);
            let cp = core.compile();
            assert_eq!(
                cp.fusion_census()[3],
                1,
                "scale {scale}: epilogue must fuse (census {:?})",
                cp.fusion_census()
            );
            assert_eq!(core.run_engine(&cp, 10_000), ExitReason::Ecall);
            assert_eq!(core.engine_stats.requant, 1, "fused path must execute");
            assert_eq!(
                core.regs[A0 as usize] as i8,
                requantize(acc, rq, false),
                "scale {scale} acc {acc}"
            );
        }
    }
}
