//! In-kernel requantization: the RV32 instruction sequence computing
//! `clamp(rounding_rshift(SRDHM(acc, m), shift))` — bit-exact against
//! [`crate::nn::quant::requantize`] (property-tested in `tests/`).
//!
//! `SRDHM` on RV32 without 64-bit registers: with `p = acc·m = H·2³² + L`
//! (`mulh`/`mul`),
//!
//! ```text
//! SRDHM(acc, m) = (p + 2³⁰) >> 31
//!               = 2·H + 2·carry + ((L + 2³⁰ mod 2³²) >> 31)
//! ```
//!
//! where `carry = (L + 2³⁰) overflowed`. The sequence costs 10 ALU ops +
//! the `mulh`/`mul` pair, amortised over one output feature.

use crate::asm::Asm;
use crate::isa::reg::*;
use crate::nn::quant::Requant;

/// Emit the requant prologue: loads the per-layer constants into
/// `s4` (Q31 multiplier), `s5` (rounding constant) and `s6` (clamp low).
pub fn emit_prologue(a: &mut Asm, rq: Requant, relu: bool) {
    a.li(S4, rq.m);
    a.li(S5, if rq.shift > 0 { 1 << (rq.shift - 1) } else { 0 });
    a.li(S6, if relu { 0 } else { -128 });
}

/// Emit requantization of the accumulator in `a0` into an int8 in `a0`.
/// Clobbers `t0..t3`. Requires [`emit_prologue`] constants.
pub fn emit_requantize(a: &mut Asm, rq: Requant) {
    // SRDHM(a0, s4)
    a.mulh(T0, A0, S4); // H
    a.mul(T1, A0, S4); // L (low 32 bits)
    a.emit(crate::isa::Instr::Lui { rd: T2, imm: 0x4000_0000 }); // 2^30
    a.add(T3, T1, T2); // Lr = L + 2^30 (mod 2^32)
    a.emit(crate::isa::Instr::Op { op: crate::isa::AluOp::Sltu, rd: T1, rs1: T3, rs2: T1 }); // carry
    a.srli(T3, T3, 31);
    a.slli(T0, T0, 1);
    a.add(T0, T0, T3);
    a.slli(T1, T1, 1);
    a.add(T0, T0, T1); // t0 = SRDHM
    // Rounding right shift (negative = left shift, scales ≥ 1).
    if rq.shift > 0 {
        a.add(T0, T0, S5);
        a.srai(T0, T0, rq.shift);
    } else if rq.shift < 0 {
        a.slli(T0, T0, -rq.shift);
    }
    // Clamp to [s6, 127].
    let hi_ok = a.new_label();
    let lo_ok = a.new_label();
    a.li(T1, 127);
    a.blt(T0, T1, hi_ok);
    a.mv(T0, T1);
    a.bind(hi_ok);
    a.bge(T0, S6, lo_ok);
    a.mv(T0, S6);
    a.bind(lo_ok);
    a.mv(A0, T0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::quant::requantize;
    use crate::rng::Rng;
    use crate::sim::{Core, CoreConfig, ExitReason};

    /// Run the emitted sequence on the ISS for one accumulator value.
    fn run_requant(acc: i32, rq: Requant, relu: bool) -> i8 {
        let mut a = Asm::new();
        emit_prologue(&mut a, rq, relu);
        a.li(A0, acc);
        emit_requantize(&mut a, rq);
        a.halt();
        let mut core =
            Core::new(CoreConfig { mem_size: 4096, ..Default::default() }, a.assemble(), 0);
        assert_eq!(core.run(10_000), ExitReason::Ecall);
        core.regs[A0 as usize] as i8
    }

    #[test]
    fn matches_host_reference_randomised() {
        let mut rng = Rng::new(0xBEEF);
        for _ in 0..200 {
            let scale = 2f64.powf(-(rng.f32() as f64) * 14.0 - 0.01);
            let rq = Requant::from_real_scale(scale);
            let acc = rng.next_u32() as i32 >> (rng.below(8) as u32); // vary magnitude
            let relu = rng.below(2) == 0;
            let want = requantize(acc, rq, relu);
            let got = run_requant(acc, rq, relu);
            assert_eq!(got, want, "acc {acc} scale {scale} relu {relu}");
        }
    }

    #[test]
    fn clamps_both_rails() {
        let rq = Requant::from_real_scale(0.5);
        assert_eq!(run_requant(10_000, rq, false), 127);
        assert_eq!(run_requant(-10_000, rq, false), -128);
        assert_eq!(run_requant(-10_000, rq, true), 0);
    }
}
