//! NN kernels as RV32 instruction streams — the reproduction of the
//! paper's C kernels ("the respective replacements of the original
//! kernels with kernels incorporating the nn_mac_(x)b operations").
//!
//! Two families per layer type:
//!
//! * **baseline** — straightforward RV32IM scalar code (byte loads,
//!   `mul`/`add`), modelling what a C compiler emits for the original
//!   Ibex (the paper's RV32IMC baseline),
//! * **mode** — the hand-optimised packed kernels using `nn_mac_8b/4b/2b`
//!   with word activation loads and packed weight streams, fully
//!   unrolled over each contiguous dot-product strip.
//!
//! ## Register conventions (all kernels)
//!
//! | regs        | role |
//! |-------------|------|
//! | `s0..s3`    | act / weight / bias / out base pointers |
//! | `s4,s5,s6`  | requant: Q31 multiplier, rounding constant, clamp low |
//! | `s7..s11`   | kernel-specific bases and cursors |
//! | `t0..t3`    | requant + scratch |
//! | `t4,t5,t6`  | bias cursor, out cursor, loop counter |
//! | `a0`        | 32-bit accumulator (the `rd` of `nn_mac`) |
//! | `a1`        | packed weight word (`rs2`) |
//! | `a2..a5`    | activation word group (`rs1..rs1+3`) |
//! | `a6,a7,gp,tp` | loop counters (bare metal — no ABI constraints) |
//!
//! ## Memory map
//!
//! Programs are linked at [`PROG_BASE`]; data buffers are allocated by
//! [`Arena`] from [`DATA_BASE`] with word alignment and a 16-byte slack
//! after activation buffers (partially-filled `nn_mac` words read past a
//! strip's end and multiply the excess by zero weights — the slack keeps
//! those reads in bounds).

pub mod conv;
pub mod dense;
pub mod depthwise;
pub mod requant;
pub mod run;

use crate::isa::Instr;

/// Program link base.
pub const PROG_BASE: u32 = 0x0;
/// Data arena base (leaves room for the largest generated program).
pub const DATA_BASE: u32 = 0x0010_0000;
/// Slack appended after activation buffers for whole-word over-reads.
pub const ACT_SLACK: u32 = 16;

/// Bump allocator for kernel data buffers.
#[derive(Debug, Clone)]
pub struct Arena {
    next: u32,
}

impl Default for Arena {
    fn default() -> Self {
        Self::new()
    }
}

impl Arena {
    /// Arena starting at [`DATA_BASE`].
    pub fn new() -> Self {
        Arena { next: DATA_BASE }
    }

    /// Allocate `size` bytes with `align` alignment; returns the address.
    pub fn alloc(&mut self, size: u32, align: u32) -> u32 {
        debug_assert!(align.is_power_of_two());
        let addr = (self.next + align - 1) & !(align - 1);
        self.next = addr + size;
        addr
    }

    /// Allocate an activation buffer: word-aligned + trailing slack.
    pub fn alloc_act(&mut self, size: u32) -> u32 {
        let a = self.alloc(size + ACT_SLACK, 4);
        a
    }

    /// Bytes allocated so far (for memory sizing).
    pub fn high_water(&self) -> u32 {
        self.next
    }
}

/// A generated kernel program plus the buffer addresses the host must
/// fill / read.
#[derive(Debug, Clone)]
pub struct KernelProgram {
    /// The instruction stream (ends in `ecall`).
    pub prog: Vec<Instr>,
    /// Activation buffer address (int8, layout per kernel).
    pub act_addr: u32,
    /// Weight buffer address (packed u32 words for mode kernels, raw
    /// int8 for baselines).
    pub w_addr: u32,
    /// Bias buffer address (int32).
    pub bias_addr: u32,
    /// Output buffer address (int8, or int32 when `out_i32`).
    pub out_addr: u32,
    /// Required memory size in bytes.
    pub mem_size: u32,
}

impl KernelProgram {
    /// Static instruction count (code size proxy).
    pub fn code_len(&self) -> usize {
        self.prog.len()
    }
}

/// Choose a `li`-free pointer-advance: emits `addi` when the constant
/// fits, else `li t0, c; add`.
pub(crate) fn emit_advance(a: &mut crate::asm::Asm, rd: u8, rs: u8, c: i32) {
    if (-2048..=2047).contains(&c) {
        a.addi(rd, rs, c);
    } else {
        a.li(crate::isa::reg::T0, c);
        a.add(rd, rs, crate::isa::reg::T0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_aligns_and_bumps() {
        let mut ar = Arena::new();
        let a = ar.alloc(3, 4);
        assert_eq!(a % 4, 0);
        let b = ar.alloc(8, 4);
        assert!(b >= a + 3);
        assert_eq!(b % 4, 0);
        let c = ar.alloc_act(10);
        assert_eq!(c % 4, 0);
        assert!(ar.high_water() >= c + 10 + ACT_SLACK);
    }
}
