//! Dense (fully-connected) layer kernels.
//!
//! * [`build_baseline`] — scalar RV32IM code: per-element byte loads,
//!   `mul`/`add`, pointer bumps (what the compiler emits for the paper's
//!   original Ibex kernels).
//! * [`build_mode`] — packed `nn_mac` kernel: the inner dot product is
//!   fully unrolled with immediate-offset word loads when the row fits
//!   in the 12-bit offset range, otherwise chunk-looped with pointer
//!   bumps. One `nn_mac_<x>b` retires 4/8/16 MACs.

use super::requant::{emit_prologue, emit_requantize};
use super::{emit_advance, Arena, KernelProgram};
use crate::asm::Asm;
use crate::isa::reg::*;
use crate::isa::MacMode;
use crate::nn::pack::words_per_group;
use crate::nn::quant::Requant;

/// Dense kernel shape/behaviour parameters.
#[derive(Debug, Clone, Copy)]
pub struct DenseSpec {
    /// Input features.
    pub in_dim: usize,
    /// Output features.
    pub out_dim: usize,
    /// Requantization parameters (ignored when `out_i32`).
    pub rq: Requant,
    /// Fused ReLU.
    pub relu: bool,
    /// Emit raw int32 accumulators (final logits layer).
    pub out_i32: bool,
}

/// Build the scalar baseline kernel.
///
/// Layout: activations int8 `[I]` at `act_addr`, weights int8 `[O][I]`
/// row-major at `w_addr`, bias int32 `[O]`, output int8 `[O]`
/// (or int32 `[O]` when `out_i32`).
pub fn build_baseline(spec: DenseSpec) -> KernelProgram {
    let mut ar = Arena::new();
    let act = ar.alloc_act(spec.in_dim as u32);
    let w = ar.alloc((spec.out_dim * spec.in_dim) as u32, 4);
    let bias = ar.alloc(4 * spec.out_dim as u32, 4);
    let out = ar.alloc(4 * spec.out_dim as u32, 4);

    let mut a = Asm::new();
    a.li(S0, act as i32);
    a.li(S1, w as i32);
    a.li(S2, bias as i32);
    a.li(S3, out as i32);
    if !spec.out_i32 {
        emit_prologue(&mut a, spec.rq, spec.relu);
    }
    a.mv(T4, S2); // bias cursor
    a.mv(T5, S3); // out cursor
    a.mv(S11, S1); // weight cursor (monotonic over rows)
    a.li(A6, spec.out_dim as i32); // output counter

    let outer = a.new_label();
    a.bind(outer);
    a.lw(A0, T4, 0); // acc = bias
    a.mv(S10, S0); // act cursor
    a.li(T6, spec.in_dim as i32); // element counter
    let inner = a.new_label();
    a.bind(inner);
    // Scalar MAC: lb act, lb weight, mul, add.
    a.lb(T0, S10, 0);
    a.lb(T1, S11, 0);
    a.mul(T0, T0, T1);
    a.add(A0, A0, T0);
    a.addi(S10, S10, 1);
    a.addi(S11, S11, 1);
    a.addi(T6, T6, -1);
    a.bne(T6, ZERO, inner);

    if spec.out_i32 {
        a.sw(T5, A0, 0);
        a.addi(T5, T5, 4);
    } else {
        emit_requantize(&mut a, spec.rq);
        a.sb(T5, A0, 0);
        a.addi(T5, T5, 1);
    }
    a.addi(T4, T4, 4);
    a.addi(A6, A6, -1);
    a.bne(A6, ZERO, outer);
    a.halt();

    KernelProgram {
        prog: a.assemble(),
        act_addr: act,
        w_addr: w,
        bias_addr: bias,
        out_addr: out,
        mem_size: ar.high_water() + 4096,
    }
}

/// Maximum immediate-offset reach for the unrolled inner product.
const UNROLL_OFFSET_LIMIT: usize = 2000;

/// Build the packed `nn_mac` kernel for `mode`.
///
/// Layout: activations int8 `[I]` (word-aligned, slack-padded), weights
/// packed u32 per output row (see [`crate::nn::pack::pack_dense`]),
/// bias int32 `[O]`, output as in the baseline.
pub fn build_mode(mode: MacMode, spec: DenseSpec) -> KernelProgram {
    let n = mode.weights_per_word() as usize; // MACs per instruction
    let wpg = words_per_group(mode, spec.in_dim); // weight words per row
    let mut ar = Arena::new();
    let act = ar.alloc_act(spec.in_dim.next_multiple_of(4) as u32);
    let w = ar.alloc((spec.out_dim * wpg * 4) as u32, 4);
    let bias = ar.alloc(4 * spec.out_dim as u32, 4);
    let out = ar.alloc(4 * spec.out_dim as u32, 4);

    let mut a = Asm::new();
    a.li(S0, act as i32);
    a.li(S1, w as i32);
    a.li(S2, bias as i32);
    a.li(S3, out as i32);
    if !spec.out_i32 {
        emit_prologue(&mut a, spec.rq, spec.relu);
    }
    a.mv(T4, S2);
    a.mv(T5, S3);
    a.mv(S11, S1); // weight row cursor
    a.li(A6, spec.out_dim as i32);

    let outer = a.new_label();
    a.bind(outer);
    a.lw(A0, T4, 0); // acc = bias

    let act_words_per_chunk = mode.activation_regs() as usize;
    if spec.in_dim <= UNROLL_OFFSET_LIMIT && wpg * 4 <= UNROLL_OFFSET_LIMIT {
        // Fully unrolled: immediate offsets off s0 (acts) and s11 (row).
        for c in 0..wpg {
            for k in 0..act_words_per_chunk {
                a.lw(A2 + k as u8, S0, (c * n + 4 * k) as i32);
            }
            a.lw(A1, S11, (4 * c) as i32);
            a.nn_mac(mode, A0, A2, A1);
        }
        emit_advance(&mut a, S11, S11, (4 * wpg) as i32);
    } else {
        // Chunk loop with pointer bumps (large layers).
        a.mv(S10, S0);
        a.li(T6, wpg as i32);
        let inner = a.new_label();
        a.bind(inner);
        for k in 0..act_words_per_chunk {
            a.lw(A2 + k as u8, S10, (4 * k) as i32);
        }
        a.lw(A1, S11, 0);
        a.nn_mac(mode, A0, A2, A1);
        a.addi(S10, S10, n as i32);
        a.addi(S11, S11, 4);
        a.addi(T6, T6, -1);
        a.bne(T6, ZERO, inner);
    }

    if spec.out_i32 {
        a.sw(T5, A0, 0);
        a.addi(T5, T5, 4);
    } else {
        emit_requantize(&mut a, spec.rq);
        a.sb(T5, A0, 0);
        a.addi(T5, T5, 1);
    }
    a.addi(T4, T4, 4);
    a.addi(A6, A6, -1);
    a.bne(A6, ZERO, outer);
    a.halt();

    KernelProgram {
        prog: a.assemble(),
        act_addr: act,
        w_addr: w,
        bias_addr: bias,
        out_addr: out,
        mem_size: ar.high_water() + 4096,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::MacMode::*;
    use crate::kernels::run::run_dense;
    use crate::nn::layers::qdense;
    use crate::rng::Rng;

    fn spec(in_dim: usize, out_dim: usize, relu: bool, out_i32: bool) -> DenseSpec {
        DenseSpec { in_dim, out_dim, rq: Requant::from_real_scale(0.004), relu, out_i32 }
    }

    fn check(spec: DenseSpec, mode: Option<MacMode>, seed: u64) {
        let mut rng = Rng::new(seed);
        let bits = mode.map_or(8, |m| m.weight_bits());
        let acts: Vec<i8> = (0..spec.in_dim).map(|_| rng.i8()).collect();
        let w: Vec<i8> =
            (0..spec.in_dim * spec.out_dim).map(|_| rng.int_bits(bits)).collect();
        let bias: Vec<i32> = (0..spec.out_dim).map(|_| rng.range_i32(-500, 500)).collect();
        let (want_q, want_acc) = qdense(
            &acts,
            &w,
            &bias,
            spec.out_dim,
            if spec.out_i32 { None } else { Some(spec.rq) },
            spec.relu,
        );
        let (got_q, got_acc, _) = run_dense(spec, mode, &acts, &w, &bias).unwrap();
        if spec.out_i32 {
            assert_eq!(got_acc, want_acc, "{mode:?}");
        } else {
            assert_eq!(got_q, want_q, "{mode:?}");
        }
    }

    #[test]
    fn baseline_matches_reference() {
        check(spec(17, 5, true, false), None, 1);
        check(spec(32, 3, false, true), None, 2);
    }

    #[test]
    fn mode_kernels_match_reference_unrolled() {
        for mode in [W8, W4, W2] {
            check(spec(64, 7, true, false), Some(mode), 3);
            // Non-multiple-of-16 input dim exercises tail padding.
            check(spec(50, 4, false, false), Some(mode), 4);
            check(spec(24, 3, false, true), Some(mode), 5);
        }
    }

    #[test]
    fn mode_kernels_match_reference_looped() {
        // in_dim above the unroll limit takes the chunk-loop path.
        for mode in [W8, W4, W2] {
            check(spec(2304, 3, true, false), Some(mode), 6);
        }
    }

    #[test]
    fn mode_kernels_cut_cycles_and_accesses() {
        let s = spec(256, 16, true, false);
        let mut rng = Rng::new(9);
        let acts: Vec<i8> = (0..s.in_dim).map(|_| rng.i8()).collect();
        let bias: Vec<i32> = vec![0; s.out_dim];
        let w8: Vec<i8> = (0..s.in_dim * s.out_dim).map(|_| rng.int_bits(8)).collect();
        let w2: Vec<i8> = (0..s.in_dim * s.out_dim).map(|_| rng.int_bits(2)).collect();
        let (_, _, base) = run_dense(s, None, &acts, &w8, &bias).unwrap();
        let (_, _, m1) = run_dense(s, Some(W8), &acts, &w8, &bias).unwrap();
        let (_, _, m3) = run_dense(s, Some(W2), &acts, &w2, &bias).unwrap();
        let su1 = base.cycles as f64 / m1.cycles as f64;
        let su3 = base.cycles as f64 / m3.cycles as f64;
        assert!(su1 > 4.0, "Mode-1 speedup too small: {su1:.2}");
        assert!(su3 > su1, "Mode-3 ({su3:.2}) must beat Mode-1 ({su1:.2})");
        // Fig. 4: packed kernels slash memory accesses.
        assert!(m3.mem_accesses() * 4 < base.mem_accesses(), "accesses {m3:?} vs {base:?}");
    }
}
