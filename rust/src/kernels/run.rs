//! Host-side kernel runners: build the kernel program, stage operands in
//! simulator memory (packing weights for the mode kernels), execute on
//! the cycle-accurate core and read back results + perf counters.
//!
//! These are the measurement entry points used by the tests, the Fig. 4 /
//! Fig. 7 / Fig. 8 harnesses and the DSE's per-layer cycle model.

use super::conv::ConvSpec;
use super::dense::DenseSpec;
use super::depthwise::DwSpec;
use super::KernelProgram;
use crate::isa::MacMode;
use crate::nn::pack::{pack_conv, pack_dense, pack_depthwise};
use crate::sim::{Core, CoreConfig, ExitReason, MacUnitConfig, PerfCounters};

/// Execute a staged kernel program and return the perf counters.
fn exec(prog: &KernelProgram, mac: MacUnitConfig, stage: impl FnOnce(&mut Core)) -> Core {
    let cfg = CoreConfig {
        mac,
        mem_size: prog.mem_size.max(super::DATA_BASE + 4096) as usize,
        ..Default::default()
    };
    let mut core = Core::new(cfg, prog.prog.clone(), super::PROG_BASE);
    stage(&mut core);
    core.mem.reset_counters(); // measure only the kernel's own traffic
    let reason = core.run(u64::MAX);
    assert_eq!(reason, ExitReason::Ecall, "kernel did not run to completion: {reason:?}");
    core
}

/// Run a dense layer. Returns `(int8 outputs, int32 accumulators, perf)` —
/// one of the two output vectors is empty depending on `spec.out_i32`.
pub fn run_dense(
    spec: DenseSpec,
    mode: Option<MacMode>,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> (Vec<i8>, Vec<i32>, PerfCounters) {
    run_dense_with(spec, mode, MacUnitConfig::full(), acts, w, bias)
}

/// [`run_dense`] with an explicit MAC-unit configuration (Fig. 7 ablations).
pub fn run_dense_with(
    spec: DenseSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> (Vec<i8>, Vec<i32>, PerfCounters) {
    assert_eq!(acts.len(), spec.in_dim);
    assert_eq!(w.len(), spec.in_dim * spec.out_dim);
    assert_eq!(bias.len(), spec.out_dim);
    let kp = match mode {
        None => super::dense::build_baseline(spec),
        Some(m) => super::dense::build_mode(m, spec),
    };
    let core = exec(&kp, mac, |core| {
        core.mem.write_i8(kp.act_addr, acts);
        match mode {
            None => core.mem.write_i8(kp.w_addr, w),
            Some(m) => core.mem.write_words(kp.w_addr, &pack_dense(m, w, spec.out_dim, spec.in_dim)),
        }
        core.mem.write_i32(kp.bias_addr, bias);
    });
    if spec.out_i32 {
        (Vec::new(), core.mem.read_i32(kp.out_addr, spec.out_dim), core.perf)
    } else {
        (core.mem.read_i8(kp.out_addr, spec.out_dim), Vec::new(), core.perf)
    }
}

/// Run a standard convolution. Returns `(int8 NHWC outputs, perf)`.
pub fn run_conv(
    spec: ConvSpec,
    mode: Option<MacMode>,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> (Vec<i8>, PerfCounters) {
    run_conv_with(spec, mode, MacUnitConfig::full(), acts, w, bias)
}

/// [`run_conv`] with an explicit MAC-unit configuration.
pub fn run_conv_with(
    spec: ConvSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> (Vec<i8>, PerfCounters) {
    assert_eq!(acts.len(), spec.h * spec.w * spec.cin);
    assert_eq!(w.len(), spec.cout * spec.k * spec.k * spec.cin);
    assert_eq!(bias.len(), spec.cout);
    let kp = match mode {
        None => super::conv::build_baseline(spec),
        Some(m) => super::conv::build_mode(m, spec),
    };
    let core = exec(&kp, mac, |core| {
        core.mem.write_i8(kp.act_addr, acts);
        match mode {
            None => core.mem.write_i8(kp.w_addr, w),
            Some(m) => {
                core.mem.write_words(kp.w_addr, &pack_conv(m, w, spec.cout, spec.k, spec.cin))
            }
        }
        core.mem.write_i32(kp.bias_addr, bias);
    });
    (core.mem.read_i8(kp.out_addr, spec.ho() * spec.wo() * spec.cout), core.perf)
}

/// Run a depthwise convolution. Returns `(int8 NHWC outputs, perf)`.
pub fn run_depthwise(
    spec: DwSpec,
    mode: Option<MacMode>,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> (Vec<i8>, PerfCounters) {
    run_depthwise_with(spec, mode, MacUnitConfig::full(), acts, w, bias)
}

/// [`run_depthwise`] with an explicit MAC-unit configuration.
pub fn run_depthwise_with(
    spec: DwSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> (Vec<i8>, PerfCounters) {
    assert_eq!(acts.len(), spec.h * spec.w * spec.c);
    assert_eq!(w.len(), spec.c * spec.k * spec.k);
    assert_eq!(bias.len(), spec.c);
    let kp = match mode {
        None => super::depthwise::build_baseline(spec),
        Some(m) => super::depthwise::build_mode(m, spec),
    };
    let core = exec(&kp, mac, |core| {
        core.mem.write_i8(kp.act_addr, acts);
        match mode {
            None => core.mem.write_i8(kp.w_addr, w),
            Some(m) => core.mem.write_words(kp.w_addr, &pack_depthwise(m, w, spec.c, spec.k)),
        }
        core.mem.write_i32(kp.bias_addr, bias);
    });
    (core.mem.read_i8(kp.out_addr, spec.ho() * spec.wo() * spec.c), core.perf)
}
