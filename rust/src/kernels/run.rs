//! Host-side kernel runners: build the kernel program, stage operands in
//! simulator memory (packing weights for the mode kernels), execute on
//! the cycle-accurate core and read back results + perf counters.
//!
//! These are the measurement entry points used by the tests, the Fig. 4 /
//! Fig. 7 / Fig. 8 harnesses and the DSE's per-layer cycle model.
//!
//! ## Compile-once / run-many
//!
//! Every `(spec, mode)` pair is assembled and translated for the
//! micro-op engine exactly once: a process-wide **kernel cache** maps
//! the spec key to an [`Arc<CompiledKernel>`], and executions go
//! through [`crate::sim::session::SimSession::global`]'s memory pool —
//! a DSE sweep or whole-model run no longer pays per-invocation
//! assembly + 16 MiB allocation. The MAC-unit configuration is *not*
//! part of the key: the generated program is identical across Fig.-7
//! ablations (nn_mac cycle costs come from the structural
//! [`crate::sim::MacUnit`] at issue time), so ablation sweeps share one
//! image.
//!
//! A kernel that exits any way other than `ecall` (memory fault,
//! runaway pc) surfaces as an `Err`, not a process abort.

use super::conv::ConvSpec;
use super::dense::DenseSpec;
use super::depthwise::DwSpec;
use super::KernelProgram;
use crate::ensure;
use crate::error::Result;
use crate::isa::MacMode;
use crate::nn::pack::{pack_conv, pack_dense, pack_depthwise, words_per_group};
use crate::sim::session::{CompiledImage, CostKey, KernelShape, SimSession};
use crate::sim::{Core, CoreConfig, ExitReason, MacUnitConfig, PerfCounters, Timing};
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

/// A weight operand already in the form the kernel consumes from
/// simulator memory: raw int8 for baseline kernels, packed `nn_mac`
/// words for mode kernels. The `run_*_staged` entry points take this
/// directly so callers that pre-stage weights — the execution-plan
/// compiler ([`crate::models::plan`]) packs every kernel's stream once
/// per configuration — skip the per-invocation packing the plain
/// `run_*` wrappers perform.
#[derive(Debug, Clone, Copy)]
pub enum StagedWeights<'a> {
    /// Raw int8 weight stream (baseline kernels).
    Bytes(&'a [i8]),
    /// Packed weight words (mode kernels).
    Words(&'a [u32]),
}

impl StagedWeights<'_> {
    /// Write the operand into kernel memory at `addr`.
    fn write(&self, core: &mut Core, addr: u32) {
        match self {
            StagedWeights::Bytes(b) => core.mem.write_i8(addr, b),
            StagedWeights::Words(w) => core.mem.write_words(addr, w),
        }
    }

    /// Validate the staged form matches `mode` and carries exactly
    /// `bytes` raw weights / `words` packed words.
    fn check(&self, what: &str, mode: Option<MacMode>, bytes: usize, words: usize) -> Result<()> {
        match (self, mode) {
            (StagedWeights::Bytes(b), None) => {
                ensure!(b.len() == bytes, "{what}: staged {} weight bytes, need {bytes}", b.len());
            }
            (StagedWeights::Words(w), Some(_)) => {
                ensure!(w.len() == words, "{what}: staged {} weight words, need {words}", w.len());
            }
            (StagedWeights::Bytes(_), Some(m)) => {
                crate::bail!("{what}: mode {m:?} kernel needs packed words, got raw bytes")
            }
            (StagedWeights::Words(_), None) => {
                crate::bail!("{what}: baseline kernel needs raw bytes, got packed words")
            }
        }
        Ok(())
    }
}

/// Which interpreter executes the kernel (see `sim::engine`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecBackend {
    /// Pre-decoded micro-op engine (the default fast path).
    #[default]
    Engine,
    /// Reference interpreter (`Core::step`) — the semantic oracle,
    /// kept selectable for differential testing and benching.
    Legacy,
}

/// A kernel prepared for repeated execution.
#[derive(Debug)]
pub struct CompiledKernel {
    /// Operand buffer addresses + memory footprint. Its `prog` vector
    /// is **empty**: the decoded stream was moved into `image.prog`
    /// (shared `Arc`) so the never-evicted cache doesn't hold every
    /// instruction stream twice.
    pub kp: KernelProgram,
    /// Encoded + engine-translated image (owns the decoded program).
    pub image: CompiledImage,
}

/// Kernel-cache key: the full generation-relevant spec. The MAC-unit
/// configuration is intentionally absent (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum KernelKey {
    Dense {
        in_dim: usize,
        out_dim: usize,
        m: i32,
        shift: i32,
        relu: bool,
        out_i32: bool,
        mode: Option<MacMode>,
    },
    Conv {
        h: usize,
        w: usize,
        cin: usize,
        cout: usize,
        k: usize,
        stride: usize,
        m: i32,
        shift: i32,
        relu: bool,
        mode: Option<MacMode>,
    },
    Dw {
        h: usize,
        w: usize,
        c: usize,
        k: usize,
        stride: usize,
        m: i32,
        shift: i32,
        relu: bool,
        mode: Option<MacMode>,
    },
}

/// Analytic cost-cache key for a dense execution — the same fields as
/// the kernel-cache key plus the MAC-unit configuration, which changes
/// the counters but not the program (see
/// [`crate::sim::session::CostKey`]).
pub fn dense_cost_key(spec: &DenseSpec, mode: Option<MacMode>, mac: MacUnitConfig) -> CostKey {
    CostKey {
        shape: KernelShape::Dense {
            in_dim: spec.in_dim,
            out_dim: spec.out_dim,
            m: spec.rq.m,
            shift: spec.rq.shift,
            relu: spec.relu,
            out_i32: spec.out_i32,
        },
        mode,
        mac,
    }
}

/// Analytic cost-cache key for a conv execution (see [`dense_cost_key`]).
pub fn conv_cost_key(spec: &ConvSpec, mode: Option<MacMode>, mac: MacUnitConfig) -> CostKey {
    CostKey {
        shape: KernelShape::Conv {
            h: spec.h,
            w: spec.w,
            cin: spec.cin,
            cout: spec.cout,
            k: spec.k,
            stride: spec.stride,
            m: spec.rq.m,
            shift: spec.rq.shift,
            relu: spec.relu,
        },
        mode,
        mac,
    }
}

/// Analytic cost-cache key for a depthwise execution (see
/// [`dense_cost_key`]).
pub fn depthwise_cost_key(spec: &DwSpec, mode: Option<MacMode>, mac: MacUnitConfig) -> CostKey {
    CostKey {
        shape: KernelShape::Dw {
            h: spec.h,
            w: spec.w,
            c: spec.c,
            k: spec.k,
            stride: spec.stride,
            m: spec.rq.m,
            shift: spec.rq.shift,
            relu: spec.relu,
        },
        mode,
        mac,
    }
}

fn cache() -> &'static Mutex<HashMap<KernelKey, Arc<CompiledKernel>>> {
    static CACHE: OnceLock<Mutex<HashMap<KernelKey, Arc<CompiledKernel>>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Distinct kernels currently cached (observability/tests).
pub fn kernel_cache_len() -> usize {
    cache().lock().unwrap().len()
}

/// Fetch (or build + translate + insert) the kernel for `key`.
fn cached(key: KernelKey, build: impl FnOnce() -> KernelProgram) -> Arc<CompiledKernel> {
    if let Some(k) = cache().lock().unwrap().get(&key) {
        return Arc::clone(k);
    }
    // Build outside the lock — assembly/translation can be slow and
    // other kernels shouldn't serialise behind it. A racing builder of
    // the same key just loses its work.
    let mut kp = build();
    let prog = std::mem::take(&mut kp.prog);
    let image = CompiledImage::new(prog, super::PROG_BASE, Timing::default());
    let ck = Arc::new(CompiledKernel { kp, image });
    Arc::clone(cache().lock().unwrap().entry(key).or_insert(ck))
}

/// Execute a staged kernel and return (`read` result, perf counters).
fn exec<T>(
    ck: &CompiledKernel,
    mac: MacUnitConfig,
    backend: ExecBackend,
    stage: impl FnOnce(&mut Core),
    read: impl FnOnce(&Core) -> T,
) -> Result<(T, PerfCounters)> {
    let cfg = CoreConfig {
        mac,
        mem_size: ck.kp.mem_size.max(super::DATA_BASE + 4096) as usize,
        ..Default::default()
    };
    let mut perf = PerfCounters::default();
    let (out, reason) = SimSession::global().execute_backend(
        cfg,
        &ck.image,
        backend == ExecBackend::Engine,
        stage,
        |core| {
            perf = core.perf;
            read(core)
        },
    );
    ensure!(reason == ExitReason::Ecall, "kernel did not run to completion: {reason:?}");
    Ok((out, perf))
}

/// Run a dense layer. Returns `(int8 outputs, int32 accumulators, perf)` —
/// one of the two output vectors is empty depending on `spec.out_i32`.
pub fn run_dense(
    spec: DenseSpec,
    mode: Option<MacMode>,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> Result<(Vec<i8>, Vec<i32>, PerfCounters)> {
    run_dense_with(spec, mode, MacUnitConfig::full(), acts, w, bias)
}

/// [`run_dense`] with an explicit MAC-unit configuration (Fig. 7 ablations).
pub fn run_dense_with(
    spec: DenseSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> Result<(Vec<i8>, Vec<i32>, PerfCounters)> {
    run_dense_backend(spec, mode, mac, ExecBackend::default(), acts, w, bias)
}

/// [`run_dense_with`] with an explicit interpreter choice.
pub fn run_dense_backend(
    spec: DenseSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    backend: ExecBackend,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> Result<(Vec<i8>, Vec<i32>, PerfCounters)> {
    ensure!(w.len() == spec.in_dim * spec.out_dim, "dense: weight count mismatch");
    match mode {
        None => {
            run_dense_staged(spec, mode, mac, backend, acts, StagedWeights::Bytes(w), bias)
        }
        Some(m) => {
            let words = pack_dense(m, w, spec.out_dim, spec.in_dim);
            run_dense_staged(spec, mode, mac, backend, acts, StagedWeights::Words(&words), bias)
        }
    }
}

/// [`run_dense_backend`] with the weights already in staged form (the
/// execution-plan fast path: no per-invocation packing).
pub fn run_dense_staged(
    spec: DenseSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    backend: ExecBackend,
    acts: &[i8],
    w: StagedWeights<'_>,
    bias: &[i32],
) -> Result<(Vec<i8>, Vec<i32>, PerfCounters)> {
    ensure!(
        acts.len() == spec.in_dim,
        "dense: {} activations for in_dim {}",
        acts.len(),
        spec.in_dim
    );
    let words = mode.map_or(0, |m| spec.out_dim * words_per_group(m, spec.in_dim));
    w.check("dense", mode, spec.in_dim * spec.out_dim, words)?;
    ensure!(bias.len() == spec.out_dim, "dense: bias count mismatch");
    let key = KernelKey::Dense {
        in_dim: spec.in_dim,
        out_dim: spec.out_dim,
        m: spec.rq.m,
        shift: spec.rq.shift,
        relu: spec.relu,
        out_i32: spec.out_i32,
        mode,
    };
    let ck = cached(key, || match mode {
        None => super::dense::build_baseline(spec),
        Some(m) => super::dense::build_mode(m, spec),
    });
    let kp = &ck.kp;
    let (out, perf) = exec(
        &ck,
        mac,
        backend,
        |core| {
            core.mem.write_i8(kp.act_addr, acts);
            w.write(core, kp.w_addr);
            core.mem.write_i32(kp.bias_addr, bias);
        },
        |core| {
            if spec.out_i32 {
                (Vec::new(), core.mem.read_i32(kp.out_addr, spec.out_dim))
            } else {
                (core.mem.read_i8(kp.out_addr, spec.out_dim), Vec::new())
            }
        },
    )?;
    Ok((out.0, out.1, perf))
}

/// Run a standard convolution. Returns `(int8 NHWC outputs, perf)`.
pub fn run_conv(
    spec: ConvSpec,
    mode: Option<MacMode>,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> Result<(Vec<i8>, PerfCounters)> {
    run_conv_with(spec, mode, MacUnitConfig::full(), acts, w, bias)
}

/// [`run_conv`] with an explicit MAC-unit configuration.
pub fn run_conv_with(
    spec: ConvSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> Result<(Vec<i8>, PerfCounters)> {
    run_conv_backend(spec, mode, mac, ExecBackend::default(), acts, w, bias)
}

/// [`run_conv_with`] with an explicit interpreter choice.
pub fn run_conv_backend(
    spec: ConvSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    backend: ExecBackend,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> Result<(Vec<i8>, PerfCounters)> {
    ensure!(w.len() == spec.cout * spec.k * spec.k * spec.cin, "conv: weight count mismatch");
    match mode {
        None => run_conv_staged(spec, mode, mac, backend, acts, StagedWeights::Bytes(w), bias),
        Some(m) => {
            let words = pack_conv(m, w, spec.cout, spec.k, spec.cin);
            run_conv_staged(spec, mode, mac, backend, acts, StagedWeights::Words(&words), bias)
        }
    }
}

/// [`run_conv_backend`] with the weights already in staged form (the
/// execution-plan fast path: no per-invocation packing).
pub fn run_conv_staged(
    spec: ConvSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    backend: ExecBackend,
    acts: &[i8],
    w: StagedWeights<'_>,
    bias: &[i32],
) -> Result<(Vec<i8>, PerfCounters)> {
    ensure!(acts.len() == spec.h * spec.w * spec.cin, "conv: activation count mismatch");
    let words = mode.map_or(0, |m| spec.cout * spec.k * words_per_group(m, spec.k * spec.cin));
    w.check("conv", mode, spec.cout * spec.k * spec.k * spec.cin, words)?;
    ensure!(bias.len() == spec.cout, "conv: bias count mismatch");
    let key = KernelKey::Conv {
        h: spec.h,
        w: spec.w,
        cin: spec.cin,
        cout: spec.cout,
        k: spec.k,
        stride: spec.stride,
        m: spec.rq.m,
        shift: spec.rq.shift,
        relu: spec.relu,
        mode,
    };
    let ck = cached(key, || match mode {
        None => super::conv::build_baseline(spec),
        Some(m) => super::conv::build_mode(m, spec),
    });
    let kp = &ck.kp;
    let (out, perf) = exec(
        &ck,
        mac,
        backend,
        |core| {
            core.mem.write_i8(kp.act_addr, acts);
            w.write(core, kp.w_addr);
            core.mem.write_i32(kp.bias_addr, bias);
        },
        |core| core.mem.read_i8(kp.out_addr, spec.ho() * spec.wo() * spec.cout),
    )?;
    Ok((out, perf))
}

/// Run a depthwise convolution. Returns `(int8 NHWC outputs, perf)`.
pub fn run_depthwise(
    spec: DwSpec,
    mode: Option<MacMode>,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> Result<(Vec<i8>, PerfCounters)> {
    run_depthwise_with(spec, mode, MacUnitConfig::full(), acts, w, bias)
}

/// [`run_depthwise`] with an explicit MAC-unit configuration.
pub fn run_depthwise_with(
    spec: DwSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> Result<(Vec<i8>, PerfCounters)> {
    run_depthwise_backend(spec, mode, mac, ExecBackend::default(), acts, w, bias)
}

/// [`run_depthwise_with`] with an explicit interpreter choice.
pub fn run_depthwise_backend(
    spec: DwSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    backend: ExecBackend,
    acts: &[i8],
    w: &[i8],
    bias: &[i32],
) -> Result<(Vec<i8>, PerfCounters)> {
    ensure!(w.len() == spec.c * spec.k * spec.k, "depthwise: weight count mismatch");
    match mode {
        None => {
            run_depthwise_staged(spec, mode, mac, backend, acts, StagedWeights::Bytes(w), bias)
        }
        Some(m) => {
            let words = pack_depthwise(m, w, spec.c, spec.k);
            run_depthwise_staged(spec, mode, mac, backend, acts, StagedWeights::Words(&words), bias)
        }
    }
}

/// [`run_depthwise_backend`] with the weights already in staged form
/// (the execution-plan fast path: no per-invocation packing).
pub fn run_depthwise_staged(
    spec: DwSpec,
    mode: Option<MacMode>,
    mac: MacUnitConfig,
    backend: ExecBackend,
    acts: &[i8],
    w: StagedWeights<'_>,
    bias: &[i32],
) -> Result<(Vec<i8>, PerfCounters)> {
    ensure!(acts.len() == spec.h * spec.w * spec.c, "depthwise: activation count mismatch");
    let words = mode.map_or(0, |m| spec.c * words_per_group(m, spec.k * spec.k));
    w.check("depthwise", mode, spec.c * spec.k * spec.k, words)?;
    ensure!(bias.len() == spec.c, "depthwise: bias count mismatch");
    let key = KernelKey::Dw {
        h: spec.h,
        w: spec.w,
        c: spec.c,
        k: spec.k,
        stride: spec.stride,
        m: spec.rq.m,
        shift: spec.rq.shift,
        relu: spec.relu,
        mode,
    };
    let ck = cached(key, || match mode {
        None => super::depthwise::build_baseline(spec),
        Some(m) => super::depthwise::build_mode(m, spec),
    });
    let kp = &ck.kp;
    let (out, perf) = exec(
        &ck,
        mac,
        backend,
        |core| {
            core.mem.write_i8(kp.act_addr, acts);
            w.write(core, kp.w_addr);
            core.mem.write_i32(kp.bias_addr, bias);
        },
        |core| core.mem.read_i8(kp.out_addr, spec.ho() * spec.wo() * spec.c),
    )?;
    Ok((out, perf))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::quant::Requant;
    use crate::rng::Rng;

    fn small_spec() -> DenseSpec {
        DenseSpec {
            in_dim: 32,
            out_dim: 4,
            rq: Requant::from_real_scale(0.004),
            relu: true,
            out_i32: false,
        }
    }

    #[test]
    fn engine_and_legacy_backends_agree() {
        let spec = small_spec();
        let mut rng = Rng::new(11);
        let acts: Vec<i8> = (0..spec.in_dim).map(|_| rng.i8()).collect();
        let w: Vec<i8> = (0..spec.in_dim * spec.out_dim).map(|_| rng.int_bits(4)).collect();
        let bias: Vec<i32> = (0..spec.out_dim).map(|_| rng.range_i32(-100, 100)).collect();
        for mode in [None, Some(MacMode::W4)] {
            let (qe, _, pe) = run_dense_backend(
                spec, mode, MacUnitConfig::full(), ExecBackend::Engine, &acts, &w, &bias,
            )
            .unwrap();
            let (ql, _, pl) = run_dense_backend(
                spec, mode, MacUnitConfig::full(), ExecBackend::Legacy, &acts, &w, &bias,
            )
            .unwrap();
            assert_eq!(qe, ql, "{mode:?}");
            assert_eq!(pe, pl, "{mode:?}");
        }
    }

    #[test]
    fn repeated_runs_hit_the_kernel_cache() {
        let spec = DenseSpec {
            in_dim: 24,
            out_dim: 3,
            rq: Requant::from_real_scale(0.005),
            relu: false,
            out_i32: false,
        };
        // Identity-based check on this spec's own entry: global cache
        // *length* would race with other tests inserting concurrently.
        let key = KernelKey::Dense {
            in_dim: spec.in_dim,
            out_dim: spec.out_dim,
            m: spec.rq.m,
            shift: spec.rq.shift,
            relu: spec.relu,
            out_i32: spec.out_i32,
            mode: Some(MacMode::W8),
        };
        let mut rng = Rng::new(5);
        let acts: Vec<i8> = (0..spec.in_dim).map(|_| rng.i8()).collect();
        let w: Vec<i8> = (0..spec.in_dim * spec.out_dim).map(|_| rng.int_bits(8)).collect();
        let bias: Vec<i32> = vec![0; spec.out_dim];
        let (a, _, _) = run_dense(spec, Some(MacMode::W8), &acts, &w, &bias).unwrap();
        let first = Arc::clone(cache().lock().unwrap().get(&key).expect("cached on first run"));
        let (b, _, _) = run_dense(spec, Some(MacMode::W8), &acts, &w, &bias).unwrap();
        assert_eq!(a, b);
        let second = Arc::clone(cache().lock().unwrap().get(&key).unwrap());
        assert!(Arc::ptr_eq(&first, &second), "second run must reuse the compiled kernel");
        // Ablation configs share the image too (mac config is not keyed).
        run_dense_with(spec, Some(MacMode::W8), MacUnitConfig::packing_only(), &acts, &w, &bias)
            .unwrap();
        let third = Arc::clone(cache().lock().unwrap().get(&key).unwrap());
        assert!(Arc::ptr_eq(&first, &third), "mac ablations must share the image");
    }

    #[test]
    fn shape_mismatch_is_an_error_not_a_panic() {
        let spec = small_spec();
        let r = run_dense(spec, None, &[0i8; 3], &[0i8; 3], &[0i32; 3]);
        assert!(r.is_err());
    }
}
