//! Minimal in-tree error type with an `anyhow`-compatible surface.
//!
//! The build environment is offline, so the crate carries no external
//! dependencies; this module provides the small subset of `anyhow` the
//! codebase actually uses: an opaque [`Error`] holding a cause chain,
//! the [`Context`] extension trait for `Result`/`Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros (exported at the crate root,
//! as `#[macro_export]` requires).

use std::fmt;

/// Opaque error: an outermost message plus a flattened cause chain.
///
/// Like `anyhow::Error`, this type deliberately does *not* implement
/// `std::error::Error`, which is what allows the blanket
/// `From<E: std::error::Error>` conversion to coexist with the reflexive
/// `From<Error>` the `?` operator needs.
pub struct Error {
    /// `chain[0]` is the outermost context; later entries are causes.
    chain: Vec<String>,
}

impl Error {
    /// Error from a plain message.
    pub fn msg(m: impl Into<String>) -> Self {
        Error { chain: vec![m.into()] }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The outermost message.
    pub fn to_msg(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Crate-wide result alias (the error side is always [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow`-style context extension for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a context message to the error side.
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    /// Attach a lazily-built context message to the error side.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c.to_string()))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string (crate-root export).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::error::Error::msg(::std::format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::error::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::error::Error::msg(::std::string::ToString::to_string(&$err))
    };
}

/// Return early with an [`Error`] (crate-root export).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Bail unless a condition holds (crate-root export).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn from_std_error_and_context() {
        let r: Result<()> = Err(io_err().into());
        let r = r.context("opening artifact");
        let e = r.unwrap_err();
        assert_eq!(e.to_msg(), "opening artifact");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("missing"), "{dbg}");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("no value {}", 7)).unwrap_err();
        assert_eq!(e.to_msg(), "no value 7");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn macros_build_messages() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(f(3).unwrap_err().to_msg(), "three is right out");
        assert_eq!(f(11).unwrap_err().to_msg(), "x too big: 11");
        assert_eq!(anyhow!("n = {}", 4).to_msg(), "n = 4");
    }
}
