//! Pre-decoded micro-op execution engine — the fast path of the ISS.
//!
//! [`CompiledProgram::translate`] lowers a decoded [`Instr`] stream
//! *once* into a flat micro-op stream:
//!
//! * branch/jump targets are resolved to **stream indices** at
//!   translation time (no byte-pc arithmetic per executed branch),
//! * per-op cycle costs are pre-computed from the [`Timing`] table
//!   (the reference interpreter re-reads the table every step),
//! * the instruction sequences the kernel generators actually emit are
//!   **fused into superinstructions**: the packed-kernel inner-loop
//!   strip (k× activation-word `lw` + weight `lw` + `nn_mac`), the
//!   scalar baseline MAC (`lb`,`lb`,`mul`,`add`) and the pointer-bump
//!   loop latch (up to 3× `addi` + conditional branch).
//!
//! [`run`] dispatches the stream against a [`Core`]'s architectural
//! state and is **observationally identical** to [`Core::run`]: same
//! final registers, memory, perf counters, cycle totals, pc and exit
//! reason (property-tested in `tests/engine_equivalence.rs`). Programs
//! the translator cannot prove clean (static control flow with
//! non-multiple-of-4 offsets) and dynamic `jalr` entries into the
//! interior of a fused strip fall back to the reference interpreter.
//!
//! The only intentional divergence: the cycle *budget* is checked
//! between micro-ops, so a fused strip is atomic with respect to
//! `max_cycles` and a `MaxCycles` exit may be detected up to
//! strip-length − 1 instructions later than the reference interpreter.
//! Measurement paths run with an effectively unlimited budget, where
//! the two are indistinguishable.

use super::{alu_eval, Core, ExitReason, Timing};
use crate::isa::*;

/// Pre-resolved control-flow target.
#[derive(Debug, Clone, Copy)]
enum Tgt {
    /// Target micro-op index.
    Op(u32),
    /// Target pc outside the program image (raises `IllegalPc`).
    Illegal(u32),
}

/// One micro-op. Cycle costs (`c`, `ct`, `cnt`, …) are baked in at
/// translation time from the core's [`Timing`] table.
#[derive(Debug, Clone, Copy)]
enum MicroOp {
    /// `lui` / `auipc` (pc-relative value pre-computed).
    LoadImm { rd: Reg, val: u32, c: u32 },
    Jal { rd: Reg, link: u32, tgt: Tgt, c: u32 },
    Jalr { rd: Reg, rs1: Reg, offset: u32, link: u32, c: u32 },
    Branch { op: BranchOp, rs1: Reg, rs2: Reg, tgt: Tgt, ct: u32, cnt: u32 },
    Load { op: LoadOp, rd: Reg, rs1: Reg, offset: u32, c: u32 },
    Store { op: StoreOp, rs1: Reg, rs2: Reg, offset: u32, c: u32 },
    OpImm { op: AluOp, rd: Reg, rs1: Reg, imm: u32, c: u32 },
    Op { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg, c: u32 },
    MulDiv { op: MulOp, rd: Reg, rs1: Reg, rs2: Reg, c: u32 },
    NnMac { mode: MacMode, rd: Reg, rs1: Reg, rs2: Reg },
    Csr { rd: Reg, csr: u16, c: u32 },
    Fence { c: u32 },
    Ecall,
    Ebreak,
    /// Fell off the end of the program (or a resolved jump landed one
    /// past it): `IllegalPc` at this op's pc.
    Trap,
    /// Fused packed-kernel strip: `k`× `lw act_rd+j, act_off+4j(act_base)`,
    /// then `lw w_rd, w_off(w_base)`, then `nn_mac mode acc, act_rd, w_rd`.
    LoadMac {
        mode: MacMode,
        acc: Reg,
        act_rd: Reg,
        act_base: Reg,
        act_off: u32,
        w_rd: Reg,
        w_base: Reg,
        w_off: u32,
        k: u8,
        c_load: u32,
    },
    /// Fused scalar baseline MAC: `lb ra`, `lb rb`, `mul rm, ra, rb`,
    /// `add acc, acc, rm`.
    ScalarMac {
        ra: Reg,
        a_base: Reg,
        a_off: u32,
        rb: Reg,
        b_base: Reg,
        b_off: u32,
        rm: Reg,
        acc: Reg,
        c_load: u32,
        c_tail: u32,
    },
    /// Fused loop latch: `n`× `addi r, r, imm` then a conditional branch.
    Latch {
        bumps: [(Reg, u32); 3],
        n: u8,
        bop: BranchOp,
        rs1: Reg,
        rs2: Reg,
        tgt: Tgt,
        c_bumps: u32,
        ct: u32,
        cnt: u32,
    },
}

/// A program translated for the micro-op engine. Tied to the decoded
/// instruction stream, its link base and a [`Timing`] table — *not* to
/// any particular core, so one translation serves any number of runs
/// (see [`super::session::SimSession`]).
#[derive(Debug, Clone)]
pub struct CompiledProgram {
    ops: Vec<MicroOp>,
    /// Byte pc of the first instruction of each op (parallel to `ops`).
    op_pc: Vec<u32>,
    /// Instruction index → op index; `u32::MAX` marks the interior of a
    /// fused strip. Has `n_instrs + 1` entries — the last maps the
    /// one-past-the-end pc to the trailing [`MicroOp::Trap`].
    instr_to_op: Vec<u32>,
    base: u32,
    n_instrs: usize,
    /// False when static control flow defeats pc pre-resolution
    /// (non-multiple-of-4 offsets); [`run`] then uses [`Core::run`].
    clean: bool,
    /// Instructions absorbed into fused superinstructions.
    fused_instrs: usize,
}

impl CompiledProgram {
    /// Translate a decoded program linked at `base` under `timing`.
    pub fn translate(program: &[Instr], base: u32, timing: Timing) -> CompiledProgram {
        let n = program.len();
        let t = &timing;

        // Pass 1: collect static branch/jump targets; any misaligned
        // offset makes pc pre-resolution unsound for the whole program.
        let mut is_target = vec![false; n];
        let mut clean = true;
        for (i, ins) in program.iter().enumerate() {
            let off = match *ins {
                Instr::Jal { offset, .. } | Instr::Branch { offset, .. } => Some(offset),
                _ => None,
            };
            if let Some(off) = off {
                if off % 4 != 0 {
                    clean = false;
                    break;
                }
                let pc = base.wrapping_add(4 * i as u32);
                let ti = pc.wrapping_add(off as u32).wrapping_sub(base) / 4;
                if (ti as usize) < n {
                    is_target[ti as usize] = true;
                }
            }
        }
        if !clean {
            return CompiledProgram {
                ops: Vec::new(),
                op_pc: Vec::new(),
                instr_to_op: Vec::new(),
                base,
                n_instrs: n,
                clean: false,
                fused_instrs: 0,
            };
        }

        // Pass 2: fuse + lower. Control-flow targets are stored as
        // *instruction* indices (`Tgt::Op`) and rewritten to op indices
        // in pass 3, once `instr_to_op` is complete.
        let mk_tgt = |branch_instr: usize, off: i32| -> Tgt {
            let pc = base.wrapping_add(4 * branch_instr as u32);
            let tpc = pc.wrapping_add(off as u32);
            let ti = tpc.wrapping_sub(base) / 4;
            if (ti as usize) <= n {
                Tgt::Op(ti)
            } else {
                Tgt::Illegal(tpc)
            }
        };

        let mut ops = Vec::with_capacity(n + 1);
        let mut op_pc = Vec::with_capacity(n + 1);
        let mut instr_to_op = vec![u32::MAX; n + 1];
        let mut fused_instrs = 0usize;
        let mut i = 0usize;
        while i < n {
            instr_to_op[i] = ops.len() as u32;
            op_pc.push(base.wrapping_add(4 * i as u32));
            if let Some((op, len)) = try_fuse(program, i, &is_target, t, &mk_tgt) {
                ops.push(op);
                fused_instrs += len;
                i += len;
            } else {
                ops.push(lower_one(program[i], base.wrapping_add(4 * i as u32), i, t, &mk_tgt));
                i += 1;
            }
        }
        instr_to_op[n] = ops.len() as u32;
        op_pc.push(base.wrapping_add(4 * n as u32));
        ops.push(MicroOp::Trap);

        // Pass 3: instruction-index targets → op indices. Every static
        // target was marked in pass 1, so fusion never swallowed it and
        // the map entry is real.
        for op in &mut ops {
            match op {
                MicroOp::Jal { tgt, .. }
                | MicroOp::Branch { tgt, .. }
                | MicroOp::Latch { tgt, .. } => {
                    if let Tgt::Op(ii) = *tgt {
                        let oi = instr_to_op[ii as usize];
                        debug_assert_ne!(oi, u32::MAX, "static target inside a fused strip");
                        *tgt = Tgt::Op(oi);
                    }
                }
                _ => {}
            }
        }

        CompiledProgram { ops, op_pc, instr_to_op, base, n_instrs: n, clean: true, fused_instrs }
    }

    /// Micro-ops in the stream (excluding the trailing trap).
    pub fn op_count(&self) -> usize {
        self.ops.len().saturating_sub(1)
    }

    /// Instructions in the source program.
    pub fn instr_count(&self) -> usize {
        self.n_instrs
    }

    /// Instructions absorbed into fused superinstructions.
    pub fn fused_instr_count(&self) -> usize {
        self.fused_instrs
    }

    /// False when [`run`] will delegate to the reference interpreter.
    pub fn is_clean(&self) -> bool {
        self.clean
    }
}

/// Lower one instruction to a micro-op.
fn lower_one(
    ins: Instr,
    pc: u32,
    instr_idx: usize,
    t: &Timing,
    mk_tgt: &impl Fn(usize, i32) -> Tgt,
) -> MicroOp {
    match ins {
        Instr::Lui { rd, imm } => MicroOp::LoadImm { rd, val: imm as u32, c: t.alu },
        Instr::Auipc { rd, imm } => {
            MicroOp::LoadImm { rd, val: pc.wrapping_add(imm as u32), c: t.alu }
        }
        Instr::Jal { rd, offset } => MicroOp::Jal {
            rd,
            link: pc.wrapping_add(4),
            tgt: mk_tgt(instr_idx, offset),
            c: t.jump,
        },
        Instr::Jalr { rd, rs1, offset } => MicroOp::Jalr {
            rd,
            rs1,
            offset: offset as u32,
            link: pc.wrapping_add(4),
            c: t.jump,
        },
        Instr::Branch { op, rs1, rs2, offset } => MicroOp::Branch {
            op,
            rs1,
            rs2,
            tgt: mk_tgt(instr_idx, offset),
            ct: t.branch_taken,
            cnt: t.branch_not_taken,
        },
        Instr::Load { op, rd, rs1, offset } => {
            MicroOp::Load { op, rd, rs1, offset: offset as u32, c: t.load }
        }
        Instr::Store { op, rs1, rs2, offset } => {
            MicroOp::Store { op, rs1, rs2, offset: offset as u32, c: t.store }
        }
        Instr::OpImm { op, rd, rs1, imm } => {
            MicroOp::OpImm { op, rd, rs1, imm: imm as u32, c: t.alu }
        }
        Instr::Op { op, rd, rs1, rs2 } => MicroOp::Op { op, rd, rs1, rs2, c: t.alu },
        Instr::MulDiv { op, rd, rs1, rs2 } => {
            let c = match op {
                MulOp::Mul => t.mul,
                MulOp::Mulh | MulOp::Mulhsu | MulOp::Mulhu => t.mulh,
                MulOp::Div | MulOp::Divu | MulOp::Rem | MulOp::Remu => t.div,
            };
            MicroOp::MulDiv { op, rd, rs1, rs2, c }
        }
        Instr::NnMac { mode, rd, rs1, rs2 } => MicroOp::NnMac { mode, rd, rs1, rs2 },
        Instr::Csr { op: _, rd, rs1: _, csr } => MicroOp::Csr { rd, csr, c: t.csr },
        Instr::Fence => MicroOp::Fence { c: t.fence },
        Instr::Ecall => MicroOp::Ecall,
        Instr::Ebreak => MicroOp::Ebreak,
    }
}

/// Try to fuse a superinstruction starting at instruction `i`. The
/// fused executor replays the exact sequential semantics, so the only
/// hard requirements are the literal opcode pattern and that no static
/// branch target points into the strip's interior.
fn try_fuse(
    p: &[Instr],
    i: usize,
    is_target: &[bool],
    t: &Timing,
    mk_tgt: &impl Fn(usize, i32) -> Tgt,
) -> Option<(MicroOp, usize)> {
    match p[i] {
        Instr::Load { op: LoadOp::Lw, .. } => try_load_mac(p, i, is_target, t),
        Instr::Load { op: LoadOp::Lb, .. } => try_scalar_mac(p, i, is_target, t),
        Instr::OpImm { op: AluOp::Add, .. } => try_latch(p, i, is_target, t, mk_tgt),
        _ => None,
    }
}

/// k× `lw` of consecutive activation words + weight `lw` + `nn_mac`.
fn try_load_mac(
    p: &[Instr],
    i: usize,
    is_target: &[bool],
    t: &Timing,
) -> Option<(MicroOp, usize)> {
    let Instr::Load { op: LoadOp::Lw, rd: rd0, rs1: ab, offset: ao } = p[i] else {
        return None;
    };
    if rd0 == 0 {
        return None;
    }
    for k in [1usize, 2, 4] {
        if i + k + 1 >= p.len() {
            continue;
        }
        let Instr::NnMac { mode, rd: acc, rs1, rs2 } = p[i + k + 1] else { continue };
        if mode.activation_regs() as usize != k || rs1 != rd0 {
            continue;
        }
        if rd0 as usize + k > NUM_REGS {
            continue;
        }
        // The activation-word run: rd0+j ← (ao + 4j)(ab).
        let mut run_ok = true;
        for j in 1..k {
            match p[i + j] {
                Instr::Load { op: LoadOp::Lw, rd, rs1: b, offset }
                    if rd == rd0 + j as u8 && b == ab && offset == ao + 4 * j as i32 => {}
                _ => {
                    run_ok = false;
                    break;
                }
            }
        }
        if !run_ok {
            continue;
        }
        let Instr::Load { op: LoadOp::Lw, rd: w_rd, rs1: w_base, offset: w_off } = p[i + k]
        else {
            continue;
        };
        if w_rd == 0 || rs2 != w_rd {
            continue;
        }
        // The fused executor reads the activation base once, so it must
        // not be overwritten by the act-word loads themselves.
        if (rd0..rd0 + k as u8).contains(&ab) {
            continue;
        }
        if is_target[i + 1..=i + k + 1].iter().any(|&b| b) {
            continue;
        }
        return Some((
            MicroOp::LoadMac {
                mode,
                acc,
                act_rd: rd0,
                act_base: ab,
                act_off: ao as u32,
                w_rd,
                w_base,
                w_off: w_off as u32,
                k: k as u8,
                c_load: t.load,
            },
            k + 2,
        ));
    }
    None
}

/// `lb ra`, `lb rb`, `mul rm, ra, rb`, `add acc, acc, rm`.
fn try_scalar_mac(
    p: &[Instr],
    i: usize,
    is_target: &[bool],
    t: &Timing,
) -> Option<(MicroOp, usize)> {
    if i + 3 >= p.len() {
        return None;
    }
    let Instr::Load { op: LoadOp::Lb, rd: ra, rs1: a_base, offset: a_off } = p[i] else {
        return None;
    };
    let Instr::Load { op: LoadOp::Lb, rd: rb, rs1: b_base, offset: b_off } = p[i + 1] else {
        return None;
    };
    let Instr::MulDiv { op: MulOp::Mul, rd: rm, rs1, rs2 } = p[i + 2] else {
        return None;
    };
    if rs1 != ra || rs2 != rb {
        return None;
    }
    let Instr::Op { op: AluOp::Add, rd: acc, rs1: ar1, rs2: ar2 } = p[i + 3] else {
        return None;
    };
    if ar1 != acc || ar2 != rm {
        return None;
    }
    if is_target[i + 1..=i + 3].iter().any(|&b| b) {
        return None;
    }
    Some((
        MicroOp::ScalarMac {
            ra,
            a_base,
            a_off: a_off as u32,
            rb,
            b_base,
            b_off: b_off as u32,
            rm,
            acc,
            c_load: t.load,
            c_tail: t.mul + t.alu,
        },
        4,
    ))
}

/// Up to 3× `addi r, r, imm` followed by a conditional branch.
fn try_latch(
    p: &[Instr],
    i: usize,
    is_target: &[bool],
    t: &Timing,
    mk_tgt: &impl Fn(usize, i32) -> Tgt,
) -> Option<(MicroOp, usize)> {
    let mut bumps = [(0u8, 0u32); 3];
    let mut nb = 0usize;
    while nb < 3 && i + nb < p.len() {
        match p[i + nb] {
            Instr::OpImm { op: AluOp::Add, rd, rs1, imm } if rd == rs1 => {
                bumps[nb] = (rd, imm as u32);
                nb += 1;
            }
            _ => break,
        }
    }
    if nb == 0 || i + nb >= p.len() {
        return None;
    }
    let Instr::Branch { op, rs1, rs2, offset } = p[i + nb] else {
        return None;
    };
    if is_target[i + 1..=i + nb].iter().any(|&b| b) {
        return None;
    }
    Some((
        MicroOp::Latch {
            bumps,
            n: nb as u8,
            bop: op,
            rs1,
            rs2,
            tgt: mk_tgt(i + nb, offset),
            c_bumps: nb as u32 * t.alu,
            ct: t.branch_taken,
            cnt: t.branch_not_taken,
        },
        nb + 1,
    ))
}

#[inline]
fn branch_taken(op: BranchOp, a: u32, b: u32) -> bool {
    match op {
        BranchOp::Beq => a == b,
        BranchOp::Bne => a != b,
        BranchOp::Blt => (a as i32) < (b as i32),
        BranchOp::Bge => (a as i32) >= (b as i32),
        BranchOp::Bltu => a < b,
        BranchOp::Bgeu => a >= b,
    }
}

/// Per-op control-flow outcome of the dispatch loop.
enum Flow {
    Seq,
    Goto(Tgt),
}

/// Run `core` on the micro-op engine until halt or `max_cycles`.
///
/// Equivalent to [`Core::run`] (see the module docs for the cycle
/// budget caveat). Falls back to the reference interpreter when the
/// translation is not clean, when the entry pc is not a translated
/// op boundary, or when a `jalr` lands inside a fused strip.
pub fn run(core: &mut Core, cp: &CompiledProgram, max_cycles: u64) -> ExitReason {
    if !cp.clean || core.prog_base != cp.base || core.program.len() != cp.n_instrs {
        return core.run(max_cycles);
    }
    // Entry: map the current pc onto the op stream.
    let rel = core.pc.wrapping_sub(cp.base);
    if rel % 4 != 0 {
        return core.run(max_cycles);
    }
    let ii = (rel / 4) as usize;
    if ii > cp.n_instrs {
        return ExitReason::IllegalPc(core.pc);
    }
    let entry = cp.instr_to_op[ii];
    if entry == u32::MAX {
        return core.run(max_cycles);
    }
    let mut idx = entry as usize;

    loop {
        let flow = match cp.ops[idx] {
            MicroOp::LoadImm { rd, val, c } => {
                core.write_reg(rd, val);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::Jal { rd, link, tgt, c } => {
                core.write_reg(rd, link);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Goto(tgt)
            }
            MicroOp::Jalr { rd, rs1, offset, link, c } => {
                let target = core.regs[rs1 as usize].wrapping_add(offset) & !1;
                core.write_reg(rd, link);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                let rel = target.wrapping_sub(cp.base);
                if rel % 4 != 0 {
                    core.pc = target;
                    if core.perf.cycles >= max_cycles {
                        return ExitReason::MaxCycles;
                    }
                    return core.run(max_cycles);
                }
                let ti = (rel / 4) as usize;
                if ti > cp.n_instrs {
                    core.pc = target;
                    if core.perf.cycles >= max_cycles {
                        return ExitReason::MaxCycles;
                    }
                    return ExitReason::IllegalPc(target);
                }
                let oi = cp.instr_to_op[ti];
                if oi == u32::MAX {
                    // Dynamic entry into a fused strip: replay on the
                    // reference interpreter from here.
                    core.pc = target;
                    if core.perf.cycles >= max_cycles {
                        return ExitReason::MaxCycles;
                    }
                    return core.run(max_cycles);
                }
                Flow::Goto(Tgt::Op(oi))
            }
            MicroOp::Branch { op, rs1, rs2, tgt, ct, cnt } => {
                let a = core.regs[rs1 as usize];
                let b = core.regs[rs2 as usize];
                core.perf.instret += 1;
                if branch_taken(op, a, b) {
                    core.perf.taken_branches += 1;
                    core.perf.cycles += ct as u64;
                    Flow::Goto(tgt)
                } else {
                    core.perf.cycles += cnt as u64;
                    Flow::Seq
                }
            }
            MicroOp::Load { op, rd, rs1, offset, c } => {
                let addr = core.regs[rs1 as usize].wrapping_add(offset);
                let (width, sign) = match op {
                    LoadOp::Lb => (1, true),
                    LoadOp::Lh => (2, true),
                    LoadOp::Lw => (4, false),
                    LoadOp::Lbu => (1, false),
                    LoadOp::Lhu => (2, false),
                };
                match core.mem.load(addr, width) {
                    Ok(raw) => {
                        let val = if sign {
                            match width {
                                1 => raw as u8 as i8 as i32 as u32,
                                2 => raw as u16 as i16 as i32 as u32,
                                _ => raw,
                            }
                        } else {
                            raw
                        };
                        core.write_reg(rd, val);
                        core.perf.loads += 1;
                        core.perf.cycles += c as u64;
                        core.perf.instret += 1;
                        Flow::Seq
                    }
                    Err(f) => {
                        core.pc = cp.op_pc[idx];
                        return ExitReason::Fault(f);
                    }
                }
            }
            MicroOp::Store { op, rs1, rs2, offset, c } => {
                let addr = core.regs[rs1 as usize].wrapping_add(offset);
                let width = match op {
                    StoreOp::Sb => 1,
                    StoreOp::Sh => 2,
                    StoreOp::Sw => 4,
                };
                match core.mem.store(addr, width, core.regs[rs2 as usize]) {
                    Ok(()) => {
                        core.perf.stores += 1;
                        core.perf.cycles += c as u64;
                        core.perf.instret += 1;
                        Flow::Seq
                    }
                    Err(f) => {
                        core.pc = cp.op_pc[idx];
                        return ExitReason::Fault(f);
                    }
                }
            }
            MicroOp::OpImm { op, rd, rs1, imm, c } => {
                let v = alu_eval(op, core.regs[rs1 as usize], imm);
                core.write_reg(rd, v);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::Op { op, rd, rs1, rs2, c } => {
                let v = alu_eval(op, core.regs[rs1 as usize], core.regs[rs2 as usize]);
                core.write_reg(rd, v);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::MulDiv { op, rd, rs1, rs2, c } => {
                let a = core.regs[rs1 as usize];
                let b = core.regs[rs2 as usize];
                let val = match op {
                    MulOp::Mul => a.wrapping_mul(b),
                    MulOp::Mulh => (((a as i32 as i64) * (b as i32 as i64)) >> 32) as u32,
                    MulOp::Mulhsu => (((a as i32 as i64) * (b as i64)) >> 32) as u32,
                    MulOp::Mulhu => (((a as u64) * (b as u64)) >> 32) as u32,
                    MulOp::Div => {
                        let (a, b) = (a as i32, b as i32);
                        let q = if b == 0 {
                            -1
                        } else if a == i32::MIN && b == -1 {
                            i32::MIN
                        } else {
                            a.wrapping_div(b)
                        };
                        q as u32
                    }
                    MulOp::Divu => {
                        if b == 0 {
                            u32::MAX
                        } else {
                            a / b
                        }
                    }
                    MulOp::Rem => {
                        let (a, b) = (a as i32, b as i32);
                        let r = if b == 0 {
                            a
                        } else if a == i32::MIN && b == -1 {
                            0
                        } else {
                            a.wrapping_rem(b)
                        };
                        r as u32
                    }
                    MulOp::Remu => {
                        if b == 0 {
                            a
                        } else {
                            a % b
                        }
                    }
                };
                core.write_reg(rd, val);
                core.perf.muldiv_instrs += 1;
                if op == MulOp::Mul {
                    core.perf.macs += 1;
                    core.mac_unit.total_macs += 1;
                }
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::NnMac { mode, rd, rs1, rs2 } => {
                let k = mode.activation_regs() as usize;
                let mut acts = [0u32; 4];
                for (j, slot) in acts.iter_mut().enumerate().take(k) {
                    *slot = core.regs[rs1 as usize + j];
                }
                let issue = core.mac_unit.issue(
                    mode,
                    core.regs[rd as usize],
                    &acts[..k],
                    core.regs[rs2 as usize],
                );
                core.write_reg(rd, issue.acc);
                core.perf.macs += issue.macs as u64;
                core.perf.nn_mac_instrs += 1;
                core.perf.cycles += issue.cycles as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::Csr { rd, csr, c } => {
                let val = core.perf.read_csr(csr);
                core.write_reg(rd, val);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::Fence { c } => {
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::Ecall => {
                core.perf.cycles += 1;
                core.perf.instret += 1;
                core.pc = cp.op_pc[idx];
                return ExitReason::Ecall;
            }
            MicroOp::Ebreak => {
                core.perf.cycles += 1;
                core.perf.instret += 1;
                core.pc = cp.op_pc[idx];
                return ExitReason::Ebreak;
            }
            MicroOp::Trap => {
                core.pc = cp.op_pc[idx];
                return ExitReason::IllegalPc(cp.op_pc[idx]);
            }
            MicroOp::LoadMac {
                mode,
                acc,
                act_rd,
                act_base,
                act_off,
                w_rd,
                w_base,
                w_off,
                k,
                c_load,
            } => {
                let k = k as usize;
                let base_addr = core.regs[act_base as usize].wrapping_add(act_off);
                let mut buf = [0u32; 4];
                match core.mem.load_word_run(base_addr, &mut buf[..k]) {
                    Ok(()) => {}
                    Err((j, f)) => {
                        // Partial strip: the first j loads completed
                        // exactly as they would have individually.
                        for (jj, &w) in buf.iter().enumerate().take(j) {
                            core.regs[act_rd as usize + jj] = w;
                        }
                        core.perf.loads += j as u64;
                        core.perf.cycles += j as u64 * c_load as u64;
                        core.perf.instret += j as u64;
                        core.pc = cp.op_pc[idx].wrapping_add(4 * j as u32);
                        return ExitReason::Fault(f);
                    }
                }
                for (j, &w) in buf.iter().enumerate().take(k) {
                    core.regs[act_rd as usize + j] = w;
                }
                let w_addr = core.regs[w_base as usize].wrapping_add(w_off);
                let w_word = match core.mem.load(w_addr, 4) {
                    Ok(w) => w,
                    Err(f) => {
                        core.perf.loads += k as u64;
                        core.perf.cycles += k as u64 * c_load as u64;
                        core.perf.instret += k as u64;
                        core.pc = cp.op_pc[idx].wrapping_add(4 * k as u32);
                        return ExitReason::Fault(f);
                    }
                };
                core.regs[w_rd as usize] = w_word;
                let issue = core.mac_unit.issue(
                    mode,
                    core.regs[acc as usize],
                    &core.regs[act_rd as usize..act_rd as usize + k],
                    w_word,
                );
                core.write_reg(acc, issue.acc);
                core.perf.loads += (k + 1) as u64;
                core.perf.macs += issue.macs as u64;
                core.perf.nn_mac_instrs += 1;
                core.perf.cycles += (k + 1) as u64 * c_load as u64 + issue.cycles as u64;
                core.perf.instret += (k + 2) as u64;
                Flow::Seq
            }
            MicroOp::ScalarMac {
                ra, a_base, a_off, rb, b_base, b_off, rm, acc, c_load, c_tail,
            } => {
                let addr_a = core.regs[a_base as usize].wrapping_add(a_off);
                let va = match core.mem.load(addr_a, 1) {
                    Ok(raw) => raw as u8 as i8 as i32 as u32,
                    Err(f) => {
                        core.pc = cp.op_pc[idx];
                        return ExitReason::Fault(f);
                    }
                };
                core.write_reg(ra, va);
                let addr_b = core.regs[b_base as usize].wrapping_add(b_off);
                let vb = match core.mem.load(addr_b, 1) {
                    Ok(raw) => raw as u8 as i8 as i32 as u32,
                    Err(f) => {
                        core.perf.loads += 1;
                        core.perf.cycles += c_load as u64;
                        core.perf.instret += 1;
                        core.pc = cp.op_pc[idx].wrapping_add(4);
                        return ExitReason::Fault(f);
                    }
                };
                core.write_reg(rb, vb);
                let prod = core.regs[ra as usize].wrapping_mul(core.regs[rb as usize]);
                core.write_reg(rm, prod);
                let sum = core.regs[acc as usize].wrapping_add(core.regs[rm as usize]);
                core.write_reg(acc, sum);
                core.perf.loads += 2;
                core.perf.muldiv_instrs += 1;
                core.perf.macs += 1;
                core.mac_unit.total_macs += 1;
                core.perf.cycles += 2 * c_load as u64 + c_tail as u64;
                core.perf.instret += 4;
                Flow::Seq
            }
            MicroOp::Latch { bumps, n, bop, rs1, rs2, tgt, c_bumps, ct, cnt } => {
                for &(r, imm) in bumps.iter().take(n as usize) {
                    let v = core.regs[r as usize].wrapping_add(imm);
                    core.write_reg(r, v);
                }
                let a = core.regs[rs1 as usize];
                let b = core.regs[rs2 as usize];
                core.perf.instret += n as u64 + 1;
                if branch_taken(bop, a, b) {
                    core.perf.taken_branches += 1;
                    core.perf.cycles += (c_bumps + ct) as u64;
                    Flow::Goto(tgt)
                } else {
                    core.perf.cycles += (c_bumps + cnt) as u64;
                    Flow::Seq
                }
            }
        };

        match flow {
            Flow::Seq => idx += 1,
            Flow::Goto(Tgt::Op(i)) => idx = i as usize,
            Flow::Goto(Tgt::Illegal(pc)) => {
                core.pc = pc;
                if core.perf.cycles >= max_cycles {
                    return ExitReason::MaxCycles;
                }
                return ExitReason::IllegalPc(pc);
            }
        }
        if core.perf.cycles >= max_cycles {
            core.pc = cp.op_pc[idx];
            return ExitReason::MaxCycles;
        }
    }
}
