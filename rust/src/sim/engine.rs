//! Pre-decoded micro-op execution engine — the fast path of the ISS.
//!
//! [`CompiledProgram::translate`] lowers a decoded [`Instr`] stream
//! *once* into a flat micro-op stream:
//!
//! * branch/jump targets are resolved to **stream indices** at
//!   translation time (no byte-pc arithmetic per executed branch),
//! * per-op cycle costs are pre-computed from the [`Timing`] table
//!   (the reference interpreter re-reads the table every step),
//! * the instruction sequences the kernel generators actually emit are
//!   **fused into superinstructions**: the packed-kernel inner-loop
//!   strip (k× activation-word `lw` + weight `lw` + `nn_mac`), the
//!   scalar baseline MAC (`lb`,`lb`,`mul`,`add`), the pointer-bump
//!   loop latch (up to 3× `addi` + conditional branch) and the whole
//!   requant epilogue (`mulh`/`mul` SRDHM chain + rounding shift +
//!   branchless clamp + `mv`, plus the trailing `sb` of the quantized
//!   output where present — the exact canonical form
//!   `kernels::requant::emit_requantize` emits, with the shift amount
//!   and cycle cost pre-resolved at translation time),
//! * a backward-branching latch whose body is a **single fused strip**
//!   becomes a *counted loop*: the entire reduction loop runs inside
//!   one native Rust loop with no per-iteration micro-op dispatch.
//!   When the latch's compare/stride registers are provably not
//!   written by the strip body, the trip count is predicted once from
//!   the register state at loop entry; otherwise (clobbered loop
//!   registers) a guard falls back to re-evaluating the branch every
//!   iteration — both paths replay exact sequential semantics.
//!
//! The full pattern → micro-op → cycle-accounting catalog is tabulated
//! in `docs/ARCHITECTURE.md` (§ Superinstruction catalog).
//!
//! [`run`] dispatches the stream against a [`Core`]'s architectural
//! state and is **observationally identical** to [`Core::run`]: same
//! final registers, memory, perf counters, cycle totals, pc and exit
//! reason (property-tested in `tests/engine_equivalence.rs`). Programs
//! the translator cannot prove clean (static control flow with
//! non-multiple-of-4 offsets) and dynamic `jalr` entries into the
//! interior of a fused strip fall back to the reference interpreter;
//! per-class superinstruction hit counters (and the fallback count)
//! are kept in [`EngineStats`] on the core.
//!
//! The only intentional divergence: the cycle *budget* is checked per
//! fused strip — after every micro-op **and after every iteration of a
//! counted loop** (both between the latch and the strip and between
//! the strip and the latch, exactly where op-at-a-time dispatch would
//! check) — so a fused strip is atomic with respect to `max_cycles`
//! and a `MaxCycles` exit may be detected at most one strip later than
//! the reference interpreter (the longest strip is the ~25-instruction
//! requant epilogue), never a whole loop later. Measurement paths run
//! with an effectively unlimited budget, where the two are
//! indistinguishable.

use super::{alu_eval, Core, ExitReason, Timing};
use crate::isa::*;

/// Translation feature toggles. The default enables every fusion; the
/// throughput bench translates the same kernel under [`TranslateOpts::v1`]
/// to report the per-PR engine trajectory (new vs. previous generation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TranslateOpts {
    /// Fuse the requant epilogue into a single `Requant` micro-op.
    pub fuse_requant: bool,
    /// Run strip-bodied backward latches as native counted loops.
    pub counted_loops: bool,
}

impl Default for TranslateOpts {
    fn default() -> Self {
        TranslateOpts { fuse_requant: true, counted_loops: true }
    }
}

impl TranslateOpts {
    /// The first-generation engine feature set (PR 1): strip/MAC/latch
    /// fusion only, no requant epilogue, no counted loops.
    pub fn v1() -> Self {
        TranslateOpts { fuse_requant: false, counted_loops: false }
    }
}

/// Per-run superinstruction hit counters plus the interpreter-fallback
/// count — the cheap stand-in for per-instruction trace hooks: they
/// show *which* fused paths a workload actually exercised without
/// slowing the engine down. Kept on [`Core`] (`Core::engine_stats`),
/// reset per core, and aggregated session-wide by
/// [`super::session::SessionStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EngineStats {
    /// Fused packed-kernel strips executed (`LoadMac`), including
    /// iterations inside counted loops.
    pub load_mac: u64,
    /// Fused scalar baseline MACs executed (`ScalarMac`), including
    /// iterations inside counted loops.
    pub scalar_mac: u64,
    /// Fused loop latches executed outside counted loops (a counted
    /// loop whose branch falls through on first evaluation counts here
    /// too — it behaved as a plain latch).
    pub latch: u64,
    /// Fused requant epilogues executed (`Requant`).
    pub requant: u64,
    /// Counted-loop entries (a taken latch whose body is one strip).
    pub counted_loops: u64,
    /// Strip iterations executed inside counted loops.
    pub counted_iters: u64,
    /// Runs delegated to the reference interpreter: unclean program,
    /// entry pc inside a fused strip, or a dynamic `jalr` into a strip
    /// interior.
    pub fallbacks: u64,
}

impl EngineStats {
    /// Elementwise accumulate (used by the session-wide totals).
    pub fn add(&mut self, o: &EngineStats) {
        self.load_mac += o.load_mac;
        self.scalar_mac += o.scalar_mac;
        self.latch += o.latch;
        self.requant += o.requant;
        self.counted_loops += o.counted_loops;
        self.counted_iters += o.counted_iters;
        self.fallbacks += o.fallbacks;
    }

    /// Elementwise difference against an `earlier` snapshot of the same
    /// monotone counters — how the shard-sweep runner attributes engine
    /// activity to one sweep on the shared global session (saturating,
    /// so a stale snapshot can never underflow).
    pub fn delta_since(&self, earlier: &EngineStats) -> EngineStats {
        EngineStats {
            load_mac: self.load_mac.saturating_sub(earlier.load_mac),
            scalar_mac: self.scalar_mac.saturating_sub(earlier.scalar_mac),
            latch: self.latch.saturating_sub(earlier.latch),
            requant: self.requant.saturating_sub(earlier.requant),
            counted_loops: self.counted_loops.saturating_sub(earlier.counted_loops),
            counted_iters: self.counted_iters.saturating_sub(earlier.counted_iters),
            fallbacks: self.fallbacks.saturating_sub(earlier.fallbacks),
        }
    }
}

/// Pre-resolved control-flow target.
#[derive(Debug, Clone, Copy)]
enum Tgt {
    /// Target micro-op index.
    Op(u32),
    /// Target pc outside the program image (raises `IllegalPc`).
    Illegal(u32),
}

/// One micro-op. Cycle costs (`c`, `ct`, `cnt`, …) are baked in at
/// translation time from the core's [`Timing`] table.
#[derive(Debug, Clone, Copy)]
enum MicroOp {
    /// `lui` / `auipc` (pc-relative value pre-computed).
    LoadImm { rd: Reg, val: u32, c: u32 },
    Jal { rd: Reg, link: u32, tgt: Tgt, c: u32 },
    Jalr { rd: Reg, rs1: Reg, offset: u32, link: u32, c: u32 },
    Branch { op: BranchOp, rs1: Reg, rs2: Reg, tgt: Tgt, ct: u32, cnt: u32 },
    Load { op: LoadOp, rd: Reg, rs1: Reg, offset: u32, c: u32 },
    Store { op: StoreOp, rs1: Reg, rs2: Reg, offset: u32, c: u32 },
    OpImm { op: AluOp, rd: Reg, rs1: Reg, imm: u32, c: u32 },
    Op { op: AluOp, rd: Reg, rs1: Reg, rs2: Reg, c: u32 },
    MulDiv { op: MulOp, rd: Reg, rs1: Reg, rs2: Reg, c: u32 },
    NnMac { mode: MacMode, rd: Reg, rs1: Reg, rs2: Reg },
    Csr { rd: Reg, csr: u16, c: u32 },
    Fence { c: u32 },
    Ecall,
    Ebreak,
    /// Fell off the end of the program (or a resolved jump landed one
    /// past it): `IllegalPc` at this op's pc.
    Trap,
    /// Fused packed-kernel strip: `k`× `lw act_rd+j, act_off+4j(act_base)`,
    /// then `lw w_rd, w_off(w_base)`, then `nn_mac mode acc, act_rd, w_rd`.
    LoadMac {
        mode: MacMode,
        acc: Reg,
        act_rd: Reg,
        act_base: Reg,
        act_off: u32,
        w_rd: Reg,
        w_base: Reg,
        w_off: u32,
        k: u8,
        c_load: u32,
    },
    /// Fused scalar baseline MAC: `lb ra`, `lb rb`, `mul rm, ra, rb`,
    /// `add acc, acc, rm`.
    ScalarMac {
        ra: Reg,
        a_base: Reg,
        a_off: u32,
        rb: Reg,
        b_base: Reg,
        b_off: u32,
        rm: Reg,
        acc: Reg,
        c_load: u32,
        c_tail: u32,
    },
    /// Fused loop latch: `n`× `addi r, r, imm` then a conditional branch.
    Latch {
        bumps: [(Reg, u32); 3],
        n: u8,
        bop: BranchOp,
        rs1: Reg,
        rs2: Reg,
        tgt: Tgt,
        c_bumps: u32,
        ct: u32,
        cnt: u32,
    },
    /// Fused requant epilogue — the exact canonical sequence
    /// `kernels::requant::emit_requantize` emits: 10-op SRDHM chain on
    /// (`acc`, `m`), optional rounding shift (`shift` > 0: `add` of the
    /// `rnd` register then `srai`; `shift` < 0: `slli`), 11-op
    /// branchless clamp to `[lo, 127]` through scratch regs
    /// `t0..t3`, `mv out, t0`, and optionally the trailing
    /// `sb out, off(base)` of the quantized byte (`store`).
    /// `n_pre` counts the fused instructions excluding the store;
    /// `c` is their pre-summed cycle cost.
    Requant {
        acc: Reg,
        m: Reg,
        rnd: Reg,
        lo: Reg,
        t0: Reg,
        t1: Reg,
        t2: Reg,
        t3: Reg,
        out: Reg,
        shift: i8,
        store: Option<(Reg, u32)>,
        n_pre: u8,
        c: u32,
        c_store: u32,
    },
    /// A latch whose taken target is the immediately preceding fused
    /// strip (`body` = this op's index − 1, always a `LoadMac` or
    /// `ScalarMac`): the whole reduction loop runs in one native loop.
    /// `counted` is `Some((counter_is_rs1, step))` when the strip body
    /// provably never writes the compare/bump registers, enabling
    /// trip-count prediction from the register state at loop entry;
    /// `None` falls back to re-evaluating the branch each iteration.
    CountedLoop {
        body: u32,
        bumps: [(Reg, u32); 3],
        n: u8,
        bop: BranchOp,
        rs1: Reg,
        rs2: Reg,
        c_bumps: u32,
        ct: u32,
        cnt: u32,
        counted: Option<(bool, u32)>,
    },
}

/// A program translated for the micro-op engine. Tied to the decoded
/// instruction stream, its link base and a [`Timing`] table — *not* to
/// any particular core, so one translation serves any number of runs
/// (see [`super::session::SimSession`]).
#[derive(Debug, Clone)]
pub struct CompiledProgram {
    ops: Vec<MicroOp>,
    /// Byte pc of the first instruction of each op (parallel to `ops`).
    op_pc: Vec<u32>,
    /// Instruction index → op index; `u32::MAX` marks the interior of a
    /// fused strip. Has `n_instrs + 1` entries — the last maps the
    /// one-past-the-end pc to the trailing [`MicroOp::Trap`].
    instr_to_op: Vec<u32>,
    base: u32,
    n_instrs: usize,
    /// False when static control flow defeats pc pre-resolution
    /// (non-multiple-of-4 offsets); [`run`] then uses [`Core::run`].
    clean: bool,
    /// Instructions absorbed into fused superinstructions.
    fused_instrs: usize,
}

impl CompiledProgram {
    /// Translate a decoded program linked at `base` under `timing`
    /// with every fusion enabled.
    pub fn translate(program: &[Instr], base: u32, timing: Timing) -> CompiledProgram {
        Self::translate_with(program, base, timing, TranslateOpts::default())
    }

    /// [`CompiledProgram::translate`] with explicit fusion toggles —
    /// the throughput bench compares fusion generations this way.
    pub fn translate_with(
        program: &[Instr],
        base: u32,
        timing: Timing,
        opts: TranslateOpts,
    ) -> CompiledProgram {
        let n = program.len();
        let t = &timing;

        // Pass 1: collect static branch/jump targets; any misaligned
        // offset makes pc pre-resolution unsound for the whole program.
        let mut is_target = vec![false; n];
        let mut clean = true;
        for (i, ins) in program.iter().enumerate() {
            let off = match *ins {
                Instr::Jal { offset, .. } | Instr::Branch { offset, .. } => Some(offset),
                _ => None,
            };
            if let Some(off) = off {
                if off % 4 != 0 {
                    clean = false;
                    break;
                }
                let pc = base.wrapping_add(4 * i as u32);
                let ti = pc.wrapping_add(off as u32).wrapping_sub(base) / 4;
                if (ti as usize) < n {
                    is_target[ti as usize] = true;
                }
            }
        }
        if !clean {
            return CompiledProgram {
                ops: Vec::new(),
                op_pc: Vec::new(),
                instr_to_op: Vec::new(),
                base,
                n_instrs: n,
                clean: false,
                fused_instrs: 0,
            };
        }

        // Pass 2: fuse + lower. Control-flow targets are stored as
        // *instruction* indices (`Tgt::Op`) and rewritten to op indices
        // in pass 3, once `instr_to_op` is complete.
        let mk_tgt = |branch_instr: usize, off: i32| -> Tgt {
            let pc = base.wrapping_add(4 * branch_instr as u32);
            let tpc = pc.wrapping_add(off as u32);
            let ti = tpc.wrapping_sub(base) / 4;
            if (ti as usize) <= n {
                Tgt::Op(ti)
            } else {
                Tgt::Illegal(tpc)
            }
        };

        let mut ops = Vec::with_capacity(n + 1);
        let mut op_pc = Vec::with_capacity(n + 1);
        let mut instr_to_op = vec![u32::MAX; n + 1];
        let mut fused_instrs = 0usize;
        let mut i = 0usize;
        while i < n {
            instr_to_op[i] = ops.len() as u32;
            op_pc.push(base.wrapping_add(4 * i as u32));
            if let Some((op, len)) = try_fuse(program, i, &is_target, t, &mk_tgt, opts) {
                ops.push(op);
                fused_instrs += len;
                i += len;
            } else {
                ops.push(lower_one(program[i], base.wrapping_add(4 * i as u32), i, t, &mk_tgt));
                i += 1;
            }
        }
        instr_to_op[n] = ops.len() as u32;
        op_pc.push(base.wrapping_add(4 * n as u32));
        ops.push(MicroOp::Trap);

        // Pass 3: instruction-index targets → op indices. Every static
        // target was marked in pass 1, so fusion never swallowed it and
        // the map entry is real.
        for op in &mut ops {
            match op {
                MicroOp::Jal { tgt, .. }
                | MicroOp::Branch { tgt, .. }
                | MicroOp::Latch { tgt, .. } => {
                    if let Tgt::Op(ii) = *tgt {
                        let oi = instr_to_op[ii as usize];
                        debug_assert_ne!(oi, u32::MAX, "static target inside a fused strip");
                        *tgt = Tgt::Op(oi);
                    }
                }
                _ => {}
            }
        }

        // Pass 4: counted loops. A `Latch` whose taken target is the
        // immediately preceding fused strip is the kernel generators'
        // reduction-loop shape; rewrite it in place (op indices stay
        // valid — the body op remains dispatchable on fall-through and
        // for dynamic entries at the loop head).
        if opts.counted_loops {
            for j in 1..ops.len() {
                let MicroOp::Latch { bumps, n: nb, bop, rs1, rs2, tgt, c_bumps, ct, cnt } = ops[j]
                else {
                    continue;
                };
                let Tgt::Op(tgt_op) = tgt else { continue };
                if tgt_op as usize != j - 1 {
                    continue;
                }
                // Architectural registers the strip body writes. x0 is
                // dropped on write, so it can never really be clobbered.
                let mut writes = [0u8; 6];
                let nw = match ops[j - 1] {
                    MicroOp::LoadMac { acc, act_rd, w_rd, k, .. } => {
                        let mut nw = 0usize;
                        for a in 0..k {
                            writes[nw] = act_rd + a;
                            nw += 1;
                        }
                        writes[nw] = w_rd;
                        writes[nw + 1] = acc;
                        nw + 2
                    }
                    MicroOp::ScalarMac { ra, rb, rm, acc, .. } => {
                        writes[..4].copy_from_slice(&[ra, rb, rm, acc]);
                        4
                    }
                    _ => continue,
                };
                let body_writes = &writes[..nw];
                let bump_slice = &bumps[..nb as usize];
                let body_clobbers = body_writes.iter().any(|&w| {
                    w != 0
                        && (w == rs1 || w == rs2 || bump_slice.iter().any(|&(r, _)| r == w))
                });
                // Trip-count prediction needs exactly one compare
                // operand to be the (singly-)bumped counter and the
                // other to be loop-invariant; everything else takes the
                // re-evaluating guard path.
                let counted = if body_clobbers || rs1 == rs2 {
                    None
                } else {
                    // The counter must be bumped exactly once and the
                    // bound not at all ("bumped twice" must not be
                    // mistaken for "invariant").
                    let count_of =
                        |r: Reg| bump_slice.iter().filter(|&&(br, _)| br == r).count();
                    let imm_of = |r: Reg| {
                        bump_slice.iter().find(|&&(br, _)| br == r).map(|&(_, im)| im)
                    };
                    if rs1 != 0 && count_of(rs1) == 1 && count_of(rs2) == 0 {
                        imm_of(rs1).filter(|&s| s != 0).map(|s| (true, s))
                    } else if rs2 != 0 && count_of(rs2) == 1 && count_of(rs1) == 0 {
                        imm_of(rs2).filter(|&s| s != 0).map(|s| (false, s))
                    } else {
                        None
                    }
                };
                ops[j] = MicroOp::CountedLoop {
                    body: (j - 1) as u32,
                    bumps,
                    n: nb,
                    bop,
                    rs1,
                    rs2,
                    c_bumps,
                    ct,
                    cnt,
                    counted,
                };
            }
        }

        CompiledProgram { ops, op_pc, instr_to_op, base, n_instrs: n, clean: true, fused_instrs }
    }

    /// Micro-ops in the stream (excluding the trailing trap).
    pub fn op_count(&self) -> usize {
        self.ops.len().saturating_sub(1)
    }

    /// Instructions in the source program.
    pub fn instr_count(&self) -> usize {
        self.n_instrs
    }

    /// Instructions absorbed into fused superinstructions.
    pub fn fused_instr_count(&self) -> usize {
        self.fused_instrs
    }

    /// Static census of fused superinstructions in the op stream:
    /// `[load_mac, scalar_mac, latch, requant, counted_loop]`.
    pub fn fusion_census(&self) -> [usize; 5] {
        let mut c = [0usize; 5];
        for op in &self.ops {
            match op {
                MicroOp::LoadMac { .. } => c[0] += 1,
                MicroOp::ScalarMac { .. } => c[1] += 1,
                MicroOp::Latch { .. } => c[2] += 1,
                MicroOp::Requant { .. } => c[3] += 1,
                MicroOp::CountedLoop { .. } => c[4] += 1,
                _ => {}
            }
        }
        c
    }

    /// False when [`run`] will delegate to the reference interpreter.
    pub fn is_clean(&self) -> bool {
        self.clean
    }
}

/// Lower one instruction to a micro-op.
fn lower_one(
    ins: Instr,
    pc: u32,
    instr_idx: usize,
    t: &Timing,
    mk_tgt: &impl Fn(usize, i32) -> Tgt,
) -> MicroOp {
    match ins {
        Instr::Lui { rd, imm } => MicroOp::LoadImm { rd, val: imm as u32, c: t.alu },
        Instr::Auipc { rd, imm } => {
            MicroOp::LoadImm { rd, val: pc.wrapping_add(imm as u32), c: t.alu }
        }
        Instr::Jal { rd, offset } => MicroOp::Jal {
            rd,
            link: pc.wrapping_add(4),
            tgt: mk_tgt(instr_idx, offset),
            c: t.jump,
        },
        Instr::Jalr { rd, rs1, offset } => MicroOp::Jalr {
            rd,
            rs1,
            offset: offset as u32,
            link: pc.wrapping_add(4),
            c: t.jump,
        },
        Instr::Branch { op, rs1, rs2, offset } => MicroOp::Branch {
            op,
            rs1,
            rs2,
            tgt: mk_tgt(instr_idx, offset),
            ct: t.branch_taken,
            cnt: t.branch_not_taken,
        },
        Instr::Load { op, rd, rs1, offset } => {
            MicroOp::Load { op, rd, rs1, offset: offset as u32, c: t.load }
        }
        Instr::Store { op, rs1, rs2, offset } => {
            MicroOp::Store { op, rs1, rs2, offset: offset as u32, c: t.store }
        }
        Instr::OpImm { op, rd, rs1, imm } => {
            MicroOp::OpImm { op, rd, rs1, imm: imm as u32, c: t.alu }
        }
        Instr::Op { op, rd, rs1, rs2 } => MicroOp::Op { op, rd, rs1, rs2, c: t.alu },
        Instr::MulDiv { op, rd, rs1, rs2 } => {
            let c = match op {
                MulOp::Mul => t.mul,
                MulOp::Mulh | MulOp::Mulhsu | MulOp::Mulhu => t.mulh,
                MulOp::Div | MulOp::Divu | MulOp::Rem | MulOp::Remu => t.div,
            };
            MicroOp::MulDiv { op, rd, rs1, rs2, c }
        }
        Instr::NnMac { mode, rd, rs1, rs2 } => MicroOp::NnMac { mode, rd, rs1, rs2 },
        Instr::Csr { op: _, rd, rs1: _, csr } => MicroOp::Csr { rd, csr, c: t.csr },
        Instr::Fence => MicroOp::Fence { c: t.fence },
        Instr::Ecall => MicroOp::Ecall,
        Instr::Ebreak => MicroOp::Ebreak,
    }
}

/// Try to fuse a superinstruction starting at instruction `i`. The
/// fused executor replays the exact sequential semantics, so the only
/// hard requirements are the literal opcode pattern and that no static
/// branch target points into the strip's interior.
fn try_fuse(
    p: &[Instr],
    i: usize,
    is_target: &[bool],
    t: &Timing,
    mk_tgt: &impl Fn(usize, i32) -> Tgt,
    opts: TranslateOpts,
) -> Option<(MicroOp, usize)> {
    match p[i] {
        Instr::Load { op: LoadOp::Lw, .. } => try_load_mac(p, i, is_target, t),
        Instr::Load { op: LoadOp::Lb, .. } => try_scalar_mac(p, i, is_target, t),
        Instr::OpImm { op: AluOp::Add, .. } => try_latch(p, i, is_target, t, mk_tgt),
        Instr::MulDiv { op: MulOp::Mulh, .. } if opts.fuse_requant => {
            try_requant(p, i, is_target, t)
        }
        _ => None,
    }
}

/// k× `lw` of consecutive activation words + weight `lw` + `nn_mac`.
fn try_load_mac(
    p: &[Instr],
    i: usize,
    is_target: &[bool],
    t: &Timing,
) -> Option<(MicroOp, usize)> {
    let Instr::Load { op: LoadOp::Lw, rd: rd0, rs1: ab, offset: ao } = p[i] else {
        return None;
    };
    if rd0 == 0 {
        return None;
    }
    for k in [1usize, 2, 4] {
        if i + k + 1 >= p.len() {
            continue;
        }
        let Instr::NnMac { mode, rd: acc, rs1, rs2 } = p[i + k + 1] else { continue };
        if mode.activation_regs() as usize != k || rs1 != rd0 {
            continue;
        }
        if rd0 as usize + k > NUM_REGS {
            continue;
        }
        // The activation-word run: rd0+j ← (ao + 4j)(ab).
        let mut run_ok = true;
        for j in 1..k {
            match p[i + j] {
                Instr::Load { op: LoadOp::Lw, rd, rs1: b, offset }
                    if rd == rd0 + j as u8 && b == ab && offset == ao + 4 * j as i32 => {}
                _ => {
                    run_ok = false;
                    break;
                }
            }
        }
        if !run_ok {
            continue;
        }
        let Instr::Load { op: LoadOp::Lw, rd: w_rd, rs1: w_base, offset: w_off } = p[i + k]
        else {
            continue;
        };
        if w_rd == 0 || rs2 != w_rd {
            continue;
        }
        // The fused executor reads the activation base once, so it must
        // not be overwritten by the act-word loads themselves.
        if (rd0..rd0 + k as u8).contains(&ab) {
            continue;
        }
        if is_target[i + 1..=i + k + 1].iter().any(|&b| b) {
            continue;
        }
        return Some((
            MicroOp::LoadMac {
                mode,
                acc,
                act_rd: rd0,
                act_base: ab,
                act_off: ao as u32,
                w_rd,
                w_base,
                w_off: w_off as u32,
                k: k as u8,
                c_load: t.load,
            },
            k + 2,
        ));
    }
    None
}

/// `lb ra`, `lb rb`, `mul rm, ra, rb`, `add acc, acc, rm`.
fn try_scalar_mac(
    p: &[Instr],
    i: usize,
    is_target: &[bool],
    t: &Timing,
) -> Option<(MicroOp, usize)> {
    if i + 3 >= p.len() {
        return None;
    }
    let Instr::Load { op: LoadOp::Lb, rd: ra, rs1: a_base, offset: a_off } = p[i] else {
        return None;
    };
    let Instr::Load { op: LoadOp::Lb, rd: rb, rs1: b_base, offset: b_off } = p[i + 1] else {
        return None;
    };
    let Instr::MulDiv { op: MulOp::Mul, rd: rm, rs1, rs2 } = p[i + 2] else {
        return None;
    };
    if rs1 != ra || rs2 != rb {
        return None;
    }
    let Instr::Op { op: AluOp::Add, rd: acc, rs1: ar1, rs2: ar2 } = p[i + 3] else {
        return None;
    };
    if ar1 != acc || ar2 != rm {
        return None;
    }
    if is_target[i + 1..=i + 3].iter().any(|&b| b) {
        return None;
    }
    Some((
        MicroOp::ScalarMac {
            ra,
            a_base,
            a_off: a_off as u32,
            rb,
            b_base,
            b_off: b_off as u32,
            rm,
            acc,
            c_load: t.load,
            c_tail: t.mul + t.alu,
        },
        4,
    ))
}

/// Up to 3× `addi r, r, imm` followed by a conditional branch.
fn try_latch(
    p: &[Instr],
    i: usize,
    is_target: &[bool],
    t: &Timing,
    mk_tgt: &impl Fn(usize, i32) -> Tgt,
) -> Option<(MicroOp, usize)> {
    let mut bumps = [(0u8, 0u32); 3];
    let mut nb = 0usize;
    while nb < 3 && i + nb < p.len() {
        match p[i + nb] {
            Instr::OpImm { op: AluOp::Add, rd, rs1, imm } if rd == rs1 => {
                bumps[nb] = (rd, imm as u32);
                nb += 1;
            }
            _ => break,
        }
    }
    if nb == 0 || i + nb >= p.len() {
        return None;
    }
    let Instr::Branch { op, rs1, rs2, offset } = p[i + nb] else {
        return None;
    };
    if is_target[i + 1..=i + nb].iter().any(|&b| b) {
        return None;
    }
    Some((
        MicroOp::Latch {
            bumps,
            n: nb as u8,
            bop: op,
            rs1,
            rs2,
            tgt: mk_tgt(i + nb, offset),
            c_bumps: nb as u32 * t.alu,
            ct: t.branch_taken,
            cnt: t.branch_not_taken,
        },
        nb + 1,
    ))
}

/// The requant epilogue in the canonical shape
/// `kernels::requant::emit_requantize` emits (see the `Requant`
/// micro-op docs): SRDHM chain, optional rounding shift, branchless
/// clamp, `mv`, and optionally the trailing `sb` of the result. The
/// fused executor computes the final values of every written register
/// in closed form, so the register constraints below ensure the
/// sequential dataflow really is the closed form (aliasing that would
/// change it rejects the fusion — the ops then lower individually).
fn try_requant(
    p: &[Instr],
    i: usize,
    is_target: &[bool],
    t: &Timing,
) -> Option<(MicroOp, usize)> {
    // ---- SRDHM chain: 10 instructions -------------------------------
    let Instr::MulDiv { op: MulOp::Mulh, rd: t0, rs1: acc, rs2: m } = p[i] else {
        return None;
    };
    let Some(&Instr::MulDiv { op: MulOp::Mul, rd: t1, rs1: m_a, rs2: m_b }) = p.get(i + 1)
    else {
        return None;
    };
    if m_a != acc || m_b != m {
        return None;
    }
    let Some(&Instr::Lui { rd: t2, imm: 0x4000_0000 }) = p.get(i + 2) else {
        return None;
    };
    let t3 = match p.get(i + 3) {
        Some(&Instr::Op { op: AluOp::Add, rd, rs1, rs2 }) if rs1 == t1 && rs2 == t2 => rd,
        _ => return None,
    };
    match p.get(i + 4) {
        Some(&Instr::Op { op: AluOp::Sltu, rd, rs1, rs2 })
            if rd == t1 && rs1 == t3 && rs2 == t1 => {}
        _ => return None,
    }
    match p.get(i + 5) {
        Some(&Instr::OpImm { op: AluOp::Srl, rd, rs1, imm: 31 }) if rd == t3 && rs1 == t3 => {}
        _ => return None,
    }
    match p.get(i + 6) {
        Some(&Instr::OpImm { op: AluOp::Sll, rd, rs1, imm: 1 }) if rd == t0 && rs1 == t0 => {}
        _ => return None,
    }
    match p.get(i + 7) {
        Some(&Instr::Op { op: AluOp::Add, rd, rs1, rs2 })
            if rd == t0 && rs1 == t0 && rs2 == t3 => {}
        _ => return None,
    }
    match p.get(i + 8) {
        Some(&Instr::OpImm { op: AluOp::Sll, rd, rs1, imm: 1 }) if rd == t1 && rs1 == t1 => {}
        _ => return None,
    }
    match p.get(i + 9) {
        Some(&Instr::Op { op: AluOp::Add, rd, rs1, rs2 })
            if rd == t0 && rs1 == t0 && rs2 == t1 => {}
        _ => return None,
    }

    // ---- optional rounding shift ------------------------------------
    let mut j = i + 10;
    let mut shift = 0i32;
    let mut rnd: Reg = 0;
    match p.get(j) {
        Some(&Instr::Op { op: AluOp::Add, rd, rs1, rs2 }) if rd == t0 && rs1 == t0 => {
            match p.get(j + 1) {
                Some(&Instr::OpImm { op: AluOp::Sra, rd: sr, rs1: ss, imm })
                    if sr == t0 && ss == t0 && (1..32).contains(&imm) =>
                {
                    rnd = rs2;
                    shift = imm;
                    j += 2;
                }
                _ => return None,
            }
        }
        Some(&Instr::OpImm { op: AluOp::Sll, rd, rs1, imm })
            if rd == t0 && rs1 == t0 && (1..32).contains(&imm) =>
        {
            shift = -imm;
            j += 1;
        }
        _ => {}
    }

    // ---- branchless clamp to [lo, 127]: 11 instructions -------------
    match p.get(j) {
        Some(&Instr::OpImm { op: AluOp::Add, rd, rs1: 0, imm: 127 }) if rd == t1 => {}
        _ => return None,
    }
    match p.get(j + 1) {
        Some(&Instr::Op { op: AluOp::Slt, rd, rs1, rs2 })
            if rd == t2 && rs1 == t1 && rs2 == t0 => {}
        _ => return None,
    }
    match p.get(j + 2) {
        Some(&Instr::Op { op: AluOp::Sub, rd, rs1: 0, rs2 }) if rd == t2 && rs2 == t2 => {}
        _ => return None,
    }
    match p.get(j + 3) {
        Some(&Instr::Op { op: AluOp::Xor, rd, rs1, rs2 })
            if rd == t3 && rs1 == t0 && rs2 == t1 => {}
        _ => return None,
    }
    match p.get(j + 4) {
        Some(&Instr::Op { op: AluOp::And, rd, rs1, rs2 })
            if rd == t3 && rs1 == t3 && rs2 == t2 => {}
        _ => return None,
    }
    match p.get(j + 5) {
        Some(&Instr::Op { op: AluOp::Xor, rd, rs1, rs2 })
            if rd == t0 && rs1 == t0 && rs2 == t3 => {}
        _ => return None,
    }
    let lo = match p.get(j + 6) {
        Some(&Instr::Op { op: AluOp::Slt, rd, rs1, rs2 }) if rd == t2 && rs1 == t0 => rs2,
        _ => return None,
    };
    match p.get(j + 7) {
        Some(&Instr::Op { op: AluOp::Sub, rd, rs1: 0, rs2 }) if rd == t2 && rs2 == t2 => {}
        _ => return None,
    }
    match p.get(j + 8) {
        Some(&Instr::Op { op: AluOp::Xor, rd, rs1, rs2 })
            if rd == t3 && rs1 == t0 && rs2 == lo => {}
        _ => return None,
    }
    match p.get(j + 9) {
        Some(&Instr::Op { op: AluOp::And, rd, rs1, rs2 })
            if rd == t3 && rs1 == t3 && rs2 == t2 => {}
        _ => return None,
    }
    match p.get(j + 10) {
        Some(&Instr::Op { op: AluOp::Xor, rd, rs1, rs2 })
            if rd == t0 && rs1 == t0 && rs2 == t3 => {}
        _ => return None,
    }
    j += 11;

    // ---- mv out, t0 --------------------------------------------------
    let out = match p.get(j) {
        Some(&Instr::OpImm { op: AluOp::Add, rd, rs1, imm: 0 }) if rs1 == t0 => rd,
        _ => return None,
    };
    j += 1;

    // ---- register constraints (closed-form soundness) ---------------
    let ts = [t0, t1, t2, t3];
    if ts.contains(&0)
        || t0 == t1
        || t0 == t2
        || t0 == t3
        || t1 == t2
        || t1 == t3
        || t2 == t3
        || acc == t0
        || m == t0
        || ts.contains(&lo)
        || (shift > 0 && ts.contains(&rnd))
    {
        return None;
    }
    if is_target[i + 1..j].iter().any(|&b| b) {
        return None;
    }

    // ---- optional trailing store of the quantized byte --------------
    let n_pre = (j - i) as u8;
    let mut store = None;
    if let Some(&Instr::Store { op: StoreOp::Sb, rs1: sbase, rs2: ssrc, offset }) = p.get(j) {
        if ssrc == out && !is_target[j] {
            store = Some((sbase, offset as u32));
            j += 1;
        }
    }

    // All fused instructions are single-cycle ALU ops except the
    // mulh/mul pair (and the store, accounted separately).
    let c = t.mulh + t.mul + (n_pre as u32 - 2) * t.alu;
    Some((
        MicroOp::Requant {
            acc,
            m,
            rnd,
            lo,
            t0,
            t1,
            t2,
            t3,
            out,
            shift: shift as i8,
            store,
            n_pre,
            c,
            c_store: t.store,
        },
        j - i,
    ))
}

/// Closed-form trip-count prediction for a counted loop whose latch
/// branch was just taken: the number of *additional* taken branches
/// (strip executions = trips + 1) from the counter value `c0` (after
/// the entry bumps), the loop-invariant `bound`, and the per-iteration
/// `step`. O(1) — no per-iteration work. Returns `None` when the exit
/// needs wrap-around modular arithmetic (non-unit `bne` strides, or an
/// ordered comparison whose linear model leaves the 32-bit domain
/// before failing); the caller then re-evaluates the branch per
/// iteration, which handles every case.
fn predict_trips(bop: BranchOp, ctr_is_rs1: bool, c0: u32, bound: u32, step: u32) -> Option<u64> {
    let steps = step as i32 as i64;
    match bop {
        // Taken entry means counter == bound; the next evaluation
        // (counter moved by step != 0) already falls out.
        BranchOp::Beq => Some(0),
        // Exact modular solution for the unit strides the kernels
        // emit; other strides may step over the bound and wrap.
        BranchOp::Bne => match steps {
            1 => Some(bound.wrapping_sub(c0) as u64 - 1),
            -1 => Some(c0.wrapping_sub(bound) as u64 - 1),
            _ => None,
        },
        // Ordered comparisons: model the counter in i64 (wrap-free)
        // and solve for the first failing evaluation; reject if the
        // exit value leaves the 32-bit domain (the machine would wrap
        // first and the linear model diverges).
        _ => {
            let signed = matches!(bop, BranchOp::Blt | BranchOp::Bge);
            let (c, k, lo, hi) = if signed {
                (c0 as i32 as i64, bound as i32 as i64, i32::MIN as i64, i32::MAX as i64)
            } else {
                (c0 as i64, bound as i64, 0i64, u32::MAX as i64)
            };
            // Normalize "taken" to a strict threshold on the counter:
            // Blt/Bltu are rs1 < rs2, Bge/Bgeu are rs1 >= rs2.
            let less = matches!(bop, BranchOp::Blt | BranchOp::Bltu);
            let (rising, t) = match (less, ctr_is_rs1) {
                (true, true) => (true, k),       // taken: c < k
                (true, false) => (false, k),     // taken: k < c
                (false, true) => (false, k - 1), // taken: c >= k  ⇔  c > k-1
                (false, false) => (true, k + 1), // taken: k >= c  ⇔  c < k+1
            };
            let i_exit = if rising {
                if steps <= 0 {
                    return None; // exits only by wrapping
                }
                let d = t - c; // > 0: taken at the entry evaluation
                (d + steps - 1) / steps
            } else {
                if steps >= 0 {
                    return None;
                }
                let d = c - t; // > 0
                (d - steps - 1) / (-steps)
            };
            let v_exit = c + i_exit * steps;
            if v_exit < lo || v_exit > hi {
                return None;
            }
            Some((i_exit - 1) as u64)
        }
    }
}

#[inline]
fn branch_taken(op: BranchOp, a: u32, b: u32) -> bool {
    match op {
        BranchOp::Beq => a == b,
        BranchOp::Bne => a != b,
        BranchOp::Blt => (a as i32) < (b as i32),
        BranchOp::Bge => (a as i32) >= (b as i32),
        BranchOp::Bltu => a < b,
        BranchOp::Bgeu => a >= b,
    }
}

/// Per-op control-flow outcome of the dispatch loop.
enum Flow {
    Seq,
    Goto(Tgt),
}

/// Execute one fused packed-kernel strip (`LoadMac`) against `core`.
/// `pc0` is the strip's first-instruction pc (fault reporting).
/// Returns `Some(reason)` when the strip faults, `None` on completion.
/// Shared by op dispatch and the counted-loop executor.
#[allow(clippy::too_many_arguments)]
#[inline]
fn exec_load_mac(
    core: &mut Core,
    mode: MacMode,
    acc: Reg,
    act_rd: Reg,
    act_base: Reg,
    act_off: u32,
    w_rd: Reg,
    w_base: Reg,
    w_off: u32,
    k: u8,
    c_load: u32,
    pc0: u32,
) -> Option<ExitReason> {
    let k = k as usize;
    let base_addr = core.regs[act_base as usize].wrapping_add(act_off);
    let mut buf = [0u32; 4];
    match core.mem.load_word_run(base_addr, &mut buf[..k]) {
        Ok(()) => {}
        Err((j, f)) => {
            // Partial strip: the first j loads completed exactly as
            // they would have individually.
            for (jj, &w) in buf.iter().enumerate().take(j) {
                core.regs[act_rd as usize + jj] = w;
            }
            core.perf.loads += j as u64;
            core.perf.cycles += j as u64 * c_load as u64;
            core.perf.instret += j as u64;
            core.pc = pc0.wrapping_add(4 * j as u32);
            return Some(ExitReason::Fault(f));
        }
    }
    for (j, &w) in buf.iter().enumerate().take(k) {
        core.regs[act_rd as usize + j] = w;
    }
    let w_addr = core.regs[w_base as usize].wrapping_add(w_off);
    let w_word = match core.mem.load(w_addr, 4) {
        Ok(w) => w,
        Err(f) => {
            core.perf.loads += k as u64;
            core.perf.cycles += k as u64 * c_load as u64;
            core.perf.instret += k as u64;
            core.pc = pc0.wrapping_add(4 * k as u32);
            return Some(ExitReason::Fault(f));
        }
    };
    core.regs[w_rd as usize] = w_word;
    let issue = core.mac_unit.issue(
        mode,
        core.regs[acc as usize],
        &core.regs[act_rd as usize..act_rd as usize + k],
        w_word,
    );
    core.write_reg(acc, issue.acc);
    core.perf.loads += (k + 1) as u64;
    core.perf.macs += issue.macs as u64;
    core.perf.nn_mac_instrs += 1;
    core.perf.cycles += (k + 1) as u64 * c_load as u64 + issue.cycles as u64;
    core.perf.instret += (k + 2) as u64;
    core.engine_stats.load_mac += 1;
    None
}

/// Execute one fused scalar baseline MAC (`ScalarMac`) against `core`.
/// Same contract as [`exec_load_mac`].
#[allow(clippy::too_many_arguments)]
#[inline]
fn exec_scalar_mac(
    core: &mut Core,
    ra: Reg,
    a_base: Reg,
    a_off: u32,
    rb: Reg,
    b_base: Reg,
    b_off: u32,
    rm: Reg,
    acc: Reg,
    c_load: u32,
    c_tail: u32,
    pc0: u32,
) -> Option<ExitReason> {
    let addr_a = core.regs[a_base as usize].wrapping_add(a_off);
    let va = match core.mem.load(addr_a, 1) {
        Ok(raw) => raw as u8 as i8 as i32 as u32,
        Err(f) => {
            core.pc = pc0;
            return Some(ExitReason::Fault(f));
        }
    };
    core.write_reg(ra, va);
    let addr_b = core.regs[b_base as usize].wrapping_add(b_off);
    let vb = match core.mem.load(addr_b, 1) {
        Ok(raw) => raw as u8 as i8 as i32 as u32,
        Err(f) => {
            core.perf.loads += 1;
            core.perf.cycles += c_load as u64;
            core.perf.instret += 1;
            core.pc = pc0.wrapping_add(4);
            return Some(ExitReason::Fault(f));
        }
    };
    core.write_reg(rb, vb);
    let prod = core.regs[ra as usize].wrapping_mul(core.regs[rb as usize]);
    core.write_reg(rm, prod);
    let sum = core.regs[acc as usize].wrapping_add(core.regs[rm as usize]);
    core.write_reg(acc, sum);
    core.perf.loads += 2;
    core.perf.muldiv_instrs += 1;
    core.perf.macs += 1;
    core.mac_unit.total_macs += 1;
    core.perf.cycles += 2 * c_load as u64 + c_tail as u64;
    core.perf.instret += 4;
    core.engine_stats.scalar_mac += 1;
    None
}

/// Run `core` on the micro-op engine until halt or `max_cycles`.
///
/// Equivalent to [`Core::run`] (see the module docs for the cycle
/// budget caveat). Falls back to the reference interpreter when the
/// translation is not clean, when the entry pc is not a translated
/// op boundary, or when a `jalr` lands inside a fused strip.
pub fn run(core: &mut Core, cp: &CompiledProgram, max_cycles: u64) -> ExitReason {
    if !cp.clean || core.prog_base != cp.base || core.program.len() != cp.n_instrs {
        core.engine_stats.fallbacks += 1;
        return core.run(max_cycles);
    }
    // Entry: map the current pc onto the op stream.
    let rel = core.pc.wrapping_sub(cp.base);
    if rel % 4 != 0 {
        core.engine_stats.fallbacks += 1;
        return core.run(max_cycles);
    }
    let ii = (rel / 4) as usize;
    if ii > cp.n_instrs {
        return ExitReason::IllegalPc(core.pc);
    }
    let entry = cp.instr_to_op[ii];
    if entry == u32::MAX {
        core.engine_stats.fallbacks += 1;
        return core.run(max_cycles);
    }
    let mut idx = entry as usize;

    loop {
        let flow = match cp.ops[idx] {
            MicroOp::LoadImm { rd, val, c } => {
                core.write_reg(rd, val);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::Jal { rd, link, tgt, c } => {
                core.write_reg(rd, link);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Goto(tgt)
            }
            MicroOp::Jalr { rd, rs1, offset, link, c } => {
                let target = core.regs[rs1 as usize].wrapping_add(offset) & !1;
                core.write_reg(rd, link);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                let rel = target.wrapping_sub(cp.base);
                if rel % 4 != 0 {
                    core.pc = target;
                    if core.perf.cycles >= max_cycles {
                        return ExitReason::MaxCycles;
                    }
                    core.engine_stats.fallbacks += 1;
                    return core.run(max_cycles);
                }
                let ti = (rel / 4) as usize;
                if ti > cp.n_instrs {
                    core.pc = target;
                    if core.perf.cycles >= max_cycles {
                        return ExitReason::MaxCycles;
                    }
                    return ExitReason::IllegalPc(target);
                }
                let oi = cp.instr_to_op[ti];
                if oi == u32::MAX {
                    // Dynamic entry into a fused strip: replay on the
                    // reference interpreter from here.
                    core.pc = target;
                    if core.perf.cycles >= max_cycles {
                        return ExitReason::MaxCycles;
                    }
                    core.engine_stats.fallbacks += 1;
                    return core.run(max_cycles);
                }
                Flow::Goto(Tgt::Op(oi))
            }
            MicroOp::Branch { op, rs1, rs2, tgt, ct, cnt } => {
                let a = core.regs[rs1 as usize];
                let b = core.regs[rs2 as usize];
                core.perf.instret += 1;
                if branch_taken(op, a, b) {
                    core.perf.taken_branches += 1;
                    core.perf.cycles += ct as u64;
                    Flow::Goto(tgt)
                } else {
                    core.perf.cycles += cnt as u64;
                    Flow::Seq
                }
            }
            MicroOp::Load { op, rd, rs1, offset, c } => {
                let addr = core.regs[rs1 as usize].wrapping_add(offset);
                let (width, sign) = match op {
                    LoadOp::Lb => (1, true),
                    LoadOp::Lh => (2, true),
                    LoadOp::Lw => (4, false),
                    LoadOp::Lbu => (1, false),
                    LoadOp::Lhu => (2, false),
                };
                match core.mem.load(addr, width) {
                    Ok(raw) => {
                        let val = if sign {
                            match width {
                                1 => raw as u8 as i8 as i32 as u32,
                                2 => raw as u16 as i16 as i32 as u32,
                                _ => raw,
                            }
                        } else {
                            raw
                        };
                        core.write_reg(rd, val);
                        core.perf.loads += 1;
                        core.perf.cycles += c as u64;
                        core.perf.instret += 1;
                        Flow::Seq
                    }
                    Err(f) => {
                        core.pc = cp.op_pc[idx];
                        return ExitReason::Fault(f);
                    }
                }
            }
            MicroOp::Store { op, rs1, rs2, offset, c } => {
                let addr = core.regs[rs1 as usize].wrapping_add(offset);
                let width = match op {
                    StoreOp::Sb => 1,
                    StoreOp::Sh => 2,
                    StoreOp::Sw => 4,
                };
                match core.mem.store(addr, width, core.regs[rs2 as usize]) {
                    Ok(()) => {
                        core.perf.stores += 1;
                        core.perf.cycles += c as u64;
                        core.perf.instret += 1;
                        Flow::Seq
                    }
                    Err(f) => {
                        core.pc = cp.op_pc[idx];
                        return ExitReason::Fault(f);
                    }
                }
            }
            MicroOp::OpImm { op, rd, rs1, imm, c } => {
                let v = alu_eval(op, core.regs[rs1 as usize], imm);
                core.write_reg(rd, v);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::Op { op, rd, rs1, rs2, c } => {
                let v = alu_eval(op, core.regs[rs1 as usize], core.regs[rs2 as usize]);
                core.write_reg(rd, v);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::MulDiv { op, rd, rs1, rs2, c } => {
                let a = core.regs[rs1 as usize];
                let b = core.regs[rs2 as usize];
                let val = match op {
                    MulOp::Mul => a.wrapping_mul(b),
                    MulOp::Mulh => (((a as i32 as i64) * (b as i32 as i64)) >> 32) as u32,
                    MulOp::Mulhsu => (((a as i32 as i64) * (b as i64)) >> 32) as u32,
                    MulOp::Mulhu => (((a as u64) * (b as u64)) >> 32) as u32,
                    MulOp::Div => {
                        let (a, b) = (a as i32, b as i32);
                        let q = if b == 0 {
                            -1
                        } else if a == i32::MIN && b == -1 {
                            i32::MIN
                        } else {
                            a.wrapping_div(b)
                        };
                        q as u32
                    }
                    MulOp::Divu => {
                        if b == 0 {
                            u32::MAX
                        } else {
                            a / b
                        }
                    }
                    MulOp::Rem => {
                        let (a, b) = (a as i32, b as i32);
                        let r = if b == 0 {
                            a
                        } else if a == i32::MIN && b == -1 {
                            0
                        } else {
                            a.wrapping_rem(b)
                        };
                        r as u32
                    }
                    MulOp::Remu => {
                        if b == 0 {
                            a
                        } else {
                            a % b
                        }
                    }
                };
                core.write_reg(rd, val);
                core.perf.muldiv_instrs += 1;
                if op == MulOp::Mul {
                    core.perf.macs += 1;
                    core.mac_unit.total_macs += 1;
                }
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::NnMac { mode, rd, rs1, rs2 } => {
                let k = mode.activation_regs() as usize;
                let mut acts = [0u32; 4];
                for (j, slot) in acts.iter_mut().enumerate().take(k) {
                    *slot = core.regs[rs1 as usize + j];
                }
                let issue = core.mac_unit.issue(
                    mode,
                    core.regs[rd as usize],
                    &acts[..k],
                    core.regs[rs2 as usize],
                );
                core.write_reg(rd, issue.acc);
                core.perf.macs += issue.macs as u64;
                core.perf.nn_mac_instrs += 1;
                core.perf.cycles += issue.cycles as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::Csr { rd, csr, c } => {
                let val = core.perf.read_csr(csr);
                core.write_reg(rd, val);
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::Fence { c } => {
                core.perf.cycles += c as u64;
                core.perf.instret += 1;
                Flow::Seq
            }
            MicroOp::Ecall => {
                core.perf.cycles += 1;
                core.perf.instret += 1;
                core.pc = cp.op_pc[idx];
                return ExitReason::Ecall;
            }
            MicroOp::Ebreak => {
                core.perf.cycles += 1;
                core.perf.instret += 1;
                core.pc = cp.op_pc[idx];
                return ExitReason::Ebreak;
            }
            MicroOp::Trap => {
                core.pc = cp.op_pc[idx];
                return ExitReason::IllegalPc(cp.op_pc[idx]);
            }
            MicroOp::LoadMac {
                mode,
                acc,
                act_rd,
                act_base,
                act_off,
                w_rd,
                w_base,
                w_off,
                k,
                c_load,
            } => {
                match exec_load_mac(
                    core, mode, acc, act_rd, act_base, act_off, w_rd, w_base, w_off, k, c_load,
                    cp.op_pc[idx],
                ) {
                    None => Flow::Seq,
                    Some(r) => return r,
                }
            }
            MicroOp::ScalarMac {
                ra, a_base, a_off, rb, b_base, b_off, rm, acc, c_load, c_tail,
            } => {
                match exec_scalar_mac(
                    core, ra, a_base, a_off, rb, b_base, b_off, rm, acc, c_load, c_tail,
                    cp.op_pc[idx],
                ) {
                    None => Flow::Seq,
                    Some(r) => return r,
                }
            }
            MicroOp::Latch { bumps, n, bop, rs1, rs2, tgt, c_bumps, ct, cnt } => {
                for &(r, imm) in bumps.iter().take(n as usize) {
                    let v = core.regs[r as usize].wrapping_add(imm);
                    core.write_reg(r, v);
                }
                let a = core.regs[rs1 as usize];
                let b = core.regs[rs2 as usize];
                core.perf.instret += n as u64 + 1;
                core.engine_stats.latch += 1;
                if branch_taken(bop, a, b) {
                    core.perf.taken_branches += 1;
                    core.perf.cycles += (c_bumps + ct) as u64;
                    Flow::Goto(tgt)
                } else {
                    core.perf.cycles += (c_bumps + cnt) as u64;
                    Flow::Seq
                }
            }
            MicroOp::Requant {
                acc, m, rnd, lo, t0, t1, t2, t3, out, shift, store, n_pre, c, c_store,
            } => {
                // Closed-form replay of the fused sequence (bit-exact
                // per-instruction semantics; see `try_requant` for the
                // aliasing constraints that make this sound).
                let av = core.regs[acc as usize] as i32;
                let mv = core.regs[m as usize] as i32;
                let p = (av as i64) * (mv as i64);
                let h = (p >> 32) as u32; // mulh
                let l = p as u32; // mul
                let lr = l.wrapping_add(0x4000_0000); // add t3, t1, t2
                let carry = (lr < l) as u32; // sltu
                let t3v = lr >> 31; // srli
                let t1v = carry << 1; // slli t1
                let s = h.wrapping_shl(1).wrapping_add(t3v).wrapping_add(t1v);
                let shifted = if shift > 0 {
                    ((s.wrapping_add(core.regs[rnd as usize]) as i32) >> shift) as u32
                } else if shift < 0 {
                    s.wrapping_shl((-(shift as i32)) as u32)
                } else {
                    s
                };
                // Branchless clamp: min(·, 127) then max(·, lo).
                let gt = ((127i32) < (shifted as i32)) as u32;
                let minv = shifted ^ ((shifted ^ 127) & 0u32.wrapping_sub(gt));
                let lov = core.regs[lo as usize];
                let lt = ((minv as i32) < (lov as i32)) as u32;
                let mask2 = 0u32.wrapping_sub(lt);
                let x2 = (minv ^ lov) & mask2;
                let clamped = minv ^ x2;
                // Final register state of the sequential execution: the
                // scratch regs carry their last intermediate values and
                // the `mv` (last write) lands after them.
                core.regs[t0 as usize] = clamped;
                core.regs[t1 as usize] = 127;
                core.regs[t2 as usize] = mask2;
                core.regs[t3 as usize] = x2;
                core.write_reg(out, clamped);
                core.perf.muldiv_instrs += 2;
                core.perf.macs += 1; // the SRDHM `mul` counts as one scalar MAC
                core.mac_unit.total_macs += 1;
                core.perf.cycles += c as u64;
                core.perf.instret += n_pre as u64;
                core.engine_stats.requant += 1;
                if let Some((sbase, off)) = store {
                    let addr = core.regs[sbase as usize].wrapping_add(off);
                    match core.mem.store(addr, 1, core.regs[out as usize]) {
                        Ok(()) => {
                            core.perf.stores += 1;
                            core.perf.cycles += c_store as u64;
                            core.perf.instret += 1;
                        }
                        Err(f) => {
                            core.pc = cp.op_pc[idx].wrapping_add(4 * n_pre as u32);
                            return ExitReason::Fault(f);
                        }
                    }
                }
                Flow::Seq
            }
            MicroOp::CountedLoop { body, bumps, n, bop, rs1, rs2, c_bumps, ct, cnt, counted } => {
                for &(r, imm) in bumps.iter().take(n as usize) {
                    let v = core.regs[r as usize].wrapping_add(imm);
                    core.write_reg(r, v);
                }
                let a = core.regs[rs1 as usize];
                let b = core.regs[rs2 as usize];
                core.perf.instret += n as u64 + 1;
                if !branch_taken(bop, a, b) {
                    core.perf.cycles += (c_bumps + cnt) as u64;
                    core.engine_stats.latch += 1;
                    Flow::Seq
                } else {
                    core.perf.taken_branches += 1;
                    core.perf.cycles += (c_bumps + ct) as u64;
                    core.engine_stats.counted_loops += 1;
                    let body_idx = body as usize;
                    let body_pc = cp.op_pc[body_idx];
                    let latch_pc = cp.op_pc[idx];
                    // Predict the remaining taken-branch count in
                    // closed form from the entry register state when
                    // the loop registers are provably unclobbered
                    // (translation-time guard); otherwise re-evaluate
                    // the branch every iteration.
                    let mut remaining = counted.and_then(|(ctr_is_rs1, step)| {
                        let (cv, bound) = if ctr_is_rs1 { (a, b) } else { (b, a) };
                        predict_trips(bop, ctr_is_rs1, cv, bound, step)
                    });
                    loop {
                        // Identical budget placement to op-at-a-time
                        // dispatch: after the taken latch (pc at the
                        // strip) and after the strip (pc at the latch).
                        if core.perf.cycles >= max_cycles {
                            core.pc = body_pc;
                            return ExitReason::MaxCycles;
                        }
                        let halt = match cp.ops[body_idx] {
                            MicroOp::LoadMac {
                                mode,
                                acc,
                                act_rd,
                                act_base,
                                act_off,
                                w_rd,
                                w_base,
                                w_off,
                                k,
                                c_load,
                            } => exec_load_mac(
                                core, mode, acc, act_rd, act_base, act_off, w_rd, w_base,
                                w_off, k, c_load, body_pc,
                            ),
                            MicroOp::ScalarMac {
                                ra, a_base, a_off, rb, b_base, b_off, rm, acc, c_load, c_tail,
                            } => exec_scalar_mac(
                                core, ra, a_base, a_off, rb, b_base, b_off, rm, acc, c_load,
                                c_tail, body_pc,
                            ),
                            _ => unreachable!("counted-loop body is always a fused strip"),
                        };
                        core.engine_stats.counted_iters += 1;
                        if let Some(r) = halt {
                            return r;
                        }
                        if core.perf.cycles >= max_cycles {
                            core.pc = latch_pc;
                            return ExitReason::MaxCycles;
                        }
                        for &(r, imm) in bumps.iter().take(n as usize) {
                            let v = core.regs[r as usize].wrapping_add(imm);
                            core.write_reg(r, v);
                        }
                        core.perf.instret += n as u64 + 1;
                        let taken = match remaining.as_mut() {
                            Some(t) => {
                                if *t > 0 {
                                    *t -= 1;
                                    true
                                } else {
                                    false
                                }
                            }
                            None => branch_taken(
                                bop,
                                core.regs[rs1 as usize],
                                core.regs[rs2 as usize],
                            ),
                        };
                        if taken {
                            core.perf.taken_branches += 1;
                            core.perf.cycles += (c_bumps + ct) as u64;
                        } else {
                            core.perf.cycles += (c_bumps + cnt) as u64;
                            break;
                        }
                    }
                    Flow::Seq
                }
            }
        };

        match flow {
            Flow::Seq => idx += 1,
            Flow::Goto(Tgt::Op(i)) => idx = i as usize,
            Flow::Goto(Tgt::Illegal(pc)) => {
                core.pc = pc;
                if core.perf.cycles >= max_cycles {
                    return ExitReason::MaxCycles;
                }
                return ExitReason::IllegalPc(pc);
            }
        }
        if core.perf.cycles >= max_cycles {
            core.pc = cp.op_pc[idx];
            return ExitReason::MaxCycles;
        }
    }
}
