//! Cycle-accurate Ibex-like RV32IM core simulator with the paper's
//! mixed-precision extension.
//!
//! The model reproduces the **timing** of a 2-stage in-order Ibex
//! configured with the single-cycle RV32M multiplier (the paper's chosen
//! baseline unit) and single-cycle instruction/data memories. Per-class
//! cycle costs follow the Ibex user manual and are collected in
//! [`Timing`]; the `nn_mac_*` cycle cost is produced structurally by the
//! [`mac_unit::MacUnit`] datapath model.
//!
//! Functional semantics are bit-exact RV32IM. Programs halt via `ecall`.
//!
//! ## Execution paths
//!
//! Two interpreters share the architectural state:
//!
//! * [`Core::step`] / [`Core::run`] — the **reference interpreter**: one
//!   decoded-[`Instr`] match per step with byte-pc arithmetic. Simple,
//!   obviously correct, and the semantic oracle for the engine below
//!   (see `tests/engine_equivalence.rs`).
//! * [`engine`] — the **micro-op engine**: [`engine::CompiledProgram`]
//!   translates the decoded program *once* into a flat micro-op stream
//!   with branch/jump targets pre-resolved to stream indices, per-op
//!   cycle costs pre-computed from [`Timing`], and the kernel
//!   generators' inner-loop strips fused into superinstructions
//!   (activation-word loads + weight load + `nn_mac`; the scalar
//!   load-load-mul-add MAC; pointer-bump/branch loop latches; the
//!   whole requant epilogue incl. the trailing output store; and
//!   counted loops — a latch back-branching to a single fused strip
//!   runs the entire reduction loop natively, with the cycle budget
//!   checked per strip iteration). Programs the translator cannot
//!   prove clean (misaligned static control flow) and dynamic jumps
//!   into fused strips fall back to the reference interpreter, so the
//!   engine is observationally identical on every program — it is
//!   purely a throughput optimisation. Per-class superinstruction hit
//!   counters live in [`engine::EngineStats`] (`Core::engine_stats`).
//!
//! [`session`] layers compile-once/run-many reuse on top:
//! [`session::SimSession`] pools [`Memory`] buffers (a run recycles a
//! previous 16 MiB buffer instead of re-allocating) and executes
//! pre-translated [`session::CompiledImage`]s; `kernels::run` keys those
//! images by kernel spec so DSE sweeps and whole-model measurement
//! assemble + translate each kernel exactly once.

pub mod cluster;
pub mod engine;
pub mod mac_unit;
pub mod memory;
pub mod perf;
pub mod session;

use crate::isa::decode::decode;
use crate::isa::*;
use std::sync::Arc;
pub use cluster::{ClusterConfig, ClusterPerf, CoreSlice};
pub use engine::{CompiledProgram, EngineStats, TranslateOpts};
pub use mac_unit::{MacUnit, MacUnitConfig};
pub use memory::{MemFault, Memory};
pub use perf::PerfCounters;
pub use session::{CompiledImage, SimSession};

/// Per-instruction-class cycle costs (Ibex user manual, 2-stage pipeline,
/// single-cycle multiplier, 0-wait-state memories).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Timing {
    /// Integer ALU / LUI / AUIPC.
    pub alu: u32,
    /// CSR access.
    pub csr: u32,
    /// `mul` on the single-cycle multiplier.
    pub mul: u32,
    /// `mulh/mulhsu/mulhu` (2 cycles on the single-cycle multiplier).
    pub mulh: u32,
    /// `div/divu/rem/remu` (long division).
    pub div: u32,
    /// Load (address phase + response).
    pub load: u32,
    /// Store.
    pub store: u32,
    /// `jal`/`jalr` (pipeline refill).
    pub jump: u32,
    /// Taken conditional branch (flush + refill).
    pub branch_taken: u32,
    /// Not-taken conditional branch.
    pub branch_not_taken: u32,
    /// `fence` (no-op on this single-hart core).
    pub fence: u32,
}

impl Default for Timing {
    fn default() -> Self {
        Timing {
            alu: 1,
            csr: 1,
            mul: 1,
            mulh: 2,
            div: 37,
            load: 2,
            store: 2,
            jump: 2,
            branch_taken: 3,
            branch_not_taken: 1,
            fence: 1,
        }
    }
}

/// Why the simulation stopped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExitReason {
    /// `ecall` — normal program completion.
    Ecall,
    /// `ebreak` hit.
    Ebreak,
    /// Memory fault.
    Fault(MemFault),
    /// PC left the program image.
    IllegalPc(u32),
    /// Cycle budget exhausted.
    MaxCycles,
}

/// Core configuration.
#[derive(Debug, Clone, Copy)]
pub struct CoreConfig {
    /// Cycle-cost table.
    pub timing: Timing,
    /// Mixed-precision MAC datapath features.
    pub mac: MacUnitConfig,
    /// Data+program memory size in bytes.
    pub mem_size: usize,
}

impl Default for CoreConfig {
    fn default() -> Self {
        CoreConfig {
            timing: Timing::default(),
            mac: MacUnitConfig::full(),
            mem_size: 16 << 20, // 16 MiB — fits every scaled model's buffers
        }
    }
}

/// The simulated core.
pub struct Core {
    /// Architectural registers; `x0` is forced to zero on write.
    pub regs: [u32; NUM_REGS],
    /// Program counter (byte address).
    pub pc: u32,
    /// Data/program memory.
    pub mem: Memory,
    /// Performance counters.
    pub perf: PerfCounters,
    /// The mixed-precision MAC block.
    pub mac_unit: MacUnit,
    /// Micro-op-engine superinstruction hit counters for this core's
    /// runs (all-zero under the reference interpreter).
    pub engine_stats: EngineStats,
    timing: Timing,
    program: Arc<[Instr]>,
    prog_base: u32,
}

impl Core {
    /// Build a core with `program` pre-decoded at byte address `base`.
    pub fn new(cfg: CoreConfig, program: Vec<Instr>, base: u32) -> Self {
        let mut mem = Memory::new(cfg.mem_size);
        // Mirror the encoded program into memory so self-inspecting
        // programs (and the disassembler) see real bytes.
        let words = crate::isa::encode::encode_program(&program);
        mem.write_words(base, &words);
        Self::with_memory(cfg, Arc::from(program), base, mem)
    }

    /// Build a core around a shared program and an existing (possibly
    /// recycled) memory. The caller is responsible for staging the
    /// program image in `mem` — [`session::SimSession`] writes the
    /// pre-encoded words once per checkout instead of re-encoding.
    pub fn with_memory(cfg: CoreConfig, program: Arc<[Instr]>, base: u32, mem: Memory) -> Self {
        Core {
            regs: [0; NUM_REGS],
            pc: base,
            mem,
            perf: PerfCounters::default(),
            mac_unit: MacUnit::new(cfg.mac),
            engine_stats: EngineStats::default(),
            timing: cfg.timing,
            program,
            prog_base: base,
        }
    }

    /// Tear the core down, recovering its memory for pooling.
    pub fn into_memory(self) -> Memory {
        self.mem
    }

    /// Build a core from raw machine words (exercises the decoder path).
    pub fn from_words(cfg: CoreConfig, words: &[u32], base: u32) -> Result<Self, decode::DecodeError> {
        let program = words.iter().map(|&w| decode(w)).collect::<Result<Vec<_>, _>>()?;
        Ok(Self::new(cfg, program, base))
    }

    /// Translate this core's program for the micro-op engine. The
    /// result is tied to the program + link base + timing table, not to
    /// this core's architectural state, so it can be shared by any
    /// number of cores running the same program.
    pub fn compile(&self) -> engine::CompiledProgram {
        engine::CompiledProgram::translate(&self.program, self.prog_base, self.timing)
    }

    /// Run on the micro-op engine until halt or `max_cycles`.
    /// Observationally identical to [`Core::run`] (the equivalence is
    /// property-tested), several-fold faster on kernel workloads.
    pub fn run_engine(&mut self, cp: &engine::CompiledProgram, max_cycles: u64) -> ExitReason {
        engine::run(self, cp, max_cycles)
    }

    #[inline]
    fn write_reg(&mut self, rd: Reg, val: u32) {
        if rd != 0 {
            self.regs[rd as usize] = val;
        }
    }

    /// Execute one instruction; returns `Some(reason)` if the core halts.
    #[inline]
    pub fn step(&mut self) -> Option<ExitReason> {
        let idx = self.pc.wrapping_sub(self.prog_base) / 4;
        let Some(&instr) = self.program.get(idx as usize) else {
            return Some(ExitReason::IllegalPc(self.pc));
        };
        let t = self.timing;
        let mut next_pc = self.pc.wrapping_add(4);
        let mut cycles = 0u32;

        match instr {
            Instr::Lui { rd, imm } => {
                self.write_reg(rd, imm as u32);
                cycles += t.alu;
            }
            Instr::Auipc { rd, imm } => {
                self.write_reg(rd, self.pc.wrapping_add(imm as u32));
                cycles += t.alu;
            }
            Instr::Jal { rd, offset } => {
                self.write_reg(rd, next_pc);
                next_pc = self.pc.wrapping_add(offset as u32);
                cycles += t.jump;
            }
            Instr::Jalr { rd, rs1, offset } => {
                let target = self.regs[rs1 as usize].wrapping_add(offset as u32) & !1;
                self.write_reg(rd, next_pc);
                next_pc = target;
                cycles += t.jump;
            }
            Instr::Branch { op, rs1, rs2, offset } => {
                let a = self.regs[rs1 as usize];
                let b = self.regs[rs2 as usize];
                let taken = match op {
                    BranchOp::Beq => a == b,
                    BranchOp::Bne => a != b,
                    BranchOp::Blt => (a as i32) < (b as i32),
                    BranchOp::Bge => (a as i32) >= (b as i32),
                    BranchOp::Bltu => a < b,
                    BranchOp::Bgeu => a >= b,
                };
                if taken {
                    next_pc = self.pc.wrapping_add(offset as u32);
                    cycles += t.branch_taken;
                    self.perf.taken_branches += 1;
                } else {
                    cycles += t.branch_not_taken;
                }
            }
            Instr::Load { op, rd, rs1, offset } => {
                let addr = self.regs[rs1 as usize].wrapping_add(offset as u32);
                let (width, sign) = match op {
                    LoadOp::Lb => (1, true),
                    LoadOp::Lh => (2, true),
                    LoadOp::Lw => (4, false),
                    LoadOp::Lbu => (1, false),
                    LoadOp::Lhu => (2, false),
                };
                match self.mem.load(addr, width) {
                    Ok(raw) => {
                        let val = if sign {
                            match width {
                                1 => raw as u8 as i8 as i32 as u32,
                                2 => raw as u16 as i16 as i32 as u32,
                                _ => raw,
                            }
                        } else {
                            raw
                        };
                        self.write_reg(rd, val);
                        self.perf.loads += 1;
                        cycles += t.load;
                    }
                    Err(f) => return Some(ExitReason::Fault(f)),
                }
            }
            Instr::Store { op, rs1, rs2, offset } => {
                let addr = self.regs[rs1 as usize].wrapping_add(offset as u32);
                let width = match op {
                    StoreOp::Sb => 1,
                    StoreOp::Sh => 2,
                    StoreOp::Sw => 4,
                };
                match self.mem.store(addr, width, self.regs[rs2 as usize]) {
                    Ok(()) => {
                        self.perf.stores += 1;
                        cycles += t.store;
                    }
                    Err(f) => return Some(ExitReason::Fault(f)),
                }
            }
            Instr::OpImm { op, rd, rs1, imm } => {
                let a = self.regs[rs1 as usize];
                let b = imm as u32;
                self.write_reg(rd, alu_eval(op, a, b));
                cycles += t.alu;
            }
            Instr::Op { op, rd, rs1, rs2 } => {
                let a = self.regs[rs1 as usize];
                let b = self.regs[rs2 as usize];
                self.write_reg(rd, alu_eval(op, a, b));
                cycles += t.alu;
            }
            Instr::MulDiv { op, rd, rs1, rs2 } => {
                let a = self.regs[rs1 as usize];
                let b = self.regs[rs2 as usize];
                let (val, c) = match op {
                    MulOp::Mul => (a.wrapping_mul(b), t.mul),
                    MulOp::Mulh => {
                        ((((a as i32 as i64) * (b as i32 as i64)) >> 32) as u32, t.mulh)
                    }
                    MulOp::Mulhsu => ((((a as i32 as i64) * (b as i64)) >> 32) as u32, t.mulh),
                    MulOp::Mulhu => ((((a as u64) * (b as u64)) >> 32) as u32, t.mulh),
                    MulOp::Div => {
                        let (a, b) = (a as i32, b as i32);
                        let q = if b == 0 {
                            -1
                        } else if a == i32::MIN && b == -1 {
                            i32::MIN
                        } else {
                            a.wrapping_div(b)
                        };
                        (q as u32, t.div)
                    }
                    MulOp::Divu => (if b == 0 { u32::MAX } else { a / b }, t.div),
                    MulOp::Rem => {
                        let (a, b) = (a as i32, b as i32);
                        let r = if b == 0 {
                            a
                        } else if a == i32::MIN && b == -1 {
                            0
                        } else {
                            a.wrapping_rem(b)
                        };
                        (r as u32, t.div)
                    }
                    MulOp::Remu => (if b == 0 { a } else { a % b }, t.div),
                };
                self.write_reg(rd, val);
                self.perf.muldiv_instrs += 1;
                if op == MulOp::Mul {
                    // One scalar MAC's multiply — counted so baseline and
                    // extended kernels share the MACs metric.
                    self.perf.macs += 1;
                    self.mac_unit.total_macs += 1;
                }
                cycles += c;
            }
            Instr::NnMac { mode, rd, rs1, rs2 } => {
                let k = mode.activation_regs() as usize;
                debug_assert!(
                    (rs1 as usize) + k <= NUM_REGS,
                    "nn_mac activation register group overruns the register file"
                );
                let mut acts = [0u32; 4];
                for (i, slot) in acts.iter_mut().enumerate().take(k) {
                    *slot = self.regs[rs1 as usize + i];
                }
                let issue =
                    self.mac_unit.issue(mode, self.regs[rd as usize], &acts[..k], self.regs[rs2 as usize]);
                self.write_reg(rd, issue.acc);
                self.perf.macs += issue.macs as u64;
                self.perf.nn_mac_instrs += 1;
                cycles += issue.cycles;
            }
            Instr::Csr { op, rd, rs1, csr } => {
                // Counters are read-only here; writes are accepted and
                // ignored (enough for rdcycle-style measurement reads).
                let _ = (op, rs1);
                let val = self.perf.read_csr(csr);
                self.write_reg(rd, val);
                cycles += t.csr;
            }
            Instr::Fence => cycles += t.fence,
            Instr::Ecall => {
                self.perf.cycles += 1;
                self.perf.instret += 1;
                return Some(ExitReason::Ecall);
            }
            Instr::Ebreak => {
                self.perf.cycles += 1;
                self.perf.instret += 1;
                return Some(ExitReason::Ebreak);
            }
        }

        self.perf.cycles += cycles as u64;
        self.perf.instret += 1;
        self.pc = next_pc;
        None
    }

    /// Run until halt or `max_cycles`.
    pub fn run(&mut self, max_cycles: u64) -> ExitReason {
        loop {
            if let Some(reason) = self.step() {
                return reason;
            }
            if self.perf.cycles >= max_cycles {
                return ExitReason::MaxCycles;
            }
        }
    }

    /// Run with a per-instruction trace callback `(pc, instr)`.
    pub fn run_traced<F: FnMut(u32, Instr)>(&mut self, max_cycles: u64, mut f: F) -> ExitReason {
        loop {
            let idx = self.pc.wrapping_sub(self.prog_base) / 4;
            if let Some(&instr) = self.program.get(idx as usize) {
                f(self.pc, instr);
            }
            if let Some(reason) = self.step() {
                return reason;
            }
            if self.perf.cycles >= max_cycles {
                return ExitReason::MaxCycles;
            }
        }
    }

    /// Program length in instructions.
    pub fn program_len(&self) -> usize {
        self.program.len()
    }
}

#[inline]
fn alu_eval(op: AluOp, a: u32, b: u32) -> u32 {
    match op {
        AluOp::Add => a.wrapping_add(b),
        AluOp::Sub => a.wrapping_sub(b),
        AluOp::Sll => a.wrapping_shl(b & 31),
        AluOp::Slt => ((a as i32) < (b as i32)) as u32,
        AluOp::Sltu => (a < b) as u32,
        AluOp::Xor => a ^ b,
        AluOp::Srl => a.wrapping_shr(b & 31),
        AluOp::Sra => ((a as i32).wrapping_shr(b & 31)) as u32,
        AluOp::Or => a | b,
        AluOp::And => a & b,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::custom::{pack_acts, pack_weights};

    fn run_program(prog: Vec<Instr>) -> Core {
        let mut core = Core::new(CoreConfig { mem_size: 4096, ..Default::default() }, prog, 0);
        assert_eq!(core.run(1_000_000), ExitReason::Ecall);
        core
    }

    #[test]
    fn arithmetic_and_halt() {
        let core = run_program(vec![
            Instr::OpImm { op: AluOp::Add, rd: 10, rs1: 0, imm: 21 },
            Instr::OpImm { op: AluOp::Add, rd: 11, rs1: 0, imm: 21 },
            Instr::Op { op: AluOp::Add, rd: 12, rs1: 10, rs2: 11 },
            Instr::Ecall,
        ]);
        assert_eq!(core.regs[12], 42);
        assert_eq!(core.perf.instret, 4);
        // 3 × ALU (1 cycle) + ecall (1 cycle)
        assert_eq!(core.perf.cycles, 4);
    }

    #[test]
    fn x0_is_immutable() {
        let core = run_program(vec![
            Instr::OpImm { op: AluOp::Add, rd: 0, rs1: 0, imm: 99 },
            Instr::Ecall,
        ]);
        assert_eq!(core.regs[0], 0);
    }

    #[test]
    fn loads_sign_extend_and_count() {
        let mut core = Core::new(
            CoreConfig { mem_size: 4096, ..Default::default() },
            vec![
                Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 1024 },
                Instr::Load { op: LoadOp::Lb, rd: 10, rs1: 5, offset: 0 },
                Instr::Load { op: LoadOp::Lbu, rd: 11, rs1: 5, offset: 0 },
                Instr::Ecall,
            ],
            0,
        );
        core.mem.write_i8(1024, &[-5]);
        assert_eq!(core.run(1000), ExitReason::Ecall);
        assert_eq!(core.regs[10] as i32, -5);
        assert_eq!(core.regs[11], 0xfb);
        assert_eq!(core.perf.loads, 2);
    }

    #[test]
    fn branch_timing_taken_vs_not() {
        // beq x0,x0 (taken, 3 cycles) vs bne x0,x0 (not taken, 1 cycle).
        let core = run_program(vec![
            Instr::Branch { op: BranchOp::Bne, rs1: 0, rs2: 0, offset: 8 }, // not taken: 1
            Instr::Branch { op: BranchOp::Beq, rs1: 0, rs2: 0, offset: 8 }, // taken: 3
            Instr::Ebreak,                                                  // skipped
            Instr::Ecall,                                                   // 1
        ]);
        assert_eq!(core.perf.cycles, 1 + 3 + 1);
        assert_eq!(core.perf.taken_branches, 1);
    }

    #[test]
    fn division_semantics_riscv_edge_cases() {
        let core = run_program(vec![
            Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 7 },
            // div by zero -> -1 ; rem by zero -> dividend
            Instr::MulDiv { op: MulOp::Div, rd: 10, rs1: 5, rs2: 0 },
            Instr::MulDiv { op: MulOp::Rem, rd: 11, rs1: 5, rs2: 0 },
            // i32::MIN / -1 -> i32::MIN ; rem -> 0
            Instr::Lui { rd: 6, imm: i32::MIN },
            Instr::OpImm { op: AluOp::Add, rd: 7, rs1: 0, imm: -1 },
            Instr::MulDiv { op: MulOp::Div, rd: 12, rs1: 6, rs2: 7 },
            Instr::MulDiv { op: MulOp::Rem, rd: 13, rs1: 6, rs2: 7 },
            Instr::Ecall,
        ]);
        assert_eq!(core.regs[10] as i32, -1);
        assert_eq!(core.regs[11] as i32, 7);
        assert_eq!(core.regs[12] as i32, i32::MIN);
        assert_eq!(core.regs[13], 0);
    }

    #[test]
    fn nn_mac_executes_with_register_group() {
        // Mode-2: activations in (x11, x12), weights in x13, acc in x10.
        let a0 = pack_acts([1, 2, 3, 4]);
        let a1 = pack_acts([5, 6, 7, 8]);
        let w = pack_weights(MacMode::W4, &[1, 1, 1, 1, 2, 2, 2, 2]);
        let mut core = Core::new(
            CoreConfig { mem_size: 4096, ..Default::default() },
            vec![Instr::NnMac { mode: MacMode::W4, rd: 10, rs1: 11, rs2: 13 }, Instr::Ecall],
            0,
        );
        core.regs[10] = 100;
        core.regs[11] = a0;
        core.regs[12] = a1;
        core.regs[13] = w;
        assert_eq!(core.run(1000), ExitReason::Ecall);
        // 100 + (1+2+3+4)·1 + (5+6+7+8)·2 = 100 + 10 + 52 = 162
        assert_eq!(core.regs[10], 162);
        assert_eq!(core.perf.macs, 8);
        assert_eq!(core.perf.nn_mac_instrs, 1);
        // full config: single cycle + ecall
        assert_eq!(core.perf.cycles, 2);
    }

    #[test]
    fn csr_reads_cycle_counter() {
        let core = run_program(vec![
            Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 1 },
            Instr::Csr { op: CsrOp::Rs, rd: 10, rs1: 0, csr: csr::MCYCLE },
            Instr::Ecall,
        ]);
        // addi retired 1 cycle before the csr read observed it.
        assert_eq!(core.regs[10], 1);
    }

    #[test]
    fn halts_on_cycle_budget() {
        // Infinite loop.
        let mut core = Core::new(
            CoreConfig { mem_size: 4096, ..Default::default() },
            vec![Instr::Jal { rd: 0, offset: 0 }],
            0,
        );
        assert_eq!(core.run(100), ExitReason::MaxCycles);
    }

    #[test]
    fn fault_on_bad_memory() {
        let mut core = Core::new(
            CoreConfig { mem_size: 64, ..Default::default() },
            vec![Instr::Load { op: LoadOp::Lw, rd: 10, rs1: 0, offset: 60 }, Instr::Ecall],
            0,
        );
        core.regs[0] = 0; // base 0 + 60 aligned, but width 4 reaches 64: ok boundary
        assert_eq!(core.run(100), ExitReason::Ecall);
        let mut core = Core::new(
            CoreConfig { mem_size: 64, ..Default::default() },
            vec![Instr::Load { op: LoadOp::Lw, rd: 10, rs1: 0, offset: 64 }, Instr::Ecall],
            0,
        );
        assert!(matches!(core.run(100), ExitReason::Fault(_)));
    }
}
