//! Reusable simulation sessions: pooled memories + pre-translated
//! program images.
//!
//! Before this layer existed, every kernel invocation allocated a fresh
//! 16 MiB [`Memory`], re-encoded the program into it and re-walked the
//! decoded stream — for a DSE sweep that is thousands of identical
//! setups. A [`SimSession`] amortises all of it:
//!
//! * [`CompiledImage`] bundles a shared decoded program, its encoded
//!   word image and its [`engine::CompiledProgram`] translation —
//!   built once per kernel (see the keyed cache in `kernels::run`).
//! * The session's **memory pool** recycles simulator memories across
//!   runs: [`Memory::reset_for_reuse`] zeroes only the bytes the
//!   previous tenant dirtied and reinstates the exact logical size, so
//!   fault behaviour is indistinguishable from a fresh allocation.
//! * [`SimSession::execute`] stitches the two together: checkout →
//!   stage image → stage operands → run on the micro-op engine → read
//!   results → return the memory to the pool.
//!
//! The session is `Sync`; the DSE/coordinator worker pools share one
//! global instance ([`SimSession::global`]).

use super::engine::{CompiledProgram, EngineStats, TranslateOpts};
use super::mac_unit::MacUnitConfig;
use super::perf::PerfCounters;
use super::{engine, Core, CoreConfig, ExitReason, Memory, Timing};
use crate::isa::{Instr, MacMode};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A program prepared for repeated execution: decoded instructions
/// (shared, for the reference interpreter / tracing), the encoded word
/// image (staged into memory on each run) and the micro-op translation.
#[derive(Debug, Clone)]
pub struct CompiledImage {
    /// Decoded program (shared with every core that runs it).
    pub prog: Arc<[Instr]>,
    /// Encoded machine words mirrored into simulator memory.
    pub words: Vec<u32>,
    /// Micro-op translation (engine fast path).
    pub compiled: CompiledProgram,
    /// Link base address.
    pub base: u32,
    /// The cycle-cost table the translation baked in. Executions under
    /// a *different* `CoreConfig::timing` must not use the micro-op
    /// path — [`SimSession::execute_backend`] checks and falls back to
    /// the reference interpreter, which always reads the live table.
    pub timing: Timing,
}

impl CompiledImage {
    /// Assemble an image from a decoded program under `timing`.
    pub fn new(prog: Vec<Instr>, base: u32, timing: Timing) -> Self {
        Self::new_with_opts(prog, base, timing, TranslateOpts::default())
    }

    /// [`CompiledImage::new`] with explicit engine translation options —
    /// the throughput bench builds images of older fusion generations
    /// to report the per-PR engine trajectory.
    pub fn new_with_opts(
        prog: Vec<Instr>,
        base: u32,
        timing: Timing,
        opts: TranslateOpts,
    ) -> Self {
        let words = crate::isa::encode::encode_program(&prog);
        let compiled = CompiledProgram::translate_with(&prog, base, timing, opts);
        CompiledImage { prog: Arc::from(prog), words, compiled, base, timing }
    }
}

/// Atomic accumulation of [`EngineStats`] across runs — the
/// session-wide view of which superinstruction classes fire (printed
/// by the `iss_throughput` bench).
#[derive(Debug, Default)]
pub struct EngineHitTotals {
    load_mac: AtomicU64,
    scalar_mac: AtomicU64,
    latch: AtomicU64,
    requant: AtomicU64,
    counted_loops: AtomicU64,
    counted_iters: AtomicU64,
    fallbacks: AtomicU64,
}

impl EngineHitTotals {
    /// Fold one run's counters in (lock-free).
    pub fn absorb(&self, s: &EngineStats) {
        self.load_mac.fetch_add(s.load_mac, Ordering::Relaxed);
        self.scalar_mac.fetch_add(s.scalar_mac, Ordering::Relaxed);
        self.latch.fetch_add(s.latch, Ordering::Relaxed);
        self.requant.fetch_add(s.requant, Ordering::Relaxed);
        self.counted_loops.fetch_add(s.counted_loops, Ordering::Relaxed);
        self.counted_iters.fetch_add(s.counted_iters, Ordering::Relaxed);
        self.fallbacks.fetch_add(s.fallbacks, Ordering::Relaxed);
    }

    /// Snapshot the totals as a plain [`EngineStats`].
    pub fn snapshot(&self) -> EngineStats {
        EngineStats {
            load_mac: self.load_mac.load(Ordering::Relaxed),
            scalar_mac: self.scalar_mac.load(Ordering::Relaxed),
            latch: self.latch.load(Ordering::Relaxed),
            requant: self.requant.load(Ordering::Relaxed),
            counted_loops: self.counted_loops.load(Ordering::Relaxed),
            counted_iters: self.counted_iters.load(Ordering::Relaxed),
            fallbacks: self.fallbacks.load(Ordering::Relaxed),
        }
    }
}

/// Shape half of a [`CostKey`]: every field of the kernel builder's
/// cache key except the packing mode (mirrors the private `KernelKey`
/// in `kernels::run` — two executions with equal shapes run the same
/// program text).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KernelShape {
    /// Dense / fully-connected layer.
    Dense {
        /// Input features.
        in_dim: usize,
        /// Output features.
        out_dim: usize,
        /// Requant multiplier.
        m: i32,
        /// Requant shift.
        shift: i32,
        /// ReLU fused into the requant epilogue.
        relu: bool,
        /// Raw 32-bit accumulators requested (logits layer).
        out_i32: bool,
    },
    /// im2col convolution.
    Conv {
        /// Padded input height.
        h: usize,
        /// Padded input width.
        w: usize,
        /// Input channels (lane-padded when a packing mode is active).
        cin: usize,
        /// Output channels.
        cout: usize,
        /// Kernel size.
        k: usize,
        /// Stride.
        stride: usize,
        /// Requant multiplier.
        m: i32,
        /// Requant shift.
        shift: i32,
        /// Fused ReLU.
        relu: bool,
    },
    /// Depthwise convolution.
    Dw {
        /// Padded input height.
        h: usize,
        /// Padded input width.
        w: usize,
        /// Channels.
        c: usize,
        /// Kernel size.
        k: usize,
        /// Stride.
        stride: usize,
        /// Requant multiplier.
        m: i32,
        /// Requant shift.
        shift: i32,
        /// Fused ReLU.
        relu: bool,
    },
}

/// Key of the analytic cost cache: the kernel's shape, its packing
/// mode, and the MAC-unit configuration. Since PR 3 made kernel timing
/// fully data-independent (branchless requant, counted strip loops),
/// the [`PerfCounters`] of a kernel execution are a pure function of
/// this triple — `dse/cycles.rs` documents the contract; the analytic
/// backend makes it load-bearing and the sampled audit enforces it.
///
/// Unlike the kernel-image cache (which deliberately omits
/// [`MacUnitConfig`] because the *program* is identical across Fig. 7
/// ablations), the cost key must include it: multi-pumping and soft
/// SIMD change cycle counts without changing a single instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CostKey {
    /// Kernel shape (geometry + requant constants).
    pub shape: KernelShape,
    /// Packing mode (`None` = byte-weight baseline).
    pub mode: Option<MacMode>,
    /// Datapath feature toggles.
    pub mac: MacUnitConfig,
}

/// Session-level analytic cost cache: the measured [`PerfCounters`] of
/// every kernel execution shape the process has run on the ISS, shared
/// across plans and with `dse/cycles.rs::CycleModel::build` so the
/// per-layer table and whole-model analytic runs can never disagree.
///
/// `insert` overwrites — last measurement wins. That is sound because
/// equal keys imply equal counters (data-independent timing), and it is
/// exactly the hook the audit tests use to inject a perturbation and
/// prove a poisoned cache fails typed, never silently.
#[derive(Debug, Default)]
pub struct CostCache {
    map: Mutex<HashMap<CostKey, PerfCounters>>,
}

impl CostCache {
    /// Cached counters for `key`, if any.
    pub fn get(&self, key: &CostKey) -> Option<PerfCounters> {
        self.map.lock().unwrap().get(key).copied()
    }

    /// Record (or overwrite) the counters measured for `key`.
    pub fn insert(&self, key: CostKey, perf: PerfCounters) {
        self.map.lock().unwrap().insert(key, perf);
    }

    /// Distinct kernel shapes measured so far.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// True when nothing has been measured yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Counters for observability (hit rates show up in bench output).
#[derive(Debug, Default)]
pub struct SessionStats {
    /// Memories handed out from the pool.
    pub mem_reuses: AtomicU64,
    /// Memories freshly allocated.
    pub mem_allocs: AtomicU64,
    /// Engine executions completed.
    pub runs: AtomicU64,
    /// Cumulative superinstruction hits across engine runs.
    pub engine: EngineHitTotals,
    /// Execution plans compiled — one per distinct
    /// `(model, config, modes)` the process ever lowered (see
    /// [`crate::models::plan::plan_for`]). A DSE sweep compiles each
    /// configuration exactly once; everything else is a `plan_hits`.
    pub plan_compiles: AtomicU64,
    /// Plan-cache hits: replays of an already-compiled plan (batch
    /// inputs, the host differential check, repeated configs).
    ///
    /// The plan counters are process-local observability and are
    /// deliberately **not** part of [`SessionSnapshot`] — the shard
    /// artifact schema stays at its current version.
    pub plan_hits: AtomicU64,
    /// Analytic cost-cache hits: kernel steps (and cycle-model
    /// measurements) whose counters came from [`CostCache`] instead of
    /// an ISS execution — how much simulation the sweep skipped.
    ///
    /// Like the plan counters, the analytic trio below is process-local
    /// observability, excluded from [`SessionSnapshot`] so the shard
    /// artifact schema stays at its current version.
    pub analytic_hits: AtomicU64,
    /// Sampled differential audits executed (`--audit-every K`): batch
    /// elements replayed on the real ISS and bit-compared.
    pub analytic_audits: AtomicU64,
    /// Audits whose ISS replay disagreed with the analytic path. Any
    /// nonzero value means the data-independence contract broke (or a
    /// test injected a perturbation); the run fails with a typed error.
    pub audit_mismatches: AtomicU64,
}

/// Plain-value snapshot of [`SessionStats`] — the unit the sharded DSE
/// sweep serialises into its shard artifacts: a runner snapshots the
/// global session before and after its sweep and records the delta, so
/// the merged totals of N shards add up to exactly one sweep's worth of
/// activity regardless of what else the process ran.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SessionSnapshot {
    /// Memories handed out from the pool.
    pub mem_reuses: u64,
    /// Memories freshly allocated.
    pub mem_allocs: u64,
    /// Engine executions completed.
    pub runs: u64,
    /// Superinstruction hits.
    pub engine: EngineStats,
}

impl SessionSnapshot {
    /// Difference against an `earlier` snapshot of the same monotone
    /// counters (saturating).
    pub fn delta_since(&self, earlier: &SessionSnapshot) -> SessionSnapshot {
        SessionSnapshot {
            mem_reuses: self.mem_reuses.saturating_sub(earlier.mem_reuses),
            mem_allocs: self.mem_allocs.saturating_sub(earlier.mem_allocs),
            runs: self.runs.saturating_sub(earlier.runs),
            engine: self.engine.delta_since(&earlier.engine),
        }
    }

    /// Elementwise accumulate (the shard merger sums these).
    pub fn add(&mut self, o: &SessionSnapshot) {
        self.mem_reuses += o.mem_reuses;
        self.mem_allocs += o.mem_allocs;
        self.runs += o.runs;
        self.engine.add(&o.engine);
    }
}

impl SessionStats {
    /// Capture the counters as plain values.
    pub fn snapshot(&self) -> SessionSnapshot {
        SessionSnapshot {
            mem_reuses: self.mem_reuses.load(Ordering::Relaxed),
            mem_allocs: self.mem_allocs.load(Ordering::Relaxed),
            runs: self.runs.load(Ordering::Relaxed),
            engine: self.engine.snapshot(),
        }
    }
}

/// A pool of simulator memories + the execution entry point.
#[derive(Debug, Default)]
pub struct SimSession {
    pool: Mutex<Vec<Memory>>,
    /// Usage counters.
    pub stats: SessionStats,
    /// Analytic per-kernel cost cache (see [`CostCache`]).
    pub costs: CostCache,
}

/// Keep at most this many idle memories around (bounds resident RAM at
/// a few × the largest model footprint while letting a worker pool run
/// fully in parallel).
const MAX_POOLED: usize = 16;

impl SimSession {
    /// Fresh session with an empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// The process-wide session shared by the kernel runners and the
    /// DSE / coordinator worker pools.
    pub fn global() -> &'static SimSession {
        static GLOBAL: OnceLock<SimSession> = OnceLock::new();
        GLOBAL.get_or_init(SimSession::new)
    }

    /// Check a memory of logical size `size` out of the pool (recycled
    /// and zeroed) or allocate a fresh one.
    pub fn checkout(&self, size: usize) -> Memory {
        let recycled = self.pool.lock().unwrap().pop();
        match recycled {
            Some(mut m) => {
                m.reset_for_reuse(size);
                self.stats.mem_reuses.fetch_add(1, Ordering::Relaxed);
                m
            }
            None => {
                self.stats.mem_allocs.fetch_add(1, Ordering::Relaxed);
                Memory::new(size)
            }
        }
    }

    /// Return a memory to the pool for later reuse.
    pub fn checkin(&self, mem: Memory) {
        let mut pool = self.pool.lock().unwrap();
        if pool.len() < MAX_POOLED {
            pool.push(mem);
        }
    }

    /// Idle memories currently pooled.
    pub fn pooled(&self) -> usize {
        self.pool.lock().unwrap().len()
    }

    /// Execute `image` on a pooled core: checkout memory, stage the
    /// program image, let `stage` fill operand buffers, run on the
    /// micro-op engine, hand the finished core to `read`, and recycle
    /// the memory. Returns `read`'s value and the exit reason.
    pub fn execute<T>(
        &self,
        cfg: CoreConfig,
        image: &CompiledImage,
        stage: impl FnOnce(&mut Core),
        read: impl FnOnce(&Core) -> T,
    ) -> (T, ExitReason) {
        self.execute_backend(cfg, image, true, stage, read)
    }

    /// [`SimSession::execute`] with an explicit interpreter choice:
    /// `use_engine = false` runs the reference interpreter instead of
    /// the micro-op engine (the bench harness measures the gap; the
    /// equivalence property test pins the semantics).
    pub fn execute_backend<T>(
        &self,
        cfg: CoreConfig,
        image: &CompiledImage,
        use_engine: bool,
        stage: impl FnOnce(&mut Core),
        read: impl FnOnce(&Core) -> T,
    ) -> (T, ExitReason) {
        let mut mem = self.checkout(cfg.mem_size);
        mem.write_words(image.base, &image.words);
        let mut core = Core::with_memory(cfg, image.prog.clone(), image.base, mem);
        stage(&mut core);
        core.mem.reset_counters(); // measure only the kernel's own traffic
        // The translation baked the image's timing table into its cycle
        // costs; a mismatched CoreConfig must take the reference path.
        let reason = if use_engine && cfg.timing == image.timing {
            engine::run(&mut core, &image.compiled, u64::MAX)
        } else {
            core.run(u64::MAX)
        };
        self.stats.runs.fetch_add(1, Ordering::Relaxed);
        self.stats.engine.absorb(&core.engine_stats);
        let out = read(&core);
        self.checkin(core.into_memory());
        (out, reason)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{reg, AluOp, MacMode};

    fn store42_image() -> CompiledImage {
        // x5 = 42 ; sw 256(x0), x5 ; ecall
        let prog = vec![
            Instr::OpImm { op: AluOp::Add, rd: reg::T0, rs1: 0, imm: 42 },
            Instr::Store { op: crate::isa::StoreOp::Sw, rs1: 0, rs2: reg::T0, offset: 256 },
            Instr::Ecall,
        ];
        CompiledImage::new(prog, 0, Timing::default())
    }

    #[test]
    fn execute_runs_and_recycles_memory() {
        let s = SimSession::new();
        let image = store42_image();
        let cfg = CoreConfig { mem_size: 4096, ..Default::default() };
        for round in 0..3 {
            let (val, reason) = s.execute(
                cfg,
                &image,
                |_| {},
                |core| core.mem.read_i32(256, 1)[0],
            );
            assert_eq!(reason, ExitReason::Ecall, "round {round}");
            // The recycled memory must be zeroed between tenants, so
            // the observed value always comes from this run.
            assert_eq!(val, 42, "round {round}");
        }
        assert_eq!(s.stats.mem_allocs.load(Ordering::Relaxed), 1);
        assert_eq!(s.stats.mem_reuses.load(Ordering::Relaxed), 2);
        assert_eq!(s.pooled(), 1);
    }

    #[test]
    fn image_translation_fuses_kernel_strips() {
        // A dense mode kernel must contain fused LoadMac strips.
        let spec = crate::kernels::dense::DenseSpec {
            in_dim: 64,
            out_dim: 4,
            rq: crate::nn::quant::Requant::from_real_scale(0.01),
            relu: true,
            out_i32: false,
        };
        let kp = crate::kernels::dense::build_mode(MacMode::W2, spec);
        let image =
            CompiledImage::new(kp.prog.clone(), crate::kernels::PROG_BASE, Timing::default());
        assert!(image.compiled.is_clean());
        assert!(
            image.compiled.fused_instr_count() > kp.prog.len() / 2,
            "expected the unrolled inner strips to fuse: {} of {}",
            image.compiled.fused_instr_count(),
            kp.prog.len()
        );
        // The requant epilogue must fuse too (one per output feature).
        let census = image.compiled.fusion_census();
        assert!(census[0] > 0, "no LoadMac strips fused: {census:?}");
        assert!(census[3] > 0, "no Requant epilogues fused: {census:?}");
        // A v1 translation of the same program has no requant fusion.
        let v1 = CompiledImage::new_with_opts(
            kp.prog,
            crate::kernels::PROG_BASE,
            Timing::default(),
            super::TranslateOpts::v1(),
        );
        assert_eq!(v1.compiled.fusion_census()[3], 0);
        assert_eq!(v1.compiled.fusion_census()[4], 0);

        // Executing through a session aggregates the hit counters.
        let s = SimSession::new();
        let cfg = CoreConfig {
            mem_size: crate::kernels::DATA_BASE as usize + 8192,
            ..Default::default()
        };
        let (_, reason) = s.execute(cfg, &image, |_| {}, |_| ());
        assert_eq!(reason, ExitReason::Ecall);
        let hits = s.stats.engine.snapshot();
        assert!(hits.requant > 0, "session never saw a Requant hit: {hits:?}");
        assert!(hits.load_mac > 0, "session never saw a LoadMac hit: {hits:?}");
        assert_eq!(hits.fallbacks, 0, "kernel run must not fall back: {hits:?}");
    }

    #[test]
    fn parallel_checkouts_are_independent() {
        let s = SimSession::new();
        let image = Arc::new(store42_image());
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let s = &s;
                let image = Arc::clone(&image);
                scope.spawn(move || {
                    for _ in 0..8 {
                        let cfg = CoreConfig { mem_size: 4096, ..Default::default() };
                        let (val, reason) =
                            s.execute(cfg, &image, |_| {}, |c| c.mem.read_i32(256, 1)[0]);
                        assert_eq!(reason, ExitReason::Ecall);
                        assert_eq!(val, 42);
                    }
                });
            }
        });
        assert!(s.pooled() <= 4);
    }
}
