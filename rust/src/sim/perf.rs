//! Performance counters mirroring Ibex's `mcycle`/`minstret`/`mhpmcounter`
//! CSRs — the measurement interface every experiment harness reads
//! (the paper reads the same counters through Verilator).

/// Counter snapshot.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PerfCounters {
    /// Core-clock cycles (`mcycle`).
    pub cycles: u64,
    /// Retired instructions (`minstret`).
    pub instret: u64,
    /// Data loads issued (`mhpmcounter3`).
    pub loads: u64,
    /// Data stores issued (`mhpmcounter4`).
    pub stores: u64,
    /// MAC operations retired, scalar `mul`-based and `nn_mac` packed
    /// alike (`mhpmcounter5`).
    pub macs: u64,
    /// `nn_mac_*` instructions retired.
    pub nn_mac_instrs: u64,
    /// Taken branches (pipeline-flush events).
    pub taken_branches: u64,
    /// Multiply/divide instructions retired.
    pub muldiv_instrs: u64,
}

impl PerfCounters {
    /// Memory accesses (loads + stores) — the Fig. 4 metric.
    pub fn mem_accesses(&self) -> u64 {
        self.loads + self.stores
    }

    /// Instructions per cycle.
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.instret as f64 / self.cycles as f64
        }
    }

    /// MACs per cycle — the throughput the ISA extension multiplies.
    pub fn macs_per_cycle(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.macs as f64 / self.cycles as f64
        }
    }

    /// Difference of two snapshots (measurement window).
    pub fn delta(&self, earlier: &PerfCounters) -> PerfCounters {
        PerfCounters {
            cycles: self.cycles - earlier.cycles,
            instret: self.instret - earlier.instret,
            loads: self.loads - earlier.loads,
            stores: self.stores - earlier.stores,
            macs: self.macs - earlier.macs,
            nn_mac_instrs: self.nn_mac_instrs - earlier.nn_mac_instrs,
            taken_branches: self.taken_branches - earlier.taken_branches,
            muldiv_instrs: self.muldiv_instrs - earlier.muldiv_instrs,
        }
    }

    /// CSR read mapping (see [`crate::isa::csr`]).
    pub fn read_csr(&self, csr: u16) -> u32 {
        use crate::isa::csr::*;
        match csr {
            MCYCLE => self.cycles as u32,
            MCYCLEH => (self.cycles >> 32) as u32,
            MINSTRET => self.instret as u32,
            MINSTRETH => (self.instret >> 32) as u32,
            MHPM_LOADS => self.loads as u32,
            MHPM_STORES => self.stores as u32,
            MHPM_MACS => self.macs as u32,
            _ => 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn delta_subtracts_fieldwise() {
        let a = PerfCounters { cycles: 10, instret: 5, loads: 2, ..Default::default() };
        let b = PerfCounters { cycles: 25, instret: 12, loads: 9, ..Default::default() };
        let d = b.delta(&a);
        assert_eq!(d.cycles, 15);
        assert_eq!(d.instret, 7);
        assert_eq!(d.loads, 7);
    }

    #[test]
    fn csr_mapping_reads_expected_slots() {
        use crate::isa::csr::*;
        let c = PerfCounters {
            cycles: 0x1_0000_0002,
            instret: 7,
            loads: 3,
            stores: 4,
            macs: 5,
            ..Default::default()
        };
        assert_eq!(c.read_csr(MCYCLE), 2);
        assert_eq!(c.read_csr(MCYCLEH), 1);
        assert_eq!(c.read_csr(MINSTRET), 7);
        assert_eq!(c.read_csr(MHPM_LOADS), 3);
        assert_eq!(c.read_csr(MHPM_STORES), 4);
        assert_eq!(c.read_csr(MHPM_MACS), 5);
    }
}
