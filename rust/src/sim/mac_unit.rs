//! The modified multiplier block of Fig. 2 — four 17×17-bit lanes, a 2×
//! multi-pumped clock domain and the guard-bit soft-SIMD datapath.
//!
//! The unit is modelled *structurally*: execution walks the same pump /
//! lane / packed-product loops the RTL would, so the cycle cost falls out
//! of the datapath configuration instead of being hard-coded per opcode.
//! Three feature toggles mirror the paper's three optimisation layers
//! (Section 3.2 / Fig. 7):
//!
//! * **packing + parallelisation** — always on for `nn_mac_*` (the four
//!   multiplier lanes; the 4th lane is the paper's added gray MUL),
//! * **multi-pumping** — the MAC block clocked at 2× the core, doubling
//!   regfile read slots and lane issues per core cycle,
//! * **soft SIMD** — two int8×int2 products per lane per pump via the
//!   Eq. (2) guard-bit composition (2-bit weights only).
//!
//! With everything on, one `nn_mac_2b` retires 16 MACs in a single core
//! cycle: 4 lanes × 2 pumps × 2 packed products.

use crate::isa::custom::{soft_simd_dual_product, unpack_acts};
use crate::isa::MacMode;

/// Allocation-free weight unpack into a fixed lane buffer (hot path:
/// one call per `nn_mac` issue — §Perf iteration 1 replaced the
/// `Vec`-returning `isa::custom::unpack_weights` here, ~2× issue rate).
#[inline]
fn unpack_lanes(mode: MacMode, word: u32, out: &mut [i8; 16]) -> usize {
    let bits = mode.weight_bits();
    let n = mode.weights_per_word() as usize;
    let shift = 32 - bits;
    for (i, slot) in out.iter_mut().enumerate().take(n) {
        let field = (word >> (i as u32 * bits)) as i32;
        *slot = ((field << shift) >> shift) as i8;
    }
    n
}

/// Datapath feature toggles (Fig. 7's standalone-Mode ablations flip these).
/// `Hash` because the analytic [`crate::sim::session::CostCache`] keys
/// on it: the kernel *program* is identical across ablations, but its
/// cycle counters are not.
///
/// `cores` rides along as the cluster axis of the simulated machine
/// (`--cores`, [`crate::sim::cluster`]): it never touches the MAC
/// datapath below — `issue`/`cycles_for` model one core's unit — but it
/// is part of the machine identity the content-addressed result store
/// and the shard artifacts key on, so it lives here with the other
/// machine-configuration knobs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MacUnitConfig {
    /// 2× clock domain for the MAC block (Mode-2 optimisation).
    pub multipump: bool,
    /// Guard-bit dual products for 2-bit weights (Mode-3 optimisation).
    pub soft_simd: bool,
    /// Cluster cores the model run is scheduled over (1 = the plain
    /// single-core machine; purely a scheduling/keying axis).
    pub cores: usize,
}

impl MacUnitConfig {
    /// Full paper configuration: multi-pumping + soft SIMD.
    pub fn full() -> Self {
        MacUnitConfig { multipump: true, soft_simd: true, cores: 1 }
    }

    /// Packing/parallelisation only (the paper's standalone Mode-1 study).
    pub fn packing_only() -> Self {
        MacUnitConfig { multipump: false, soft_simd: false, cores: 1 }
    }

    /// Packing + multi-pumping, no soft SIMD (standalone Mode-2 study).
    pub fn multipump_only() -> Self {
        MacUnitConfig { multipump: true, soft_simd: false, cores: 1 }
    }

    /// The same datapath features on an N-core cluster (`--cores`).
    pub fn with_cores(mut self, cores: usize) -> Self {
        self.cores = cores.max(1);
        self
    }
}

impl Default for MacUnitConfig {
    fn default() -> Self {
        Self::full()
    }
}

/// Result of issuing one `nn_mac` instruction to the unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MacIssue {
    /// New accumulator value (wrapping 32-bit).
    pub acc: u32,
    /// Core-clock cycles the instruction occupies the pipeline.
    pub cycles: u32,
    /// MAC operations retired.
    pub macs: u32,
}

/// The modified multiplier block.
#[derive(Debug, Clone, Default)]
pub struct MacUnit {
    cfg: MacUnitConfig,
    /// Total MACs retired (feeds the `MHPM_MACS` counter).
    pub total_macs: u64,
    /// Total `nn_mac` instructions issued.
    pub total_issues: u64,
}

/// Number of 17×17 multiplier lanes (3 from Ibex's RV32M single-cycle
/// multiplier + the added gray MUL of Fig. 2).
pub const LANES: usize = 4;

impl MacUnit {
    /// Build a unit with the given feature configuration.
    pub fn new(cfg: MacUnitConfig) -> Self {
        MacUnit { cfg, total_macs: 0, total_issues: 0 }
    }

    /// Current configuration.
    pub fn config(&self) -> MacUnitConfig {
        self.cfg
    }

    /// Issue one `nn_mac_<x>b` with incoming accumulator `acc`, activation
    /// register file slice `act_words` (rs1-pair/quad contents, one word
    /// per 4 activations) and the packed weight word `w_word` (rs2).
    ///
    /// Walks the structural pump/lane loops; the returned `cycles` is the
    /// number of *core* cycles: `ceil(pumps_needed / pumps_per_cycle)`.
    pub fn issue(&mut self, mode: MacMode, acc: u32, act_words: &[u32], w_word: u32) -> MacIssue {
        debug_assert_eq!(act_words.len(), mode.activation_regs() as usize);
        let mut lanes = [0i8; 16];
        let n = unpack_lanes(mode, w_word, &mut lanes);
        let weights = &lanes[..n];

        // Products per lane-pump: 2 when the soft-SIMD path is active for
        // 2-bit weights, else 1.
        let soft = self.cfg.soft_simd && mode == MacMode::W2;
        let per_lane = if soft { 2 } else { 1 };
        let per_pump = LANES * per_lane;
        let pumps_needed = n.div_ceil(per_pump);
        let pumps_per_cycle = if self.cfg.multipump { 2 } else { 1 };
        let cycles = pumps_needed.div_ceil(pumps_per_cycle) as u32;

        let mut sum = acc as i32;
        for pump in 0..pumps_needed {
            for lane in 0..LANES {
                if soft {
                    // Soft-SIMD lane: two int8×int2 products per pump.
                    //
                    // Circuit-level note: the Eq. (2) composed multiply
                    // (`soft_simd_dual_product`, exhaustively verified)
                    // requires the two packed weights to share one
                    // activation — in the paper's Fig. 3c the two weights
                    // belong to two output channels of the same input. Our
                    // single-accumulator dot-product ISA semantics instead
                    // pair each weight with its own activation, so the
                    // functional model computes the two lane products
                    // directly while the *throughput* (2 products per lane
                    // per pump) and the guard-bit arithmetic are modelled
                    // faithfully. See DESIGN.md §ISA-Interpretation.
                    let i = (pump * LANES + lane) * 2;
                    if i >= n {
                        break;
                    }
                    for ii in i..(i + 2).min(n) {
                        let a = unpack_acts(act_words[ii / 4])[ii % 4];
                        sum = sum.wrapping_add((a as i32).wrapping_mul(weights[ii] as i32));
                    }
                    // Keep the Eq.(2) datapath exercised: the composed
                    // multiply must agree with the two direct products
                    // whenever the activation is shared.
                    debug_assert_eq!(
                        soft_simd_dual_product(
                            unpack_acts(act_words[i / 4])[i % 4],
                            weights[i],
                            if i + 1 < n { weights[i + 1] } else { 0 }
                        )
                        .0,
                        unpack_acts(act_words[i / 4])[i % 4] as i32 * weights[i] as i32
                    );
                } else {
                    let i = pump * LANES + lane;
                    if i >= n {
                        break;
                    }
                    let a = unpack_acts(act_words[i / 4])[i % 4];
                    sum = sum.wrapping_add((a as i32).wrapping_mul(weights[i] as i32));
                }
            }
        }

        self.total_macs += n as u64;
        self.total_issues += 1;
        MacIssue { acc: sum as u32, cycles, macs: n as u32 }
    }

    /// Core cycles one instruction of `mode` takes under this configuration
    /// (used by the analytic layer-cycle model in `dse`).
    pub fn cycles_for(&self, mode: MacMode) -> u32 {
        let soft = self.cfg.soft_simd && mode == MacMode::W2;
        let per_lane = if soft { 2 } else { 1 };
        let per_pump = LANES * per_lane;
        let pumps = (mode.weights_per_word() as usize).div_ceil(per_pump);
        let per_cycle = if self.cfg.multipump { 2 } else { 1 };
        pumps.div_ceil(per_cycle) as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::custom::{nn_mac_ref, pack_acts, pack_weights};
    use crate::isa::MacMode::*;
    use crate::rng::Rng;

    fn random_issue(rng: &mut Rng, mode: MacMode, cfg: MacUnitConfig) {
        let n = mode.weights_per_word() as usize;
        let w: Vec<i8> = (0..n).map(|_| rng.int_bits(mode.weight_bits())).collect();
        let w_word = pack_weights(mode, &w);
        let act_words: Vec<u32> = (0..mode.activation_regs())
            .map(|_| pack_acts([rng.i8(), rng.i8(), rng.i8(), rng.i8()]))
            .collect();
        let acc = rng.next_u32();
        let mut unit = MacUnit::new(cfg);
        let got = unit.issue(mode, acc, &act_words, w_word);
        let want = nn_mac_ref(mode, acc, &act_words, w_word);
        assert_eq!(got.acc, want, "mode {mode:?} cfg {cfg:?}");
        assert_eq!(got.macs, mode.macs_per_instr());
    }

    #[test]
    fn matches_scalar_reference_all_modes_all_configs() {
        let mut rng = Rng::new(0xC0DE);
        let cfgs = [
            MacUnitConfig::full(),
            MacUnitConfig::packing_only(),
            MacUnitConfig::multipump_only(),
        ];
        for _ in 0..500 {
            for mode in [W8, W4, W2] {
                for cfg in cfgs {
                    random_issue(&mut rng, mode, cfg);
                }
            }
        }
    }

    #[test]
    fn cycle_costs_follow_the_paper_modes() {
        // Full configuration: every mode is single-cycle (Table 2's
        // "N parallel MAC" claim).
        let full = MacUnit::new(MacUnitConfig::full());
        assert_eq!(full.cycles_for(W8), 1);
        assert_eq!(full.cycles_for(W4), 1);
        assert_eq!(full.cycles_for(W2), 1);

        // Packing only (standalone Mode-1 technique): 4 MACs/cycle.
        let p = MacUnit::new(MacUnitConfig::packing_only());
        assert_eq!(p.cycles_for(W8), 1);
        assert_eq!(p.cycles_for(W4), 2);
        assert_eq!(p.cycles_for(W2), 4);

        // + multi-pumping (standalone Mode-2): 8 MACs/cycle.
        let mp = MacUnit::new(MacUnitConfig::multipump_only());
        assert_eq!(mp.cycles_for(W8), 1);
        assert_eq!(mp.cycles_for(W4), 1);
        assert_eq!(mp.cycles_for(W2), 2);
    }

    #[test]
    fn issue_cycles_match_cycles_for() {
        let mut rng = Rng::new(7);
        for cfg in [
            MacUnitConfig::full(),
            MacUnitConfig::packing_only(),
            MacUnitConfig::multipump_only(),
        ] {
            for mode in [W8, W4, W2] {
                let n = mode.weights_per_word() as usize;
                let w: Vec<i8> = (0..n).map(|_| rng.int_bits(mode.weight_bits())).collect();
                let acts: Vec<u32> =
                    (0..mode.activation_regs()).map(|_| rng.next_u32()).collect();
                let mut unit = MacUnit::new(cfg);
                let issue = unit.issue(mode, 0, &acts, pack_weights(mode, &w));
                assert_eq!(issue.cycles, unit.cycles_for(mode));
            }
        }
    }

    #[test]
    fn counters_accumulate() {
        let mut unit = MacUnit::new(MacUnitConfig::full());
        let acts = [pack_acts([1, 2, 3, 4])];
        let w = pack_weights(W8, &[1, 1, 1, 1]);
        unit.issue(W8, 0, &acts, w);
        unit.issue(W8, 0, &acts, w);
        assert_eq!(unit.total_issues, 2);
        assert_eq!(unit.total_macs, 8);
    }
}
