//! Flat byte-addressable data/instruction memory with access accounting.
//!
//! The paper's Fig. 4 reports *memory accesses* (loads + stores issued by
//! the core) per layer; the counters here are the measurement substrate.
//! Ibex's LSU issues one bus transaction per (naturally aligned) load or
//! store regardless of width, so accesses are counted per instruction,
//! with byte totals tracked separately for bandwidth accounting.

/// Memory fault raised on out-of-bounds or misaligned access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemFault {
    /// Faulting byte address.
    pub addr: u32,
    /// Access width in bytes.
    pub width: u32,
    /// True if a store.
    pub is_store: bool,
}

impl std::fmt::Display for MemFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "memory fault: {} of {} bytes at {:#x}",
            if self.is_store { "store" } else { "load" },
            self.width,
            self.addr
        )
    }
}

impl std::error::Error for MemFault {}

/// Flat little-endian memory.
///
/// The backing buffer may be larger than the *logical* size (`limit`):
/// the [`crate::sim::session::SimSession`] pool hands the same buffer
/// to kernels of different footprints, and bounds checks always use the
/// logical size so fault behaviour is identical to a freshly-allocated
/// memory of exactly `limit` bytes. A dirty high-water mark tracks the
/// highest byte ever written so reuse only zeroes what was touched.
#[derive(Debug, Clone)]
pub struct Memory {
    bytes: Vec<u8>,
    /// Logical size: accesses at or beyond this address fault.
    limit: usize,
    /// One past the highest byte written (guest stores + host writes).
    dirty_high: usize,
    /// Loads issued (instruction count).
    pub loads: u64,
    /// Stores issued (instruction count).
    pub stores: u64,
    /// Bytes read.
    pub load_bytes: u64,
    /// Bytes written.
    pub store_bytes: u64,
}

impl Memory {
    /// Allocate `size` zeroed bytes.
    pub fn new(size: usize) -> Self {
        Memory {
            bytes: vec![0; size],
            limit: size,
            dirty_high: 0,
            loads: 0,
            stores: 0,
            load_bytes: 0,
            store_bytes: 0,
        }
    }

    /// Logical size in bytes (the fault boundary).
    pub fn size(&self) -> usize {
        self.limit
    }

    /// Recycle this memory for a new run of logical size `limit`:
    /// grows the backing buffer if needed, zeroes every byte written by
    /// the previous tenant and resets the access counters. Equivalent
    /// to `Memory::new(limit)` without the allocation.
    pub fn reset_for_reuse(&mut self, limit: usize) {
        if self.bytes.len() < limit {
            self.bytes.resize(limit, 0);
        }
        let dirty = self.dirty_high.min(self.bytes.len());
        self.bytes[..dirty].fill(0);
        self.dirty_high = 0;
        self.limit = limit;
        self.reset_counters();
    }

    /// Reset the access counters (e.g. between warm-up and measurement).
    pub fn reset_counters(&mut self) {
        self.loads = 0;
        self.stores = 0;
        self.load_bytes = 0;
        self.store_bytes = 0;
    }

    /// Total accesses (loads + stores) — the Fig. 4 metric.
    pub fn accesses(&self) -> u64 {
        self.loads + self.stores
    }

    #[inline]
    fn check(&self, addr: u32, width: u32, is_store: bool) -> Result<usize, MemFault> {
        let a = addr as usize;
        // Natural alignment, as required by Ibex without the unaligned
        // access retry path (our codegen always emits aligned accesses).
        if addr % width != 0 || a + width as usize > self.limit {
            return Err(MemFault { addr, width, is_store });
        }
        Ok(a)
    }

    /// Counted load of `width` ∈ {1,2,4} bytes, zero-extended.
    #[inline]
    pub fn load(&mut self, addr: u32, width: u32) -> Result<u32, MemFault> {
        let a = self.check(addr, width, false)?;
        self.loads += 1;
        self.load_bytes += width as u64;
        Ok(match width {
            1 => self.bytes[a] as u32,
            2 => u16::from_le_bytes([self.bytes[a], self.bytes[a + 1]]) as u32,
            4 => u32::from_le_bytes([
                self.bytes[a],
                self.bytes[a + 1],
                self.bytes[a + 2],
                self.bytes[a + 3],
            ]),
            _ => unreachable!(),
        })
    }

    /// Counted load of a run of `out.len()` consecutive words starting
    /// at `addr` — the micro-op engine's fused-strip fast path. Counts
    /// exactly like `out.len()` individual word loads. On a fault,
    /// returns the index of the first faulting word; earlier words have
    /// been read (and counted), exactly as sequential loads would.
    #[inline]
    pub fn load_word_run(&mut self, addr: u32, out: &mut [u32]) -> Result<(), (usize, MemFault)> {
        let a = addr as usize;
        let n = out.len();
        if addr % 4 == 0 && a + 4 * n <= self.limit {
            for (j, slot) in out.iter_mut().enumerate() {
                let b = a + 4 * j;
                *slot = u32::from_le_bytes([
                    self.bytes[b],
                    self.bytes[b + 1],
                    self.bytes[b + 2],
                    self.bytes[b + 3],
                ]);
            }
            self.loads += n as u64;
            self.load_bytes += 4 * n as u64;
            return Ok(());
        }
        // Cold path: replay element-wise to find the faulting word with
        // per-access counting semantics.
        for (j, slot) in out.iter_mut().enumerate() {
            match self.load(addr.wrapping_add(4 * j as u32), 4) {
                Ok(v) => *slot = v,
                Err(f) => return Err((j, f)),
            }
        }
        Ok(())
    }

    /// Counted store of `width` ∈ {1,2,4} bytes.
    #[inline]
    pub fn store(&mut self, addr: u32, width: u32, value: u32) -> Result<(), MemFault> {
        let a = self.check(addr, width, true)?;
        self.stores += 1;
        self.store_bytes += width as u64;
        let end = a + width as usize;
        if end > self.dirty_high {
            self.dirty_high = end;
        }
        match width {
            1 => self.bytes[a] = value as u8,
            2 => self.bytes[a..a + 2].copy_from_slice(&(value as u16).to_le_bytes()),
            4 => self.bytes[a..a + 4].copy_from_slice(&value.to_le_bytes()),
            _ => unreachable!(),
        }
        Ok(())
    }

    /// Uncounted host-side write (program/data loading).
    pub fn write_bytes(&mut self, addr: u32, data: &[u8]) {
        let a = addr as usize;
        assert!(a + data.len() <= self.limit, "host write out of bounds");
        self.bytes[a..a + data.len()].copy_from_slice(data);
        if a + data.len() > self.dirty_high {
            self.dirty_high = a + data.len();
        }
    }

    /// Uncounted host-side write of 32-bit words.
    pub fn write_words(&mut self, addr: u32, words: &[u32]) {
        for (i, &w) in words.iter().enumerate() {
            self.write_bytes(addr + 4 * i as u32, &w.to_le_bytes());
        }
    }

    /// Uncounted host-side write of int8 values.
    pub fn write_i8(&mut self, addr: u32, data: &[i8]) {
        let a = addr as usize;
        assert!(a + data.len() <= self.limit, "host write out of bounds");
        for (i, &v) in data.iter().enumerate() {
            self.bytes[a + i] = v as u8;
        }
        if a + data.len() > self.dirty_high {
            self.dirty_high = a + data.len();
        }
    }

    /// Uncounted host-side write of int32 values.
    pub fn write_i32(&mut self, addr: u32, data: &[i32]) {
        for (i, &v) in data.iter().enumerate() {
            self.write_bytes(addr + 4 * i as u32, &v.to_le_bytes());
        }
    }

    /// Uncounted host-side read. Bounds-checked against the *logical*
    /// size so a recycled pooled buffer behaves exactly like a fresh
    /// `Memory::new(limit)` (no silent zeros from slack capacity).
    pub fn read_bytes(&self, addr: u32, len: usize) -> &[u8] {
        let a = addr as usize;
        assert!(a + len <= self.limit, "host read out of bounds");
        &self.bytes[a..a + len]
    }

    /// Uncounted host-side read of int8 values.
    pub fn read_i8(&self, addr: u32, len: usize) -> Vec<i8> {
        self.read_bytes(addr, len).iter().map(|&b| b as i8).collect()
    }

    /// Uncounted host-side read of int32 values.
    pub fn read_i32(&self, addr: u32, len: usize) -> Vec<i32> {
        (0..len)
            .map(|i| {
                let b = self.read_bytes(addr + 4 * i as u32, 4);
                i32::from_le_bytes([b[0], b[1], b[2], b[3]])
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_store_round_trip_and_counts() {
        let mut m = Memory::new(64);
        m.store(8, 4, 0xdeadbeef).unwrap();
        assert_eq!(m.load(8, 4).unwrap(), 0xdeadbeef);
        assert_eq!(m.load(8, 1).unwrap(), 0xef);
        assert_eq!(m.load(10, 2).unwrap(), 0xdead);
        assert_eq!(m.loads, 3);
        assert_eq!(m.stores, 1);
        assert_eq!(m.accesses(), 4);
        assert_eq!(m.load_bytes, 7);
        assert_eq!(m.store_bytes, 4);
    }

    #[test]
    fn faults_on_misaligned_and_oob() {
        let mut m = Memory::new(16);
        assert!(m.load(2, 4).is_err());
        assert!(m.load(16, 1).is_err());
        assert!(m.store(14, 4, 0).is_err());
    }

    #[test]
    fn reuse_restores_pristine_state() {
        let mut m = Memory::new(32);
        m.store(4, 4, 0x11223344).unwrap();
        m.write_i8(8, &[7, 8]);
        m.reset_for_reuse(64);
        assert_eq!(m.size(), 64);
        assert_eq!(m.accesses(), 0);
        assert_eq!(m.read_i32(4, 1), vec![0]);
        assert_eq!(m.read_i8(8, 2), vec![0, 0]);
        // The larger logical size is addressable; beyond it faults.
        assert!(m.store(60, 4, 1).is_ok());
        assert!(m.load(64, 1).is_err());
        // Shrinking the logical size reinstates the tighter bound.
        m.reset_for_reuse(16);
        assert!(m.load(16, 1).is_err());
    }

    #[test]
    fn word_run_counts_like_individual_loads() {
        let mut m = Memory::new(64);
        m.write_words(8, &[1, 2, 3]);
        let mut out = [0u32; 3];
        m.load_word_run(8, &mut out).unwrap();
        assert_eq!(out, [1, 2, 3]);
        assert_eq!(m.loads, 3);
        assert_eq!(m.load_bytes, 12);
        // Faulting run: first word reads (and counts), second faults.
        let mut out2 = [0u32; 2];
        let err = m.load_word_run(60, &mut out2).unwrap_err();
        assert_eq!(err.0, 1);
        assert_eq!(m.loads, 4);
    }

    #[test]
    fn host_writes_are_uncounted() {
        let mut m = Memory::new(32);
        m.write_words(0, &[1, 2, 3]);
        m.write_i8(12, &[-1, -2]);
        assert_eq!(m.accesses(), 0);
        assert_eq!(m.read_i32(0, 3), vec![1, 2, 3]);
        assert_eq!(m.read_i8(12, 2), vec![-1, -2]);
    }
}
