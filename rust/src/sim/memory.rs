//! Flat byte-addressable data/instruction memory with access accounting.
//!
//! The paper's Fig. 4 reports *memory accesses* (loads + stores issued by
//! the core) per layer; the counters here are the measurement substrate.
//! Ibex's LSU issues one bus transaction per (naturally aligned) load or
//! store regardless of width, so accesses are counted per instruction,
//! with byte totals tracked separately for bandwidth accounting.

/// Memory fault raised on out-of-bounds or misaligned access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemFault {
    /// Faulting byte address.
    pub addr: u32,
    /// Access width in bytes.
    pub width: u32,
    /// True if a store.
    pub is_store: bool,
}

impl std::fmt::Display for MemFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "memory fault: {} of {} bytes at {:#x}",
            if self.is_store { "store" } else { "load" },
            self.width,
            self.addr
        )
    }
}

impl std::error::Error for MemFault {}

/// Flat little-endian memory.
#[derive(Debug, Clone)]
pub struct Memory {
    bytes: Vec<u8>,
    /// Loads issued (instruction count).
    pub loads: u64,
    /// Stores issued (instruction count).
    pub stores: u64,
    /// Bytes read.
    pub load_bytes: u64,
    /// Bytes written.
    pub store_bytes: u64,
}

impl Memory {
    /// Allocate `size` zeroed bytes.
    pub fn new(size: usize) -> Self {
        Memory { bytes: vec![0; size], loads: 0, stores: 0, load_bytes: 0, store_bytes: 0 }
    }

    /// Total size in bytes.
    pub fn size(&self) -> usize {
        self.bytes.len()
    }

    /// Reset the access counters (e.g. between warm-up and measurement).
    pub fn reset_counters(&mut self) {
        self.loads = 0;
        self.stores = 0;
        self.load_bytes = 0;
        self.store_bytes = 0;
    }

    /// Total accesses (loads + stores) — the Fig. 4 metric.
    pub fn accesses(&self) -> u64 {
        self.loads + self.stores
    }

    #[inline]
    fn check(&self, addr: u32, width: u32, is_store: bool) -> Result<usize, MemFault> {
        let a = addr as usize;
        // Natural alignment, as required by Ibex without the unaligned
        // access retry path (our codegen always emits aligned accesses).
        if addr % width != 0 || a + width as usize > self.bytes.len() {
            return Err(MemFault { addr, width, is_store });
        }
        Ok(a)
    }

    /// Counted load of `width` ∈ {1,2,4} bytes, zero-extended.
    #[inline]
    pub fn load(&mut self, addr: u32, width: u32) -> Result<u32, MemFault> {
        let a = self.check(addr, width, false)?;
        self.loads += 1;
        self.load_bytes += width as u64;
        Ok(match width {
            1 => self.bytes[a] as u32,
            2 => u16::from_le_bytes([self.bytes[a], self.bytes[a + 1]]) as u32,
            4 => u32::from_le_bytes([
                self.bytes[a],
                self.bytes[a + 1],
                self.bytes[a + 2],
                self.bytes[a + 3],
            ]),
            _ => unreachable!(),
        })
    }

    /// Counted store of `width` ∈ {1,2,4} bytes.
    #[inline]
    pub fn store(&mut self, addr: u32, width: u32, value: u32) -> Result<(), MemFault> {
        let a = self.check(addr, width, true)?;
        self.stores += 1;
        self.store_bytes += width as u64;
        match width {
            1 => self.bytes[a] = value as u8,
            2 => self.bytes[a..a + 2].copy_from_slice(&(value as u16).to_le_bytes()),
            4 => self.bytes[a..a + 4].copy_from_slice(&value.to_le_bytes()),
            _ => unreachable!(),
        }
        Ok(())
    }

    /// Uncounted host-side write (program/data loading).
    pub fn write_bytes(&mut self, addr: u32, data: &[u8]) {
        let a = addr as usize;
        assert!(a + data.len() <= self.bytes.len(), "host write out of bounds");
        self.bytes[a..a + data.len()].copy_from_slice(data);
    }

    /// Uncounted host-side write of 32-bit words.
    pub fn write_words(&mut self, addr: u32, words: &[u32]) {
        for (i, &w) in words.iter().enumerate() {
            self.write_bytes(addr + 4 * i as u32, &w.to_le_bytes());
        }
    }

    /// Uncounted host-side write of int8 values.
    pub fn write_i8(&mut self, addr: u32, data: &[i8]) {
        let a = addr as usize;
        assert!(a + data.len() <= self.bytes.len(), "host write out of bounds");
        for (i, &v) in data.iter().enumerate() {
            self.bytes[a + i] = v as u8;
        }
    }

    /// Uncounted host-side write of int32 values.
    pub fn write_i32(&mut self, addr: u32, data: &[i32]) {
        for (i, &v) in data.iter().enumerate() {
            self.write_bytes(addr + 4 * i as u32, &v.to_le_bytes());
        }
    }

    /// Uncounted host-side read.
    pub fn read_bytes(&self, addr: u32, len: usize) -> &[u8] {
        let a = addr as usize;
        &self.bytes[a..a + len]
    }

    /// Uncounted host-side read of int8 values.
    pub fn read_i8(&self, addr: u32, len: usize) -> Vec<i8> {
        self.read_bytes(addr, len).iter().map(|&b| b as i8).collect()
    }

    /// Uncounted host-side read of int32 values.
    pub fn read_i32(&self, addr: u32, len: usize) -> Vec<i32> {
        (0..len)
            .map(|i| {
                let b = self.read_bytes(addr + 4 * i as u32, 4);
                i32::from_le_bytes([b[0], b[1], b[2], b[3]])
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_store_round_trip_and_counts() {
        let mut m = Memory::new(64);
        m.store(8, 4, 0xdeadbeef).unwrap();
        assert_eq!(m.load(8, 4).unwrap(), 0xdeadbeef);
        assert_eq!(m.load(8, 1).unwrap(), 0xef);
        assert_eq!(m.load(10, 2).unwrap(), 0xdead);
        assert_eq!(m.loads, 3);
        assert_eq!(m.stores, 1);
        assert_eq!(m.accesses(), 4);
        assert_eq!(m.load_bytes, 7);
        assert_eq!(m.store_bytes, 4);
    }

    #[test]
    fn faults_on_misaligned_and_oob() {
        let mut m = Memory::new(16);
        assert!(m.load(2, 4).is_err());
        assert!(m.load(16, 1).is_err());
        assert!(m.store(14, 4, 0).is_err());
    }

    #[test]
    fn host_writes_are_uncounted() {
        let mut m = Memory::new(32);
        m.write_words(0, &[1, 2, 3]);
        m.write_i8(12, &[-1, -2]);
        assert_eq!(m.accesses(), 0);
        assert_eq!(m.read_i32(0, 3), vec![1, 2, 3]);
        assert_eq!(m.read_i8(12, 2), vec![-1, -2]);
    }
}
