//! Analytic N-core cluster timing model with banked-TCDM contention.
//!
//! The paper evaluates its multi-pumped MAC unit on a single in-order
//! core; the parallel-cluster line of work it cites (3 TOPS/W
//! PULP-style clusters, PAPERS.md arxiv 2307.01056) runs the same
//! fine-grain mixed-precision kernels across N cores sharing a
//! word-interleaved multi-banked TCDM. This module models that scaling
//! *analytically*, the same trade the crate's analytic execution
//! backend makes: kernels are measured **once** on the single-core ISS
//! (the measurement and its [`CostKey`](crate::sim::session::CostKey)
//! are cluster-independent), and the cluster overlay composes the
//! measured per-layer cost into an N-core schedule:
//!
//! * the **scheduler** ([`partition`]) splits a layer's parallel units
//!   (output channels for conv/dense, channels for depthwise — the
//!   outermost, dependence-free kernel loop) contiguously across cores;
//!   the first `units % cores` cores take one extra unit. The partition
//!   is a pure function of `(units, cores)` — deterministic across
//!   worker counts, machines and runs;
//! * each core's **work share** scales the measured layer cost by its
//!   unit fraction (floor arithmetic — integers end to end);
//! * **banked contention** charges each active core a stall penalty for
//!   its TCDM traffic ([`bank_conflict_stalls`]): with `A` active cores
//!   on `B` banks, a word-interleaved access collides with one of the
//!   `A-1` rivals with probability `(A-1)/B`, so `accesses·(A-1)/B`
//!   cycles are lost re-arbitrating. `banks = 2·cores` (the PULP
//!   banking factor [`BANKING_FACTOR`]) keeps that well under the
//!   parallel win;
//! * layers synchronise at a **barrier**: a layer costs the slowest
//!   core's busy time (work + stalls), and the model run is the sum of
//!   layer barriers ([`ClusterPerf::add_layer`]).
//!
//! With `cores = 1` every path degenerates structurally: one part
//! holding all units, a work share of exactly the measured cost, zero
//! stalls (`active ≤ 1`), and a barrier equal to the single-core
//! cycles — which is what lets the `--cores 1` sweep outputs stay
//! byte-identical to the pre-cluster pipeline.
//!
//! Contention stalls deliberately live here, in [`CoreSlice`] /
//! [`ClusterPerf`], and **not** in
//! [`PerfCounters`](crate::sim::perf::PerfCounters): the per-core
//! counters are produced identically by the ISS and the analytic
//! replay path and are bit-compared by the audit machinery — a
//! cluster-level penalty has no single-core ground truth to audit
//! against, so it stays in the cluster layer's own accounting.

use std::ops::Range;

/// TCDM banks per core — the PULP-cluster banking factor (2× banking
/// keeps the uniform-traffic collision probability below 1/2 at full
/// occupancy).
pub const BANKING_FACTOR: usize = 2;

/// Cluster shape: core count and shared-TCDM bank count.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ClusterConfig {
    /// Replicated cores (≥ 1; 1 = the plain single-core pipeline).
    pub cores: usize,
    /// Word-interleaved TCDM banks shared by the cores.
    pub banks: usize,
}

impl ClusterConfig {
    /// Cluster of `cores` with the default [`BANKING_FACTOR`]× banks.
    pub fn new(cores: usize) -> Self {
        let cores = cores.max(1);
        ClusterConfig { cores, banks: cores * BANKING_FACTOR }
    }

    /// The single-core degenerate cluster.
    pub fn single() -> Self {
        Self::new(1)
    }

    /// Whether this is the single-core degenerate configuration (the
    /// cluster overlay must stay entirely out of the cost path then).
    pub fn is_single(&self) -> bool {
        self.cores <= 1
    }
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self::single()
    }
}

/// Deterministic contiguous partition of `items` work units over
/// `cores` parts: part `i` is a `Range` into `0..items`, the first
/// `items % cores` parts take one extra unit, and the parts cover the
/// item space exactly, in order, without overlap. A pure function of
/// `(items, cores)` — the scheduler contract the shard/merge machinery
/// relies on (same split on every machine, worker count and run).
pub fn partition(items: usize, cores: usize) -> Vec<Range<usize>> {
    let cores = cores.max(1);
    let base = items / cores;
    let extra = items % cores;
    let mut start = 0;
    (0..cores)
        .map(|i| {
            let len = base + usize::from(i < extra);
            let r = start..start + len;
            start += len;
            r
        })
        .collect()
}

/// Stall cycles charged to one active core issuing `accesses` TCDM
/// accesses while `active_cores` cores contend for `banks` banks: each
/// access collides with one of the `active_cores - 1` rivals with
/// probability `(active_cores - 1) / banks` under word-interleaved
/// addressing, losing one re-arbitration cycle. Zero when the core has
/// the TCDM to itself — which is what keeps the single-core path exact.
pub fn bank_conflict_stalls(accesses: u64, active_cores: usize, banks: usize) -> u64 {
    if active_cores <= 1 || banks == 0 {
        return 0;
    }
    accesses * (active_cores as u64 - 1) / banks as u64
}

/// One core's share of a split layer: its unit count, the work-share
/// cycles and TCDM accesses scaled from the measured single-core cost,
/// and the contention stalls charged on that traffic.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CoreSlice {
    /// Parallel units (output channels / channels) this core owns.
    pub units: usize,
    /// Work cycles (excluding stalls).
    pub cycles: u64,
    /// TCDM accesses this core issues.
    pub mem_accesses: u64,
    /// Bank-conflict stall cycles charged on those accesses.
    pub stalls: u64,
}

impl CoreSlice {
    /// Busy time: work plus contention stalls.
    pub fn busy(&self) -> u64 {
        self.cycles + self.stalls
    }
}

/// Split one layer's measured single-core cost (`cycles`,
/// `mem_accesses`) over the cluster along its `units` parallel units.
/// Returns one [`CoreSlice`] per core (idle cores get all-zero slices
/// and are never charged stalls — `active_cores` counts only cores
/// with work). `cores = 1` returns the measured cost verbatim.
pub fn split_layer(
    cycles: u64,
    mem_accesses: u64,
    units: usize,
    cfg: &ClusterConfig,
) -> Vec<CoreSlice> {
    let units = units.max(1);
    let parts = partition(units, cfg.cores);
    let active = parts.iter().filter(|r| !r.is_empty()).count();
    parts
        .iter()
        .map(|r| {
            let len = r.len();
            if len == 0 {
                return CoreSlice::default();
            }
            // Exact when len == units (the single-core / fewer-units-
            // than-cores cases); proportional floor split otherwise.
            let c = cycles * len as u64 / units as u64;
            let a = mem_accesses * len as u64 / units as u64;
            CoreSlice {
                units: len,
                cycles: c,
                mem_accesses: a,
                stalls: bank_conflict_stalls(a, active, cfg.banks),
            }
        })
        .collect()
}

/// Whole-run cluster performance, accumulated layer by layer with a
/// barrier between layers — the cluster-level extension of the
/// single-core [`PerfCounters`](crate::sim::perf::PerfCounters).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterPerf {
    /// Cluster shape the run was scheduled for.
    pub config: ClusterConfig,
    /// Per-core busy cycles (work + stalls) summed over layers.
    pub busy: Vec<u64>,
    /// Critical-path cycles: the sum over layers of the slowest core's
    /// busy time (the barrier cost the run actually pays).
    pub cycles: u64,
    /// Bank-conflict stall cycles summed over cores and layers.
    pub bank_stalls: u64,
}

impl ClusterPerf {
    /// Empty accumulator for `cfg`.
    pub fn new(cfg: ClusterConfig) -> Self {
        ClusterPerf { config: cfg, busy: vec![0; cfg.cores], cycles: 0, bank_stalls: 0 }
    }

    /// Fold one layer's split into the run: the barrier advances by the
    /// slowest slice, every core logs its own busy time, stalls sum.
    pub fn add_layer(&mut self, slices: &[CoreSlice]) {
        debug_assert_eq!(slices.len(), self.config.cores);
        let barrier = slices.iter().map(CoreSlice::busy).max().unwrap_or(0);
        self.cycles += barrier;
        for (b, s) in self.busy.iter_mut().zip(slices) {
            *b += s.busy();
        }
        self.bank_stalls += slices.iter().map(|s| s.stalls).sum::<u64>();
    }

    /// Per-core utilization: busy time over critical-path time, in
    /// `[0, 1]` per core (the slowest core of every layer is busy for
    /// the whole barrier by construction).
    pub fn utilization(&self) -> Vec<f64> {
        if self.cycles == 0 {
            return vec![0.0; self.busy.len()];
        }
        self.busy.iter().map(|&b| b as f64 / self.cycles as f64).collect()
    }

    /// Total stall cycles across the cluster.
    pub fn total_bank_stalls(&self) -> u64 {
        self.bank_stalls
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_exactly_and_balances() {
        for items in 0..40 {
            for cores in 1..9 {
                let parts = partition(items, cores);
                assert_eq!(parts.len(), cores);
                // Exact, ordered, gap-free coverage.
                let mut next = 0;
                for r in &parts {
                    assert_eq!(r.start, next);
                    next = r.end;
                }
                assert_eq!(next, items, "items {items} cores {cores}");
                // Balance: part sizes differ by at most one, larger
                // parts first.
                let lens: Vec<usize> = parts.iter().map(|r| r.len()).collect();
                let (min, max) =
                    (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                assert!(max - min <= 1, "items {items} cores {cores}: {lens:?}");
                let mut sorted = lens.clone();
                sorted.sort_unstable_by(|a, b| b.cmp(a));
                assert_eq!(lens, sorted, "larger parts must come first");
            }
        }
    }

    #[test]
    fn partition_is_deterministic() {
        assert_eq!(partition(10, 4), partition(10, 4));
        assert_eq!(partition(7, 3), vec![0..3, 3..5, 5..7]);
        assert_eq!(partition(2, 4), vec![0..1, 1..2, 2..2, 2..2]);
    }

    #[test]
    fn stalls_vanish_without_contention() {
        assert_eq!(bank_conflict_stalls(1_000_000, 1, 8), 0);
        assert_eq!(bank_conflict_stalls(1_000_000, 0, 8), 0);
        assert_eq!(bank_conflict_stalls(0, 4, 8), 0);
        // 4 active cores on 8 banks: 3/8 of accesses collide.
        assert_eq!(bank_conflict_stalls(800, 4, 8), 300);
    }

    #[test]
    fn single_core_split_is_the_identity() {
        let cfg = ClusterConfig::single();
        let s = split_layer(12_345, 678, 17, &cfg);
        assert_eq!(s.len(), 1);
        assert_eq!(s[0], CoreSlice { units: 17, cycles: 12_345, mem_accesses: 678, stalls: 0 });
        // One unit on a big cluster: one active core, full cost, no
        // stalls — also exactly the single-core cost.
        let s = split_layer(12_345, 678, 1, &ClusterConfig::new(8));
        assert_eq!(s[0], CoreSlice { units: 1, cycles: 12_345, mem_accesses: 678, stalls: 0 });
        assert!(s[1..].iter().all(|x| *x == CoreSlice::default()));
    }

    #[test]
    fn layer_barrier_never_exceeds_single_core_cost() {
        // The core guarantee behind "cycles non-increasing": for any
        // realistic accesses ≤ cycles/2 (every access costs ≥ 2 cycles
        // on this core), the slowest slice (work + stalls) is bounded
        // by the measured single-core cycles.
        for cores in [1usize, 2, 4, 8] {
            let cfg = ClusterConfig::new(cores);
            for units in 1..50 {
                for (cycles, accesses) in [(1000u64, 400u64), (7919, 3959), (64, 8)] {
                    let slices = split_layer(cycles, accesses, units, &cfg);
                    let barrier = slices.iter().map(CoreSlice::busy).max().unwrap();
                    assert!(
                        barrier <= cycles,
                        "cores {cores} units {units}: barrier {barrier} > {cycles}"
                    );
                }
            }
        }
    }

    #[test]
    fn cluster_perf_accumulates_barriers_and_stalls() {
        let cfg = ClusterConfig::new(2);
        let mut perf = ClusterPerf::new(cfg);
        // Layer 1: 10 units → split 5/5.
        perf.add_layer(&split_layer(1000, 400, 10, &cfg));
        // Layer 2: 1 unit → core 0 does everything.
        perf.add_layer(&split_layer(300, 60, 1, &cfg));
        // Layer 1 slice: 500 cycles + 200·1/4 = 50 stalls each.
        assert_eq!(perf.cycles, 550 + 300);
        assert_eq!(perf.bank_stalls, 100);
        assert_eq!(perf.busy, vec![550 + 300, 550]);
        let u = perf.utilization();
        assert!((u[0] - 1.0).abs() < 1e-12);
        assert!(u[1] < 1.0 && u[1] > 0.0);
    }
}
