//! The Table-3 model zoo, scaled to laptop-trainable sizes while keeping
//! every topological property the paper's evaluation depends on:
//!
//! | paper model      | topology | ours |
//! |------------------|----------|------|
//! | LeNet5 (MNIST)   | 2C-3D    | identical topology, 28×28×1 input |
//! | CNN (CIFAR10)    | 3C-1D    | 3 conv + dense, 32×32×3 |
//! | MCUNet-vww1      | 1C-15R-1D| stem conv + 15 inverted-residual blocks + dense, 64×64×3, 2 classes |
//! | MobileNetV1      | 14C-1D   | stem conv + 13 depthwise-separable pairs + dense, 32×32×3, width ≈0.25 |
//!
//! The depthwise-separable structure of MobileNet/MCUNet is preserved
//! exactly because the paper's depthwise observation (less input reuse →
//! smaller gains) is one of the shape-claims we reproduce.

use super::{LayerSpec, ModelSpec, Node};

/// LeNet5: 2 conv + 3 dense (Table 3 row 2).
pub fn lenet5() -> ModelSpec {
    use LayerSpec::*;
    ModelSpec {
        name: "lenet5",
        input: [28, 28, 1],
        num_classes: 10,
        nodes: vec![
            Node::Layer(Conv { cout: 6, k: 5, stride: 1, pad: 0, relu: true }),
            Node::Layer(MaxPool2),
            Node::Layer(Conv { cout: 16, k: 5, stride: 1, pad: 0, relu: true }),
            Node::Layer(MaxPool2),
            Node::Layer(Dense { out: 120, relu: true }),
            Node::Layer(Dense { out: 84, relu: true }),
            Node::Layer(Dense { out: 10, relu: false }),
        ],
    }
}

/// CIFAR-10 CNN: 3 conv + 1 dense (Table 3 row 1).
pub fn cifar_cnn() -> ModelSpec {
    use LayerSpec::*;
    ModelSpec {
        name: "cifar_cnn",
        input: [32, 32, 3],
        num_classes: 10,
        nodes: vec![
            Node::Layer(Conv { cout: 16, k: 3, stride: 1, pad: 1, relu: true }),
            Node::Layer(MaxPool2),
            Node::Layer(Conv { cout: 32, k: 3, stride: 1, pad: 1, relu: true }),
            Node::Layer(MaxPool2),
            Node::Layer(Conv { cout: 64, k: 3, stride: 1, pad: 1, relu: true }),
            Node::Layer(MaxPool2),
            Node::Layer(Dense { out: 10, relu: false }),
        ],
    }
}

/// Append one MobileNetV2-style inverted residual block: 1×1 expand →
/// 3×3 depthwise → 1×1 (linear) project, wrapped in [`Node::Residual`]
/// when the skip connection applies (stride 1, cin == cout).
fn push_block(nodes: &mut Vec<Node>, cin: usize, cout: usize, expand: usize, stride: usize) {
    use LayerSpec::*;
    let hidden = cin * expand;
    let seq = vec![
        Conv { cout: hidden, k: 1, stride: 1, pad: 0, relu: true },
        Depthwise { k: 3, stride, pad: 1, relu: true },
        Conv { cout, k: 1, stride: 1, pad: 0, relu: false },
    ];
    if stride == 1 && cin == cout {
        nodes.push(Node::Residual(seq));
    } else {
        nodes.extend(seq.into_iter().map(Node::Layer));
    }
}

/// MCUNet-VWW-like: stem conv + 15 inverted-residual blocks + dense,
/// binary Visual-Wake-Words-style task (Table 3 row 3, "1C-15R-1D").
pub fn mcunet_vww() -> ModelSpec {
    use LayerSpec::*;
    let mut nodes = vec![Node::Layer(Conv { cout: 8, k: 3, stride: 2, pad: 1, relu: true })];
    // (cin → cout, expand, stride) ladder; skip applies on the
    // stride-1 same-width blocks, matching MCUNet's block distribution.
    let blocks: [(usize, usize, usize, usize); 15] = [
        (8, 16, 2, 2),  // 32→16
        (16, 16, 2, 1), // skip
        (16, 16, 2, 1), // skip
        (16, 24, 2, 2), // 16→8
        (24, 24, 2, 1), // skip
        (24, 24, 2, 1), // skip
        (24, 32, 2, 2), // 8→4
        (32, 32, 2, 1), // skip
        (32, 32, 2, 1), // skip
        (32, 32, 2, 1), // skip
        (32, 48, 2, 2), // 4→2
        (48, 48, 2, 1), // skip
        (48, 48, 2, 1), // skip
        (48, 64, 2, 1), // widen, no skip
        (64, 64, 2, 1), // skip
    ];
    for (cin, cout, t, s) in blocks {
        push_block(&mut nodes, cin, cout, t, s);
    }
    nodes.push(Node::Layer(AvgPoolGlobal));
    nodes.push(Node::Layer(Dense { out: 2, relu: false }));
    ModelSpec { name: "mcunet_vww", input: [64, 64, 3], num_classes: 2, nodes }
}

/// MobileNetV1 at width ≈0.25 on 32×32 inputs: stem conv + 13
/// depthwise-separable pairs + dense (Table 3 row 4, "14C-1D").
pub fn mobilenet_v1() -> ModelSpec {
    use LayerSpec::*;
    let mut nodes = vec![Node::Layer(Conv { cout: 8, k: 3, stride: 1, pad: 1, relu: true })];
    // (channels out, stride of the depthwise) — the standard MobileNetV1
    // ladder scaled by 0.25 with strides adapted to the 32×32 input.
    let pairs: [(usize, usize); 13] = [
        (16, 1),
        (32, 2), // 32→16
        (32, 1),
        (64, 2), // 16→8
        (64, 1),
        (128, 2), // 8→4
        (128, 1),
        (128, 1),
        (128, 1),
        (128, 1),
        (128, 1),
        (256, 2), // 4→2
        (256, 1),
    ];
    for (cout, s) in pairs {
        nodes.push(Node::Layer(Depthwise { k: 3, stride: s, pad: 1, relu: true }));
        nodes.push(Node::Layer(Conv { cout, k: 1, stride: 1, pad: 0, relu: true }));
    }
    nodes.push(Node::Layer(AvgPoolGlobal));
    nodes.push(Node::Layer(Dense { out: 100, relu: false }));
    ModelSpec { name: "mobilenet_v1", input: [32, 32, 3], num_classes: 100, nodes }
}

/// All four Table-3 models.
pub fn all_models() -> Vec<ModelSpec> {
    vec![cifar_cnn(), lenet5(), mcunet_vww(), mobilenet_v1()]
}

/// Look a model up by name.
pub fn by_name(name: &str) -> Option<ModelSpec> {
    all_models().into_iter().find(|m| m.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{analyze, QKind};

    #[test]
    fn lenet5_topology_is_2c3d() {
        let a = analyze(&lenet5());
        let convs = a.layers.iter().filter(|l| l.kind == QKind::Conv).count();
        let denses = a.layers.iter().filter(|l| l.kind == QKind::Dense).count();
        assert_eq!((convs, denses), (2, 3));
        // Flatten between conv and dense: 4·4·16 = 256 inputs.
        assert_eq!(a.layers[2].in_shape, [1, 1, 256]);
    }

    #[test]
    fn cifar_cnn_topology_is_3c1d() {
        let a = analyze(&cifar_cnn());
        let convs = a.layers.iter().filter(|l| l.kind == QKind::Conv).count();
        let denses = a.layers.iter().filter(|l| l.kind == QKind::Dense).count();
        assert_eq!((convs, denses), (3, 1));
    }

    #[test]
    fn mcunet_has_15_blocks_and_residuals() {
        let m = mcunet_vww();
        let res = m.nodes.iter().filter(|n| matches!(n, Node::Residual(_))).count();
        assert_eq!(res, 10, "skip blocks");
        let a = analyze(&m);
        // 1 stem + 15 blocks × 3 + 1 dense = 47 quantizable layers.
        assert_eq!(a.layers.len(), 47);
        assert_eq!(a.residuals.len(), 10);
        assert!(a.layers.iter().any(|l| l.kind == QKind::Depthwise));
    }

    #[test]
    fn mobilenet_is_14c_1d() {
        let a = analyze(&mobilenet_v1());
        // 1 stem + 13·(dw+pw) + 1 dense = 28 quantizable layers.
        assert_eq!(a.layers.len(), 28);
        let dws = a.layers.iter().filter(|l| l.kind == QKind::Depthwise).count();
        assert_eq!(dws, 13);
        // Final spatial is 2×2 before the global pool.
        assert_eq!(a.layers[26].out_shape, [2, 2, 256]);
        assert_eq!(a.layers[27].in_shape, [1, 1, 256]);
    }

    #[test]
    fn every_model_analyzes_cleanly() {
        for m in all_models() {
            let a = analyze(&m);
            assert!(a.total_macs > 100_000, "{}: {}", m.name, a.total_macs);
            assert!(a.layers.last().unwrap().is_last);
            // Output classes match the final dense.
            assert_eq!(a.layers.last().unwrap().out_shape[2], m.num_classes);
        }
    }

    #[test]
    fn by_name_round_trips() {
        for m in all_models() {
            assert_eq!(by_name(m.name).unwrap(), m);
        }
        assert!(by_name("nope").is_none());
    }
}
