//! Model-level inference: float forward (training-side semantics),
//! activation-scale calibration, per-configuration quantization and the
//! integer forward pass — the host golden reference the RV32 execution
//! ([`super::sim_exec`]) and the JAX artifact are checked against.

use super::{analyze, LayerSpec, ModelAnalysis, ModelSpec, Node, QKind};
use crate::nn::layers::*;
use crate::nn::quant::{quantize_value, symmetric_scale, Requant};
use crate::nn::tensor::Tensor;
use crate::nn::{quantize_layer, QLayer};

/// Float parameters of one quantizable layer.
#[derive(Debug, Clone)]
pub struct LayerParams {
    /// Weights (layout per layer kind — see `nn::layers`).
    pub w: Vec<f32>,
    /// Biases.
    pub b: Vec<f32>,
}

/// Float parameters for a whole model (canonical quantizable-layer order).
pub type ModelParams = Vec<LayerParams>;

/// Random He-style initialisation (tests / artifact-free operation).
pub fn random_params(spec: &ModelSpec, seed: u64) -> ModelParams {
    let a = analyze(spec);
    let mut rng = crate::rng::Rng::new(seed);
    a.layers
        .iter()
        .map(|l| {
            let fan_in = match l.kind {
                QKind::Conv => l.k * l.k * l.in_shape[2],
                QKind::Depthwise => l.k * l.k,
                QKind::Dense => l.in_shape[2],
            };
            let std = (2.0 / fan_in as f32).sqrt();
            LayerParams {
                w: (0..l.w_len).map(|_| rng.normal() * std).collect(),
                b: (0..l.b_len).map(|_| rng.normal() * 0.01).collect(),
            }
        })
        .collect()
}

enum Flow<T> {
    Map(Tensor<T>),
    Flat(Vec<T>),
}

impl<T: Copy + Default> Flow<T> {
    fn to_flat(self) -> Vec<T> {
        match self {
            Flow::Map(t) => t.data,
            Flow::Flat(v) => v,
        }
    }
    fn map(self) -> Tensor<T> {
        match self {
            Flow::Map(t) => t,
            Flow::Flat(_) => panic!("expected a feature map"),
        }
    }
}

/// Float forward pass. `record` (if given) receives every site tensor's
/// abs-max in site order — the calibration hook.
pub fn float_forward(
    spec: &ModelSpec,
    params: &ModelParams,
    input: &Tensor<f32>,
    mut record: Option<&mut Vec<f32>>,
) -> Vec<f32> {
    let rec = |v: f32, record: &mut Option<&mut Vec<f32>>| {
        if let Some(r) = record.as_deref_mut() {
            r.push(v);
        }
    };
    rec(input.abs_max(), &mut record);
    let mut x = Flow::Map(input.clone());
    let mut li = 0usize;
    let run_layer = |l: &LayerSpec, x: Flow<f32>, li: &mut usize| -> Flow<f32> {
        match *l {
            LayerSpec::Conv { cout, k, stride, pad, relu } => {
                let p = &params[*li];
                *li += 1;
                Flow::Map(conv2d_f32(&x.map(), &p.w, &p.b, cout, ConvGeom { k, stride, pad }, relu))
            }
            LayerSpec::Depthwise { k, stride, pad, relu } => {
                let p = &params[*li];
                *li += 1;
                Flow::Map(depthwise_f32(&x.map(), &p.w, &p.b, ConvGeom { k, stride, pad }, relu))
            }
            LayerSpec::Dense { out, relu } => {
                let p = &params[*li];
                *li += 1;
                Flow::Flat(dense_f32(&x.to_flat(), &p.w, &p.b, out, relu))
            }
            LayerSpec::MaxPool2 => Flow::Map(maxpool2_f32(&x.map())),
            LayerSpec::AvgPoolGlobal => {
                let m = x.map();
                let c = m.shape[2];
                Flow::Map(Tensor::from_vec(&[1, 1, c], avgpool_global_f32(&m)))
            }
        }
    };
    let abs_max = |x: &Flow<f32>| match x {
        Flow::Map(t) => t.abs_max(),
        Flow::Flat(v) => v.iter().fold(0.0f32, |m, &x| m.max(x.abs())),
    };
    for node in &spec.nodes {
        match node {
            Node::Layer(l) => {
                let is_q = !matches!(l, LayerSpec::MaxPool2 | LayerSpec::AvgPoolGlobal);
                x = run_layer(l, x, &mut li);
                if is_q {
                    rec(abs_max(&x), &mut record);
                }
            }
            Node::Residual(inner) => {
                let skip = x.map();
                let mut b = Flow::Map(skip.clone());
                for l in inner {
                    b = run_layer(l, b, &mut li);
                    rec(abs_max(&b), &mut record);
                }
                let bm = b.map();
                let mut sum = skip.clone();
                for (o, &v) in sum.data.iter_mut().zip(bm.data.iter()) {
                    *o += v;
                }
                rec(sum.abs_max(), &mut record);
                x = Flow::Map(sum);
            }
        }
    }
    x.to_flat()
}

/// Calibrate activation-scale sites over a batch of float inputs:
/// per-site abs-max over the batch, converted to int8 symmetric scales.
pub fn calibrate(spec: &ModelSpec, params: &ModelParams, inputs: &[Tensor<f32>]) -> Vec<f32> {
    let a = analyze(spec);
    let mut maxes = vec![0.0f32; a.n_sites];
    for input in inputs {
        let mut rec = Vec::with_capacity(a.n_sites);
        float_forward(spec, params, input, Some(&mut rec));
        assert_eq!(rec.len(), a.n_sites, "site walk mismatch");
        for (m, r) in maxes.iter_mut().zip(&rec) {
            *m = m.max(*r);
        }
    }
    maxes.iter().map(|&m| symmetric_scale(m.max(1e-6), 8)).collect()
}

/// A fully quantized model under one mixed-precision configuration.
#[derive(Debug, Clone)]
pub struct QModel {
    /// The model spec.
    pub spec: ModelSpec,
    /// Static analysis (layer order matches `layers`).
    pub analysis: ModelAnalysis,
    /// Quantized per-layer parameters.
    pub layers: Vec<QLayer>,
    /// Per-site activation scales.
    pub sites: Vec<f32>,
    /// Per-layer weight bit-widths (the DSE configuration).
    pub bits: Vec<u32>,
}

/// Quantize a model under a per-layer bit-width configuration.
pub fn quantize_model(
    spec: &ModelSpec,
    params: &ModelParams,
    sites: &[f32],
    bits: &[u32],
) -> QModel {
    let analysis = analyze(spec);
    assert_eq!(params.len(), analysis.layers.len());
    assert_eq!(bits.len(), analysis.layers.len());
    assert_eq!(sites.len(), analysis.n_sites);
    let layers = analysis
        .layers
        .iter()
        .zip(params)
        .zip(bits)
        .map(|((info, p), &b)| {
            quantize_layer(&p.w, &p.b, sites[info.site_in], sites[info.site_out], b)
        })
        .collect();
    QModel { spec: spec.clone(), analysis, layers, sites: sites.to_vec(), bits: bits.to_vec() }
}

/// Quantize a float input image to the model's input site scale.
pub fn quantize_input(qm: &QModel, input: &Tensor<f32>) -> Tensor<i8> {
    let s0 = qm.sites[0];
    Tensor::from_vec(&input.shape, input.data.iter().map(|&v| quantize_value(v, s0, 8)).collect())
}

/// Residual-add requant pair for block `r` (pre-shifted `<<8` semantics
/// of [`crate::nn::layers::qadd`]).
pub fn residual_requants(qm: &QModel, r: usize) -> (Requant, Requant) {
    let (skip, branch, out) = qm.analysis.residuals[r];
    let rq_skip = Requant::from_real_scale(qm.sites[skip] as f64 / qm.sites[out] as f64 / 256.0);
    let rq_branch =
        Requant::from_real_scale(qm.sites[branch] as f64 / qm.sites[out] as f64 / 256.0);
    (rq_skip, rq_branch)
}

/// Integer forward pass: int8 input → int32 logits. Bit-exact reference
/// for the ISS execution and the JAX artifact.
///
/// This contains **no graph walk of its own**: the model lowers once
/// (per configuration, through the keyed plan cache of
/// [`super::plan::plan_for`]) into an
/// [`ExecutionPlan`](super::plan::ExecutionPlan), and the host integer
/// executor [`super::plan::host_logits`] interprets the same plan the
/// ISS execution ([`super::sim_exec::run_plan`]) replays — host/ISS
/// structural agreement by construction.
pub fn qforward(qm: &QModel, input: &Tensor<i8>) -> Vec<i32> {
    // Host logits are mode-independent, so lower with baseline modes:
    // the baseline plan stages weights as zero-copy Arc clones instead
    // of packing nn_mac word streams this executor would never read.
    let modes = vec![None; qm.layers.len()];
    let plan = super::plan::plan_for(qm, &modes)
        .expect("model must lower to an execution plan (ends in a dense logits layer)");
    super::plan::host_logits(&plan, input)
}

/// Classify a batch: argmax of the integer logits.
pub fn qpredict(qm: &QModel, input: &Tensor<f32>) -> usize {
    let qi = quantize_input(qm, input);
    let logits = qforward(qm, &qi);
    argmax_i32(&logits)
}

/// Argmax helper (ties broken toward the lower index, as in jnp.argmax).
pub fn argmax_i32(v: &[i32]) -> usize {
    v.iter().enumerate().max_by(|a, b| a.1.cmp(b.1).then(b.0.cmp(&a.0))).map(|(i, _)| i).unwrap()
}

/// Float-model prediction.
pub fn fpredict(spec: &ModelSpec, params: &ModelParams, input: &Tensor<f32>) -> usize {
    let logits = float_forward(spec, params, input, None);
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
        .map(|(i, _)| i)
        .unwrap()
}
