//! Synthetic image-classification datasets — the substitution for
//! MNIST / CIFAR-10 / VWW / ImageNet (none of which are available in
//! this environment; see DESIGN.md §5).
//!
//! Each class has a smooth random prototype image; samples are the
//! prototype plus noise with a controlled margin, which reproduces the
//! property the paper's evaluation depends on: layers exhibit *graded*
//! sensitivity to weight bit-width, so the accuracy-vs-compression
//! Pareto structure of Fig. 6 emerges. The Python trainer uses the same
//! construction (independent RNG; distributional, not bitwise, match).

use crate::nn::tensor::Tensor;
use crate::rng::Rng;

/// A labelled dataset of float images in `[-1, 1]`.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Images (HWC).
    pub images: Vec<Tensor<f32>>,
    /// Labels in `0..num_classes`.
    pub labels: Vec<usize>,
    /// Class count.
    pub num_classes: usize,
}

/// Smooth a random field with a separable box blur (prototype texture).
fn smooth(t: &mut Tensor<f32>, passes: usize) {
    let (h, w, c) = (t.shape[0], t.shape[1], t.shape[2]);
    for _ in 0..passes {
        let src = t.clone();
        for y in 0..h {
            for x in 0..w {
                for ch in 0..c {
                    let mut s = 0.0;
                    let mut n = 0.0;
                    for dy in -1i64..=1 {
                        for dx in -1i64..=1 {
                            let (yy, xx) = (y as i64 + dy, x as i64 + dx);
                            if yy >= 0 && yy < h as i64 && xx >= 0 && xx < w as i64 {
                                s += src.at3(yy as usize, xx as usize, ch);
                                n += 1.0;
                            }
                        }
                    }
                    *t.at3_mut(y, x, ch) = s / n;
                }
            }
        }
    }
}

/// Generate a dataset with `n` samples of shape `[h, w, c]` across
/// `num_classes` classes. `noise` controls the class margin (0.3–0.6
/// gives the graded-difficulty regime used by the experiments).
///
/// Prototypes (the *task*) are derived from `seed`'s high bits so that
/// [`generate_split`] can produce train/test splits sharing prototypes.
pub fn generate(
    seed: u64,
    n: usize,
    shape: [usize; 3],
    num_classes: usize,
    noise: f32,
) -> Dataset {
    generate_split(seed, seed ^ 0xA5A5_5A5A, n, shape, num_classes, noise)
}

/// Like [`generate`] but with separate prototype and sample seeds:
/// datasets sharing `proto_seed` are splits of the same task.
pub fn generate_split(
    proto_seed: u64,
    sample_seed: u64,
    n: usize,
    shape: [usize; 3],
    num_classes: usize,
    noise: f32,
) -> Dataset {
    let mut rng = Rng::new(proto_seed);
    let protos: Vec<Tensor<f32>> = (0..num_classes)
        .map(|_| {
            let mut t = Tensor::from_vec(
                &shape,
                (0..shape.iter().product::<usize>()).map(|_| rng.normal()).collect(),
            );
            smooth(&mut t, 2);
            // Normalise prototype to unit abs-max.
            let m = t.abs_max().max(1e-6);
            for v in &mut t.data {
                *v = (*v / m).clamp(-1.0, 1.0);
            }
            t
        })
        .collect();
    let mut rng = Rng::new(sample_seed);
    let mut images = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let label = i % num_classes;
        let proto = &protos[label];
        let gain = 0.8 + 0.4 * rng.f32();
        let data = proto
            .data
            .iter()
            .map(|&v| (v * gain + rng.normal() * noise).clamp(-1.0, 1.0))
            .collect();
        images.push(Tensor::from_vec(&shape, data));
        labels.push(label);
    }
    Dataset { images, labels, num_classes }
}

/// Classification accuracy of a predictor over the dataset.
pub fn accuracy(ds: &Dataset, mut predict: impl FnMut(&Tensor<f32>) -> usize) -> f32 {
    let correct = ds
        .images
        .iter()
        .zip(&ds.labels)
        .filter(|(img, &label)| predict(img) == label)
        .count();
    correct as f32 / ds.images.len() as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_balanced() {
        let a = generate(7, 40, [8, 8, 3], 4, 0.3);
        let b = generate(7, 40, [8, 8, 3], 4, 0.3);
        assert_eq!(a.images[0].data, b.images[0].data);
        for c in 0..4 {
            assert_eq!(a.labels.iter().filter(|&&l| l == c).count(), 10);
        }
    }

    #[test]
    fn values_bounded() {
        let ds = generate(1, 20, [6, 6, 1], 2, 0.5);
        for img in &ds.images {
            assert!(img.data.iter().all(|v| (-1.0..=1.0).contains(v)));
        }
    }

    #[test]
    fn nearest_prototype_separable() {
        // A trivial nearest-prototype classifier must beat chance by a
        // wide margin at moderate noise — the margin knob works.
        let ds = generate(3, 60, [8, 8, 1], 3, 0.3);
        let protos: Vec<&Tensor<f32>> =
            (0..3).map(|c| &ds.images[ds.labels.iter().position(|&l| l == c).unwrap()]).collect();
        let acc = accuracy(&ds, |img| {
            (0..3)
                .min_by(|&a, &b| {
                    let d = |p: &Tensor<f32>| -> f32 {
                        p.data.iter().zip(&img.data).map(|(x, y)| (x - y) * (x - y)).sum()
                    };
                    d(protos[a]).partial_cmp(&d(protos[b])).unwrap()
                })
                .unwrap()
        });
        assert!(acc > 0.6, "nearest-prototype accuracy {acc}");
    }
}
