//! The `.mpw` model-artifact format — trained weights, calibrated
//! activation scales, the float-baseline accuracy and the held-out test
//! set, written by `python/compile/train.py` and loaded here. A Rust
//! writer exists too (round-trip tested) so the whole pipeline can run
//! artifact-free with randomly-initialised models.
//!
//! Layout (little-endian):
//!
//! ```text
//! magic "MPW1"
//! u32 name_len, utf8 name
//! u32 h, w, c, num_classes
//! u32 n_nodes, then nodes:
//!   u8 0 (layer)    + layer encoding
//!   u8 1 (residual) + u32 n_inner + inner layer encodings
//! layer encoding: u8 kind (0 conv | 1 dw | 2 dense | 3 maxpool2 | 4 avgpool)
//!   conv:  u32 cout,k,stride,pad + u8 relu
//!   dw:    u32 k,stride,pad     + u8 relu
//!   dense: u32 out              + u8 relu
//! u32 n_params, per layer: u32 w_len, u32 b_len, f32*w_len, f32*b_len
//! u32 n_sites, f32*n_sites
//! f32 float_accuracy
//! u32 n_test, f32 images [n_test·h·w·c], u8 labels [n_test]
//! ```

use super::infer::{LayerParams, ModelParams};
use super::synthetic::Dataset;
use super::{LayerSpec, ModelSpec, Node};
use crate::nn::tensor::Tensor;
use crate::bail;
use crate::error::{Context, Result};
use std::io::{Read, Write};
use std::path::Path;

/// A fully-loaded model artifact.
#[derive(Debug, Clone)]
pub struct LoadedModel {
    /// The model spec parsed from the artifact (validated against the
    /// in-crate zoo when a name matches).
    pub spec: ModelSpec,
    /// Trained float parameters.
    pub params: ModelParams,
    /// Calibrated activation scales (one per site).
    pub sites: Vec<f32>,
    /// Float-model test accuracy recorded by the trainer.
    pub float_acc: f32,
    /// Held-out test set.
    pub test: Dataset,
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("artifact truncated at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn f32(&mut self) -> Result<f32> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }
    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.take(4 * n)?;
        Ok(b.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }
}

fn read_layer(r: &mut Reader) -> Result<LayerSpec> {
    Ok(match r.u8()? {
        0 => LayerSpec::Conv {
            cout: r.u32()? as usize,
            k: r.u32()? as usize,
            stride: r.u32()? as usize,
            pad: r.u32()? as usize,
            relu: r.u8()? != 0,
        },
        1 => LayerSpec::Depthwise {
            k: r.u32()? as usize,
            stride: r.u32()? as usize,
            pad: r.u32()? as usize,
            relu: r.u8()? != 0,
        },
        2 => LayerSpec::Dense { out: r.u32()? as usize, relu: r.u8()? != 0 },
        3 => LayerSpec::MaxPool2,
        4 => LayerSpec::AvgPoolGlobal,
        k => bail!("unknown layer kind {k}"),
    })
}

fn write_layer(out: &mut Vec<u8>, l: &LayerSpec) {
    match *l {
        LayerSpec::Conv { cout, k, stride, pad, relu } => {
            out.push(0);
            for v in [cout, k, stride, pad] {
                out.extend((v as u32).to_le_bytes());
            }
            out.push(relu as u8);
        }
        LayerSpec::Depthwise { k, stride, pad, relu } => {
            out.push(1);
            for v in [k, stride, pad] {
                out.extend((v as u32).to_le_bytes());
            }
            out.push(relu as u8);
        }
        LayerSpec::Dense { out: o, relu } => {
            out.push(2);
            out.extend((o as u32).to_le_bytes());
            out.push(relu as u8);
        }
        LayerSpec::MaxPool2 => out.push(3),
        LayerSpec::AvgPoolGlobal => out.push(4),
    }
}

/// Parse an `.mpw` artifact from bytes.
pub fn parse(bytes: &[u8]) -> Result<LoadedModel> {
    let mut r = Reader { buf: bytes, pos: 0 };
    if r.take(4)? != b"MPW1" {
        bail!("bad magic (not an .mpw artifact)");
    }
    let name_len = r.u32()? as usize;
    let name = String::from_utf8(r.take(name_len)?.to_vec()).context("artifact name")?;
    let input = [r.u32()? as usize, r.u32()? as usize, r.u32()? as usize];
    let num_classes = r.u32()? as usize;
    let n_nodes = r.u32()? as usize;
    let mut nodes = Vec::with_capacity(n_nodes);
    for _ in 0..n_nodes {
        match r.u8()? {
            0 => nodes.push(Node::Layer(read_layer(&mut r)?)),
            1 => {
                let n = r.u32()? as usize;
                let mut inner = Vec::with_capacity(n);
                for _ in 0..n {
                    inner.push(read_layer(&mut r)?);
                }
                nodes.push(Node::Residual(inner));
            }
            k => bail!("unknown node kind {k}"),
        }
    }
    // Resolve the name against the in-crate zoo (gives the 'static str)
    // and validate structural equality.
    let spec = match super::zoo::by_name(&name) {
        Some(z) => {
            let parsed = ModelSpec { name: z.name, input, num_classes, nodes };
            if parsed != z {
                bail!("artifact `{name}` disagrees with the in-crate model zoo definition");
            }
            z
        }
        None => bail!("unknown model `{name}` (not in the zoo)"),
    };

    let n_params = r.u32()? as usize;
    let analysis = super::analyze(&spec);
    if n_params != analysis.layers.len() {
        bail!("artifact has {n_params} parameter blocks, model needs {}", analysis.layers.len());
    }
    let mut params = Vec::with_capacity(n_params);
    for info in &analysis.layers {
        let w_len = r.u32()? as usize;
        let b_len = r.u32()? as usize;
        if w_len != info.w_len || b_len != info.b_len {
            bail!("parameter block shape mismatch: got ({w_len},{b_len}), want ({},{})", info.w_len, info.b_len);
        }
        params.push(LayerParams { w: r.f32s(w_len)?, b: r.f32s(b_len)? });
    }
    let n_sites = r.u32()? as usize;
    if n_sites != analysis.n_sites {
        bail!("artifact has {n_sites} sites, model walk has {}", analysis.n_sites);
    }
    let sites = r.f32s(n_sites)?;
    if sites.iter().any(|&s| !(s > 0.0)) {
        bail!("non-positive activation scale in artifact");
    }
    let float_acc = r.f32()?;
    let n_test = r.u32()? as usize;
    let px = input[0] * input[1] * input[2];
    let mut images = Vec::with_capacity(n_test);
    for _ in 0..n_test {
        images.push(Tensor::from_vec(&input, r.f32s(px)?));
    }
    let labels: Vec<usize> = r.take(n_test)?.iter().map(|&b| b as usize).collect();
    if labels.iter().any(|&l| l >= num_classes) {
        bail!("test label out of range");
    }
    Ok(LoadedModel {
        spec,
        params,
        sites,
        float_acc,
        test: Dataset { images, labels, num_classes },
    })
}

/// Serialize a model artifact (Rust writer — used by tests and the
/// artifact-free fallback path).
pub fn serialize(
    spec: &ModelSpec,
    params: &ModelParams,
    sites: &[f32],
    float_acc: f32,
    test: &Dataset,
) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend(b"MPW1");
    out.extend((spec.name.len() as u32).to_le_bytes());
    out.extend(spec.name.as_bytes());
    for v in [spec.input[0], spec.input[1], spec.input[2], spec.num_classes] {
        out.extend((v as u32).to_le_bytes());
    }
    out.extend((spec.nodes.len() as u32).to_le_bytes());
    for node in &spec.nodes {
        match node {
            Node::Layer(l) => {
                out.push(0);
                write_layer(&mut out, l);
            }
            Node::Residual(inner) => {
                out.push(1);
                out.extend((inner.len() as u32).to_le_bytes());
                for l in inner {
                    write_layer(&mut out, l);
                }
            }
        }
    }
    out.extend((params.len() as u32).to_le_bytes());
    for p in params {
        out.extend((p.w.len() as u32).to_le_bytes());
        out.extend((p.b.len() as u32).to_le_bytes());
        for &v in &p.w {
            out.extend(v.to_le_bytes());
        }
        for &v in &p.b {
            out.extend(v.to_le_bytes());
        }
    }
    out.extend((sites.len() as u32).to_le_bytes());
    for &s in sites {
        out.extend(s.to_le_bytes());
    }
    out.extend(float_acc.to_le_bytes());
    out.extend((test.images.len() as u32).to_le_bytes());
    for img in &test.images {
        for &v in &img.data {
            out.extend(v.to_le_bytes());
        }
    }
    out.extend(test.labels.iter().map(|&l| l as u8));
    out
}

/// Load an artifact from `artifacts/weights/<name>.mpw`.
pub fn load_file(path: &Path) -> Result<LoadedModel> {
    let mut f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    parse(&buf)
}

/// Write an artifact file.
pub fn write_file(path: &Path, bytes: &[u8]) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(bytes)?;
    Ok(())
}

/// Standard artifact path for a model name.
pub fn artifact_path(root: &Path, name: &str) -> std::path::PathBuf {
    root.join("weights").join(format!("{name}.mpw"))
}

/// Load a model artifact if present, else build a self-contained
/// fallback: random init + Rust-side calibration on a synthetic set.
/// The fallback keeps every harness runnable before `make artifacts`.
pub fn load_or_fallback(root: &Path, name: &str, seed: u64) -> Result<LoadedModel> {
    let path = artifact_path(root, name);
    if path.exists() {
        return load_file(&path);
    }
    let spec = super::zoo::by_name(name)
        .with_context(|| format!("unknown model `{name}`"))?;
    let params = super::infer::random_params(&spec, seed);
    let calib =
        super::synthetic::generate_split(seed, seed ^ 0x5EED, 16, spec.input, spec.num_classes, 0.4);
    let sites = super::infer::calibrate(&spec, &params, &calib.images);
    let test =
        super::synthetic::generate_split(seed, seed ^ 0x7E57, 64, spec.input, spec.num_classes, 0.4);
    let float_acc =
        super::synthetic::accuracy(&test, |img| super::infer::fpredict(&spec, &params, img));
    Ok(LoadedModel { spec, params, sites, float_acc, test })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::infer::random_params;
    use crate::models::synthetic::generate;
    use crate::models::zoo;

    #[test]
    fn round_trips_lenet() {
        let spec = zoo::lenet5();
        let params = random_params(&spec, 1);
        let a = crate::models::analyze(&spec);
        let sites = vec![0.01f32; a.n_sites];
        let test = generate(2, 8, spec.input, spec.num_classes, 0.4);
        let bytes = serialize(&spec, &params, &sites, 0.5, &test);
        let loaded = parse(&bytes).unwrap();
        assert_eq!(loaded.spec, spec);
        assert_eq!(loaded.params.len(), params.len());
        assert_eq!(loaded.params[0].w, params[0].w);
        assert_eq!(loaded.sites, sites);
        assert_eq!(loaded.float_acc, 0.5);
        assert_eq!(loaded.test.labels, test.labels);
        assert_eq!(loaded.test.images[3].data, test.images[3].data);
    }

    #[test]
    fn round_trips_residual_model() {
        let spec = zoo::mcunet_vww();
        let params = random_params(&spec, 3);
        let a = crate::models::analyze(&spec);
        let sites = vec![0.02f32; a.n_sites];
        let test = generate(4, 4, spec.input, spec.num_classes, 0.4);
        let bytes = serialize(&spec, &params, &sites, 0.9, &test);
        let loaded = parse(&bytes).unwrap();
        assert_eq!(loaded.spec, spec);
    }

    #[test]
    fn rejects_corrupt() {
        assert!(parse(b"nope").is_err());
        let spec = zoo::lenet5();
        let params = random_params(&spec, 1);
        let a = crate::models::analyze(&spec);
        let test = generate(2, 2, spec.input, spec.num_classes, 0.4);
        let mut bytes = serialize(&spec, &params, &vec![0.01; a.n_sites], 0.5, &test);
        bytes.truncate(bytes.len() - 10);
        assert!(parse(&bytes).is_err());
    }

    #[test]
    fn fallback_works_without_artifacts() {
        let tmp = std::env::temp_dir().join("mpnn-no-artifacts");
        let m = load_or_fallback(&tmp, "lenet5", 7).unwrap();
        assert_eq!(m.spec.name, "lenet5");
        assert_eq!(m.test.images.len(), 64);
        assert!(m.sites.iter().all(|&s| s > 0.0));
    }
}
