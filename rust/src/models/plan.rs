//! Lowered execution-plan IR: one compiled model plan driving **both**
//! the host golden reference and the ISS execution.
//!
//! Before this layer existed, [`super::infer::qforward`] and
//! [`super::sim_exec::run_model`] each re-walked the [`ModelSpec`]
//! graph on every run of every batch input — re-deriving kernel specs,
//! requant parameters, spatial/channel padding and residual-site
//! bookkeeping twice, in two hand-synchronized code paths. An
//! [`ExecutionPlan`] lowers a `(QModel, modes)` pair **once** into a
//! linear step list with everything resolved:
//!
//! * [`Step::Kernel`] carries the fully-resolved
//!   [`ConvSpec`] / [`DwSpec`] / [`DenseSpec`], the [`MacMode`], the
//!   requant parameters, the activation-site indices **and the staged
//!   weight operands** — spatially/channel-padded and (for mode
//!   kernels) packed into the exact `nn_mac` word stream the ISS
//!   runner writes into simulator memory. Per-run work shrinks to
//!   per-input tensor movement.
//! * The host glue the paper keeps off the core — pooling, residual
//!   save/add — lowers to [`Step::MaxPool2`] / [`Step::AvgPoolGlobal`]
//!   / [`Step::SaveSkip`] / [`Step::AddSkip`] with the residual
//!   requant pair pre-computed.
//!
//! Both executors are thin interpreters over the *same* plan:
//! [`host_logits`] (the integer golden reference behind
//! [`super::infer::qforward`]) and
//! [`super::sim_exec::run_plan`] (the ISS execution). Structural
//! host-vs-ISS agreement is therefore true **by construction** — the
//! two paths cannot walk the graph differently because neither walks
//! the graph at all.
//!
//! ## Plan cache
//!
//! [`plan_for`] memoises compiled plans in a process-wide keyed cache:
//! the key is `(model name, bits, modes)` plus a content fingerprint
//! (FNV-1a over the spec structure, site scales and quantized layer
//! parameters), so two models that merely share a name never collide
//! and an in-place mutated `QModel` (the divergence tests do this)
//! recompiles instead of replaying a stale plan. DSE sweeps and
//! [`super::sim_exec::run_model_batch`] compile each configuration
//! exactly once and replay it across the whole input batch; hits and
//! compiles are counted on the global
//! [`SessionStats`](crate::sim::session::SessionStats)
//! (`plan_compiles` / `plan_hits`). The cache is bounded
//! ([`MAX_PLANS`], FIFO eviction) because plans own staged weight
//! copies.
//!
//! ## Observer hooks
//!
//! The ISS plan executor accepts an optional [`PlanObserver`]: one
//! [`StepEvent`] per executed step, in plan order, *after* the step
//! completes — kernel steps carry the layer's [`PerfCounters`], host
//! glue steps carry `None`. This is the step-granular trace surface
//! (see [`super::sim_exec::StepTrace`] and `mpnn trace
//! --trace-steps`); it needs no legacy-interpreter fallback because
//! the plan executor *is* the production path.

use super::infer::{residual_requants, QModel};
use super::{LayerSpec, ModelSpec, Node};
use crate::error::Result;
use crate::isa::MacMode;
use crate::kernels::conv::ConvSpec;
use crate::kernels::dense::DenseSpec;
use crate::kernels::depthwise::DwSpec;
use crate::nn::layers::{qadd, qavgpool_global, qconv2d, qdense, qdepthwise, qmaxpool2, ConvGeom};
use crate::nn::pack::{pack_conv, pack_dense, pack_depthwise};
use crate::nn::quant::Requant;
use crate::nn::tensor::Tensor;
use crate::sim::PerfCounters;
use crate::{bail, ensure};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, OnceLock};

/// Staged weight operand owned by a plan — exactly the bytes/words the
/// ISS runner writes into simulator memory, produced once at compile.
#[derive(Debug, Clone)]
pub enum PlanWeights {
    /// Raw int8 stream (baseline kernels; channel-padded for conv).
    Bytes(Arc<Vec<i8>>),
    /// Packed `nn_mac` word stream (mode kernels).
    Words(Arc<Vec<u32>>),
}

impl PlanWeights {
    /// Borrow as the kernel runners' staged-weight view.
    pub fn staged(&self) -> crate::kernels::run::StagedWeights<'_> {
        match self {
            PlanWeights::Bytes(b) => crate::kernels::run::StagedWeights::Bytes(b.as_slice()),
            PlanWeights::Words(w) => crate::kernels::run::StagedWeights::Words(w.as_slice()),
        }
    }
}

/// Fully-resolved geometry of one kernel step.
#[derive(Debug, Clone)]
pub enum KernelOp {
    /// Standard convolution.
    Conv {
        /// ISS kernel spec: pre-padded spatial dims, channel-padded
        /// `cin` (mode kernels need `Cin % 4 == 0`).
        spec: ConvSpec,
        /// Logical geometry for the host reference (pads internally).
        geom: ConvGeom,
        /// Output channels.
        cout: usize,
        /// Logical (unpadded) input channels.
        cin: usize,
    },
    /// Depthwise convolution.
    Depthwise {
        /// ISS kernel spec (pre-padded spatial dims).
        spec: DwSpec,
        /// Logical geometry for the host reference.
        geom: ConvGeom,
    },
    /// Fully-connected layer.
    Dense {
        /// ISS kernel spec (`out_i32` set on the logits layer).
        spec: DenseSpec,
    },
}

/// One quantizable layer lowered to a kernel invocation.
#[derive(Debug, Clone)]
pub struct KernelStep {
    /// Quantizable-layer index (canonical [`super::analyze`] order).
    pub layer: usize,
    /// Resolved geometry + ISS kernel spec.
    pub op: KernelOp,
    /// Kernel mode (`None` = scalar baseline).
    pub mode: Option<MacMode>,
    /// Fused ReLU.
    pub relu: bool,
    /// Output requantization parameters.
    pub rq: Requant,
    /// Input activation-scale site.
    pub site_in: usize,
    /// Output activation-scale site.
    pub site_out: usize,
    /// Final logits layer (raw int32 out, terminates the plan).
    pub is_last: bool,
    /// Weights in the host reference's logical layout.
    pub host_w: Arc<Vec<i8>>,
    /// Weights staged for the ISS (padded and/or packed).
    pub iss_w: PlanWeights,
    /// Int32 biases (accumulator scale).
    pub bias: Arc<Vec<i32>>,
}

/// One lowered step of the plan.
#[derive(Debug, Clone)]
pub enum Step {
    /// A quantizable layer executed as a kernel.
    Kernel(KernelStep),
    /// 2×2 stride-2 max pool (host glue; site unchanged).
    MaxPool2,
    /// Global average pool (host glue; site unchanged).
    AvgPoolGlobal,
    /// Push the current tensor as the residual skip input.
    SaveSkip,
    /// Pop the saved skip and add:
    /// `out = rescale(skip) + rescale(branch)` ([`qadd`] semantics).
    AddSkip {
        /// Skip-path requant into the output site.
        rq_skip: Requant,
        /// Branch-path requant into the output site.
        rq_branch: Requant,
        /// The add's output activation-scale site.
        site_out: usize,
    },
}

impl Step {
    /// Short step-kind label (observer events, traces).
    pub fn kind(&self) -> &'static str {
        match self {
            Step::Kernel(k) => match k.op {
                KernelOp::Conv { .. } => "conv",
                KernelOp::Depthwise { .. } => "depthwise",
                KernelOp::Dense { .. } => "dense",
            },
            Step::MaxPool2 => "maxpool2",
            Step::AvgPoolGlobal => "avgpool_global",
            Step::SaveSkip => "save_skip",
            Step::AddSkip { .. } => "add_skip",
        }
    }
}

/// A lowered, immutable execution plan — compiled once per
/// `(QModel, modes)`, replayed for every input.
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    /// Model name.
    pub model: String,
    /// Per-layer weight bit-widths (the DSE configuration).
    pub bits: Vec<u32>,
    /// Per-layer kernel modes this plan was lowered for.
    pub modes: Vec<Option<MacMode>>,
    /// Expected input shape `[H, W, C]`.
    pub input_shape: [usize; 3],
    /// Classification classes (logits length).
    pub num_classes: usize,
    /// The linear step list; the final step is the `is_last` dense.
    pub steps: Vec<Step>,
}

impl ExecutionPlan {
    /// Number of kernel (quantizable-layer) steps.
    pub fn kernel_steps(&self) -> usize {
        self.steps.iter().filter(|s| matches!(s, Step::Kernel(_))).count()
    }
}

/// Per-step observer for the ISS plan executor (tracing/profiling).
/// Called once per executed step, in plan order, after the step
/// completes.
pub trait PlanObserver {
    /// Observe one executed step.
    fn on_step(&mut self, ev: &StepEvent<'_>);
}

/// What one executed step looked like.
#[derive(Debug)]
pub struct StepEvent<'a> {
    /// Step index into [`ExecutionPlan::steps`].
    pub index: usize,
    /// Step kind label ([`Step::kind`]).
    pub kind: &'static str,
    /// Quantizable-layer index (kernel steps only).
    pub layer: Option<usize>,
    /// Kernel mode (kernel steps only; `None` also means baseline).
    pub mode: Option<MacMode>,
    /// The step's own perf counters (kernel steps only — host glue
    /// runs off-core and has no cycle cost by the paper's accounting).
    pub perf: Option<&'a PerfCounters>,
}

/// The kernel modes matching each layer's quantized bit-width — the
/// extended-ISA execution this plan cache keys the host reference on.
pub fn canonical_modes(qm: &QModel) -> Vec<Option<MacMode>> {
    qm.bits.iter().map(|&b| MacMode::from_weight_bits(b)).collect()
}

/// Pad conv weights `[Cout][K][K][Cin]` to `[Cout][K][K][Cin_p]` with
/// zeros (mode kernels need word-aligned channel runs).
fn pad_conv_weights(qw: &[i8], cout: usize, k: usize, cin: usize, cin_p: usize) -> Vec<i8> {
    if cin == cin_p {
        return qw.to_vec();
    }
    let mut out = vec![0i8; cout * k * k * cin_p];
    for oc in 0..cout {
        for t in 0..k * k {
            let src = (oc * k * k + t) * cin;
            let dst = (oc * k * k + t) * cin_p;
            out[dst..dst + cin].copy_from_slice(&qw[src..src + cin]);
        }
    }
    out
}

/// Lower one quantized model under a per-layer mode assignment into an
/// [`ExecutionPlan`]. This is the **only** graph walk left in the
/// execution stack; everything downstream interprets the step list.
pub fn compile(qm: &QModel, modes: &[Option<MacMode>]) -> Result<ExecutionPlan> {
    ensure!(modes.len() == qm.layers.len(), "one mode per quantizable layer");
    let mut steps = Vec::new();
    let mut li = 0usize;
    let mut res_i = 0usize;
    let mut done = false;

    let mut lower_layer = |l: &LayerSpec, steps: &mut Vec<Step>| -> Result<bool> {
        match *l {
            LayerSpec::MaxPool2 => {
                steps.push(Step::MaxPool2);
                return Ok(false);
            }
            LayerSpec::AvgPoolGlobal => {
                steps.push(Step::AvgPoolGlobal);
                return Ok(false);
            }
            _ => {}
        }
        let idx = li;
        li += 1;
        let q = &qm.layers[idx];
        let info = &qm.analysis.layers[idx];
        let mode = modes[idx];
        if let Some(m) = mode {
            ensure!(
                m.weight_bits() == q.w_bits,
                "layer {idx}: kernel mode {m:?} vs quantized bits {}",
                q.w_bits
            );
        }
        let host_w = Arc::new(q.qw.clone());
        let bias = Arc::new(q.bias.clone());
        let step = match *l {
            LayerSpec::Conv { cout, k, stride, pad, relu } => {
                let cin = info.in_shape[2];
                // Mode kernels need Cin % 4 == 0: the executor
                // channel-pads the input, the plan pre-pads the weights.
                let cin_p = if mode.is_some() { cin.div_ceil(4) * 4 } else { cin };
                let spec = ConvSpec {
                    h: info.in_shape[0] + 2 * pad,
                    w: info.in_shape[1] + 2 * pad,
                    cin: cin_p,
                    cout,
                    k,
                    stride,
                    rq: q.rq,
                    relu,
                };
                let iss_w = match mode {
                    None => PlanWeights::Bytes(Arc::clone(&host_w)),
                    Some(m) => {
                        let padded = pad_conv_weights(&q.qw, cout, k, cin, cin_p);
                        PlanWeights::Words(Arc::new(pack_conv(m, &padded, cout, k, cin_p)))
                    }
                };
                KernelStep {
                    layer: idx,
                    op: KernelOp::Conv { spec, geom: ConvGeom { k, stride, pad }, cout, cin },
                    mode,
                    relu,
                    rq: q.rq,
                    site_in: info.site_in,
                    site_out: info.site_out,
                    is_last: false,
                    host_w,
                    iss_w,
                    bias,
                }
            }
            LayerSpec::Depthwise { k, stride, pad, relu } => {
                let c = info.in_shape[2];
                let spec = DwSpec {
                    h: info.in_shape[0] + 2 * pad,
                    w: info.in_shape[1] + 2 * pad,
                    c,
                    k,
                    stride,
                    rq: q.rq,
                    relu,
                };
                let iss_w = match mode {
                    None => PlanWeights::Bytes(Arc::clone(&host_w)),
                    Some(m) => PlanWeights::Words(Arc::new(pack_depthwise(m, &q.qw, c, k))),
                };
                KernelStep {
                    layer: idx,
                    op: KernelOp::Depthwise { spec, geom: ConvGeom { k, stride, pad } },
                    mode,
                    relu,
                    rq: q.rq,
                    site_in: info.site_in,
                    site_out: info.site_out,
                    is_last: false,
                    host_w,
                    iss_w,
                    bias,
                }
            }
            LayerSpec::Dense { out, relu } => {
                let in_dim = info.in_shape[2];
                let is_last = info.is_last;
                let spec = DenseSpec { in_dim, out_dim: out, rq: q.rq, relu, out_i32: is_last };
                let iss_w = match mode {
                    None => PlanWeights::Bytes(Arc::clone(&host_w)),
                    Some(m) => PlanWeights::Words(Arc::new(pack_dense(m, &q.qw, out, in_dim))),
                };
                KernelStep {
                    layer: idx,
                    op: KernelOp::Dense { spec },
                    mode,
                    relu,
                    rq: q.rq,
                    site_in: info.site_in,
                    site_out: info.site_out,
                    is_last,
                    host_w,
                    iss_w,
                    bias,
                }
            }
            _ => unreachable!("pool handled above"),
        };
        let is_last = step.is_last;
        steps.push(Step::Kernel(step));
        Ok(is_last)
    };

    'nodes: for node in &qm.spec.nodes {
        match node {
            Node::Layer(l) => {
                if lower_layer(l, &mut steps)? {
                    done = true;
                    break 'nodes;
                }
            }
            Node::Residual(inner) => {
                steps.push(Step::SaveSkip);
                for l in inner {
                    ensure!(
                        !lower_layer(l, &mut steps)?,
                        "model must end in a dense logits layer (not inside a residual)"
                    );
                }
                let (rq_skip, rq_branch) = residual_requants(qm, res_i);
                let (_, _, site_out) = qm.analysis.residuals[res_i];
                res_i += 1;
                steps.push(Step::AddSkip { rq_skip, rq_branch, site_out });
            }
        }
    }
    if !done {
        bail!("model must end in a dense logits layer");
    }
    Ok(ExecutionPlan {
        model: qm.spec.name.to_string(),
        bits: qm.bits.clone(),
        modes: modes.to_vec(),
        input_shape: qm.spec.input,
        num_classes: qm.spec.num_classes,
        steps,
    })
}

// ----------------------------------------------------- host executor ---

/// Tensor-or-flat value flowing between steps — shared by both plan
/// interpreters (host here, ISS in [`super::sim_exec::run_plan`]).
pub(crate) enum Flow {
    /// A feature map (HWC tensor).
    Map(Tensor<i8>),
    /// A flattened activation vector (dense layers).
    Flat(Vec<i8>),
}

impl Flow {
    pub(crate) fn flat(self) -> Vec<i8> {
        match self {
            Flow::Map(t) => t.data,
            Flow::Flat(v) => v,
        }
    }
    pub(crate) fn map(self) -> Tensor<i8> {
        match self {
            Flow::Map(t) => t,
            Flow::Flat(_) => panic!("expected a feature map"),
        }
    }
}

/// Host integer executor: interpret the plan with the bit-exact `nn`
/// layer implementations. This **is** the golden reference — the same
/// plan the ISS executor replays, so the two paths agree structurally
/// by construction. Returns the raw int32 logits.
pub fn host_logits(plan: &ExecutionPlan, input: &Tensor<i8>) -> Vec<i32> {
    let mut x = Flow::Map(input.clone());
    let mut skips: Vec<Tensor<i8>> = Vec::new();
    for step in &plan.steps {
        match step {
            Step::Kernel(ks) => match &ks.op {
                KernelOp::Conv { geom, cout, .. } => {
                    x = Flow::Map(qconv2d(
                        &x.map(),
                        &ks.host_w,
                        &ks.bias,
                        *cout,
                        *geom,
                        ks.rq,
                        ks.relu,
                    ));
                }
                KernelOp::Depthwise { geom, .. } => {
                    x = Flow::Map(qdepthwise(&x.map(), &ks.host_w, &ks.bias, *geom, ks.rq, ks.relu));
                }
                KernelOp::Dense { spec } => {
                    let flat = x.flat();
                    if ks.is_last {
                        let (_, accs) =
                            qdense(&flat, &ks.host_w, &ks.bias, spec.out_dim, None, false);
                        return accs;
                    }
                    let (qv, _) =
                        qdense(&flat, &ks.host_w, &ks.bias, spec.out_dim, Some(ks.rq), ks.relu);
                    x = Flow::Flat(qv);
                }
            },
            Step::MaxPool2 => x = Flow::Map(qmaxpool2(&x.map())),
            Step::AvgPoolGlobal => {
                let m = x.map();
                let c = m.shape[2];
                x = Flow::Map(Tensor::from_vec(&[1, 1, c], qavgpool_global(&m)));
            }
            Step::SaveSkip => {
                let m = x.map();
                skips.push(m.clone());
                x = Flow::Map(m);
            }
            Step::AddSkip { rq_skip, rq_branch, .. } => {
                let skip = skips.pop().expect("AddSkip without SaveSkip");
                x = Flow::Map(qadd(&skip, *rq_skip, &x.map(), *rq_branch));
            }
        }
    }
    unreachable!("compile guarantees the plan ends in an is_last dense step")
}

// -------------------------------------------------------- plan cache ---

/// Bound on cached plans (FIFO eviction). Plans own staged weight
/// copies (~2× the model's weight bytes each), so an unbounded
/// never-evicted cache — fine for the kernel cache, whose entries are
/// instruction streams — would retain large dead plans: a DSE sweep
/// touches each `(model, config)` key exactly once, and the reuse
/// that matters (batch replay) holds the `Arc` directly. The bound is
/// therefore deliberately small; eviction never forces a recompile in
/// a sweep because each configuration is evaluated once.
pub const MAX_PLANS: usize = 32;

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct PlanKey {
    model: String,
    bits: Vec<u32>,
    modes: Vec<Option<MacMode>>,
    fingerprint: u64,
}

/// FNV-1a content fingerprint of everything the plan lowers from: the
/// spec structure, the site scales and the quantized layer parameters.
/// Two `QModel`s that merely share `(name, bits)` — different seeds,
/// or a test-mutated copy — therefore never share a plan.
fn fingerprint(qm: &QModel, spec_repr: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    };
    for b in spec_repr.bytes() {
        eat(b);
    }
    for &s in &qm.sites {
        for b in s.to_bits().to_le_bytes() {
            eat(b);
        }
    }
    for l in &qm.layers {
        for b in l.w_bits.to_le_bytes() {
            eat(b);
        }
        for b in l.rq.m.to_le_bytes() {
            eat(b);
        }
        for b in l.rq.shift.to_le_bytes() {
            eat(b);
        }
        for &w in &l.qw {
            eat(w as u8);
        }
        for &b32 in &l.bias {
            for b in b32.to_le_bytes() {
                eat(b);
            }
        }
    }
    h
}

fn key_for(qm: &QModel, modes: &[Option<MacMode>]) -> PlanKey {
    let spec_repr = spec_structure(&qm.spec);
    PlanKey {
        model: qm.spec.name.to_string(),
        bits: qm.bits.clone(),
        modes: modes.to_vec(),
        fingerprint: fingerprint(qm, &spec_repr),
    }
}

/// Canonical textual form of the graph structure (Debug is stable and
/// covers every geometry field the lowering reads).
fn spec_structure(spec: &ModelSpec) -> String {
    format!("{:?}|{:?}|{}", spec.input, spec.nodes, spec.num_classes)
}

/// Public FNV-1a content key of one evaluation *subject*: everything
/// the plan lowering reads (spec structure, site scales, quantized
/// weights/bias/requant, per-layer widths) folded together with the
/// model name, the configuration's bit vector and the per-layer kernel
/// modes. Two models that differ anywhere the lowering can see — or
/// the same model lowered under different modes — never share a
/// fingerprint, which is exactly the property the content-addressed
/// result store ([`crate::store::StoreKey`]) keys on.
pub fn content_fingerprint(qm: &QModel, modes: &[Option<MacMode>]) -> u64 {
    let mut h = fingerprint(qm, &spec_structure(&qm.spec));
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    };
    for b in qm.spec.name.bytes() {
        eat(b);
    }
    eat(0xff); // name / bits separator
    for &w in &qm.bits {
        for b in w.to_le_bytes() {
            eat(b);
        }
    }
    // One byte per layer mode: 0 = baseline (no nn_mac), else the
    // mode's weight width (8/4/2) — distinct for every MacMode.
    for m in modes {
        eat(match m {
            None => 0,
            Some(mm) => mm.weight_bits() as u8,
        });
    }
    h
}

#[derive(Default)]
struct PlanCache {
    map: HashMap<PlanKey, Arc<ExecutionPlan>>,
    order: VecDeque<PlanKey>,
}

fn cache() -> &'static Mutex<PlanCache> {
    static CACHE: OnceLock<Mutex<PlanCache>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(PlanCache::default()))
}

/// Distinct plans currently cached (observability/tests).
pub fn plan_cache_len() -> usize {
    cache().lock().unwrap().map.len()
}

/// Fetch (or compile + insert) the plan for `(qm, modes)`.
///
/// Cache traffic is counted on the global session stats
/// ([`SessionStats::plan_compiles`](crate::sim::session::SessionStats)
/// / `plan_hits`): a DSE sweep compiles each `(model, config)` exactly
/// once, and every cache-resolved replay — each input of a
/// `run_model_batch`, a repeated `run_model`/`qforward` — is a hit.
/// (Callers holding the returned `Arc` replay it directly with no
/// further lookups — `IssEval` and `HostEval` do exactly that.)
pub fn plan_for(qm: &QModel, modes: &[Option<MacMode>]) -> Result<Arc<ExecutionPlan>> {
    let stats = &crate::sim::session::SimSession::global().stats;
    let key = key_for(qm, modes);
    if let Some(p) = cache().lock().unwrap().map.get(&key) {
        stats.plan_hits.fetch_add(1, Ordering::Relaxed);
        return Ok(Arc::clone(p));
    }
    // Compile outside the lock — lowering packs whole weight streams
    // and other configurations shouldn't serialise behind it. A racing
    // compiler of the same key loses its work and counts as a hit, so
    // `plan_compiles` equals the number of distinct plans built.
    let plan = Arc::new(compile(qm, modes)?);
    let mut c = cache().lock().unwrap();
    if let Some(p) = c.map.get(&key) {
        stats.plan_hits.fetch_add(1, Ordering::Relaxed);
        return Ok(Arc::clone(p));
    }
    stats.plan_compiles.fetch_add(1, Ordering::Relaxed);
    c.map.insert(key.clone(), Arc::clone(&plan));
    c.order.push_back(key);
    if c.order.len() > MAX_PLANS {
        if let Some(old) = c.order.pop_front() {
            c.map.remove(&old);
        }
    }
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::infer::{calibrate, quantize_model, random_params};
    use crate::models::synthetic::generate;
    use crate::models::{analyze, zoo};

    fn lenet_qm(seed: u64, bits: u32) -> QModel {
        let spec = zoo::lenet5();
        let n = analyze(&spec).layers.len();
        let params = random_params(&spec, seed);
        let ds = generate(seed ^ 1, 2, spec.input, spec.num_classes, 0.4);
        let sites = calibrate(&spec, &params, &ds.images[..2]);
        quantize_model(&spec, &params, &sites, &vec![bits; n])
    }

    #[test]
    fn compile_lowers_one_kernel_step_per_quantizable_layer() {
        let qm = lenet_qm(3, 4);
        let plan = compile(&qm, &canonical_modes(&qm)).unwrap();
        assert_eq!(plan.kernel_steps(), qm.layers.len());
        // The final step is the logits dense.
        match plan.steps.last().unwrap() {
            Step::Kernel(ks) => {
                assert!(ks.is_last);
                assert!(matches!(ks.op, KernelOp::Dense { .. }));
            }
            other => panic!("plan must end in a kernel step, got {}", other.kind()),
        }
        // Mode kernel steps carry pre-packed word streams.
        let packed = plan
            .steps
            .iter()
            .filter(|s| match s {
                Step::Kernel(ks) => matches!(ks.iss_w, PlanWeights::Words(_)),
                _ => false,
            })
            .count();
        assert_eq!(packed, qm.layers.len(), "every mode kernel pre-packs its weights");
    }

    #[test]
    fn mode_bits_mismatch_is_a_compile_error() {
        let qm = lenet_qm(4, 4);
        let mut modes = canonical_modes(&qm);
        modes[1] = Some(MacMode::W8); // layer is quantized at 4 bits
        assert!(compile(&qm, &modes).is_err());
        assert!(compile(&qm, &modes[..1]).is_err(), "mode-count mismatch");
    }

    #[test]
    fn plan_cache_compiles_once_and_distinguishes_content() {
        let qm = lenet_qm(5, 8);
        let modes = canonical_modes(&qm);
        let a = plan_for(&qm, &modes).unwrap();
        let b = plan_for(&qm, &modes).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "second lookup must replay the compiled plan");
        // Same name + bits, different weights: a different plan.
        let other = lenet_qm(6, 8);
        let c = plan_for(&other, &canonical_modes(&other)).unwrap();
        assert!(!Arc::ptr_eq(&a, &c), "content fingerprint must separate models");
        // A mutated copy (the divergence tests do this) recompiles.
        let mut bad = qm.clone();
        bad.layers[0].rq = Requant { m: 0, shift: 0 };
        let d = plan_for(&bad, &modes).unwrap();
        assert!(!Arc::ptr_eq(&a, &d), "in-place mutation must not replay a stale plan");
    }
}
