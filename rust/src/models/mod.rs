//! Model zoo and model-graph machinery for the paper's Table-3 benchmarks.
//!
//! A [`ModelSpec`] is a chain of [`Node`]s (plain layers or residual
//! blocks). The *quantizable* layers — conv / depthwise / dense, the
//! layers the paper's DSE retunes ("the most computationally intensive
//! layers") — are enumerated in a canonical order by [`analyze`]; the DSE
//! assigns one weight bit-width per quantizable layer.
//!
//! Activation scales live at *sites*: site 0 is the model input, each
//! quantizable layer output opens a new site, pooling reuses its input
//! site (max/avg cannot grow the range) and each residual add opens a
//! site. The Python trainer exports one calibrated scale per site; the
//! site walk here and in `python/compile/model.py` is structurally
//! identical (cross-checked by the artifact loader).
//!
//! Execution lowers through [`plan`]: a `(QModel, modes)` pair
//! compiles **once** into an immutable [`plan::ExecutionPlan`], and
//! both the host golden reference ([`infer::qforward`]) and the ISS
//! execution ([`sim_exec::run_model`]) are thin interpreters over that
//! same plan — host/ISS structural agreement by construction.

pub mod format;
pub mod infer;
pub mod plan;
pub mod sim_exec;
pub mod synthetic;
pub mod zoo;

/// A single layer inside a model graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayerSpec {
    /// Standard convolution (NHWC, square kernel).
    Conv {
        /// Output channels.
        cout: usize,
        /// Kernel size.
        k: usize,
        /// Stride.
        stride: usize,
        /// Symmetric zero padding.
        pad: usize,
        /// Fused ReLU.
        relu: bool,
    },
    /// Depthwise convolution (channel multiplier 1).
    Depthwise {
        /// Kernel size.
        k: usize,
        /// Stride.
        stride: usize,
        /// Padding.
        pad: usize,
        /// Fused ReLU.
        relu: bool,
    },
    /// Fully-connected layer (input implicitly flattened).
    Dense {
        /// Output features.
        out: usize,
        /// Fused ReLU.
        relu: bool,
    },
    /// 2×2 stride-2 max pool.
    MaxPool2,
    /// Global average pool (HWC → C).
    AvgPoolGlobal,
}

/// A node of the model graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Node {
    /// A plain layer.
    Layer(LayerSpec),
    /// Residual block: `out = add(input, seq(input))`. Inner layers must
    /// be quantizable (conv/depthwise/dense) and preserve the shape.
    Residual(Vec<LayerSpec>),
}

/// A benchmark model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    /// Model name (Table 3 row).
    pub name: &'static str,
    /// Input shape `[H, W, C]`.
    pub input: [usize; 3],
    /// Classification classes.
    pub num_classes: usize,
    /// Graph nodes.
    pub nodes: Vec<Node>,
}

/// Kind of a quantizable layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QKind {
    /// Standard convolution.
    Conv,
    /// Depthwise convolution.
    Depthwise,
    /// Dense.
    Dense,
}

/// Static analysis of one quantizable layer: geometry, MACs, scale sites.
#[derive(Debug, Clone, Copy)]
pub struct QLayerInfo {
    /// Layer kind.
    pub kind: QKind,
    /// Input shape `[H, W, C]` *before* padding (dense: `[1, 1, I]`).
    pub in_shape: [usize; 3],
    /// Output shape `[H, W, C]` (dense: `[1, 1, O]`).
    pub out_shape: [usize; 3],
    /// Kernel size (dense: 1).
    pub k: usize,
    /// Stride (dense: 1).
    pub stride: usize,
    /// Padding (dense: 0).
    pub pad: usize,
    /// Fused ReLU.
    pub relu: bool,
    /// MAC operations for one inference.
    pub macs: u64,
    /// Weight count.
    pub w_len: usize,
    /// Bias count.
    pub b_len: usize,
    /// Input activation scale site.
    pub site_in: usize,
    /// Output activation scale site.
    pub site_out: usize,
    /// True for the final logits layer (emits raw int32, no requant).
    pub is_last: bool,
}

/// Full static analysis of a model.
#[derive(Debug, Clone)]
pub struct ModelAnalysis {
    /// Per-quantizable-layer info, in canonical order.
    pub layers: Vec<QLayerInfo>,
    /// Total number of activation-scale sites.
    pub n_sites: usize,
    /// Residual adds: `(skip_site, branch_site, out_site)` per block.
    pub residuals: Vec<(usize, usize, usize)>,
    /// Total MACs (Table 3's `#MAC`).
    pub total_macs: u64,
}

fn layer_out_shape(l: LayerSpec, s: [usize; 3]) -> [usize; 3] {
    match l {
        LayerSpec::Conv { cout, k, stride, pad, .. } => {
            let ho = (s[0] + 2 * pad - k) / stride + 1;
            let wo = (s[1] + 2 * pad - k) / stride + 1;
            [ho, wo, cout]
        }
        LayerSpec::Depthwise { k, stride, pad, .. } => {
            let ho = (s[0] + 2 * pad - k) / stride + 1;
            let wo = (s[1] + 2 * pad - k) / stride + 1;
            [ho, wo, s[2]]
        }
        LayerSpec::Dense { out, .. } => [1, 1, out],
        LayerSpec::MaxPool2 => [s[0] / 2, s[1] / 2, s[2]],
        LayerSpec::AvgPoolGlobal => [1, 1, s[2]],
    }
}

fn qinfo(l: LayerSpec, s: [usize; 3], site_in: usize, site_out: usize) -> Option<QLayerInfo> {
    let out = layer_out_shape(l, s);
    match l {
        LayerSpec::Conv { cout, k, stride, pad, relu } => Some(QLayerInfo {
            kind: QKind::Conv,
            in_shape: s,
            out_shape: out,
            k,
            stride,
            pad,
            relu,
            macs: (out[0] * out[1] * cout * k * k * s[2]) as u64,
            w_len: cout * k * k * s[2],
            b_len: cout,
            site_in,
            site_out,
            is_last: false,
        }),
        LayerSpec::Depthwise { k, stride, pad, relu } => Some(QLayerInfo {
            kind: QKind::Depthwise,
            in_shape: s,
            out_shape: out,
            k,
            stride,
            pad,
            relu,
            macs: (out[0] * out[1] * s[2] * k * k) as u64,
            w_len: s[2] * k * k,
            b_len: s[2],
            site_in,
            site_out,
            is_last: false,
        }),
        LayerSpec::Dense { out: o, relu } => {
            let i = s[0] * s[1] * s[2];
            Some(QLayerInfo {
                kind: QKind::Dense,
                in_shape: [1, 1, i],
                out_shape: [1, 1, o],
                k: 1,
                stride: 1,
                pad: 0,
                relu,
                macs: (i * o) as u64,
                w_len: i * o,
                b_len: o,
                site_in,
                site_out,
                is_last: false,
            })
        }
        _ => None,
    }
}

/// Run the canonical graph walk: shapes, MACs, scale sites.
pub fn analyze(spec: &ModelSpec) -> ModelAnalysis {
    let mut layers = Vec::new();
    let mut residuals = Vec::new();
    let mut shape = spec.input;
    let mut site = 0usize; // current tensor's site
    let mut n_sites = 1usize;
    for node in &spec.nodes {
        match node {
            Node::Layer(l) => {
                if let Some(info) = qinfo(*l, shape, site, n_sites) {
                    site = n_sites;
                    n_sites += 1;
                    shape = info.out_shape;
                    layers.push(info);
                } else {
                    shape = layer_out_shape(*l, shape); // pool: site unchanged
                }
            }
            Node::Residual(inner) => {
                let skip_site = site;
                let in_shape = shape;
                let mut bshape = shape;
                let mut bsite = site;
                for l in inner {
                    let info = qinfo(*l, bshape, bsite, n_sites)
                        .expect("residual inner layers must be quantizable");
                    bsite = n_sites;
                    n_sites += 1;
                    bshape = info.out_shape;
                    layers.push(info);
                }
                assert_eq!(bshape, in_shape, "residual branch must preserve shape");
                // The add's output opens its own site.
                residuals.push((skip_site, bsite, n_sites));
                site = n_sites;
                n_sites += 1;
            }
        }
    }
    if let Some(last) = layers.last_mut() {
        last.is_last = true;
    }
    let total_macs = layers.iter().map(|l| l.macs).sum();
    ModelAnalysis { layers, n_sites, residuals, total_macs }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> ModelSpec {
        ModelSpec {
            name: "toy",
            input: [8, 8, 3],
            num_classes: 4,
            nodes: vec![
                Node::Layer(LayerSpec::Conv { cout: 8, k: 3, stride: 1, pad: 1, relu: true }),
                Node::Layer(LayerSpec::MaxPool2),
                Node::Residual(vec![
                    LayerSpec::Conv { cout: 16, k: 1, stride: 1, pad: 0, relu: true },
                    LayerSpec::Depthwise { k: 3, stride: 1, pad: 1, relu: true },
                    LayerSpec::Conv { cout: 8, k: 1, stride: 1, pad: 0, relu: false },
                ]),
                Node::Layer(LayerSpec::AvgPoolGlobal),
                Node::Layer(LayerSpec::Dense { out: 4, relu: false }),
            ],
        }
    }

    #[test]
    fn analyze_counts_layers_sites_macs() {
        let a = analyze(&toy());
        assert_eq!(a.layers.len(), 5); // conv + 3 residual inner + dense
        // Sites: input(0), conv(1), res-inner(2,3,4), add(5), dense(6).
        assert_eq!(a.n_sites, 7);
        assert_eq!(a.residuals, vec![(1, 4, 5)]);
        assert!(a.layers[4].is_last);
        assert_eq!(a.layers[4].in_shape, [1, 1, 8]);
        // conv: 8·8·8·9·3
        assert_eq!(a.layers[0].macs, 8 * 8 * 8 * 9 * 3);
        // pool halves spatial before the residual
        assert_eq!(a.layers[1].in_shape, [4, 4, 8]);
        assert!(a.total_macs > 0);
    }

    #[test]
    fn maxpool_keeps_site() {
        let a = analyze(&toy());
        // conv output is site 1; the residual's first inner layer reads
        // site 1 even though a pool sits in between.
        assert_eq!(a.layers[0].site_out, 1);
        assert_eq!(a.layers[1].site_in, 1);
    }
}
