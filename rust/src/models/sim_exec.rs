//! Whole-model execution on the cycle-accurate core: every quantizable
//! layer runs as a generated RV32 kernel on the ISS (baseline or the
//! mode matching its weight bit-width); pooling, padding and residual
//! adds run host-side between kernels (their cycle share is negligible
//! and identical across baseline/extended architectures — DESIGN.md §5).
//!
//! This is the reproduction of the paper's Verilator flow: the same
//! binary-level kernels the extended processor would run, measured with
//! the same per-layer performance counters. Each [`SimRun`] carries
//! both the per-layer [`PerfCounters`] **and** the integer logits /
//! top-1 class of the execution, so a single pass yields performance
//! *and* accuracy from the same binary-level run — the substrate behind
//! the ISS-backed accuracy evaluator
//! ([`IssEval`](crate::coordinator::IssEval)).
//!
//! ## Plan-driven execution (post execution-plan refactor)
//!
//! There is **no graph walk here anymore**: a `(QModel, modes)` pair
//! lowers once — through the keyed plan cache of
//! [`plan_for`](crate::models::plan::plan_for) — into an
//! [`ExecutionPlan`] whose kernel steps carry fully-resolved specs and
//! pre-staged (padded + packed) weight operands. [`run_plan`]
//! interprets that step list on the ISS via the staged kernel runners
//! (`kernels::run::run_*_staged`), and the host golden reference
//! ([`host_logits`](crate::models::plan::host_logits)) interprets the
//! *same* plan — so the two executions cannot disagree structurally.
//! Per-run work is reduced to per-input tensor movement; the
//! per-configuration derivation (kernel specs, requant parameters,
//! weight padding/packing, residual bookkeeping) is paid exactly once
//! per batch/sweep.
//!
//! Kernels execute on the micro-op engine through the global
//! [`crate::sim::session::SimSession`]: every `(spec, mode)` pair is
//! assembled and engine-translated exactly once into the keyed kernel
//! cache (`kernels::run`), and simulator memories are recycled through
//! the session's pool. One model execution is inherently sequential
//! (each layer consumes the previous layer's activations), so the
//! parallel axis is the *input batch*: [`run_model_batch`] /
//! [`run_plan_batch`] fan independent inputs out over a worker pool
//! sharing the kernel cache and memory pool.
//!
//! [`run_plan`] additionally takes an optional
//! [`PlanObserver`](crate::models::plan::PlanObserver): one event per
//! executed step, with the kernel steps' own perf counters — the
//! step-granular trace surface ([`StepTrace`] writes it as JSON lines
//! for `mpnn trace --trace-steps`).
//!
//! ## Analytic fast path ([`ExecMode::Analytic`])
//!
//! Since the kernels became fully data-independent in timing
//! (branchless requant epilogue, counted strip loops), a kernel step's
//! [`PerfCounters`] are a pure function of `(shape, mode, mac)`. The
//! analytic mode makes that contract load-bearing: the **first** time a
//! given cost key runs, it executes on the real ISS and its counters
//! land in the session-level
//! [`CostCache`](crate::sim::session::CostCache); every subsequent
//! execution runs the bit-exact **host** kernel for the values and
//! fills the counters from the cache. A batch of N inputs then costs
//! ~1 ISS execution per distinct kernel step instead of N, and a warm
//! sweep costs ~0. [`audit_run`] + [`audit_indices`] implement the
//! sampled differential audit (`--audit-every K`) that re-checks the
//! contract on the real ISS.
//!
//! See `docs/ARCHITECTURE.md` for the lowering diagram and the unified
//! accuracy+cycles dataflow.

use super::infer::QModel;
use super::plan::{
    plan_for, ExecutionPlan, Flow, KernelOp, KernelStep, PlanObserver, Step, StepEvent,
};
use super::QKind;
use crate::error::Result;
use crate::isa::MacMode;
use crate::kernels::run::{
    conv_cost_key, dense_cost_key, depthwise_cost_key, run_conv_staged, run_dense_staged,
    run_depthwise_staged, ExecBackend,
};
use crate::nn::layers::{pad_spatial, qadd, qavgpool_global, qconv2d, qdense, qdepthwise, qmaxpool2};
use crate::nn::tensor::{pad_channels, Tensor};
use crate::sim::session::{CostKey, SimSession};
use crate::sim::{MacUnitConfig, PerfCounters};
use crate::{bail, ensure};
use std::sync::atomic::Ordering;

/// Per-layer measurement from an ISS execution.
#[derive(Debug, Clone)]
pub struct LayerRun {
    /// Quantizable-layer index.
    pub layer: usize,
    /// Mode used (`None` = scalar baseline kernel).
    pub mode: Option<MacMode>,
    /// Perf counters for the layer's kernel alone.
    pub perf: PerfCounters,
}

/// Result of a full-model ISS execution.
#[derive(Debug, Clone)]
pub struct SimRun {
    /// Integer logits (must equal `infer::qforward`).
    pub logits: Vec<i32>,
    /// Per-layer measurements.
    pub layers: Vec<LayerRun>,
}

impl SimRun {
    /// Total cycles across all layer kernels.
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.perf.cycles).sum()
    }

    /// Total memory accesses (Fig. 4 metric).
    pub fn total_accesses(&self) -> u64 {
        self.layers.iter().map(|l| l.perf.mem_accesses()).sum()
    }

    /// Total retired instructions.
    pub fn total_instret(&self) -> u64 {
        self.layers.iter().map(|l| l.perf.instret).sum()
    }

    /// Top-1 class of this run's logits (ties broken toward the lower
    /// index, matching [`crate::models::infer::argmax_i32`] so ISS and
    /// host predictions are directly comparable).
    pub fn argmax(&self) -> usize {
        crate::models::infer::argmax_i32(&self.logits)
    }
}

/// How a plan's kernel steps execute (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Every kernel step runs on the cycle-accurate ISS (the default,
    /// and the semantic oracle the analytic mode is audited against).
    #[default]
    Iss,
    /// Kernel steps whose cost key is already in the session
    /// [`CostCache`](crate::sim::session::CostCache) run the bit-exact
    /// host kernel and take their counters from the cache; cache misses
    /// run the ISS once and populate it.
    Analytic,
}

/// The analytic cost-cache key of a kernel step under `mac` — the same
/// `(spec, mode)` fingerprint the kernel-image cache uses, plus the
/// MAC-unit configuration (shared across plans: two steps with equal
/// keys run the identical program, so their counters agree).
pub fn cost_key_for(ks: &KernelStep, mac: MacUnitConfig) -> CostKey {
    match &ks.op {
        KernelOp::Conv { spec, .. } => conv_cost_key(spec, ks.mode, mac),
        KernelOp::Depthwise { spec, .. } => depthwise_cost_key(spec, ks.mode, mac),
        KernelOp::Dense { spec } => dense_cost_key(spec, ks.mode, mac),
    }
}

/// Execute one kernel step on the ISS. Returns the outgoing flow, the
/// final logits (`is_last` dense only) and the step's measured perf.
fn exec_kernel_iss(
    ks: &KernelStep,
    x: Flow,
    mac: MacUnitConfig,
) -> Result<(Flow, Option<Vec<i32>>, PerfCounters)> {
    match &ks.op {
        KernelOp::Conv { spec, geom, cout, .. } => {
            let mut xp = pad_spatial(&x.map(), geom.pad);
            if xp.shape[2] != spec.cin {
                // Mode kernels need Cin % 4 == 0; the plan
                // pre-padded the weights to match.
                xp = pad_channels(&xp, 4, 0);
                ensure!(
                    xp.shape[2] == spec.cin,
                    "layer {}: channel-padded input {} vs plan cin {}",
                    ks.layer,
                    xp.shape[2],
                    spec.cin
                );
            }
            let (out, perf) = run_conv_staged(
                *spec,
                ks.mode,
                mac,
                ExecBackend::default(),
                &xp.data,
                ks.iss_w.staged(),
                &ks.bias,
            )?;
            Ok((Flow::Map(Tensor::from_vec(&[spec.ho(), spec.wo(), *cout], out)), None, perf))
        }
        KernelOp::Depthwise { spec, geom } => {
            let xp = pad_spatial(&x.map(), geom.pad);
            let (out, perf) = run_depthwise_staged(
                *spec,
                ks.mode,
                mac,
                ExecBackend::default(),
                &xp.data,
                ks.iss_w.staged(),
                &ks.bias,
            )?;
            Ok((Flow::Map(Tensor::from_vec(&[spec.ho(), spec.wo(), spec.c], out)), None, perf))
        }
        KernelOp::Dense { spec } => {
            let flat = x.flat();
            let (qv, accs, perf) = run_dense_staged(
                *spec,
                ks.mode,
                mac,
                ExecBackend::default(),
                &flat,
                ks.iss_w.staged(),
                &ks.bias,
            )?;
            if ks.is_last {
                Ok((Flow::Flat(Vec::new()), Some(accs), perf))
            } else {
                Ok((Flow::Flat(qv), None, perf))
            }
        }
    }
}

/// Execute one kernel step with the bit-exact host implementations —
/// the same arms [`host_logits`](crate::models::plan::host_logits)
/// interprets, so mixing host and ISS steps inside one analytic run
/// cannot change a single activation byte.
fn exec_kernel_host(ks: &KernelStep, x: Flow) -> (Flow, Option<Vec<i32>>) {
    match &ks.op {
        KernelOp::Conv { geom, cout, .. } => {
            (Flow::Map(qconv2d(&x.map(), &ks.host_w, &ks.bias, *cout, *geom, ks.rq, ks.relu)), None)
        }
        KernelOp::Depthwise { geom, .. } => {
            (Flow::Map(qdepthwise(&x.map(), &ks.host_w, &ks.bias, *geom, ks.rq, ks.relu)), None)
        }
        KernelOp::Dense { spec } => {
            let flat = x.flat();
            if ks.is_last {
                let (_, accs) = qdense(&flat, &ks.host_w, &ks.bias, spec.out_dim, None, false);
                (Flow::Flat(Vec::new()), Some(accs))
            } else {
                let (qv, _) = qdense(&flat, &ks.host_w, &ks.bias, spec.out_dim, Some(ks.rq), ks.relu);
                (Flow::Flat(qv), None)
            }
        }
    }
}

/// Execute a compiled [`ExecutionPlan`] for one input.
///
/// This is the plan interpreter: each [`Step::Kernel`] stages its
/// pre-padded/pre-packed operands into pooled simulator memory and runs
/// through the keyed kernel cache (or, under [`ExecMode::Analytic`]
/// with a warm cost cache, runs the host kernel and takes its counters
/// from the cache); host glue steps (pool / residual save & add) run
/// between kernels. A kernel that misbehaves on the core (memory fault,
/// runaway pc) surfaces as an `Err`.
///
/// `observer`, when given, receives one [`StepEvent`] per executed step
/// in plan order — kernel steps carry the layer's own [`PerfCounters`]
/// (measured or cache-served), host glue steps carry `None`. On error,
/// no event is emitted for the failing step.
pub fn run_plan(
    plan: &ExecutionPlan,
    input: &Tensor<i8>,
    mac: MacUnitConfig,
    mode: ExecMode,
    mut observer: Option<&mut dyn PlanObserver>,
) -> Result<SimRun> {
    ensure!(
        input.shape == plan.input_shape,
        "plan for {} expects input {:?}, got {:?}",
        plan.model,
        plan.input_shape,
        input.shape
    );
    let mut layers = Vec::new();
    let mut skips: Vec<Tensor<i8>> = Vec::new();
    let mut x = Flow::Map(input.clone());

    fn notify(
        index: usize,
        kind: &'static str,
        layer: Option<usize>,
        mode: Option<MacMode>,
        perf: Option<&PerfCounters>,
        observer: &mut Option<&mut dyn PlanObserver>,
    ) {
        if let Some(obs) = observer.as_deref_mut() {
            obs.on_step(&StepEvent { index, kind, layer, mode, perf });
        }
    }

    for (si, step) in plan.steps.iter().enumerate() {
        match step {
            Step::Kernel(ks) => {
                let (nx, logits, perf) = match mode {
                    ExecMode::Iss => exec_kernel_iss(ks, x, mac)?,
                    ExecMode::Analytic => {
                        let session = SimSession::global();
                        let key = cost_key_for(ks, mac);
                        match session.costs.get(&key) {
                            Some(perf) => {
                                session.stats.analytic_hits.fetch_add(1, Ordering::Relaxed);
                                let (nx, logits) = exec_kernel_host(ks, x);
                                (nx, logits, perf)
                            }
                            None => {
                                // First sighting of this kernel shape:
                                // measure it for real, remember forever.
                                let out = exec_kernel_iss(ks, x, mac)?;
                                session.costs.insert(key, out.2);
                                out
                            }
                        }
                    }
                };
                layers.push(LayerRun { layer: ks.layer, mode: ks.mode, perf });
                notify(si, step.kind(), Some(ks.layer), ks.mode, Some(&perf), &mut observer);
                if let Some(logits) = logits {
                    return Ok(SimRun { logits, layers });
                }
                x = nx;
            }
            Step::MaxPool2 => {
                x = Flow::Map(qmaxpool2(&x.map()));
                notify(si, step.kind(), None, None, None, &mut observer);
            }
            Step::AvgPoolGlobal => {
                let m = x.map();
                let c = m.shape[2];
                x = Flow::Map(Tensor::from_vec(&[1, 1, c], qavgpool_global(&m)));
                notify(si, step.kind(), None, None, None, &mut observer);
            }
            Step::SaveSkip => {
                let m = x.map();
                skips.push(m.clone());
                x = Flow::Map(m);
                notify(si, step.kind(), None, None, None, &mut observer);
            }
            Step::AddSkip { rq_skip, rq_branch, .. } => {
                let skip = match skips.pop() {
                    Some(s) => s,
                    None => bail!("plan step {si}: AddSkip without a SaveSkip"),
                };
                x = Flow::Map(qadd(&skip, *rq_skip, &x.map(), *rq_branch));
                notify(si, step.kind(), None, None, None, &mut observer);
            }
        }
    }
    bail!("plan did not terminate in a logits step")
}

/// Run a compiled plan over a batch of independent inputs in parallel
/// (the plan is compiled once by the caller and replayed per input).
///
/// Under [`ExecMode::Analytic`] the first input runs alone before the
/// pool fans out: every kernel step misses the cost cache at most once,
/// so an N-input batch costs ~(unique kernel steps) ISS executions —
/// not steps × N, and not steps × workers as a racing cold start would.
pub fn run_plan_batch(
    plan: &ExecutionPlan,
    inputs: &[Tensor<i8>],
    mac: MacUnitConfig,
    mode: ExecMode,
    workers: usize,
) -> Result<Vec<SimRun>> {
    if mode == ExecMode::Analytic && inputs.len() > 1 {
        let first = run_plan(plan, &inputs[0], mac, mode, None)?;
        let rest = crate::par::parallel_map(inputs.len() - 1, workers, |j| {
            run_plan(plan, &inputs[j + 1], mac, mode, None)
        })?;
        let mut out = Vec::with_capacity(inputs.len());
        out.push(first);
        out.extend(rest);
        return Ok(out);
    }
    crate::par::parallel_map(inputs.len(), workers, |j| run_plan(plan, &inputs[j], mac, mode, None))
}

// -------------------------------------------------- sampled audit ---

/// Deterministic audit-sample selection for `--audit-every K`: every
/// Kth batch element starting from a seeded phase, so repeated runs —
/// and any sharding of the same element order — audit the same
/// elements. `every == 0` disables auditing; `every == 1` selects the
/// whole batch (the degenerate full-ISS check CI's byte-identity smoke
/// relies on).
pub fn audit_indices(seed: u64, n: usize, every: usize) -> Vec<usize> {
    // One shared FNV-phase stride (`rng::seeded_stride`) serves both
    // this audit sampler and the guided-search rung tie-break; the pin
    // test in `rng` keeps the historical audit sequences unchanged.
    crate::rng::seeded_stride(seed, n, every)
}

/// Differential audit of one analytic execution: replay `input` on the
/// real ISS and bit-compare logits **and** per-layer perf counters
/// against the analytic run. A disagreement increments
/// `SessionStats::audit_mismatches` and fails with a typed
/// "analytic audit mismatch" error — the analytic fast path never
/// silently serves counters the ISS wouldn't produce.
pub fn audit_run(
    plan: &ExecutionPlan,
    input: &Tensor<i8>,
    mac: MacUnitConfig,
    analytic: &SimRun,
) -> Result<()> {
    let stats = &SimSession::global().stats;
    stats.analytic_audits.fetch_add(1, Ordering::Relaxed);
    let iss = run_plan(plan, input, mac, ExecMode::Iss, None)?;
    let logits_ok = iss.logits == analytic.logits;
    let counters_ok = iss.layers.len() == analytic.layers.len()
        && iss
            .layers
            .iter()
            .zip(&analytic.layers)
            .all(|(a, b)| a.layer == b.layer && a.mode == b.mode && a.perf == b.perf);
    if !logits_ok || !counters_ok {
        stats.audit_mismatches.fetch_add(1, Ordering::Relaxed);
        bail!(
            "analytic audit mismatch for {}: ISS replay disagrees with the analytic \
             execution (logits {}, per-layer counters {})",
            plan.model,
            if logits_ok { "agree" } else { "DIFFER" },
            if counters_ok { "agree" } else { "DIFFER" }
        );
    }
    Ok(())
}

/// Execute the quantized model on the ISS.
///
/// `modes[i]` selects the kernel for quantizable layer `i`: `None` runs
/// the scalar baseline, `Some(mode)` the packed kernel (the mode must
/// match the layer's quantization grid — checked at plan compile). The
/// `(qm, modes)` pair resolves through the keyed plan cache
/// ([`plan_for`]), so repeated runs replay one compiled plan. `mac`
/// configures the MAC-unit features (Fig. 7 ablations).
pub fn run_model(
    qm: &QModel,
    input: &Tensor<i8>,
    modes: &[Option<MacMode>],
    mac: MacUnitConfig,
) -> Result<SimRun> {
    let plan = plan_for(qm, modes)?;
    run_plan(&plan, input, mac, ExecMode::Iss, None)
}

/// Run one model over a batch of independent inputs in parallel.
///
/// The configuration's [`ExecutionPlan`] is compiled once (warm plan
/// cache) and replayed for every input; each worker then runs the full
/// sequential step list for its input, sharing the global kernel cache
/// and memory pool. Results are in input order and identical to
/// per-input [`run_model`] calls. Every [`SimRun`] carries the integer
/// logits and [`SimRun::argmax`] class alongside the perf counters, so
/// accuracy and cycles for a batch come out of the same executions.
///
/// # Example
///
/// ```no_run
/// use mpnn::models::infer::{calibrate, quantize_input, quantize_model, random_params};
/// use mpnn::models::sim_exec::{modes_for, run_model_batch};
/// use mpnn::models::synthetic::generate;
/// use mpnn::models::{analyze, zoo};
/// use mpnn::sim::MacUnitConfig;
///
/// let spec = zoo::lenet5();
/// let n = analyze(&spec).layers.len();
/// let params = random_params(&spec, 1);
/// let ds = generate(2, 8, spec.input, spec.num_classes, 0.4);
/// let sites = calibrate(&spec, &params, &ds.images[..2]);
/// let qm = quantize_model(&spec, &params, &sites, &vec![4u32; n]);
/// let inputs: Vec<_> = ds.images.iter().map(|im| quantize_input(&qm, im)).collect();
///
/// let runs = run_model_batch(&qm, &inputs, &modes_for(&qm), MacUnitConfig::full(), 4).unwrap();
/// for (run, &label) in runs.iter().zip(&ds.labels) {
///     println!("pred {} (label {label}), {} cycles", run.argmax(), run.total_cycles());
/// }
/// ```
pub fn run_model_batch(
    qm: &QModel,
    inputs: &[Tensor<i8>],
    modes: &[Option<MacMode>],
    mac: MacUnitConfig,
    workers: usize,
) -> Result<Vec<SimRun>> {
    // One cache resolution for the whole batch: the workers replay the
    // `Arc` directly instead of re-deriving the O(model size) cache
    // key per input.
    let plan = plan_for(qm, modes)?;
    run_plan_batch(&plan, inputs, mac, ExecMode::Iss, workers)
}

/// Kernel modes for a quantized model: the mode matching each layer's
/// bit-width (the extended-ISA execution).
pub fn modes_for(qm: &QModel) -> Vec<Option<MacMode>> {
    super::plan::canonical_modes(qm)
}

/// All-baseline modes (the original-Ibex execution).
pub fn baseline_modes(qm: &QModel) -> Vec<Option<MacMode>> {
    vec![None; qm.layers.len()]
}

/// Convenience: does this layer benefit less from the extension (the
/// paper's depthwise observation)?
pub fn is_depthwise(qm: &QModel, idx: usize) -> bool {
    qm.analysis.layers[idx].kind == QKind::Depthwise
}

// ------------------------------------------------------ trace sidecar ---

/// [`PlanObserver`] that writes one JSON line per executed step — the
/// trace sidecar behind `mpnn trace --trace-steps <path>`. Each record
/// carries the step index/kind, the quantizable-layer index and mode
/// (kernel steps), and the step's own cycles / retired instructions /
/// memory accesses. Host glue steps record `null` counters (they run
/// off-core).
///
/// IO errors are latched and reported by [`StepTrace::finish`] so the
/// observer callback stays infallible.
pub struct StepTrace {
    out: std::io::BufWriter<std::fs::File>,
    path: std::path::PathBuf,
    err: Option<std::io::Error>,
    /// Steps written so far.
    pub steps: usize,
}

impl StepTrace {
    /// Create (truncate) the JSONL trace file at `path`.
    pub fn create(path: &std::path::Path) -> Result<Self> {
        use crate::error::Context;
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating step trace {}", path.display()))?;
        Ok(StepTrace {
            out: std::io::BufWriter::new(file),
            path: path.to_path_buf(),
            err: None,
            steps: 0,
        })
    }

    /// Flush the trace and surface any latched IO error.
    pub fn finish(mut self) -> Result<()> {
        use crate::error::Context;
        use std::io::Write;
        if let Some(e) = self.err.take() {
            return Err(crate::error::Error::from(e))
                .with_context(|| format!("writing step trace {}", self.path.display()));
        }
        self.out
            .flush()
            .with_context(|| format!("flushing step trace {}", self.path.display()))
    }
}

impl PlanObserver for StepTrace {
    fn on_step(&mut self, ev: &StepEvent<'_>) {
        use crate::json::Json;
        use std::io::Write;
        if self.err.is_some() {
            return;
        }
        let record = Json::obj(vec![
            ("step", Json::i(ev.index as i64)),
            ("kind", Json::s(ev.kind)),
            ("layer", ev.layer.map_or(Json::Null, |l| Json::i(l as i64))),
            (
                "mode",
                ev.mode.map_or(Json::Null, |m| Json::s(&format!("{m:?}").to_lowercase())),
            ),
            ("cycles", ev.perf.map_or(Json::Null, |p| Json::i(p.cycles as i64))),
            ("instret", ev.perf.map_or(Json::Null, |p| Json::i(p.instret as i64))),
            (
                "mem_accesses",
                ev.perf.map_or(Json::Null, |p| Json::i(p.mem_accesses() as i64)),
            ),
        ]);
        // `Json::to_string` is inherent (no `Display` impl on `Json`).
        let line = record.to_string();
        if let Err(e) = writeln!(self.out, "{line}") {
            self.err = Some(e);
        } else {
            self.steps += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::infer::{calibrate, qforward, quantize_input, quantize_model, random_params};
    use crate::models::synthetic::generate;
    use crate::models::{zoo, LayerSpec, ModelSpec, Node};

    fn toy_residual_model() -> ModelSpec {
        ModelSpec {
            name: "toy",
            input: [8, 8, 3],
            num_classes: 4,
            nodes: vec![
                Node::Layer(LayerSpec::Conv { cout: 8, k: 3, stride: 1, pad: 1, relu: true }),
                Node::Layer(LayerSpec::MaxPool2),
                Node::Residual(vec![
                    LayerSpec::Conv { cout: 16, k: 1, stride: 1, pad: 0, relu: true },
                    LayerSpec::Depthwise { k: 3, stride: 1, pad: 1, relu: true },
                    LayerSpec::Conv { cout: 8, k: 1, stride: 1, pad: 0, relu: false },
                ]),
                Node::Layer(LayerSpec::AvgPoolGlobal),
                Node::Layer(LayerSpec::Dense { out: 4, relu: false }),
            ],
        }
    }

    fn check_model(spec: &ModelSpec, bits: Vec<u32>, seed: u64) {
        let params = random_params(spec, seed);
        let ds = generate(seed ^ 1, 4, spec.input, spec.num_classes, 0.4);
        let sites = calibrate(spec, &params, &ds.images[..2]);
        let qm = quantize_model(spec, &params, &sites, &bits);
        let input = quantize_input(&qm, &ds.images[3]);
        let want = qforward(&qm, &input);

        // Extended execution (per-layer modes) must be bit-exact.
        let run = run_model(&qm, &input, &modes_for(&qm), MacUnitConfig::full()).unwrap();
        assert_eq!(run.logits, want, "extended ISS vs host reference");
        assert_eq!(run.layers.len(), qm.layers.len());

        // Baseline execution must also be bit-exact (same arithmetic).
        let base = run_model(&qm, &input, &baseline_modes(&qm), MacUnitConfig::full()).unwrap();
        assert_eq!(base.logits, want, "baseline ISS vs host reference");

        // And the extension must be faster + lighter on memory.
        assert!(run.total_cycles() < base.total_cycles());
        assert!(run.total_accesses() < base.total_accesses());
    }

    #[test]
    fn toy_residual_model_bit_exact_all_widths() {
        let spec = toy_residual_model();
        let n = crate::models::analyze(&spec).layers.len();
        check_model(&spec, vec![8; n], 100);
        check_model(&spec, vec![4; n], 101);
        check_model(&spec, vec![2; n], 102);
        // Mixed configuration: 8-bit first, then alternating.
        check_model(&spec, vec![8, 4, 2, 4, 8], 103);
    }

    #[test]
    fn lenet5_bit_exact_mixed() {
        let spec = zoo::lenet5();
        check_model(&spec, vec![8, 4, 4, 2, 8], 200);
    }

    #[test]
    fn batch_run_matches_sequential_runs() {
        let spec = toy_residual_model();
        let n = crate::models::analyze(&spec).layers.len();
        let bits = vec![4u32; n];
        let params = random_params(&spec, 7);
        let ds = generate(8, 6, spec.input, spec.num_classes, 0.4);
        let sites = calibrate(&spec, &params, &ds.images[..2]);
        let qm = quantize_model(&spec, &params, &sites, &bits);
        let inputs: Vec<_> = ds.images.iter().map(|im| quantize_input(&qm, im)).collect();
        let modes = modes_for(&qm);

        let batch = run_model_batch(&qm, &inputs, &modes, MacUnitConfig::full(), 3).unwrap();
        assert_eq!(batch.len(), inputs.len());
        for (i, input) in inputs.iter().enumerate() {
            let solo = run_model(&qm, input, &modes, MacUnitConfig::full()).unwrap();
            assert_eq!(batch[i].logits, solo.logits, "input {i}");
            assert_eq!(batch[i].total_cycles(), solo.total_cycles(), "input {i}");
        }
    }

    #[test]
    fn observer_sees_every_step_with_kernel_perf() {
        struct Collect {
            events: Vec<(usize, &'static str, Option<usize>, bool)>,
        }
        impl PlanObserver for Collect {
            fn on_step(&mut self, ev: &StepEvent<'_>) {
                self.events.push((ev.index, ev.kind, ev.layer, ev.perf.is_some()));
            }
        }
        let spec = toy_residual_model();
        let n = crate::models::analyze(&spec).layers.len();
        let params = random_params(&spec, 21);
        let ds = generate(22, 3, spec.input, spec.num_classes, 0.4);
        let sites = calibrate(&spec, &params, &ds.images[..2]);
        let qm = quantize_model(&spec, &params, &sites, &vec![4; n]);
        let input = quantize_input(&qm, &ds.images[2]);
        let plan = plan_for(&qm, &modes_for(&qm)).unwrap();

        let mut obs = Collect { events: Vec::new() };
        let run =
            run_plan(&plan, &input, MacUnitConfig::full(), ExecMode::Iss, Some(&mut obs)).unwrap();
        // One event per step, in plan order.
        assert_eq!(obs.events.len(), plan.steps.len());
        for (i, ev) in obs.events.iter().enumerate() {
            assert_eq!(ev.0, i, "events arrive in plan order");
        }
        // Kernel events carry perf and the layer index; glue events don't.
        let kernel_events: Vec<_> = obs.events.iter().filter(|e| e.3).collect();
        assert_eq!(kernel_events.len(), run.layers.len());
        assert_eq!(kernel_events.len(), qm.layers.len());
        for (ev, lr) in kernel_events.iter().zip(&run.layers) {
            assert_eq!(ev.2, Some(lr.layer));
        }
        // Glue kinds appear (pool + residual save/add).
        let kinds: Vec<&str> = obs.events.iter().map(|e| e.1).collect();
        for k in ["maxpool2", "avgpool_global", "save_skip", "add_skip"] {
            assert!(kinds.contains(&k), "missing {k} in {kinds:?}");
        }
        // An un-observed run is identical (observers are read-only).
        let bare = run_plan(&plan, &input, MacUnitConfig::full(), ExecMode::Iss, None).unwrap();
        assert_eq!(bare.logits, run.logits);
        assert_eq!(bare.total_cycles(), run.total_cycles());
    }

    #[test]
    fn step_trace_writes_one_json_line_per_step() {
        let spec = toy_residual_model();
        let n = crate::models::analyze(&spec).layers.len();
        let params = random_params(&spec, 31);
        let ds = generate(32, 3, spec.input, spec.num_classes, 0.4);
        let sites = calibrate(&spec, &params, &ds.images[..2]);
        let qm = quantize_model(&spec, &params, &sites, &vec![8; n]);
        let input = quantize_input(&qm, &ds.images[1]);
        let plan = plan_for(&qm, &modes_for(&qm)).unwrap();

        let dir = std::env::temp_dir().join(format!("mpnn_trace_{}", std::process::id()));
        let path = dir.join("steps.jsonl");
        let mut trace = StepTrace::create(&path).unwrap();
        run_plan(&plan, &input, MacUnitConfig::full(), ExecMode::Iss, Some(&mut trace)).unwrap();
        let steps = trace.steps;
        trace.finish().unwrap();

        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), plan.steps.len());
        assert_eq!(steps, plan.steps.len());
        let mut kernel_lines = 0;
        for line in &lines {
            let j = crate::json::Json::parse(line).unwrap();
            assert!(j.get("step").and_then(|v| v.as_i64()).is_some());
            assert!(j.get("kind").is_some());
            if j.get("cycles").and_then(|v| v.as_i64()).is_some() {
                kernel_lines += 1;
            }
        }
        assert_eq!(kernel_lines, qm.layers.len(), "kernel steps carry cycle counters");
        std::fs::remove_dir_all(&dir).ok();
    }
}
