//! Whole-model execution on the cycle-accurate core: every quantizable
//! layer runs as a generated RV32 kernel on the ISS (baseline or the
//! mode matching its weight bit-width); pooling, padding and residual
//! adds run host-side between kernels (their cycle share is negligible
//! and identical across baseline/extended architectures — DESIGN.md §5).
//!
//! This is the reproduction of the paper's Verilator flow: the same
//! binary-level kernels the extended processor would run, measured with
//! the same per-layer performance counters. Each [`SimRun`] carries
//! both the per-layer [`PerfCounters`] **and** the integer logits /
//! top-1 class of the execution, so a single pass yields performance
//! *and* accuracy from the same binary-level run — the substrate behind
//! the ISS-backed accuracy evaluator
//! ([`IssEval`](crate::coordinator::IssEval)).
//!
//! ## Session / cache architecture (post micro-op-engine refactor)
//!
//! Layer kernels execute on the micro-op engine through the global
//! [`crate::sim::session::SimSession`]: every `(spec, mode)` pair is
//! assembled and engine-translated exactly once into the keyed kernel
//! cache (`kernels::run`), and simulator memories are recycled through
//! the session's pool — across a whole model (and across a whole DSE
//! sweep) the per-invocation assembly and 16 MiB allocation are paid
//! once. One model execution is inherently sequential (each layer
//! consumes the previous layer's activations), so the parallel axis is
//! the *input batch*: [`run_model_batch`] fans independent inputs out
//! over a worker pool sharing the kernel cache and memory pool.
//!
//! See `docs/ARCHITECTURE.md` for the dataflow diagram of the unified
//! accuracy+cycles path.

use super::infer::{residual_requants, QModel};
use super::{LayerSpec, Node, QKind};
use crate::error::Result;
use crate::isa::MacMode;
use crate::kernels::conv::ConvSpec;
use crate::kernels::dense::DenseSpec;
use crate::kernels::depthwise::DwSpec;
use crate::kernels::run::{run_conv_with, run_dense_with, run_depthwise_with};
use crate::nn::layers::{pad_spatial, qadd, qavgpool_global, qmaxpool2};
use crate::nn::tensor::{pad_channels, Tensor};
use crate::sim::{MacUnitConfig, PerfCounters};
use crate::{bail, ensure};

/// Per-layer measurement from an ISS execution.
#[derive(Debug, Clone)]
pub struct LayerRun {
    /// Quantizable-layer index.
    pub layer: usize,
    /// Mode used (`None` = scalar baseline kernel).
    pub mode: Option<MacMode>,
    /// Perf counters for the layer's kernel alone.
    pub perf: PerfCounters,
}

/// Result of a full-model ISS execution.
#[derive(Debug, Clone)]
pub struct SimRun {
    /// Integer logits (must equal `infer::qforward`).
    pub logits: Vec<i32>,
    /// Per-layer measurements.
    pub layers: Vec<LayerRun>,
}

impl SimRun {
    /// Total cycles across all layer kernels.
    pub fn total_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.perf.cycles).sum()
    }

    /// Total memory accesses (Fig. 4 metric).
    pub fn total_accesses(&self) -> u64 {
        self.layers.iter().map(|l| l.perf.mem_accesses()).sum()
    }

    /// Total retired instructions.
    pub fn total_instret(&self) -> u64 {
        self.layers.iter().map(|l| l.perf.instret).sum()
    }

    /// Top-1 class of this run's logits (ties broken toward the lower
    /// index, matching [`crate::models::infer::argmax_i32`] so ISS and
    /// host predictions are directly comparable).
    pub fn argmax(&self) -> usize {
        crate::models::infer::argmax_i32(&self.logits)
    }
}

/// Pad conv weights `[Cout][K][K][Cin]` to `[Cout][K][K][Cin_p]` with
/// zeros (mode kernels need word-aligned channel runs).
fn pad_conv_weights(qw: &[i8], cout: usize, k: usize, cin: usize, cin_p: usize) -> Vec<i8> {
    if cin == cin_p {
        return qw.to_vec();
    }
    let mut out = vec![0i8; cout * k * k * cin_p];
    for oc in 0..cout {
        for t in 0..k * k {
            let src = (oc * k * k + t) * cin;
            let dst = (oc * k * k + t) * cin_p;
            out[dst..dst + cin].copy_from_slice(&qw[src..src + cin]);
        }
    }
    out
}

/// Execute the quantized model on the ISS.
///
/// `modes[i]` selects the kernel for quantizable layer `i`: `None` runs
/// the scalar baseline, `Some(mode)` the packed kernel (the mode must
/// match the layer's quantization grid — checked). `mac` configures the
/// MAC-unit features (Fig. 7 ablations). A kernel that misbehaves on
/// the core (memory fault, runaway pc) surfaces as an `Err`.
pub fn run_model(
    qm: &QModel,
    input: &Tensor<i8>,
    modes: &[Option<MacMode>],
    mac: MacUnitConfig,
) -> Result<SimRun> {
    ensure!(modes.len() == qm.layers.len(), "one mode per quantizable layer");
    let mut layers = Vec::new();
    let mut li = 0usize;
    let mut res_i = 0usize;

    enum Flow {
        Map(Tensor<i8>),
        Flat(Vec<i8>),
    }
    impl Flow {
        fn flat(self) -> Vec<i8> {
            match self {
                Flow::Map(t) => t.data,
                Flow::Flat(v) => v,
            }
        }
        fn map(self) -> Tensor<i8> {
            match self {
                Flow::Map(t) => t,
                Flow::Flat(_) => panic!("expected feature map"),
            }
        }
    }

    let run_one = |l: &LayerSpec,
                   x: Flow,
                   li: &mut usize,
                   layers: &mut Vec<LayerRun>|
     -> Result<(Flow, Option<Vec<i32>>)> {
        let idx = *li;
        let q = &qm.layers[idx];
        let info = &qm.analysis.layers[idx];
        let mode = modes[idx];
        if let Some(m) = mode {
            ensure!(
                m.weight_bits() == q.w_bits,
                "layer {idx}: kernel mode {m:?} vs quantized bits {}",
                q.w_bits
            );
        }
        match *l {
            LayerSpec::Conv { cout, k, stride, pad, relu } => {
                *li += 1;
                let xp = pad_spatial(&x.map(), pad);
                // Mode kernels need Cin % 4 == 0: channel-pad with zeros.
                let (xp, cin_p) = if mode.is_some() && xp.shape[2] % 4 != 0 {
                    let p = pad_channels(&xp, 4, 0);
                    let c = p.shape[2];
                    (p, c)
                } else {
                    let c = xp.shape[2];
                    (xp, c)
                };
                let w = pad_conv_weights(&q.qw, cout, k, info.in_shape[2], cin_p);
                let spec = ConvSpec {
                    h: xp.shape[0],
                    w: xp.shape[1],
                    cin: cin_p,
                    cout,
                    k,
                    stride,
                    rq: q.rq,
                    relu,
                };
                let (out, perf) = run_conv_with(spec, mode, mac, &xp.data, &w, &q.bias)?;
                layers.push(LayerRun { layer: idx, mode, perf });
                Ok((Flow::Map(Tensor::from_vec(&[spec.ho(), spec.wo(), cout], out)), None))
            }
            LayerSpec::Depthwise { k, stride, pad, relu } => {
                *li += 1;
                let xp = pad_spatial(&x.map(), pad);
                let spec = DwSpec {
                    h: xp.shape[0],
                    w: xp.shape[1],
                    c: xp.shape[2],
                    k,
                    stride,
                    rq: q.rq,
                    relu,
                };
                let (out, perf) = run_depthwise_with(spec, mode, mac, &xp.data, &q.qw, &q.bias)?;
                layers.push(LayerRun { layer: idx, mode, perf });
                Ok((Flow::Map(Tensor::from_vec(&[spec.ho(), spec.wo(), spec.c], out)), None))
            }
            LayerSpec::Dense { out, relu } => {
                let is_last = info.is_last;
                *li += 1;
                let flat = x.flat();
                let spec = DenseSpec {
                    in_dim: flat.len(),
                    out_dim: out,
                    rq: q.rq,
                    relu,
                    out_i32: is_last,
                };
                let (qv, accs, perf) = run_dense_with(spec, mode, mac, &flat, &q.qw, &q.bias)?;
                layers.push(LayerRun { layer: idx, mode, perf });
                if is_last {
                    Ok((Flow::Flat(Vec::new()), Some(accs)))
                } else {
                    Ok((Flow::Flat(qv), None))
                }
            }
            LayerSpec::MaxPool2 => Ok((Flow::Map(qmaxpool2(&x.map())), None)),
            LayerSpec::AvgPoolGlobal => {
                let m = x.map();
                let c = m.shape[2];
                Ok((Flow::Map(Tensor::from_vec(&[1, 1, c], qavgpool_global(&m))), None))
            }
        }
    };

    let mut x = Flow::Map(input.clone());
    for node in &qm.spec.nodes {
        match node {
            Node::Layer(l) => {
                let (nx, logits) = run_one(l, x, &mut li, &mut layers)?;
                if let Some(logits) = logits {
                    return Ok(SimRun { logits, layers });
                }
                x = nx;
            }
            Node::Residual(inner) => {
                let skip = x.map();
                let mut b = Flow::Map(skip.clone());
                for l in inner {
                    let (nb, _) = run_one(l, b, &mut li, &mut layers)?;
                    b = nb;
                }
                let (rq_skip, rq_branch) = residual_requants(qm, res_i);
                res_i += 1;
                x = Flow::Map(qadd(&skip, rq_skip, &b.map(), rq_branch));
            }
        }
    }
    bail!("model must end in a dense logits layer")
}

/// Run one model over a batch of independent inputs in parallel.
///
/// Each worker runs the full sequential layer pipeline for its input;
/// all workers share the global kernel cache and memory pool, so the
/// per-input setup cost is amortised batch-wide. Results are in input
/// order and identical to per-input [`run_model`] calls. Every
/// [`SimRun`] carries the integer logits and [`SimRun::argmax`] class
/// alongside the perf counters, so accuracy and cycles for a batch
/// come out of the same executions.
///
/// # Example
///
/// ```no_run
/// use mpnn::models::infer::{calibrate, quantize_input, quantize_model, random_params};
/// use mpnn::models::sim_exec::{modes_for, run_model_batch};
/// use mpnn::models::synthetic::generate;
/// use mpnn::models::{analyze, zoo};
/// use mpnn::sim::MacUnitConfig;
///
/// let spec = zoo::lenet5();
/// let n = analyze(&spec).layers.len();
/// let params = random_params(&spec, 1);
/// let ds = generate(2, 8, spec.input, spec.num_classes, 0.4);
/// let sites = calibrate(&spec, &params, &ds.images[..2]);
/// let qm = quantize_model(&spec, &params, &sites, &vec![4u32; n]);
/// let inputs: Vec<_> = ds.images.iter().map(|im| quantize_input(&qm, im)).collect();
///
/// let runs = run_model_batch(&qm, &inputs, &modes_for(&qm), MacUnitConfig::full(), 4).unwrap();
/// for (run, &label) in runs.iter().zip(&ds.labels) {
///     println!("pred {} (label {label}), {} cycles", run.argmax(), run.total_cycles());
/// }
/// ```
pub fn run_model_batch(
    qm: &QModel,
    inputs: &[Tensor<i8>],
    modes: &[Option<MacMode>],
    mac: MacUnitConfig,
    workers: usize,
) -> Result<Vec<SimRun>> {
    crate::par::parallel_map(inputs.len(), workers, |j| run_model(qm, &inputs[j], modes, mac))
}

/// Kernel modes for a quantized model: the mode matching each layer's
/// bit-width (the extended-ISA execution).
pub fn modes_for(qm: &QModel) -> Vec<Option<MacMode>> {
    qm.bits.iter().map(|&b| MacMode::from_weight_bits(b)).collect()
}

/// All-baseline modes (the original-Ibex execution).
pub fn baseline_modes(qm: &QModel) -> Vec<Option<MacMode>> {
    vec![None; qm.layers.len()]
}

/// Convenience: does this layer benefit less from the extension (the
/// paper's depthwise observation)?
pub fn is_depthwise(qm: &QModel, idx: usize) -> bool {
    qm.analysis.layers[idx].kind == QKind::Depthwise
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::infer::{calibrate, qforward, quantize_input, quantize_model, random_params};
    use crate::models::synthetic::generate;
    use crate::models::{zoo, LayerSpec, ModelSpec, Node};

    fn toy_residual_model() -> ModelSpec {
        ModelSpec {
            name: "toy",
            input: [8, 8, 3],
            num_classes: 4,
            nodes: vec![
                Node::Layer(LayerSpec::Conv { cout: 8, k: 3, stride: 1, pad: 1, relu: true }),
                Node::Layer(LayerSpec::MaxPool2),
                Node::Residual(vec![
                    LayerSpec::Conv { cout: 16, k: 1, stride: 1, pad: 0, relu: true },
                    LayerSpec::Depthwise { k: 3, stride: 1, pad: 1, relu: true },
                    LayerSpec::Conv { cout: 8, k: 1, stride: 1, pad: 0, relu: false },
                ]),
                Node::Layer(LayerSpec::AvgPoolGlobal),
                Node::Layer(LayerSpec::Dense { out: 4, relu: false }),
            ],
        }
    }

    fn check_model(spec: &ModelSpec, bits: Vec<u32>, seed: u64) {
        let params = random_params(spec, seed);
        let ds = generate(seed ^ 1, 4, spec.input, spec.num_classes, 0.4);
        let sites = calibrate(spec, &params, &ds.images[..2]);
        let qm = quantize_model(spec, &params, &sites, &bits);
        let input = quantize_input(&qm, &ds.images[3]);
        let want = qforward(&qm, &input);

        // Extended execution (per-layer modes) must be bit-exact.
        let run = run_model(&qm, &input, &modes_for(&qm), MacUnitConfig::full()).unwrap();
        assert_eq!(run.logits, want, "extended ISS vs host reference");
        assert_eq!(run.layers.len(), qm.layers.len());

        // Baseline execution must also be bit-exact (same arithmetic).
        let base = run_model(&qm, &input, &baseline_modes(&qm), MacUnitConfig::full()).unwrap();
        assert_eq!(base.logits, want, "baseline ISS vs host reference");

        // And the extension must be faster + lighter on memory.
        assert!(run.total_cycles() < base.total_cycles());
        assert!(run.total_accesses() < base.total_accesses());
    }

    #[test]
    fn toy_residual_model_bit_exact_all_widths() {
        let spec = toy_residual_model();
        let n = crate::models::analyze(&spec).layers.len();
        check_model(&spec, vec![8; n], 100);
        check_model(&spec, vec![4; n], 101);
        check_model(&spec, vec![2; n], 102);
        // Mixed configuration: 8-bit first, then alternating.
        check_model(&spec, vec![8, 4, 2, 4, 8], 103);
    }

    #[test]
    fn lenet5_bit_exact_mixed() {
        let spec = zoo::lenet5();
        check_model(&spec, vec![8, 4, 4, 2, 8], 200);
    }

    #[test]
    fn batch_run_matches_sequential_runs() {
        let spec = toy_residual_model();
        let n = crate::models::analyze(&spec).layers.len();
        let bits = vec![4u32; n];
        let params = random_params(&spec, 7);
        let ds = generate(8, 6, spec.input, spec.num_classes, 0.4);
        let sites = calibrate(&spec, &params, &ds.images[..2]);
        let qm = quantize_model(&spec, &params, &sites, &bits);
        let inputs: Vec<_> = ds.images.iter().map(|im| quantize_input(&qm, im)).collect();
        let modes = modes_for(&qm);

        let batch = run_model_batch(&qm, &inputs, &modes, MacUnitConfig::full(), 3).unwrap();
        assert_eq!(batch.len(), inputs.len());
        for (i, input) in inputs.iter().enumerate() {
            let solo = run_model(&qm, input, &modes, MacUnitConfig::full()).unwrap();
            assert_eq!(batch[i].logits, solo.logits, "input {i}");
            assert_eq!(batch[i].total_cycles(), solo.total_cycles(), "input {i}");
        }
    }
}
