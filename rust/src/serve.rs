//! `mpnn serve` — a zero-dependency warm-evaluator daemon over the
//! content-addressed result store ([`crate::store`]).
//!
//! The sweep harnesses pay their warm-up (cycle-model measurement,
//! plan compilation, kernel translation, simulator memory pools) per
//! *process*; the ROADMAP's "sweep-as-a-service" story is to pay it
//! once and keep it resident. `Server` holds one [`Coordinator`] per
//! requested model — each wired to the shared [`ResultStore`] — plus
//! the process-global `SimSession` (plan cache, kernel cache,
//! `CostCache`), and answers a minimal HTTP/1.1 + JSON protocol on
//! `std::net::TcpListener` alone:
//!
//! * `POST /eval` `{"model": "lenet5", "bits": [8,4,4,2,8],
//!   "n_eval": 64}` → the sweep-level point for that configuration
//!   (store-backed: a repeat request from any client is a cache read,
//!   `"cached": true`).
//! * `GET /pareto?model=lenet5` → every stored point for the model
//!   plus the Pareto-front indices over them
//!   ([`pareto_front`](crate::dse::pareto::pareto_front), by MAC
//!   instructions — the Fig. 6 objective).
//! * `GET /stats` → request/store/coordinator/session counters.
//! * `POST /shutdown` → graceful stop: workers drain and `run`
//!   returns (no signal handling required — the protocol is the
//!   control surface).
//!
//! Concurrency: the listener is nonblocking and shared by a
//! [`crate::par::parallel_map`] worker pool (`--eval-workers`
//! threads); each worker loops accept → handle, so up to that many
//! clients are served in parallel and shutdown needs no thread
//! interruption, just the flag.

use crate::coordinator::Coordinator;
use crate::dse::pareto::pareto_front;
use crate::dse::EvalPoint;
use crate::error::{Context, Result};
use crate::exp::{EvalBackend, ExpOpts, MODEL_NAMES};
use crate::json::Json;
use crate::store::ResultStore;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Largest accepted request (headers + body). Far above any legitimate
/// eval/pareto request; a cap, not a tuning knob.
const MAX_REQUEST_BYTES: usize = 1 << 20;

/// How long an idle accept loop sleeps between polls of the
/// nonblocking listener (also the shutdown-latency bound per worker).
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// The daemon: a bound listener plus per-model warm coordinators.
pub struct Server {
    listener: TcpListener,
    opts: ExpOpts,
    store: ResultStore,
    coords: Mutex<HashMap<String, Arc<Coordinator>>>,
    shutdown: AtomicBool,
    requests: AtomicU64,
}

impl Server {
    /// Bind the daemon. Requires `--store` (the whole point is serving
    /// store-deduped results) and a pinned evaluator (`auto` would key
    /// the shared store inconsistently — same rule as sharded sweeps,
    /// see `docs/EVALUATORS.md`).
    pub fn bind(opts: &ExpOpts, addr: &str) -> Result<Server> {
        let dir = opts
            .store
            .clone()
            .ok_or_else(|| crate::anyhow!("serve needs --store <dir> (the shared result store)"))?;
        crate::ensure!(
            opts.backend != EvalBackend::Auto,
            "serve needs a pinned --evaluator (host|iss|analytic|pjrt): `auto` resolves per \
             machine and would key the shared store inconsistently (see docs/EVALUATORS.md)"
        );
        let store = ResultStore::open(&dir)?;
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding serve address {addr}"))?;
        // Nonblocking accept + poll: workers can observe the shutdown
        // flag without a self-connect trick or per-thread signals.
        listener.set_nonblocking(true)?;
        Ok(Server {
            listener,
            opts: opts.clone(),
            store,
            coords: Mutex::new(HashMap::new()),
            shutdown: AtomicBool::new(false),
            requests: AtomicU64::new(0),
        })
    }

    /// The bound address (ephemeral-port friendly: bind to `:0`, then
    /// read the port back).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Poison-tolerant lock on the coordinator map. A panicking request
    /// handler (served as HTTP 500, see [`Server::handle`]) may die
    /// while holding this mutex; no handler ever leaves the map
    /// mid-mutation (lookups and whole-entry inserts only), so
    /// recovering the guard is sound — and the alternative is a
    /// poisoned `unwrap()` bricking every request for the rest of the
    /// daemon's lifetime.
    fn coords_lock(&self) -> std::sync::MutexGuard<'_, HashMap<String, Arc<Coordinator>>> {
        self.coords.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Fetch (or build + cache) the warm coordinator for `model`. The
    /// build runs outside the map lock — it measures the cycle model on
    /// the ISS, and other models' requests shouldn't serialise behind
    /// it; a racing builder of the same model loses its work.
    fn coordinator(&self, model: &str) -> Result<Arc<Coordinator>> {
        if let Some(c) = self.coords_lock().get(model) {
            return Ok(Arc::clone(c));
        }
        crate::ensure!(
            MODEL_NAMES.contains(&model),
            "unknown model `{model}` (known: {})",
            MODEL_NAMES.join(", ")
        );
        let built = Arc::new(self.opts.coordinator(model)?);
        let mut map = self.coords_lock();
        let c = map.entry(model.to_string()).or_insert(built);
        Ok(Arc::clone(c))
    }

    /// Serve until `/shutdown`: each pool worker loops accept → handle
    /// over the shared nonblocking listener. Per-connection failures
    /// (malformed requests, dropped sockets) are logged and served as
    /// HTTP errors where possible — only listener-level failures abort.
    pub fn run(&self) -> Result<()> {
        let workers = self.opts.eval_workers.max(1);
        crate::par::parallel_map(workers, workers, |_| {
            loop {
                if self.shutdown.load(Ordering::SeqCst) {
                    return Ok(());
                }
                match self.listener.accept() {
                    Ok((stream, _)) => {
                        // Belt and braces around the per-request
                        // catch in `handle`: a panic escaping here
                        // would kill this pool worker and, at scope
                        // exit, the daemon.
                        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            self.handle(stream)
                        })) {
                            Ok(Ok(())) => {}
                            Ok(Err(e)) => eprintln!("[serve] connection error: {e}"),
                            Err(_) => {
                                eprintln!("[serve] connection handler panicked (recovered)")
                            }
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(ACCEPT_POLL);
                    }
                    Err(e) => return Err(crate::error::Error::from(e)),
                }
            }
        })?;
        Ok(())
    }

    fn handle(&self, mut stream: TcpStream) -> Result<()> {
        // Linux does not propagate the listener's nonblocking flag to
        // accepted sockets, but that is platform behaviour — pin it.
        stream.set_nonblocking(false)?;
        stream.set_read_timeout(Some(Duration::from_secs(10)))?;
        let (method, path, body) = read_request(&mut stream)?;
        self.requests.fetch_add(1, Ordering::Relaxed);
        let (route, query) = match path.split_once('?') {
            Some((r, q)) => (r, q),
            None => (path.as_str(), ""),
        };
        // A panic anywhere in a handler must stay inside this request:
        // answer a typed HTTP 500 and keep the worker alive. Without
        // the catch a single panicking request killed the daemon (and,
        // if it died holding `coords`, poisoned the map for good —
        // see [`Server::coords_lock`]).
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            match (method.as_str(), route) {
                ("POST", "/eval") => self.eval(&body).map(|j| (200, j)),
                ("GET", "/pareto") => self.pareto(query).map(|j| (200, j)),
                ("GET", "/stats") => Ok((200, self.stats())),
                (_, "/shutdown") => {
                    self.shutdown.store(true, Ordering::SeqCst);
                    Ok((200, Json::obj(vec![("ok", Json::Bool(true))])))
                }
                // Test-only route: dies while *holding* the coords
                // lock — the worst-case request the hardening tests
                // exercise end-to-end (panic + poisoned mutex).
                #[cfg(test)]
                ("POST", "/panic") => {
                    let _guard = self.coords_lock();
                    panic!("deliberate test panic while holding the coords lock");
                }
                _ => Ok((404, Json::obj(vec![("error", Json::s("no such endpoint"))]))),
            }
        }));
        let (status, json) = match outcome {
            Ok(Ok(r)) => r,
            Ok(Err(e)) => (400, Json::obj(vec![("error", Json::s(&e.to_string()))])),
            Err(payload) => {
                let what = payload
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| payload.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "opaque panic payload".to_string());
                (500, Json::obj(vec![("error", Json::s(&format!("internal panic: {what}")))]))
            }
        };
        write_response(&mut stream, status, &json)
    }

    /// `POST /eval`: score one configuration through the warm
    /// coordinator (store-consulting evaluate path). `cached` reports
    /// whether the backend actually ran for this request — false only
    /// on a genuine store+RAM miss.
    fn eval(&self, body: &str) -> Result<Json> {
        let j = Json::parse(body).map_err(|e| crate::anyhow!("bad /eval JSON: {e}"))?;
        let model = j.req_str("model")?.to_string();
        let bits: Vec<u32> = j
            .req_arr("bits")?
            .iter()
            .map(|b| match b.as_f64() {
                Some(v) if v == v.trunc() && [2.0, 4.0, 8.0].contains(&v) => Ok(v as u32),
                _ => Err(crate::anyhow!("bits entries must be 2, 4 or 8")),
            })
            .collect::<Result<_>>()?;
        let n_eval = match j.get("n_eval") {
            None | Some(Json::Null) => self.opts.eval_n,
            Some(v) => match v.as_f64() {
                Some(x) if x >= 1.0 && x == x.trunc() => x as usize,
                _ => crate::bail!("n_eval must be a positive integer"),
            },
        };
        let c = self.coordinator(&model)?;
        crate::ensure!(
            bits.len() == c.analysis.layers.len(),
            "model `{model}` has {} quantizable layers, got {} bits entries",
            c.analysis.layers.len(),
            bits.len()
        );
        let evals_before = c.metrics.acc_evals.load(Ordering::Relaxed);
        let point = c.evaluate(&bits, n_eval)?;
        let cached = c.metrics.acc_evals.load(Ordering::Relaxed) == evals_before;
        Ok(Json::obj(vec![
            ("model", Json::s(&model)),
            ("n_eval", Json::i(n_eval.min(c.model.test.images.len()) as i64)),
            ("cached", Json::Bool(cached)),
            ("point", point_json(&point)),
        ]))
    }

    /// `GET /pareto?model=..`: every stored point for the model, plus
    /// the Pareto-front indices over them (by MAC instructions — the
    /// Fig. 6 objective). Cost fields are recomposed from the local
    /// cycle model exactly as the sweep harnesses do.
    fn pareto(&self, query: &str) -> Result<Json> {
        let model = query
            .split('&')
            .find_map(|kv| kv.strip_prefix("model="))
            .ok_or_else(|| crate::anyhow!("/pareto needs ?model=<name>"))?
            .to_string();
        let c = self.coordinator(&model)?;
        let n_layers = c.analysis.layers.len();
        let points: Vec<EvalPoint> = self
            .store
            .scan()?
            .into_iter()
            .filter(|e| {
                e.model == model
                    && e.bits.len() == n_layers
                    && e.bits.iter().all(|b| [2, 4, 8].contains(b))
            })
            .map(|e| c.compose_point(&e.bits, &e.report))
            .collect();
        let front = pareto_front(&points, |p| p.mac_instructions);
        Ok(Json::obj(vec![
            ("model", Json::s(&model)),
            ("points", Json::Arr(points.iter().map(point_json).collect())),
            ("front", Json::Arr(front.iter().map(|&i| Json::i(i as i64)).collect())),
        ]))
    }

    /// `GET /stats`: request count, store contents/traffic (aggregated
    /// over the warm coordinators), and the process-global session
    /// counters the daemon exists to keep warm.
    fn stats(&self) -> Json {
        let entries = self.store.scan().map(|v| v.len()).unwrap_or(0);
        let (mut hits, mut misses) = (0u64, 0u64);
        let (mut submitted, mut cache_hits, mut acc_evals) = (0u64, 0u64, 0u64);
        let coords = self.coords_lock();
        let warm: Vec<Json> = coords.keys().map(|k| Json::s(k)).collect();
        for c in coords.values() {
            if let Some((h, m)) = c.store_counters() {
                hits += h;
                misses += m;
            }
            submitted += c.metrics.submitted.load(Ordering::Relaxed);
            cache_hits += c.metrics.cache_hits.load(Ordering::Relaxed);
            acc_evals += c.metrics.acc_evals.load(Ordering::Relaxed);
        }
        drop(coords);
        let st = &crate::sim::session::SimSession::global().stats;
        Json::obj(vec![
            ("requests", Json::i(self.requests.load(Ordering::Relaxed) as i64)),
            ("evaluator", Json::s(self.opts.backend.name())),
            ("models_warm", Json::Arr(warm)),
            (
                "store",
                Json::obj(vec![
                    ("entries", Json::i(entries as i64)),
                    ("hits", Json::i(hits as i64)),
                    ("misses", Json::i(misses as i64)),
                ]),
            ),
            (
                "coordinator",
                Json::obj(vec![
                    ("submitted", Json::i(submitted as i64)),
                    ("cache_hits", Json::i(cache_hits as i64)),
                    ("acc_evals", Json::i(acc_evals as i64)),
                ]),
            ),
            (
                "session",
                Json::obj(vec![
                    ("runs", Json::i(st.runs.load(Ordering::Relaxed) as i64)),
                    (
                        "plan_compiles",
                        Json::i(st.plan_compiles.load(Ordering::Relaxed) as i64),
                    ),
                    ("plan_hits", Json::i(st.plan_hits.load(Ordering::Relaxed) as i64)),
                    (
                        "analytic_hits",
                        Json::i(st.analytic_hits.load(Ordering::Relaxed) as i64),
                    ),
                ]),
            ),
        ])
    }
}

/// The `/eval` and `/pareto` point payload — same field set as the
/// shard artifacts (bits + accuracy + cost fields).
fn point_json(p: &EvalPoint) -> Json {
    Json::obj(vec![
        ("bits", Json::Arr(p.config.iter().map(|&b| Json::i(b as i64)).collect())),
        ("acc", Json::Num(p.accuracy as f64)),
        ("mac_instrs", Json::i(p.mac_instructions as i64)),
        ("cycles", Json::i(p.cycles as i64)),
        ("mem_accesses", Json::i(p.mem_accesses as i64)),
        ("iss_cycles", p.iss_cycles.map_or(Json::Null, |c| Json::i(c as i64))),
        ("divergence", p.divergence.map_or(Json::Null, |d| Json::Num(d as f64))),
    ])
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Minimal HTTP/1.1 request reader: request line, headers (only
/// `Content-Length` is honoured), then exactly the declared body.
fn read_request(stream: &mut TcpStream) -> Result<(String, String, String)> {
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let header_end = loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            break pos + 4;
        }
        crate::ensure!(buf.len() < MAX_REQUEST_BYTES, "request headers too large");
        let n = stream.read(&mut tmp)?;
        crate::ensure!(n > 0, "connection closed mid-request");
        buf.extend_from_slice(&tmp[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..header_end]).into_owned();
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    crate::ensure!(!method.is_empty() && path.starts_with('/'), "malformed request line");
    let content_length = lines
        .filter_map(|l| l.split_once(':'))
        .find(|(k, _)| k.trim().eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.trim().parse::<usize>().ok())
        .unwrap_or(0);
    crate::ensure!(content_length <= MAX_REQUEST_BYTES, "request body too large");
    let mut body = buf[header_end..].to_vec();
    while body.len() < content_length {
        let n = stream.read(&mut tmp)?;
        crate::ensure!(n > 0, "connection closed mid-body");
        body.extend_from_slice(&tmp[..n]);
    }
    body.truncate(content_length);
    Ok((method, path, String::from_utf8_lossy(&body).into_owned()))
}

fn write_response(stream: &mut TcpStream, status: u16, json: &Json) -> Result<()> {
    let body = json.to_string();
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        500 => "Internal Server Error",
        _ => "Error",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    /// Minimal blocking HTTP/1.1 client (mirrors the integration-test
    /// client in `tests/store.rs`).
    fn http(addr: &SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
        let mut s = TcpStream::connect(addr).unwrap();
        write!(
            s,
            "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .unwrap();
        let mut resp = String::new();
        s.read_to_string(&mut resp).unwrap();
        let status: u16 = resp.split_whitespace().nth(1).unwrap().parse().unwrap();
        let payload = resp.split("\r\n\r\n").nth(1).unwrap();
        (status, Json::parse(payload).unwrap())
    }

    #[test]
    fn daemon_survives_handler_panic_and_poisoned_lock() {
        // Regression for the mutex-poisoning brick: a panic inside one
        // request handler used to (a) kill the accept worker — taking
        // the whole `parallel_map` pool down at scope exit — and
        // (b) poison the `coords` lock so even a surviving worker died
        // on the next `.unwrap()`. The daemon must instead answer a
        // typed 500 and keep serving.
        let dir =
            std::env::temp_dir().join(format!("mpnn_serve_panic_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut opts = ExpOpts::default();
        opts.artifacts = PathBuf::from("/nonexistent");
        opts.backend = EvalBackend::Host;
        opts.eval_n = 8;
        opts.eval_workers = 2;
        opts.seed = 43;
        opts.store = Some(dir.clone());

        let server = Arc::new(Server::bind(&opts, "127.0.0.1:0").unwrap());
        let addr = server.local_addr().unwrap();
        let s2 = Arc::clone(&server);
        let daemon = std::thread::spawn(move || s2.run().unwrap());

        // The test-only route dies while *holding* the coords lock —
        // the worst case: panic and poisoned mutex in one request.
        let (st, err) = http(&addr, "POST", "/panic", "");
        assert_eq!(st, 500, "{err:?}");
        assert!(err.req_str("error").unwrap().contains("internal panic"), "{err:?}");

        // Every endpoint class still answers afterwards: stats (reads
        // the poisoned map), a real evaluation (builds a coordinator
        // and inserts into it), and malformed input (400, not death).
        let (st, stats) = http(&addr, "GET", "/stats", "");
        assert_eq!(st, 200, "{stats:?}");
        assert!(stats.req_u64("requests").unwrap() >= 2);

        let n = {
            let m = crate::models::format::load_or_fallback(
                std::path::Path::new("/nonexistent"),
                "lenet5",
                opts.seed,
            )
            .unwrap();
            crate::models::analyze(&m.spec).layers.len()
        };
        let bits = format!("[{}]", vec!["8"; n].join(","));
        let req = format!(r#"{{"model":"lenet5","bits":{bits},"n_eval":8}}"#);
        let (st, ev) = http(&addr, "POST", "/eval", &req);
        assert_eq!(st, 200, "{ev:?}");
        assert_eq!(http(&addr, "POST", "/eval", "not json").0, 400);

        // A second poisoned request after the map is populated must not
        // unsettle the warm coordinator either.
        assert_eq!(http(&addr, "POST", "/panic", "").0, 500);
        let (st, ev2) = http(&addr, "POST", "/eval", &req);
        assert_eq!(st, 200, "{ev2:?}");
        assert!(ev2.req_bool("cached").unwrap(), "warm repeat must be cache-served");

        let (st, bye) = http(&addr, "POST", "/shutdown", "");
        assert_eq!(st, 200);
        assert!(bye.req_bool("ok").unwrap());
        daemon.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// CLI entry point for `mpnn serve`: bind, announce, serve until
/// `/shutdown`.
pub fn run(opts: &ExpOpts, addr: &str) -> Result<()> {
    let server = Server::bind(opts, addr)?;
    println!(
        "[serve] listening on {} (store {}, evaluator {}, {} workers)",
        server.local_addr()?,
        opts.store.as_ref().expect("bind checked --store").display(),
        opts.backend.name(),
        opts.eval_workers.max(1),
    );
    server.run()?;
    println!("[serve] shut down cleanly");
    Ok(())
}
