//! Micro-benchmark harness used by `benches/*.rs` (offline environment —
//! criterion is not in the vendored crate set). Reports min/mean/p50/max
//! over timed iterations after warm-up, in criterion-like one-line format.

use std::time::{Duration, Instant};

/// One benchmark's collected statistics.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark label.
    pub name: String,
    /// Per-iteration wall times.
    pub samples: Vec<Duration>,
}

impl Stats {
    /// Mean per-iteration time.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    /// Median per-iteration time.
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        s[s.len() / 2]
    }

    /// Minimum per-iteration time.
    pub fn min(&self) -> Duration {
        *self.samples.iter().min().unwrap()
    }

    /// Maximum per-iteration time.
    pub fn max(&self) -> Duration {
        *self.samples.iter().max().unwrap()
    }
}

/// Time `f` for `iters` measured iterations (plus one warm-up) and print
/// a one-line summary. Returns the stats for further reporting.
pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> Stats {
    assert!(iters > 0);
    f(); // warm-up
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    let s = Stats { name: name.to_string(), samples };
    println!(
        "bench {:<44} iters {:>3}  min {:>12?}  mean {:>12?}  p50 {:>12?}  max {:>12?}",
        s.name,
        iters,
        s.min(),
        s.mean(),
        s.median(),
        s.max()
    );
    s
}

/// Convenience: benchmark returning a value (value of last call returned).
pub fn bench_val<T, F: FnMut() -> T>(name: &str, iters: usize, mut f: F) -> (Stats, T) {
    let mut last = None;
    let stats = bench(name, iters, || {
        last = Some(f());
    });
    (stats, last.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples() {
        let s = bench("noop", 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.samples.len(), 5);
        assert!(s.min() <= s.mean() && s.mean() <= s.max());
    }

    #[test]
    fn bench_val_returns_value() {
        let (_, v) = bench_val("val", 3, || 42);
        assert_eq!(v, 42);
    }
}
