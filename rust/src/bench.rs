//! Micro-benchmark harness used by `benches/*.rs` (offline environment —
//! criterion is not in the vendored crate set). Reports min/mean/p50/max
//! over timed iterations after warm-up, in criterion-like one-line format.
//!
//! [`JsonReport`] adds the machine-readable side: each bench binary can
//! collect its entries (name, iters, ns/iter, plus derived metrics like
//! MIPS) and write a `BENCH_<name>.json` next to the human output, so
//! the perf trajectory is tracked across PRs (CI uploads the files as
//! artifacts).

use crate::json::Json;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

/// One benchmark's collected statistics.
#[derive(Debug, Clone)]
pub struct Stats {
    /// Benchmark label.
    pub name: String,
    /// Per-iteration wall times.
    pub samples: Vec<Duration>,
}

impl Stats {
    /// Mean per-iteration time.
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    /// Median per-iteration time.
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        s[s.len() / 2]
    }

    /// Minimum per-iteration time.
    pub fn min(&self) -> Duration {
        *self.samples.iter().min().unwrap()
    }

    /// Maximum per-iteration time.
    pub fn max(&self) -> Duration {
        *self.samples.iter().max().unwrap()
    }
}

/// Time `f` for `iters` measured iterations (plus one warm-up) and print
/// a one-line summary. Returns the stats for further reporting.
pub fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> Stats {
    assert!(iters > 0);
    f(); // warm-up
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    let s = Stats { name: name.to_string(), samples };
    println!(
        "bench {:<44} iters {:>3}  min {:>12?}  mean {:>12?}  p50 {:>12?}  max {:>12?}",
        s.name,
        iters,
        s.min(),
        s.mean(),
        s.median(),
        s.max()
    );
    s
}

/// Convenience: benchmark returning a value (value of last call returned).
pub fn bench_val<T, F: FnMut() -> T>(name: &str, iters: usize, mut f: F) -> (Stats, T) {
    let mut last = None;
    let stats = bench(name, iters, || {
        last = Some(f());
    });
    (stats, last.unwrap())
}

/// Measured iterations for a bench binary: the `BENCH_ITERS` env var
/// overrides (CI smoke runs set `2` — the minimum at which
/// `iss_throughput` enforces its ratio floors), else `default`.
pub fn iters_from_env(default: usize) -> usize {
    std::env::var("BENCH_ITERS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}

/// Machine-readable bench report: collects per-benchmark entries and
/// top-level summary figures, then writes `BENCH_<name>.json`.
#[derive(Debug, Default)]
pub struct JsonReport {
    name: String,
    entries: Vec<Json>,
    summaries: Vec<(String, Json)>,
}

impl JsonReport {
    /// Report for the bench binary `name` (file `BENCH_<name>.json`).
    pub fn new(name: &str) -> Self {
        JsonReport { name: name.to_string(), ..Default::default() }
    }

    /// Record one benchmark's stats plus derived numeric metrics
    /// (e.g. `("mips", 840.0)`).
    pub fn record(&mut self, stats: &Stats, extras: &[(&str, f64)]) {
        let mut pairs = vec![
            ("name", Json::s(&stats.name)),
            ("iters", Json::i(stats.samples.len() as i64)),
            ("ns_per_iter", Json::Num(stats.median().as_nanos() as f64)),
            ("min_ns", Json::Num(stats.min().as_nanos() as f64)),
            ("mean_ns", Json::Num(stats.mean().as_nanos() as f64)),
        ];
        for &(k, v) in extras {
            pairs.push((k, Json::Num(v)));
        }
        self.entries.push(Json::obj(pairs));
    }

    /// Add a top-level summary figure (e.g. a worst-case speedup).
    pub fn summary(&mut self, key: &str, value: f64) {
        self.summaries.push((key.to_string(), Json::Num(value)));
    }

    /// The full document.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("bench".to_string(), Json::s(&self.name)),
            ("entries".to_string(), Json::Arr(self.entries.clone())),
        ];
        pairs.extend(self.summaries.iter().cloned());
        Json::Obj(pairs)
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the path.
    pub fn write_to(&self, dir: &Path) -> std::io::Result<PathBuf> {
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string())?;
        Ok(path)
    }

    /// Write `BENCH_<name>.json` into the current directory.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        self.write_to(Path::new("."))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples() {
        let s = bench("noop", 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(s.samples.len(), 5);
        assert!(s.min() <= s.mean() && s.mean() <= s.max());
    }

    #[test]
    fn bench_val_returns_value() {
        let (_, v) = bench_val("val", 3, || 42);
        assert_eq!(v, 42);
    }

    #[test]
    fn json_report_round_trips() {
        let s = bench("unit/json", 3, || {
            std::hint::black_box(1 + 1);
        });
        let mut rep = JsonReport::new("unit_test_report");
        rep.record(&s, &[("mips", 123.5)]);
        rep.summary("worst_speedup", 2.0);
        let path = rep.write_to(&std::env::temp_dir()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str(), Some("unit_test_report"));
        let entries = doc.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("name").unwrap().as_str(), Some("unit/json"));
        assert_eq!(entries[0].get("iters").unwrap().as_i64(), Some(3));
        assert_eq!(entries[0].get("mips").unwrap().as_f64(), Some(123.5));
        assert!(entries[0].get("ns_per_iter").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(doc.get("worst_speedup").unwrap().as_f64(), Some(2.0));
        let _ = std::fs::remove_file(path);
    }
}
