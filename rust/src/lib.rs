//! # mpnn-riscv — Mixed-precision Neural Networks on RISC-V Cores
//!
//! Full-system reproduction of *"Mixed-precision Neural Networks on RISC-V
//! Cores: ISA extensions for Multi-Pumped Soft SIMD Operations"* (ICCAD'24,
//! DOI 10.1145/3676536.3676840) as a three-layer Rust + JAX + Pallas stack.
//!
//! The crate contains every substrate the paper depends on, built from
//! scratch:
//!
//! * [`isa`] — bit-exact RV32IM encoder/decoder/disassembler plus the
//!   paper's three custom instructions (`nn_mac_8b/4b/2b`, Table 2).
//! * [`sim`] — a cycle-accurate Ibex-like 2-stage core simulator with the
//!   modified multiplier block: four 17-bit lanes, 2× multi-pumping and the
//!   guard-bit soft-SIMD datapath of Eq. (2). Two execution paths share
//!   the architectural model: the reference interpreter (`Core::step`)
//!   and the pre-decoded **micro-op engine** (`sim::engine`) that the
//!   hot measurement paths run on — branch targets resolved to program
//!   indices at translation time, per-op cycle costs precomputed, the
//!   kernel generators' inner-loop strips **and requant epilogues**
//!   fused into superinstructions, and whole reduction loops executed
//!   as native counted loops. `sim::session` adds the reuse layer:
//!   [`sim::session::SimSession`] pools simulator memories and caches
//!   translated kernels so repeated runs (DSE sweeps, whole-model
//!   measurement) stop paying per-invocation assembly + allocation.
//! * [`asm`] — macro-assembler (labels, pseudo-instructions) used by the
//!   kernel code generators.
//! * [`kernels`] — NN kernels emitted as RV32 instruction streams: baseline
//!   RV32IM conv/dense/depthwise and the Mode-1/2/3 variants using the
//!   custom MAC instructions.
//! * [`nn`] — quantized-NN substrate: tensors, integer layers, per-layer
//!   symmetric quantization to 2/4/8-bit grids, weight packing and the
//!   Jacob-style fixed-point requantization.
//! * [`models`] — the Table-3 model zoo (LeNet5, CIFAR-10 CNN, MCUNet-VWW,
//!   MobileNetV1) with weights trained at build time by `python/compile`.
//!   Execution lowers through [`models::plan`]: one compiled
//!   `ExecutionPlan` per `(model, config)` — kernel specs, requant
//!   parameters and pre-packed weight operands resolved once — drives
//!   both the host golden reference (`qforward`) and the whole-model
//!   ISS execution (`run_plan`), with per-step observer hooks for
//!   tracing; a keyed plan cache makes sweeps compile each
//!   configuration exactly once.
//! * [`dse`] — the mixed-precision design-space exploration: enumeration,
//!   pruning, Pareto extraction and accuracy-threshold selection.
//! * [`coordinator`] — the evaluation orchestrator: a worker pool with a
//!   cached per-config evaluation path, routing accuracy jobs to one of
//!   three [`coordinator::AccuracyEval`] backends — the host integer
//!   reference, the ISS-backed [`coordinator::IssEval`] (accuracy and
//!   cycles from the same binary-level `run_model_batch` executions,
//!   with a host-vs-ISS divergence check), or the PJRT runtime — and
//!   cycle jobs to the core simulator.
//! * [`energy`] — FPGA (Virtex-7) and ASIC (ASAP7) power/area/energy models
//!   calibrated to the paper's Table 4, plus the Table-5 SOTA comparison.
//! * [`runtime`] — PJRT client wrapper loading the AOT HLO-text artifacts
//!   produced by `python/compile/aot.py`.
//! * [`exp`] — the experiment harnesses regenerating every table and figure
//!   of the paper's evaluation section.
//! * [`store`] — persistent content-addressed result store: evaluation
//!   reports keyed by plan content fingerprint + dataset digest +
//!   sample count + MAC config + pinned backend, written atomically
//!   with quarantine-on-corruption, so results are computed once per
//!   unique subject anywhere and served from disk everywhere
//!   (`--store <dir>` on the sweep harnesses).
//! * [`serve`] — `mpnn serve`: a zero-dependency HTTP/JSON daemon
//!   holding warm simulator sessions, the plan cache and the cost
//!   cache across requests, answering `/eval`, `/pareto` and `/stats`
//!   over the shared result store.
//!
//! ## Repo-level documentation
//!
//! * `docs/ARCHITECTURE.md` — top-down tour of the crate (asm → isa →
//!   sim engine/session → kernels → models/sim_exec → dse → coordinator
//!   → exp) with the dataflow diagram of the unified accuracy+cycles
//!   path and where PJRT slots in once vendored.
//! * `docs/EVALUATORS.md` — the three accuracy backends
//!   (host / iss / pjrt), their fidelity/speed trade-offs and how to
//!   pick one per experiment.

pub mod asm;
pub mod bench;
pub mod coordinator;
pub mod dse;
pub mod energy;
pub mod error;
pub mod exp;
pub mod isa;
pub mod json;
pub mod kernels;
pub mod models;
pub mod nn;
pub mod par;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod store;

pub use error::{Context, Error};

/// Crate-wide result type.
pub type Result<T> = error::Result<T>;
