//! Content-addressed result store integration tests.
//!
//! The store's contract has four load-bearing properties, each pinned
//! here:
//!
//! 1. **Key sensitivity** — flipping any key component (model content,
//!    bit vector, kernel modes, dataset, sample count, backend tag,
//!    MAC-unit features) produces a distinct key; nothing aliases.
//! 2. **Warm re-runs are free and identical** — a second coordinator
//!    over the same store serves every configuration from disk (zero
//!    evaluator runs) and reproduces the cold points *exactly*
//!    (`EvalPoint` equality is field-exact, the same bar the shard
//!    merger holds results to).
//! 3. **Corruption is quarantined, never served** — a damaged entry
//!    surfaces as a typed [`StoreError`] on the strict path, is moved
//!    aside to `.bad` on the lenient path, and the recomputed result
//!    matches the original.
//! 4. **`mpnn serve` round-trips** — a daemon on an ephemeral port
//!    answers `/eval` (store-deduped on repeat), `/pareto` (front
//!    matching a local recomputation), `/stats`, and `/shutdown`.

use mpnn::coordinator::{Coordinator, HostEval};
use mpnn::dse::pareto::pareto_front;
use mpnn::exp::{EvalBackend, ExpOpts};
use mpnn::json::Json;
use mpnn::models::analyze;
use mpnn::models::format::load_or_fallback;
use mpnn::models::infer::quantize_model;
use mpnn::models::plan::content_fingerprint;
use mpnn::models::sim_exec::modes_for;
use mpnn::serve::Server;
use mpnn::sim::MacUnitConfig;
use mpnn::store::{dataset_digest, ResultStore, StoreError, StoreKey};
use std::collections::HashSet;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;

/// Fresh per-test store directory (removed up front so reruns of a
/// failed test start clean).
fn tmp_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mpnn_store_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Host-evaluator coordinator over the synthetic lenet5 fallback.
fn coordinator(seed: u64) -> Coordinator {
    let model = load_or_fallback(Path::new("/nonexistent"), "lenet5", seed).unwrap();
    let test = model.test.clone();
    Coordinator::new(model, Box::new(HostEval { test }), 2).unwrap()
}

#[test]
fn key_hash_is_sensitive_to_every_component() {
    let m = load_or_fallback(Path::new("/nonexistent"), "lenet5", 11).unwrap();
    let n = analyze(&m.spec).layers.len();
    let bits = vec![8u32; n];
    let qm = quantize_model(&m.spec, &m.params, &m.sites, &bits);
    let fp = content_fingerprint(&qm, &modes_for(&qm));
    let dd = dataset_digest(&m.test);
    let full = MacUnitConfig::full();
    let key = |fp, dd, n_eval, backend: &str, mac| {
        StoreKey::new(fp, dd, n_eval, backend, mac).unwrap().hash()
    };

    let mut hashes = HashSet::new();
    assert!(hashes.insert(key(fp, dd, 8, "host", full)), "baseline");

    // Model content: same architecture and bits, different trained
    // weights (seed) — must not alias.
    let m2 = load_or_fallback(Path::new("/nonexistent"), "lenet5", 12).unwrap();
    let qm2 = quantize_model(&m2.spec, &m2.params, &m2.sites, &bits);
    let fp2 = content_fingerprint(&qm2, &modes_for(&qm2));
    assert!(hashes.insert(key(fp2, dd, 8, "host", full)), "model content");

    // Bit vector.
    let mut bits_b = bits.clone();
    bits_b[1] = 4;
    let qmb = quantize_model(&m.spec, &m.params, &m.sites, &bits_b);
    let fpb = content_fingerprint(&qmb, &modes_for(&qmb));
    assert!(hashes.insert(key(fpb, dd, 8, "host", full)), "bit vector");

    // Kernel modes: same quantized model, baseline (no custom MAC)
    // modes instead of the canonical per-width ones.
    let fpm = content_fingerprint(&qm, &vec![None; n]);
    assert!(hashes.insert(key(fpm, dd, 8, "host", full)), "kernel modes");

    // Evaluation dataset.
    let dd2 = dataset_digest(&m2.test);
    assert!(hashes.insert(key(fp, dd2, 8, "host", full)), "dataset");

    // Sample count, backend tag, MAC-unit features.
    assert!(hashes.insert(key(fp, dd, 9, "host", full)), "n_eval");
    assert!(hashes.insert(key(fp, dd, 8, "iss", full)), "backend");
    assert!(
        hashes.insert(key(fp, dd, 8, "host", MacUnitConfig::packing_only())),
        "mac config"
    );

    // Cluster cores: a multi-core geometry keys separately, but the
    // explicit single-core form must alias the implicit default (the
    // byte-compatibility contract with pre-cluster stores).
    assert!(hashes.insert(key(fp, dd, 8, "host", full.with_cores(4))), "cores");
    assert!(!hashes.insert(key(fp, dd, 8, "host", full.with_cores(1))), "cores=1 aliases");
    assert_eq!(hashes.len(), 9);
}

#[test]
fn warm_rerun_serves_everything_from_the_store_identically() {
    let dir = tmp_dir("warm");
    let n = {
        let m = load_or_fallback(Path::new("/nonexistent"), "lenet5", 21).unwrap();
        analyze(&m.spec).layers.len()
    };
    let mut configs = vec![vec![8u32; n], vec![4u32; n]];
    let mut mixed = vec![4u32; n];
    mixed[0] = 8;
    configs.push(mixed);

    let mut cold = coordinator(21);
    cold.attach_store(ResultStore::open(&dir).unwrap()).unwrap();
    let cold_pts: Vec<_> = configs.iter().map(|c| cold.evaluate(c, 8).unwrap()).collect();
    assert_eq!(cold.metrics.acc_evals.load(Ordering::Relaxed), configs.len() as u64);
    assert_eq!(cold.store_counters(), Some((0, configs.len() as u64)));

    // A fresh process (fresh coordinator, empty RAM cache) over the
    // same store: zero evaluator runs, field-exact points.
    let mut warm = coordinator(21);
    warm.attach_store(ResultStore::open(&dir).unwrap()).unwrap();
    let warm_pts: Vec<_> = configs.iter().map(|c| warm.evaluate(c, 8).unwrap()).collect();
    assert_eq!(warm_pts, cold_pts);
    assert_eq!(warm.metrics.acc_evals.load(Ordering::Relaxed), 0);
    assert_eq!(warm.store_counters(), Some((configs.len() as u64, 0)));

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_entry_fails_typed_quarantines_and_recomputes() {
    let dir = tmp_dir("bad");
    let mut c = coordinator(31);
    c.attach_store(ResultStore::open(&dir).unwrap()).unwrap();
    let cfg = vec![8u32; c.analysis.layers.len()];
    let original = c.evaluate(&cfg, 8).unwrap();

    // Re-derive the entry's key exactly as the coordinator does.
    let qm = c.quantized(&cfg);
    let key = StoreKey::new(
        content_fingerprint(&qm, &modes_for(&qm)),
        dataset_digest(&c.model.test),
        8.min(c.model.test.images.len()),
        "host",
        MacUnitConfig::full(),
    )
    .unwrap();
    let store = ResultStore::open(&dir).unwrap();
    let path = store.path_for(&key);
    assert!(path.exists(), "cold evaluation must have persisted {}", path.display());
    assert!(store.load(&key).is_ok());

    // Truncated garbage: typed Parse error on the strict path.
    std::fs::write(&path, "{\"schema\": 1, trunca").unwrap();
    match store.load(&key) {
        Err(StoreError::Parse { .. }) => {}
        other => panic!("expected StoreError::Parse, got {other:?}"),
    }

    // Wrong schema version: typed Version error (valid JSON, wrong era).
    std::fs::write(&path, "{\"schema\": 999}").unwrap();
    match store.load(&key) {
        Err(StoreError::Version { found: 999, .. }) => {}
        other => panic!("expected StoreError::Version, got {other:?}"),
    }

    // Lenient path: miss + quarantine to `.bad`, never a wrong report.
    assert!(store.get(&key).is_none());
    assert!(PathBuf::from(format!("{}.bad", path.display())).exists());
    let (hits, misses, quarantined) = store.counters();
    assert_eq!((hits, quarantined), (0, 1));
    assert!(misses >= 1);

    // A fresh coordinator recomputes, repairs the entry, and matches.
    let mut c2 = coordinator(31);
    c2.attach_store(ResultStore::open(&dir).unwrap()).unwrap();
    let recomputed = c2.evaluate(&cfg, 8).unwrap();
    assert_eq!(recomputed, original);
    assert_eq!(c2.metrics.acc_evals.load(Ordering::Relaxed), 1);
    assert!(store.load(&key).is_ok(), "recompute must rewrite a clean entry");

    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn crash_leftover_tmp_files_are_invisible() {
    let dir = tmp_dir("tmp");
    let store = ResultStore::open(&dir).unwrap();
    // Simulate an interrupted atomic write: temp files (both the real
    // naming shape and a json-suffixed cousin) in a fan-out directory.
    let fan = dir.join("ab");
    std::fs::create_dir_all(&fan).unwrap();
    std::fs::write(fan.join(".tmp.abcd1234abcd1234.9999"), "{\"schema\": 1, trunc").unwrap();
    std::fs::write(fan.join(".tmp.abcd1234abcd1234.json"), "{\"schema\": 1}").unwrap();
    assert_eq!(store.scan().unwrap().len(), 0, "scan must skip temp files");

    // Keyed reads are equally unaffected: a plain miss, no quarantine.
    let k = StoreKey::new(1, 2, 3, "host", MacUnitConfig::full()).unwrap();
    assert!(store.get(&k).is_none());
    assert_eq!(store.counters(), (0, 1, 0));

    let _ = std::fs::remove_dir_all(&dir);
}

/// Minimal blocking HTTP/1.1 client for the serve tests.
fn http(addr: &SocketAddr, method: &str, path: &str, body: &str) -> (u16, Json) {
    let mut s = TcpStream::connect(addr).unwrap();
    write!(
        s,
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    let status: u16 = resp.split_whitespace().nth(1).unwrap().parse().unwrap();
    let payload = resp.split("\r\n\r\n").nth(1).unwrap();
    (status, Json::parse(payload).unwrap())
}

#[test]
fn serve_round_trips_eval_pareto_stats_shutdown() {
    let dir = tmp_dir("serve");
    let mut opts = ExpOpts::default();
    opts.artifacts = PathBuf::from("/nonexistent");
    opts.backend = EvalBackend::Host;
    opts.eval_n = 8;
    opts.eval_workers = 2;
    opts.seed = 41;
    opts.store = Some(dir.clone());

    let server = Arc::new(Server::bind(&opts, "127.0.0.1:0").unwrap());
    let addr = server.local_addr().unwrap();
    let s2 = Arc::clone(&server);
    let daemon = std::thread::spawn(move || s2.run().unwrap());

    let m = load_or_fallback(Path::new("/nonexistent"), "lenet5", opts.seed).unwrap();
    let n = analyze(&m.spec).layers.len();
    let mut mixed = vec![4u32; n];
    mixed[0] = 8;
    let arr = |b: &[u32]| {
        format!("[{}]", b.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(","))
    };

    // First /eval runs the backend; the identical repeat is served warm.
    let req = format!(r#"{{"model":"lenet5","bits":{},"n_eval":8}}"#, arr(&mixed));
    let (st, first) = http(&addr, "POST", "/eval", &req);
    assert_eq!(st, 200, "{first:?}");
    assert_eq!(first.req_bool("cached").unwrap(), false);
    let (st, second) = http(&addr, "POST", "/eval", &req);
    assert_eq!(st, 200);
    assert!(second.req_bool("cached").unwrap(), "repeat must be cache/store-served");
    assert_eq!(second.get("point"), first.get("point"));

    // A second configuration so the front is over two points.
    let all8 = vec![8u32; n];
    let req8 = format!(r#"{{"model":"lenet5","bits":{},"n_eval":8}}"#, arr(&all8));
    assert_eq!(http(&addr, "POST", "/eval", &req8).0, 200);

    // Malformed requests are 400s, not daemon deaths.
    let (st, err) = http(&addr, "POST", "/eval", r#"{"model":"nope","bits":[8]}"#);
    assert_eq!(st, 400);
    assert!(err.req_str("error").unwrap().contains("unknown model"));
    assert_eq!(http(&addr, "GET", "/nowhere", "").0, 404);

    // /pareto: points from the store, front matching a local
    // recomputation over the same reports.
    let (st, pj) = http(&addr, "GET", "/pareto?model=lenet5", "");
    assert_eq!(st, 200, "{pj:?}");
    let points = pj.req_arr("points").unwrap();
    assert_eq!(points.len(), 2);
    let mut local = coordinator(opts.seed);
    local.attach_store(ResultStore::open(&dir).unwrap()).unwrap();
    let local_pts: Vec<_> = points
        .iter()
        .map(|p| {
            let bits: Vec<u32> =
                p.req_arr("bits").unwrap().iter().map(|b| b.as_f64().unwrap() as u32).collect();
            local.evaluate(&bits, 8).unwrap()
        })
        .collect();
    assert_eq!(local.metrics.acc_evals.load(Ordering::Relaxed), 0, "store must be warm");
    let want: Vec<i64> =
        pareto_front(&local_pts, |p| p.mac_instructions).iter().map(|&i| i as i64).collect();
    let got: Vec<i64> =
        pj.req_arr("front").unwrap().iter().map(|f| f.as_i64().unwrap()).collect();
    assert_eq!(got, want);

    // /stats reflects the traffic; /shutdown drains the workers.
    let (st, stats) = http(&addr, "GET", "/stats", "");
    assert_eq!(st, 200);
    assert!(stats.req_u64("requests").unwrap() >= 6);
    assert_eq!(stats.get("store").unwrap().req_u64("entries").unwrap(), 2);
    assert_eq!(stats.req_str("evaluator").unwrap(), "host");

    let (st, bye) = http(&addr, "POST", "/shutdown", "");
    assert_eq!(st, 200);
    assert!(bye.req_bool("ok").unwrap());
    daemon.join().unwrap();

    let _ = std::fs::remove_dir_all(&dir);
}
