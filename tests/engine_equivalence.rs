//! Differential property test: the pre-decoded micro-op engine
//! (`sim::engine`) must be observationally identical to the reference
//! interpreter (`Core::step`) — same exit reason, registers, pc, memory
//! image, memory-access counters, perf counters (including exact cycle
//! totals) and MAC-unit counters — over randomly generated RV32IM +
//! `nn_mac` programs.
//!
//! The generator emits every instruction class, the exact inner-loop
//! strips the engine fuses (packed-MAC, scalar-MAC, loop latches with
//! backward branches), deliberate memory faults, and `jalr`s that land
//! near (or inside) fused strips to exercise the dynamic-entry
//! fallback. Programs terminate by construction: control flow is
//! forward-only except bounded counted loops.

use mpnn::isa::*;
use mpnn::rng::Rng;
use mpnn::sim::{Core, CoreConfig, ExitReason};

const MEM: usize = 4096;

/// Run `prog` on both interpreters and assert identical outcomes.
fn assert_equiv(prog: Vec<Instr>, max_cycles: u64, tag: &str) -> ExitReason {
    let cfg = CoreConfig { mem_size: MEM, ..Default::default() };
    let mut legacy = Core::new(cfg, prog.clone(), 0);
    let mut fast = Core::new(cfg, prog, 0);
    let cp = fast.compile();
    let r1 = legacy.run(max_cycles);
    let r2 = fast.run_engine(&cp, max_cycles);
    assert_eq!(r1, r2, "{tag}: exit reason");
    assert_eq!(legacy.regs, fast.regs, "{tag}: registers");
    assert_eq!(legacy.pc, fast.pc, "{tag}: pc");
    assert_eq!(legacy.perf, fast.perf, "{tag}: perf counters");
    assert_eq!(legacy.mem.loads, fast.mem.loads, "{tag}: mem loads");
    assert_eq!(legacy.mem.stores, fast.mem.stores, "{tag}: mem stores");
    assert_eq!(legacy.mem.load_bytes, fast.mem.load_bytes, "{tag}: load bytes");
    assert_eq!(legacy.mem.store_bytes, fast.mem.store_bytes, "{tag}: store bytes");
    assert_eq!(
        legacy.mem.read_bytes(0, MEM),
        fast.mem.read_bytes(0, MEM),
        "{tag}: memory image"
    );
    assert_eq!(legacy.mac_unit.total_macs, fast.mac_unit.total_macs, "{tag}: mac count");
    assert_eq!(legacy.mac_unit.total_issues, fast.mac_unit.total_issues, "{tag}: mac issues");
    r1
}

/// Registers the generator may clobber with arbitrary values.
const SCRATCH: [u8; 10] = [5, 6, 7, 8, 10, 11, 12, 13, 14, 15];
/// Data-pointer registers (initialised to in-bounds word addresses).
const BASES: [u8; 6] = [21, 22, 23, 24, 25, 26];
/// Loop counter (only the latch template touches it).
const CTR: u8 = 9;
/// Jump-target register (holds the final ecall's pc).
const JREG: u8 = 30;
/// Out-of-bounds pointer (initialised past the end of memory).
const OOB: u8 = 27;

struct Gen {
    rng: Rng,
    prog: Vec<Instr>,
}

impl Gen {
    fn pick<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[(self.rng.next_u32() as usize) % xs.len()]
    }

    fn scratch(&mut self) -> u8 {
        let s = SCRATCH;
        self.pick(&s)
    }

    fn base(&mut self) -> u8 {
        let b = BASES;
        self.pick(&b)
    }

    fn alu_op(&mut self) -> AluOp {
        self.pick(&[
            AluOp::Add,
            AluOp::Sub,
            AluOp::Sll,
            AluOp::Slt,
            AluOp::Sltu,
            AluOp::Xor,
            AluOp::Srl,
            AluOp::Sra,
            AluOp::Or,
            AluOp::And,
        ])
    }

    /// One random body item; may emit several instructions.
    fn emit_item(&mut self, faulty: bool) {
        match self.rng.next_u32() % 14 {
            0 => {
                let (op, rd, rs1) = (self.alu_op(), self.scratch(), self.scratch());
                let op = if op == AluOp::Sub { AluOp::Add } else { op }; // OP-IMM has no sub
                let imm = self.rng.range_i32(-2048, 2047);
                self.prog.push(Instr::OpImm { op, rd, rs1, imm });
            }
            1 => {
                let (op, rd) = (self.alu_op(), self.scratch());
                let (rs1, rs2) = (self.scratch(), self.scratch());
                self.prog.push(Instr::Op { op, rd, rs1, rs2 });
            }
            2 => {
                let op = self.pick(&[
                    MulOp::Mul,
                    MulOp::Mulh,
                    MulOp::Mulhsu,
                    MulOp::Mulhu,
                    MulOp::Div,
                    MulOp::Divu,
                    MulOp::Rem,
                    MulOp::Remu,
                ]);
                let (rd, rs1, rs2) = (self.scratch(), self.scratch(), self.scratch());
                self.prog.push(Instr::MulDiv { op, rd, rs1, rs2 });
            }
            3 => {
                let rd = self.scratch();
                let imm = (self.rng.next_u32() & 0xffff_f000) as i32;
                if self.rng.next_u32() % 2 == 0 {
                    self.prog.push(Instr::Lui { rd, imm });
                } else {
                    self.prog.push(Instr::Auipc { rd, imm });
                }
            }
            4 => {
                // In-bounds load of a random width.
                let op = self.pick(&[LoadOp::Lb, LoadOp::Lbu, LoadOp::Lh, LoadOp::Lhu, LoadOp::Lw]);
                let width = match op {
                    LoadOp::Lb | LoadOp::Lbu => 1,
                    LoadOp::Lh | LoadOp::Lhu => 2,
                    LoadOp::Lw => 4,
                };
                let offset = ((self.rng.next_u32() % 128) * width) as i32 & !(width as i32 - 1);
                let (rd, rs1) = (self.scratch(), self.base());
                self.prog.push(Instr::Load { op, rd, rs1, offset });
            }
            5 => {
                let op = self.pick(&[StoreOp::Sb, StoreOp::Sh, StoreOp::Sw]);
                let width = match op {
                    StoreOp::Sb => 1,
                    StoreOp::Sh => 2,
                    StoreOp::Sw => 4,
                };
                let offset = ((self.rng.next_u32() % 128) * width) as i32;
                let (rs1, rs2) = (self.base(), self.scratch());
                self.prog.push(Instr::Store { op, rs1, rs2, offset });
            }
            6 => {
                // Standalone nn_mac on whatever the registers hold.
                let mode = self.pick(&[MacMode::W8, MacMode::W4, MacMode::W2]);
                let k = mode.activation_regs() as u8;
                let rd = self.scratch();
                let rs1 = 10 + (self.rng.next_u32() % (17 - k as u32)) as u8; // rs1+k <= 27
                let rs2 = self.scratch();
                self.prog.push(Instr::NnMac { mode, rd, rs1, rs2 });
            }
            7 => {
                let csr = self.pick(&[
                    csr::MCYCLE,
                    csr::MINSTRET,
                    csr::MHPM_LOADS,
                    csr::MHPM_STORES,
                    csr::MHPM_MACS,
                ]);
                let rd = self.scratch();
                self.prog.push(Instr::Csr { op: CsrOp::Rs, rd, rs1: 0, csr });
            }
            8 => self.prog.push(Instr::Fence),
            9 => {
                // Forward conditional branch over 1..=4 instructions.
                let op = self.pick(&[
                    BranchOp::Beq,
                    BranchOp::Bne,
                    BranchOp::Blt,
                    BranchOp::Bge,
                    BranchOp::Bltu,
                    BranchOp::Bgeu,
                ]);
                let (rs1, rs2) = (self.scratch(), self.scratch());
                let d = 1 + (self.rng.next_u32() % 4) as i32;
                self.prog.push(Instr::Branch { op, rs1, rs2, offset: 4 * (d + 1) });
                for _ in 0..d {
                    self.emit_simple();
                }
            }
            10 => {
                // Forward jal over 1..=3 instructions.
                let d = 1 + (self.rng.next_u32() % 3) as i32;
                let rd = if self.rng.next_u32() % 2 == 0 { 0 } else { 1 };
                self.prog.push(Instr::Jal { rd, offset: 4 * (d + 1) });
                for _ in 0..d {
                    self.emit_simple();
                }
            }
            11 => {
                // The packed-kernel strip the engine fuses.
                let mode = self.pick(&[MacMode::W8, MacMode::W4, MacMode::W2]);
                let k = mode.activation_regs() as usize;
                let act_rd = 12u8; // x12..x15
                let act_base = 21u8;
                let act_off = ((self.rng.next_u32() % 64) * 4) as i32;
                for j in 0..k {
                    self.prog.push(Instr::Load {
                        op: LoadOp::Lw,
                        rd: act_rd + j as u8,
                        rs1: act_base,
                        offset: act_off + 4 * j as i32,
                    });
                }
                let w_off = ((self.rng.next_u32() % 64) * 4) as i32;
                self.prog.push(Instr::Load { op: LoadOp::Lw, rd: 11, rs1: 22, offset: w_off });
                self.prog.push(Instr::NnMac { mode, rd: 10, rs1: act_rd, rs2: 11 });
            }
            12 => {
                // The scalar baseline MAC strip.
                let a_off = (self.rng.next_u32() % 256) as i32;
                let b_off = (self.rng.next_u32() % 256) as i32;
                self.prog.push(Instr::Load { op: LoadOp::Lb, rd: 5, rs1: 23, offset: a_off });
                self.prog.push(Instr::Load { op: LoadOp::Lb, rd: 6, rs1: 24, offset: b_off });
                self.prog.push(Instr::MulDiv { op: MulOp::Mul, rd: 7, rs1: 5, rs2: 6 });
                self.prog.push(Instr::Op { op: AluOp::Add, rd: 8, rs1: 8, rs2: 7 });
            }
            _ => {
                if faulty && self.rng.next_u32() % 3 == 0 {
                    // Deliberate fault: out-of-bounds (x27 holds an
                    // address beyond memory) or misaligned.
                    if self.rng.next_u32() % 2 == 0 {
                        self.prog.push(Instr::Load {
                            op: LoadOp::Lw,
                            rd: self.scratch(),
                            rs1: OOB,
                            offset: 0,
                        });
                    } else {
                        self.prog.push(Instr::Store {
                            op: StoreOp::Sw,
                            rs1: self.base(),
                            rs2: self.scratch(),
                            offset: 2,
                        });
                    }
                } else {
                    // Bounded backward loop: the latch shape the engine
                    // fuses. Counter in x9; `blt x0, x9` exits cleanly
                    // even when entered with a stale counter.
                    let c = 1 + (self.rng.next_u32() % 3) as i32;
                    self.prog.push(Instr::OpImm { op: AluOp::Add, rd: CTR, rs1: 0, imm: c });
                    let bump = self.scratch();
                    self.prog.push(Instr::OpImm { op: AluOp::Add, rd: bump, rs1: bump, imm: 1 });
                    self.prog.push(Instr::OpImm { op: AluOp::Add, rd: CTR, rs1: CTR, imm: -1 });
                    self.prog.push(Instr::Branch {
                        op: BranchOp::Blt,
                        rs1: 0,
                        rs2: CTR,
                        offset: -8,
                    });
                }
            }
        }
    }

    /// A single always-safe instruction (used under skipped branches).
    fn emit_simple(&mut self) {
        let (rd, rs1) = (self.scratch(), self.scratch());
        let imm = self.rng.range_i32(-64, 64);
        self.prog.push(Instr::OpImm { op: AluOp::Add, rd, rs1, imm });
    }
}

/// Generate one random terminating program.
fn random_program(seed: u64, faulty: bool, with_jalr: bool) -> Vec<Instr> {
    let mut g = Gen { rng: Rng::new(seed), prog: Vec::new() };

    // Prologue. Slot 0 is patched with the final ecall's pc below.
    g.prog.push(Instr::OpImm { op: AluOp::Add, rd: JREG, rs1: 0, imm: 0 });
    // x27 → the first address past the 4 KiB memory (fault pointer).
    g.prog.push(Instr::Lui { rd: OOB, imm: 0x1000 });
    for (i, &b) in BASES.iter().enumerate() {
        let addr = 1024 + 128 * i as i32 + ((g.rng.next_u32() % 16) * 4) as i32;
        g.prog.push(Instr::OpImm { op: AluOp::Add, rd: b, rs1: 0, imm: addr });
    }
    for &r in &SCRATCH {
        let imm = g.rng.range_i32(-2048, 2047);
        g.prog.push(Instr::OpImm { op: AluOp::Add, rd: r, rs1: 0, imm });
    }
    // Seed some data so loads see non-zero bytes.
    for j in 0..8 {
        let rs2 = g.scratch();
        g.prog.push(Instr::Store { op: StoreOp::Sw, rs1: 21, rs2, offset: 4 * j });
    }

    let items = 12 + (g.rng.next_u32() % 20) as usize;
    for i in 0..items {
        g.emit_item(faulty);
        if with_jalr && i == items / 2 {
            // Jump via x30 to (near) the final ecall; negative offsets
            // land just before it — possibly inside a fused strip,
            // exercising the dynamic-entry fallback.
            let off = -4 * (g.rng.next_u32() % 3) as i32;
            g.prog.push(Instr::Jalr { rd: 1, rs1: JREG, offset: off });
        }
    }
    g.prog.push(Instr::Ecall);

    // Patch x30 with the ecall pc (fits in a 12-bit immediate as long
    // as programs stay short).
    let ecall_pc = 4 * (g.prog.len() as i32 - 1);
    assert!(ecall_pc <= 2047, "generated program too long: {} instrs", g.prog.len());
    g.prog[0] = Instr::OpImm { op: AluOp::Add, rd: JREG, rs1: 0, imm: ecall_pc };
    g.prog
}

#[test]
fn random_programs_equivalent_1000() {
    let mut ecalls = 0u32;
    for seed in 0..1000u64 {
        let prog = random_program(seed * 7919 + 13, false, false);
        let r = assert_equiv(prog, 1_000_000, &format!("seed {seed}"));
        if r == ExitReason::Ecall {
            ecalls += 1;
        }
    }
    // Sanity: the generator must overwhelmingly produce clean runs.
    assert!(ecalls >= 990, "only {ecalls}/1000 programs ran to ecall");
}

#[test]
fn random_faulting_programs_equivalent() {
    let mut faults = 0u32;
    for seed in 0..200u64 {
        let prog = random_program(seed * 104729 + 7, true, false);
        let r = assert_equiv(prog, 1_000_000, &format!("faulty seed {seed}"));
        if matches!(r, ExitReason::Fault(_)) {
            faults += 1;
        }
    }
    assert!(faults > 20, "fault injection never fired ({faults}/200)");
}

#[test]
fn random_jalr_programs_equivalent() {
    for seed in 0..200u64 {
        let prog = random_program(seed * 31337 + 3, false, true);
        assert_equiv(prog, 1_000_000, &format!("jalr seed {seed}"));
    }
}

#[test]
fn jalr_into_fused_strip_interior_falls_back() {
    // x30 → the `mul` in the middle of a fused scalar-MAC strip.
    let prog = vec![
        Instr::OpImm { op: AluOp::Add, rd: 30, rs1: 0, imm: 4 * 4 },
        Instr::OpImm { op: AluOp::Add, rd: 23, rs1: 0, imm: 1024 },
        Instr::Load { op: LoadOp::Lb, rd: 5, rs1: 23, offset: 0 },
        Instr::Load { op: LoadOp::Lb, rd: 6, rs1: 23, offset: 1 },
        Instr::MulDiv { op: MulOp::Mul, rd: 7, rs1: 5, rs2: 6 },
        Instr::Op { op: AluOp::Add, rd: 8, rs1: 8, rs2: 7 },
        Instr::Jalr { rd: 1, rs1: 30, offset: 0 }, // → instr 4 (mul)
        Instr::Ecall,
    ];
    // The jalr lands on instruction 4, which sits inside the fused
    // strip [2..6); the engine must replay via the reference
    // interpreter. The mul→add→jalr sequence then loops until the
    // cycle budget trips — both interpreters must stop in exactly the
    // same state.
    let r = assert_equiv(prog, 10_000, "jalr-interior");
    assert_eq!(r, ExitReason::MaxCycles);
}

#[test]
fn misaligned_static_branch_falls_back_whole_program() {
    // offset 6 defeats pc pre-resolution; both paths floor pc/4.
    let prog = vec![
        Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 1 },
        Instr::Branch { op: BranchOp::Beq, rs1: 0, rs2: 0, offset: 6 },
        Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 5, imm: 10 },
        Instr::Ecall,
    ];
    assert_equiv(prog, 10_000, "misaligned-branch");
}

#[test]
fn infinite_loop_hits_budget_identically() {
    let prog = vec![Instr::Jal { rd: 0, offset: 0 }];
    let r = assert_equiv(prog, 1_000, "jal-self");
    assert_eq!(r, ExitReason::MaxCycles);
}

#[test]
fn fall_off_end_and_wild_branch_are_illegal_pc() {
    let r = assert_equiv(
        vec![Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 1 }],
        1_000,
        "fall-off-end",
    );
    assert!(matches!(r, ExitReason::IllegalPc(_)));
    let r = assert_equiv(
        vec![Instr::Branch { op: BranchOp::Beq, rs1: 0, rs2: 0, offset: 1024 }, Instr::Ecall],
        1_000,
        "wild-branch",
    );
    assert!(matches!(r, ExitReason::IllegalPc(_)));
}

#[test]
fn fault_inside_fused_load_mac_strip() {
    // x21 = MEM-4: the first act word loads, the second faults.
    let prog = vec![
        Instr::OpImm { op: AluOp::Add, rd: 21, rs1: 0, imm: MEM as i32 - 4 },
        Instr::OpImm { op: AluOp::Add, rd: 22, rs1: 0, imm: 1024 },
        Instr::Load { op: LoadOp::Lw, rd: 12, rs1: 21, offset: 0 },
        Instr::Load { op: LoadOp::Lw, rd: 13, rs1: 21, offset: 4 },
        Instr::Load { op: LoadOp::Lw, rd: 11, rs1: 22, offset: 0 },
        Instr::NnMac { mode: MacMode::W4, rd: 10, rs1: 12, rs2: 11 },
        Instr::Ecall,
    ];
    let r = assert_equiv(prog, 10_000, "fault-in-strip");
    assert!(matches!(r, ExitReason::Fault(_)));
}
