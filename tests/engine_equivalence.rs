//! Differential property test: the pre-decoded micro-op engine
//! (`sim::engine`) must be observationally identical to the reference
//! interpreter (`Core::step`) — same exit reason, registers, pc, memory
//! image, memory-access counters, perf counters (including exact cycle
//! totals) and MAC-unit counters — over randomly generated RV32IM +
//! `nn_mac` programs.
//!
//! The generator emits every instruction class, the exact inner-loop
//! strips the engine fuses (packed-MAC, scalar-MAC, loop latches with
//! backward branches, the requant epilogue in its canonical branchless
//! form, counted reduction loops with clean and clobbered loop
//! registers), deliberate memory faults, and `jalr`s that land near
//! (or inside) fused strips to exercise the dynamic-entry fallback.
//! Programs terminate by construction: control flow is forward-only
//! except bounded counted loops.

use mpnn::isa::*;
use mpnn::rng::Rng;
use mpnn::sim::{Core, CoreConfig, EngineStats, ExitReason};

const MEM: usize = 4096;

/// Run `prog` on both interpreters and assert identical outcomes.
/// Returns the exit reason and the engine's superinstruction hit
/// counters (to assert the fused paths actually ran).
fn assert_equiv(prog: Vec<Instr>, max_cycles: u64, tag: &str) -> (ExitReason, EngineStats) {
    let cfg = CoreConfig { mem_size: MEM, ..Default::default() };
    let mut legacy = Core::new(cfg, prog.clone(), 0);
    let mut fast = Core::new(cfg, prog, 0);
    let cp = fast.compile();
    let r1 = legacy.run(max_cycles);
    let r2 = fast.run_engine(&cp, max_cycles);
    assert_eq!(r1, r2, "{tag}: exit reason");
    assert_eq!(legacy.regs, fast.regs, "{tag}: registers");
    assert_eq!(legacy.pc, fast.pc, "{tag}: pc");
    assert_eq!(legacy.perf, fast.perf, "{tag}: perf counters");
    assert_eq!(legacy.mem.loads, fast.mem.loads, "{tag}: mem loads");
    assert_eq!(legacy.mem.stores, fast.mem.stores, "{tag}: mem stores");
    assert_eq!(legacy.mem.load_bytes, fast.mem.load_bytes, "{tag}: load bytes");
    assert_eq!(legacy.mem.store_bytes, fast.mem.store_bytes, "{tag}: store bytes");
    assert_eq!(
        legacy.mem.read_bytes(0, MEM),
        fast.mem.read_bytes(0, MEM),
        "{tag}: memory image"
    );
    assert_eq!(legacy.mac_unit.total_macs, fast.mac_unit.total_macs, "{tag}: mac count");
    assert_eq!(legacy.mac_unit.total_issues, fast.mac_unit.total_issues, "{tag}: mac issues");
    assert_eq!(legacy.engine_stats, EngineStats::default(), "{tag}: legacy ran no engine");
    (r1, fast.engine_stats)
}

/// Registers the generator may clobber with arbitrary values.
const SCRATCH: [u8; 10] = [5, 6, 7, 8, 10, 11, 12, 13, 14, 15];
/// Data-pointer registers (initialised to in-bounds word addresses).
const BASES: [u8; 6] = [21, 22, 23, 24, 25, 26];
/// Loop counter (only the latch template touches it).
const CTR: u8 = 9;
/// Jump-target register (holds the final ecall's pc).
const JREG: u8 = 30;
/// Out-of-bounds pointer (initialised past the end of memory).
const OOB: u8 = 27;

struct Gen {
    rng: Rng,
    prog: Vec<Instr>,
}

impl Gen {
    fn pick<T: Copy>(&mut self, xs: &[T]) -> T {
        xs[(self.rng.next_u32() as usize) % xs.len()]
    }

    fn scratch(&mut self) -> u8 {
        let s = SCRATCH;
        self.pick(&s)
    }

    fn base(&mut self) -> u8 {
        let b = BASES;
        self.pick(&b)
    }

    fn alu_op(&mut self) -> AluOp {
        self.pick(&[
            AluOp::Add,
            AluOp::Sub,
            AluOp::Sll,
            AluOp::Slt,
            AluOp::Sltu,
            AluOp::Xor,
            AluOp::Srl,
            AluOp::Sra,
            AluOp::Or,
            AluOp::And,
        ])
    }

    /// The requant epilogue in the exact canonical shape
    /// `kernels::requant::emit_requantize` emits (SRDHM chain, random
    /// rounding shift, branchless clamp, `mv`), over whatever random
    /// values the operand registers hold; roughly half the time with
    /// the trailing `sb` of the result that the kernels emit.
    fn emit_requant_epilogue(&mut self) {
        let (t0, t1, t2, t3) = (5u8, 6u8, 7u8, 8u8);
        let (acc, m, rnd, lo) = (10u8, 11u8, 12u8, 13u8);
        let shift = self.rng.range_i32(-4, 12);
        let p = &mut self.prog;
        p.push(Instr::MulDiv { op: MulOp::Mulh, rd: t0, rs1: acc, rs2: m });
        p.push(Instr::MulDiv { op: MulOp::Mul, rd: t1, rs1: acc, rs2: m });
        p.push(Instr::Lui { rd: t2, imm: 0x4000_0000 });
        p.push(Instr::Op { op: AluOp::Add, rd: t3, rs1: t1, rs2: t2 });
        p.push(Instr::Op { op: AluOp::Sltu, rd: t1, rs1: t3, rs2: t1 });
        p.push(Instr::OpImm { op: AluOp::Srl, rd: t3, rs1: t3, imm: 31 });
        p.push(Instr::OpImm { op: AluOp::Sll, rd: t0, rs1: t0, imm: 1 });
        p.push(Instr::Op { op: AluOp::Add, rd: t0, rs1: t0, rs2: t3 });
        p.push(Instr::OpImm { op: AluOp::Sll, rd: t1, rs1: t1, imm: 1 });
        p.push(Instr::Op { op: AluOp::Add, rd: t0, rs1: t0, rs2: t1 });
        if shift > 0 {
            p.push(Instr::Op { op: AluOp::Add, rd: t0, rs1: t0, rs2: rnd });
            p.push(Instr::OpImm { op: AluOp::Sra, rd: t0, rs1: t0, imm: shift });
        } else if shift < 0 {
            p.push(Instr::OpImm { op: AluOp::Sll, rd: t0, rs1: t0, imm: -shift });
        }
        p.push(Instr::OpImm { op: AluOp::Add, rd: t1, rs1: 0, imm: 127 });
        p.push(Instr::Op { op: AluOp::Slt, rd: t2, rs1: t1, rs2: t0 });
        p.push(Instr::Op { op: AluOp::Sub, rd: t2, rs1: 0, rs2: t2 });
        p.push(Instr::Op { op: AluOp::Xor, rd: t3, rs1: t0, rs2: t1 });
        p.push(Instr::Op { op: AluOp::And, rd: t3, rs1: t3, rs2: t2 });
        p.push(Instr::Op { op: AluOp::Xor, rd: t0, rs1: t0, rs2: t3 });
        p.push(Instr::Op { op: AluOp::Slt, rd: t2, rs1: t0, rs2: lo });
        p.push(Instr::Op { op: AluOp::Sub, rd: t2, rs1: 0, rs2: t2 });
        p.push(Instr::Op { op: AluOp::Xor, rd: t3, rs1: t0, rs2: lo });
        p.push(Instr::Op { op: AluOp::And, rd: t3, rs1: t3, rs2: t2 });
        p.push(Instr::Op { op: AluOp::Xor, rd: t0, rs1: t0, rs2: t3 });
        p.push(Instr::OpImm { op: AluOp::Add, rd: acc, rs1: t0, imm: 0 });
        if self.rng.next_u32() % 2 == 0 {
            let off = (self.rng.next_u32() % 64) as i32;
            p.push(Instr::Store { op: StoreOp::Sb, rs1: 25, rs2: acc, offset: off });
        }
    }

    /// A bounded reduction loop whose body is a single fusible strip —
    /// the counted-loop shape. Variants: 0 = packed LoadMac body
    /// (clean), 1 = scalar-MAC body (clean), 2 = scalar-MAC body that
    /// clobbers its own (bumped) base pointer, forcing the engine's
    /// re-evaluating guard path. Variant 2 chases loaded bytes as
    /// addresses and may fault, so it only runs in `faulty` mode.
    fn emit_counted_loop(&mut self, faulty: bool) {
        let variant = self.rng.next_u32() % if faulty { 3 } else { 2 };
        let count = 1 + (self.rng.next_u32() % 4) as i32;
        self.prog.push(Instr::OpImm { op: AluOp::Add, rd: CTR, rs1: 0, imm: count });
        let body_start = self.prog.len();
        match variant {
            0 => {
                // k× lw + lw + nn_mac, then optional base bump + counter.
                let mode = self.pick(&[MacMode::W8, MacMode::W4, MacMode::W2]);
                let k = mode.activation_regs() as usize;
                let act_off = ((self.rng.next_u32() % 32) * 4) as i32;
                for j in 0..k {
                    self.prog.push(Instr::Load {
                        op: LoadOp::Lw,
                        rd: 12 + j as u8,
                        rs1: 21,
                        offset: act_off + 4 * j as i32,
                    });
                }
                let w_off = ((self.rng.next_u32() % 32) * 4) as i32;
                self.prog.push(Instr::Load { op: LoadOp::Lw, rd: 11, rs1: 22, offset: w_off });
                self.prog.push(Instr::NnMac { mode, rd: 10, rs1: 12, rs2: 11 });
                if self.rng.next_u32() % 2 == 0 {
                    self.prog.push(Instr::OpImm { op: AluOp::Add, rd: 21, rs1: 21, imm: 4 });
                }
            }
            _ => {
                // lb/lb/mul/add; variant 2 loads the a-side byte *into*
                // its own base pointer x23 (a bumped register), which
                // defeats trip-count prediction.
                let ra = if variant == 2 { 23u8 } else { 5u8 };
                let a_off = (self.rng.next_u32() % 128) as i32;
                let b_off = (self.rng.next_u32() % 128) as i32;
                self.prog.push(Instr::Load { op: LoadOp::Lb, rd: ra, rs1: 23, offset: a_off });
                self.prog.push(Instr::Load { op: LoadOp::Lb, rd: 6, rs1: 24, offset: b_off });
                self.prog.push(Instr::MulDiv { op: MulOp::Mul, rd: 7, rs1: ra, rs2: 6 });
                self.prog.push(Instr::Op { op: AluOp::Add, rd: 8, rs1: 8, rs2: 7 });
                self.prog.push(Instr::OpImm { op: AluOp::Add, rd: 23, rs1: 23, imm: 1 });
            }
        }
        self.prog.push(Instr::OpImm { op: AluOp::Add, rd: CTR, rs1: CTR, imm: -1 });
        let branch_at = self.prog.len();
        self.prog.push(Instr::Branch {
            op: BranchOp::Blt,
            rs1: 0,
            rs2: CTR,
            offset: -4 * (branch_at - body_start) as i32,
        });
        if variant != 0 {
            // x23 drifted (or was clobbered outright): restore it to an
            // aligned in-bounds base so later random loads/stores off
            // it behave. Same constant on both interpreters.
            self.prog.push(Instr::OpImm { op: AluOp::Add, rd: 23, rs1: 0, imm: 1280 });
        }
    }

    /// One random body item; may emit several instructions.
    fn emit_item(&mut self, faulty: bool) {
        match self.rng.next_u32() % 16 {
            0 => {
                let (op, rd, rs1) = (self.alu_op(), self.scratch(), self.scratch());
                let op = if op == AluOp::Sub { AluOp::Add } else { op }; // OP-IMM has no sub
                let imm = self.rng.range_i32(-2048, 2047);
                self.prog.push(Instr::OpImm { op, rd, rs1, imm });
            }
            1 => {
                let (op, rd) = (self.alu_op(), self.scratch());
                let (rs1, rs2) = (self.scratch(), self.scratch());
                self.prog.push(Instr::Op { op, rd, rs1, rs2 });
            }
            2 => {
                let op = self.pick(&[
                    MulOp::Mul,
                    MulOp::Mulh,
                    MulOp::Mulhsu,
                    MulOp::Mulhu,
                    MulOp::Div,
                    MulOp::Divu,
                    MulOp::Rem,
                    MulOp::Remu,
                ]);
                let (rd, rs1, rs2) = (self.scratch(), self.scratch(), self.scratch());
                self.prog.push(Instr::MulDiv { op, rd, rs1, rs2 });
            }
            3 => {
                let rd = self.scratch();
                let imm = (self.rng.next_u32() & 0xffff_f000) as i32;
                if self.rng.next_u32() % 2 == 0 {
                    self.prog.push(Instr::Lui { rd, imm });
                } else {
                    self.prog.push(Instr::Auipc { rd, imm });
                }
            }
            4 => {
                // In-bounds load of a random width.
                let op = self.pick(&[LoadOp::Lb, LoadOp::Lbu, LoadOp::Lh, LoadOp::Lhu, LoadOp::Lw]);
                let width = match op {
                    LoadOp::Lb | LoadOp::Lbu => 1,
                    LoadOp::Lh | LoadOp::Lhu => 2,
                    LoadOp::Lw => 4,
                };
                let offset = ((self.rng.next_u32() % 128) * width) as i32 & !(width as i32 - 1);
                let (rd, rs1) = (self.scratch(), self.base());
                self.prog.push(Instr::Load { op, rd, rs1, offset });
            }
            5 => {
                let op = self.pick(&[StoreOp::Sb, StoreOp::Sh, StoreOp::Sw]);
                let width = match op {
                    StoreOp::Sb => 1,
                    StoreOp::Sh => 2,
                    StoreOp::Sw => 4,
                };
                let offset = ((self.rng.next_u32() % 128) * width) as i32;
                let (rs1, rs2) = (self.base(), self.scratch());
                self.prog.push(Instr::Store { op, rs1, rs2, offset });
            }
            6 => {
                // Standalone nn_mac on whatever the registers hold.
                let mode = self.pick(&[MacMode::W8, MacMode::W4, MacMode::W2]);
                let k = mode.activation_regs() as u8;
                let rd = self.scratch();
                let rs1 = 10 + (self.rng.next_u32() % (17 - k as u32)) as u8; // rs1+k <= 27
                let rs2 = self.scratch();
                self.prog.push(Instr::NnMac { mode, rd, rs1, rs2 });
            }
            7 => {
                let csr = self.pick(&[
                    csr::MCYCLE,
                    csr::MINSTRET,
                    csr::MHPM_LOADS,
                    csr::MHPM_STORES,
                    csr::MHPM_MACS,
                ]);
                let rd = self.scratch();
                self.prog.push(Instr::Csr { op: CsrOp::Rs, rd, rs1: 0, csr });
            }
            8 => self.prog.push(Instr::Fence),
            9 => {
                // Forward conditional branch over 1..=4 instructions.
                let op = self.pick(&[
                    BranchOp::Beq,
                    BranchOp::Bne,
                    BranchOp::Blt,
                    BranchOp::Bge,
                    BranchOp::Bltu,
                    BranchOp::Bgeu,
                ]);
                let (rs1, rs2) = (self.scratch(), self.scratch());
                let d = 1 + (self.rng.next_u32() % 4) as i32;
                self.prog.push(Instr::Branch { op, rs1, rs2, offset: 4 * (d + 1) });
                for _ in 0..d {
                    self.emit_simple();
                }
            }
            10 => {
                // Forward jal over 1..=3 instructions.
                let d = 1 + (self.rng.next_u32() % 3) as i32;
                let rd = if self.rng.next_u32() % 2 == 0 { 0 } else { 1 };
                self.prog.push(Instr::Jal { rd, offset: 4 * (d + 1) });
                for _ in 0..d {
                    self.emit_simple();
                }
            }
            11 => {
                // The packed-kernel strip the engine fuses.
                let mode = self.pick(&[MacMode::W8, MacMode::W4, MacMode::W2]);
                let k = mode.activation_regs() as usize;
                let act_rd = 12u8; // x12..x15
                let act_base = 21u8;
                let act_off = ((self.rng.next_u32() % 64) * 4) as i32;
                for j in 0..k {
                    self.prog.push(Instr::Load {
                        op: LoadOp::Lw,
                        rd: act_rd + j as u8,
                        rs1: act_base,
                        offset: act_off + 4 * j as i32,
                    });
                }
                let w_off = ((self.rng.next_u32() % 64) * 4) as i32;
                self.prog.push(Instr::Load { op: LoadOp::Lw, rd: 11, rs1: 22, offset: w_off });
                self.prog.push(Instr::NnMac { mode, rd: 10, rs1: act_rd, rs2: 11 });
            }
            12 => {
                // The scalar baseline MAC strip.
                let a_off = (self.rng.next_u32() % 256) as i32;
                let b_off = (self.rng.next_u32() % 256) as i32;
                self.prog.push(Instr::Load { op: LoadOp::Lb, rd: 5, rs1: 23, offset: a_off });
                self.prog.push(Instr::Load { op: LoadOp::Lb, rd: 6, rs1: 24, offset: b_off });
                self.prog.push(Instr::MulDiv { op: MulOp::Mul, rd: 7, rs1: 5, rs2: 6 });
                self.prog.push(Instr::Op { op: AluOp::Add, rd: 8, rs1: 8, rs2: 7 });
            }
            13 => self.emit_requant_epilogue(),
            14 => self.emit_counted_loop(faulty),
            _ => {
                if faulty && self.rng.next_u32() % 3 == 0 {
                    // Deliberate fault: out-of-bounds (x27 holds an
                    // address beyond memory) or misaligned.
                    if self.rng.next_u32() % 2 == 0 {
                        self.prog.push(Instr::Load {
                            op: LoadOp::Lw,
                            rd: self.scratch(),
                            rs1: OOB,
                            offset: 0,
                        });
                    } else {
                        self.prog.push(Instr::Store {
                            op: StoreOp::Sw,
                            rs1: self.base(),
                            rs2: self.scratch(),
                            offset: 2,
                        });
                    }
                } else {
                    // Bounded backward loop: the latch shape the engine
                    // fuses. Counter in x9; `blt x0, x9` exits cleanly
                    // even when entered with a stale counter.
                    let c = 1 + (self.rng.next_u32() % 3) as i32;
                    self.prog.push(Instr::OpImm { op: AluOp::Add, rd: CTR, rs1: 0, imm: c });
                    let bump = self.scratch();
                    self.prog.push(Instr::OpImm { op: AluOp::Add, rd: bump, rs1: bump, imm: 1 });
                    self.prog.push(Instr::OpImm { op: AluOp::Add, rd: CTR, rs1: CTR, imm: -1 });
                    self.prog.push(Instr::Branch {
                        op: BranchOp::Blt,
                        rs1: 0,
                        rs2: CTR,
                        offset: -8,
                    });
                }
            }
        }
    }

    /// A single always-safe instruction (used under skipped branches).
    fn emit_simple(&mut self) {
        let (rd, rs1) = (self.scratch(), self.scratch());
        let imm = self.rng.range_i32(-64, 64);
        self.prog.push(Instr::OpImm { op: AluOp::Add, rd, rs1, imm });
    }
}

/// Generate one random terminating program.
fn random_program(seed: u64, faulty: bool, with_jalr: bool) -> Vec<Instr> {
    let mut g = Gen { rng: Rng::new(seed), prog: Vec::new() };

    // Prologue. Slots 0–1 are patched with the final ecall's pc below
    // (lui + addi, so programs longer than 2 KiB still patch cleanly).
    g.prog.push(Instr::OpImm { op: AluOp::Add, rd: JREG, rs1: 0, imm: 0 });
    g.prog.push(Instr::OpImm { op: AluOp::Add, rd: JREG, rs1: JREG, imm: 0 });
    // x27 → the first address past the 4 KiB memory (fault pointer).
    g.prog.push(Instr::Lui { rd: OOB, imm: 0x1000 });
    for (i, &b) in BASES.iter().enumerate() {
        let addr = 1024 + 128 * i as i32 + ((g.rng.next_u32() % 16) * 4) as i32;
        g.prog.push(Instr::OpImm { op: AluOp::Add, rd: b, rs1: 0, imm: addr });
    }
    for &r in &SCRATCH {
        let imm = g.rng.range_i32(-2048, 2047);
        g.prog.push(Instr::OpImm { op: AluOp::Add, rd: r, rs1: 0, imm });
    }
    // Seed some data so loads see non-zero bytes.
    for j in 0..8 {
        let rs2 = g.scratch();
        g.prog.push(Instr::Store { op: StoreOp::Sw, rs1: 21, rs2, offset: 4 * j });
    }

    let items = 12 + (g.rng.next_u32() % 20) as usize;
    for i in 0..items {
        g.emit_item(faulty);
        if with_jalr && i == items / 2 {
            // Jump via x30 to (near) the final ecall; negative offsets
            // land just before it — possibly inside a fused strip,
            // exercising the dynamic-entry fallback.
            let off = -4 * (g.rng.next_u32() % 3) as i32;
            g.prog.push(Instr::Jalr { rd: 1, rs1: JREG, offset: off });
        }
    }
    g.prog.push(Instr::Ecall);

    // Patch x30 with the ecall pc via lui + addi (li splitting).
    let ecall_pc = 4 * (g.prog.len() as i32 - 1);
    let hi = ecall_pc.wrapping_add(0x800) & !0xfff;
    let lo = ecall_pc - hi;
    g.prog[0] = Instr::Lui { rd: JREG, imm: hi };
    g.prog[1] = Instr::OpImm { op: AluOp::Add, rd: JREG, rs1: JREG, imm: lo };
    g.prog
}

#[test]
fn random_programs_equivalent_1000() {
    let mut ecalls = 0u32;
    let mut hits = EngineStats::default();
    for seed in 0..1000u64 {
        let prog = random_program(seed * 7919 + 13, false, false);
        let (r, st) = assert_equiv(prog, 1_000_000, &format!("seed {seed}"));
        hits.add(&st);
        if r == ExitReason::Ecall {
            ecalls += 1;
        }
    }
    // Sanity: the generator must overwhelmingly produce clean runs.
    assert!(ecalls >= 990, "only {ecalls}/1000 programs ran to ecall");
    // ... and actually exercise every fused superinstruction class,
    // including the new requant epilogue and counted loops.
    assert!(hits.load_mac > 0, "LoadMac never fused/executed: {hits:?}");
    assert!(hits.scalar_mac > 0, "ScalarMac never fused/executed: {hits:?}");
    assert!(hits.latch > 0, "Latch never fused/executed: {hits:?}");
    assert!(hits.requant > 0, "Requant never fused/executed: {hits:?}");
    assert!(hits.counted_loops > 0, "counted loops never entered: {hits:?}");
    assert!(hits.counted_iters > 0, "counted loops never iterated: {hits:?}");
}

#[test]
fn random_faulting_programs_equivalent() {
    let mut faults = 0u32;
    for seed in 0..200u64 {
        let prog = random_program(seed * 104729 + 7, true, false);
        let (r, _) = assert_equiv(prog, 1_000_000, &format!("faulty seed {seed}"));
        if matches!(r, ExitReason::Fault(_)) {
            faults += 1;
        }
    }
    assert!(faults > 20, "fault injection never fired ({faults}/200)");
}

#[test]
fn random_jalr_programs_equivalent() {
    for seed in 0..200u64 {
        let prog = random_program(seed * 31337 + 3, false, true);
        assert_equiv(prog, 1_000_000, &format!("jalr seed {seed}"));
    }
}

#[test]
fn jalr_into_fused_strip_interior_falls_back() {
    // x30 → the `mul` in the middle of a fused scalar-MAC strip.
    let prog = vec![
        Instr::OpImm { op: AluOp::Add, rd: 30, rs1: 0, imm: 4 * 4 },
        Instr::OpImm { op: AluOp::Add, rd: 23, rs1: 0, imm: 1024 },
        Instr::Load { op: LoadOp::Lb, rd: 5, rs1: 23, offset: 0 },
        Instr::Load { op: LoadOp::Lb, rd: 6, rs1: 23, offset: 1 },
        Instr::MulDiv { op: MulOp::Mul, rd: 7, rs1: 5, rs2: 6 },
        Instr::Op { op: AluOp::Add, rd: 8, rs1: 8, rs2: 7 },
        Instr::Jalr { rd: 1, rs1: 30, offset: 0 }, // → instr 4 (mul)
        Instr::Ecall,
    ];
    // The jalr lands on instruction 4, which sits inside the fused
    // strip [2..6); the engine must replay via the reference
    // interpreter. The mul→add→jalr sequence then loops until the
    // cycle budget trips — both interpreters must stop in exactly the
    // same state.
    let (r, st) = assert_equiv(prog, 10_000, "jalr-interior");
    assert_eq!(r, ExitReason::MaxCycles);
    assert!(st.fallbacks > 0, "dynamic strip entry must count as a fallback");
}

#[test]
fn misaligned_static_branch_falls_back_whole_program() {
    // offset 6 defeats pc pre-resolution; both paths floor pc/4.
    let prog = vec![
        Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 1 },
        Instr::Branch { op: BranchOp::Beq, rs1: 0, rs2: 0, offset: 6 },
        Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 5, imm: 10 },
        Instr::Ecall,
    ];
    assert_equiv(prog, 10_000, "misaligned-branch");
}

#[test]
fn infinite_loop_hits_budget_identically() {
    let prog = vec![Instr::Jal { rd: 0, offset: 0 }];
    let (r, _) = assert_equiv(prog, 1_000, "jal-self");
    assert_eq!(r, ExitReason::MaxCycles);
}

#[test]
fn fall_off_end_and_wild_branch_are_illegal_pc() {
    let (r, _) = assert_equiv(
        vec![Instr::OpImm { op: AluOp::Add, rd: 5, rs1: 0, imm: 1 }],
        1_000,
        "fall-off-end",
    );
    assert!(matches!(r, ExitReason::IllegalPc(_)));
    let (r, _) = assert_equiv(
        vec![Instr::Branch { op: BranchOp::Beq, rs1: 0, rs2: 0, offset: 1024 }, Instr::Ecall],
        1_000,
        "wild-branch",
    );
    assert!(matches!(r, ExitReason::IllegalPc(_)));
}

#[test]
fn fault_inside_fused_load_mac_strip() {
    // x21 = MEM-4: the first act word loads, the second faults.
    let prog = vec![
        Instr::OpImm { op: AluOp::Add, rd: 21, rs1: 0, imm: MEM as i32 - 4 },
        Instr::OpImm { op: AluOp::Add, rd: 22, rs1: 0, imm: 1024 },
        Instr::Load { op: LoadOp::Lw, rd: 12, rs1: 21, offset: 0 },
        Instr::Load { op: LoadOp::Lw, rd: 13, rs1: 21, offset: 4 },
        Instr::Load { op: LoadOp::Lw, rd: 11, rs1: 22, offset: 0 },
        Instr::NnMac { mode: MacMode::W4, rd: 10, rs1: 12, rs2: 11 },
        Instr::Ecall,
    ];
    let (r, _) = assert_equiv(prog, 10_000, "fault-in-strip");
    assert!(matches!(r, ExitReason::Fault(_)));
}

#[test]
fn clobbered_counted_loop_takes_guard_path() {
    // The strip body writes x8, which is also a latch bump register:
    // trip-count prediction is unsound, so the engine must take the
    // re-evaluating guard path — and still match the interpreter.
    let prog = vec![
        Instr::OpImm { op: AluOp::Add, rd: 9, rs1: 0, imm: 4 }, // counter
        Instr::OpImm { op: AluOp::Add, rd: 23, rs1: 0, imm: 1024 },
        Instr::OpImm { op: AluOp::Add, rd: 24, rs1: 0, imm: 1032 },
        Instr::Load { op: LoadOp::Lb, rd: 5, rs1: 23, offset: 0 }, // 3: loop head
        Instr::Load { op: LoadOp::Lb, rd: 6, rs1: 24, offset: 0 },
        Instr::MulDiv { op: MulOp::Mul, rd: 7, rs1: 5, rs2: 6 },
        Instr::Op { op: AluOp::Add, rd: 8, rs1: 8, rs2: 7 },
        Instr::OpImm { op: AluOp::Add, rd: 8, rs1: 8, imm: 1 }, // bump == body reg
        Instr::OpImm { op: AluOp::Add, rd: 9, rs1: 9, imm: -1 },
        Instr::Branch { op: BranchOp::Blt, rs1: 0, rs2: 9, offset: -24 }, // → instr 3
        Instr::Ecall,
    ];
    let (r, st) = assert_equiv(prog, 10_000, "clobbered-counted-loop");
    assert_eq!(r, ExitReason::Ecall);
    assert!(st.counted_loops > 0, "clobbered loop still runs natively: {st:?}");
    assert_eq!(st.counted_iters, 3, "4 trips = 1 dispatched body + 3 native: {st:?}");
}

#[test]
fn jalr_into_counted_loop_strip_interior_falls_back() {
    // A counted reduction loop (LoadMac body + latch), then a jalr that
    // lands on the weight `lw` *inside* the strip: the engine must run
    // the loop natively, then replay the dynamic entry on the
    // reference interpreter. From there the lw→nn_mac→addi→jalr chain
    // re-enters forever, so both interpreters must trip the budget in
    // exactly the same state.
    let prog = vec![
        Instr::OpImm { op: AluOp::Add, rd: 30, rs1: 0, imm: 5 * 4 }, // → instr 5
        Instr::OpImm { op: AluOp::Add, rd: 21, rs1: 0, imm: 1024 },
        Instr::OpImm { op: AluOp::Add, rd: 22, rs1: 0, imm: 1100 },
        Instr::OpImm { op: AluOp::Add, rd: 9, rs1: 0, imm: 2 }, // counter
        Instr::Load { op: LoadOp::Lw, rd: 12, rs1: 21, offset: 0 }, // 4: loop head
        Instr::Load { op: LoadOp::Lw, rd: 11, rs1: 22, offset: 0 }, // 5: interior
        Instr::NnMac { mode: MacMode::W8, rd: 10, rs1: 12, rs2: 11 }, // 6
        Instr::OpImm { op: AluOp::Add, rd: 9, rs1: 9, imm: -1 }, // 7
        Instr::Branch { op: BranchOp::Blt, rs1: 0, rs2: 9, offset: -16 }, // 8 → instr 4
        Instr::Jalr { rd: 1, rs1: 30, offset: 0 }, // 9 → instr 5
        Instr::Ecall,
    ];
    let (r, st) = assert_equiv(prog, 10_000, "jalr-into-counted-loop");
    assert_eq!(r, ExitReason::MaxCycles);
    assert!(st.counted_loops > 0, "loop must run on the counted path first: {st:?}");
    assert!(st.fallbacks > 0, "dynamic strip entry must fall back: {st:?}");
}

/// The acceptance shape for the packed kernels: chunk-looped mode
/// kernels and scalar baselines must light up the `Requant` and
/// counted-loop counters while staying bit-identical to the reference
/// interpreter end to end.
#[test]
fn packed_kernels_exercise_requant_and_counted_loops() {
    use mpnn::kernels::dense::{build_baseline, build_mode, DenseSpec};
    use mpnn::nn::quant::Requant;

    let rq = Requant::from_real_scale(0.004);
    let looped = build_mode(
        MacMode::W4,
        DenseSpec { in_dim: 2304, out_dim: 3, rq, relu: true, out_i32: false },
    );
    let baseline = build_baseline(DenseSpec {
        in_dim: 64,
        out_dim: 4,
        rq,
        relu: false,
        out_i32: false,
    });
    for (kp, tag) in [(looped, "dense-mode-looped"), (baseline, "dense-baseline")] {
        let mem_size = kp.mem_size as usize;
        let cfg = CoreConfig { mem_size, ..Default::default() };
        let mut legacy = Core::new(cfg, kp.prog.clone(), 0);
        let mut fast = Core::new(cfg, kp.prog, 0);
        let cp = fast.compile();
        let census = cp.fusion_census();
        assert!(census[3] > 0, "{tag}: no Requant ops fused ({census:?})");
        assert!(census[4] > 0, "{tag}: no counted loops formed ({census:?})");
        let r1 = legacy.run(u64::MAX);
        let r2 = fast.run_engine(&cp, u64::MAX);
        assert_eq!(r1, ExitReason::Ecall, "{tag}");
        assert_eq!(r1, r2, "{tag}: exit reason");
        assert_eq!(legacy.regs, fast.regs, "{tag}: registers");
        assert_eq!(legacy.pc, fast.pc, "{tag}: pc");
        assert_eq!(legacy.perf, fast.perf, "{tag}: perf counters");
        assert_eq!(legacy.mem.loads, fast.mem.loads, "{tag}: mem loads");
        assert_eq!(legacy.mem.stores, fast.mem.stores, "{tag}: mem stores");
        assert_eq!(
            legacy.mem.read_bytes(0, mem_size),
            fast.mem.read_bytes(0, mem_size),
            "{tag}: memory image"
        );
        assert_eq!(legacy.mac_unit.total_macs, fast.mac_unit.total_macs, "{tag}: macs");
        let st = fast.engine_stats;
        assert!(st.requant > 0, "{tag}: Requant never executed ({st:?})");
        assert!(st.counted_loops > 0, "{tag}: counted loop never entered ({st:?})");
        assert!(st.counted_iters > 0, "{tag}: counted loop never iterated ({st:?})");
        assert_eq!(st.fallbacks, 0, "{tag}: kernel run must not fall back ({st:?})");
    }
}
