//! ISS-backed accuracy evaluation (`IssEval`) integration tests.
//!
//! The evaluator's whole point is that accuracy, cycles and the
//! host-vs-ISS divergence metric come from the *same*
//! `run_model_batch` executions, so the tests pin three properties:
//!
//! 1. on a small synthetic model, host and ISS evaluators agree
//!    *exactly* (the ISS kernels are bit-exact vs the host reference),
//!    and the differential check reads zero;
//! 2. under a deliberate requant mismatch the divergence metric must
//!    be nonzero — the failure mode the backend exists to catch is
//!    actually caught;
//! 3. a coordinator sweep over the synthetic-zoo fallback reports
//!    accuracy, ISS-measured cycles and per-config divergence from the
//!    ISS executions.

use mpnn::coordinator::{AccuracyEval, Coordinator, HostEval, IssEval};
use mpnn::models::format::{load_or_fallback, LoadedModel};
use mpnn::models::infer::{calibrate, quantize_model, random_params};
use mpnn::models::synthetic::{generate, generate_split};
use mpnn::models::{analyze, LayerSpec, ModelSpec, Node};
use mpnn::nn::quant::Requant;
use std::path::Path;

/// A tiny conv→pool→dense model with a synthetic train/test task.
fn tiny_model(seed: u64) -> LoadedModel {
    let spec = ModelSpec {
        name: "tiny",
        input: [8, 8, 3],
        num_classes: 4,
        nodes: vec![
            Node::Layer(LayerSpec::Conv { cout: 8, k: 3, stride: 1, pad: 1, relu: true }),
            Node::Layer(LayerSpec::MaxPool2),
            Node::Layer(LayerSpec::Dense { out: 4, relu: false }),
        ],
    };
    let params = random_params(&spec, seed);
    let calib = generate(seed ^ 1, 8, spec.input, spec.num_classes, 0.4);
    let sites = calibrate(&spec, &params, &calib.images[..4]);
    let test = generate_split(seed ^ 1, seed ^ 2, 12, spec.input, spec.num_classes, 0.4);
    LoadedModel { spec, params, sites, float_acc: 1.0, test }
}

#[test]
fn host_and_iss_evaluators_agree_exactly() {
    let m = tiny_model(41);
    let n_layers = analyze(&m.spec).layers.len();
    for bits in [vec![8u32; n_layers], vec![4; n_layers], vec![2; n_layers]] {
        let qm = quantize_model(&m.spec, &m.params, &m.sites, &bits);

        let host = HostEval { test: m.test.clone() };
        let hr = host.evaluate(&qm, 12).unwrap();
        assert!(hr.iss_cycles.is_none() && hr.divergence.is_none());

        let iss = IssEval::new(m.test.clone(), 3);
        let ir = iss.evaluate(&qm, 12).unwrap();
        assert_eq!(ir.accuracy, hr.accuracy, "bits {bits:?}: host vs ISS accuracy");
        assert_eq!(ir.divergence, Some(0.0), "bits {bits:?}: bit-exact paths must not diverge");
        assert!(ir.iss_cycles.unwrap() > 0);
        assert!(ir.iss_mem_accesses.unwrap() > 0);
    }
}

#[test]
fn deliberate_requant_mismatch_surfaces_as_nonzero_divergence() {
    let m = tiny_model(43);
    let n_layers = analyze(&m.spec).layers.len();
    let qm = quantize_model(&m.spec, &m.params, &m.sites, &vec![8u32; n_layers]);

    // Perturbed host references: requant multiplier 0 on the first
    // layer zeroes every activation, so the reference's logits collapse
    // to the last layer's bias alone — a constant prediction per
    // reference. Two references with different constant classes cannot
    // both agree with the ISS on any input, so at least one divergence
    // reading is nonzero, deterministically.
    let divergence_vs_constant_class = |class: usize| -> f32 {
        let mut bad = qm.clone();
        bad.layers[0].rq = Requant { m: 0, shift: 0 };
        let last = bad.layers.last_mut().unwrap();
        for b in last.bias.iter_mut() {
            *b = 0;
        }
        last.bias[class] = 1_000;
        let mut iss = IssEval::new(m.test.clone(), 2);
        iss.reference = Some(bad);
        let r = iss.evaluate(&qm, 8).unwrap();
        r.divergence.expect("differential check enabled")
    };

    let d0 = divergence_vs_constant_class(0);
    let d1 = divergence_vs_constant_class(1);
    assert!(
        d0 > 0.0 || d1 > 0.0,
        "a mismatched requant reference must register divergence (got {d0} / {d1})"
    );
    assert!(d0 + d1 >= 0.999, "every input disagrees with at least one constant class");
}

#[test]
fn coordinator_sweep_reports_iss_cycles_and_divergence_per_config() {
    // Synthetic-zoo fallback model + ISS evaluator through the full
    // coordinator path (acceptance criterion of the ISS-eval issue).
    let model = load_or_fallback(Path::new("/nonexistent"), "lenet5", 9).unwrap();
    let test = model.test.clone();
    let c = Coordinator::new(model, Box::new(IssEval::new(test, 2)), 2).unwrap();
    assert_eq!(c.evaluator_name(), "iss");

    let n = c.analysis.layers.len();
    let configs = vec![vec![8u32; n], vec![4; n], vec![2; n]];
    let pts = c.run_sweep(&configs, 4).unwrap();
    assert_eq!(pts.len(), 3);
    for p in &pts {
        assert!((0.0..=1.0).contains(&p.accuracy));
        assert!(p.iss_cycles.unwrap() > 0, "ISS-measured cycles ride along with accuracy");
        assert_eq!(p.divergence, Some(0.0), "bit-exact host/ISS paths: zero divergence");
    }
    // The ISS-measured whole-model cycles must show the extension's
    // packing win, independently of the cycle model's composition.
    assert!(pts[2].iss_cycles.unwrap() < pts[0].iss_cycles.unwrap());
    // And no config was flagged divergent in the metrics.
    assert_eq!(c.metrics.diverged_configs.load(std::sync::atomic::Ordering::Relaxed), 0);
}
