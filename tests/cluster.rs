//! Multi-core cluster integration tests — the determinism contract of
//! the banked-TCDM cluster overlay (`sim::cluster`, ISSUE 9):
//!
//! 1. **`--cores 1` is the existing pipeline, byte for byte** — a
//!    coordinator pinned to the single-core cluster produces sweep
//!    points bit-identical to an untouched coordinator, and the fig6
//!    sweep JSON is byte-identical string-for-string.
//! 2. **The scheduler partition is deterministic** — cluster pricing is
//!    a pure function of the measured cycle table and `(units, cores)`,
//!    so independently built coordinators (different measurement worker
//!    counts included) agree on every composed cluster cost.
//! 3. **Cluster scaling behaves** — with cores > 1 the sweep reports
//!    per-core utilization and bank-conflict stalls, cycles never
//!    exceed the single-core totals, and accuracy is untouched (the
//!    cluster overlay prices, it does not re-evaluate).
//! 4. **Shards from different geometries never mix** — artifacts carry
//!    the cores axis and the merge refuses a mismatch typed.

use mpnn::coordinator::{Coordinator, HostEval};
use mpnn::dse::shard::{merge, point_divergence, ShardError, ShardSpec};
use mpnn::dse::{default_pinned, enumerate};
use mpnn::exp::{fig6, EvalBackend, ExpOpts};
use mpnn::models::analyze;
use mpnn::models::format::load_or_fallback;
use std::path::Path;

/// Host-evaluator coordinator over the synthetic lenet5 fallback,
/// built with an explicit measurement worker count.
fn coordinator(seed: u64, workers: usize) -> Coordinator {
    let model = load_or_fallback(Path::new("/nonexistent"), "lenet5", seed).unwrap();
    let test = model.test.clone();
    Coordinator::new(model, Box::new(HostEval { test }), workers).unwrap()
}

fn opts(seed: u64, cores: usize) -> ExpOpts {
    ExpOpts {
        artifacts: "/nonexistent".into(),
        eval_n: 8,
        budget: 9,
        backend: EvalBackend::Host,
        seed,
        cores,
        ..ExpOpts::default()
    }
}

#[test]
fn cores_one_is_bit_identical_to_the_untouched_pipeline() {
    let untouched = coordinator(19, 2);
    let mut pinned = coordinator(19, 2);
    pinned.set_cluster(1).unwrap();
    assert!(pinned.cluster().is_single());

    let n = analyze(&untouched.model.spec).layers.len();
    let configs = enumerate(n, &default_pinned(), 9, 19);
    let a = untouched.run_sweep(&configs, 8).unwrap();
    let b = pinned.run_sweep(&configs, 8).unwrap();
    assert_eq!(a.len(), b.len());
    for (i, (pa, pb)) in a.iter().zip(&b).enumerate() {
        if let Some((field, va, vb)) = point_divergence(pa, pb) {
            panic!("cores=1 point #{i} differs on `{field}`: {va} vs {vb}");
        }
    }
}

#[test]
fn cores_one_fig6_json_is_byte_identical() {
    // The harness-level form of the identity: `--cores 1` must write
    // exactly the pre-cluster fig6 document (the CI cluster-smoke job
    // `cmp`s the files; this is the in-process pin of the same bar).
    // cores: 0 exercises the ClusterConfig::new clamp to single-core.
    let default_sweep = fig6::sweep_model(&ExpOpts { cores: 0, ..opts(23, 1) }, "lenet5").unwrap();
    let pinned_sweep = fig6::sweep_model(&opts(23, 1), "lenet5").unwrap();
    let dj = fig6::sweep_json(&default_sweep).to_string();
    let pj = fig6::sweep_json(&pinned_sweep).to_string();
    assert_eq!(dj, pj, "--cores 1 fig6 JSON must match the default byte-for-byte");
    assert!(!dj.contains("\"cluster\""), "single-core JSON must not grow a cluster block");
    assert!(default_sweep.cluster.is_none() && pinned_sweep.cluster.is_none());
}

#[test]
fn cluster_pricing_is_deterministic_across_builds_and_workers() {
    // Two independently built coordinators — different measurement
    // fan-out widths — must agree on every composed cluster cost: the
    // measurement is seeded per (layer, variant) and the partition is a
    // pure function of (units, cores).
    let mut narrow = coordinator(29, 1);
    let mut wide = coordinator(29, 4);
    narrow.set_cluster(4).unwrap();
    wide.set_cluster(4).unwrap();

    let n = analyze(&narrow.model.spec).layers.len();
    for cfg in enumerate(n, &default_pinned(), 9, 29) {
        let a = narrow.cluster_cost(&cfg);
        let b = wide.cluster_cost(&cfg);
        assert_eq!(a.cost.cycles, b.cost.cycles, "cycles for {cfg:?}");
        assert_eq!(a.cost.mem_accesses, b.cost.mem_accesses);
        assert_eq!(a.perf, b.perf, "per-core accounting for {cfg:?}");
    }
}

#[test]
fn multi_core_sweep_reports_scaling_and_never_costs_more_cycles() {
    let single = fig6::sweep_model(&opts(37, 1), "lenet5").unwrap();
    let clustered = fig6::sweep_model(&opts(37, 4), "lenet5").unwrap();

    // The cluster report: right shape, visible contention, real win.
    let r = clustered.cluster.as_ref().expect("cores=4 sweep must carry a cluster report");
    assert_eq!(r.cores, 4);
    assert_eq!(r.utilization.len(), 4);
    assert!(r.utilization.iter().all(|&u| (0.0..=1.0).contains(&u)));
    assert!(r.utilization[0] > 0.0);
    assert!(r.bank_stalls > 0, "a real model's TCDM traffic must show contention");
    assert!(r.cycles <= r.cycles_single, "cluster baseline may never cost extra cycles");

    // Point-by-point against the single-core sweep: same configs in
    // the same order, identical accuracy (pricing never re-evaluates),
    // cycles non-increasing, total work conserved.
    assert_eq!(single.points.len(), clustered.points.len());
    for (s, c) in single.points.iter().zip(&clustered.points) {
        assert_eq!(s.config, c.config);
        assert_eq!(s.accuracy.to_bits(), c.accuracy.to_bits());
        assert_eq!(s.mem_accesses, c.mem_accesses);
        assert!(c.cycles <= s.cycles, "config {:?}: {} > {}", c.config, c.cycles, s.cycles);
    }

    // And the serialised sweep carries the cluster block.
    let j = fig6::sweep_json(&clustered).to_string();
    assert!(j.contains("\"cores\":4"));
    assert!(j.contains("\"cluster\""));
    assert!(j.contains("\"bank_conflict_stalls\""));
    assert!(j.contains("\"utilization\""));
}

#[test]
fn shards_from_different_cluster_geometries_refuse_to_merge() {
    // End to end through the fig6 shard writer: artifacts record the
    // cores axis, same-geometry shards merge cleanly, and a mixed
    // merge fails typed on `cores` — never silently blends machines.
    let o = ExpOpts { cores: 2, ..opts(43, 2) };
    let s0 = ShardSpec::parse("0/2").unwrap();
    let s1 = ShardSpec::parse("1/2").unwrap();
    let a0 = fig6::sweep_shard(&o, "lenet5", &s0).unwrap();
    let a1 = fig6::sweep_shard(&o, "lenet5", &s1).unwrap();
    assert_eq!(a0.cores, 2);

    let merged = merge(&[a0.clone(), a1.clone()]).unwrap();
    assert_eq!(merged.cores, 2);
    assert_eq!(merged.points.len(), merged.indices.len());

    // Re-run shard 1 on a different geometry: its artifact must carry
    // the new axis and poison the mixed merge.
    let a1_single = fig6::sweep_shard(&opts(43, 1), "lenet5", &s1).unwrap();
    assert_eq!(a1_single.cores, 1);
    match merge(&[a0, a1_single]) {
        Err(ShardError::Incompatible { field: "cores", .. }) => {}
        other => panic!("expected Incompatible(cores), got {other:?}"),
    }
}

#[test]
fn set_cluster_must_precede_attach_store() {
    use mpnn::store::ResultStore;
    let dir = std::env::temp_dir().join(format!("mpnn_cluster_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut c = coordinator(47, 2);
    c.attach_store(ResultStore::open(&dir).unwrap()).unwrap();
    let err = c.set_cluster(4).expect_err("store keys pin the cores axis");
    assert!(err.to_string().contains("attach_store"), "got: {err}");
    let _ = std::fs::remove_dir_all(&dir);
}
