//! Guided-search artifacts through the sharding subsystem:
//!
//! (a) guided [`ShardArtifact`]s round-trip their JSON byte-identically
//!     (strategy + rung knobs included), and legacy artifacts written
//!     before guided search existed still parse (as exhaustive);
//! (b) `--merge` refuses to mix guided and exhaustive artifacts — and
//!     guided artifacts with different rung schedules — with a typed
//!     [`ShardError::Incompatible`] on the `search` identity field;
//! (c) guided merges skip the exhaustive coverage requirement (a guided
//!     shard legitimately carries only its fully-evaluated subset) but
//!     still bound indices to the declared space and still catch
//!     point-level conflicts;
//! (d) end to end through the fig6 entry points: a guided sweep's
//!     Pareto front is bit-identical to the exhaustive sweep's, both
//!     unsharded and recombined from guided shard artifacts.

use mpnn::dse::search::SearchStrategy;
use mpnn::dse::shard::{
    merge, point_divergence, ShardArtifact, ShardError, ShardSpec, ShardStrategy,
};
use mpnn::dse::EvalPoint;
use mpnn::exp::fig6;
use mpnn::exp::{EvalBackend, ExpOpts};
use mpnn::sim::session::SessionSnapshot;

fn mk_point(ws: &[u32], acc: f32, cycles: u64) -> EvalPoint {
    EvalPoint {
        config: ws.to_vec(),
        accuracy: acc,
        mac_instructions: cycles / 2,
        cycles,
        mem_accesses: cycles / 3,
        iss_cycles: None,
        divergence: None,
    }
}

fn guided_artifact(
    spec: ShardSpec,
    total: usize,
    points: Vec<(usize, EvalPoint)>,
) -> ShardArtifact {
    ShardArtifact {
        model: "lenet5".to_string(),
        evaluator: "host".to_string(),
        spec,
        total_configs: total,
        seed: 7,
        eval_n: 16,
        float_acc: 0.875,
        baseline_instrs: 1234,
        search: SearchStrategy::Guided,
        rungs: 3,
        eta: 2,
        cores: 1,
        points,
        stats: SessionSnapshot::default(),
    }
}

// ------------------------------------------------ (a) schema round trip ---

#[test]
fn guided_artifact_round_trips_byte_identically() {
    let spec = ShardSpec::new(1, 3, ShardStrategy::Range).unwrap();
    let art = guided_artifact(
        spec,
        40,
        vec![(3, mk_point(&[8, 4, 2], 0.75, 1_000)), (17, mk_point(&[8, 2, 2], 0.5, 600))],
    );
    let text = art.to_json().to_string();
    assert!(text.contains("\"search\":\"guided\""), "{text}");
    assert!(text.contains("\"rungs\":3"), "{text}");
    assert!(text.contains("\"eta\":2"), "{text}");

    let back = ShardArtifact::from_str(&text).unwrap();
    assert_eq!(back, art);
    // Fixed point: parse → re-emit compares equal.
    assert_eq!(back.to_json().to_string(), text);
}

#[test]
fn exhaustive_artifacts_stay_lean_and_legacy_files_still_parse() {
    let spec = ShardSpec::new(0, 1, ShardStrategy::Hash).unwrap();
    let mut art = guided_artifact(spec, 4, vec![(0, mk_point(&[8, 8], 1.0, 9))]);
    art.search = SearchStrategy::Exhaustive;
    art.rungs = 0;
    art.eta = 0;
    let text = art.to_json().to_string();
    // The strategy tag is always present; the rung knobs only under
    // guided search (exhaustive files don't grow).
    assert!(text.contains("\"search\":\"exhaustive\""), "{text}");
    assert!(!text.contains("\"rungs\""), "{text}");
    assert!(!text.contains("\"eta\""), "{text}");
    assert_eq!(ShardArtifact::from_str(&text).unwrap(), art);

    // A version-1 artifact written before guided search existed has no
    // `search` field at all: it parses as an exhaustive sweep.
    let legacy = text.replace("\"search\":\"exhaustive\",", "");
    assert!(!legacy.contains("search"), "{legacy}");
    let back = ShardArtifact::from_str(&legacy).unwrap();
    assert_eq!(back.search, SearchStrategy::Exhaustive);
    assert_eq!((back.rungs, back.eta), (0, 0));
    assert_eq!(back, art);

    // A corrupted strategy tag is a typed schema error, not a default.
    let bad = text.replace("\"search\":\"exhaustive\"", "\"search\":\"psychic\"");
    match ShardArtifact::from_str(&bad) {
        Err(ShardError::Schema(e)) => assert_eq!(e.field, "search"),
        other => panic!("expected Schema(search), got {other:?}"),
    }
}

// ------------------------------------------- (b) strategies never mix ---

#[test]
fn merge_refuses_to_mix_guided_and_exhaustive_artifacts() {
    let s0 = ShardSpec::new(0, 2, ShardStrategy::Range).unwrap();
    let s1 = ShardSpec::new(1, 2, ShardStrategy::Range).unwrap();
    let guided = guided_artifact(s0, 8, vec![(0, mk_point(&[8, 2], 0.5, 10))]);
    let mut exhaustive = guided_artifact(s1, 8, vec![(4, mk_point(&[8, 4], 0.75, 20))]);
    exhaustive.search = SearchStrategy::Exhaustive;
    exhaustive.rungs = 0;
    exhaustive.eta = 0;
    match merge(&[guided.clone(), exhaustive]) {
        Err(ShardError::Incompatible { field: "search", a, b }) => {
            let both = format!("{a} / {b}");
            assert!(both.contains("guided") && both.contains("exhaustive"), "{both}");
        }
        other => panic!("expected Incompatible(search), got {other:?}"),
    }

    // Two guided runs with different rung schedules are different
    // sweeps too — their promotion decisions differ.
    let mut other_schedule = guided_artifact(s1, 8, vec![(4, mk_point(&[8, 4], 0.75, 20))]);
    other_schedule.rungs = 4;
    match merge(&[guided, other_schedule]) {
        Err(ShardError::Incompatible { field: "search", a, b }) => {
            assert!(a.contains("rungs 3") && b.contains("rungs 4"), "{a} / {b}");
        }
        other => panic!("expected Incompatible(search), got {other:?}"),
    }
}

// ------------------------------------------------ (c) guided coverage ---

#[test]
fn guided_merge_accepts_subsets_but_bounds_and_conflict_checks_them() {
    let s0 = ShardSpec::new(0, 2, ShardStrategy::Range).unwrap();
    let s1 = ShardSpec::new(1, 2, ShardStrategy::Range).unwrap();
    let a = guided_artifact(
        s0,
        10,
        vec![(0, mk_point(&[8, 2], 0.5, 10)), (2, mk_point(&[8, 4], 0.75, 20))],
    );
    let b = guided_artifact(
        s1,
        10,
        vec![(5, mk_point(&[8, 8], 0.875, 40)), (7, mk_point(&[4, 4], 0.25, 8))],
    );

    // 4 of 10 configs present: an exhaustive merge would be a Coverage
    // error; a guided merge is exactly this shape.
    let m = merge(&[a.clone(), b.clone()]).unwrap();
    assert_eq!(m.search, SearchStrategy::Guided);
    assert_eq!(m.indices, vec![0, 2, 5, 7]);
    assert_eq!(m.points.len(), 4);
    for (pos, &i) in m.indices.iter().enumerate() {
        let src = if i < 5 { &a } else { &b };
        let (_, original) = src.points.iter().find(|(pi, _)| *pi == i).unwrap();
        assert!(point_divergence(&m.points[pos], original).is_none(), "index {i}");
    }

    // An index outside the declared space is still refused.
    let oob = guided_artifact(s1, 10, vec![(12, mk_point(&[2, 2], 0.125, 4))]);
    match merge(&[a.clone(), oob]) {
        Err(ShardError::Coverage { expected: 10, first_missing: None, .. }) => {}
        other => panic!("expected Coverage, got {other:?}"),
    }

    // Disagreeing duplicates stay conflicts under guided merges.
    let mut clash = b.clone();
    clash.spec = s0;
    clash.points = vec![(2, mk_point(&[8, 4], 0.8125, 20))];
    match merge(&[a, clash]) {
        Err(ShardError::Conflict { global_index: 2, field: "accuracy", .. }) => {}
        other => panic!("expected Conflict at #2, got {other:?}"),
    }
}

// ------------------------------------------------- (d) fig6 end to end ---

#[test]
fn guided_fig6_front_is_bit_identical_to_exhaustive_sharded_or_not() {
    let exhaustive_opts = ExpOpts {
        artifacts: "/nonexistent".into(),
        eval_n: 8,
        budget: 27,
        backend: EvalBackend::Host,
        seed: 41,
        ..ExpOpts::default()
    };
    let guided_opts = ExpOpts {
        search: SearchStrategy::Guided,
        rungs: 3,
        eta: 2,
        ..exhaustive_opts.clone()
    };

    let ex = fig6::sweep_model(&exhaustive_opts, "lenet5").unwrap();
    assert_eq!(ex.search, SearchStrategy::Exhaustive);
    assert_eq!(ex.indices, (0..ex.points.len()).collect::<Vec<_>>());

    // Unsharded guided sweep: every retained point and the whole front
    // must be bit-identical to the oracle's.
    let gd = fig6::sweep_model(&guided_opts, "lenet5").unwrap();
    assert_eq!(gd.search, SearchStrategy::Guided);
    assert!(gd.points.len() <= ex.points.len());
    for (pos, &gi) in gd.indices.iter().enumerate() {
        if let Some((f, va, vb)) = point_divergence(&gd.points[pos], &ex.points[gi]) {
            panic!("guided point #{gi} differs on `{f}`: {va} vs {vb}");
        }
    }
    let gd_front_global: Vec<usize> = gd.front.iter().map(|&pos| gd.indices[pos]).collect();
    assert_eq!(gd_front_global, ex.front, "guided front != exhaustive front");

    // Bit-reproducible: a second guided run serialises identically.
    let gd2 = fig6::sweep_model(&guided_opts, "lenet5").unwrap();
    assert_eq!(fig6::sweep_json(&gd2).to_string(), fig6::sweep_json(&gd).to_string());

    // Sharded guided sweep: two hash shards, each searched on its own
    // slice, recombined through the same merge path — the front still
    // equals the exhaustive one (a global front point is non-dominated
    // in any subset containing it, so each shard's repair keeps it).
    let arts: Vec<ShardArtifact> = (0..2)
        .map(|i| {
            let spec = ShardSpec::new(i, 2, ShardStrategy::Hash).unwrap();
            let art = fig6::sweep_shard(&guided_opts, "lenet5", &spec).unwrap();
            // Cross the process boundary: round-trip the JSON schema.
            ShardArtifact::from_str(&art.to_json().to_string()).unwrap()
        })
        .collect();
    for a in &arts {
        assert_eq!(a.search, SearchStrategy::Guided);
        assert_eq!((a.rungs, a.eta), (3, 2));
    }
    let merged = fig6::sweep_from_artifacts(&guided_opts, &arts).unwrap();
    assert_eq!(merged.search, SearchStrategy::Guided);
    let merged_front_global: Vec<usize> =
        merged.front.iter().map(|&pos| merged.indices[pos]).collect();
    assert_eq!(merged_front_global, ex.front, "sharded-guided front != exhaustive front");
    for (&pos, &gi) in merged.front.iter().zip(&merged_front_global) {
        if let Some((f, va, vb)) = point_divergence(&merged.points[pos], &ex.points[gi]) {
            panic!("sharded-guided front point #{gi} differs on `{f}`: {va} vs {vb}");
        }
    }

    // And mixing one of those guided shards with an exhaustive shard of
    // the same sweep is refused at the merge layer.
    let spec0 = ShardSpec::new(0, 2, ShardStrategy::Hash).unwrap();
    let ex_shard = fig6::sweep_shard(&exhaustive_opts, "lenet5", &spec0).unwrap();
    match merge(&[arts[0].clone(), ex_shard]) {
        Err(ShardError::Incompatible { field: "search", .. }) => {}
        other => panic!("expected Incompatible(search), got {other:?}"),
    }
}
